//! End-to-end serving driver (the repository's headline validation run,
//! recorded in EXPERIMENTS.md): loads the real TinyLM artifacts, serves a
//! batch of requests through the full coordinator -> scheduler -> wave
//! index -> wave buffer -> PJRT pipeline in BOTH attention modes, and
//! reports latency, throughput, data movement and cross-mode agreement.
//! A third pass re-serves the wave workload under an arena capacity cap
//! sized BELOW the uncapped run's peak occupancy: admission control must
//! defer prefills, keep resident bytes under the cap at every step, and
//! still complete every request (DESIGN.md §2 "Admission & quotas").
//! A fourth pass re-serves it with the TIERED arena: the hot tier is
//! capped at ~40% of the uncapped peak and the cold spill tier absorbs
//! the overflow — no admission gate, zero deferrals, demote-then-retry
//! everywhere, tokens bit-identical to the single-tier run, and
//! promotions/demotions > 0 with hot-resident blocks ≤ cap at every
//! step (DESIGN.md §2 "Tiered arena & spill").
//!
//! A fifth pass serves N sessions over a COMMON prompt prefix with
//! cross-session prefix sharing armed (DESIGN.md §2 "Prefix sharing &
//! CoW"): tokens bit-identical to the unshared run, the prefix resident
//! once in the arena (dedup ratio ≈ N on the shared region), and a
//! capped re-run where the admission discount admits a session mix the
//! unshared gate defers.
//!
//! A sixth, online pass (DESIGN.md §2 "Online serving & preemption")
//! drives CHUNKED prefill interleaved with live decode steps, then
//! preempts a mid-decode session to the cold tier and resumes it —
//! both bit-identical to the uninterleaved, unpreempted run, with the
//! hot arena under a cap the unpreempted set exceeded while parked.
//!
//!     make artifacts && cargo run --release --example serve_e2e
//!
//! Flags: --requests N (default 4)  --prompt-len L (2048)  --max-new M (24)
//!        --tenants T (2)  --capacity-blocks C (0 = auto: 60% of peak)
//!        --online-modelled (artifact-free: the modelled 256k-midstream
//!        SLO scenario through the real scheduler's planning loop)

use retroinfer::config::{BufferConfig, CapacityConfig, SpillCodec, ZoneConfig};
use retroinfer::coordinator::{Action, Batcher, Request, Scheduler};
use retroinfer::engine::{live::structured_prompt, AttnMode, LiveEngine};
use retroinfer::kvcache::{ColdestFirst, DEFAULT_TENANT};
use retroinfer::runtime::default_artifacts_dir;
use retroinfer::util::cli::Args;
use retroinfer::workload::{run_online_serving, OnlineConfig, RequestSpec};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

struct ServeStats {
    out: HashMap<u64, Vec<i32>>,
    wall_s: f64,
    decode_tps: f64,
    hit_ratio: f64,
    peak_live_blocks: u64,
    deferrals: u64,
    demoted: u64,
    promoted: u64,
    cold_hits: u64,
    cold_staged: u64,
    overlap_pct: u64,
    spill_logical_peak: usize,
    spill_physical_peak: usize,
    compressed_peak: usize,
}

fn serve(
    mode: AttnMode,
    prompts: &[Vec<i32>],
    max_new: usize,
    tenants: usize,
    capacity_blocks: Option<usize>,
    spill: bool,
    codec: SpillCodec,
    pipelined: bool,
) -> anyhow::Result<ServeStats> {
    let dir = default_artifacts_dir();
    let mut eng = LiveEngine::new(&dir, mode)?;
    if spill {
        eng.enable_spill(Arc::new(ColdestFirst));
        // permissive accuracy floor: only the steady-zone rules gate
        // lossy placement (the codec choice carries the experiment)
        eng.set_spill_codec(codec, 0.0);
        // stage-decoupled decode pipeline is on by default under spill;
        // the serial control runs disarm it to prove tokens don't move
        eng.set_pipelined_decode(pipelined);
    }
    let mut sched = match capacity_blocks {
        Some(cap) if !spill => {
            eng.set_arena_capacity_blocks(Some(cap));
            // default knobs: 20% decode headroom, 1.5x footprint fudge
            Scheduler::with_admission(
                Batcher::new(&[1, 2, 4, 8], 8),
                Arc::clone(eng.arena()),
                eng.admission_config(&CapacityConfig::default()),
            )
        }
        Some(cap) => {
            // tiered: the hot cap is the engine's problem (demote, then
            // retry) — no occupancy gate, so nothing can defer forever
            eng.set_arena_capacity_blocks(Some(cap));
            Scheduler::new(Batcher::new(&[1, 2, 4, 8], 8))
        }
        None => Scheduler::new(Batcher::new(&[1, 2, 4, 8], 8)),
    };
    for (id, p) in prompts.iter().enumerate() {
        let tenant = (id % tenants.max(1)) as u32;
        sched.submit(Request::new(id as u64, p.clone(), max_new).with_tenant(tenant), 0.0);
    }
    let t0 = Instant::now();
    let (mut spill_log_peak, mut spill_phys_peak, mut comp_peak) = (0usize, 0usize, 0usize);
    while !sched.all_done() {
        match sched.next_action() {
            Action::Prefill(id) => {
                let (tenant, p) = {
                    let s = sched.session(id).unwrap();
                    (s.req.tenant, s.req.prompt.clone())
                };
                let tok = eng.prefill_for(id, tenant, &p)?;
                sched.prefill_done(id, tok, t0.elapsed().as_secs_f64());
            }
            Action::DecodeBatch(ids, bucket) => {
                let toks = eng.decode_step(&ids, bucket)?;
                let now = t0.elapsed().as_secs_f64();
                for (id, t) in ids.iter().zip(toks) {
                    sched.token_decoded(*id, t, now);
                }
            }
            // deferred prefills re-enter once reclamation below frees blocks
            Action::Defer => {}
            Action::Idle => break,
        }
        // the capped run's core invariant, checked at EVERY step (for
        // tiered runs this bounds the HOT tier; total live KV may —
        // and must, to mean anything — exceed it)
        if let Some(cap) = capacity_blocks {
            assert!(
                eng.arena().live_blocks() <= cap,
                "arena hot live blocks {} exceeded capacity {cap}",
                eng.arena().live_blocks()
            );
            assert!(
                eng.arena().resident_bytes() <= cap * eng.arena().block_bytes(),
                "arena hot resident bytes {} exceeded capacity",
                eng.arena().resident_bytes()
            );
        }
        spill_log_peak = spill_log_peak.max(eng.arena().spill().logical_bytes());
        spill_phys_peak = spill_phys_peak.max(eng.arena().spill().physical_bytes());
        comp_peak = comp_peak.max(eng.arena().spill().compressed_blocks());
        // Finished sessions hand their KV blocks back to the arena.
        for fid in sched.take_finished() {
            eng.finish_session(fid);
        }
    }
    assert_eq!(
        eng.arena().live_blocks(),
        0,
        "all sessions finished — every hot arena block must be reclaimed"
    );
    assert_eq!(
        eng.arena().cold_blocks(),
        0,
        "all sessions finished — every cold block must have been dropped"
    );
    assert_eq!(sched.n_rejections(), 0, "no request may be dropped");
    for s in sched.sessions() {
        assert_eq!(s.generated.len(), max_new, "request {} lost tokens", s.req.id);
    }
    let wall = t0.elapsed().as_secs_f64();
    let decode_tokens = eng.metrics.counter("decoded_tokens") as f64;
    let decode_wall: f64 =
        eng.metrics.mean("decode_step_s") * eng.metrics.count("decode_step_s") as f64;
    let out: HashMap<u64, Vec<i32>> =
        sched.sessions().map(|s| (s.req.id, s.generated.clone())).collect();
    Ok(ServeStats {
        out,
        wall_s: wall,
        decode_tps: decode_tokens / decode_wall.max(1e-9),
        hit_ratio: eng.buffer_hit_ratio(),
        peak_live_blocks: eng.metrics.gauge("arena_live_blocks_peak"),
        deferrals: sched.n_deferrals(),
        demoted: eng.arena().demoted_total(),
        promoted: eng.arena().promoted_total(),
        cold_hits: eng.metrics.counter("cold_hit_blocks"),
        cold_staged: eng.metrics.counter("cold_staged_blocks"),
        overlap_pct: eng.metrics.gauge("spill_overlap_pct"),
        spill_logical_peak: spill_log_peak,
        spill_physical_peak: spill_phys_peak,
        compressed_peak: comp_peak,
    })
}

struct PrefixStats {
    out: HashMap<u64, Vec<i32>>,
    peak_live_blocks: u64,
    peak_shared_blocks: usize,
    peak_shared_refs: usize,
    deferrals: u64,
    prefix_hits: u64,
    matched_tokens: u64,
}

/// Serve `prompts` (which share a long common prefix) through a
/// smaller-segment wave config, with prefix sharing armed or not.
/// Content-derived clustering seeds in BOTH modes make the token
/// streams bit-comparable.
fn serve_prefix(
    prompts: &[Vec<i32>],
    max_new: usize,
    capacity_blocks: Option<usize>,
    share: bool,
) -> anyhow::Result<PrefixStats> {
    let dir = default_artifacts_dir();
    // build segments at 512 tokens so a 2048-token prompt carries
    // several sealable chain links (the default live config clusters
    // whole prompts in one segment — nothing would be prefix-aligned)
    let zcfg = ZoneConfig {
        retrieval_frac: 0.5,
        estimation_frac: 1.0,
        build_segment: 512,
        update_segment: 256,
        ..ZoneConfig::default()
    };
    let bcfg = BufferConfig { cache_frac: 0.25, ..BufferConfig::default() };
    let mut eng = LiveEngine::with_config(&dir, AttnMode::Wave, zcfg, bcfg)?;
    let reg = if share {
        Some(eng.enable_prefix_sharing(16))
    } else {
        eng.set_content_seeds(true);
        None
    };
    let mut sched = match capacity_blocks {
        Some(cap) => {
            eng.set_arena_capacity_blocks(Some(cap));
            let mut s = Scheduler::with_admission(
                Batcher::new(&[1, 2, 4, 8], 8),
                Arc::clone(eng.arena()),
                eng.admission_config(&CapacityConfig::default()),
            );
            if let Some(r) = &reg {
                s.set_prefix_registry(Arc::clone(r));
            }
            s
        }
        None => Scheduler::new(Batcher::new(&[1, 2, 4, 8], 8)),
    };
    for (id, p) in prompts.iter().enumerate() {
        sched.submit(Request::new(id as u64, p.clone(), max_new), 0.0);
    }
    let t0 = Instant::now();
    let mut peak_shared_blocks = 0usize;
    let mut peak_shared_refs = 0usize;
    while !sched.all_done() {
        match sched.next_action() {
            Action::Prefill(id) => {
                let p = sched.session(id).unwrap().req.prompt.clone();
                let tok = eng.prefill(id, &p)?;
                sched.prefill_done(id, tok, t0.elapsed().as_secs_f64());
            }
            Action::DecodeBatch(ids, bucket) => {
                let toks = eng.decode_step(&ids, bucket)?;
                let now = t0.elapsed().as_secs_f64();
                for (id, t) in ids.iter().zip(toks) {
                    sched.token_decoded(*id, t, now);
                }
            }
            Action::Defer => {}
            Action::Idle => break,
        }
        if let Some(cap) = capacity_blocks {
            assert!(
                eng.arena().live_blocks() <= cap,
                "shared-prefix serve: live blocks {} exceeded cap {cap}",
                eng.arena().live_blocks()
            );
        }
        peak_shared_blocks = peak_shared_blocks.max(eng.arena().shared_blocks_live());
        peak_shared_refs = peak_shared_refs.max(eng.arena().shared_session_refs());
        for fid in sched.take_finished() {
            eng.finish_session(fid);
        }
    }
    assert_eq!(sched.n_rejections(), 0, "no request may be dropped");
    for s in sched.sessions() {
        assert_eq!(s.generated.len(), max_new, "request {} lost tokens", s.req.id);
    }
    // the registry keeps the prefix pinned past session exit; clearing
    // it must drain every refcount
    eng.clear_prefix_cache();
    assert_eq!(eng.arena().live_blocks(), 0, "prefix blocks must free at refcount zero");
    let out = sched.sessions().map(|s| (s.req.id, s.generated.clone())).collect();
    Ok(PrefixStats {
        out,
        peak_live_blocks: eng.metrics.gauge("arena_live_blocks_peak"),
        peak_shared_blocks,
        peak_shared_refs,
        deferrals: sched.n_deferrals(),
        prefix_hits: eng.metrics.counter("prefix_hits"),
        matched_tokens: eng.metrics.counter("prefix_matched_tokens"),
    })
}

/// Artifact-free modelled online pass (`--online-modelled`): the
/// acceptance scenario for chunked prefill + continuous batching — a
/// 256k-token prompt arriving at t = 50 ms while two interactive
/// sessions decode under a 50 ms TPOT target, in deterministic virtual
/// time through the real scheduler's `next_plan` loop. Chunked prefill
/// keeps every inter-token gap inside the per-step budget; the
/// monolithic prefill-eager baseline stalls the batch for the full
/// ~2.6 s prompt cost. Token streams are bit-identical across both
/// modes and across reruns.
fn run_online_modelled() -> anyhow::Result<()> {
    let spec = |arrive_s: f64, input: usize, output: usize, tenant: u32| RequestSpec {
        arrive_s,
        input_tokens: input,
        output_tokens: output,
        tenant,
        prefix_hash: None,
    };
    let mk = |chunked: bool| OnlineConfig {
        trace: vec![
            spec(0.0, 64, 200, 0),
            spec(0.0, 64, 200, 0),
            spec(0.05, 262_144, 4, 1),
        ],
        chunked,
        chunk_tokens: 512,
        prefill_token_s: 1e-5,
        decode_step_s: 5e-3,
        max_chunks_per_step: 2,
        max_batch: 4,
        slo_ttft_s: 0.05,
        slo_tpot_s: 0.05,
        slo_max_input: 1024,
        ..OnlineConfig::default()
    };
    let budget = mk(true).step_budget_s();
    let chunked = run_online_serving(&mk(true));
    let mono = run_online_serving(&mk(false));
    println!("# modelled online serving: 2 decode streams (TPOT 50ms) + 256k prompt at t=50ms");
    println!(
        "chunked    : max_gap={:.4}s (step budget {budget:.4}s) tpot_attain={:.3} \
         ttft_p50={:.4}s tput={:.0} tok/s",
        chunked.max_gap_s, chunked.tpot_attainment, chunked.ttft_p50_s, chunked.throughput_tok_s
    );
    println!(
        "monolithic : max_gap={:.4}s tpot_attain={:.3}",
        mono.max_gap_s, mono.tpot_attainment
    );
    assert!(
        chunked.max_gap_s <= budget + 1e-9,
        "chunked max gap {} exceeds the per-step budget {budget}",
        chunked.max_gap_s
    );
    assert_eq!(chunked.tpot_attainment, 1.0, "chunked must meet every TPOT gap");
    assert!(
        mono.max_gap_s > 2.0,
        "monolithic must stall for the 256k prefill (~2.6 s), saw {}",
        mono.max_gap_s
    );
    assert!(mono.tpot_attainment < 1.0, "monolithic must miss TPOT gaps");
    assert_eq!(chunked.tokens, mono.tokens, "scheduling mode must not change tokens");
    let rerun = run_online_serving(&mk(true));
    assert_eq!(rerun, chunked, "online runs must be bit-identical");
    println!("OK (modelled online)");
    Ok(())
}

/// One decode step over the subset of `ids` that still owes tokens,
/// recording each output; returns false once every id is complete.
fn decode_record(
    eng: &mut LiveEngine,
    toks: &mut HashMap<u64, Vec<i32>>,
    ids: &[u64],
    max_new: usize,
) -> anyhow::Result<bool> {
    let active: Vec<u64> = ids.iter().copied().filter(|i| toks[i].len() < max_new).collect();
    if active.is_empty() {
        return Ok(false);
    }
    let bucket = active.len().next_power_of_two();
    let out = eng.decode_step(&active, bucket)?;
    for (id, t) in active.iter().zip(out) {
        toks.get_mut(id).unwrap().push(t);
    }
    Ok(true)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false);
    if args.has("online-modelled") {
        return run_online_modelled();
    }
    let n_requests = args.usize_or("requests", 4);
    let prompt_len = args.usize_or("prompt-len", 2048);
    let max_new = args.usize_or("max-new", 24);
    let tenants = args.usize_or("tenants", 2);
    let cap_flag = args.usize_or("capacity-blocks", 0);

    println!("# end-to-end serve: {n_requests} requests x {prompt_len} prompt + {max_new} new tokens ({tenants} tenants)");
    let prompts: Vec<Vec<i32>> =
        (0..n_requests).map(|i| structured_prompt(prompt_len, 100 + i as u64)).collect();

    let full =
        serve(AttnMode::Full, &prompts, max_new, tenants, None, false, SpillCodec::Exact, true)?;
    println!("full attention : wall={:.2}s decode={:.1} tok/s", full.wall_s, full.decode_tps);

    let wave =
        serve(AttnMode::Wave, &prompts, max_new, tenants, None, false, SpillCodec::Exact, true)?;
    println!(
        "wave attention : wall={:.2}s decode={:.1} tok/s hit_ratio={:.3} peak_arena={} blocks",
        wave.wall_s, wave.decode_tps, wave.hit_ratio, wave.peak_live_blocks
    );

    // Overcommitted re-run: cap the arena below the uncapped peak so the
    // aggregate footprint exceeds it; admission must defer, never drop.
    // The floor of 2x one session's share keeps a single request always
    // admittable (usable capacity must cover its ~1.5x-fudged estimate).
    let peak = wave.peak_live_blocks as usize;
    let cap = if cap_flag > 0 {
        cap_flag
    } else {
        (peak * 3 / 5).max(2 * peak / n_requests.max(1)).max(1)
    };
    let capped = serve(
        AttnMode::Wave,
        &prompts,
        max_new,
        tenants,
        Some(cap),
        false,
        SpillCodec::Exact,
        true,
    )?;
    println!(
        "wave (capped)  : wall={:.2}s cap={cap} blocks peak={} blocks deferral_events={}",
        capped.wall_s, capped.peak_live_blocks, capped.deferrals
    );
    if n_requests > 1 && cap_flag == 0 {
        assert!(
            capped.deferrals > 0,
            "cap at 60% of peak must force deferrals (peak={})",
            wave.peak_live_blocks
        );
    }
    assert_eq!(capped.out.len(), n_requests, "capped serve dropped requests");
    // capped serving changes scheduling, never results: same prompts with
    // teacher-free greedy decode must produce the same token streams
    for (id, toks) in &wave.out {
        assert_eq!(toks, &capped.out[id], "capped serve changed request {id}'s tokens");
    }

    // Tiered re-run: hot tier at ~40% of the uncapped peak (floored so
    // one session still fits hot — a session under construction cannot
    // spill its own half-built heads), cold tier absorbing the rest.
    // No admission gate: a full hot tier demotes-then-retries, so
    // nothing can defer forever.
    let hot_cap = (peak * 2 / 5).max(peak / n_requests.max(1) + 8).max(1);
    let tiered = serve(
        AttnMode::Wave,
        &prompts,
        max_new,
        tenants,
        Some(hot_cap),
        true,
        SpillCodec::Exact,
        true,
    )?;
    println!(
        "wave (tiered)  : wall={:.2}s hot_cap={hot_cap} blocks demoted={} promoted={} \
         cold_hit_blocks={} (staged {} / overlap {}%) deferral_events={}",
        tiered.wall_s,
        tiered.demoted,
        tiered.promoted,
        tiered.cold_hits,
        tiered.cold_staged,
        tiered.overlap_pct,
        tiered.deferrals
    );
    assert_eq!(tiered.deferrals, 0, "tiered serving must never defer");
    assert_eq!(tiered.out.len(), n_requests, "tiered serve dropped requests");
    if n_requests > 1 {
        assert!(tiered.demoted > 0, "hot cap at 40% of peak must force demotions");
        assert!(tiered.promoted > 0, "decode must promote spilled clusters back");
    }
    // the tiered arena changes placement, never results: tokens must be
    // bit-identical to the single-tier run
    for (id, toks) in &wave.out {
        assert_eq!(toks, &tiered.out[id], "tiered serve changed request {id}'s tokens");
    }
    // every cold-tier gather in the pipelined run must have been served
    // from the I/O lane's staging area — the stage-decoupled executor
    // waits for a task's pages before gathering, so a stall here means
    // the pipeline silently fell back to synchronous reads
    if tiered.cold_hits > 0 {
        assert_eq!(
            tiered.cold_staged, tiered.cold_hits,
            "pipelined tiered serve read cold pages without staging them"
        );
    }

    // Serial-decode control: the SAME tiered run with the stage-
    // decoupled pipeline disarmed. Pipelining changes when cold-page
    // I/O happens, never what the gather returns: token streams must
    // be bit-identical, and the serial run's gathers never touch the
    // staging area.
    let tiered_serial = serve(
        AttnMode::Wave,
        &prompts,
        max_new,
        tenants,
        Some(hot_cap),
        true,
        SpillCodec::Exact,
        false,
    )?;
    println!(
        "wave (tiered, serial decode): wall={:.2}s cold_hit_blocks={} (staged {})",
        tiered_serial.wall_s, tiered_serial.cold_hits, tiered_serial.cold_staged
    );
    for (id, toks) in &tiered.out {
        assert_eq!(
            toks, &tiered_serial.out[id],
            "pipelined tiered serve changed request {id}'s tokens vs serial decode"
        );
    }

    // Tiered re-run with the int8 spill codec (DESIGN.md §2 "Spill
    // codecs"): the estimation head clears interior clusters for lossy
    // cold storage, so the cold tier's physical footprint drops to at
    // most half its logical size while every request still completes.
    let comp = serve(
        AttnMode::Wave,
        &prompts,
        max_new,
        tenants,
        Some(hot_cap),
        true,
        SpillCodec::Int8,
        true,
    )?;
    let comp_ratio =
        comp.spill_physical_peak as f64 / comp.spill_logical_peak.max(1) as f64;
    println!(
        "wave (tiered, int8): wall={:.2}s hot_cap={hot_cap} blocks demoted={} \
         cold bytes logical={} physical={} ratio={comp_ratio:.2} compressed_pages_peak={}",
        comp.wall_s,
        comp.demoted,
        comp.spill_logical_peak,
        comp.spill_physical_peak,
        comp.compressed_peak,
    );
    assert_eq!(comp.deferrals, 0, "tiered serving must never defer");
    assert_eq!(comp.out.len(), n_requests, "compressed tiered serve dropped requests");
    if n_requests > 1 {
        assert!(comp.compressed_peak > 0, "int8 codec never applied under spill");
        assert!(
            2 * comp.spill_physical_peak <= comp.spill_logical_peak,
            "int8 must at least halve cold bytes: physical {} vs logical {}",
            comp.spill_physical_peak,
            comp.spill_logical_peak
        );
    }
    // Serial-decode control for the lossy codec too: a staged page is
    // decoded from the same cold bytes the synchronous read decodes, so
    // pipelining and int8 compose without moving a single token.
    let comp_serial = serve(
        AttnMode::Wave,
        &prompts,
        max_new,
        tenants,
        Some(hot_cap),
        true,
        SpillCodec::Int8,
        false,
    )?;
    for (id, toks) in &comp.out {
        assert_eq!(
            toks, &comp_serial.out[id],
            "pipelined int8 tiered serve changed request {id}'s tokens vs serial decode"
        );
    }

    // Shared-prefix pass: N sessions over one 1792-token template plus a
    // distinct 256-token tail each. Chain links seal at 512-token
    // segments, so sessions 2..N graft the first 1540 tokens (sink + 3
    // segments) as shared refcounted blocks instead of recomputing them.
    let shared_n = n_requests.max(3);
    let template = structured_prompt(1792, 500);
    let shared_prompts: Vec<Vec<i32>> = (0..shared_n)
        .map(|i| {
            let mut p = template.clone();
            p.extend_from_slice(&structured_prompt(256, 600 + i as u64));
            p
        })
        .collect();
    let unshared = serve_prefix(&shared_prompts, max_new, None, false)?;
    let shared = serve_prefix(&shared_prompts, max_new, None, true)?;
    let dedup = shared.peak_shared_refs as f64 / shared.peak_shared_blocks.max(1) as f64;
    println!(
        "wave (shared-prefix): {} sessions, prefix_hits={} matched_tokens={} \
         peak_arena={} blocks (unshared {}) shared_peak={} blocks x{dedup:.1} refs",
        shared_n,
        shared.prefix_hits,
        shared.matched_tokens,
        shared.peak_live_blocks,
        unshared.peak_live_blocks,
        shared.peak_shared_blocks,
    );
    // sharing changes placement, never results: token streams are
    // bit-identical to the unshared (content-seeded) run
    for (id, toks) in &unshared.out {
        assert_eq!(toks, &shared.out[id], "prefix sharing changed request {id}'s tokens");
    }
    assert_eq!(shared.prefix_hits, shared_n as u64 - 1, "every follower must match");
    assert!(shared.peak_shared_blocks > 0);
    // the shared region is resident once, referenced by every live
    // session: dedup ratio ≈ N on the prefix
    assert!(
        dedup >= (shared_n - 1) as f64,
        "dedup ratio {dedup:.1} below expected ~{shared_n}x"
    );
    assert!(
        shared.peak_live_blocks < unshared.peak_live_blocks,
        "sharing must shrink the peak arena footprint"
    );
    // capped re-run: under a cap that makes the unshared gate defer,
    // the prefix-discounted gate admits the shared mix
    let upeak = unshared.peak_live_blocks as usize;
    let pcap = (upeak * 3 / 5).max(2 * upeak / shared_n.max(1)).max(1);
    let unshared_capped = serve_prefix(&shared_prompts, max_new, Some(pcap), false)?;
    let shared_capped = serve_prefix(&shared_prompts, max_new, Some(pcap), true)?;
    println!(
        "wave (shared-prefix, cap={pcap}): deferral_events shared={} unshared={}",
        shared_capped.deferrals, unshared_capped.deferrals
    );
    assert!(
        unshared_capped.deferrals > 0,
        "cap at 60% of peak must force deferrals without sharing"
    );
    assert!(
        shared_capped.deferrals < unshared_capped.deferrals,
        "the admission discount must admit a mix that defers unshared ({} vs {})",
        shared_capped.deferrals,
        unshared_capped.deferrals
    );
    for (id, toks) in &unshared.out {
        assert_eq!(toks, &shared_capped.out[id], "capped sharing changed request {id}");
    }

    // Online pass (a): CHUNKED prefill interleaved with live decode.
    // Two sessions decode a head start, then session 2's prompt
    // prefills in 256-token chunks with a decode step riding between
    // chunks — the bounded unit of work the SLO scheduler interleaves.
    // Every token stream must match the uninterleaved run (`wave.out`):
    // chunking changes latency structure, never content.
    if n_requests >= 3 {
        let dir3 = default_artifacts_dir();
        let mut eng = LiveEngine::new(&dir3, AttnMode::Wave)?;
        let mut toks: HashMap<u64, Vec<i32>> = HashMap::new();
        for id in 0..2u64 {
            let t = eng.prefill_for(id, DEFAULT_TENANT, &prompts[id as usize])?;
            toks.insert(id, vec![t]);
        }
        for _ in 0..4 {
            decode_record(&mut eng, &mut toks, &[0, 1], max_new)?;
        }
        let mut job = eng.prefill_start(2, DEFAULT_TENANT, &prompts[2])?;
        let mut chunks = 0u32;
        loop {
            let done = eng.prefill_advance(&mut job, 256)?;
            chunks += 1;
            decode_record(&mut eng, &mut toks, &[0, 1], max_new)?;
            if done {
                break;
            }
        }
        let first2 = eng.prefill_finish(job)?;
        toks.insert(2, vec![first2]);
        while decode_record(&mut eng, &mut toks, &[0, 1, 2], max_new)? {}
        for id in 0..3u64 {
            assert_eq!(
                toks[&id], wave.out[&id],
                "chunked-interleaved serve changed request {id}'s tokens"
            );
        }
        println!(
            "wave (online)  : prefill of request 2 rode along in {chunks} chunks — \
             all token streams bit-identical; {}",
            eng.metrics.summary("prefill_chunk_s")
        );
        for id in 0..3u64 {
            eng.finish_session(id);
        }
        assert_eq!(eng.arena().live_blocks(), 0, "online pass must reclaim all blocks");

        // Online pass (b): preempt a mid-decode session to the cold
        // tier, serve the survivors under a hot cap the 3-session set
        // exceeded, resume, and finish — bit-identical throughout.
        let mut eng2 = LiveEngine::new(&dir3, AttnMode::Wave)?;
        let mut ptoks: HashMap<u64, Vec<i32>> = HashMap::new();
        for id in 0..3u64 {
            let t = eng2.prefill_for(id, DEFAULT_TENANT, &prompts[id as usize])?;
            ptoks.insert(id, vec![t]);
        }
        let k = (max_new / 2).max(1);
        while ptoks[&2].len() < k {
            decode_record(&mut eng2, &mut ptoks, &[0, 1, 2], max_new)?;
        }
        let live3 = eng2.arena().live_blocks();
        let freed = eng2.preempt_session(2)?;
        assert!(freed > 0, "preemption must free hot blocks");
        assert!(eng2.is_parked(2) && eng2.parked_bytes() > 0);
        // while parked, the survivors fit under a cap the unpreempted
        // set violated — the capacity preemption exists to reclaim
        let cap = live3.saturating_sub(1).max(1);
        eng2.set_arena_capacity_blocks(Some(cap));
        for _ in 0..4 {
            decode_record(&mut eng2, &mut ptoks, &[0, 1], max_new)?;
            assert!(
                eng2.arena().live_blocks() <= cap,
                "parked serve exceeded the hot cap"
            );
        }
        eng2.set_arena_capacity_blocks(None);
        eng2.resume_session(2, DEFAULT_TENANT)?;
        assert!(!eng2.is_parked(2), "resume must unpark");
        while decode_record(&mut eng2, &mut ptoks, &[0, 1, 2], max_new)? {}
        for id in 0..3u64 {
            assert_eq!(
                ptoks[&id], wave.out[&id],
                "preempt/resume changed request {id}'s tokens"
            );
        }
        println!(
            "wave (preempt) : freed={freed} blocks at step {k}, survivors under cap={cap} \
             (3-session peak {live3}), resumed bit-identical"
        );
        for id in 0..3u64 {
            eng2.finish_session(id);
        }
        assert_eq!(eng2.arena().live_blocks(), 0, "preempt pass must reclaim all blocks");
    }

    // Cross-mode agreement, TEACHER-FORCED: replay full attention's token
    // history through the wave engine and compare each step's prediction
    // (autoregressive free-running diverges after any single mismatch, so
    // per-step prediction agreement is the meaningful fidelity metric).
    let dir2 = default_artifacts_dir();
    let mut wave_eng = LiveEngine::new(&dir2, AttnMode::Wave)?;
    let mut same = 0usize;
    let mut total = 0usize;
    for (i, p) in prompts.iter().enumerate() {
        let id = i as u64;
        let first = wave_eng.prefill(id, p)?;
        let ftoks = &full.out[&id];
        if first == ftoks[0] {
            same += 1;
        }
        total += 1;
        for step in 0..ftoks.len() - 1 {
            wave_eng.force_token(id, ftoks[step]);
            let pred = wave_eng.decode_step(&[id], 1)?[0];
            total += 1;
            if pred == ftoks[step + 1] {
                same += 1;
            }
        }
    }
    let agreement = same as f64 / total.max(1) as f64;
    println!("teacher-forced prediction agreement: {same}/{total} = {agreement:.3}");
    println!(
        "decode speed ratio (wave/full, CPU-interpreted kernels): {:.2}x",
        wave.decode_tps / full.decode_tps
    );
    if agreement < 0.5 {
        anyhow::bail!("wave decode agreement below 0.5 — accuracy regression");
    }
    println!("OK");
    Ok(())
}
