//! End-to-end serving driver (the repository's headline validation run,
//! recorded in EXPERIMENTS.md): loads the real TinyLM artifacts, serves a
//! batch of requests through the full coordinator -> scheduler -> wave
//! index -> wave buffer -> PJRT pipeline in BOTH attention modes, and
//! reports latency, throughput, data movement and cross-mode agreement.
//!
//!     make artifacts && cargo run --release --example serve_e2e
//!
//! Flags: --requests N (default 4)  --prompt-len L (2048)  --max-new M (24)

use retroinfer::coordinator::{Action, Batcher, Request, Scheduler};
use retroinfer::engine::{live::structured_prompt, AttnMode, LiveEngine};
use retroinfer::runtime::default_artifacts_dir;
use retroinfer::util::cli::Args;
use std::collections::HashMap;
use std::time::Instant;

fn serve(
    mode: AttnMode,
    prompts: &[Vec<i32>],
    max_new: usize,
) -> anyhow::Result<(HashMap<u64, Vec<i32>>, f64, f64, f64)> {
    let dir = default_artifacts_dir();
    let mut eng = LiveEngine::new(&dir, mode)?;
    let mut sched = Scheduler::new(Batcher::new(&[1, 2, 4, 8], 8));
    for (id, p) in prompts.iter().enumerate() {
        sched.submit(Request::new(id as u64, p.clone(), max_new), 0.0);
    }
    let t0 = Instant::now();
    while !sched.all_done() {
        match sched.next_action() {
            Action::Prefill(id) => {
                let p = sched.session(id).unwrap().req.prompt.clone();
                let tok = eng.prefill(id, &p)?;
                sched.prefill_done(id, tok, t0.elapsed().as_secs_f64());
            }
            Action::DecodeBatch(ids, bucket) => {
                let toks = eng.decode_step(&ids, bucket)?;
                let now = t0.elapsed().as_secs_f64();
                for (id, t) in ids.iter().zip(toks) {
                    sched.token_decoded(*id, t, now);
                }
            }
            Action::Idle => break,
        }
        // Finished sessions hand their KV blocks back to the arena.
        for fid in sched.take_finished() {
            eng.finish_session(fid);
        }
    }
    assert_eq!(
        eng.arena().live_blocks(),
        0,
        "all sessions finished — every arena block must be reclaimed"
    );
    let wall = t0.elapsed().as_secs_f64();
    let decode_tokens = eng.metrics.counter("decoded_tokens") as f64;
    let decode_wall: f64 =
        eng.metrics.mean("decode_step_s") * eng.metrics.count("decode_step_s") as f64;
    let out: HashMap<u64, Vec<i32>> =
        sched.sessions().map(|s| (s.req.id, s.generated.clone())).collect();
    Ok((out, wall, decode_tokens / decode_wall.max(1e-9), eng.buffer_hit_ratio()))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false);
    let n_requests = args.usize_or("requests", 4);
    let prompt_len = args.usize_or("prompt-len", 2048);
    let max_new = args.usize_or("max-new", 24);

    println!("# end-to-end serve: {n_requests} requests x {prompt_len} prompt + {max_new} new tokens");
    let prompts: Vec<Vec<i32>> =
        (0..n_requests).map(|i| structured_prompt(prompt_len, 100 + i as u64)).collect();

    let (full_out, full_wall, full_tps, _) = serve(AttnMode::Full, &prompts, max_new)?;
    println!("full attention : wall={full_wall:.2}s decode={full_tps:.1} tok/s");

    let (_wave_out, wave_wall, wave_tps, hit) = serve(AttnMode::Wave, &prompts, max_new)?;
    println!("wave attention : wall={wave_wall:.2}s decode={wave_tps:.1} tok/s hit_ratio={hit:.3}");

    // Cross-mode agreement, TEACHER-FORCED: replay full attention's token
    // history through the wave engine and compare each step's prediction
    // (autoregressive free-running diverges after any single mismatch, so
    // per-step prediction agreement is the meaningful fidelity metric).
    let dir2 = default_artifacts_dir();
    let mut wave = LiveEngine::new(&dir2, AttnMode::Wave)?;
    let mut same = 0usize;
    let mut total = 0usize;
    for (i, p) in prompts.iter().enumerate() {
        let id = i as u64;
        let first = wave.prefill(id, p)?;
        let ftoks = &full_out[&id];
        if first == ftoks[0] {
            same += 1;
        }
        total += 1;
        for step in 0..ftoks.len() - 1 {
            wave.force_token(id, ftoks[step]);
            let pred = wave.decode_step(&[id], 1)?[0];
            total += 1;
            if pred == ftoks[step + 1] {
                same += 1;
            }
        }
    }
    let agreement = same as f64 / total.max(1) as f64;
    println!("teacher-forced prediction agreement: {same}/{total} = {agreement:.3}");
    println!(
        "decode speed ratio (wave/full, CPU-interpreted kernels): {:.2}x",
        wave_tps / full_tps
    );
    if agreement < 0.5 {
        anyhow::bail!("wave decode agreement below 0.5 — accuracy regression");
    }
    println!("OK");
    Ok(())
}
