//! Long-generation scenario (the paper's Table 1 / reasoning workloads):
//! short prompt, long output, exercising the incremental index-update
//! path — new tokens enter the steady zone and are re-clustered into the
//! wave index once a full update segment accumulates (§4.2).
//!
//!     cargo run --release --example long_generation -- --new-tokens 600

use retroinfer::engine::{live::structured_prompt, AttnMode, LiveEngine};
use retroinfer::runtime::default_artifacts_dir;
use retroinfer::util::cli::Args;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false);
    let new_tokens = args.usize_or("new-tokens", 600);

    let dir = default_artifacts_dir();
    let mut eng = LiveEngine::new(&dir, AttnMode::Wave)?;
    let prompt = structured_prompt(2048, 9);
    eng.prefill(1, &prompt)?;
    println!("# long generation: prompt=2048, generating {new_tokens} tokens");

    let mut step_ms = Vec::new();
    for step in 0..new_tokens {
        let t0 = Instant::now();
        eng.decode_step(&[1], 1)?;
        step_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        if (step + 1) % 128 == 0 {
            let recent: f64 =
                step_ms[step.saturating_sub(127)..=step].iter().sum::<f64>() / 128.0;
            println!(
                "  step {:4}: ctx={} mean_step={recent:.1}ms hit_ratio={:.3}",
                step + 1,
                eng.session_len(1).unwrap(),
                eng.buffer_hit_ratio()
            );
        }
    }

    // Latency must stay stable as the index grows (update cost amortized:
    // the paper reports 0.2% decode overhead from index updates).
    let first_q: f64 = step_ms[..new_tokens / 4].iter().sum::<f64>() / (new_tokens / 4) as f64;
    let last_q: f64 =
        step_ms[3 * new_tokens / 4..].iter().sum::<f64>() / (new_tokens - 3 * new_tokens / 4) as f64;
    println!("first-quarter mean step: {first_q:.1}ms, last-quarter: {last_q:.1}ms");
    println!(
        "context grew 2048 -> {}; decode latency ratio {:.2}x",
        eng.session_len(1).unwrap(),
        last_q / first_q
    );
    if last_q > 3.0 * first_q {
        anyhow::bail!("decode latency degraded superlinearly under index updates");
    }
    println!("OK");
    Ok(())
}
