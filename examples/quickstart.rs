//! Quickstart: load the AOT artifacts, serve one request through the
//! wave-attention decode path, print the generated tokens and the
//! wave-buffer statistics.
//!
//!     make artifacts && cargo run --release --example quickstart

use retroinfer::engine::{live::structured_prompt, AttnMode, LiveEngine};
use retroinfer::runtime::default_artifacts_dir;

fn main() -> anyhow::Result<()> {
    let dir = default_artifacts_dir();
    println!("loading artifacts from {dir}");
    let mut engine = LiveEngine::new(&dir, AttnMode::Wave)?;

    // A 2048-token synthetic prompt (region-structured, like topical text).
    let prompt = structured_prompt(2048, 42);
    let first = engine.prefill(1, &prompt)?;
    println!("prefill done: context={} first_token={first}", prompt.len());

    let mut tokens = vec![first];
    for _ in 0..16 {
        let t = engine.decode_step(&[1], 1)?[0];
        tokens.push(t);
    }
    println!("generated: {tokens:?}");
    println!("{}", engine.metrics.summary("decode_step_s"));
    println!("wave-buffer hit ratio: {:.3}", engine.buffer_hit_ratio());
    println!(
        "pcie bytes: {} (vs full-attention equivalent {})",
        engine.metrics.counter("pcie_bytes"),
        // full attention would read the whole KV cache per step per layer
        16 * 4 * 2 * 2 * 2048 * 32 * 4
    );
    Ok(())
}
