//! Million-token scaling study (paper Figure 13d): replays the paper's
//! headline comparison at 1M context on the calibrated A100 hardware
//! model. Full attention, Quest and InfiniGen OOM; RetroInfer sustains an
//! order of magnitude over the surviving offload systems.
//!
//!     cargo run --release --example million_token_sim

use retroinfer::config::{HardwareSpec, ModelSpec};
use retroinfer::memsim::{self, profiles};
use retroinfer::util::bench::Table;

fn main() {
    let model = ModelSpec::llama3_8b();
    let hw = HardwareSpec::a100();
    println!("# 1M-token decode, {} on {}", model.name, hw.name);
    println!(
        "# KV cache at 1M: {:.0} GB (GPU capacity {} GB)",
        model.kv_cache_bytes(1 << 20, 1) as f64 / 1e9,
        hw.gpu_mem_bytes / (1 << 30)
    );

    let mut table = Table::new(&["system", "max_batch", "tok/s @ max", "vs retroinfer"]);
    let mut retro_tput = 0.0;
    let mut rows = Vec::new();
    for p in profiles::headline() {
        let ctx = 1 << 20;
        let mb = memsim::max_batch(&model, &hw, &p, ctx);
        let tput = if mb == 0 {
            0.0
        } else {
            memsim::decode_throughput(&model, &hw, &p, ctx, mb.min(64)).unwrap_or(0.0)
        };
        if p.name == "retroinfer" {
            retro_tput = tput;
        }
        rows.push((p.name, mb, tput));
    }
    for (name, mb, tput) in rows {
        table.row(vec![
            name.to_string(),
            if mb == 0 { "OOM".into() } else { mb.min(64).to_string() },
            if tput == 0.0 { "-".into() } else { format!("{tput:.1}") },
            if tput == 0.0 { "-".into() } else { format!("{:.1}x", retro_tput / tput) },
        ]);
    }
    table.print();
    println!("\npaper: RetroInfer 10.5x over MagicPIG, 12.2x over PQCache at 1M (Fig. 13d)");
}
