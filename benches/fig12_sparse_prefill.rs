//! Figure 12 reproduction: compatibility with sparse prefilling.
//! XAttention/MInference accelerate prefill by computing approximate
//! attention; downstream, the KV vectors the wave index ingests carry a
//! small approximation error. In the synthetic substrate (K/V given, not
//! computed) we model that as a bounded perturbation of the KV at the
//! accuracy level block-sparse prefill attains (~1-2% output error), and
//! measure the wave index's end accuracy with and without it
//! (DESIGN.md §1 substitution).
//!
//!     cargo bench --bench fig12_sparse_prefill

use retroinfer::baselines::{FullAttention, Retro, SparseSystem};
use retroinfer::util::bench::{quick_mode, Table};
use retroinfer::util::rng::Rng;
use retroinfer::util::stats::cosine;
use retroinfer::workload::tasks::{generate, needle_accuracy, TaskKind};

fn main() {
    let d = 32;
    let ctx = if quick_mode() { 8192 } else { 16384 };
    let n_queries = 8;
    // approximation error levels: exact, XAttention-like, MInference-like
    let variants = [("exact prefill", 0.0f32), ("xattention", 0.02), ("minference", 0.01)];

    println!("## Fig 12: wave-index accuracy with sparse-prefill KV perturbation (ctx={ctx})");
    let mut table = Table::new(&["prefill", "task", "needle_acc", "output_cos"]);
    let mut exact_by_task = std::collections::HashMap::new();
    let mut worst_drop = 0.0f64;
    for kind in [TaskKind::SingleNeedle, TaskKind::Qa] {
        let task = generate(kind, ctx, d, n_queries, 77);
        let wl = &task.workload;
        let budget = ((ctx as f64 * 0.018) as usize).max(8 * 16) + 68;

        // reference outputs from EXACT KV
        let mut full_outs = Vec::new();
        {
            let mut f = FullAttention::new(&wl.keys, &wl.vals, d);
            for q in &wl.queries {
                let mut o = vec![0.0; d];
                f.decode(q, ctx, &mut o);
                full_outs.push(o);
            }
        }

        for (name, eps) in variants {
            let mut rng = Rng::new(13);
            let mut perturb = |x: &[f32]| -> Vec<f32> {
                x.iter().map(|v| v * (1.0 + eps * rng_norm(&mut rng))).collect()
            };
            let keys = perturb(&wl.keys);
            let vals = perturb(&wl.vals);
            let mut sys = Retro::build_default(&keys, &vals, d, 5);
            let mut exact = Vec::new();
            let mut cs = 0.0;
            for (qi, q) in wl.queries.iter().enumerate() {
                let mut o = vec![0.0; d];
                let st = sys.decode(q, budget, &mut o);
                exact.push(st.exact_positions);
                cs += cosine(&o, &full_outs[qi]);
            }
            let acc = needle_accuracy(&exact, &wl.needles);
            let cos = cs / n_queries as f64;
            if eps == 0.0 {
                exact_by_task.insert(kind.name(), acc);
            } else {
                let base = exact_by_task[kind.name()];
                worst_drop = worst_drop.max(base - acc);
            }
            table.row(vec![
                name.to_string(),
                kind.name().to_string(),
                format!("{acc:.2}"),
                format!("{cos:.4}"),
            ]);
        }
    }
    table.print();
    // paper: only a 1.52% average accuracy drop with sparse prefilling
    // paper: only a 1.52% average drop; allow modest slack on the proxy
    assert!(
        worst_drop <= 0.25,
        "sparse prefill must not collapse accuracy: worst drop {worst_drop}"
    );
    println!("\nshape check OK: sparse-prefill perturbation costs only marginal accuracy");
}

fn rng_norm(rng: &mut Rng) -> f32 {
    rng.normal_f32()
}
