//! L3 hot-path micro-benchmarks (the §Perf baseline/after numbers in
//! EXPERIMENTS.md): centroid scoring + zone selection, execution-buffer
//! assembly, block-cache ops, segmented k-means build, tripartite merge.
//!
//!     cargo bench --bench hotpath

use retroinfer::attention::{tripartite_attention, TripartiteInputs};
use retroinfer::buffer::{ExecBuffer, WaveBuffer};
use retroinfer::config::{BufferConfig, CachePolicy, ZoneConfig};
use retroinfer::buffer::cache::BlockCache;
use retroinfer::index::{spherical_kmeans, SelectScratch, WaveIndex};
use retroinfer::util::bench::{bench, print_result, quick_mode};
use retroinfer::util::rng::Rng;
use retroinfer::util::threadpool::ThreadPool;
use std::sync::Arc;

fn main() {
    let budget = if quick_mode() { 120.0 } else { 400.0 };
    let d = 32;
    let n = 32768;
    let mut rng = Rng::new(1);
    let keys = rng.normal_vec(n * d);
    let vals = rng.normal_vec(n * d);
    let idx = WaveIndex::build(ZoneConfig::default(), d, 2048, &keys, &vals, 2);
    let m = idx.meta().m();
    let q = rng.normal_vec(d);
    let qg = rng.normal_vec(4 * d);

    // --- centroid scoring + top-r selection (per head per step) ----------
    let mut scratch = SelectScratch::default();
    let r = (m / 55).max(8);
    let e = (m as f64 * 0.232) as usize;
    print_result(&bench("select (m=2048, r+e)", 20, budget, || {
        std::hint::black_box(idx.select_with(&q, r, e, &mut scratch));
    }));
    print_result(&bench("select_group (G=4)", 20, budget, || {
        std::hint::black_box(idx.select_group_with(&qg, 4, r, e, &mut scratch));
    }));

    // --- execution-buffer assembly ----------------------------------------
    let pool = Arc::new(ThreadPool::new(2));
    let bcfg = BufferConfig::default();
    let cap = WaveBuffer::capacity_for(&bcfg, n, idx.store().tokens_per_block());
    let wb = WaveBuffer::new(bcfg, d, idx.store().tokens_per_block(), cap, pool);
    wb.register_index(&idx);
    let sel = idx.select_with(&q, r, e, &mut scratch);
    let mut eb = ExecBuffer::new(d);
    wb.assemble(&idx, &sel, &mut eb); // warm the cache
    wb.flush();
    print_result(&bench("exec-buffer assemble (warm)", 20, budget, || {
        std::hint::black_box(wb.assemble(&idx, &sel, &mut eb));
    }));
    wb.flush();

    // --- block cache ops ---------------------------------------------------
    let mut cache = BlockCache::new(CachePolicy::Lru, 4096, 2 * 8 * d);
    for k in 0..4096u64 {
        cache.admit(k);
    }
    let mut i = 0u64;
    print_result(&bench("cache admit+evict", 100, budget, || {
        let (_, ev) = cache.admit(4096 + i % 8192);
        std::hint::black_box(ev);
        i += 1;
    }));
    print_result(&bench("cache touch (LRU)", 100, budget, || {
        cache.touch(i % 4096);
        i += 1;
    }));

    // --- tripartite merge ----------------------------------------------------
    let exact: Vec<usize> = (0..512).collect();
    let estimated: Vec<usize> = (0..e.min(m)).collect();
    let inp = TripartiteInputs {
        d,
        keys: &keys,
        vals: &vals,
        exact: &exact,
        centroids: idx.meta().centroids_flat(),
        vsum: idx.meta().vsum_flat(),
        sizes: idx.meta().counts(),
        estimated: &estimated,
    };
    let mut out = vec![0.0f32; d];
    print_result(&bench("tripartite merge (512ex+est)", 20, budget, || {
        tripartite_attention(&q, &inp, &mut out);
    }));

    // --- live PJRT step components -------------------------------------------
    {
        use retroinfer::runtime::tinylm::{TinyLm, WaveInputs};
        use retroinfer::runtime::default_artifacts_dir;
        use retroinfer::tensor::Tensor;
        if let Ok(mut lm) = TinyLm::load(&default_artifacts_dir()) {
            let (kvh, dh, g) = (lm.cfg.kv_heads, lm.cfg.d_head, lm.cfg.group());
            let (ne, mc) = (lm.buckets.wave_ne, lm.buckets.wave_m);
            let mut wi = WaveInputs::zeros(1, kvh, ne, mc, dh);
            for h in 0..kvh {
                for t in 0..400 {
                    wi.kmask[h * ne + t] = 1.0;
                }
                for c in 0..120 {
                    wi.csize[h * mc + c] = 16.0;
                    wi.emask[h * mc + c] = 1.0;
                }
            }
            let qt = Tensor::zeros(&[1, kvh, g, dh]);
            lm.attn_wave(&qt, &wi).unwrap(); // compile
            print_result(&bench("pjrt attn_wave b=1", 3, budget, || {
                std::hint::black_box(lm.attn_wave(&qt, &wi).unwrap());
            }));
            let hid = Tensor::zeros(&[1, 256]);
            lm.qkv(0, &hid, &[0]).unwrap();
            print_result(&bench("pjrt qkv b=1", 3, budget, || {
                std::hint::black_box(lm.qkv(0, &hid, &[0]).unwrap());
            }));
            let ctx = Tensor::zeros(&[1, 256]);
            lm.mlp(0, &hid, &ctx).unwrap();
            print_result(&bench("pjrt mlp b=1", 3, budget, || {
                std::hint::black_box(lm.mlp(0, &hid, &ctx).unwrap());
            }));
        }
    }

    // --- segmented k-means build --------------------------------------------
    let seg_keys = &keys[..8192 * d];
    print_result(&bench("kmeans 8K segment (10 iters)", 1, budget * 2.0, || {
        std::hint::black_box(spherical_kmeans(seg_keys, d, 512, 10, true, 3));
    }));
}
