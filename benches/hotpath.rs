//! L3 hot-path micro-benchmarks (the §Perf baseline/after numbers in
//! EXPERIMENTS.md): centroid scoring + zone selection, execution-buffer
//! assembly, block-cache ops, segmented k-means build, tripartite merge.
//!
//!     cargo bench --bench hotpath

use retroinfer::attention::{
    tripartite_attention, tripartite_attention_in, MergeScratch, TripartiteInputs,
};
use retroinfer::buffer::cache::BlockCache;
use retroinfer::buffer::{ExecBuffer, WaveBuffer};
use retroinfer::config::{BufferConfig, CachePolicy, ZoneConfig};
use retroinfer::engine::{AssembleShape, BatchAssembler, HeadTask};
use retroinfer::index::{
    spherical_kmeans, spherical_kmeans_pooled, DecodeScratch, SelectScratch, WaveIndex,
};
use retroinfer::kernels::{self, Backend};
use retroinfer::kvcache::{BlockArena, ColdestFirst};
use retroinfer::metrics::Metrics;
use retroinfer::runtime::tinylm::WaveInputs;
use retroinfer::util::bench::{bench, print_result, quick_mode};
use retroinfer::util::rng::Rng;
use retroinfer::util::threadpool::ThreadPool;
use std::sync::Arc;

/// Print a grep-able scalar-vs-SIMD summary row; under `RI_ASSERT_SIMD=1`
/// a SIMD path slower than scalar is a failure (counted by the caller
/// and turned into a nonzero exit).
fn simd_row(name: &str, scalar_ns: f64, simd_ns: f64, fails: &mut usize) {
    let ratio = scalar_ns / simd_ns;
    println!(
        "# simd-speedup {name}: {ratio:.2}x (scalar {scalar_ns:.0} ns, simd {simd_ns:.0} ns)"
    );
    let assert_on = std::env::var("RI_ASSERT_SIMD").ok().as_deref() == Some("1");
    if assert_on && ratio < 1.0 {
        println!("# FAIL: simd slower than scalar on {name} ({ratio:.2}x)");
        *fails += 1;
    }
}

fn main() {
    let budget = if quick_mode() { 120.0 } else { 400.0 };
    let d = 32;
    let n = 32768;
    let mut rng = Rng::new(1);
    let keys = rng.normal_vec(n * d);
    let vals = rng.normal_vec(n * d);
    let idx = WaveIndex::build(ZoneConfig::default(), d, 2048, &keys, &vals, 2);
    let m = idx.meta().m();
    let q = rng.normal_vec(d);
    let qg = rng.normal_vec(4 * d);

    // --- centroid scoring + top-r selection (per head per step) ----------
    let mut scratch = SelectScratch::default();
    let r = (m / 55).max(8);
    let e = (m as f64 * 0.232) as usize;
    print_result(&bench("select (m=2048, r+e)", 20, budget, || {
        std::hint::black_box(idx.select_with(&q, r, e, &mut scratch));
    }));
    print_result(&bench("select_group (G=4)", 20, budget, || {
        std::hint::black_box(idx.select_group_with(&qg, 4, r, e, &mut scratch));
    }));

    // --- execution-buffer assembly ----------------------------------------
    let pool = Arc::new(ThreadPool::new(2));
    let bcfg = BufferConfig::default();
    let cap = WaveBuffer::capacity_for(&bcfg, n, idx.store().tokens_per_block());
    let wb = WaveBuffer::new(bcfg, d, idx.store().tokens_per_block(), cap, pool);
    wb.register_index(&idx);
    let sel = idx.select_with(&q, r, e, &mut scratch);
    let mut eb = ExecBuffer::new(d);
    wb.assemble(&idx, &sel, &mut eb); // warm the cache
    wb.flush();
    print_result(&bench("exec-buffer assemble (warm)", 20, budget, || {
        std::hint::black_box(wb.assemble(&idx, &sel, &mut eb));
    }));
    wb.flush();

    // --- parallel head fan-out (decode_step's per-layer assembly) ---------
    // b × kvh (row, head) assemblies, sequential on the caller thread vs
    // fanned across the engine pool. The acceptance bar: parallel beats
    // sequential for batch >= 4 at 8 kv-heads.
    {
        let kvh = 8;
        let group = 4;
        let n_ctx = 4096;
        let zcfg = ZoneConfig {
            retrieval_frac: 0.2,
            build_segment: 1024,
            update_segment: 128,
            kmeans_iters: 5,
            ..ZoneConfig::default()
        };
        let arena = BlockArena::shared(d, BufferConfig::default().block_bytes);
        let fan_pool = Arc::new(ThreadPool::new(8));
        let mut rng2 = Rng::new(42);
        let mut heads: Vec<(WaveIndex, WaveBuffer)> = Vec::new();
        for h in 0..kvh {
            let hk = rng2.normal_vec(n_ctx * d);
            let hv = rng2.normal_vec(n_ctx * d);
            let hidx = WaveIndex::build_in(&arena, zcfg.clone(), &hk, &hv, 100 + h as u64);
            let bcfg2 = BufferConfig { cache_frac: 0.25, ..BufferConfig::default() };
            let cap2 = WaveBuffer::capacity_for(&bcfg2, n_ctx, hidx.store().tokens_per_block());
            let hbuf = WaveBuffer::new(
                bcfg2,
                d,
                hidx.store().tokens_per_block(),
                cap2,
                Arc::clone(&fan_pool),
            );
            hbuf.register_index(&hidx);
            heads.push((hidx, hbuf));
        }
        let shape = AssembleShape { ne: 1024, m_cap: 256, d, group };
        let metrics = Metrics::new();
        let mut ratios = Vec::new();
        for &bsz in &[1usize, 4, 8] {
            let tasks: Vec<HeadTask> = (0..bsz * kvh)
                .map(|t| {
                    let (hidx, hbuf) = &heads[t % kvh];
                    HeadTask { index: hidx, buffer: hbuf }
                })
                .collect();
            let qg_all = rng2.normal_vec(bsz * kvh * group * d);
            let mut wi = WaveInputs::zeros(bsz, kvh, shape.ne, shape.m_cap, d);
            let seq = BatchAssembler::new(Arc::clone(&fan_pool), false);
            let par = BatchAssembler::new(Arc::clone(&fan_pool), true);
            // warm both caches and the scratch pools
            seq.assemble_into(&tasks, &qg_all, shape, &mut wi);
            par.assemble_into(&tasks, &qg_all, shape, &mut wi);
            let rs = bench(&format!("assemble b={bsz} kvh={kvh} sequential"), 5, budget, || {
                std::hint::black_box(seq.assemble_into(&tasks, &qg_all, shape, &mut wi));
            });
            print_result(&rs);
            let rp = bench(&format!("assemble b={bsz} kvh={kvh} parallel"), 5, budget, || {
                std::hint::black_box(par.assemble_into(&tasks, &qg_all, shape, &mut wi));
            });
            print_result(&rp);
            // metrics export sampled OUTSIDE the timed closures so the
            // seq/par ratio compares identical work
            let st = par.assemble_into(&tasks, &qg_all, shape, &mut wi);
            metrics.inc("pcie_bytes", st.pcie_bytes as u64);
            metrics.inc("hit_blocks", st.hit_blocks as u64);
            metrics.inc("assembled_heads", (bsz * kvh) as u64);
            println!(
                "  -> b={bsz}: parallel speedup {:.2}x over sequential",
                rs.mean_ns / rp.mean_ns
            );
            ratios.push((bsz, rs.mean_ns / rp.mean_ns));
        }
        metrics.set_gauge("arena_live_blocks", arena.live_blocks() as u64);
        metrics.set_gauge("arena_live_bytes", arena.live_bytes() as u64);
        drop(heads);
        metrics.set_gauge("arena_reclaimed_blocks_total", arena.reclaimed_total());
        println!("# fan-out metrics export:");
        for (name, v) in metrics.counters_snapshot() {
            println!("  counter {name} = {v}");
        }
        for (name, v) in metrics.gauges_snapshot() {
            println!("  gauge {name} = {v}");
        }
        for (bsz, r) in ratios {
            if bsz >= 4 && r < 1.0 {
                println!("  WARNING: batch {bsz} fan-out slower than sequential ({r:.2}x)");
            }
        }
    }

    // --- pipelined decode under spill pressure -----------------------------
    // The fan-out above is all-hot; here each head's clusters are
    // demoted until only a 20% / 40% / 60% hot cap survives, and the
    // spill store charges a 20 µs fault per cold page read. The
    // stage-decoupled executor issues those reads on the pool's I/O
    // lane while hot heads compute; the serial loop eats every stall
    // inline. Two epoch bumps per timed round drop all staged pages,
    // so each round pays the full cold working set. The `#`-prefixed
    // rows feed the EXPERIMENTS.md serial-vs-pipelined table;
    // RI_ASSERT_PIPELINE=1 turns "pipelined slower than serial" (and
    // "batched GQA scoring slower than per-head") into a nonzero
    // exit, same contract as RI_ASSERT_SIMD above.
    {
        let mut fails = 0usize;
        let assert_on = std::env::var("RI_ASSERT_PIPELINE").ok().as_deref() == Some("1");
        let kvh = 8;
        let group = 4;
        let n_ctx = 4096;
        let zcfg = ZoneConfig {
            retrieval_frac: 0.2,
            build_segment: 1024,
            update_segment: 128,
            kmeans_iters: 5,
            ..ZoneConfig::default()
        };
        let pipe_pool = Arc::new(ThreadPool::with_io_threads(8, 2));
        for &hot_pct in &[20usize, 40, 60] {
            let arena = BlockArena::shared(d, BufferConfig::default().block_bytes);
            arena.spill().set_read_fault(20, 0); // deterministic cold-read stall
            let mut rng3 = Rng::new(43);
            let mut heads: Vec<(WaveIndex, WaveBuffer)> = Vec::new();
            for h in 0..kvh {
                let hk = rng3.normal_vec(n_ctx * d);
                let hv = rng3.normal_vec(n_ctx * d);
                let mut hidx =
                    WaveIndex::build_in(&arena, zcfg.clone(), &hk, &hv, 200 + h as u64);
                let bcfg2 = BufferConfig { cache_frac: 0.25, ..BufferConfig::default() };
                let cap2 =
                    WaveBuffer::capacity_for(&bcfg2, n_ctx, hidx.store().tokens_per_block());
                let hbuf = WaveBuffer::new(
                    bcfg2,
                    d,
                    hidx.store().tokens_per_block(),
                    cap2,
                    Arc::clone(&pipe_pool),
                );
                hbuf.register_index(&hidx);
                let total_hot: usize =
                    (0..hidx.meta().m()).map(|c| hidx.cluster_hot_blocks(c as u32)).sum();
                // demote until only ~hot_pct% of the blocks stay hot
                let (_, demoted) =
                    hidx.demote_until(&ColdestFirst, total_hot * (100 - hot_pct) / 100);
                for c in &demoted {
                    hbuf.note_demoted(hidx.cluster_blocks(*c));
                }
                heads.push((hidx, hbuf));
            }
            let shape = AssembleShape { ne: 1024, m_cap: 256, d, group };
            let bsz = 4;
            let tasks: Vec<HeadTask> = (0..bsz * kvh)
                .map(|t| {
                    let (hidx, hbuf) = &heads[t % kvh];
                    HeadTask { index: hidx, buffer: hbuf }
                })
                .collect();
            let qg_all = rng3.normal_vec(bsz * kvh * group * d);
            let mut wi = WaveInputs::zeros(bsz, kvh, shape.ne, shape.m_cap, d);
            let cold_seq = BatchAssembler::new(Arc::clone(&pipe_pool), false);
            let mut cold_pipe = BatchAssembler::new(Arc::clone(&pipe_pool), true);
            cold_pipe.set_pipelined(true);
            cold_seq.assemble_into(&tasks, &qg_all, shape, &mut wi);
            cold_pipe.assemble_into(&tasks, &qg_all, shape, &mut wi);
            let rs =
                bench(&format!("decode-step hot={hot_pct}% b=4 kvh=8 serial"), 5, budget, || {
                    arena.begin_staging_epoch();
                    arena.begin_staging_epoch(); // drop every staged page
                    std::hint::black_box(cold_seq.assemble_into(&tasks, &qg_all, shape, &mut wi));
                });
            print_result(&rs);
            let rp = bench(
                &format!("decode-step hot={hot_pct}% b=4 kvh=8 pipelined"),
                5,
                budget,
                || {
                    arena.begin_staging_epoch();
                    arena.begin_staging_epoch();
                    std::hint::black_box(cold_pipe.assemble_into(&tasks, &qg_all, shape, &mut wi));
                },
            );
            print_result(&rp);
            let ratio = rs.mean_ns / rp.mean_ns;
            println!(
                "# pipeline-speedup decode-step hot={hot_pct}% b={bsz} kvh={kvh}: {ratio:.2}x \
                 (serial {:.0} ns, pipelined {:.0} ns)",
                rs.mean_ns, rp.mean_ns
            );
            if assert_on && ratio < 1.0 {
                println!(
                    "# FAIL: pipelined decode slower than serial at hot={hot_pct}% ({ratio:.2}x)"
                );
                fails += 1;
            }
            arena.spill().set_read_fault(0, 0);
        }

        // GQA-batched centroid scoring: one G×m GEMM + group-max reduce
        // (what `select_group_into` issues per kv-head) vs G per-head
        // matvecs with an elementwise max merge.
        {
            let bk = kernels::active();
            let (mm, dd, g) = (2048usize, 64usize, 4usize);
            let mut rngg = Rng::new(44);
            let cents = rngg.normal_vec(mm * dd);
            let qs = rngg.normal_vec(g * dd);
            let mut gm = vec![0.0f32; g * mm];
            let mut scores = vec![0.0f32; mm];
            let mut tmp = vec![0.0f32; mm];
            let rh = bench("gqa-score per-head G=4 m=2048 d=64", 50, budget, || {
                scores.fill(f32::NEG_INFINITY);
                for gi in 0..g {
                    bk.matvec_nt(&qs[gi * dd..(gi + 1) * dd], &cents, dd, &mut tmp);
                    for (s, t) in scores.iter_mut().zip(&tmp) {
                        if *t > *s {
                            *s = *t;
                        }
                    }
                }
                std::hint::black_box(scores[0]);
            });
            print_result(&rh);
            let rb = bench("gqa-score batched G=4 m=2048 d=64", 50, budget, || {
                bk.gemm_nt(&qs, &cents, dd, &mut gm);
                bk.group_max_reduce(&gm, g, mm, &mut scores);
                std::hint::black_box(scores[0]);
            });
            print_result(&rb);
            let gr = rh.mean_ns / rb.mean_ns;
            println!(
                "# gqa-batched-speedup G={g} m={mm} d={dd}: {gr:.2}x \
                 (per-head {:.0} ns, batched {:.0} ns)",
                rh.mean_ns, rb.mean_ns
            );
            if assert_on && gr < 1.0 {
                println!("# FAIL: batched GQA scoring slower than per-head ({gr:.2}x)");
                fails += 1;
            }
        }
        if fails > 0 {
            println!("# bench-pipeline: {fails} pipeline regression(s)");
            std::process::exit(1);
        }
    }

    // --- block cache ops ---------------------------------------------------
    let mut cache = BlockCache::new(CachePolicy::Lru, 4096, 2 * 8 * d);
    for k in 0..4096u64 {
        cache.admit(k);
    }
    let mut i = 0u64;
    print_result(&bench("cache admit+evict", 100, budget, || {
        let (_, ev) = cache.admit(4096 + i % 8192);
        std::hint::black_box(ev);
        i += 1;
    }));
    print_result(&bench("cache touch (LRU)", 100, budget, || {
        cache.touch(i % 4096);
        i += 1;
    }));

    // --- tripartite merge ----------------------------------------------------
    let exact: Vec<usize> = (0..512).collect();
    let estimated: Vec<usize> = (0..e.min(m)).collect();
    let inp = TripartiteInputs {
        d,
        keys: &keys,
        vals: &vals,
        exact: &exact,
        centroids: idx.meta().centroids_flat(),
        vsum: idx.meta().vsum_flat(),
        sizes: idx.meta().counts(),
        estimated: &estimated,
    };
    let mut out = vec![0.0f32; d];
    print_result(&bench("tripartite merge (512ex+est)", 20, budget, || {
        tripartite_attention(&q, &inp, &mut out);
    }));

    // --- kernel backends: scalar vs SIMD in one process -------------------
    // The `#`-prefixed summary rows are what CI's bench-smoke job greps;
    // RI_ASSERT_SIMD=1 turns "SIMD slower than scalar" into a failure.
    {
        let mut fails = 0usize;
        let mut rngk = Rng::new(77);
        match Backend::simd() {
            None => println!("# simd-speedup: no SIMD backend on this machine (scalar only)"),
            Some(simd) => {
                // centroid scoring: the select phase's inner GEMM
                for &dd in &[64usize, 128] {
                    let mm = 2048;
                    let cents = rngk.normal_vec(mm * dd);
                    let qq = rngk.normal_vec(dd);
                    let mut scores = vec![0.0f32; mm];
                    let rs = bench(&format!("matvec m={mm} d={dd} scalar"), 20, budget, || {
                        Backend::Scalar.matvec_nt(&qq, &cents, dd, &mut scores);
                        std::hint::black_box(scores[0]);
                    });
                    print_result(&rs);
                    let rv = bench(&format!("matvec m={mm} d={dd} simd"), 20, budget, || {
                        simd.matvec_nt(&qq, &cents, dd, &mut scores);
                        std::hint::black_box(scores[0]);
                    });
                    print_result(&rv);
                    let label = format!("centroid-scoring m={mm} d={dd}");
                    simd_row(&label, rs.mean_ns, rv.mean_ns, &mut fails);
                }
                // GQA group-max scoring (G=4)
                {
                    let (mm, dd, g) = (2048usize, 64usize, 4usize);
                    let cents = rngk.normal_vec(mm * dd);
                    let qs = rngk.normal_vec(g * dd);
                    let mut scores = vec![0.0f32; mm];
                    let rs = bench("group_max m=2048 d=64 G=4 scalar", 20, budget, || {
                        Backend::Scalar.group_max_scores(&qs, g, &cents, dd, &mut scores);
                        std::hint::black_box(scores[0]);
                    });
                    print_result(&rs);
                    let rv = bench("group_max m=2048 d=64 G=4 simd", 20, budget, || {
                        simd.group_max_scores(&qs, g, &cents, dd, &mut scores);
                        std::hint::black_box(scores[0]);
                    });
                    print_result(&rv);
                    let label = "group-max-scoring m=2048 d=64 G=4";
                    simd_row(label, rs.mean_ns, rv.mean_ns, &mut fails);
                }
                // fused tripartite merge (same inputs, explicit backend)
                {
                    let mut scratch = MergeScratch::default();
                    let mut om = vec![0.0f32; d];
                    let rs = bench("tripartite merge scalar", 20, budget, || {
                        tripartite_attention_in(Backend::Scalar, &q, &inp, &mut scratch, &mut om);
                        std::hint::black_box(om[0]);
                    });
                    print_result(&rs);
                    let rv = bench("tripartite merge simd", 20, budget, || {
                        tripartite_attention_in(simd, &q, &inp, &mut scratch, &mut om);
                        std::hint::black_box(om[0]);
                    });
                    print_result(&rv);
                    simd_row("tripartite-merge 512ex+est", rs.mean_ns, rv.mean_ns, &mut fails);
                }
            }
        }
        // End-to-end decode-step core (select + exec-buffer assemble +
        // tripartite merge) under the PINNED backend: CI runs this bench
        // twice (RETRO_KERNELS=scalar / =simd) and compares the rows.
        {
            let mut sc2 = SelectScratch::default();
            let mut ds = DecodeScratch::default();
            let mut eb2 = ExecBuffer::new(d);
            let mut om = vec![0.0f32; d];
            let name =
                format!("decode-step select+assemble+merge [{}]", kernels::active().name());
            print_result(&bench(&name, 20, budget, || {
                let sel = idx.select_into(&q, r, e, &mut sc2);
                std::hint::black_box(wb.assemble(&idx, sel, &mut eb2));
                idx.attend_with(&q, sel, &mut ds, &mut om);
                std::hint::black_box(om[0]);
            }));
        }
        if fails > 0 {
            println!("# bench-smoke: {fails} SIMD regression(s)");
            std::process::exit(1);
        }
    }

    // --- live PJRT step components -------------------------------------------
    {
        use retroinfer::runtime::tinylm::{TinyLm, WaveInputs};
        use retroinfer::runtime::default_artifacts_dir;
        use retroinfer::tensor::Tensor;
        if let Ok(mut lm) = TinyLm::load(&default_artifacts_dir()) {
            let (kvh, dh, g) = (lm.cfg.kv_heads, lm.cfg.d_head, lm.cfg.group());
            let (ne, mc) = (lm.buckets.wave_ne, lm.buckets.wave_m);
            let mut wi = WaveInputs::zeros(1, kvh, ne, mc, dh);
            for h in 0..kvh {
                for t in 0..400 {
                    wi.kmask[h * ne + t] = 1.0;
                }
                for c in 0..120 {
                    wi.csize[h * mc + c] = 16.0;
                    wi.emask[h * mc + c] = 1.0;
                }
            }
            let qt = Tensor::zeros(&[1, kvh, g, dh]);
            lm.attn_wave(&qt, &wi).unwrap(); // compile
            print_result(&bench("pjrt attn_wave b=1", 3, budget, || {
                std::hint::black_box(lm.attn_wave(&qt, &wi).unwrap());
            }));
            let hid = Tensor::zeros(&[1, 256]);
            lm.qkv(0, &hid, &[0]).unwrap();
            print_result(&bench("pjrt qkv b=1", 3, budget, || {
                std::hint::black_box(lm.qkv(0, &hid, &[0]).unwrap());
            }));
            let ctx = Tensor::zeros(&[1, 256]);
            lm.mlp(0, &hid, &ctx).unwrap();
            print_result(&bench("pjrt mlp b=1", 3, budget, || {
                std::hint::black_box(lm.mlp(0, &hid, &ctx).unwrap());
            }));
        }
    }

    // --- segmented k-means build --------------------------------------------
    let seg_keys = &keys[..8192 * d];
    print_result(&bench("kmeans 8K segment (10 iters)", 1, budget * 2.0, || {
        std::hint::black_box(spherical_kmeans(seg_keys, d, 512, 10, true, 3));
    }));
    // pooled assignment fan-out (same result bit-for-bit: partition-invariant
    // GEMM tiles); only the assignment phase parallelizes
    let kpool = ThreadPool::new(4);
    print_result(&bench("kmeans 8K segment (10 iters, pool=4)", 1, budget * 2.0, || {
        std::hint::black_box(spherical_kmeans_pooled(seg_keys, d, 512, 10, true, 3, Some(&kpool)));
    }));
}
