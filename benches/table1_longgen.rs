//! Table 1 reproduction: long-generation (reasoning) accuracy.
//! Short prompt, long output: the KV cache is mostly *generated* tokens,
//! so accuracy depends on decode-time index updates (§4.2). Needles are
//! planted among the APPENDED tokens; a system that cannot index new
//! tokens (MagicPIG — excluded by the paper too) or that indexes them
//! coarsely loses them.
//!
//!     cargo bench --bench table1_longgen

use retroinfer::baselines::{
    FullAttention, InfiniGen, PqCache, Quest, Retro, SparseSystem, StreamingLlm,
};
use retroinfer::util::bench::{quick_mode, Table};
use retroinfer::util::rng::Rng;
use retroinfer::util::stats::cosine;
use retroinfer::workload::{base_context, GeometryCfg};

fn main() {
    let d = 32;
    let prompt = 512;
    let generated = if quick_mode() { 4096 } else { 8192 };
    let n_needles = 8;
    println!("## Table 1: long-generation accuracy (prompt={prompt}, generated={generated})");

    // Base short prompt.
    let mut rng = Rng::new(3);
    let cfg = GeometryCfg { n: prompt, d, region: 128, ..GeometryCfg::default() };
    let (keys0, vals0) = base_context(&cfg, &mut rng);

    // The generation stream: topic-drift tokens with planted needles.
    let mut gen_keys = Vec::new();
    let mut gen_vals = Vec::new();
    let gcfg = GeometryCfg { n: generated, d, region: 256, ..GeometryCfg::default() };
    let (gk, gv) = base_context(&gcfg, &mut rng);
    gen_keys.extend_from_slice(&gk);
    gen_vals.extend_from_slice(&gv);
    // Each needle is an 8-token span (a generated "fact" is a sentence;
    // spans also cluster as their own unit in every system's index).
    let span = 8usize;
    let mut needles: Vec<Vec<u32>> = Vec::new();
    let mut dirs = Vec::new();
    for i in 0..n_needles {
        let pos = (i + 1) * generated / (n_needles + 1);
        let dir = rng.normal_vec(d);
        let payload = rng.normal_vec(d);
        for s in 0..span {
            for j in 0..d {
                gen_keys[(pos + s) * d + j] = 3.0 * dir[j] + 0.1 * rng.normal_f32();
                gen_vals[(pos + s) * d + j] = payload[j];
            }
        }
        needles.push((pos..pos + span).map(|p| (prompt + p) as u32).collect());
        dirs.push(dir);
    }

    let systems: Vec<Box<dyn SparseSystem>> = vec![
        Box::new(FullAttention::new(&keys0, &vals0, d)),
        Box::new(StreamingLlm::new(&keys0, &vals0, d, 4)),
        Box::new(Quest::new(&keys0, &vals0, d, 16)),
        Box::new(InfiniGen::new(&keys0, &vals0, d, d / 2)),
        Box::new(PqCache::new(&keys0, &vals0, d, 2, 16, 1)),
        Box::new(Retro::build_default(&keys0, &vals0, d, 2)),
    ];

    let total = prompt + generated;
    let budget = ((total as f64 * 0.018) as usize).max(8 * 16) + 68;
    let mut table = Table::new(&["system", "needle_acc", "output_cos", "updates"]);
    let mut retro_acc = 0.0;
    let mut best_baseline_acc: f64 = 0.0;
    for mut sys in systems {
        // stream the generated tokens through the update path
        for t in 0..generated {
            sys.append(&gen_keys[t * d..(t + 1) * d], &gen_vals[t * d..(t + 1) * d]);
        }
        // query each needle
        let mut full = FullAttention::new(&keys0, &vals0, d);
        for t in 0..generated {
            full.append(&gen_keys[t * d..(t + 1) * d], &gen_vals[t * d..(t + 1) * d]);
        }
        let mut hits = 0usize;
        let mut cs = 0.0;
        for (ni, dir) in dirs.iter().enumerate() {
            let q: Vec<f32> = dir.iter().map(|x| x * 3.0).collect();
            let mut o = vec![0.0; d];
            let st = sys.decode(&q, budget, &mut o);
            let mut fo = vec![0.0; d];
            full.decode(&q, total, &mut fo);
            // success = at least half the fact's span attended exactly
            let set: std::collections::HashSet<u32> =
                st.exact_positions.iter().copied().collect();
            let covered = needles[ni].iter().filter(|p| set.contains(p)).count();
            if covered * 2 >= needles[ni].len() {
                hits += 1;
            }
            cs += cosine(&o, &fo);
        }
        let acc = hits as f64 / n_needles as f64;
        let cos = cs / n_needles as f64;
        if sys.name() == "retroinfer" {
            retro_acc = acc;
        } else if sys.name() != "full" && sys.name() != "streaming" {
            best_baseline_acc = best_baseline_acc.max(acc);
        }
        table.row(vec![
            sys.name().to_string(),
            format!("{acc:.2}"),
            format!("{cos:.4}"),
            if sys.supports_updates() { "yes".into() } else { "no".into() },
        ]);
    }
    table.print();
    assert!(retro_acc >= 0.75, "retroinfer long-gen accuracy {retro_acc}");
    assert!(
        retro_acc >= best_baseline_acc - 1e-9,
        "retroinfer ({retro_acc}) must match/beat baselines ({best_baseline_acc})"
    );
    println!("\nshape check OK: incremental updates keep generated-token needles retrievable");
}
