//! Figure 3 + Figure 4 reproduction: dynamic attention sparsity.
//!
//! Fig 3: the top-100 heavy-hitter set changes across decoding steps
//! (the paper measures ~31% overlap between adjacent steps).
//! Fig 4(a): sparsity varies across "layers/heads" (here: independent
//! geometry seeds). Fig 4(b): sparsity ratio varies across tasks.
//!
//!     cargo bench --bench fig03_sparsity    (RI_QUICK=1 to shrink)

use retroinfer::attention::attention_weights;
use retroinfer::attention::sparsity::{top_k_indices, top_k_overlap, tokens_for_mass};
use retroinfer::util::bench::{quick_mode, Table};
use retroinfer::util::rng::Rng;
use retroinfer::workload::tasks::{generate, TaskKind};

fn main() {
    let ctx = if quick_mode() { 4096 } else { 16384 };
    let d = 32;

    // ---- Fig 3: top-100 overlap across decoding steps -------------------
    println!("## Fig 3: top-100 overlap across adjacent decoding steps (ctx={ctx})");
    let task = generate(TaskKind::Qa, ctx, d, 1, 1);
    let wl = &task.workload;
    let mut rng = Rng::new(77);
    // a decoding trajectory: the query drifts step to step
    let mut q = wl.queries[0].clone();
    let mut prev: Option<Vec<usize>> = None;
    let mut overlaps = Vec::new();
    for _ in 0..8 {
        let w = attention_weights(&q, &wl.keys, d);
        let top = top_k_indices(&w, 100);
        if let Some(p) = &prev {
            overlaps.push(top_k_overlap(p, &top));
        }
        prev = Some(top);
        for x in q.iter_mut() {
            *x = 0.85 * *x + 0.35 * rng.normal_f32();
        }
    }
    let mean_overlap = overlaps.iter().sum::<f64>() / overlaps.len() as f64;
    println!("adjacent-step top-100 overlap: mean={mean_overlap:.2} (paper: ~0.31)");
    assert!(mean_overlap < 0.95, "importance must be dynamic");

    // ---- Fig 4(a): sparsity across heads (geometry seeds) ---------------
    println!("\n## Fig 4(a): tokens for 90% attention mass across heads");
    let mut table = Table::new(&["head", "tokens_for_90%", "fraction"]);
    for head in 0..6 {
        let t = generate(TaskKind::Qa, ctx, d, 1, 100 + head);
        let w = attention_weights(&t.workload.queries[0], &t.workload.keys, d);
        let n90 = tokens_for_mass(&w, 0.90);
        table.row(vec![
            head.to_string(),
            n90.to_string(),
            format!("{:.4}", n90 as f64 / ctx as f64),
        ]);
    }
    table.print();

    // ---- Fig 4(b): sparsity across tasks ---------------------------------
    println!("\n## Fig 4(b): sparsity ratio by task (tokens for 90%/99% mass)");
    let mut table = Table::new(&["task", "n90", "n99", "sparsity_90"]);
    let mut n90s = Vec::new();
    for kind in TaskKind::all() {
        let t = generate(kind, ctx, d, 4, 9);
        let mut n90 = 0usize;
        let mut n99 = 0usize;
        for q in &t.workload.queries {
            let w = attention_weights(q, &t.workload.keys, d);
            n90 += tokens_for_mass(&w, 0.90);
            n99 += tokens_for_mass(&w, 0.99);
        }
        n90 /= t.workload.queries.len();
        n99 /= t.workload.queries.len();
        n90s.push((kind.name(), n90));
        table.row(vec![
            kind.name().to_string(),
            n90.to_string(),
            n99.to_string(),
            format!("{:.4}", 1.0 - n90 as f64 / ctx as f64),
        ]);
    }
    table.print();
    // the aggregation task must be the least sparse (paper Fig 4b: fwe)
    let fwe = n90s.iter().find(|(n, _)| *n == "fwe").unwrap().1;
    let sn = n90s.iter().find(|(n, _)| *n == "s_niah").unwrap().1;
    assert!(fwe > sn, "fwe ({fwe}) must need more tokens than s_niah ({sn})");
    println!("\nshape check OK: sparsity is dynamic, head- and task-dependent");
}
