//! Figure 13 reproduction: decode throughput vs batch size at 30K / 60K /
//! 120K / 1M contexts (Llama3-8B on the calibrated A100 model). The
//! wave-buffer hit ratio fed into the simulator is MEASURED by running
//! the real index + buffer on a scaled workload trace (DESIGN.md §5).
//!
//!     cargo bench --bench fig13_throughput

use retroinfer::baselines::{Retro, SparseSystem};
use retroinfer::config::{HardwareSpec, ModelSpec, SpillCodec};
use retroinfer::memsim::{self, profiles};
use retroinfer::util::bench::{quick_mode, Table};
use retroinfer::workload::tasks::{generate, TaskKind};
use retroinfer::workload::{
    diurnal_poisson, multi_tenant_poisson, poisson_arrivals, run_memory_pressure,
    run_online_serving, stamp_shared_prefix, OnlineConfig, PressureConfig, RequestSpec,
};

/// Measure the block-cache hit ratio by replaying a real query trace
/// through the real wave index + wave buffer at reduced scale, and
/// report the KV arena's occupancy/reclaim accounting for the run.
fn measured_hit_ratio() -> f64 {
    let d = 32;
    let ctx = if quick_mode() { 4096 } else { 8192 };
    let task = generate(TaskKind::Qa, ctx, d, 1, 9);
    let wl = &task.workload;
    let mut sys = Retro::build_default(&wl.keys, &wl.vals, d, 3);
    let budget = ((ctx as f64 * 0.018) as usize).max(8 * 16) + 68;
    let mut out = vec![0.0; d];
    for q in drift_trace(&wl.queries[0], 48, 7) {
        sys.decode(&q, budget, &mut out);
        if let Some(b) = sys.buffer() {
            b.flush();
        }
    }
    let hit = sys.buffer().map(|b| b.stats().hit_ratio()).unwrap_or(0.0);
    let arena = std::sync::Arc::clone(sys.arena());
    println!(
        "# arena during replay: live={} blocks ({} B), allocated_total={}",
        arena.live_blocks(),
        arena.live_bytes(),
        arena.allocated_total(),
    );
    drop(sys);
    println!(
        "# arena after session teardown: live={} blocks, reclaimed_total={}",
        arena.live_blocks(),
        arena.reclaimed_total(),
    );
    assert_eq!(arena.live_blocks(), 0, "finished session must return every block");
    hit
}

/// A decode trajectory: the query drifts step-to-step (topic continuity),
/// which is where the paper's temporal locality comes from (§4.3).
fn drift_trace(base: &[f32], steps: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = retroinfer::util::rng::Rng::new(seed);
    let mut q = base.to_vec();
    (0..steps)
        .map(|_| {
            for x in q.iter_mut() {
                *x = 0.96 * *x + 0.1 * rng.normal_f32();
            }
            q.clone()
        })
        .collect()
}


/// Serve an overcommitted multi-tenant trace through the real admission
/// gate + arena under a hard cap and report the deferral behaviour
/// (ROADMAP: multi-tenant arena caps + admission control).
fn capped_admission_report() {
    let n_per_tenant = if quick_mode() { 3 } else { 6 };
    let trace = multi_tenant_poisson(&[4.0, 2.0], n_per_tenant, 120, 8, 11);
    let cfg = PressureConfig {
        capacity_blocks: 512,
        tenant_quota_blocks: Some(300),
        ..PressureConfig::default()
    };
    let rep = run_memory_pressure(&cfg, &trace);
    println!(
        "# admission under cap: {} reqs x 2 tenants, cap={} blocks quota={:?} -> \
         completed={} deferral_events={} peak_live={} blocks (resident peak {} B)",
        trace.len(),
        cfg.capacity_blocks,
        cfg.tenant_quota_blocks,
        rep.completed,
        rep.deferrals,
        rep.peak_live_blocks,
        rep.peak_resident_bytes,
    );
    assert!(rep.drained, "admission run deadlocked: {rep:?}");
    assert_eq!(rep.capacity_violations, 0, "resident bytes exceeded the cap");
    assert_eq!(rep.quota_violations, 0, "a tenant exceeded its quota");
    assert_eq!(rep.prefill_failures, 0, "gate admitted an unservable prefill");
    assert_eq!(rep.append_failures, 0, "headroom too small for decode growth");
    assert_eq!(
        rep.completed + rep.rejected,
        trace.len(),
        "requests lost under memory pressure"
    );
    assert!(rep.deferrals > 0, "cap sized to force deferrals");
}

/// Serve the same overcommitted trace with the cold spill tier enabled
/// (ROADMAP: CPU-tier spill): the hot cap binds at every step while the
/// total live footprint exceeds it — the spill-forcing config the
/// EXPERIMENTS.md tiered-arena table is fed by. Runs twice, with the
/// Exact and the int8 spill codec, reports logical vs physical cold
/// bytes plus the measured intra-step spill overlap (the fraction of
/// cold-tier reads the pipelined I/O lane had already staged when the
/// gather asked for them), and returns the MEASURED (physical/logical
/// codec ratio, overlap fraction) of the int8 run — the fig13
/// `retroinfer-spill-*` rows are fed by both.
fn spill_pressure_report() -> (f64, f64) {
    let n_per_tenant = if quick_mode() { 3 } else { 6 };
    let trace = multi_tenant_poisson(&[4.0, 2.0], n_per_tenant, 120, 8, 13);
    let mut codec_ratio = 1.0f64;
    let mut overlap_frac = 1.0f64;
    for codec in [SpillCodec::Exact, SpillCodec::Int8] {
        let cfg = PressureConfig {
            capacity_blocks: 256,
            tenant_quota_blocks: None,
            spill: true,
            spill_codec: codec,
            ..PressureConfig::default()
        };
        let rep = run_memory_pressure(&cfg, &trace);
        let ratio =
            rep.peak_cold_physical_bytes as f64 / rep.peak_cold_logical_bytes.max(1) as f64;
        println!(
            "# tiered arena under spill [{} codec]: {} reqs, hot cap={} blocks -> \
             completed={} demoted={} promoted={} peak_hot={} peak_total={} blocks \
             (cold peak {}; cold bytes logical={} physical={} ratio={:.2} \
             compressed_pages_peak={})",
            codec.name(),
            trace.len(),
            cfg.capacity_blocks,
            rep.completed,
            rep.demotions,
            rep.promotions,
            rep.peak_live_blocks,
            rep.peak_total_live_blocks,
            rep.peak_cold_blocks,
            rep.peak_cold_logical_bytes,
            rep.peak_cold_physical_bytes,
            ratio,
            rep.peak_compressed_blocks,
        );
        println!(
            "#   pipelined cold reads [{} codec]: {} total, {} staged \
             (intra-step spill_overlap_pct {:.1}%)",
            codec.name(),
            rep.cold_reads,
            rep.cold_reads_staged,
            rep.spill_overlap_pct(),
        );
        assert!(rep.drained, "spill run deadlocked: {rep:?}");
        assert_eq!(rep.capacity_violations, 0, "hot tier exceeded its cap");
        assert_eq!(rep.deferrals, 0, "tiered admission must never defer");
        assert_eq!(rep.completed, trace.len(), "requests lost under spill");
        assert!(rep.demotions > 0, "config sized to force spill");
        assert!(
            rep.peak_total_live_blocks > cfg.capacity_blocks,
            "total live must exceed the hot tier for the report to mean anything"
        );
        assert_eq!(rep.final_cold_blocks, 0, "cold blocks must die with their sessions");
        assert!(rep.cold_reads > 0, "spill run never read through the cold tier");
        assert!(
            rep.cold_reads_staged > 0,
            "pipelined staging never beat a gather to a cold page: {rep:?}"
        );
        if codec.is_lossy() {
            assert!(rep.peak_compressed_blocks > 0, "lossy codec never applied: {rep:?}");
            assert!(
                2 * rep.peak_cold_physical_bytes <= rep.peak_cold_logical_bytes,
                "int8 must at least halve cold bytes: {rep:?}"
            );
            codec_ratio = ratio;
            overlap_frac = rep.spill_overlap_pct() / 100.0;
        } else {
            assert_eq!(rep.peak_compressed_blocks, 0, "exact run stored lossy pages");
        }
    }
    (codec_ratio, overlap_frac)
}

/// Serve a shared-prefix trace through the real refcounted arena
/// (ROADMAP: cross-session block-cache sharing): N sessions over one
/// template prefix — one donor seals it, everyone else attaches — and
/// report the dedup factor plus the resident/transfer bytes it saves.
/// Feeds the EXPERIMENTS.md "Prefix sharing" table.
fn shared_prefix_report() {
    let n = if quick_mode() { 6 } else { 12 };
    let mut trace = poisson_arrivals(20.0, n, 120, 6, 17);
    stamp_shared_prefix(&mut trace, 0x7E3A);
    let cfg = PressureConfig {
        capacity_blocks: 420,
        shared_prefix_tokens: 96,
        ..PressureConfig::default()
    };
    let rep = run_memory_pressure(&cfg, &trace);
    // block geometry of the run (d=16, 512 B blocks -> tpb 4)
    let block_bytes = 512;
    let dedup = rep.peak_shared_refs as f64 / rep.peak_shared_blocks.max(1) as f64;
    let saved_blocks = rep.peak_shared_refs.saturating_sub(rep.peak_shared_blocks);
    println!(
        "# shared-prefix replay: {} reqs x one 96-token template, cap={} blocks -> \
         donors={} attaches={} peak_shared={} blocks peak_refs={} \
         (dedup {dedup:.1}x, {} B resident+transfer saved at peak)",
        trace.len(),
        cfg.capacity_blocks,
        rep.prefix_donors,
        rep.prefix_attaches,
        rep.peak_shared_blocks,
        rep.peak_shared_refs,
        saved_blocks * block_bytes,
    );
    assert!(rep.drained, "shared-prefix run deadlocked: {rep:?}");
    assert_eq!(rep.capacity_violations, 0, "resident bytes exceeded the cap");
    assert_eq!(rep.prefill_failures, 0, "gate admitted an unservable prefill");
    assert_eq!(rep.completed + rep.rejected, trace.len(), "requests lost");
    assert_eq!(rep.prefix_donors, 1, "one donor per template");
    assert!(dedup >= 2.0, "peak dedup must reflect concurrent sharers: {rep:?}");
    assert_eq!(rep.final_live_blocks, 0, "shared refcounts must drain");
}

/// SLO-aware online serving (ROADMAP: chunked prefill + continuous
/// batching): a diurnal interactive trace with long best-effort prompts
/// mixed in, served through the real scheduler's planning loop in
/// virtual time — monolithic prefill-eager baseline vs chunked prefill
/// at three chunk sizes. Feeds the EXPERIMENTS.md "Online serving"
/// table; percentiles come from the fixed-memory streaming histograms.
fn online_serving_report() {
    let horizon = if quick_mode() { 3.0 } else { 6.0 };
    // 20 req/s base per tenant at 16 output tokens ≈ 1280 tok/s mean
    // demand against ~1600 tok/s modelled decode capacity: bursts
    // oversubscribe transiently, troughs drain the backlog
    let mut trace = diurnal_poisson(&[20.0, 20.0], 3.0, 4.0, horizon, 64, 16, 29);
    trace.push(RequestSpec {
        arrive_s: horizon / 4.0,
        input_tokens: 262_144,
        output_tokens: 4,
        tenant: 2,
        prefix_hash: None,
    });
    trace.sort_by(|a, b| a.arrive_s.partial_cmp(&b.arrive_s).unwrap());
    let n = trace.len();
    let run = |chunked: bool, chunk_tokens: usize| {
        let cfg = OnlineConfig {
            trace: trace.clone(),
            chunked,
            chunk_tokens,
            prefill_token_s: 1e-5,
            decode_step_s: 5e-3,
            max_chunks_per_step: 2,
            slo_ttft_s: 0.5,
            slo_tpot_s: 0.05,
            slo_max_input: 1024,
            ..OnlineConfig::default()
        };
        (cfg.step_budget_s(), run_online_serving(&cfg))
    };
    println!("# online serving: {n} reqs (diurnal 2-tenant + one 256k prompt), TPOT SLO 50ms");
    let (_, mono) = run(false, 512);
    println!(
        "#   monolithic : max_gap={:.3}s tpot_p99={:.4}s attain_ttft={:.3} attain_tpot={:.3} \
         tput={:.0} tok/s",
        mono.max_gap_s,
        mono.tpot_p99_s,
        mono.ttft_attainment,
        mono.tpot_attainment,
        mono.throughput_tok_s,
    );
    assert!(mono.max_gap_s > 2.0, "the 256k prefill must stall the monolithic baseline");
    for cs in [256usize, 512, 1024] {
        let (budget, r) = run(true, cs);
        println!(
            "#   chunk={cs:<4}: max_gap={:.4}s (budget {budget:.4}s) tpot_p99={:.4}s \
             attain_ttft={:.3} attain_tpot={:.3} tput={:.0} tok/s",
            r.max_gap_s,
            r.tpot_p99_s,
            r.ttft_attainment,
            r.tpot_attainment,
            r.throughput_tok_s,
        );
        assert_eq!(r.completed + r.rejected, n, "requests lost in online serving");
        assert!(
            r.max_gap_s <= budget + 1e-9,
            "chunk {cs}: SLO-class gap {} over the per-step budget {budget}",
            r.max_gap_s
        );
        assert!(
            r.tpot_attainment > mono.tpot_attainment,
            "chunking must improve TPOT attainment (chunk {cs}: {} vs mono {})",
            r.tpot_attainment,
            mono.tpot_attainment
        );
    }
}

fn main() {
    let model = ModelSpec::llama3_8b();
    let hw = HardwareSpec::a100();
    let hit = measured_hit_ratio();
    println!("# measured wave-buffer hit ratio (real trace replay): {hit:.3}");
    println!("# paper reports 0.79-0.94 across tasks at 5% cache");
    capped_admission_report();
    let (codec_ratio, spill_overlap) = spill_pressure_report();
    println!("# measured int8 spill-codec ratio (physical/logical): {codec_ratio:.2}");
    println!(
        "# measured intra-step spill overlap fed to the simulator: {:.1}% of cold \
         reads staged ahead of the gather",
        100.0 * spill_overlap
    );
    shared_prefix_report();
    online_serving_report();
    println!();

    let contexts: &[(usize, &str)] =
        &[(30 * 1024, "30K"), (60 * 1024, "60K"), (120 * 1024, "120K"), (1 << 20, "1M")];
    let batches = [1usize, 2, 4, 8, 16, 32, 64];

    let mut retro_vs_full_120k = 0.0;
    for &(ctx, label) in contexts {
        println!("## Fig 13 ({label} context): decode throughput (tok/s) vs batch");
        let mut table = Table::new(&["system", "b=1", "b=2", "b=4", "b=8", "b=16", "b=32", "b=64"]);
        let mut best: Vec<(String, f64)> = Vec::new();
        for p in [
            profiles::full(),
            profiles::quest(),
            profiles::magicpig(),
            profiles::infinigen(),
            profiles::pqcache(),
            profiles::retroinfer(hit),
            // tiered arena: 30% of uncached fetches climb from the cold
            // spill tier first (hot RAM tier capped below the working
            // set); cold reads overlap compute at the MEASURED
            // intra-step staging ratio from the pressure replay above
            profiles::retroinfer_spilled(hit, 0.3).with_spill_overlap(spill_overlap),
            // same tiered arena with the int8 spill codec: cold pages
            // cross the spill channel at the MEASURED physical/logical
            // ratio from the pressure replay above
            profiles::retroinfer_spilled_compressed(hit, 0.3, codec_ratio)
                .with_spill_overlap(spill_overlap),
            // cross-session prefix sharing: half of each sequence's KV
            // is a template prefix resident once per batch (refcounted
            // blocks + shared GPU prefix cache)
            profiles::retroinfer_prefix(hit, 0.5),
        ] {
            let mut row = vec![p.name.to_string()];
            let mut peak = 0.0f64;
            for &b in &batches {
                match memsim::decode_throughput(&model, &hw, &p, ctx, b) {
                    Ok(t) => {
                        peak = peak.max(t);
                        row.push(format!("{t:.0}"));
                    }
                    Err(_) => row.push("OOM".into()),
                }
            }
            best.push((p.name.to_string(), peak));
            table.row(row);
        }
        table.print();
        let peak = |n: &str| best.iter().find(|(s, _)| s == n).unwrap().1;
        if ctx == 120 * 1024 {
            retro_vs_full_120k = peak("retroinfer") / peak("full");
            println!(
                "retroinfer / full at {label}: {:.1}x (paper: 4.4x)",
                retro_vs_full_120k
            );
        }
        if ctx == 1 << 20 {
            assert_eq!(peak("full"), 0.0, "full attention must OOM at 1M");
            assert_eq!(peak("quest"), 0.0, "quest must OOM at 1M");
            assert_eq!(peak("infinigen"), 0.0, "infinigen must OOM at 1M");
            let vs_mp = peak("retroinfer") / peak("magicpig");
            let vs_pq = peak("retroinfer") / peak("pqcache");
            println!("retroinfer vs magicpig: {vs_mp:.1}x (paper: 10.5x)");
            println!("retroinfer vs pqcache:  {vs_pq:.1}x (paper: 12.2x)");
            assert!(vs_mp > 2.0 && vs_pq > 2.0, "retroinfer must dominate at 1M");
        }
        println!();
    }
    // The factor overshoots the paper's 4.4x because the calibrated
    // full-attention baseline saturates HBM exactly at the analytic bound
    // while production FlashInfer keeps some headroom; the SHAPE (full
    // capped at batch 4 by memory, RetroInfer scaling to batch ~38) is
    // the reproduced claim.
    assert!(
        (2.0..12.0).contains(&retro_vs_full_120k),
        "120K speedup out of range: {retro_vs_full_120k}"
    );
    println!("shape check OK: crossovers and OOMs match the paper's Figure 13");
}
