//! Figure 19(b) reproduction: segmented-clustering segment size vs index
//! build time and retrieval quality. Recall@100: fraction of the true
//! top-100 attention tokens covered by the retrieval zone. The paper
//! finds 8K segments lose <1% recall vs global k-means while cutting
//! build time ~80%; the context here is scaled to one CPU core.
//!
//!     cargo bench --bench fig19_segments

use retroinfer::attention::attention_weights;
use retroinfer::attention::sparsity::{recall, top_k_indices};
use retroinfer::config::ZoneConfig;
use retroinfer::index::{SelectScratch, WaveIndex};
use retroinfer::util::bench::{quick_mode, Table};
use retroinfer::workload::tasks::{generate, TaskKind};
use std::time::Instant;

fn main() {
    let d = 32;
    let ctx = if quick_mode() { 8192 } else { 32768 };
    let task = generate(TaskKind::MultiNeedle, ctx, d, 6, 17);
    let wl = &task.workload;
    // ground-truth heavy hitters per query
    let truths: Vec<Vec<usize>> = wl
        .queries
        .iter()
        .map(|q| top_k_indices(&attention_weights(q, &wl.keys, d), 100))
        .collect();

    println!("## Fig 19(b): segment size vs build time and recall@100 (ctx={ctx})");
    let mut table = Table::new(&["segment", "build_ms", "recall@100", "clusters"]);
    let mut results = Vec::new();
    let segments: Vec<usize> =
        if quick_mode() { vec![1024, 4096, 8192] } else { vec![1024, 2048, 8192, 16384, ctx] };
    for seg in segments {
        let zcfg = ZoneConfig { build_segment: seg, ..ZoneConfig::default() };
        let t0 = Instant::now();
        let idx = WaveIndex::build(zcfg, d, 2048, &wl.keys, &wl.vals, 4);
        let build_ms = t0.elapsed().as_secs_f64() * 1e3;
        let m = idx.meta().m();
        let r = ((m as f64 * 0.05) as usize).max(8); // retrieval covering ~top-100 tokens
        let mut scratch = SelectScratch::default();
        let mut rec = 0.0;
        for (qi, q) in wl.queries.iter().enumerate() {
            let sel = idx.select_with(q, r, 0, &mut scratch);
            let pos: Vec<usize> =
                idx.exact_positions(&sel).into_iter().map(|p| p as usize).collect();
            rec += recall(&truths[qi], &pos);
        }
        rec /= wl.queries.len() as f64;
        results.push((seg, build_ms, rec));
        table.row(vec![
            if seg == ctx { format!("{seg} (global)") } else { seg.to_string() },
            format!("{build_ms:.0}"),
            format!("{rec:.3}"),
            m.to_string(),
        ]);
    }
    table.print();

    let (seg8k, t8k, r8k) = *results.iter().find(|(s, _, _)| *s == 8192).unwrap();
    let (_, tg, rg) = *results.last().unwrap();
    if !quick_mode() {
        println!(
            "\n8K segments: build {:.0}% of global, recall {:+.3} vs global",
            t8k / tg * 100.0,
            r8k - rg
        );
        assert!(t8k < 0.7 * tg, "segmenting must cut build time: {t8k} vs {tg}");
        assert!(r8k > rg - 0.05, "8K segments must keep recall: {r8k} vs {rg}");
    }
    let _ = seg8k;
    println!("\nshape check OK: segment=8K balances build time and clustering quality");
}
