//! Figure 18 reproduction: zone-size sensitivity. Sweeps (a-b) the
//! retrieval budget, (c-d) the estimation budget, (e-f) the steady-zone
//! configuration; reports task accuracy (real index on synthetic tasks)
//! and max decode throughput (A100 model, A6000 as the second hardware
//! point, as in the paper).
//!
//!     cargo bench --bench fig18_zones

use retroinfer::baselines::{FullAttention, SparseSystem};
use retroinfer::config::{HardwareSpec, ModelSpec, ZoneConfig};
use retroinfer::index::{SelectScratch, WaveIndex};
use retroinfer::memsim::{self, profiles};
use retroinfer::util::bench::{quick_mode, Table};
use retroinfer::util::stats::cosine;
use retroinfer::workload::tasks::{generate, TaskKind};

struct Fixture {
    d: usize,
    idx: WaveIndex,
    queries: Vec<Vec<f32>>,
    needles: Vec<Vec<u32>>,
    full_outs: Vec<Vec<f32>>,
}

fn fixture(kind: TaskKind, ctx: usize) -> Fixture {
    let d = 32;
    let task = generate(kind, ctx, d, 8, 55);
    let wl = task.workload;
    let mut full = FullAttention::new(&wl.keys, &wl.vals, d);
    let full_outs = wl
        .queries
        .iter()
        .map(|q| {
            let mut o = vec![0.0; d];
            full.decode(q, ctx, &mut o);
            o
        })
        .collect();
    let idx = WaveIndex::build(ZoneConfig::default(), d, 2048, &wl.keys, &wl.vals, 5);
    Fixture { d, idx, queries: wl.queries, needles: wl.needles, full_outs }
}

/// (needle accuracy, mean output cosine) at explicit (r, e) budgets.
fn eval(f: &Fixture, r: usize, e: usize) -> (f64, f64) {
    let mut scratch = SelectScratch::default();
    let mut hits = 0usize;
    let mut cs = 0.0;
    for (qi, q) in f.queries.iter().enumerate() {
        let sel = f.idx.select_with(q, r, e, &mut scratch);
        let mut o = vec![0.0; f.d];
        f.idx.attend(q, &sel, &mut o);
        cs += cosine(&o, &f.full_outs[qi]);
        let pos = f.idx.exact_positions(&sel);
        let set: std::collections::HashSet<u32> = pos.into_iter().collect();
        if f.needles[qi].iter().all(|p| set.contains(p)) {
            hits += 1;
        }
    }
    (hits as f64 / f.queries.len() as f64, cs / f.queries.len() as f64)
}

fn main() {
    let ctx = if quick_mode() { 8192 } else { 16384 };
    let model = ModelSpec::llama3_8b();
    let sniah = fixture(TaskKind::SingleNeedle, ctx);
    let qa = fixture(TaskKind::Qa, ctx);
    let m = sniah.idx.meta().m();
    let e_default = (m as f64 * 0.232) as usize;
    let r_default = ((m as f64 * 0.018) as usize).max(8);

    // ---- (a-b) retrieval budget sweep -------------------------------------
    println!("## Fig 18(a-b): retrieval-budget sweep (ctx={ctx}, m={m} clusters)");
    let mut table = Table::new(&[
        "r_frac", "s_niah_acc", "qa_acc", "qa_cos", "tok/s A100", "tok/s A6000",
    ]);
    let mut accs = Vec::new();
    for frac in [0.005, 0.018, 0.05, 0.1, 0.2] {
        let r = ((m as f64 * frac) as usize).max(1);
        let (a1, _) = eval(&sniah, r, e_default);
        let (a2, c2) = eval(&qa, r, e_default);
        let p = profiles::retroinfer(0.85).with_exact_frac(frac);
        let t100 = memsim::decode_throughput(&model, &HardwareSpec::a100(), &p, 120 * 1024, 16)
            .unwrap_or(0.0);
        let t6000 = memsim::decode_throughput(&model, &HardwareSpec::a6000(), &p, 30 * 1024, 8)
            .unwrap_or(0.0);
        accs.push((frac, a2, t100));
        table.row(vec![
            format!("{frac:.3}"),
            format!("{a1:.2}"),
            format!("{a2:.2}"),
            format!("{c2:.3}"),
            format!("{t100:.0}"),
            format!("{t6000:.0}"),
        ]);
    }
    table.print();
    // throughput must fall as retrieval grows; accuracy must not fall
    assert!(accs.last().unwrap().1 >= accs[0].1 - 1e-9, "accuracy grows with budget");
    assert!(accs.last().unwrap().2 < accs[0].2, "throughput falls with budget");

    // ---- (c-d) estimation budget sweep -------------------------------------
    println!("\n## Fig 18(c-d): estimation-budget sweep (r fixed at default)");
    let mut table = Table::new(&["e_frac", "s_niah_cos", "qa_cos", "tok/s A100"]);
    let mut prev_cos = 0.0;
    for frac in [0.0, 0.1, 0.232, 0.5, 1.0] {
        let e = (m as f64 * frac) as usize;
        let (_, c1) = eval(&sniah, r_default, e);
        let (_, c2) = eval(&qa, r_default, e);
        let p = profiles::retroinfer(0.85).with_est_frac(frac);
        let t = memsim::decode_throughput(&model, &HardwareSpec::a100(), &p, 120 * 1024, 16)
            .unwrap_or(0.0);
        if frac == 0.0 {
            prev_cos = c2;
        }
        table.row(vec![
            format!("{frac:.3}"),
            format!("{c1:.3}"),
            format!("{c2:.3}"),
            format!("{t:.0}"),
        ]);
        if frac >= 0.99 {
            assert!(
                c2 >= prev_cos,
                "estimation must improve qa fidelity: {c2} vs {prev_cos}"
            );
        }
    }
    table.print();

    // ---- (e-f) steady zone sweep -------------------------------------------
    println!("\n## Fig 18(e-f): steady-zone configurations (sink+local)");
    let mut table = Table::new(&["steady", "qa_cos", "note"]);
    for (label, sink, local) in
        [("0+0", 0usize, 0usize), ("4+0", 4, 0), ("0+64", 0, 64), ("4+64", 4, 64), ("16+256", 16, 256)]
    {
        let d = 32;
        let task = generate(TaskKind::Qa, ctx, d, 8, 55);
        let wl = task.workload;
        let zcfg = ZoneConfig { steady_sink: sink, steady_local: local, ..ZoneConfig::default() };
        let idx = WaveIndex::build(zcfg, d, 2048, &wl.keys, &wl.vals, 5);
        let mut full = FullAttention::new(&wl.keys, &wl.vals, d);
        let mut scratch = SelectScratch::default();
        let mut cs = 0.0;
        for q in &wl.queries {
            let sel = idx.select_with(q, r_default, e_default, &mut scratch);
            let mut o = vec![0.0; d];
            idx.attend(q, &sel, &mut o);
            let mut fo = vec![0.0; d];
            full.decode(q, ctx, &mut fo);
            cs += cosine(&o, &fo);
        }
        table.row(vec![
            label.to_string(),
            format!("{:.3}", cs / wl.queries.len() as f64),
            if label == "4+64" { "paper default".into() } else { String::new() },
        ]);
    }
    table.print();
    println!("\nshape check OK: small retrieval + larger estimation = accuracy & throughput");
}
