//! Figure 19(a) reproduction: effect of accuracy-bounded attention
//! estimation. Compares RetroInfer with and without the estimation zone
//! at the default retrieval budget across tasks; the paper reports up to
//! +20% task accuracy from estimation, at no throughput cost (overlapped).
//!
//!     cargo bench --bench fig19_estimation

use retroinfer::baselines::FullAttention;
use retroinfer::baselines::SparseSystem;
use retroinfer::config::ZoneConfig;
use retroinfer::index::{SelectScratch, WaveIndex};
use retroinfer::util::bench::{quick_mode, Table};
use retroinfer::util::stats::cosine;
use retroinfer::workload::tasks::{generate, TaskKind};

fn main() {
    let d = 32;
    let ctx = if quick_mode() { 8192 } else { 16384 };
    println!("## Fig 19(a): accuracy with vs without the estimation zone (ctx={ctx})");
    let mut table = Table::new(&["task", "cos w/o est", "cos w/ est", "delta"]);
    let mut worst_gain = f64::INFINITY;
    for kind in TaskKind::all() {
        let task = generate(kind, ctx, d, 8, 91);
        let wl = task.workload;
        let idx = WaveIndex::build(ZoneConfig::default(), d, 2048, &wl.keys, &wl.vals, 3);
        let m = idx.meta().m();
        let r = ((m as f64 * 0.018) as usize).max(8);
        let e = (m as f64 * 0.232) as usize;
        let mut full = FullAttention::new(&wl.keys, &wl.vals, d);
        let mut scratch = SelectScratch::default();
        let (mut c_no, mut c_yes) = (0.0, 0.0);
        for q in &wl.queries {
            let mut fo = vec![0.0; d];
            full.decode(q, ctx, &mut fo);
            let sel_no = idx.select_with(q, r, 0, &mut scratch);
            let mut o = vec![0.0; d];
            idx.attend(q, &sel_no, &mut o);
            c_no += cosine(&o, &fo);
            let sel_yes = idx.select_with(q, r, e, &mut scratch);
            idx.attend(q, &sel_yes, &mut o);
            c_yes += cosine(&o, &fo);
        }
        let n = wl.queries.len() as f64;
        let (c_no, c_yes) = (c_no / n, c_yes / n);
        worst_gain = worst_gain.min(c_yes - c_no);
        table.row(vec![
            kind.name().to_string(),
            format!("{c_no:.4}"),
            format!("{c_yes:.4}"),
            format!("{:+.4}", c_yes - c_no),
        ]);
    }
    table.print();
    assert!(
        worst_gain > -0.02,
        "estimation must not hurt fidelity (worst delta {worst_gain})"
    );
    println!("\nshape check OK: estimation improves (or preserves) fidelity on every task");
}
