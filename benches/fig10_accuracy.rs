//! Figure 10 reproduction: task accuracy under a fixed retrieval budget
//! across context lengths and systems (RULER substitution: needle recall
//! + output fidelity vs full attention — DESIGN.md §1).
//!
//! Paper shape: RetroInfer is the only sparse system matching full
//! attention across lengths; fixed-position and coarse-grained baselines
//! degrade as the context grows.
//!
//!     cargo bench --bench fig10_accuracy    (RI_QUICK=1 for short run)

use retroinfer::baselines::{all_systems, FullAttention, SparseSystem};
use retroinfer::util::bench::{quick_mode, Table};
use retroinfer::util::stats::cosine;
use retroinfer::workload::tasks::{generate, needle_accuracy, TaskKind};

fn main() {
    let d = 32;
    let lengths: Vec<usize> =
        if quick_mode() { vec![4096, 8192] } else { vec![4096, 8192, 16384, 32768] };
    let n_queries = if quick_mode() { 4 } else { 8 };

    for kind in [TaskKind::SingleNeedle, TaskKind::MultiNeedle, TaskKind::Qa] {
        println!("\n## Fig 10 ({}): accuracy vs context length, 1.8%+floor budget", kind.name());
        let mut table = Table::new(&["system", "metric", "4K", "8K", "16K", "32K"]);
        let mut acc_rows: Vec<(String, Vec<f64>, Vec<f64>)> = Vec::new();
        for &ctx in &lengths {
            let task = generate(kind, ctx, d, n_queries, 42 + ctx as u64);
            let wl = &task.workload;
            let budget = ((ctx as f64 * 0.018) as usize).max(8 * 16) + 68;
            let mut full_outs = Vec::new();
            {
                let mut f = FullAttention::new(&wl.keys, &wl.vals, d);
                for q in &wl.queries {
                    let mut o = vec![0.0; d];
                    f.decode(q, ctx, &mut o);
                    full_outs.push(o);
                }
            }
            for sys in all_systems(&wl.keys, &wl.vals, d, 5).iter_mut() {
                let mut exact = Vec::new();
                let mut cs = 0.0;
                for (qi, q) in wl.queries.iter().enumerate() {
                    let mut o = vec![0.0; d];
                    let st = sys.decode(q, budget, &mut o);
                    exact.push(st.exact_positions);
                    cs += cosine(&o, &full_outs[qi]);
                }
                let acc = needle_accuracy(&exact, &wl.needles);
                let cos = cs / wl.queries.len() as f64;
                match acc_rows.iter_mut().find(|(n, _, _)| n == sys.name()) {
                    Some((_, accs, coss)) => {
                        accs.push(acc);
                        coss.push(cos);
                    }
                    None => acc_rows.push((sys.name().to_string(), vec![acc], vec![cos])),
                }
            }
        }
        let fmt = |v: &[f64]| -> Vec<String> {
            let mut cells: Vec<String> = v.iter().map(|x| format!("{x:.2}")).collect();
            cells.resize(4, "-".into());
            cells
        };
        for (name, accs, coss) in &acc_rows {
            let mut row = vec![name.clone(), "acc".into()];
            row.extend(fmt(accs));
            table.row(row);
            let mut row = vec![String::new(), "cos".into()];
            row.extend(fmt(coss));
            table.row(row);
        }
        table.print();

        // shape assertions at the longest length
        let get = |n: &str| acc_rows.iter().find(|(s, _, _)| s == n).unwrap();
        let retro_acc = *get("retroinfer").1.last().unwrap();
        let stream_acc = *get("streaming").1.last().unwrap();
        let retro_cos = *get("retroinfer").2.last().unwrap();
        if kind != TaskKind::Qa {
            // needle tasks: exact retrieval expected (strong needles)
            assert!(retro_acc >= 0.75, "{}: retroinfer acc {retro_acc}", kind.name());
        }
        // qa mixes weak needles into topical queries — the paper's qa
        // accuracy also trails niah; output fidelity is the metric there
        assert!(
            retro_acc >= stream_acc,
            "{}: retroinfer must beat fixed-position heuristics",
            kind.name()
        );
        assert!(retro_cos > 0.84, "{}: retroinfer cos {retro_cos}", kind.name());
    }
    println!("\nshape check OK: retroinfer tracks full attention; static heuristics degrade");
}
