//! Figure 11 reproduction: needle-in-a-haystack up to long contexts.
//! Grid of (context length x needle depth); cell = needle retrieval
//! success of the wave index at the paper budget. Paper: 100% at all
//! cells up to 1M; here the context axis is scaled to what a single
//! CPU core can cluster (DESIGN.md §1).
//!
//!     cargo bench --bench fig11_niah    (RI_QUICK=1 to shrink)

use retroinfer::baselines::{Retro, SparseSystem};
use retroinfer::util::bench::{quick_mode, Table};
use retroinfer::util::rng::Rng;
use retroinfer::workload::{base_context, plant_needle, GeometryCfg};

fn main() {
    let d = 32;
    let lengths: Vec<usize> =
        if quick_mode() { vec![8192, 16384] } else { vec![8192, 16384, 32768, 65536] };
    let depths = [0.1, 0.3, 0.5, 0.7, 0.9];
    println!("## Fig 11: needle retrieval success (wave index, 1.8%+floor budget)");
    let mut table = Table::new(&["ctx", "d=0.1", "d=0.3", "d=0.5", "d=0.7", "d=0.9"]);
    let mut all_pass = true;
    for &ctx in &lengths {
        let mut row = vec![ctx.to_string()];
        for &depth in &depths {
            let mut rng = Rng::new((ctx as u64) * 31 + (depth * 100.0) as u64);
            let cfg = GeometryCfg { n: ctx, d, region: (ctx / 16).clamp(64, 4096), ..GeometryCfg::default() };
            let (mut keys, mut vals) = base_context(&cfg, &mut rng);
            let pos = vec![(depth * ctx as f64) as u32];
            let dir = plant_needle(&mut keys, &mut vals, d, &pos, cfg.needle_gain, &mut rng);
            let q: Vec<f32> = dir.iter().map(|x| x * cfg.needle_gain).collect();
            let mut sys = Retro::build_default(&keys, &vals, d, 11);
            let budget = ((ctx as f64 * 0.018) as usize).max(8 * 16) + 68;
            let mut out = vec![0.0; d];
            let st = sys.decode(&q, budget, &mut out);
            let hit = st.exact_positions.contains(&pos[0]);
            all_pass &= hit;
            row.push(if hit { "100".into() } else { "0".into() });
        }
        table.row(row);
    }
    table.print();
    assert!(all_pass, "wave index must retrieve every planted needle");
    println!("\nshape check OK: 100% needle retrieval at every (length, depth) — paper Fig 11");
}
