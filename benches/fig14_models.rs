//! Figure 14 reproduction: maximum decode throughput (a) across tasks —
//! hit ratios measured per task family by replaying real traces through
//! the wave buffer — and (b) across models (Llama3.1-8B, Qwen2.5-7B,
//! Llama3-8B-1048K, Qwen2.5-72B on 8 GPUs).
//!
//!     cargo bench --bench fig14_models

use retroinfer::baselines::{Retro, SparseSystem};
use retroinfer::config::{HardwareSpec, ModelSpec};
use retroinfer::memsim::{self, profiles};
use retroinfer::util::bench::{quick_mode, Table};
use retroinfer::workload::tasks::{generate, TaskKind};

fn task_hit_ratio(kind: TaskKind) -> f64 {
    let d = 32;
    let ctx = if quick_mode() { 4096 } else { 8192 };
    let task = generate(kind, ctx, d, 1, 21);
    let wl = &task.workload;
    let mut sys = Retro::build_default(&wl.keys, &wl.vals, d, 4);
    let budget = ((ctx as f64 * 0.018) as usize).max(8 * 16) + 68;
    let mut out = vec![0.0; d];
    for q in drift_trace(&wl.queries[0], 48, kind as u64) {
        sys.decode(&q, budget, &mut out);
        if let Some(b) = sys.buffer() {
            b.flush();
        }
    }
    sys.buffer().map(|b| b.stats().hit_ratio()).unwrap_or(0.0)
}

/// A decode trajectory: the query drifts step-to-step (topic continuity),
/// which is where the paper's temporal locality comes from (§4.3).
fn drift_trace(base: &[f32], steps: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = retroinfer::util::rng::Rng::new(seed);
    let mut q = base.to_vec();
    (0..steps)
        .map(|_| {
            for x in q.iter_mut() {
                *x = 0.96 * *x + 0.1 * rng.normal_f32();
            }
            q.clone()
        })
        .collect()
}


fn peak(model: &ModelSpec, hw: &HardwareSpec, p: &profiles::SystemProfile, ctx: usize) -> f64 {
    let mb = memsim::max_batch(model, hw, p, ctx).min(64);
    if mb == 0 {
        return 0.0;
    }
    memsim::decode_throughput(model, hw, p, ctx, mb).unwrap_or(0.0)
}

fn main() {
    let hw = HardwareSpec::a100();
    let ctx = 120 * 1024;

    // ---- (a) across tasks: measured hit ratios --------------------------
    println!("## Fig 14(a): max decode throughput by task (Llama3-8B, 120K)");
    let mut table = Table::new(&["task", "hit_ratio", "retroinfer", "full", "quest", "speedup_vs_full"]);
    let model = ModelSpec::llama3_8b();
    for kind in TaskKind::all() {
        let hit = task_hit_ratio(kind);
        let tr = peak(&model, &hw, &profiles::retroinfer(hit), ctx);
        let tf = peak(&model, &hw, &profiles::full(), ctx);
        let tq = peak(&model, &hw, &profiles::quest(), ctx);
        table.row(vec![
            kind.name().to_string(),
            format!("{hit:.3}"),
            format!("{tr:.0}"),
            format!("{tf:.0}"),
            format!("{tq:.0}"),
            format!("{:.1}x", tr / tf),
        ]);
        assert!(tr > tf, "{}: retroinfer must beat full attention", kind.name());
    }
    table.print();

    // ---- (b) across models ----------------------------------------------
    println!("\n## Fig 14(b): max decode throughput by model (120K context)");
    let mut table = Table::new(&["model", "gpus", "retroinfer", "best_baseline", "advantage"]);
    for model in [
        ModelSpec::llama31_8b(),
        ModelSpec::qwen25_7b(),
        ModelSpec::llama3_8b(),
        ModelSpec::qwen25_72b(),
    ] {
        let tr = peak(&model, &hw, &profiles::retroinfer(0.85), ctx);
        let mut best = ("-", 0.0f64);
        for p in [profiles::full(), profiles::quest(), profiles::magicpig(), profiles::infinigen(), profiles::pqcache()] {
            let t = peak(&model, &hw, &p, ctx);
            if t > best.1 {
                best = (p.name, t);
            }
        }
        table.row(vec![
            model.name.to_string(),
            model.n_gpus.to_string(),
            format!("{tr:.0}"),
            format!("{} ({:.0})", best.0, best.1),
            format!("{:.1}x", tr / best.1.max(1e-9)),
        ]);
        assert!(tr > best.1, "{}: retroinfer must lead", model.name);
    }
    table.print();
    println!("\nshape check OK: retroinfer leads across tasks and model scales (7B-72B)");
}
