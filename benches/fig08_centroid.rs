//! Figure 8 reproduction: centroid representativeness and estimation
//! accuracy. Ranks centroids by query similarity and reports (a) the
//! cumulative true attention score captured by the top-ranked clusters
//! (blue line) and (b) the centroid-based estimate vs the ground-truth
//! per-cluster attention mass (green vs dashed), demonstrating the
//! Jensen lower bound of Eq. 3.
//!
//!     cargo bench --bench fig08_centroid

use retroinfer::config::ZoneConfig;
use retroinfer::index::{SelectScratch, WaveIndex};
use retroinfer::tensor::dot;
use retroinfer::util::bench::{quick_mode, Table};
use retroinfer::workload::tasks::{generate, TaskKind};

fn main() {
    let ctx = if quick_mode() { 8192 } else { 32768 };
    let d = 32;
    let task = generate(TaskKind::Aggregate, ctx, d, 1, 3);
    let wl = &task.workload;
    let cfg = ZoneConfig::default();
    let idx = WaveIndex::build(cfg, d, 2048, &wl.keys, &wl.vals, 7);
    let q = &wl.queries[0];
    let m = idx.meta().m();
    println!("## Fig 8: centroid rank vs attention mass (ctx={ctx}, m={m} clusters)");

    // rank clusters by centroid score
    let mut scratch = SelectScratch::default();
    let sel = idx.select_with(q, m, 0, &mut scratch);
    let scale = 1.0 / (d as f32).sqrt();

    // ground-truth per-cluster attention mass (unnormalized exp scores)
    let total: f64 = {
        let mut s = 0.0f64;
        for c in 0..m {
            for r in idx.cluster_blocks(c as u32) {
                let keys = idx.store().block_keys(*r);
                for t in 0..keys.len() / d {
                    s += ((dot(q, &keys[t * d..(t + 1) * d]) * scale) as f64).exp();
                }
            }
        }
        s
    };

    let mut table = Table::new(&["rank", "cum_true_mass", "est/true (bucket)"]);
    let mut cum = 0.0f64;
    let buckets = 8usize;
    let per = m.div_ceil(buckets);
    let mut jensen_violations = 0usize;
    for (b, chunk) in sel.retrieval.chunks(per).enumerate() {
        let mut true_mass = 0.0f64;
        let mut est_mass = 0.0f64;
        for &c in chunk {
            let ci = c as usize;
            let mut cluster_true = 0.0f64;
            for r in idx.cluster_blocks(c) {
                let keys = idx.store().block_keys(*r);
                for t in 0..keys.len() / d {
                    cluster_true += ((dot(q, &keys[t * d..(t + 1) * d]) * scale) as f64).exp();
                }
            }
            let est = (idx.meta().counts()[ci] as f64)
                * ((dot(q, idx.meta().centroid(ci)) * scale) as f64).exp();
            // Eq. 3: s_i * exp(q.C_i) <= sum exp(q.K_j)  (Jensen)
            if est > cluster_true * 1.001 {
                jensen_violations += 1;
            }
            true_mass += cluster_true;
            est_mass += est;
        }
        cum += true_mass;
        table.row(vec![
            format!("{}-{}", b * per, (b + 1) * per - 1),
            format!("{:.3}", cum / total),
            format!("{:.3}", est_mass / true_mass.max(1e-30)),
        ]);
    }
    table.print();
    assert_eq!(jensen_violations, 0, "centroid estimate must lower-bound Eq. 3");
    println!("\nJensen bound holds for all {m} clusters (0 violations)");
    println!("top-ranked centroids capture the mass first — the paper's blue curve shape");
}
