//! Cluster serving: modelled vs measured (DESIGN.md §2 "Cluster serving
//! & migration", paper §4.5 modularity).
//!
//! Two views of the same workload, compared field-for-field:
//!
//! * **modelled** — `simulate_cluster_detailed`: the analytic load model
//!   (roofline step times, no gate dynamics) whose aggregation bugs this
//!   report regression-guards (`inf × 0` NaN, zero-output underflow).
//! * **measured** — `run_cluster_pressure`: the real `Router`, real
//!   per-worker admission gates and real arena accounting driving the
//!   modelled KV footprint, including work stealing and failure
//!   injection the analytic model cannot express.
//!
//!     cargo bench --bench cluster_serving

use retroinfer::config::{HardwareSpec, ModelSpec};
use retroinfer::engine::simulate_cluster_detailed;
use retroinfer::memsim::profiles;
use retroinfer::util::bench::{quick_mode, Table};
use retroinfer::workload::{
    closed_loop, run_cluster_pressure, ClusterPressureConfig, PressureConfig,
};

fn node() -> PressureConfig {
    PressureConfig {
        // gate estimate for 512 in / 64 out is 864 blocks (4 heads ×
        // 144 × 1.5 fudge); usable = 0.75 × cap, so 2048 admits ~2
        // concurrent sessions per worker and defers the rest
        capacity_blocks: 2048,
        ..PressureConfig::default()
    }
}

fn main() {
    let model = ModelSpec::llama3_8b();
    let hw = HardwareSpec::a100();
    let n_req = if quick_mode() { 12 } else { 24 };
    // block-scale requests both views can serve: 512 in / 64 out
    let reqs = closed_loop(8, n_req, 512, 64);

    println!("## modelled vs measured cluster scaling ({n_req} requests, 512 in / 64 out)");
    let mut table = Table::new(&[
        "workers",
        "model_req/s",
        "model_p99_s",
        "meas_rounds",
        "meas_steals",
        "meas_defers",
        "completed",
    ]);
    let mut rounds_1 = 0usize;
    let mut rounds_4 = 0usize;
    let mut model_rps_1 = 0.0;
    let mut model_rps_4 = 0.0;
    for workers in [1usize, 2, 4] {
        let modelled = simulate_cluster_detailed(
            &model,
            &hw,
            &profiles::retroinfer(0.85),
            &reqs,
            4,
            workers,
        );
        let agg = &modelled.aggregate;
        assert!(!agg.oom);
        assert_eq!(agg.completed, n_req, "model must complete all at {workers} workers");
        // the satellite fixes under regression: aggregation stays NaN-free
        assert!(agg.mean_latency_s.is_finite() && agg.p99_latency_s.is_finite());
        assert!(!agg.req_per_s.is_nan());

        let cfg = ClusterPressureConfig {
            workers,
            node: node(),
            steal: true,
            kill_worker: None,
            kill_at_step: 0,
        };
        let meas = run_cluster_pressure(&cfg, &reqs);
        assert!(meas.drained, "measured cluster deadlocked: {meas:?}");
        assert_eq!(meas.completed, n_req, "measured must complete all: {meas:?}");
        assert_eq!(meas.capacity_violations, 0, "{meas:?}");

        if workers == 1 {
            rounds_1 = meas.steps;
            model_rps_1 = agg.req_per_s;
        }
        if workers == 4 {
            rounds_4 = meas.steps;
            model_rps_4 = agg.req_per_s;
        }
        table.row(vec![
            workers.to_string(),
            format!("{:.4}", agg.req_per_s),
            format!("{:.2}", agg.p99_latency_s),
            meas.steps.to_string(),
            meas.steals.to_string(),
            meas.deferrals.to_string(),
            format!("{}/{}", meas.completed, n_req),
        ]);
    }
    table.print();
    // both views must agree on the §4.5 claim: more replicas, more
    // throughput (model: req/s up; measured: coordinator rounds down)
    assert!(
        model_rps_4 > model_rps_1,
        "model stopped scaling: {model_rps_1:.4} -> {model_rps_4:.4}"
    );
    assert!(
        rounds_4 < rounds_1,
        "measured coordinator stopped scaling: {rounds_1} -> {rounds_4} rounds"
    );
    println!(
        "\nagreement: modelled {:.2}x req/s, measured {:.2}x fewer rounds at 4 workers",
        model_rps_4 / model_rps_1,
        rounds_1 as f64 / rounds_4 as f64
    );

    println!("\n## failure injection: kill worker 1 of 3 mid-run ({n_req} requests)");
    let mut ftable = Table::new(&[
        "kill_step",
        "recovered",
        "mid_decode",
        "steals",
        "completed",
        "leaked_blocks",
    ]);
    for kill_step in [4usize, 16, 64] {
        let cfg = ClusterPressureConfig {
            workers: 3,
            node: node(),
            steal: true,
            kill_worker: Some(1),
            kill_at_step: kill_step,
        };
        let rep = run_cluster_pressure(&cfg, &reqs);
        assert!(rep.drained, "kill at {kill_step} deadlocked: {rep:?}");
        assert_eq!(
            rep.completed + rep.rejected,
            n_req,
            "kill at {kill_step} lost requests: {rep:?}"
        );
        assert_eq!(rep.leaked_blocks, 0, "dead worker leaked blocks: {rep:?}");
        assert_eq!(rep.capacity_violations, 0, "{rep:?}");
        ftable.row(vec![
            kill_step.to_string(),
            rep.recovered.to_string(),
            rep.restarted_mid_decode.to_string(),
            rep.steals.to_string(),
            format!("{}/{}", rep.completed, n_req),
            rep.leaked_blocks.to_string(),
        ]);
    }
    ftable.print();
    println!("\nshape check OK: every killed worker's session completed on survivors");
}
