//! Figure 15 reproduction: prefill latency vs context length. RetroInfer
//! adds only segmented clustering (+ asynchronous buffer construction) to
//! the prefill critical path; the paper reports 6% at 120K and 3% at 1M.
//! The clustering *fraction* here is the analytic flop share; the live
//! measurement of the same quantity is reported by the serve_e2e example.
//!
//!     cargo bench --bench fig15_prefill

use retroinfer::config::{HardwareSpec, ModelSpec};
use retroinfer::memsim::{clustering_flops, prefill_latency};
use retroinfer::util::bench::Table;

fn main() {
    let model = ModelSpec::llama3_8b();
    let hw = HardwareSpec::a100();
    println!("## Fig 15: prefill latency (s) vs context length");
    let mut table =
        Table::new(&["ctx", "full", "retroinfer", "overhead", "cluster_flops_share"]);
    for ctx in [30 * 1024, 60 * 1024, 120 * 1024, 512 * 1024, 1 << 20] {
        let cf = clustering_flops(&model, ctx, 8192, 10);
        let offload = ctx >= 512 * 1024; // paper offloads at 1M to avoid OOM
        let t_full = prefill_latency(&model, &hw, ctx, 0.0, false);
        let t_retro = prefill_latency(&model, &hw, ctx, cf, offload);
        let overhead = t_retro / t_full - 1.0;
        // clustering share of total prefill flops
        let t = ctx as f64;
        let total_flops = t * model.decode_dense_flops() + model.attention_flops(ctx) * t / 2.0;
        table.row(vec![
            format!("{}K", ctx / 1024),
            format!("{t_full:.1}"),
            format!("{t_retro:.1}"),
            format!("{:.1}%", overhead * 100.0),
            format!("{:.2}%", cf / total_flops * 100.0),
        ]);
        assert!(
            overhead < 0.08,
            "clustering overhead must stay under ~8% (paper: 3-6%): {overhead}"
        );
    }
    table.print();
    println!("\nshape check OK: segmented clustering adds <8% prefill latency at all lengths");
}
