//! Figure 17 reproduction: end-to-end request throughput vs mean latency
//! under increasing load, for the two paper workloads — (a) long input
//! (120K in / 4K out) and (b) long output (512 in / 32K out). Continuous
//! batching with prefill admission, on the calibrated A100 model.
//!
//!     cargo bench --bench fig17_e2e    (RI_QUICK=1 to shrink)

use retroinfer::config::{HardwareSpec, ModelSpec};
use retroinfer::engine::sim::simulate_load;
use retroinfer::memsim::profiles;
use retroinfer::util::bench::{quick_mode, Table};
use retroinfer::workload::closed_loop;

fn main() {
    let model = ModelSpec::llama3_8b();
    let hw = HardwareSpec::a100();
    let loads: Vec<usize> = if quick_mode() { vec![2, 8] } else { vec![2, 4, 8, 16, 32] };
    let n_req = if quick_mode() { 8 } else { 16 };

    for (label, input, output, skip_nonupdating) in [
        ("long input (120K in / 4K out)", 120 * 1024usize, 4096usize, false),
        ("long output (512 in / 32K out)", 512, 32 * 1024, true),
    ] {
        println!("## Fig 17: {label}");
        let mut table = Table::new(&["system", "load", "req/s", "mean_lat_s", "p99_s"]);
        let mut retro_best = 0.0f64;
        let mut full_best = 0.0f64;
        for p in [
            profiles::vllm(),
            profiles::full(),
            profiles::quest(),
            profiles::magicpig(),
            profiles::infinigen(),
            profiles::pqcache(),
            profiles::retroinfer(0.85),
            profiles::retroinfer_gpu(),
        ] {
            if skip_nonupdating && !p.supports_update {
                continue; // paper excludes MagicPIG from long-output runs
            }
            for &clients in &loads {
                let reqs = closed_loop(clients, n_req, input, output);
                let rep = simulate_load(&model, &hw, &p, &reqs, clients);
                if rep.oom {
                    table.row(vec![p.name.into(), clients.to_string(), "OOM".into(), "-".into(), "-".into()]);
                    break;
                }
                if p.name == "retroinfer" {
                    retro_best = retro_best.max(rep.req_per_s);
                }
                if p.name == "full" {
                    full_best = full_best.max(rep.req_per_s);
                }
                table.row(vec![
                    p.name.into(),
                    clients.to_string(),
                    format!("{:.4}", rep.req_per_s),
                    format!("{:.1}", rep.mean_latency_s),
                    format!("{:.1}", rep.p99_latency_s),
                ]);
            }
        }
        table.print();
        println!(
            "retroinfer peak {:.4} req/s vs full attention {:.4} ({:.1}x)\n",
            retro_best,
            full_best,
            retro_best / full_best.max(1e-12)
        );
        assert!(
            retro_best > full_best,
            "{label}: retroinfer must win under load"
        );
    }
    println!("shape check OK: retroinfer scales with load on both workloads (paper Fig 17)");
}
