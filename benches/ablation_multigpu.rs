//! Ablation: multi-GPU scaling (paper §4.5 + the Qwen2.5-72B/8-GPU rows
//! of Figure 14). Wave index and wave buffer are modular per attention
//! head, so the only cross-GPU coordination is request routing — request
//! throughput should scale near-linearly with replicas under load.
//!
//!     cargo bench --bench ablation_multigpu

use retroinfer::config::{HardwareSpec, ModelSpec};
use retroinfer::engine::simulate_cluster;
use retroinfer::memsim::profiles;
use retroinfer::util::bench::{quick_mode, Table};
use retroinfer::workload::closed_loop;

fn main() {
    let model = ModelSpec::llama3_8b();
    let hw = HardwareSpec::a100();
    let n_req = if quick_mode() { 16 } else { 48 };
    let reqs = closed_loop(32, n_req, 120 * 1024, 2048);

    println!("## multi-GPU request-throughput scaling (120K in / 2K out, {n_req} requests)");
    let mut table = Table::new(&["workers", "req/s", "scaling", "mean_lat_s"]);
    let mut base = 0.0;
    let mut last_scaling = 0.0;
    for workers in [1usize, 2, 4, 8] {
        let rep = simulate_cluster(&model, &hw, &profiles::retroinfer(0.85), &reqs, 16, workers);
        assert!(!rep.oom);
        assert_eq!(rep.completed, n_req, "{workers} workers must complete all");
        if workers == 1 {
            base = rep.req_per_s;
        }
        last_scaling = rep.req_per_s / base;
        table.row(vec![
            workers.to_string(),
            format!("{:.4}", rep.req_per_s),
            format!("{:.2}x", last_scaling),
            format!("{:.1}", rep.mean_latency_s),
        ]);
    }
    table.print();
    assert!(last_scaling > 4.0, "8 workers must scale >4x: {last_scaling:.2}x");
    println!("\nshape check OK: near-linear scaling — no cross-GPU coordination needed (§4.5)");
}
