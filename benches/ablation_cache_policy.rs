//! Ablation: cache replacement policy (paper §5.1 — "we explored several
//! cache policies and selected LRU as default due to its best
//! performance"). Replays the same real decode trace through the real
//! wave buffer under LRU / FIFO / CLOCK / 2Q and reports hit ratios and
//! the throughput each implies on the A100 model.
//!
//!     cargo bench --bench ablation_cache_policy

use retroinfer::baselines::{Retro, SparseSystem};
use retroinfer::config::{BufferConfig, CachePolicy, HardwareSpec, ModelSpec, ZoneConfig};
use retroinfer::memsim::{self, profiles};
use retroinfer::util::bench::{quick_mode, Table};
use retroinfer::util::rng::Rng;
use retroinfer::workload::tasks::{generate, TaskKind};

fn drift_trace(base: &[f32], steps: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    let mut q = base.to_vec();
    (0..steps)
        .map(|_| {
            for x in q.iter_mut() {
                *x = 0.96 * *x + 0.1 * rng.normal_f32();
            }
            q.clone()
        })
        .collect()
}

fn main() {
    let d = 32;
    let ctx = if quick_mode() { 4096 } else { 8192 };
    let task = generate(TaskKind::Qa, ctx, d, 1, 13);
    let wl = &task.workload;
    let trace = drift_trace(&wl.queries[0], 64, 3);
    let budget = ((ctx as f64 * 0.018) as usize).max(8 * 16) + 68;
    let model = ModelSpec::llama3_8b();
    let hw = HardwareSpec::a100();

    println!("## cache-policy ablation (same real trace, 5% cache, ctx={ctx})");
    let mut table = Table::new(&["policy", "hit_ratio", "pcie_bytes", "tok/s @120K b=16"]);
    let mut results = Vec::new();
    for policy in [CachePolicy::Lru, CachePolicy::Fifo, CachePolicy::Clock, CachePolicy::TwoQ] {
        let zcfg = ZoneConfig {
            build_segment: ZoneConfig::default().build_segment.min(ctx / 2),
            ..ZoneConfig::default()
        };
        let bcfg = BufferConfig { policy, ..BufferConfig::default() };
        let mut sys = Retro::build(zcfg, bcfg, &wl.keys, &wl.vals, d, 7);
        let mut out = vec![0.0; d];
        let mut pcie = 0usize;
        for q in &trace {
            let st = sys.decode(q, budget, &mut out);
            pcie += st.pcie_bytes;
            if let Some(b) = sys.buffer() {
                b.flush();
            }
        }
        let hit = sys.buffer().map(|b| b.stats().hit_ratio()).unwrap_or(0.0);
        let p = profiles::retroinfer(hit);
        let tput = memsim::decode_throughput(&model, &hw, &p, 120 * 1024, 16).unwrap_or(0.0);
        results.push((policy, hit));
        table.row(vec![
            policy.name().to_string(),
            format!("{hit:.3}"),
            pcie.to_string(),
            format!("{tput:.0}"),
        ]);
    }
    table.print();

    let lru = results.iter().find(|(p, _)| *p == CachePolicy::Lru).unwrap().1;
    let best = results.iter().map(|(_, h)| *h).fold(0.0f64, f64::max);
    assert!(
        lru >= best - 0.05,
        "LRU must be within 5% of the best policy (paper's default choice): {lru} vs {best}"
    );
    // every policy must beat no-cache on this trace
    for (p, h) in &results {
        assert!(*h > 0.3, "{}: hit ratio {h} too low", p.name());
    }
    println!("\nshape check OK: LRU at/near the best hit ratio — the paper's default");
}
