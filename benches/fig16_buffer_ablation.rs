//! Figure 16 reproduction: wave-buffer design ablation. Three variants —
//! "Base" (KV offloaded, no GPU cache), "+GPU cache", "+Async update" —
//! across batch sizes. Two layers of evidence: (1) REAL data-movement
//! measurements from the actual wave buffer (PCIe bytes with/without the
//! cache on the same trace), and (2) the throughput composition on the
//! calibrated A100 model.
//!
//!     cargo bench --bench fig16_buffer_ablation

use retroinfer::baselines::{Retro, SparseSystem};
use retroinfer::config::{BufferConfig, HardwareSpec, ModelSpec, ZoneConfig};
use retroinfer::memsim::{self, profiles};
use retroinfer::util::bench::{quick_mode, Table};
use retroinfer::workload::tasks::{generate, TaskKind};

fn run_real_trace(gpu_cache: bool) -> (usize, f64) {
    let d = 32;
    let ctx = if quick_mode() { 4096 } else { 8192 };
    let task = generate(TaskKind::Qa, ctx, d, 16, 33);
    let wl = &task.workload;
    let n = wl.n_tokens();
    let zcfg = ZoneConfig {
        build_segment: ZoneConfig::default().build_segment.min(n / 2),
        ..ZoneConfig::default()
    };
    let bcfg = BufferConfig { gpu_cache_enabled: gpu_cache, ..BufferConfig::default() };
    let mut sys = Retro::build(zcfg, bcfg, &wl.keys, &wl.vals, d, 6);
    let budget = ((ctx as f64 * 0.018) as usize).max(8 * 16) + 68;
    let mut out = vec![0.0; d];
    let mut pcie = 0usize;
    for q in drift_trace(&wl.queries[0], 48, 5) {
        let st = sys.decode(&q, budget, &mut out);
        pcie += st.pcie_bytes;
        if let Some(b) = sys.buffer() {
            b.flush();
        }
    }
    let hit = sys.buffer().map(|b| b.stats().hit_ratio()).unwrap_or(0.0);
    (pcie, hit)
}


/// A decode trajectory: the query drifts step-to-step (topic continuity),
/// which is where the paper's temporal locality comes from (§4.3).
fn drift_trace(base: &[f32], steps: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = retroinfer::util::rng::Rng::new(seed);
    let mut q = base.to_vec();
    (0..steps)
        .map(|_| {
            for x in q.iter_mut() {
                *x = 0.96 * *x + 0.1 * rng.normal_f32();
            }
            q.clone()
        })
        .collect()
}

fn main() {
    // ---- real wave-buffer measurement ------------------------------------
    let (pcie_base, _) = run_real_trace(false);
    let (pcie_cached, hit) = run_real_trace(true);
    println!("## measured on the real wave buffer (same trace):");
    println!("  PCIe bytes without GPU cache: {pcie_base}");
    println!("  PCIe bytes with    GPU cache: {pcie_cached} (hit ratio {hit:.3})");
    assert!(
        pcie_cached * 2 < pcie_base,
        "cache must cut PCIe traffic at least 2x: {pcie_cached} vs {pcie_base}"
    );

    // ---- throughput composition (Fig 16) ---------------------------------
    let model = ModelSpec::llama3_8b();
    let hw = HardwareSpec::a100();
    let ctx = 120 * 1024;
    println!("\n## Fig 16: decode throughput (tok/s) vs batch, wave-buffer ablation ({})", "120K");
    let mut table = Table::new(&["variant", "b=4", "b=8", "b=16", "b=32"]);
    let variants = [
        ("base (no cache)", profiles::retroinfer_base()),
        ("+ gpu cache", profiles::retroinfer_sync(hit)),
        ("+ async update", profiles::retroinfer(hit)),
    ];
    let mut peaks = Vec::new();
    for (label, p) in &variants {
        let mut row = vec![label.to_string()];
        let mut peak = 0.0f64;
        for b in [4usize, 8, 16, 32] {
            match memsim::decode_throughput(&model, &hw, p, ctx, b) {
                Ok(t) => {
                    peak = peak.max(t);
                    row.push(format!("{t:.0}"));
                }
                Err(_) => row.push("OOM".into()),
            }
        }
        peaks.push(peak);
        table.row(row);
    }
    table.print();
    assert!(peaks[1] > 1.2 * peaks[0], "+cache must scale past base");
    assert!(peaks[2] > 1.02 * peaks[1], "+async must beat sync updates");
    println!(
        "\nshape check OK: base {:.0} < +cache {:.0} < +async {:.0} (paper Fig 16 ordering)",
        peaks[0], peaks[1], peaks[2]
    );
}
