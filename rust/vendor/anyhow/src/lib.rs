//! Offline stand-in for the `anyhow` crate (DESIGN.md §1).
//!
//! The build image has no crates.io registry, so this vendored shim
//! provides the small surface the engine uses: [`Error`] (a context
//! chain), [`Result`], the [`anyhow!`]/[`bail!`] macros and the
//! [`Context`] extension trait. Display shows the outermost message;
//! alternate Display (`{:#}`) walks the whole chain like real anyhow.

use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chained error. `chain[0]` is the outermost message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Prepend an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if f.alternate() {
            for c in &self.chain[1..] {
                write!(f, ": {c}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error { chain: vec![c.to_string(), e.to_string()] })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { chain: vec![f().to_string(), e.to_string()] })
    }
}

/// Construct an [`Error`] from a format string (or a displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Early-return with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_chain_formats() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.with_context(|| "reading x".to_string()).unwrap_err();
        assert_eq!(format!("{e}"), "reading x");
        assert_eq!(format!("{e:#}"), "reading x: gone");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn macro_forms() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let n = 3;
        let e = anyhow!("got {} of {n}", 2);
        assert_eq!(format!("{e}"), "got 2 of 3");
    }

    #[test]
    fn from_std_error() {
        fn f() -> Result<()> {
            let _ = std::str::from_utf8(&[0xff, 0xfe])?;
            Ok(())
        }
        assert!(f().is_err());
    }
}
