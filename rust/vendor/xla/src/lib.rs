//! Offline stub of the `xla` PJRT bindings (DESIGN.md §1).
//!
//! The build image ships no XLA/PJRT shared library, so this vendored
//! crate keeps the engine compiling and testing offline. Host-side
//! literal plumbing (construction, reshape, readback) is real; anything
//! that needs a device — client construction, HLO parsing, compilation,
//! execution — returns [`Error::Unavailable`]. `runtime::Runtime::load`
//! therefore fails cleanly at session start, and every live-PJRT code
//! path (engine tests, serve examples) reports the stub instead of
//! crashing. Swapping this path dependency for the real `xla` crate in
//! `rust/Cargo.toml` re-enables live TinyLM execution with no source
//! changes.

use std::borrow::Borrow;

/// Stub error. `Unavailable` marks device functionality that needs the
/// real PJRT bindings; `Shape` marks host-side literal misuse.
#[derive(Clone, Debug)]
pub enum Error {
    Unavailable(&'static str),
    Shape(String),
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Element types a [`Literal`] can hold. Public only because the sealed
/// [`NativeType`] trait mentions it; not part of the usable API.
#[doc(hidden)]
#[derive(Clone, Debug)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Dims of an array-shaped literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Native element types supported by the stub.
pub trait NativeType: sealed::Sealed + Copy {
    fn wrap(data: Vec<Self>) -> Payload
    where
        Self: Sized;
    fn unwrap(p: &Payload) -> Option<Vec<Self>>
    where
        Self: Sized;
}

use self::Payload as P;

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Payload {
        P::F32(data)
    }
    fn unwrap(p: &Payload) -> Option<Vec<f32>> {
        match p {
            P::F32(v) => Some(v.clone()),
            P::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Payload {
        P::I32(data)
    }
    fn unwrap(p: &Payload) -> Option<Vec<i32>> {
        match p {
            P::I32(v) => Some(v.clone()),
            P::F32(_) => None,
        }
    }
}

/// Host-side literal: shaped, typed data.
#[derive(Clone, Debug)]
pub struct Literal {
    shape: Vec<i64>,
    payload: Payload,
}

impl Literal {
    /// 1-D literal over a native slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { shape: vec![data.len() as i64], payload: T::wrap(data.to_vec()) }
    }

    /// Scalar i32 literal.
    pub fn scalar(v: i32) -> Literal {
        Literal { shape: Vec::new(), payload: P::I32(vec![v]) }
    }

    fn elements(&self) -> usize {
        match &self.payload {
            P::F32(v) => v.len(),
            P::I32(v) => v.len(),
        }
    }

    /// Same data, new logical shape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.elements() {
            return Err(Error::Shape(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.shape
            )));
        }
        Ok(Literal { shape: dims.to_vec(), payload: self.payload.clone() })
    }

    /// Decompose a tuple literal. The stub never produces tuples (they
    /// only come back from device execution), so this is unavailable.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple on a stub literal")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.shape.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.payload)
            .ok_or_else(|| Error::Shape("literal element type mismatch".into()))
    }
}

/// Parsed HLO module (device-side only; never constructible offline).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file (offline xla stub)")
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer (never constructible offline).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync (offline xla stub)")
    }
}

/// Compiled executable (never constructible offline).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute (offline xla stub)")
    }

    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _inputs: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b (offline xla stub)")
    }
}

/// PJRT client handle. Construction fails offline — this is the single
/// gate that keeps every live-execution path behind a clean error.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable(
            "PjRtClient::cpu: PJRT is not available in this offline build \
             (vendored xla stub; swap rust/Cargo.toml to the real `xla` crate)",
        )
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile (offline xla stub)")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_literal (offline xla stub)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn device_paths_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
