//! Deterministic memory-pressure harness (DESIGN.md §2 "Admission &
//! quotas", §6 invariants): seeded multi-tenant workloads whose
//! aggregate KV footprint exceeds the arena capacity, driven through the
//! real scheduler admission gate + the real arena accounting by
//! `workload::pressure`. The three invariants under test:
//!
//! 1. resident bytes never exceed capacity (at every scheduler step);
//! 2. every deferred prefill is eventually admitted once reclamation
//!    frees space — no lost requests, no deadlock;
//! 3. per-tenant occupancy never exceeds the tenant quota.

use retroinfer::util::prop::check;
use retroinfer::workload::{
    multi_tenant_poisson, run_memory_pressure, PressureConfig, PressureReport, RequestSpec,
};
use retroinfer::{prop_assert, prop_assert_eq};

/// An oversubscribed 3-tenant scenario: ~12 requests of ~116 blocks each
/// (aggregate ~1400 blocks) against a 512-block arena.
fn oversubscribed_cfg(seed: u64) -> (PressureConfig, Vec<RequestSpec>) {
    let cfg = PressureConfig {
        capacity_blocks: 512,
        tenant_quota_blocks: Some(250),
        ..PressureConfig::default()
    };
    let trace = multi_tenant_poisson(&[4.0, 2.0, 1.0], 4, 112, 8, seed);
    (cfg, trace)
}

fn assert_invariants(cfg: &PressureConfig, trace: &[RequestSpec], rep: &PressureReport) {
    let block_bytes = 2 * 4 * cfg.d * 4; // tpb=4 at (d, 512 B) geometry
    assert!(rep.drained, "pressure run deadlocked: {rep:?}");
    assert_eq!(rep.capacity_violations, 0, "resident exceeded capacity: {rep:?}");
    assert_eq!(rep.quota_violations, 0, "tenant exceeded quota: {rep:?}");
    assert_eq!(rep.prefill_failures, 0, "gate admitted an unservable prefill: {rep:?}");
    assert_eq!(rep.append_failures, 0, "headroom too small for decode growth: {rep:?}");
    assert_eq!(
        rep.completed + rep.rejected,
        trace.len(),
        "requests lost under pressure: {rep:?}"
    );
    assert!(rep.peak_live_blocks <= cfg.capacity_blocks);
    assert!(rep.peak_resident_bytes <= cfg.capacity_blocks * block_bytes);
    if let Some(q) = cfg.tenant_quota_blocks {
        for (t, peak) in &rep.per_tenant_peak {
            assert!(*peak <= q, "tenant {t} peaked at {peak} > quota {q}");
        }
    }
}

#[test]
fn oversubscribed_multi_tenant_run_holds_invariants() {
    let (cfg, trace) = oversubscribed_cfg(11);
    let rep = run_memory_pressure(&cfg, &trace);
    assert_invariants(&cfg, &trace, &rep);
    // the workload genuinely oversubscribes: the gate must have deferred
    assert!(rep.deferrals > 0, "cap never bit: {rep:?}");
    // and nothing was impossible to serve
    assert_eq!(rep.rejected, 0, "workload sized to fit per-request: {rep:?}");
    // the arena was actually used near its budget (the scenario is not
    // trivially under-committed)
    assert!(
        rep.peak_live_blocks * 2 > cfg.capacity_blocks / 2,
        "pressure too low to be meaningful: {rep:?}"
    );
}

#[test]
fn prop_memory_pressure_invariants_across_seeds() {
    check("memory-pressure", 4, |rng| {
        let seed = rng.next_u64();
        let input = 96 + rng.below(25); // 96..120 tokens
        let output = 4 + rng.below(8); // 4..11 tokens
        let cfg = PressureConfig {
            capacity_blocks: 512,
            tenant_quota_blocks: Some(250),
            ..PressureConfig::default()
        };
        let trace = multi_tenant_poisson(&[4.0, 2.0, 1.0], 4, input, output, seed);
        let rep = run_memory_pressure(&cfg, &trace);
        prop_assert!(rep.drained, "deadlock: {:?}", rep);
        prop_assert_eq!(rep.capacity_violations, 0);
        prop_assert_eq!(rep.quota_violations, 0);
        prop_assert_eq!(rep.prefill_failures, 0);
        prop_assert_eq!(rep.append_failures, 0);
        prop_assert_eq!(rep.completed + rep.rejected, trace.len());
        prop_assert_eq!(rep.rejected, 0);
        prop_assert!(rep.deferrals > 0, "cap never bit: {:?}", rep);
        Ok(())
    });
}

#[test]
fn impossible_request_rejected_without_blocking_others() {
    let (cfg, mut trace) = oversubscribed_cfg(23);
    // one request whose estimated lifetime footprint exceeds usable
    // capacity: est = ceil(1.5 * 4 heads * ceil((2000+8)/4)) = 3012
    // blocks > 384 usable
    trace[1].input_tokens = 2000;
    let rep = run_memory_pressure(&cfg, &trace);
    assert!(rep.drained, "rejection must not deadlock the queue: {rep:?}");
    assert_eq!(rep.rejected, 1, "oversized request must be rejected: {rep:?}");
    assert_eq!(rep.completed, trace.len() - 1, "everything else must serve: {rep:?}");
    assert_eq!(rep.capacity_violations, 0);
    assert_eq!(rep.quota_violations, 0);
}

#[test]
fn uncontended_capacity_never_defers() {
    // a cap far above the workload's aggregate footprint must behave
    // exactly like the unbounded arena: zero deferrals, zero rejections
    let cfg = PressureConfig {
        capacity_blocks: 100_000,
        tenant_quota_blocks: None,
        ..PressureConfig::default()
    };
    let trace = multi_tenant_poisson(&[4.0, 2.0], 3, 64, 4, 5);
    let rep = run_memory_pressure(&cfg, &trace);
    assert!(rep.drained);
    assert_eq!(rep.deferrals, 0, "uncontended run must not defer: {rep:?}");
    assert_eq!(rep.rejected, 0);
    assert_eq!(rep.completed, trace.len());
}

/// Nightly-scale sweep (CI runs it via `--include-ignored`): more
/// tenants, longer backlogs, more seeds — the same three invariants.
#[test]
#[ignore = "nightly-scale memory-pressure sweep; run with --include-ignored"]
fn prop_memory_pressure_nightly_sweep() {
    check("memory-pressure-nightly", 10, |rng| {
        let seed = rng.next_u64();
        let rates = [8.0, 4.0, 2.0, 1.0];
        let input = 80 + rng.below(41); // 80..120
        let output = 4 + rng.below(12); // 4..15
        let cfg = PressureConfig {
            capacity_blocks: 384 + 128 * rng.below(3), // 384 / 512 / 640
            tenant_quota_blocks: Some(260),
            max_batch: 1 + rng.below(8),
            ..PressureConfig::default()
        };
        let trace = multi_tenant_poisson(&rates, 8, input, output, seed);
        let rep = run_memory_pressure(&cfg, &trace);
        prop_assert!(rep.drained, "deadlock: {:?}", rep);
        prop_assert_eq!(rep.capacity_violations, 0);
        prop_assert_eq!(rep.quota_violations, 0);
        prop_assert_eq!(rep.prefill_failures, 0);
        prop_assert_eq!(rep.append_failures, 0);
        prop_assert_eq!(rep.completed + rep.rejected, trace.len());
        prop_assert!(
            rep.peak_live_blocks <= cfg.capacity_blocks,
            "peak {} > cap {}",
            rep.peak_live_blocks,
            cfg.capacity_blocks
        );
        Ok(())
    });
}
