//! Integration tests: cross-module behaviour over the runtime, index,
//! buffer and coordinator — plus randomized property tests (mini-proptest)
//! on the invariants DESIGN.md calls out: token partition, zone ordering,
//! budget monotonicity, cache consistency and batching equivalence.

use retroinfer::attention::full_attention;
use retroinfer::baselines::{all_systems, SparseSystem};
use retroinfer::buffer::{ExecBuffer, WaveBuffer};
use retroinfer::config::{BufferConfig, CachePolicy, ZoneConfig};
use retroinfer::coordinator::{Action, Batcher, Request, Scheduler};
use retroinfer::index::{SelectScratch, WaveIndex};
use retroinfer::tensor::dot;
use retroinfer::util::prop::check;
use retroinfer::util::rng::Rng;
use retroinfer::util::stats::cosine;
use retroinfer::util::threadpool::ThreadPool;
use retroinfer::{prop_assert, prop_assert_eq};
use std::sync::Arc;

fn small_zone(n: usize) -> ZoneConfig {
    ZoneConfig {
        steady_sink: 4,
        steady_local: 16,
        tokens_per_cluster: 8,
        build_segment: (n / 2).max(64),
        update_segment: 32,
        kmeans_iters: 5,
        ..ZoneConfig::default()
    }
}

/// Invariant: build + any number of appends partitions every token into
/// exactly one of {sink, pending, some cluster}.
#[test]
fn prop_index_partitions_tokens() {
    check("index-partition", 12, |rng| {
        let d = 8 + 8 * rng.below(2); // 8 or 16
        let n = 64 + rng.below(400);
        let keys = rng.normal_vec(n * d);
        let vals = rng.normal_vec(n * d);
        let mut idx = WaveIndex::build(small_zone(n), d, 512, &keys, &vals, rng.next_u64());
        let appends = rng.below(120);
        for _ in 0..appends {
            let k = rng.normal_vec(d);
            let v = rng.normal_vec(d);
            idx.append(&k, &v);
        }
        let total = n + appends;
        prop_assert_eq!(idx.n_seen(), total);
        let mut seen = vec![0u32; total];
        for c in 0..idx.meta().m() {
            for &p in idx.meta().cluster_tokens(c) {
                seen[p as usize] += 1;
            }
        }
        let sel = Default::default();
        for p in idx.exact_positions(&sel) {
            seen[p as usize] += 1;
        }
        prop_assert!(seen.iter().all(|&s| s == 1), "partition violated: {:?}", seen.iter().enumerate().filter(|(_, &s)| s != 1).take(3).collect::<Vec<_>>());
        Ok(())
    });
}

/// Invariant: retrieval-zone centroid scores dominate estimation-zone
/// scores, and growing the retrieval budget only adds clusters.
#[test]
fn prop_zone_ordering_and_monotonicity() {
    check("zone-ordering", 10, |rng| {
        let d = 16;
        let n = 256 + rng.below(512);
        let keys = rng.normal_vec(n * d);
        let vals = rng.normal_vec(n * d);
        let idx = WaveIndex::build(small_zone(n), d, 1024, &keys, &vals, rng.next_u64());
        let m = idx.meta().m();
        if m < 4 {
            return Ok(());
        }
        let q = rng.normal_vec(d);
        let mut sc = SelectScratch::default();
        let r = 1 + rng.below(m / 2);
        let e = rng.below(m - r);
        let sel = idx.select_with(&q, r, e, &mut sc);
        let score = |c: u32| dot(&q, idx.meta().centroid(c as usize));
        let min_r = sel.retrieval.iter().map(|&c| score(c)).fold(f32::INFINITY, f32::min);
        for &c in &sel.estimation {
            prop_assert!(score(c) <= min_r + 1e-4, "estimation beats retrieval");
        }
        // monotonicity: r+1 retrieval is a superset
        let sel2 = idx.select_with(&q, r + 1, e.saturating_sub(1), &mut sc);
        for c in &sel.retrieval {
            prop_assert!(sel2.retrieval.contains(c), "budget growth dropped cluster {c}");
        }
        Ok(())
    });
}

/// Invariant: wave attention converges to full attention as the retrieval
/// budget approaches the whole index (with estimation covering the rest,
/// fidelity is monotone-ish; at full budget it is exact).
#[test]
fn prop_full_budget_exactness() {
    check("full-budget-exact", 8, |rng| {
        let d = 16;
        let n = 200 + rng.below(300);
        let keys = rng.normal_vec(n * d);
        let vals = rng.normal_vec(n * d);
        let idx = WaveIndex::build(small_zone(n), d, 1024, &keys, &vals, rng.next_u64());
        let q = rng.normal_vec(d);
        let mut sc = SelectScratch::default();
        let sel = idx.select_with(&q, idx.meta().m(), 0, &mut sc);
        let mut out = vec![0.0; d];
        idx.attend(&q, &sel, &mut out);
        let mut full = vec![0.0; d];
        full_attention(&q, &keys, &vals, d, &mut full);
        prop_assert!(cosine(&out, &full) > 0.999, "cos = {}", cosine(&out, &full));
        Ok(())
    });
}

/// Invariant: the wave buffer serves byte-identical data through hit and
/// miss paths, under every cache policy, and never exceeds capacity.
#[test]
fn prop_buffer_consistency_all_policies() {
    check("buffer-consistency", 8, |rng| {
        let d = 16;
        let n = 512;
        let keys = rng.normal_vec(n * d);
        let vals = rng.normal_vec(n * d);
        let idx = WaveIndex::build(small_zone(n), d, 1024, &keys, &vals, rng.next_u64());
        let policies = [CachePolicy::Lru, CachePolicy::Fifo, CachePolicy::Clock, CachePolicy::TwoQ];
        let policy = policies[rng.below(4)];
        let cap = 2 + rng.below(16);
        let bcfg = BufferConfig { policy, async_update: false, ..BufferConfig::default() };
        let pool = Arc::new(ThreadPool::new(1));
        let wb = WaveBuffer::new(bcfg, d, idx.store().tokens_per_block(), cap, pool);
        wb.register_index(&idx);
        let mut sc = SelectScratch::default();
        let mut eb1 = ExecBuffer::new(d);
        let mut eb2 = ExecBuffer::new(d);
        for _ in 0..20 {
            let q = rng.normal_vec(d);
            let sel = idx.select_with(&q, 1 + rng.below(6), 0, &mut sc);
            wb.assemble(&idx, &sel, &mut eb1);
            wb.assemble(&idx, &sel, &mut eb2);
            prop_assert_eq!(eb1.keys, eb2.keys);
            prop_assert_eq!(eb1.vals, eb2.vals);
            prop_assert!(wb.resident_blocks() <= cap, "capacity exceeded");
            prop_assert!(wb.check_consistency(), "mapping/cache inconsistent");
        }
        Ok(())
    });
}

/// Invariant: every sparse system returns finite outputs and in-range
/// positions for arbitrary budgets, including degenerate ones.
#[test]
fn prop_systems_robust_to_budgets() {
    check("system-budgets", 6, |rng| {
        let d = 16;
        let n = 128 + rng.below(256);
        let keys = rng.normal_vec(n * d);
        let vals = rng.normal_vec(n * d);
        let q = rng.normal_vec(d);
        for sys in all_systems(&keys, &vals, d, rng.next_u64()).iter_mut() {
            for budget in [1usize, 7, n / 2, n, 3 * n] {
                let mut out = vec![0.0; d];
                let st = sys.decode(&q, budget, &mut out);
                prop_assert!(
                    out.iter().all(|x| x.is_finite()),
                    "{} budget {budget}: non-finite",
                    sys.name()
                );
                prop_assert!(
                    st.exact_positions.iter().all(|&p| (p as usize) < n),
                    "{} budget {budget}: bad position",
                    sys.name()
                );
            }
        }
        Ok(())
    });
}

/// Invariant: the scheduler conserves requests — every submitted request
/// finishes exactly once with exactly max_new tokens, under random
/// interleavings of arrivals.
#[test]
fn prop_scheduler_conserves_requests() {
    check("scheduler-conservation", 10, |rng| {
        let max_batch = 1 + rng.below(8);
        let mut sched = Scheduler::new(Batcher::new(&[1, 2, 4, 8], max_batch));
        let n_req = 1 + rng.below(12);
        let mut submitted = 0u64;
        let mut now = 0.0;
        let mut steps = 0;
        while !sched.all_done() || submitted < n_req as u64 {
            steps += 1;
            prop_assert!(steps < 10_000, "scheduler did not terminate");
            // random arrivals interleaved with service
            if submitted < n_req as u64 && rng.below(3) == 0 {
                let max_new = 1 + rng.below(5);
                sched.submit(Request::new(submitted, vec![1, 2, 3], max_new), now);
                submitted += 1;
            }
            now += 0.1;
            match sched.next_action() {
                Action::Prefill(id) => sched.prefill_done(id, 0, now),
                Action::DecodeBatch(ids, bucket) => {
                    prop_assert!(ids.len() <= bucket);
                    prop_assert!(bucket <= 8);
                    for id in ids {
                        sched.token_decoded(id, 1, now);
                    }
                }
                Action::Defer => {
                    prop_assert!(false, "defer without admission control");
                }
                Action::Idle => {
                    if submitted == n_req as u64 {
                        break;
                    }
                }
            }
        }
        // drain remaining service
        let mut guard = 0;
        while !sched.all_done() {
            guard += 1;
            prop_assert!(guard < 10_000, "drain did not terminate");
            now += 0.1;
            match sched.next_action() {
                Action::Prefill(id) => sched.prefill_done(id, 0, now),
                Action::DecodeBatch(ids, _) => {
                    for id in ids {
                        sched.token_decoded(id, 1, now);
                    }
                }
                Action::Defer => {
                    prop_assert!(false, "defer without admission control");
                }
                Action::Idle => break,
            }
        }
        prop_assert_eq!(sched.sessions().count(), n_req);
        for s in sched.sessions() {
            prop_assert_eq!(s.generated.len(), s.req.max_new);
            prop_assert!(s.done_s >= s.req.arrive_s, "finished before arrival");
        }
        Ok(())
    });
}

/// Shared baseline fixture: `full`, `quest` and `retro` decode the same
/// tiny seeded workload — 8 semantic key bundles INTERLEAVED in position
/// so positional chunks (Quest) mix topics while k-means clusters
/// (RetroInfer) separate them — and are scored by attention-mass recall:
/// the fraction of the true softmax mass carried by the positions each
/// system attends exactly. Locks in the paper's tripartite-approximation
/// accuracy claim at toy scale: full is exact (recall 1), retro ≥ the
/// sparse baseline at the same budget.
#[test]
fn retro_recall_dominates_sparse_baseline_on_shared_fixture() {
    use retroinfer::attention::attention_weights;
    use retroinfer::baselines::{FullAttention, Quest, Retro};

    fn attention_mass(q: &[f32], keys: &[f32], d: usize, exact: &[u32]) -> f64 {
        let w = attention_weights(q, keys, d);
        exact.iter().map(|&p| w[p as usize] as f64).sum()
    }

    let d = 16;
    let n = 512;
    let mut rng = Rng::new(42);
    let dirs: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(d)).collect();
    let mut keys = Vec::with_capacity(n * d);
    for i in 0..n {
        let t = &dirs[i % 8]; // topics interleave token-by-token
        for j in 0..d {
            keys.push(2.0 * t[j] + 0.3 * rng.normal_f32());
        }
    }
    let vals = rng.normal_vec(n * d);
    let budget = 64;

    let mut full = FullAttention::new(&keys, &vals, d);
    let mut quest = Quest::new(&keys, &vals, d, 16);
    let mut retro = Retro::build_default(&keys, &vals, d, 7);
    let (mut rf, mut rq, mut rr) = (0.0f64, 0.0f64, 0.0f64);
    let mut out = vec![0.0; d];
    for t in 0..8 {
        let q: Vec<f32> = dirs[t].iter().map(|x| 1.5 * x).collect();
        rf += attention_mass(&q, &keys, d, &full.decode(&q, n, &mut out).exact_positions);
        rq += attention_mass(&q, &keys, d, &quest.decode(&q, budget, &mut out).exact_positions);
        rr += attention_mass(&q, &keys, d, &retro.decode(&q, budget, &mut out).exact_positions);
    }
    let (rf, rq, rr) = (rf / 8.0, rq / 8.0, rr / 8.0);
    assert!((rf - 1.0).abs() < 1e-4, "full attention must be exact (recall {rf})");
    assert!(
        rr >= rq,
        "retro attention-mass recall {rr:.3} must be >= quest's {rq:.3} at budget {budget}"
    );
    assert!(rr > 0.3, "retro recall degenerate at {rr:.3}");
}

/// Cross-layer: the PJRT-executed tripartite kernel agrees with the pure
/// Rust tripartite oracle on random (masked, padded) inputs.
#[test]
fn kernel_matches_rust_oracle_via_pjrt() {
    retroinfer::require_live_path!();
    use retroinfer::attention::{tripartite_attention, TripartiteInputs};
    use retroinfer::runtime::tinylm::{TinyLm, WaveInputs};
    use retroinfer::runtime::default_artifacts_dir;
    use retroinfer::tensor::Tensor;

    let mut lm = TinyLm::load(&default_artifacts_dir()).unwrap();
    let (kvh, d, g) = (lm.cfg.kv_heads, lm.cfg.d_head, lm.cfg.group());
    let (ne, mcap) = (lm.buckets.wave_ne, lm.buckets.wave_m);
    let mut rng = Rng::new(99);

    let n_exact = 100;
    let n_est = 37;
    let mut wi = WaveInputs::zeros(1, kvh, ne, mcap, d);
    for h in 0..kvh {
        for t in 0..n_exact {
            wi.kmask[h * ne + t] = 1.0;
        }
        let base = h * ne * d;
        for x in &mut wi.kx[base..base + n_exact * d] {
            *x = rng.normal_f32();
        }
        for x in &mut wi.vx[base..base + n_exact * d] {
            *x = rng.normal_f32();
        }
        let mbase = h * mcap * d;
        for x in &mut wi.cent[mbase..mbase + n_est * d] {
            *x = rng.normal_f32();
        }
        for x in &mut wi.vsum[mbase..mbase + n_est * d] {
            *x = rng.normal_f32();
        }
        for c in 0..n_est {
            wi.csize[h * mcap + c] = 1.0 + rng.below(16) as f32;
            wi.emask[h * mcap + c] = 1.0;
        }
    }
    let qdata = rng.normal_vec(kvh * g * d);
    let q = Tensor::from_vec(&[1, kvh, g, d], qdata.clone());
    let ctx = lm.attn_wave(&q, &wi).unwrap();

    for h in 0..kvh {
        let exact: Vec<usize> = (0..n_exact).collect();
        let estimated: Vec<usize> = (0..n_est).collect();
        let keys = &wi.kx[h * ne * d..h * ne * d + n_exact * d];
        let vals = &wi.vx[h * ne * d..h * ne * d + n_exact * d];
        let inp = TripartiteInputs {
            d,
            keys,
            vals,
            exact: &exact,
            centroids: &wi.cent[h * mcap * d..(h * mcap + n_est) * d],
            vsum: &wi.vsum[h * mcap * d..(h * mcap + n_est) * d],
            sizes: &wi.csize[h * mcap..h * mcap + n_est],
            estimated: &estimated,
        };
        for gi in 0..g {
            let qr = &qdata[(h * g + gi) * d..(h * g + gi + 1) * d];
            let mut oracle = vec![0.0f32; d];
            tripartite_attention(qr, &inp, &mut oracle);
            let got = &ctx.data()[(h * g + gi) * d..(h * g + gi + 1) * d];
            let c = cosine(got, &oracle);
            assert!(c > 0.9999, "head {h} group {gi}: kernel/oracle cos {c}");
        }
    }
}

/// Invariant: simulator throughput is monotone in the obvious directions —
/// more context never increases throughput; a higher hit ratio never
/// decreases it; every breakdown term is non-negative and finite.
#[test]
fn prop_memsim_monotonicity() {
    use retroinfer::config::{HardwareSpec, ModelSpec};
    use retroinfer::memsim::{self, profiles};
    check("memsim-monotone", 12, |rng| {
        let model = ModelSpec::llama3_8b();
        let hw = HardwareSpec::a100();
        let ctx = 8 * 1024 + rng.below(120 * 1024);
        let b = 1 + rng.below(16);
        let h1 = rng.f64() * 0.9;
        let h2 = (h1 + rng.f64() * (0.99 - h1)).min(0.99);
        let p_lo = profiles::retroinfer(h1);
        let p_hi = profiles::retroinfer(h2);
        let t_lo = memsim::decode_throughput(&model, &hw, &p_lo, ctx, b);
        let t_hi = memsim::decode_throughput(&model, &hw, &p_hi, ctx, b);
        if let (Ok(lo), Ok(hi)) = (t_lo, t_hi) {
            prop_assert!(hi >= lo - 1e-9, "higher hit ratio slower: {hi} < {lo}");
        }
        // more context at the same batch is never faster
        if let (Ok(a), Ok(c)) = (
            memsim::decode_throughput(&model, &hw, &p_lo, ctx, b),
            memsim::decode_throughput(&model, &hw, &p_lo, ctx * 2, b),
        ) {
            prop_assert!(c <= a + 1e-9, "longer context faster: {c} > {a}");
        }
        // breakdown terms finite and non-negative
        let br = memsim::decode_step(&model, &hw, &p_lo, ctx, b);
        for v in [br.dense_s, br.attn_gpu_s, br.scan_s, br.estimation_s, br.pcie_s, br.cpu_s, br.overhead_s, br.total_s] {
            prop_assert!(v.is_finite() && v >= 0.0, "bad breakdown term {v}");
        }
        prop_assert!(br.total_s > 0.0);
        Ok(())
    });
}

/// Invariant: memory accounting — max_batch is exactly the largest batch
/// that passes check_fit, and OOM is monotone in batch and context.
#[test]
fn prop_memsim_oom_monotone() {
    use retroinfer::config::{HardwareSpec, ModelSpec};
    use retroinfer::memsim::{self, profiles};
    check("memsim-oom", 10, |rng| {
        let model = ModelSpec::llama3_8b();
        let hw = HardwareSpec::a100();
        let profs = [profiles::full(), profiles::quest(), profiles::retroinfer(0.85), profiles::infinigen()];
        let p = &profs[rng.below(4)];
        let ctx = 16 * 1024 + rng.below(1 << 20);
        let mb = memsim::max_batch(&model, &hw, p, ctx);
        if mb > 0 {
            prop_assert!(memsim::check_fit(&model, &hw, p, ctx, mb).is_ok());
        }
        prop_assert!(memsim::check_fit(&model, &hw, p, ctx, mb + 1).is_err());
        // OOM monotone in context
        if mb == 0 {
            prop_assert_eq!(memsim::max_batch(&model, &hw, p, ctx * 2), 0);
        }
        Ok(())
    });
}
