//! Tiered-arena (cold-tier spill) integration tests — DESIGN.md §2
//! "Tiered arena & spill" invariants:
//!
//! 1. **No block in two tiers**: under any interleaving of alloc /
//!    demote / promote / reclaim, a block id is hot xor cold, and the
//!    arena's tier counters track a reference model exactly.
//! 2. **Bit-identity**: demote→promote round-trips reproduce every f32
//!    bit pattern; the cold read path serves bytes identical to hot.
//! 3. **Hot cap holds under overcommit**: with the spill tier enabled,
//!    the `workload::pressure` driver keeps hot-resident blocks ≤ cap
//!    at every step even while total live blocks exceed the cap, with
//!    zero deferrals (tiered admission) and zero lost requests.
//! 4. **Mapping bookkeeping**: invalidating a cluster with mixed
//!    `BlockHome` states (`Gpu` + `Cold` + `Cpu`) leaves no stale
//!    `owner` reverse-map entry (eviction-bookkeeping regression).

use retroinfer::attention::full_attention;
use retroinfer::buffer::{BlockHome, ExecBuffer, MappingTable, WaveBuffer};
use retroinfer::config::{BufferConfig, ZoneConfig};
use retroinfer::engine::{AssembleShape, BatchAssembler, HeadTask};
use retroinfer::index::{SelectScratch, WaveIndex};
use retroinfer::kvcache::arena::BlockData;
use retroinfer::kvcache::{
    BlockArena, BlockRef, CodecTag, ColdestFirst, HeadStore, DEFAULT_TENANT,
};
use retroinfer::prop_assert;
use retroinfer::prop_assert_eq;
use retroinfer::runtime::tinylm::WaveInputs;
use retroinfer::util::prop::check;
use retroinfer::util::rng::Rng;
use retroinfer::util::threadpool::ThreadPool;
use retroinfer::workload::{multi_tenant_poisson, run_memory_pressure, PressureConfig};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn small_zone() -> ZoneConfig {
    ZoneConfig {
        steady_sink: 4,
        steady_local: 16,
        tokens_per_cluster: 8,
        build_segment: 256,
        update_segment: 32,
        kmeans_iters: 4,
        ..ZoneConfig::default()
    }
}

/// (1) Tier accounting vs a reference model under random interleaving.
#[test]
fn prop_arena_tier_accounting_consistent() {
    check("arena-tier-accounting", 8, |rng| {
        let arena = BlockArena::shared(8, 256);
        let cap = 6 + rng.below(20);
        arena.set_capacity_blocks(Some(cap));
        let mut hot: Vec<(u64, BlockData)> = Vec::new();
        let mut cold: Vec<u64> = Vec::new();
        for _ in 0..300 {
            match rng.below(4) {
                0 => match arena.try_alloc_for(DEFAULT_TENANT) {
                    Ok((id, b)) => {
                        prop_assert!(hot.len() < cap, "alloc succeeded at the hot cap");
                        hot.push((id, b));
                    }
                    Err(_) => prop_assert_eq!(hot.len(), cap),
                },
                1 if !hot.is_empty() => {
                    let k = rng.below(hot.len());
                    let (id, b) = hot.swap_remove(k);
                    arena.demote_for(DEFAULT_TENANT, id, b);
                    cold.push(id);
                }
                2 if !cold.is_empty() => {
                    let k = rng.below(cold.len());
                    let id = cold.swap_remove(k);
                    match arena.try_promote_for(DEFAULT_TENANT, id) {
                        Ok((b, _)) => hot.push((id, b)),
                        Err(_) => {
                            prop_assert_eq!(hot.len(), cap);
                            cold.push(id);
                        }
                    }
                }
                3 if !hot.is_empty() => {
                    let (_, b) = hot.pop().unwrap();
                    arena.reclaim_for(DEFAULT_TENANT, [b]);
                }
                _ => {}
            }
            prop_assert_eq!(arena.live_blocks(), hot.len());
            prop_assert_eq!(arena.cold_blocks(), cold.len());
            prop_assert_eq!(arena.total_live_blocks(), hot.len() + cold.len());
            prop_assert!(
                arena.live_blocks() + arena.free_blocks() <= cap,
                "hot-resident {} blocks exceeds cap {}",
                arena.live_blocks() + arena.free_blocks(),
                cap
            );
            prop_assert_eq!(
                arena.allocated_total() - arena.reclaimed_total(),
                hot.len() as u64
            );
            // no block is ever in two tiers
            for &id in &cold {
                prop_assert!(arena.spill().contains(id), "cold block {} lost", id);
            }
            for (id, _) in &hot {
                prop_assert!(!arena.spill().contains(*id), "hot block {} also cold", id);
            }
        }
        // teardown: cold blocks drop in place, hot blocks reclaim
        for id in cold {
            prop_assert!(arena.drop_cold(id));
        }
        arena.reclaim_for(DEFAULT_TENANT, hot.into_iter().map(|(_, b)| b));
        prop_assert_eq!(arena.live_blocks(), 0);
        prop_assert_eq!(arena.cold_blocks(), 0);
        Ok(())
    });
}

/// (2) Demote→promote round-trips are bit-identical for every block —
/// including NaN / denormal / negative-zero f32 bit patterns.
#[test]
fn prop_demote_promote_roundtrip_bit_identical() {
    check("spill-roundtrip", 8, |rng| {
        let d = 8;
        let arena = BlockArena::shared(d, 256); // tpb = 4
        let mut hs = HeadStore::new_in(Arc::clone(&arena));
        let n = 9 + rng.below(40);
        let keys: Vec<f32> =
            (0..n * d).map(|_| f32::from_bits(rng.next_u64() as u32)).collect();
        let vals: Vec<f32> =
            (0..n * d).map(|_| f32::from_bits(rng.next_u64() as u32)).collect();
        let pos: Vec<u32> = (0..n as u32).collect();
        let refs = hs.try_alloc_cluster(&keys, &vals, &pos).unwrap();
        let snap: Vec<(Vec<u32>, Vec<u32>, Vec<u32>)> = refs
            .iter()
            .map(|r| {
                (
                    hs.block_keys(*r).iter().map(|x| x.to_bits()).collect(),
                    hs.block_vals(*r).iter().map(|x| x.to_bits()).collect(),
                    hs.block_pos(*r).to_vec(),
                )
            })
            .collect();
        for r in &refs {
            prop_assert!(hs.demote_block(*r));
        }
        prop_assert_eq!(arena.live_blocks(), 0);
        prop_assert_eq!(arena.cold_blocks(), refs.len());
        // promote in a scrambled order: page recycling must not leak
        // one block's bytes into another
        let mut order: Vec<usize> = (0..refs.len()).collect();
        rng.shuffle(&mut order);
        for &i in &order {
            prop_assert!(hs.promote_block(refs[i]).unwrap().is_some());
        }
        for (r, want) in refs.iter().zip(&snap) {
            let got_k: Vec<u32> = hs.block_keys(*r).iter().map(|x| x.to_bits()).collect();
            let got_v: Vec<u32> = hs.block_vals(*r).iter().map(|x| x.to_bits()).collect();
            prop_assert!(got_k == want.0, "keys changed bits across the round-trip");
            prop_assert!(got_v == want.1, "vals changed bits across the round-trip");
            prop_assert!(hs.block_pos(*r) == &want.2[..], "positions changed");
        }
        prop_assert_eq!(arena.cold_blocks(), 0);
        Ok(())
    });
}

/// (2b) End-to-end data-path identity: attention over a fully demoted
/// index is bit-identical to attention over the hot index, and matches
/// full attention at full retrieval budget.
#[test]
fn attend_is_bit_identical_after_full_demotion() {
    let d = 16;
    let mut rng = Rng::new(42);
    let k = rng.normal_vec(512 * d);
    let v = rng.normal_vec(512 * d);
    let mut idx = WaveIndex::build(small_zone(), d, 1024, &k, &v, 7);
    let m = idx.meta().m();
    assert!(m > 0);
    let q = rng.normal_vec(d);
    let mut sc = SelectScratch::default();
    let sel = idx.select_with(&q, m, 0, &mut sc); // retrieve ALL clusters
    let mut hot_out = vec![0.0; d];
    idx.attend(&q, &sel, &mut hot_out);
    // demote every cluster
    let mut demoted = 0;
    for c in 0..m {
        demoted += idx.demote_cluster(c as u32);
        assert!(!idx.cluster_is_hot(c as u32));
    }
    assert!(demoted > 0);
    assert_eq!(idx.arena().live_blocks(), 0, "all clustered blocks must be cold");
    let mut cold_out = vec![0.0; d];
    idx.attend(&q, &sel, &mut cold_out);
    assert_eq!(
        hot_out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        cold_out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "cold-tier attention must be bit-identical to hot"
    );
    let mut full = vec![0.0; d];
    full_attention(&q, &k, &v, d, &mut full);
    let cos = retroinfer::util::stats::cosine(&cold_out, &full);
    assert!(cos > 0.999, "cold full-budget attention vs full: {cos}");
}

/// Policy-driven demotion respects access recency: the clusters the
/// last selection retrieved are demoted last.
#[test]
fn demote_until_spills_coldest_clusters_first() {
    let d = 16;
    let mut rng = Rng::new(9);
    let k = rng.normal_vec(768 * d);
    let v = rng.normal_vec(768 * d);
    let mut idx = WaveIndex::build(small_zone(), d, 1024, &k, &v, 3);
    let m = idx.meta().m();
    assert!(m >= 4);
    let q = rng.normal_vec(d);
    let mut sc = SelectScratch::default();
    let sel = idx.select_with(&q, 2, 0, &mut sc);
    idx.note_selection(&sel);
    assert!(idx.selection_epoch() > 0);
    let hot_sel: Vec<u32> = sel.retrieval.clone();
    // demote roughly half the blocks: the recently-selected clusters
    // must survive in the hot tier
    let total_hot: usize = (0..m).map(|c| idx.cluster_hot_blocks(c as u32)).sum();
    let (freed, demoted) = idx.demote_until(&ColdestFirst, total_hot / 2);
    assert!(freed >= total_hot / 2);
    for c in &hot_sel {
        assert!(
            !demoted.contains(c) && idx.cluster_is_hot(*c),
            "recently-retrieved cluster {c} was demoted before colder ones"
        );
    }
    // the recent (wanted) set is what the engine prefetches
    assert_eq!(idx.recent_clusters(), hot_sel);
}

/// (4) Mapping regression: invalidating a cluster with mixed homes
/// cannot leave a stale owner reverse-map entry, and the wave buffer's
/// demote/promote notes keep cache and mapping consistent.
#[test]
fn mapping_invalidation_and_tier_notes_stay_consistent() {
    let bref = |block: u64, idx: u32, len: u16| BlockRef { block, idx, len };
    let mut mt = MappingTable::new();
    let c0 = mt.add_cluster(vec![bref(100, 0, 8), bref(101, 1, 8), bref(102, 2, 2)]);
    let c1 = mt.add_cluster(vec![bref(103, 0, 8)]);
    mt.set_cached(100, 5);
    mt.set_cold(101);
    // mixed Gpu + Cold + Cpu: every owner entry must go
    let removed = mt.invalidate_cluster(c0);
    assert_eq!(removed.len(), 3);
    assert!(removed.contains(&(100, BlockHome::Gpu(5))));
    assert!(removed.contains(&(101, BlockHome::Cold)));
    assert!(removed.contains(&(102, BlockHome::Cpu)));
    for b in [100u64, 101, 102] {
        assert_eq!(mt.owner(b), (u32::MAX, 0), "stale owner entry for {b}");
    }
    // the untouched cluster keeps its entries; stale-id updates are
    // no-ops rather than corruption
    assert_eq!(mt.owner(103), (c1, 0));
    mt.set_cold(101);
    mt.set_evicted(100);
    assert_eq!(mt.gpu_resident_blocks(), 0);
    assert_eq!(mt.cold_blocks(), 0);
}

/// Cold clusters selected by a query are cold-hit stalls served through
/// the spill tier with bytes identical to the hot path (buffer-level
/// counterpart of the engine's promote-then-fill).
#[test]
fn buffer_assembly_survives_mid_stream_demotion() {
    let d = 16;
    let mut rng = Rng::new(11);
    let k = rng.normal_vec(512 * d);
    let v = rng.normal_vec(512 * d);
    let mut idx = WaveIndex::build(small_zone(), d, 1024, &k, &v, 5);
    let pool = Arc::new(ThreadPool::new(2));
    let bcfg = BufferConfig::default();
    let cap = WaveBuffer::capacity_for(&bcfg, 512, idx.store().tokens_per_block());
    let wb = WaveBuffer::new(bcfg, d, idx.store().tokens_per_block(), cap, Arc::clone(&pool));
    wb.register_index(&idx);
    let q = rng.normal_vec(d);
    let mut sc = SelectScratch::default();
    let sel = idx.select_with(&q, 4, 0, &mut sc);
    let mut eb_hot = ExecBuffer::new(d);
    wb.assemble(&idx, &sel, &mut eb_hot);
    wb.flush();
    // demote the retrieved clusters (GPU copies go with them)
    for &c in &sel.retrieval {
        idx.demote_cluster(c);
        wb.note_demoted(idx.cluster_blocks(c));
    }
    assert!(wb.check_consistency());
    let mut eb_cold = ExecBuffer::new(d);
    let st = wb.assemble(&idx, &sel, &mut eb_cold);
    assert!(st.cold_blocks > 0, "demoted blocks must count as cold-hit stalls");
    assert_eq!(st.hit_blocks, 0, "demotion must invalidate GPU-cache copies");
    assert_eq!(eb_hot.keys, eb_cold.keys, "cold assembly changed bytes");
    assert_eq!(eb_hot.vals, eb_cold.vals);
    assert!(wb.stats().spill_bytes.load(std::sync::atomic::Ordering::Relaxed) > 0);
}

/// (3) Overcommitted multi-tenant trace with the cold tier enabled:
/// hot-resident blocks never exceed the hot cap while total live blocks
/// do; no deferrals (tiered admission), no lost requests, tier traffic
/// in both directions, and cold blocks die with their sessions.
#[test]
fn spilled_pressure_run_keeps_hot_tier_bounded() {
    let cfg = PressureConfig {
        capacity_blocks: 256,
        tenant_quota_blocks: None,
        spill: true,
        ..PressureConfig::default()
    };
    let trace = multi_tenant_poisson(&[4.0, 2.0, 1.0], 4, 112, 8, 11);
    let rep = run_memory_pressure(&cfg, &trace);
    assert!(rep.drained, "tiered run deadlocked: {rep:?}");
    assert_eq!(rep.capacity_violations, 0, "hot tier exceeded its cap: {rep:?}");
    assert_eq!(rep.prefill_failures, 0, "demote-then-retry failed a prefill: {rep:?}");
    assert_eq!(rep.append_failures, 0, "demote-then-retry failed an append: {rep:?}");
    assert_eq!(rep.deferrals, 0, "tiered admission must never defer: {rep:?}");
    assert_eq!(rep.rejected, 0);
    assert_eq!(rep.completed, trace.len(), "requests lost under spill: {rep:?}");
    assert!(rep.demotions > 0, "overcommit must force demotions: {rep:?}");
    assert!(rep.promotions > 0, "decode must promote spilled blocks: {rep:?}");
    assert!(
        rep.peak_total_live_blocks > cfg.capacity_blocks,
        "workload must genuinely exceed the hot tier: {rep:?}"
    );
    assert!(rep.peak_live_blocks <= cfg.capacity_blocks);
    assert_eq!(rep.final_cold_blocks, 0, "finished sessions must drop cold blocks: {rep:?}");
}

/// Same invariants across seeds (tier-1 scale).
#[test]
fn prop_spilled_pressure_invariants_across_seeds() {
    check("spill-pressure", 3, |rng| {
        let seed = rng.next_u64();
        let input = 96 + rng.below(25);
        let output = 4 + rng.below(8);
        let cfg = PressureConfig {
            capacity_blocks: 192 + 64 * rng.below(3),
            tenant_quota_blocks: None,
            spill: true,
            ..PressureConfig::default()
        };
        let trace = multi_tenant_poisson(&[4.0, 2.0, 1.0], 4, input, output, seed);
        let rep = run_memory_pressure(&cfg, &trace);
        prop_assert!(rep.drained, "deadlock: {:?}", rep);
        prop_assert_eq!(rep.capacity_violations, 0);
        prop_assert_eq!(rep.prefill_failures, 0);
        prop_assert_eq!(rep.append_failures, 0);
        prop_assert_eq!(rep.deferrals, 0);
        prop_assert_eq!(rep.completed, trace.len());
        prop_assert!(rep.demotions > 0, "no demotions: {:?}", rep);
        prop_assert_eq!(rep.final_cold_blocks, 0);
        prop_assert!(rep.peak_live_blocks <= cfg.capacity_blocks, "hot cap broken");
        Ok(())
    });
}

/// Spill-codec tentpole (DESIGN.md §2 "Spill codecs"), part 1: the
/// Exact codec is a bit-identical passthrough for EVERY f32 bit
/// pattern — NaN payloads, denormals, negative zero, infinities — even
/// when a lossy codec is configured store-wide, because the default
/// demote path is never lossy-eligible. Pages must carry the Exact tag.
#[test]
fn prop_exact_pages_roundtrip_all_bit_patterns_under_lossy_config() {
    check("spill-exact-under-lossy-config", 8, |rng| {
        let d = 8;
        let arena = BlockArena::shared(d, 256); // tpb = 4
        arena.spill().set_codec(CodecTag::Int8Angle);
        let mut hs = HeadStore::new_in(Arc::clone(&arena));
        let n = 9 + rng.below(40);
        let keys: Vec<f32> =
            (0..n * d).map(|_| f32::from_bits(rng.next_u64() as u32)).collect();
        let vals: Vec<f32> =
            (0..n * d).map(|_| f32::from_bits(rng.next_u64() as u32)).collect();
        let pos: Vec<u32> = (0..n as u32).collect();
        let refs = hs.try_alloc_cluster(&keys, &vals, &pos).unwrap();
        let snap: Vec<(Vec<u32>, Vec<u32>)> = refs
            .iter()
            .map(|r| {
                (
                    hs.block_keys(*r).iter().map(|x| x.to_bits()).collect(),
                    hs.block_vals(*r).iter().map(|x| x.to_bits()).collect(),
                )
            })
            .collect();
        for r in &refs {
            prop_assert!(hs.demote_block(*r)); // not lossy-eligible
        }
        for r in &refs {
            prop_assert_eq!(arena.spill().page_tag(r.block), Some(CodecTag::Exact));
        }
        prop_assert_eq!(arena.spill().compressed_blocks(), 0);
        prop_assert_eq!(
            arena.spill().physical_bytes(),
            refs.len() * arena.spill().page_bytes()
        );
        let mut order: Vec<usize> = (0..refs.len()).collect();
        rng.shuffle(&mut order);
        for &i in &order {
            prop_assert!(hs.promote_block(refs[i]).unwrap().is_some());
        }
        for (r, want) in refs.iter().zip(&snap) {
            let got_k: Vec<u32> = hs.block_keys(*r).iter().map(|x| x.to_bits()).collect();
            let got_v: Vec<u32> = hs.block_vals(*r).iter().map(|x| x.to_bits()).collect();
            prop_assert!(got_k == want.0, "keys changed bits under a configured lossy codec");
            prop_assert!(got_v == want.1, "vals changed bits under a configured lossy codec");
        }
        Ok(())
    });
}

/// Spill-codec tentpole, part 2: lossy codecs hold a configured
/// attention-mass recall floor on the shared topic fixture
/// (`tests/integration.rs`): scoring on pages decoded from int8/int4
/// cold storage selects a top-`budget` set that carries nearly all the
/// true softmax mass of the ideal top-`budget` set.
#[test]
fn lossy_codecs_hold_attention_mass_recall_floor() {
    use retroinfer::attention::attention_weights;
    use retroinfer::tensor::dot;

    let d = 16;
    let n = 512;
    let budget = 64;
    for (tag, floor) in [(CodecTag::Int8Angle, 0.95f64), (CodecTag::Int4Angle, 0.75f64)] {
        let mut rng = Rng::new(42);
        let dirs: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(d)).collect();
        let mut keys = Vec::with_capacity(n * d);
        for i in 0..n {
            let t = &dirs[i % 8]; // topics interleave token-by-token
            for j in 0..d {
                keys.push(2.0 * t[j] + 0.3 * rng.normal_f32());
            }
        }
        let vals = rng.normal_vec(n * d);
        let arena = BlockArena::shared(d, 512); // tpb = 4
        arena.spill().set_codec(tag);
        let mut hs = HeadStore::new_in(Arc::clone(&arena));
        let pos: Vec<u32> = (0..n as u32).collect();
        let refs = hs.try_alloc_cluster(&keys, &vals, &pos).unwrap();
        let ref_pos: Vec<Vec<u32>> = refs.iter().map(|r| hs.block_pos(*r).to_vec()).collect();
        for r in &refs {
            assert!(hs.demote_block_with(*r, true)); // lossy-eligible
        }
        assert_eq!(arena.spill().compressed_blocks(), refs.len(), "{tag:?} not applied");
        assert!(arena.spill().physical_bytes() < arena.spill().logical_bytes());
        // decoded keys, scattered back into position order
        let mut dec = vec![0.0f32; n * d];
        for (r, ps) in refs.iter().zip(&ref_pos) {
            let (mut k, mut v) = (Vec::new(), Vec::new());
            assert!(!hs.copy_block_kv(*r, &mut k, &mut v), "block must read cold");
            for (t, &p) in ps.iter().enumerate() {
                let p = p as usize;
                dec[p * d..(p + 1) * d].copy_from_slice(&k[t * d..(t + 1) * d]);
            }
        }
        let top = |scores: &[f32]| -> Vec<usize> {
            let mut ix: Vec<usize> = (0..n).collect();
            ix.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
            ix.truncate(budget);
            ix
        };
        let mut worst = 1.0f64;
        for t in 0..8 {
            let q: Vec<f32> = dirs[t].iter().map(|x| 1.5 * x).collect();
            let w = attention_weights(&q, &keys, d);
            let score = |ks: &[f32]| -> Vec<f32> {
                (0..n).map(|i| dot(&q, &ks[i * d..(i + 1) * d])).collect()
            };
            let ideal: f64 = top(&score(&keys)).iter().map(|&p| w[p] as f64).sum();
            let got: f64 = top(&score(&dec)).iter().map(|&p| w[p] as f64).sum();
            assert!(ideal > 0.0);
            worst = worst.min(got / ideal);
        }
        assert!(worst >= floor, "{tag:?}: worst recall {worst:.4} < floor {floor}");
    }
}

/// Spill-codec tentpole, part 3: accuracy-bounded placement. The
/// steady zone can never be stored lossy, at two independent layers:
/// structurally, no cluster ever holds a sink token or a token inside
/// the trailing local window (steady-zone KV lives outside the block
/// store and is never spilled at all); and at the eligibility rule,
/// clusters are cleared for lossy storage only when they avoid both
/// zones — including the cluster sitting flush against the window
/// boundary. Demoting through the policy path then applies the codec
/// exactly to the cleared clusters. (The rule's refusal branches are
/// unreachable from public flows and unit-tested in `index::tests`.)
#[test]
fn steady_zone_is_never_stored_lossy_and_interior_clusters_compress() {
    let d = 16;
    // sink 4 + one 248-token segment + 16 pending local tokens = 268:
    // the last cluster ends at position 251, flush against the window
    // (251 + 16 == 267 == n_seen - 1) — the tightest legal placement.
    let n = 268;
    let mut rng = Rng::new(42);
    let dirs: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(d)).collect();
    let mut k = Vec::with_capacity(n * d);
    for i in 0..n {
        let t = &dirs[i % 8];
        for j in 0..d {
            k.push(2.0 * t[j] + 0.3 * rng.normal_f32());
        }
    }
    let v = rng.normal_vec(n * d);
    let zone = small_zone();
    let mut idx = WaveIndex::build(zone.clone(), d, 1024, &k, &v, 7);
    idx.arena().spill().set_codec(CodecTag::Int8Angle);
    idx.set_lossy_cos_floor(0.0); // permissive: only the zone rules gate
    let m = idx.meta().m();
    assert!(m > 2, "fixture must produce several clusters");
    // eligibility is decided while the member keys are still hot
    let eligible: Vec<bool> = (0..m).map(|c| idx.cluster_lossy_ok(c as u32)).collect();
    let mut tail_max = 0usize;
    for c in 0..m {
        let pos = idx.meta().cluster_tokens(c);
        assert!(
            pos.iter().all(|&p| (p as usize) >= zone.steady_sink),
            "sink token leaked into cluster {c}"
        );
        let max = *pos.iter().max().unwrap() as usize;
        assert!(
            max + zone.steady_local < idx.n_seen(),
            "cluster {c} reaches into the trailing local window"
        );
        tail_max = tail_max.max(max);
    }
    // the clustered span really ends flush against the local window,
    // and the boundary cluster still clears (strict `<` in the rule)
    assert_eq!(tail_max + zone.steady_local, idx.n_seen() - 1);
    let total_hot: usize = (0..m).map(|c| idx.cluster_hot_blocks(c as u32)).sum();
    let (freed, _) = idx.demote_until(&ColdestFirst, total_hot);
    assert_eq!(freed, total_hot, "everything demotable must spill");
    let mut lossy_seen = false;
    for c in 0..m {
        let tags: Vec<CodecTag> = idx
            .cluster_blocks(c as u32)
            .iter()
            .filter_map(|r| idx.arena().spill().page_tag(r.block))
            .collect();
        assert!(!tags.is_empty(), "cluster {c} left no cold pages");
        if eligible[c] {
            assert!(
                tags.iter().all(|t| *t == CodecTag::Int8Angle),
                "cleared cluster {c} missed the codec: {tags:?}"
            );
            lossy_seen = true;
        } else {
            assert!(
                tags.iter().all(|t| *t == CodecTag::Exact),
                "uncleared cluster {c} stored lossy: {tags:?}"
            );
        }
    }
    assert!(lossy_seen, "no interior cluster exercised the lossy path");
    // the steady zone never even reached the spill tier: sink + local
    // tokens are still served from the index, not from cold pages
    assert!(idx.steady_tokens() >= zone.steady_sink + zone.steady_local);
}

/// The pressure driver reports the achieved compression: with the int8
/// codec on an overcommitted tiered run, peak physical cold bytes stay
/// at or below half the peak logical bytes, and the hot-cap / drain
/// invariants are unchanged from the exact-codec run.
#[test]
fn spilled_pressure_run_compresses_cold_bytes_with_int8() {
    use retroinfer::config::SpillCodec;
    let cfg = PressureConfig {
        capacity_blocks: 256,
        tenant_quota_blocks: None,
        spill: true,
        spill_codec: SpillCodec::Int8,
        ..PressureConfig::default()
    };
    let trace = multi_tenant_poisson(&[4.0, 2.0, 1.0], 4, 112, 8, 11);
    let rep = run_memory_pressure(&cfg, &trace);
    assert!(rep.drained, "tiered run deadlocked: {rep:?}");
    assert_eq!(rep.capacity_violations, 0, "hot tier exceeded its cap: {rep:?}");
    assert_eq!(rep.completed, trace.len(), "requests lost under spill: {rep:?}");
    assert!(rep.demotions > 0 && rep.peak_cold_blocks > 0, "no cold traffic: {rep:?}");
    assert!(rep.peak_compressed_blocks > 0, "int8 codec never applied: {rep:?}");
    assert!(
        rep.peak_cold_physical_bytes * 2 <= rep.peak_cold_logical_bytes,
        "int8 must at least halve cold bytes: physical {} vs logical {}",
        rep.peak_cold_physical_bytes,
        rep.peak_cold_logical_bytes
    );
    assert_eq!(rep.final_cold_blocks, 0, "finished sessions must drop cold blocks: {rep:?}");
}

/// Pipelined-decode tentpole, part 1: the stage-decoupled executor
/// (select → async I/O-lane page reads → completion-order gather)
/// writes `WaveInputs` bit-identical to the sequential path — under
/// forced full demotion (every cluster cold), mixed hot/cold heads,
/// scrambled I/O completion order (per-page jittered fault shim), and
/// every spill codec tag. Both the parallel and the serial pipelined
/// executors are compared against the sequential loop.
#[test]
fn prop_pipelined_assembly_bit_identical_to_sequential() {
    check("pipelined-assembly-identical", 2, |rng| {
        for tag in
            [CodecTag::Exact, CodecTag::Int8Angle, CodecTag::Int4Angle, CodecTag::LowRankK]
        {
            let d = 16;
            let (kvh, group) = (3usize, 2usize);
            let b = 1 + rng.below(3);
            let n = 256 + rng.below(128);
            let arena = BlockArena::shared(d, 512);
            arena.spill().set_codec(tag);
            // jitter keyed on the page id scrambles which task's reads
            // land first — drain order must never leak into the output
            arena.spill().set_read_fault(10, 200);
            let pool = Arc::new(ThreadPool::with_io_threads(4, 2));
            let bcfg = BufferConfig { cpu_threads: 4, ..BufferConfig::default() };
            let full = rng.below(2) == 0; // every cluster cold vs mixed
            let mut heads = Vec::new();
            for h in 0..kvh {
                let keys = rng.normal_vec(n * d);
                let vals = rng.normal_vec(n * d);
                let mut idx =
                    WaveIndex::build_in(&arena, small_zone(), &keys, &vals, h as u64);
                idx.set_lossy_cos_floor(0.0); // codec gated by zone rules only
                let cap = WaveBuffer::capacity_for(&bcfg, n, idx.store().tokens_per_block());
                let buf = WaveBuffer::new(
                    bcfg.clone(),
                    d,
                    idx.store().tokens_per_block(),
                    cap,
                    Arc::clone(&pool),
                );
                buf.register_index(&idx);
                let total_hot: usize =
                    (0..idx.meta().m()).map(|c| idx.cluster_hot_blocks(c as u32)).sum();
                let goal = if full { total_hot } else { total_hot / 2 };
                let (_, demoted) = idx.demote_until(&ColdestFirst, goal);
                for c in &demoted {
                    buf.note_demoted(idx.cluster_blocks(*c));
                }
                heads.push((idx, buf));
            }
            let tasks: Vec<HeadTask> = (0..b * kvh)
                .map(|t| {
                    let (idx, buf) = &heads[t % kvh];
                    HeadTask { index: idx, buffer: buf }
                })
                .collect();
            let shape = AssembleShape { ne: 192, m_cap: 32, d, group };
            let qg_all = rng.normal_vec(b * kvh * group * d);

            let seq = BatchAssembler::new(Arc::clone(&pool), false);
            let mut pipe = BatchAssembler::new(Arc::clone(&pool), true);
            pipe.set_pipelined(true);
            let mut spipe = BatchAssembler::new(Arc::clone(&pool), false);
            spipe.set_pipelined(true);
            prop_assert!(pipe.pipelined() && spipe.pipelined());
            let mut wi_seq = WaveInputs::zeros(b, kvh, shape.ne, shape.m_cap, d);
            let mut wi_pipe = WaveInputs::zeros(b, kvh, shape.ne, shape.m_cap, d);
            let mut wi_sp = WaveInputs::zeros(b, kvh, shape.ne, shape.m_cap, d);
            // dirty the outputs: assembly must fully overwrite its slice
            wi_seq.kx.fill(3.0);
            wi_pipe.kmask.fill(-1.0);
            wi_sp.cent.fill(9.0);
            for round in 0..2 {
                let ps = pipe.assemble_into(&tasks, &qg_all, shape, &mut wi_pipe);
                spipe.assemble_into(&tasks, &qg_all, shape, &mut wi_sp);
                seq.assemble_into(&tasks, &qg_all, shape, &mut wi_seq);
                if full && round == 0 {
                    prop_assert!(ps.cold_blocks > 0, "{:?}: no cold traffic", tag);
                    prop_assert!(
                        ps.cold_staged_blocks > 0,
                        "{:?}: pipelined gather never hit the staging area",
                        tag
                    );
                }
                prop_assert!(wi_seq.kx == wi_pipe.kx, "{:?} kx diverged (round {})", tag, round);
                prop_assert!(wi_seq.vx == wi_pipe.vx, "{:?} vx diverged (round {})", tag, round);
                prop_assert!(
                    wi_seq.kmask == wi_pipe.kmask,
                    "{:?} kmask diverged (round {})",
                    tag,
                    round
                );
                prop_assert!(
                    wi_seq.cent == wi_pipe.cent,
                    "{:?} cent diverged (round {})",
                    tag,
                    round
                );
                prop_assert!(
                    wi_seq.vsum == wi_pipe.vsum,
                    "{:?} vsum diverged (round {})",
                    tag,
                    round
                );
                prop_assert!(
                    wi_seq.csize == wi_pipe.csize,
                    "{:?} csize diverged (round {})",
                    tag,
                    round
                );
                prop_assert!(
                    wi_seq.emask == wi_pipe.emask,
                    "{:?} emask diverged (round {})",
                    tag,
                    round
                );
                prop_assert!(
                    wi_seq.kx == wi_sp.kx
                        && wi_seq.vx == wi_sp.vx
                        && wi_seq.kmask == wi_sp.kmask
                        && wi_seq.cent == wi_sp.cent
                        && wi_seq.vsum == wi_sp.vsum
                        && wi_seq.csize == wi_sp.csize
                        && wi_seq.emask == wi_sp.emask,
                    "{:?} serial pipelined diverged (round {})",
                    tag,
                    round
                );
            }
            arena.spill().set_read_fault(0, 0);
            for (_, buf) in &heads {
                buf.flush();
                prop_assert!(buf.check_consistency(), "buffer inconsistent after pipeline");
            }
        }
        Ok(())
    });
}

/// Pipelined-decode tentpole, part 2 (staging-footprint regression):
/// a long run of steps, each staging a fresh window of pages, keeps the
/// staging area O(depth) — double-buffered epoch retention drops stale
/// pages (counted), and the explicit depth knob tightens the bound to
/// exactly `depth`. The footprint must never scale with step count.
#[test]
fn staging_footprint_is_bounded_by_depth_not_steps() {
    let d = 8;
    let arena = BlockArena::shared(d, 256); // tpb = 4
    let mut rng = Rng::new(5);
    let mut hs = HeadStore::new_in(Arc::clone(&arena));
    let n = 64 * 4; // 64 full blocks
    let keys = rng.normal_vec(n * d);
    let vals = rng.normal_vec(n * d);
    let pos: Vec<u32> = (0..n as u32).collect();
    let refs = hs.try_alloc_cluster(&keys, &vals, &pos).unwrap();
    for r in &refs {
        assert!(hs.demote_block(*r));
    }
    let ids: Vec<u64> = refs.iter().map(|r| r.block).collect();
    let depth = 4usize;
    let mut peak = 0usize;
    for step in 0..ids.len() {
        arena.begin_staging_epoch();
        for j in 0..depth {
            assert!(arena.prefetch(ids[(step + j) % ids.len()]));
        }
        peak = peak.max(arena.staged_blocks());
    }
    assert!(peak <= 2 * depth, "staging footprint {peak} grew past 2x depth {depth}");
    assert!(peak < ids.len(), "staging footprint scaled with steps, not depth");
    assert!(arena.staged_stale_dropped() > 0, "stale staged pages were never dropped");
    // the depth knob (LiveEngine::set_pipeline_depth) tightens the
    // bound from 2x (double-buffer) to exactly `depth`
    arena.set_staging_cap(Some(depth));
    for step in 0..ids.len() {
        arena.begin_staging_epoch();
        for j in 0..depth {
            arena.prefetch(ids[(step * 3 + j) % ids.len()]);
        }
        assert!(
            arena.staged_blocks() <= depth,
            "depth cap ignored: {} staged",
            arena.staged_blocks()
        );
    }
}

/// Pipelined-decode tentpole, part 3 (lane-starvation regression): with
/// the fault-injection shim stalling every staged page read 30ms, a
/// compute fan-out issued behind ~360ms of queued spill I/O still
/// completes immediately on the compute workers — the dedicated I/O
/// lane must still be grinding when compute finishes.
#[test]
fn slow_spill_io_never_starves_the_compute_lane() {
    let d = 8;
    let arena = BlockArena::shared(d, 256); // tpb = 4
    let mut rng = Rng::new(6);
    let mut hs = HeadStore::new_in(Arc::clone(&arena));
    let n = 12 * 4;
    let keys = rng.normal_vec(n * d);
    let vals = rng.normal_vec(n * d);
    let pos: Vec<u32> = (0..n as u32).collect();
    let refs = hs.try_alloc_cluster(&keys, &vals, &pos).unwrap();
    for r in &refs {
        assert!(hs.demote_block(*r));
    }
    arena.spill().set_read_fault(30_000, 0); // 30ms per staged read
    let pool = ThreadPool::with_io_threads(2, 1);
    for r in &refs {
        let a = Arc::clone(&arena);
        let id = r.block;
        pool.submit_io(move || {
            a.prefetch(id);
        });
    }
    let hits = AtomicUsize::new(0);
    pool.scope_for_each(64, &|_| {
        hits.fetch_add(1, Ordering::Relaxed);
    });
    assert_eq!(hits.load(Ordering::Relaxed), 64);
    assert!(
        pool.io_pending() > 0,
        "compute fan-out outlasted ~360ms of injected I/O stall — lanes are not isolated"
    );
    arena.spill().set_read_fault(0, 0);
    pool.wait_idle();
    assert_eq!(pool.io_pending(), 0);
    assert_eq!(arena.staged_blocks(), refs.len());
}

/// Pipelined-decode tentpole, part 4 (measured overlap): the spilled
/// pressure harness stages each decode step's upcoming cold reads on
/// the I/O lane and reports how many gathers were served from the
/// staging area — `spill_overlap_pct` must clear a floor while the
/// hot-resident cap still holds at every step (the CI `spill-overlap`
/// job asserts exactly this).
#[test]
fn spilled_pressure_run_overlaps_cold_reads() {
    let cfg = PressureConfig {
        capacity_blocks: 256,
        tenant_quota_blocks: None,
        spill: true,
        ..PressureConfig::default()
    };
    let trace = multi_tenant_poisson(&[4.0, 2.0, 1.0], 4, 112, 8, 11);
    let rep = run_memory_pressure(&cfg, &trace);
    assert!(rep.drained, "tiered run deadlocked: {rep:?}");
    assert_eq!(rep.capacity_violations, 0, "hot tier exceeded its cap: {rep:?}");
    assert_eq!(rep.completed, trace.len(), "requests lost under spill: {rep:?}");
    assert!(rep.cold_reads > 0, "no cold gather traffic to overlap: {rep:?}");
    assert!(rep.cold_reads_staged > 0, "no gather was served staged: {rep:?}");
    assert!(
        rep.spill_overlap_pct() > 50.0,
        "intra-step overlap {:.1}% below floor: {rep:?}",
        rep.spill_overlap_pct()
    );
    assert_eq!(
        rep.staged_read_steps, rep.cold_read_steps,
        "some decode step read cold pages with zero staged hits: {rep:?}"
    );
    assert_eq!(rep.final_cold_blocks, 0, "finished sessions must drop cold blocks: {rep:?}");
}

/// Nightly-scale sweep (CI `spill-pressure` job runs it via
/// `--include-ignored`): more tenants, longer backlogs, more seeds.
#[test]
#[ignore = "nightly-scale tiered-arena overcommit sweep; run with --include-ignored"]
fn prop_spilled_pressure_nightly_sweep() {
    check("spill-pressure-nightly", 8, |rng| {
        let seed = rng.next_u64();
        let rates = [8.0, 4.0, 2.0, 1.0];
        let input = 80 + rng.below(41);
        let output = 4 + rng.below(12);
        let cfg = PressureConfig {
            capacity_blocks: 192 + 96 * rng.below(4),
            tenant_quota_blocks: None,
            max_batch: 1 + rng.below(8),
            spill: true,
            ..PressureConfig::default()
        };
        let trace = multi_tenant_poisson(&rates, 6, input, output, seed);
        let rep = run_memory_pressure(&cfg, &trace);
        prop_assert!(rep.drained, "deadlock: {:?}", rep);
        prop_assert_eq!(rep.capacity_violations, 0);
        prop_assert_eq!(rep.prefill_failures, 0);
        prop_assert_eq!(rep.append_failures, 0);
        prop_assert_eq!(rep.deferrals, 0);
        prop_assert_eq!(rep.completed, trace.len());
        prop_assert_eq!(rep.final_cold_blocks, 0);
        prop_assert!(
            rep.peak_live_blocks <= cfg.capacity_blocks,
            "hot peak {} > cap {}",
            rep.peak_live_blocks,
            cfg.capacity_blocks
        );
        Ok(())
    });
}
