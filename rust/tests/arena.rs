//! Arena lifecycle + parallel fan-out integration tests (DESIGN.md §2-3
//! invariants): finishing sessions returns every KV block to the shared
//! [`BlockArena`]'s free-list (no leaks across session churn), recycled
//! storage serves later sessions, and the thread-pool head fan-out
//! assembles execution buffers bit-identical to the sequential path.

use retroinfer::buffer::WaveBuffer;
use retroinfer::config::{BufferConfig, ZoneConfig};
use retroinfer::engine::{AssembleShape, BatchAssembler, HeadTask};
use retroinfer::index::WaveIndex;
use retroinfer::kvcache::{AllocError, BlockArena, DEFAULT_TENANT};
use retroinfer::prop_assert;
use retroinfer::prop_assert_eq;
use retroinfer::runtime::tinylm::WaveInputs;
use retroinfer::util::prop::check;
use retroinfer::util::rng::Rng;
use retroinfer::util::threadpool::ThreadPool;
use std::sync::Arc;

fn small_zone() -> ZoneConfig {
    ZoneConfig {
        steady_sink: 4,
        steady_local: 16,
        tokens_per_cluster: 8,
        build_segment: 256,
        update_segment: 32,
        kmeans_iters: 4,
        ..ZoneConfig::default()
    }
}

/// A "session" at the substrate level: layers × heads wave indexes
/// checked out of one shared arena, like `LiveEngine::prefill` builds.
fn build_session(
    arena: &Arc<BlockArena>,
    layers: usize,
    heads: usize,
    n: usize,
    rng: &mut Rng,
) -> Vec<WaveIndex> {
    let d = arena.d();
    (0..layers * heads)
        .map(|slot| {
            let keys = rng.normal_vec(n * d);
            let vals = rng.normal_vec(n * d);
            WaveIndex::build_in(arena, small_zone(), &keys, &vals, slot as u64)
        })
        .collect()
}

/// Invariant: after any number of sessions are created, decoded
/// (appended into), and finished, the arena's live-block count returns
/// to its pre-session baseline — nothing leaks, and the free-list is
/// actually recycled by later sessions.
#[test]
fn prop_arena_reclaims_every_session_block() {
    check("arena-reclaim", 8, |rng| {
        let d = 16;
        let arena = BlockArena::shared(d, 512);
        let baseline = arena.live_blocks();
        prop_assert_eq!(baseline, 0);
        let sessions = 1 + rng.below(4);
        let mut max_live = 0usize;
        for s in 0..sessions {
            let n = 128 + rng.below(256);
            let mut idxs = build_session(&arena, 2, 2, n, rng);
            prop_assert!(arena.live_blocks() > baseline, "session holds no blocks");
            // decode phase: appends trigger incremental re-clustering,
            // which checks out more blocks mid-session
            let appends = rng.below(100);
            for _ in 0..appends {
                for idx in idxs.iter_mut() {
                    let k = rng.normal_vec(d);
                    let v = rng.normal_vec(d);
                    idx.append(&k, &v);
                }
            }
            let live_before_drop = arena.live_blocks();
            max_live = max_live.max(live_before_drop);
            let expect_reclaimed = arena.reclaimed_total() + live_before_drop as u64;
            drop(idxs);
            prop_assert!(
                arena.live_blocks() == baseline,
                "session {} leaked {} blocks",
                s,
                arena.live_blocks() - baseline
            );
            prop_assert_eq!(arena.reclaimed_total(), expect_reclaimed);
            prop_assert!(arena.free_blocks() >= live_before_drop, "free-list lost blocks");
        }
        // sessions run one at a time, so recycled storage must bound the
        // arena's footprint by the LARGEST session — not the sum of all
        // sessions (the grow-only leak this refactor removes)
        prop_assert_eq!(arena.free_blocks(), max_live);
        prop_assert_eq!(arena.resident_bytes(), max_live * arena.block_bytes());
        Ok(())
    });
}

/// Invariant: the batched thread-pool fan-out writes exactly the same
/// WaveInputs bytes as the sequential loop — parallel assembly can
/// never change decoded tokens (the kernel consumes only these arrays).
#[test]
fn prop_parallel_assembly_bit_identical_to_sequential() {
    check("assembly-parallel-identical", 6, |rng| {
        let d = 16;
        let (kvh, group) = (4, 2);
        let b = 1 + rng.below(4);
        let n = 256 + rng.below(256);
        let arena = BlockArena::shared(d, 512);
        let pool = Arc::new(ThreadPool::new(4));
        let bcfg = BufferConfig { cpu_threads: 4, ..BufferConfig::default() };
        let mut heads = Vec::new();
        for h in 0..kvh {
            let keys = rng.normal_vec(n * d);
            let vals = rng.normal_vec(n * d);
            let idx = WaveIndex::build_in(&arena, small_zone(), &keys, &vals, h as u64);
            let cap = WaveBuffer::capacity_for(&bcfg, n, idx.store().tokens_per_block());
            let buf = WaveBuffer::new(
                bcfg.clone(),
                d,
                idx.store().tokens_per_block(),
                cap,
                Arc::clone(&pool),
            );
            buf.register_index(&idx);
            heads.push((idx, buf));
        }
        let tasks: Vec<HeadTask> = (0..b * kvh)
            .map(|t| {
                let (idx, buf) = &heads[t % kvh];
                HeadTask { index: idx, buffer: buf }
            })
            .collect();
        let shape = AssembleShape { ne: 128, m_cap: 32, d, group };
        let qg_all = rng.normal_vec(b * kvh * group * d);

        let seq = BatchAssembler::new(Arc::clone(&pool), false);
        let par = BatchAssembler::new(Arc::clone(&pool), true);
        // dirty both outputs first: assembly must fully overwrite its
        // slice, so reuse across layers/steps cannot leak stale state
        let mut wi_seq = WaveInputs::zeros(b, kvh, shape.ne, shape.m_cap, d);
        let mut wi_par = WaveInputs::zeros(b, kvh, shape.ne, shape.m_cap, d);
        wi_seq.kmask.fill(7.0);
        wi_par.cent.fill(-3.0);
        for round in 0..3 {
            seq.assemble_into(&tasks, &qg_all, shape, &mut wi_seq);
            par.assemble_into(&tasks, &qg_all, shape, &mut wi_par);
            prop_assert!(wi_seq.kx == wi_par.kx, "kx diverged (round {})", round);
            prop_assert!(wi_seq.vx == wi_par.vx, "vx diverged (round {})", round);
            prop_assert!(wi_seq.kmask == wi_par.kmask, "kmask diverged (round {})", round);
            prop_assert!(wi_seq.cent == wi_par.cent, "cent diverged (round {})", round);
            prop_assert!(wi_seq.vsum == wi_par.vsum, "vsum diverged (round {})", round);
            prop_assert!(wi_seq.csize == wi_par.csize, "csize diverged (round {})", round);
            prop_assert!(wi_seq.emask == wi_par.emask, "emask diverged (round {})", round);
        }
        for (_, buf) in &heads {
            buf.flush();
            prop_assert!(buf.check_consistency(), "buffer inconsistent after fan-out");
        }
        Ok(())
    });
}

/// Satellite (ROADMAP "fan-out past assembly"): fanning the decode-step
/// KV appends across sessions produces per-session index state
/// bit-identical to the sequential loop. Appends mutate only their own
/// session, so parallelism can only change the interleaving of arena
/// block-id issuance — never data, clustering, or the steady zone.
#[test]
fn prop_parallel_session_appends_bit_identical_to_sequential() {
    check("append-fanout-identical", 4, |rng| {
        let d = 16;
        let n_sessions = 2 + rng.below(3);
        let n0 = 128 + rng.below(128);
        let steps = 40 + rng.below(60);
        let base_seed = rng.next_u64();
        let mk = |seed: u64| -> Vec<Vec<WaveIndex>> {
            let arena = BlockArena::shared(d, 512);
            let mut r = Rng::new(seed);
            (0..n_sessions).map(|_| build_session(&arena, 2, 2, n0, &mut r)).collect()
        };
        let mut seq = mk(base_seed);
        let mut par = mk(base_seed);
        // deterministic token stream per (session, slot, step)
        let tok = |si: usize, slot: usize, step: usize| -> (Vec<f32>, Vec<f32>) {
            let mut r = Rng::new(
                base_seed ^ ((si as u64) << 40) ^ ((slot as u64) << 20) ^ step as u64,
            );
            (r.normal_vec(d), r.normal_vec(d))
        };
        let pool = ThreadPool::new(4);
        for step in 0..steps {
            for (si, sess) in seq.iter_mut().enumerate() {
                for (slot, idx) in sess.iter_mut().enumerate() {
                    let (k, v) = tok(si, slot, step);
                    idx.try_append(&k, &v).unwrap();
                }
            }
            pool.scope_for_each_mut(&mut par, &|si, sess| {
                for (slot, idx) in sess.iter_mut().enumerate() {
                    let (k, v) = tok(si, slot, step);
                    idx.try_append(&k, &v).unwrap();
                }
            });
        }
        for (sa, sb) in seq.iter().zip(&par) {
            for (ia, ib) in sa.iter().zip(sb) {
                prop_assert_eq!(ia.n_seen(), ib.n_seen());
                prop_assert_eq!(ia.n_updates(), ib.n_updates());
                prop_assert_eq!(ia.meta().m(), ib.meta().m());
                prop_assert!(
                    ia.meta().centroids_flat() == ib.meta().centroids_flat(),
                    "centroids diverged"
                );
                prop_assert!(ia.meta().vsum_flat() == ib.meta().vsum_flat(), "vsum diverged");
                prop_assert!(ia.meta().counts() == ib.meta().counts(), "counts diverged");
                let (ka, va) = ia.steady_kv();
                let (kb, vb) = ib.steady_kv();
                prop_assert!(ka == kb && va == vb, "steady zone diverged");
                for c in 0..ia.meta().m() {
                    prop_assert!(
                        ia.meta().cluster_tokens(c) == ib.meta().cluster_tokens(c),
                        "cluster {} tokens diverged",
                        c
                    );
                    let ra = ia.cluster_blocks(c as u32);
                    let rb = ib.cluster_blocks(c as u32);
                    prop_assert_eq!(ra.len(), rb.len());
                    // block IDS may differ (allocation order is racy);
                    // block BYTES must not
                    for (x, y) in ra.iter().zip(rb) {
                        prop_assert!(
                            ia.store().block_keys(*x) == ib.store().block_keys(*y),
                            "cluster {} block keys diverged",
                            c
                        );
                        prop_assert!(
                            ia.store().block_vals(*x) == ib.store().block_vals(*y),
                            "cluster {} block vals diverged",
                            c
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

/// Invariant (capacity satellite): under ANY interleaving of alloc /
/// reclaim against a capped arena, the arena's counters track a simple
/// reference model exactly — no double-free is representable (block
/// storage moves), reclaimed global ids are never reissued, ids stay
/// strictly monotone, `live = allocated_total - reclaimed_total`, and
/// the resident footprint (live + free) never exceeds the cap.
#[test]
fn prop_interleaved_alloc_reclaim_accounting_consistent() {
    check("arena-accounting", 10, |rng| {
        let d = 8;
        let arena = BlockArena::shared(d, 256); // tpb = 4, block_bytes = 256
        let cap = 8 + rng.below(48);
        arena.set_capacity_blocks(Some(cap));
        let mut held: Vec<(u64, retroinfer::kvcache::arena::BlockData)> = Vec::new();
        let mut seen = std::collections::HashSet::new();
        let (mut model_live, mut model_free) = (0usize, 0usize);
        for step in 0..400 {
            if rng.below(2) == 0 {
                match arena.try_alloc_for(DEFAULT_TENANT) {
                    Ok((id, data)) => {
                        prop_assert!(seen.insert(id), "block id {} reissued (step {})", id, step);
                        // ids issue sequentially (single-threaded), so a
                        // reclaimed id can never resurrect
                        prop_assert_eq!(id, arena.allocated_total() - 1);
                        prop_assert!(model_live < cap, "alloc succeeded at capacity");
                        // the arena recycles free storage before growing
                        if model_free > 0 {
                            model_free -= 1;
                        }
                        model_live += 1;
                        held.push((id, data));
                    }
                    Err(e) => {
                        prop_assert_eq!(model_live, cap);
                        prop_assert!(
                            matches!(e, AllocError::ArenaFull { .. }),
                            "unexpected error {:?}",
                            e
                        );
                    }
                }
            } else if !held.is_empty() {
                let k = 1 + rng.below(held.len());
                let at = held.len() - k;
                let drained: Vec<_> = held.drain(at..).map(|(_, b)| b).collect();
                arena.reclaim_for(DEFAULT_TENANT, drained);
                model_live -= k;
                model_free += k;
            }
            prop_assert_eq!(arena.live_blocks(), model_live);
            prop_assert_eq!(arena.free_blocks(), model_free);
            prop_assert_eq!(
                arena.allocated_total() - arena.reclaimed_total(),
                model_live as u64
            );
            prop_assert!(
                arena.live_blocks() + arena.free_blocks() <= cap,
                "resident {} blocks exceeds cap {}",
                arena.live_blocks() + arena.free_blocks(),
                cap
            );
            prop_assert_eq!(arena.resident_bytes(), (model_live + model_free) * 256);
        }
        let rest: Vec<_> = held.drain(..).map(|(_, b)| b).collect();
        arena.reclaim_for(DEFAULT_TENANT, rest);
        prop_assert_eq!(arena.live_blocks(), 0);
        prop_assert_eq!(arena.allocated_total(), arena.reclaimed_total());
        Ok(())
    });
}

/// Quota accounting follows interleaved multi-tenant traffic: each
/// tenant's occupancy is tracked independently, refusals are typed, and
/// reclamation re-opens exactly the reclaimed tenant's budget.
#[test]
fn prop_tenant_quota_accounting_consistent() {
    check("arena-quota", 8, |rng| {
        let arena = BlockArena::shared(8, 256);
        let quotas = [3 + rng.below(6), 3 + rng.below(6)];
        arena.set_tenant_quota(0, Some(quotas[0]));
        arena.set_tenant_quota(1, Some(quotas[1]));
        let mut held: Vec<Vec<retroinfer::kvcache::arena::BlockData>> =
            vec![Vec::new(), Vec::new()];
        for _ in 0..200 {
            let t = rng.below(2);
            if rng.below(2) == 0 {
                match arena.try_alloc_for(t as u32) {
                    Ok((_, b)) => {
                        held[t].push(b);
                        prop_assert!(held[t].len() <= quotas[t], "quota overshoot");
                    }
                    Err(e) => {
                        prop_assert_eq!(held[t].len(), quotas[t]);
                        prop_assert_eq!(
                            e,
                            AllocError::QuotaExceeded {
                                tenant: t as u32,
                                quota_blocks: quotas[t]
                            }
                        );
                    }
                }
            } else if !held[t].is_empty() {
                let b = held[t].pop().unwrap();
                arena.reclaim_for(t as u32, [b]);
            }
            prop_assert_eq!(arena.tenant_live_blocks(t as u32), held[t].len());
        }
        for (t, blocks) in held.into_iter().enumerate() {
            arena.reclaim_for(t as u32, blocks);
        }
        prop_assert_eq!(arena.live_blocks(), 0);
        prop_assert_eq!(arena.tenant_live_blocks(0), 0);
        prop_assert_eq!(arena.tenant_live_blocks(1), 0);
        Ok(())
    });
}

/// The engine-facing shape of reclamation: many concurrent "sessions"
/// live at once, finish out of order, and the arena ends at baseline
/// with its id space still monotone (no reuse, so stale cache keys from
/// finished sessions can never alias a new session's blocks).
#[test]
fn interleaved_session_churn_keeps_arena_balanced() {
    let d = 16;
    let arena = BlockArena::shared(d, 512);
    let mut rng = Rng::new(77);
    let mut live: Vec<Vec<WaveIndex>> = Vec::new();
    for round in 0..6 {
        live.push(build_session(&arena, 2, 2, 192 + 32 * round, &mut rng));
        if round % 2 == 1 {
            // finish the OLDEST session while newer ones stay live
            live.remove(0);
        }
        let held: usize = live
            .iter()
            .flat_map(|s| s.iter())
            .map(|i| i.store().n_blocks())
            .sum();
        assert_eq!(arena.live_blocks(), held, "arena count != sum of live handles");
    }
    live.clear();
    assert_eq!(arena.live_blocks(), 0);
    assert_eq!(arena.allocated_total(), arena.reclaimed_total());
}
