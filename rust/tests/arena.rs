//! Arena lifecycle + parallel fan-out integration tests (DESIGN.md §2-3
//! invariants): finishing sessions returns every KV block to the shared
//! [`BlockArena`]'s free-list (no leaks across session churn), recycled
//! storage serves later sessions, and the thread-pool head fan-out
//! assembles execution buffers bit-identical to the sequential path.

use retroinfer::buffer::WaveBuffer;
use retroinfer::config::{BufferConfig, ZoneConfig};
use retroinfer::engine::{AssembleShape, BatchAssembler, HeadTask};
use retroinfer::index::WaveIndex;
use retroinfer::kvcache::BlockArena;
use retroinfer::prop_assert;
use retroinfer::prop_assert_eq;
use retroinfer::runtime::tinylm::WaveInputs;
use retroinfer::util::prop::check;
use retroinfer::util::rng::Rng;
use retroinfer::util::threadpool::ThreadPool;
use std::sync::Arc;

fn small_zone() -> ZoneConfig {
    ZoneConfig {
        steady_sink: 4,
        steady_local: 16,
        tokens_per_cluster: 8,
        build_segment: 256,
        update_segment: 32,
        kmeans_iters: 4,
        ..ZoneConfig::default()
    }
}

/// A "session" at the substrate level: layers × heads wave indexes
/// checked out of one shared arena, like `LiveEngine::prefill` builds.
fn build_session(
    arena: &Arc<BlockArena>,
    layers: usize,
    heads: usize,
    n: usize,
    rng: &mut Rng,
) -> Vec<WaveIndex> {
    let d = arena.d();
    (0..layers * heads)
        .map(|slot| {
            let keys = rng.normal_vec(n * d);
            let vals = rng.normal_vec(n * d);
            WaveIndex::build_in(arena, small_zone(), &keys, &vals, slot as u64)
        })
        .collect()
}

/// Invariant: after any number of sessions are created, decoded
/// (appended into), and finished, the arena's live-block count returns
/// to its pre-session baseline — nothing leaks, and the free-list is
/// actually recycled by later sessions.
#[test]
fn prop_arena_reclaims_every_session_block() {
    check("arena-reclaim", 8, |rng| {
        let d = 16;
        let arena = BlockArena::shared(d, 512);
        let baseline = arena.live_blocks();
        prop_assert_eq!(baseline, 0);
        let sessions = 1 + rng.below(4);
        let mut max_live = 0usize;
        for s in 0..sessions {
            let n = 128 + rng.below(256);
            let mut idxs = build_session(&arena, 2, 2, n, rng);
            prop_assert!(arena.live_blocks() > baseline, "session holds no blocks");
            // decode phase: appends trigger incremental re-clustering,
            // which checks out more blocks mid-session
            let appends = rng.below(100);
            for _ in 0..appends {
                for idx in idxs.iter_mut() {
                    let k = rng.normal_vec(d);
                    let v = rng.normal_vec(d);
                    idx.append(&k, &v);
                }
            }
            let live_before_drop = arena.live_blocks();
            max_live = max_live.max(live_before_drop);
            let expect_reclaimed = arena.reclaimed_total() + live_before_drop as u64;
            drop(idxs);
            prop_assert!(
                arena.live_blocks() == baseline,
                "session {} leaked {} blocks",
                s,
                arena.live_blocks() - baseline
            );
            prop_assert_eq!(arena.reclaimed_total(), expect_reclaimed);
            prop_assert!(arena.free_blocks() >= live_before_drop, "free-list lost blocks");
        }
        // sessions run one at a time, so recycled storage must bound the
        // arena's footprint by the LARGEST session — not the sum of all
        // sessions (the grow-only leak this refactor removes)
        prop_assert_eq!(arena.free_blocks(), max_live);
        prop_assert_eq!(arena.resident_bytes(), max_live * arena.block_bytes());
        Ok(())
    });
}

/// Invariant: the batched thread-pool fan-out writes exactly the same
/// WaveInputs bytes as the sequential loop — parallel assembly can
/// never change decoded tokens (the kernel consumes only these arrays).
#[test]
fn prop_parallel_assembly_bit_identical_to_sequential() {
    check("assembly-parallel-identical", 6, |rng| {
        let d = 16;
        let (kvh, group) = (4, 2);
        let b = 1 + rng.below(4);
        let n = 256 + rng.below(256);
        let arena = BlockArena::shared(d, 512);
        let pool = Arc::new(ThreadPool::new(4));
        let bcfg = BufferConfig { cpu_threads: 4, ..BufferConfig::default() };
        let mut heads = Vec::new();
        for h in 0..kvh {
            let keys = rng.normal_vec(n * d);
            let vals = rng.normal_vec(n * d);
            let idx = WaveIndex::build_in(&arena, small_zone(), &keys, &vals, h as u64);
            let cap = WaveBuffer::capacity_for(&bcfg, n, idx.store().tokens_per_block());
            let buf = WaveBuffer::new(
                bcfg.clone(),
                d,
                idx.store().tokens_per_block(),
                cap,
                Arc::clone(&pool),
            );
            buf.register_index(&idx);
            heads.push((idx, buf));
        }
        let tasks: Vec<HeadTask> = (0..b * kvh)
            .map(|t| {
                let (idx, buf) = &heads[t % kvh];
                HeadTask { index: idx, buffer: buf }
            })
            .collect();
        let shape = AssembleShape { ne: 128, m_cap: 32, d, group };
        let qg_all = rng.normal_vec(b * kvh * group * d);

        let seq = BatchAssembler::new(Arc::clone(&pool), false);
        let par = BatchAssembler::new(Arc::clone(&pool), true);
        // dirty both outputs first: assembly must fully overwrite its
        // slice, so reuse across layers/steps cannot leak stale state
        let mut wi_seq = WaveInputs::zeros(b, kvh, shape.ne, shape.m_cap, d);
        let mut wi_par = WaveInputs::zeros(b, kvh, shape.ne, shape.m_cap, d);
        wi_seq.kmask.fill(7.0);
        wi_par.cent.fill(-3.0);
        for round in 0..3 {
            seq.assemble_into(&tasks, &qg_all, shape, &mut wi_seq);
            par.assemble_into(&tasks, &qg_all, shape, &mut wi_par);
            prop_assert!(wi_seq.kx == wi_par.kx, "kx diverged (round {})", round);
            prop_assert!(wi_seq.vx == wi_par.vx, "vx diverged (round {})", round);
            prop_assert!(wi_seq.kmask == wi_par.kmask, "kmask diverged (round {})", round);
            prop_assert!(wi_seq.cent == wi_par.cent, "cent diverged (round {})", round);
            prop_assert!(wi_seq.vsum == wi_par.vsum, "vsum diverged (round {})", round);
            prop_assert!(wi_seq.csize == wi_par.csize, "csize diverged (round {})", round);
            prop_assert!(wi_seq.emask == wi_par.emask, "emask diverged (round {})", round);
        }
        for (_, buf) in &heads {
            buf.flush();
            prop_assert!(buf.check_consistency(), "buffer inconsistent after fan-out");
        }
        Ok(())
    });
}

/// The engine-facing shape of reclamation: many concurrent "sessions"
/// live at once, finish out of order, and the arena ends at baseline
/// with its id space still monotone (no reuse, so stale cache keys from
/// finished sessions can never alias a new session's blocks).
#[test]
fn interleaved_session_churn_keeps_arena_balanced() {
    let d = 16;
    let arena = BlockArena::shared(d, 512);
    let mut rng = Rng::new(77);
    let mut live: Vec<Vec<WaveIndex>> = Vec::new();
    for round in 0..6 {
        live.push(build_session(&arena, 2, 2, 192 + 32 * round, &mut rng));
        if round % 2 == 1 {
            // finish the OLDEST session while newer ones stay live
            live.remove(0);
        }
        let held: usize = live
            .iter()
            .flat_map(|s| s.iter())
            .map(|i| i.store().n_blocks())
            .sum();
        assert_eq!(arena.live_blocks(), held, "arena count != sum of live handles");
    }
    live.clear();
    assert_eq!(arena.live_blocks(), 0);
    assert_eq!(arena.allocated_total(), arena.reclaimed_total());
}
