//! Kernel-layer integration tests (DESIGN.md "Kernel layer & dispatch"):
//! scalar-vs-SIMD agreement on adversarial inputs (empty slices, odd
//! lengths, denormals), deterministic bit-identity under a pinned
//! backend, non-finite score handling in the fused merge, and the
//! zero-allocation guarantee for the steady-state decode hot path —
//! counted with a thread-local counting allocator, so pool workers and
//! the test harness don't pollute the measurement.

use retroinfer::attention::{tripartite_attention_in, MergeScratch, TripartiteInputs};
use retroinfer::buffer::{ExecBuffer, WaveBuffer};
use retroinfer::config::{BufferConfig, ZoneConfig};
use retroinfer::engine::assemble::{assemble_head, HeadSlices};
use retroinfer::engine::{AssembleShape, BatchAssembler, HeadTask};
use retroinfer::index::{BuildScratch, DecodeScratch, SelectScratch, WaveIndex};
use retroinfer::kernels::Backend;
use retroinfer::kvcache::{BlockArena, DEFAULT_TENANT};
use retroinfer::prop_assert;
use retroinfer::runtime::tinylm::WaveInputs;
use retroinfer::util::prop::check;
use retroinfer::util::rng::Rng;
use retroinfer::util::threadpool::ThreadPool;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::Arc;

// --- counting allocator ------------------------------------------------

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Pass-through to the system allocator that counts alloc/realloc calls
/// on the current thread only (thread-local, so the pool's workers and
/// the libtest harness don't perturb hot-path measurements).
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        // try_with: TLS may already be torn down during thread exit
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs_on_this_thread() -> u64 {
    ALLOCS.with(|c| c.get())
}

// --- scalar vs SIMD agreement ------------------------------------------

/// Relative closeness with an absolute floor; non-finite values must
/// agree in kind (the backends share overflow behavior, not bits).
fn close(a: f32, b: f32, tol: f32) -> bool {
    if a.is_finite() && b.is_finite() {
        (a - b).abs() <= tol * a.abs().max(b.abs()).max(1.0)
    } else {
        a == b || (a.is_nan() && b.is_nan())
    }
}

#[test]
fn scalar_and_simd_agree_on_adversarial_inputs() {
    let Some(simd) = Backend::simd() else {
        eprintln!("no SIMD backend on this machine; scalar-only, skipping");
        return;
    };
    // Lengths straddle every blocking boundary in the AVX2 kernels:
    // empty, sub-lane, one lane, 2-lane unroll, and ragged tails.
    let lens = [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 33, 64, 100, 127];
    check("kernels-scalar-vs-simd", 16, |rng| {
        for &n in &lens {
            let mut a = rng.normal_vec(n);
            let mut b = rng.normal_vec(n);
            // sprinkle denormals and exact zeros into the operands
            for i in 0..n {
                match rng.below(8) {
                    0 => a[i] = 1.0e-41,
                    1 => b[i] = -1.0e-41,
                    2 => a[i] = 0.0,
                    _ => {}
                }
            }
            let s = Backend::Scalar.dot(&a, &b);
            let v = simd.dot(&a, &b);
            prop_assert!(close(s, v, 1e-4), "dot len {n}: scalar {s} vs simd {v}");

            let mut ys = rng.normal_vec(n);
            let mut yv = ys.clone();
            Backend::Scalar.axpy(0.37, &a, &mut ys);
            simd.axpy(0.37, &a, &mut yv);
            for i in 0..n {
                prop_assert!(
                    close(ys[i], yv[i], 1e-4),
                    "axpy len {n} lane {i}: scalar {} vs simd {}",
                    ys[i],
                    yv[i]
                );
            }
        }
        // Row widths cover the 4-row-block + remainder paths of
        // matvec_nt/group_max_scores, with a row count that leaves a
        // non-multiple-of-4 remainder.
        for &d in &[3usize, 8, 16, 33, 64] {
            let m = 17;
            let rows = rng.normal_vec(m * d);
            let q = rng.normal_vec(d);
            let mut os = vec![0.0f32; m];
            let mut ov = vec![0.0f32; m];
            Backend::Scalar.matvec_nt(&q, &rows, d, &mut os);
            simd.matvec_nt(&q, &rows, d, &mut ov);
            for c in 0..m {
                prop_assert!(
                    close(os[c], ov[c], 1e-4),
                    "matvec d={d} row {c}: scalar {} vs simd {}",
                    os[c],
                    ov[c]
                );
            }
            let g = 3;
            let qs = rng.normal_vec(g * d);
            Backend::Scalar.group_max_scores(&qs, g, &rows, d, &mut os);
            simd.group_max_scores(&qs, g, &rows, d, &mut ov);
            for c in 0..m {
                prop_assert!(
                    close(os[c], ov[c], 1e-4),
                    "group_max d={d} row {c}: scalar {} vs simd {}",
                    os[c],
                    ov[c]
                );
            }
        }
        Ok(())
    });
}

#[test]
fn tripartite_merge_agrees_and_is_deterministic_per_backend() {
    let d = 16;
    let mut rng = Rng::new(33);
    let n = 96;
    let m = 12;
    let keys = rng.normal_vec(n * d);
    let vals = rng.normal_vec(n * d);
    let cents = rng.normal_vec(m * d);
    let vsum = rng.normal_vec(m * d);
    let sizes: Vec<f32> = (0..m).map(|i| 4.0 + i as f32).collect();
    let exact: Vec<usize> = (0..n).step_by(2).collect();
    let estimated: Vec<usize> = (0..m).collect();
    let q = rng.normal_vec(d);
    let inp = TripartiteInputs {
        d,
        keys: &keys,
        vals: &vals,
        exact: &exact,
        centroids: &cents,
        vsum: &vsum,
        sizes: &sizes,
        estimated: &estimated,
    };
    let mut backends = vec![Backend::Scalar];
    backends.extend(Backend::simd());
    let mut outs: Vec<Vec<f32>> = Vec::new();
    for bk in &backends {
        let mut scratch = MergeScratch::default();
        let mut o1 = vec![0.0f32; d];
        let mut o2 = vec![0.0f32; d];
        tripartite_attention_in(*bk, &q, &inp, &mut scratch, &mut o1);
        tripartite_attention_in(*bk, &q, &inp, &mut scratch, &mut o2);
        // each backend is bit-identical to itself (fixed reduction order)
        assert_eq!(o1, o2, "backend {} not deterministic", bk.name());
        outs.push(o1);
    }
    if outs.len() == 2 {
        for i in 0..d {
            assert!(
                close(outs[0][i], outs[1][i], 1e-3),
                "merge lane {i}: scalar {} vs simd {}",
                outs[0][i],
                outs[1][i]
            );
        }
    }
}

#[test]
fn overflowed_scores_merge_to_zeros_on_every_backend() {
    // A +inf score (q·k overflow) poisons the softmax; the merge emits
    // zeros deterministically instead of NaN — on both backends.
    let d = 8;
    let q = vec![1.0e30f32; d];
    let keys = vec![1.0e30f32; 2 * d]; // dot = d * 1e60 -> +inf
    let vals = vec![1.0f32; 2 * d];
    let exact = [0usize, 1];
    let inp = TripartiteInputs {
        d,
        keys: &keys,
        vals: &vals,
        exact: &exact,
        centroids: &[],
        vsum: &[],
        sizes: &[],
        estimated: &[],
    };
    let mut backends = vec![Backend::Scalar];
    backends.extend(Backend::simd());
    for bk in backends {
        let mut scratch = MergeScratch::default();
        let mut out = vec![7.0f32; d];
        tripartite_attention_in(bk, &q, &inp, &mut scratch, &mut out);
        assert_eq!(out, vec![0.0f32; d], "backend {}", bk.name());
        // degenerate empty selection also merges to zeros, no panic
        let empty = TripartiteInputs {
            d,
            keys: &[],
            vals: &[],
            exact: &[],
            centroids: &[],
            vsum: &[],
            sizes: &[],
            estimated: &[],
        };
        let mut out = vec![7.0f32; d];
        tripartite_attention_in(bk, &q, &empty, &mut scratch, &mut out);
        assert_eq!(out, vec![0.0f32; d], "backend {} (empty)", bk.name());
    }
}

// --- zero-allocation decode hot path -----------------------------------

fn small_zone() -> ZoneConfig {
    ZoneConfig {
        steady_sink: 4,
        steady_local: 16,
        tokens_per_cluster: 8,
        build_segment: 256,
        update_segment: 32,
        kmeans_iters: 4,
        ..ZoneConfig::default()
    }
}

#[test]
fn select_and_attend_are_alloc_free_after_warmup() {
    let d = 16;
    let n = 1024;
    let mut rng = Rng::new(7);
    let keys = rng.normal_vec(n * d);
    let vals = rng.normal_vec(n * d);
    let idx = WaveIndex::build(small_zone(), d, 2048, &keys, &vals, 1);
    let m = idx.meta().m();
    let (r, e) = ((m / 8).max(2), (m / 4).max(2));
    let q = rng.normal_vec(d);
    let mut sc = SelectScratch::default();
    let mut ds = DecodeScratch::default();
    let mut out = vec![0.0f32; d];
    retroinfer::kernels::active(); // pin the backend (one-time log)
    for _ in 0..3 {
        let sel = idx.select_into(&q, r, e, &mut sc);
        idx.attend_with(&q, sel, &mut ds, &mut out);
    }
    let before = allocs_on_this_thread();
    for _ in 0..20 {
        let sel = idx.select_into(&q, r, e, &mut sc);
        idx.attend_with(&q, sel, &mut ds, &mut out);
    }
    let grew = allocs_on_this_thread() - before;
    assert_eq!(grew, 0, "select+attend allocated {grew} times after warmup");
    assert!(out.iter().all(|x| x.is_finite()));
}

#[test]
fn warm_prefill_chunks_append_alloc_free() {
    // Chunked prefill's hot path: a feed chunk that stays inside the
    // current build segment is a pure append into buffers pre-sized at
    // `begin_build_in_for`. After the first segment-drain cycle (which
    // sets the pending buffer's high-water capacity), such chunks must
    // not allocate — only segment-completing chunks may (they cluster
    // and check out arena blocks).
    let d = 16;
    let n = 1024;
    let cs = 32;
    let mut rng = Rng::new(9);
    let keys = rng.normal_vec(n * d);
    let vals = rng.normal_vec(n * d);
    let arena = BlockArena::shared(d, 4096);
    let cfg = small_zone();
    // Segment-completion points for this geometry, mirroring the build
    // cursor: segments start after the sink and cover [sink, n - local)
    // in `build_segment` steps (a < tokens_per_cluster remainder stays
    // pending).
    let (sink, seg) = (cfg.steady_sink, cfg.build_segment);
    let mid_end = n - cfg.steady_local;
    let mut boundaries = Vec::new();
    let mut s = sink;
    while s < mid_end {
        let take = (mid_end - s).min(seg);
        if take < cfg.tokens_per_cluster {
            break;
        }
        boundaries.push(s + take);
        s += take;
    }
    assert!(boundaries.len() >= 3, "geometry must span several segments");

    let mut idx = WaveIndex::begin_build_in_for(&arena, DEFAULT_TENANT, cfg, n, 3);
    let mut scratch = BuildScratch::default();
    retroinfer::kernels::active(); // pin the backend (one-time log)
    let mut fed = 0usize;
    let mut warm_chunks = 0u32;
    while fed < n {
        let end = (fed + cs).min(n);
        let crosses = end == n || boundaries.iter().any(|&b| fed < b && end >= b);
        // warm once the first drain cycle is behind us: the pending
        // buffer has hit its steady high-water mark by then
        let warmed = fed >= sink + seg + cs;
        let before = allocs_on_this_thread();
        idx.try_feed_build_with(&keys[fed * d..end * d], &vals[fed * d..end * d], &mut scratch)
            .unwrap();
        let grew = allocs_on_this_thread() - before;
        if warmed && !crosses {
            assert_eq!(grew, 0, "warm chunk [{fed}, {end}) allocated {grew} times");
            warm_chunks += 1;
        }
        fed = end;
    }
    assert!(warm_chunks >= 10, "only {warm_chunks} warm chunks measured");
    assert!(!idx.build_in_progress(), "build did not close");
}

#[test]
fn assemble_head_is_alloc_free_after_warmup() {
    let d = 16;
    let n = 2048;
    let mut rng = Rng::new(8);
    let keys = rng.normal_vec(n * d);
    let vals = rng.normal_vec(n * d);
    let idx = WaveIndex::build(small_zone(), d, 2048, &keys, &vals, 2);
    // Synchronous cache updates (the async path hands the update scratch
    // to a pool worker — reuse is then best-effort) and a cache big
    // enough that the steady working set is all hits.
    let bcfg = BufferConfig {
        cache_frac: 1.0,
        cpu_threads: 1,
        async_update: false,
        ..BufferConfig::default()
    };
    let tpb = idx.store().tokens_per_block();
    let cap = WaveBuffer::capacity_for(&bcfg, n, tpb).max(64);
    let pool = Arc::new(ThreadPool::new(1));
    let wb = WaveBuffer::new(bcfg, d, tpb, cap, pool);
    wb.register_index(&idx);

    let shape = AssembleShape { ne: 512, m_cap: 64, d, group: 2 };
    let qg = rng.normal_vec(2 * d);
    let mut sc = SelectScratch::default();
    let mut eb = ExecBuffer::new(d);
    let mut kx = vec![0.0f32; shape.ne * d];
    let mut vx = vec![0.0f32; shape.ne * d];
    let mut kmask = vec![0.0f32; shape.ne];
    let mut cent = vec![0.0f32; shape.m_cap * d];
    let mut vsum = vec![0.0f32; shape.m_cap * d];
    let mut csize = vec![0.0f32; shape.m_cap];
    let mut emask = vec![0.0f32; shape.m_cap];
    let task = HeadTask { index: &idx, buffer: &wb };
    retroinfer::kernels::active();

    let mut run = |counted: bool| {
        let mut out = HeadSlices {
            kx: &mut kx,
            vx: &mut vx,
            kmask: &mut kmask,
            cent: &mut cent,
            vsum: &mut vsum,
            csize: &mut csize,
            emask: &mut emask,
        };
        let st = assemble_head(task, &qg, shape, &mut sc, &mut eb, &mut out);
        if counted {
            assert_eq!(st.miss_blocks, 0, "cache not warm: misses re-stage blocks");
        }
        st
    };
    for _ in 0..3 {
        run(false);
    }
    let before = allocs_on_this_thread();
    for _ in 0..20 {
        run(true);
    }
    let grew = allocs_on_this_thread() - before;
    assert_eq!(grew, 0, "assemble_head allocated {grew} times after warmup");
}

/// GQA-batched centroid scoring: with identical queries in the group,
/// the batched `gemm_nt` + `group_max_reduce` path (g > 1) must
/// reproduce the per-head `group_max_scores` path (g = 1) selection
/// exactly — a group-max over duplicate score rows is the row itself,
/// bitwise, so any divergence is a scoring-path bug. Distinct queries
/// additionally check call-to-call determinism of the batched path.
#[test]
fn gqa_batched_group_selection_matches_per_head_selection() {
    let d = 16;
    let n = 1024;
    let mut rng = Rng::new(11);
    let keys = rng.normal_vec(n * d);
    let vals = rng.normal_vec(n * d);
    let idx = WaveIndex::build(small_zone(), d, 2048, &keys, &vals, 5);
    let m = idx.meta().m();
    assert!(m > 4, "fixture must produce several clusters");
    let (r, e) = ((m / 3).max(2), (m / 4).max(1));
    retroinfer::kernels::active(); // pin the backend (one-time log)
    let q = rng.normal_vec(d);
    let mut qg = q.clone();
    qg.extend_from_slice(&q);
    let mut sc_g = SelectScratch::default();
    let mut sc_1 = SelectScratch::default();
    let (g_ret, g_est) = {
        let sel = idx.select_group_into(&qg, 2, r, e, &mut sc_g);
        (sel.retrieval.clone(), sel.estimation.clone())
    };
    let sel_1 = idx.select_group_into(&q, 1, r, e, &mut sc_1);
    assert_eq!(g_ret, sel_1.retrieval, "batched retrieval diverged from per-head");
    assert_eq!(g_est, sel_1.estimation, "batched estimation diverged from per-head");
    // distinct group queries: deterministic across calls and scratches
    let qs = rng.normal_vec(2 * d);
    let first = {
        let sel = idx.select_group_into(&qs, 2, r, e, &mut sc_g);
        (sel.retrieval.clone(), sel.estimation.clone())
    };
    let again = idx.select_group_into(&qs, 2, r, e, &mut sc_1);
    assert_eq!(first.0, again.retrieval, "batched selection not deterministic");
    assert_eq!(first.1, again.estimation, "batched estimation not deterministic");
}

/// The warm all-hot pipelined decode path allocates nothing: in serial
/// pipelined mode (`set_pipelined(true)`, `parallel = false`) a step
/// whose selections find no cold pages gathers inline — no I/O jobs
/// boxed, no scope jobs queued, and zero allocations after warmup.
#[test]
fn warm_pipelined_assemble_into_is_alloc_free_after_warmup() {
    let d = 16;
    let n = 2048;
    let mut rng = Rng::new(12);
    let keys = rng.normal_vec(n * d);
    let vals = rng.normal_vec(n * d);
    let idx = WaveIndex::build(small_zone(), d, 2048, &keys, &vals, 4);
    let bcfg = BufferConfig {
        cache_frac: 1.0,
        cpu_threads: 1,
        async_update: false,
        ..BufferConfig::default()
    };
    let tpb = idx.store().tokens_per_block();
    let cap = WaveBuffer::capacity_for(&bcfg, n, tpb).max(64);
    let pool = Arc::new(ThreadPool::with_io_threads(1, 1));
    let wb = WaveBuffer::new(bcfg, d, tpb, cap, Arc::clone(&pool));
    wb.register_index(&idx);
    let shape = AssembleShape { ne: 512, m_cap: 64, d, group: 2 };
    let qg_all = rng.normal_vec(2 * d);
    let tasks = [HeadTask { index: &idx, buffer: &wb }];
    let mut asm = BatchAssembler::new(Arc::clone(&pool), false);
    asm.set_pipelined(true);
    let mut wi = WaveInputs::zeros(1, 1, shape.ne, shape.m_cap, d);
    retroinfer::kernels::active(); // pin the backend (one-time log)
    for _ in 0..3 {
        asm.assemble_into(&tasks, &qg_all, shape, &mut wi);
    }
    let before = allocs_on_this_thread();
    for _ in 0..20 {
        let st = asm.assemble_into(&tasks, &qg_all, shape, &mut wi);
        assert_eq!(st.miss_blocks, 0, "cache not warm: misses re-stage blocks");
        assert_eq!(st.cold_blocks, 0, "all-hot fixture unexpectedly read cold");
    }
    let grew = allocs_on_this_thread() - before;
    assert_eq!(grew, 0, "warm pipelined assemble_into allocated {grew} times");
}
