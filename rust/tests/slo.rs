//! SLO-aware online-serving integration tests (DESIGN.md §2 "Online
//! serving & preemption"). The acceptance scenario: a 256k-token prompt
//! arrives while interactive sessions are mid-decode. Under chunked
//! prefill every inter-token gap stays inside the per-step budget
//! `decode_step_s + max_chunks_per_step × chunk_tokens ×
//! prefill_token_s`; the monolithic prefill-eager baseline stalls the
//! whole batch for the full prompt cost. Runs in deterministic virtual
//! time through [`run_online_serving`] (no model artifacts), so it is
//! tier-1. The `#[ignore]`d sweep at the bottom is the CI `slo-serving`
//! job's payload: attainment/throughput across chunk sizes.

use retroinfer::workload::{diurnal_poisson, run_online_serving, OnlineConfig, RequestSpec};

fn spec(arrive_s: f64, input: usize, output: usize, tenant: u32) -> RequestSpec {
    RequestSpec {
        arrive_s,
        input_tokens: input,
        output_tokens: output,
        tenant,
        prefix_hash: None,
    }
}

/// Two interactive decode streams under a 50 ms TPOT target, plus a
/// 256k-token best-effort prompt landing at t = 50 ms.
fn midstream_256k(chunked: bool, chunk_tokens: usize) -> OnlineConfig {
    OnlineConfig {
        trace: vec![
            spec(0.0, 64, 400, 0),
            spec(0.0, 64, 400, 0),
            spec(0.05, 262_144, 4, 1),
        ],
        chunked,
        chunk_tokens,
        prefill_token_s: 1e-5,
        decode_step_s: 5e-3,
        max_chunks_per_step: 2,
        max_batch: 4,
        slo_ttft_s: 0.05,
        slo_tpot_s: 0.05,
        slo_max_input: 1024,
        ..OnlineConfig::default()
    }
}

#[test]
fn chunked_prefill_bounds_gaps_on_256k_midstream_arrival() {
    let cfg = midstream_256k(true, 512);
    let budget = cfg.step_budget_s();
    let chunked = run_online_serving(&cfg);
    let mono = run_online_serving(&midstream_256k(false, 512));

    assert_eq!(chunked.completed, 3);
    assert_eq!(mono.completed, 3);
    assert_eq!(chunked.rejected + mono.rejected, 0);

    // chunked: the decode sessions' max inter-token gap respects the
    // per-step budget even while the 256k prefill streams through
    assert!(
        chunked.max_gap_s <= budget + 1e-9,
        "chunked max gap {} exceeds step budget {}",
        chunked.max_gap_s,
        budget
    );
    assert_eq!(chunked.tpot_attainment, 1.0, "every chunked gap inside the TPOT target");
    assert_eq!(chunked.ttft_attainment, 1.0);

    // monolithic: the whole 262144-token prefill (~2.6 s at 10 µs/token)
    // lands in one step and blows the decode sessions' gap
    assert!(
        mono.max_gap_s > 2.0,
        "monolithic gap {} should stall for the full 256k prefill",
        mono.max_gap_s
    );
    assert!(mono.tpot_attainment < 1.0);

    // identical token streams — scheduling mode changes latency, never
    // content — completing each session's full output budget
    assert_eq!(chunked.tokens, mono.tokens);
    for (id, want) in [(0u64, 400usize), (1, 400), (2, 4)] {
        assert_eq!(chunked.tokens[&id].len(), want, "session {id} token count");
    }
}

#[test]
fn online_token_streams_invariant_across_chunk_sizes_and_runs() {
    let base = run_online_serving(&midstream_256k(true, 512));
    for &cs in &[256usize, 1024] {
        let r = run_online_serving(&midstream_256k(true, cs));
        assert_eq!(r.tokens, base.tokens, "chunk size {cs} changed a token stream");
        assert_eq!(r.completed, base.completed);
        let budget = midstream_256k(true, cs).step_budget_s();
        assert!(
            r.max_gap_s <= budget + 1e-9,
            "chunk size {cs}: gap {} over budget {budget}",
            r.max_gap_s
        );
    }
    // exact rerun determinism, full-report equality
    let again = run_online_serving(&midstream_256k(true, 512));
    assert_eq!(again, base);
}

#[test]
fn admission_rejects_provably_unmeetable_interactive_ttft() {
    // The scheduler estimates prefill at full chunks (51.2 ms each
    // here): the 1024-token prompt needs 2 — past its 80 ms TTFT
    // deadline before it starts, so the EDF admission pass rejects it
    // instead of wasting prefill work. The 64-token prompt (1 chunk,
    // 51.2 ms ≤ 80 ms) admits and completes.
    let cfg = OnlineConfig {
        trace: vec![spec(0.0, 64, 8, 0), spec(0.0, 1024, 8, 0)],
        chunked: true,
        chunk_tokens: 512,
        prefill_token_s: 1e-4,
        slo_ttft_s: 0.08,
        slo_tpot_s: f64::INFINITY,
        slo_max_input: 1024,
        ..OnlineConfig::default()
    };
    let r = run_online_serving(&cfg);
    assert_eq!(r.rejected, 1, "unmeetable TTFT must reject");
    assert_eq!(r.completed, 1);
    assert!(r.ttft_attainment < 1.0, "a rejected SLO session counts as a TTFT miss");
}

#[test]
fn diurnal_load_serves_every_request_under_slo_accounting() {
    let trace = diurnal_poisson(&[25.0, 25.0], 3.0, 4.0, 4.0, 64, 8, 17);
    let n = trace.len();
    assert!(n > 40, "trace too small to exercise bursts: {n}");
    let cfg = OnlineConfig {
        trace,
        slo_ttft_s: 0.5,
        slo_tpot_s: 0.1,
        ..OnlineConfig::default()
    };
    let r = run_online_serving(&cfg);
    assert_eq!(r.completed + r.rejected, n, "no request lost");
    assert!(r.ttft_attainment >= 0.0 && r.ttft_attainment <= 1.0);
    assert!(r.tpot_attainment >= 0.0 && r.tpot_attainment <= 1.0);
    assert!(r.max_gap_all_s >= r.max_gap_s, "SLO-class gaps are a subset of all gaps");
    assert!(r.throughput_tok_s > 0.0);
}

/// CI `slo-serving` payload: SLO attainment vs throughput across chunk
/// sizes plus the monolithic baseline, on a diurnal trace with long
/// best-effort prompts mixed in. `#`-prefixed lines land in the job's
/// timing artifacts (EXPERIMENTS.md "Online serving").
#[test]
#[ignore]
fn slo_sweep_chunk_sizes() {
    let mut trace = diurnal_poisson(&[40.0, 40.0], 3.0, 6.0, 6.0, 64, 32, 23);
    // a 256k and two 64k best-effort prompts land mid-trace
    trace.push(spec(1.0, 262_144, 4, 2));
    trace.push(spec(2.5, 65_536, 4, 2));
    trace.push(spec(4.0, 65_536, 4, 2));
    trace.sort_by(|a, b| a.arrive_s.partial_cmp(&b.arrive_s).unwrap());
    let n = trace.len();
    println!("# slo-sweep requests={n} slo_ttft=0.5s slo_tpot=0.05s");

    let run = |chunked: bool, chunk_tokens: usize| {
        let cfg = OnlineConfig {
            trace: trace.clone(),
            chunked,
            chunk_tokens,
            prefill_token_s: 1e-5,
            decode_step_s: 5e-3,
            max_chunks_per_step: 2,
            max_batch: 8,
            slo_ttft_s: 0.5,
            slo_tpot_s: 0.05,
            slo_max_input: 1024,
            ..OnlineConfig::default()
        };
        (cfg.step_budget_s(), run_online_serving(&cfg))
    };

    let (_, mono) = run(false, 512);
    println!(
        "# mono       ttft_p50={:.4}s tpot_p99={:.4}s max_gap={:.4}s attain_ttft={:.3} \
         attain_tpot={:.3} tput={:.0}tok/s",
        mono.ttft_p50_s,
        mono.tpot_p99_s,
        mono.max_gap_s,
        mono.ttft_attainment,
        mono.tpot_attainment,
        mono.throughput_tok_s
    );
    let mut chunk512_gap = f64::INFINITY;
    for &cs in &[256usize, 512, 1024] {
        let (budget, r) = run(true, cs);
        println!(
            "# chunk={cs:<5} ttft_p50={:.4}s tpot_p99={:.4}s max_gap={:.4}s attain_ttft={:.3} \
             attain_tpot={:.3} tput={:.0}tok/s budget={budget:.4}s",
            r.ttft_p50_s,
            r.tpot_p99_s,
            r.max_gap_s,
            r.ttft_attainment,
            r.tpot_attainment,
            r.throughput_tok_s
        );
        assert_eq!(r.completed + r.rejected, n);
        assert!(
            r.max_gap_s <= budget + 1e-9,
            "chunk {cs}: SLO-class gap {} over per-step budget {budget}",
            r.max_gap_s
        );
        if cs == 512 {
            chunk512_gap = r.max_gap_s;
        }
    }
    assert_eq!(mono.completed + mono.rejected, n);
    assert!(
        mono.max_gap_s > chunk512_gap,
        "monolithic baseline must show the head-of-line stall: mono {} vs chunked {}",
        mono.max_gap_s,
        chunk512_gap
    );
}
