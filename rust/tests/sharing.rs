//! Cross-session prefix-sharing invariants (DESIGN.md §2 "Prefix
//! sharing & CoW", §6):
//!
//! - refcounted reclaim never double-frees or leaks — an alloc / seal /
//!   share / pin / release fuzz tracks an oracle and the arena's
//!   counters must agree at every step;
//! - CoW divergence leaves every other owner's bytes bit-identical;
//! - grafted index builds are bit-identical to unshared builds of the
//!   same tokens (same content-derived seed) — sharing changes
//!   placement, never results;
//! - a shared-prefix `workload::pressure` run keeps resident ≤ cap
//!   while N sessions share one prefix whose unshared footprint would
//!   blow past it, and per-tenant quotas still bound private footprint
//!   (the charge-once / transfer-on-exit rule).

use retroinfer::config::ZoneConfig;
use retroinfer::index::{SelectScratch, WaveIndex};
use retroinfer::kvcache::{BlockArena, BlockData, HeadStore, TenantId};
use retroinfer::prop_assert;
use retroinfer::prop_assert_eq;
use retroinfer::util::prop::check;
use retroinfer::util::rng::Rng;
use retroinfer::workload::{
    run_memory_pressure, shared_prefix_poisson, stamp_shared_prefix, PressureConfig,
};
use std::collections::HashMap;
use std::sync::Arc;

/// Oracle record of one shared block in the fuzz.
struct SharedModel {
    id: u64,
    /// Outstanding session holds per tenant (the Arc clones live here).
    holds: Vec<(TenantId, Arc<BlockData>)>,
    pins: usize,
}

/// Alloc/share/drop fuzz against an oracle: the arena's live/free/
/// tenant counters must match a reference model under any interleaving
/// of private allocs, seals, shares, pins, releases and unpins — no
/// double-free (refcount math never goes negative), no leak (everything
/// drains to zero at the end).
#[test]
fn prop_refcounted_reclaim_matches_oracle() {
    check("shared-refcount-oracle", 12, |rng| {
        let d = 8;
        let arena = BlockArena::shared(d, 256); // tpb = 2
        let n_tenants = 1 + rng.below(3) as TenantId;
        // oracle state
        let mut privates: Vec<(TenantId, u64, BlockData)> = Vec::new();
        let mut shared: Vec<SharedModel> = Vec::new();
        for _ in 0..400 {
            match rng.below(6) {
                // private alloc
                0 | 1 => {
                    let t = rng.below(n_tenants as usize) as TenantId;
                    let (id, data) = arena.try_alloc_for(t).unwrap();
                    privates.push((t, id, data));
                }
                // seal a private block into a shared one
                2 => {
                    if privates.is_empty() {
                        continue;
                    }
                    let i = rng.below(privates.len());
                    let (t, id, data) = privates.swap_remove(i);
                    let arc = arena.note_shared_for(t, id, data);
                    shared.push(SharedModel { id, holds: vec![(t, arc)], pins: 0 });
                }
                // take another session hold of a shared block
                3 => {
                    if shared.is_empty() {
                        continue;
                    }
                    let i = rng.below(shared.len());
                    let t = rng.below(n_tenants as usize) as TenantId;
                    let arc = arena.share_block_for(t, shared[i].id).unwrap();
                    shared[i].holds.push((t, arc));
                }
                // pin / unpin (the registry's tenant-less hold)
                4 => {
                    if shared.is_empty() {
                        continue;
                    }
                    let i = rng.below(shared.len());
                    if shared[i].pins > 0 && rng.below(2) == 0 {
                        shared[i].pins -= 1;
                        let freed = arena.unpin_shared(shared[i].id);
                        if freed {
                            prop_assert!(shared[i].holds.is_empty(), "freed with holds");
                            shared.swap_remove(i);
                        }
                    } else {
                        prop_assert!(arena.pin_shared(shared[i].id));
                        shared[i].pins += 1;
                    }
                }
                // release: a private reclaim or one shared hold
                _ => {
                    if !privates.is_empty() && (shared.is_empty() || rng.below(2) == 0) {
                        let i = rng.below(privates.len());
                        let (t, _, data) = privates.swap_remove(i);
                        arena.reclaim_for(t, [data]);
                    } else if !shared.is_empty() {
                        let i = rng.below(shared.len());
                        if shared[i].holds.is_empty() {
                            continue;
                        }
                        let j = rng.below(shared[i].holds.len());
                        let (t, arc) = shared[i].holds.swap_remove(j);
                        drop(arc);
                        let freed = arena.release_shared_for(t, shared[i].id);
                        if freed {
                            prop_assert!(
                                shared[i].holds.is_empty() && shared[i].pins == 0,
                                "freed while holds/pins remain"
                            );
                            shared.swap_remove(i);
                        } else {
                            prop_assert!(
                                !shared[i].holds.is_empty() || shared[i].pins > 0,
                                "not freed at refcount zero"
                            );
                        }
                    }
                }
            }
            // arena counters vs oracle, every step
            let oracle_live = privates.len() + shared.len();
            prop_assert_eq!(arena.live_blocks(), oracle_live);
            prop_assert_eq!(arena.shared_blocks_live(), shared.len());
            let oracle_refs: usize = shared.iter().map(|s| s.holds.len()).sum();
            prop_assert_eq!(arena.shared_session_refs(), oracle_refs);
            for s in &shared {
                prop_assert_eq!(arena.shared_refcount(s.id), s.holds.len() + s.pins);
            }
            // per-tenant: privates owned + exactly one charge per shared
            // block, billed to some tenant that held it (or last did)
            let mut min_by_tenant: HashMap<TenantId, usize> = HashMap::new();
            for (t, _, _) in &privates {
                *min_by_tenant.entry(*t).or_insert(0) += 1;
            }
            let total_tenant: usize =
                (0..n_tenants).map(|t| arena.tenant_live_blocks(t)).sum();
            prop_assert_eq!(total_tenant, oracle_live);
            for t in 0..n_tenants {
                let have = arena.tenant_live_blocks(t);
                let need = min_by_tenant.get(&t).copied().unwrap_or(0);
                prop_assert!(
                    have >= need,
                    "tenant {} charged {} < its {} private blocks",
                    t,
                    have,
                    need
                );
            }
        }
        // drain everything: no leak survives
        for (t, _, data) in privates.drain(..) {
            arena.reclaim_for(t, [data]);
        }
        for mut s in shared.drain(..) {
            for (t, arc) in s.holds.drain(..) {
                drop(arc);
                arena.release_shared_for(t, s.id);
            }
            for _ in 0..s.pins {
                arena.unpin_shared(s.id);
            }
        }
        prop_assert_eq!(arena.live_blocks(), 0);
        prop_assert_eq!(arena.shared_blocks_live(), 0);
        prop_assert_eq!(arena.allocated_total(), arena.reclaimed_total());
        for t in 0..n_tenants {
            prop_assert_eq!(arena.tenant_live_blocks(t), 0);
        }
        Ok(())
    });
}

/// CoW divergence: random writers fork shared blocks and scribble;
/// every other owner's view must stay bit-identical to the original.
#[test]
fn prop_cow_never_mutates_a_sharers_view() {
    check("cow-divergence", 10, |rng| {
        let d = 8;
        let arena = BlockArena::shared(d, 256); // tpb = 2
        let n = 2 + rng.below(6); // tokens in the sealed cluster
        let keys = rng.normal_vec(n * d);
        let vals = rng.normal_vec(n * d);
        let pos: Vec<u32> = (0..n as u32).collect();
        let mut donor = HeadStore::new_in_for(Arc::clone(&arena), 0);
        let refs = donor.try_alloc_cluster(&keys, &vals, &pos).unwrap();
        for r in &refs {
            prop_assert!(donor.seal_block(*r));
        }
        // several sharers attach; a random subset diverge and scribble
        let mut sharers: Vec<(HeadStore, Vec<retroinfer::kvcache::BlockRef>)> = Vec::new();
        for t in 1..=3u32 {
            let mut hs = HeadStore::new_in_for(Arc::clone(&arena), t);
            let atts: Vec<_> = refs
                .iter()
                .map(|r| hs.attach_shared(r.block, r.len).unwrap())
                .collect();
            sharers.push((hs, atts));
        }
        for (hs, atts) in sharers.iter_mut() {
            for i in 0..atts.len() {
                if rng.below(2) == 0 {
                    let forked = hs.unshare_for_write(atts[i]).unwrap();
                    prop_assert!(forked.block != atts[i].block, "CoW reuses an id");
                    hs.block_keys_mut(forked).fill(1e9);
                    hs.block_vals_mut(forked).fill(-1e9);
                    atts[i] = forked;
                }
            }
        }
        // the donor's bytes — and every non-diverged sharer's — are intact
        let mut off = 0usize;
        for r in &refs {
            let span = r.len as usize * d;
            prop_assert_eq!(donor.block_keys(*r), &keys[off..off + span]);
            prop_assert_eq!(donor.block_vals(*r), &vals[off..off + span]);
            off += span;
        }
        for (hs, atts) in &sharers {
            let mut off = 0usize;
            for (r, orig) in atts.iter().zip(&refs) {
                let span = orig.len as usize * d;
                if r.block == orig.block {
                    prop_assert_eq!(hs.block_keys(*r), &keys[off..off + span]);
                } else {
                    prop_assert!(hs.block_keys(*r).iter().all(|&x| x == 1e9));
                }
                off += span;
            }
        }
        drop(sharers);
        drop(donor);
        prop_assert_eq!(arena.live_blocks(), 0);
        Ok(())
    });
}

fn small_zone() -> ZoneConfig {
    ZoneConfig {
        steady_sink: 4,
        steady_local: 16,
        tokens_per_cluster: 8,
        build_segment: 128,
        update_segment: 32,
        kmeans_iters: 4,
        ..ZoneConfig::default()
    }
}

/// Grafted builds are bit-identical to unshared builds of the same
/// tokens: meta (centroids, vsum, sizes), steady zone, and attention
/// output all match exactly — including for a LONGER prompt grafting a
/// shorter prompt's sealed prefix, the cross-session case.
#[test]
fn grafted_build_is_bit_identical_to_unshared() {
    let d = 16;
    let cfg = small_zone();
    let mut rng = Rng::new(77);
    let prefix_n = 4 + 2 * 128; // sink + two full segments
    let keys_p = rng.normal_vec(prefix_n * d);
    let vals_p = rng.normal_vec(prefix_n * d);
    // donor prompt: prefix + its own tail
    let (mut keys_a, mut vals_a) = (keys_p.clone(), vals_p.clone());
    keys_a.extend(rng.normal_vec(64 * d));
    vals_a.extend(rng.normal_vec(64 * d));
    // a longer second prompt sharing the prefix, different tail
    let (mut keys_b, mut vals_b) = (keys_p.clone(), vals_p.clone());
    keys_b.extend(rng.normal_vec(200 * d));
    vals_b.extend(rng.normal_vec(200 * d));

    let arena = BlockArena::shared(d, 512);
    let seed = 0xC0117E47; // "content-derived": equal across sessions
    let mut donor =
        WaveIndex::try_build_in_for(&arena, 0, cfg.clone(), &keys_a, &vals_a, seed).unwrap();
    let covered = prefix_n; // both full segments committed
    assert!(donor.clustered_prefix_tokens() >= covered);
    let sealed = donor.seal_prefix(covered);
    assert!(!sealed.clusters.is_empty());
    for c in &sealed.clusters {
        for b in &c.blocks {
            assert!(arena.pin_shared(b.id));
        }
    }

    // session B: grafted vs unshared build of the same longer prompt
    let grafted = WaveIndex::try_build_grafted_in_for(
        &arena, 1, cfg.clone(), &sealed, covered, &keys_b, &vals_b, seed,
    )
    .unwrap();
    let fresh =
        WaveIndex::try_build_in_for(&arena, 2, cfg.clone(), &keys_b, &vals_b, seed).unwrap();
    assert_eq!(grafted.meta().m(), fresh.meta().m());
    assert_eq!(grafted.meta().centroids_flat(), fresh.meta().centroids_flat());
    assert_eq!(grafted.meta().vsum_flat(), fresh.meta().vsum_flat());
    assert_eq!(grafted.meta().counts(), fresh.meta().counts());
    for c in 0..grafted.meta().m() {
        assert_eq!(grafted.meta().cluster_tokens(c), fresh.meta().cluster_tokens(c));
    }
    assert_eq!(grafted.steady_kv(), fresh.steady_kv());
    assert_eq!(grafted.n_seen(), fresh.n_seen());
    assert!(grafted.n_shared_blocks() > 0, "the prefix must be shared, not copied");
    // same selection, bitwise-equal attention output
    let mut sc = SelectScratch::default();
    for qseed in 0..4u64 {
        let q = Rng::new(1000 + qseed).normal_vec(d);
        let sel_g = grafted.select(&q, &mut sc);
        let sel_f = fresh.select(&q, &mut sc);
        assert_eq!(sel_g, sel_f, "identical meta must select identically");
        let mut out_g = vec![0.0f32; d];
        let mut out_f = vec![0.0f32; d];
        grafted.attend(&q, &sel_g, &mut out_g);
        fresh.attend(&q, &sel_f, &mut out_f);
        assert_eq!(out_g, out_f, "grafted attention must be bit-identical");
    }
    // dedup accounting: the grafted session added no blocks for the prefix
    let shared = arena.shared_blocks_live();
    assert!(shared > 0);
    assert_eq!(arena.shared_session_refs(), 2 * shared, "donor + grafted session");
    drop(grafted);
    drop(fresh);
    drop(donor);
    assert_eq!(arena.shared_blocks_live(), shared, "pins keep the prefix");
    for c in &sealed.clusters {
        for b in &c.blocks {
            arena.unpin_shared(b.id);
        }
    }
    assert_eq!(arena.live_blocks(), 0);
}

/// Appending to a grafted index never touches the shared prefix: new
/// tokens cluster into fresh private blocks, and the donor's view stays
/// bit-identical throughout.
#[test]
fn appends_after_graft_leave_the_shared_prefix_untouched() {
    let d = 16;
    let cfg = small_zone();
    let mut rng = Rng::new(99);
    let n = 4 + 128 + 40;
    let keys = rng.normal_vec(n * d);
    let vals = rng.normal_vec(n * d);
    let arena = BlockArena::shared(d, 512);
    let mut donor = WaveIndex::try_build_in_for(&arena, 0, cfg.clone(), &keys, &vals, 5).unwrap();
    let covered = 4 + 128;
    let sealed = donor.seal_prefix(covered);
    assert!(!sealed.clusters.is_empty());
    // snapshot the donor's view of every sealed block
    let snapshot = |idx: &WaveIndex| -> Vec<(u64, Vec<f32>, Vec<f32>)> {
        let mut out = Vec::new();
        for c in 0..idx.meta().m() {
            for r in idx.cluster_blocks(c as u32) {
                if idx.store().is_shared(*r) {
                    out.push((
                        r.block,
                        idx.store().block_keys(*r).to_vec(),
                        idx.store().block_vals(*r).to_vec(),
                    ));
                }
            }
        }
        out
    };
    let before = snapshot(&donor);
    assert!(!before.is_empty());
    let mut grafted = WaveIndex::try_build_grafted_in_for(
        &arena, 1, cfg.clone(), &sealed, covered, &keys, &vals, 5,
    )
    .unwrap();
    let shared_before = grafted.n_shared_blocks();
    // push enough tokens through the grafted index to trip re-clustering
    for i in 0..(cfg.steady_local + cfg.update_segment + 4) {
        let k = Rng::new(500 + i as u64).normal_vec(d);
        let v = Rng::new(900 + i as u64).normal_vec(d);
        grafted.try_append(&k, &v).unwrap();
    }
    assert!(grafted.n_updates() >= 1, "appends must re-cluster");
    assert_eq!(
        grafted.n_shared_blocks(),
        shared_before,
        "appends must not fork or drop shared prefix blocks"
    );
    assert_eq!(grafted.meta().n_tokens() + grafted.steady_tokens(), grafted.n_seen());
    // the donor's sealed bytes are bit-identical after the sharer's life
    assert_eq!(snapshot(&donor), before, "appends leaked into the shared prefix");
    drop(grafted);
    assert_eq!(snapshot(&donor), before);
}

/// Charge-once tenant accounting: quotas bound a tenant's PRIVATE
/// footprint; attached shared blocks bill the first owner and transfer
/// when it exits.
#[test]
fn quota_bounds_private_footprint_not_shared_attachments() {
    let d = 16; // tpb = 4 at 512-byte blocks
    let arena = BlockArena::shared(d, 512);
    let mut rng = Rng::new(3);
    let keys = rng.normal_vec(12 * d);
    let vals = rng.normal_vec(12 * d);
    let pos: Vec<u32> = (0..12).collect();
    // tenant 1 donates a 3-block prefix
    let mut donor = HeadStore::new_in_for(Arc::clone(&arena), 1);
    let refs = donor.try_alloc_cluster(&keys, &vals, &pos).unwrap();
    assert_eq!(refs.len(), 3);
    for r in &refs {
        assert!(donor.seal_block(*r));
    }
    assert_eq!(arena.tenant_live_blocks(1), 3);
    // tenant 2 (quota 2) attaches all 3 shared blocks for free...
    arena.set_tenant_quota(2, Some(2));
    let mut b = HeadStore::new_in_for(Arc::clone(&arena), 2);
    for r in &refs {
        b.attach_shared(r.block, r.len).unwrap();
    }
    assert_eq!(arena.tenant_live_blocks(2), 0, "sharers are not charged");
    // ...and can still fill its whole private quota
    let (k1, v1, p1) = (rng.normal_vec(4 * d), rng.normal_vec(4 * d), (0..4).collect::<Vec<u32>>());
    b.try_alloc_cluster(&k1, &v1, &p1).unwrap();
    b.try_alloc_cluster(&k1, &v1, &p1).unwrap();
    assert_eq!(arena.tenant_live_blocks(2), 2);
    // the quota still bounds private growth exactly
    assert!(b.try_alloc_cluster(&k1, &v1, &p1).is_err());
    // donor exits: the 3 shared charges transfer to tenant 2 (the only
    // surviving owner) — occupancy may exceed quota, allocation may not
    drop(donor);
    assert_eq!(arena.tenant_live_blocks(1), 0);
    assert_eq!(arena.tenant_live_blocks(2), 5);
    assert!(b.try_alloc_cluster(&k1, &v1, &p1).is_err(), "quota still gates allocs");
    drop(b);
    assert_eq!(arena.live_blocks(), 0);
    assert_eq!(arena.tenant_live_blocks(2), 0);
}

/// Shared-prefix pressure run: N sessions share one prefix whose
/// UNSHARED aggregate footprint exceeds the arena cap — with sharing
/// the run completes with resident ≤ cap at every step, and the peak
/// dedup ratio reflects the concurrent sharers.
#[test]
fn shared_prefix_pressure_keeps_resident_under_cap() {
    let cfg = PressureConfig {
        capacity_blocks: 420,
        shared_prefix_tokens: 96,
        max_batch: 4,
        ..PressureConfig::default()
    };
    // geometry: d=16, block 512 B -> tpb=4; 2 layers × 2 heads.
    // per-session UNSHARED prompt footprint: 4 heads × (120 tokens in
    // 7-token clusters -> ~18×2=... ) ≈ 4 × 35 = 140 blocks; 8 sessions
    // nominal ≈ 1120 blocks ≫ 420 cap. Shared: one 96-token prefix run
    // (~4 × 28 = 112 blocks) + 8 × tail (~4 × 7 = 28) ≈ 336 < cap.
    let mut trace = retroinfer::workload::poisson_arrivals(50.0, 8, 120, 6, 9);
    stamp_shared_prefix(&mut trace, 0xFACE);
    let rep = run_memory_pressure(&cfg, &trace);
    assert!(rep.drained, "shared-prefix run deadlocked: {rep:?}");
    assert_eq!(rep.capacity_violations, 0, "resident exceeded the cap: {rep:?}");
    assert_eq!(rep.prefill_failures, 0, "admission admitted an unservable prefill");
    assert_eq!(rep.append_failures, 0);
    assert_eq!(rep.completed + rep.rejected, trace.len());
    assert_eq!(rep.rejected, 0, "sharing must make every request servable");
    assert_eq!(rep.prefix_donors, 1, "exactly one donor seals the prefix");
    assert_eq!(rep.prefix_attaches, trace.len() - 1);
    assert!(rep.peak_shared_blocks > 0);
    // at peak, multiple sessions reference every shared block at once
    assert!(
        rep.peak_shared_refs >= 2 * rep.peak_shared_blocks,
        "dedup ratio < 2x at peak: {rep:?}"
    );
    assert_eq!(rep.final_live_blocks, 0, "refcounts must drain to zero");
    // the same trace WITHOUT sharing cannot fit concurrently: the
    // nominal footprint above the cap forces the gate to defer
    let unshared_cfg = PressureConfig { shared_prefix_tokens: 0, ..cfg.clone() };
    let rep_unshared = run_memory_pressure(&unshared_cfg, &trace);
    assert!(rep_unshared.drained);
    assert!(
        rep_unshared.deferrals > 0,
        "cap sized to stress the unshared run ({rep_unshared:?})"
    );
    assert!(
        rep.peak_live_blocks <= rep_unshared.peak_live_blocks.max(cfg.capacity_blocks),
        "sharing cannot raise the peak"
    );
}

/// Multi-template mix through the router-facing trace generator, at
/// nightly scale (several prefixes, more sessions).
#[test]
#[ignore]
fn shared_prefix_pressure_sweep() {
    for seed in 0..4u64 {
        let cfg = PressureConfig {
            capacity_blocks: 700,
            shared_prefix_tokens: 64,
            max_batch: 8,
            ..PressureConfig::default()
        };
        let trace = shared_prefix_poisson(40.0, 24, 3, 100, 6, seed);
        let rep = run_memory_pressure(&cfg, &trace);
        assert!(rep.drained, "seed {seed}: {rep:?}");
        assert_eq!(rep.capacity_violations, 0, "seed {seed}: {rep:?}");
        assert_eq!(rep.quota_violations, 0, "seed {seed}: {rep:?}");
        assert_eq!(rep.completed + rep.rejected, trace.len(), "seed {seed}: {rep:?}");
        assert!(rep.prefix_donors >= 1 && rep.prefix_donors <= 3, "seed {seed}: {rep:?}");
        assert!(rep.peak_shared_refs >= rep.peak_shared_blocks, "seed {seed}: {rep:?}");
        assert_eq!(rep.final_live_blocks, 0, "seed {seed}: {rep:?}");
    }
}
