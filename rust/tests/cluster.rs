//! Cluster serving, work stealing, live migration and failure recovery
//! (DESIGN.md §2 "Cluster serving & migration").
//!
//! Two layers, mirroring the admission/spill suites:
//!
//! * **Modelled** (tier-1, no artifacts): `workload::run_cluster_pressure`
//!   drives N per-worker admission gates + arenas behind the real
//!   `Router` with the modelled KV footprint — proves the coordinator
//!   invariants (stealing drains skewed load, a killed worker's sessions
//!   all complete on survivors, per-worker caps never breached, failure
//!   leaks no blocks) without model artifacts.
//! * **Live** (gated on artifacts + PJRT): `engine::ClusterEngine` runs
//!   real `LiveEngine` replicas — proves the bit-level claims: a migrated
//!   session's remaining tokens are bit-identical to an unmigrated run,
//!   and a killed replica's sessions recover via deterministic re-prefill
//!   + teacher-forced replay with zero divergence.

use retroinfer::coordinator::Request;
use retroinfer::engine::live::structured_prompt;
use retroinfer::engine::{AttnMode, ClusterConfig, ClusterEngine, LiveEngine};
use retroinfer::kvcache::DEFAULT_TENANT;
use retroinfer::runtime::default_artifacts_dir;
use retroinfer::workload::{
    run_cluster_pressure, ClusterPressureConfig, PressureConfig, RequestSpec,
};

fn spec(input_tokens: usize, output_tokens: usize) -> RequestSpec {
    RequestSpec {
        arrive_s: 0.0,
        input_tokens,
        output_tokens,
        tenant: DEFAULT_TENANT,
        prefix_hash: None,
    }
}

/// Big requests land on worker 0, small ones on worker 1 (least-loaded
/// routing balances counts, not footprints), so worker 0's gate defers
/// while worker 1 idles — exactly the skew stealing exists for.
fn skewed_trace() -> Vec<RequestSpec> {
    let mut trace = Vec::new();
    for _ in 0..8 {
        trace.push(spec(112, 8)); // ~128 blocks resident at d=16/512B
        trace.push(spec(8, 4)); // ~8 blocks
    }
    trace
}

fn two_worker_cfg(steal: bool) -> ClusterPressureConfig {
    ClusterPressureConfig {
        workers: 2,
        node: PressureConfig {
            capacity_blocks: 256, // two big requests fill a worker
            ..PressureConfig::default()
        },
        steal,
        kill_worker: None,
        kill_at_step: 0,
    }
}

#[test]
fn modelled_cluster_steals_skewed_load_and_drains() {
    let cfg = two_worker_cfg(true);
    let trace = skewed_trace();
    let rep = run_cluster_pressure(&cfg, &trace);
    assert!(rep.drained, "cluster deadlocked: {rep:?}");
    assert_eq!(rep.completed, trace.len(), "requests lost: {rep:?}");
    assert_eq!(rep.rejected, 0, "workload sized to fit per-request: {rep:?}");
    assert_eq!(rep.capacity_violations, 0, "per-worker cap breached: {rep:?}");
    assert_eq!(rep.prefill_failures, 0, "gate admitted an unservable prefill: {rep:?}");
    // the skew genuinely bit, and stealing genuinely moved work
    assert!(rep.deferrals > 0, "worker 0 never deferred: {rep:?}");
    assert!(rep.steals > 0, "no deferred head was stolen: {rep:?}");
    assert!(
        rep.completed_per_worker.iter().all(|&c| c > 0),
        "stealing should spread completions over both workers: {rep:?}"
    );
}

#[test]
fn modelled_cluster_drains_without_stealing_too() {
    // stealing is a latency optimisation, not a liveness requirement:
    // with it off, deferred heads wait for local reclamation instead
    let cfg = two_worker_cfg(false);
    let trace = skewed_trace();
    let rep = run_cluster_pressure(&cfg, &trace);
    assert!(rep.drained, "no-steal cluster deadlocked: {rep:?}");
    assert_eq!(rep.completed, trace.len(), "requests lost: {rep:?}");
    assert_eq!(rep.steals, 0, "steal=false must not move work: {rep:?}");
    assert_eq!(rep.capacity_violations, 0, "{rep:?}");
}

#[test]
fn modelled_cluster_kill_recovers_every_session_on_survivors() {
    let cfg = ClusterPressureConfig {
        workers: 3,
        node: PressureConfig {
            capacity_blocks: 512,
            ..PressureConfig::default()
        },
        steal: true,
        kill_worker: Some(1),
        kill_at_step: 8,
    };
    let trace: Vec<RequestSpec> = (0..12).map(|_| spec(64, 16)).collect();
    let rep = run_cluster_pressure(&cfg, &trace);
    assert!(rep.drained, "cluster deadlocked after the kill: {rep:?}");
    assert_eq!(
        rep.completed + rep.rejected,
        trace.len(),
        "the failure lost requests: {rep:?}"
    );
    assert_eq!(rep.rejected, 0, "workload sized to fit per-request: {rep:?}");
    assert!(rep.recovered > 0, "kill_at_step=8 should catch sessions in flight: {rep:?}");
    assert_eq!(rep.leaked_blocks, 0, "dead worker's arena failed to drain: {rep:?}");
    assert_eq!(rep.capacity_violations, 0, "recovery breached a survivor's cap: {rep:?}");
    assert_eq!(rep.prefill_failures, 0, "{rep:?}");
    // re-homes are accounted through the router's steal counter
    assert!(rep.steals >= rep.recovered as u64, "{rep:?}");
}

// ---------------------------------------------------------------------
// Live-path tests: real engines, bit-level claims.
// ---------------------------------------------------------------------

/// The uninterrupted run every cluster scenario must reproduce
/// bit-exactly: one solo engine, same session id (the clustering seed),
/// greedy free-running decode.
fn reference_tokens(dir: &str, id: u64, p: &[i32], max_new: usize) -> Vec<i32> {
    let mut eng = LiveEngine::new(dir, AttnMode::Wave).unwrap();
    let mut toks = vec![eng.prefill_for(id, DEFAULT_TENANT, p).unwrap()];
    while toks.len() < max_new {
        toks.push(eng.decode_step(&[id], 1).unwrap()[0]);
    }
    toks
}

#[test]
fn migrated_session_finishes_bit_identical_to_unmigrated_run() {
    retroinfer::require_live_path!();
    let dir = default_artifacts_dir();
    let p = structured_prompt(2048, 31);
    let max_new = 12usize;
    let want = reference_tokens(&dir, 1, &p, max_new);

    let mut cluster = ClusterEngine::new(&dir, &ClusterConfig::default()).unwrap();
    let w0 = cluster.submit(Request::new(1, p.clone(), max_new));
    // round 1 prefills, rounds 2..5 decode: 5 tokens before migration
    for _ in 0..5 {
        cluster.step().unwrap();
    }
    let to = 1 - w0;
    let bytes = cluster.migrate_session(1, to).unwrap();
    assert!(bytes > 0, "a mid-decode session must serialize real state");
    assert_eq!(cluster.home_of(1), Some(to));
    let rep = cluster.run_until_done(10_000).unwrap();
    assert_eq!(
        cluster.output(1).unwrap(),
        &want[..],
        "migration changed the token stream"
    );
    assert_eq!(rep.migrations, 1);
    assert!(rep.migrated_bytes as usize >= bytes);
    assert_eq!(rep.completed, 1);
    assert!(rep.finite_or_empty(), "report grew a NaN: {rep:?}");
}

#[test]
fn killed_replica_sessions_replay_bit_identical_on_survivor() {
    retroinfer::require_live_path!();
    let dir = default_artifacts_dir();
    let p1 = structured_prompt(2048, 32);
    let p2 = structured_prompt(2048, 33);
    let max_new = 10usize;
    let want1 = reference_tokens(&dir, 1, &p1, max_new);
    let want2 = reference_tokens(&dir, 2, &p2, max_new);

    let mut cluster = ClusterEngine::new(&dir, &ClusterConfig::default()).unwrap();
    let w1 = cluster.submit(Request::new(1, p1, max_new));
    let w2 = cluster.submit(Request::new(2, p2, max_new));
    assert_ne!(w1, w2, "least-loaded routing shards the two sessions");
    // both mid-decode (1 prefill + 3 decode rounds) when the axe falls
    for _ in 0..4 {
        cluster.step().unwrap();
    }
    let recovered = cluster.kill_replica(w1).unwrap();
    assert_eq!(recovered, 1, "the killed replica held exactly one session");
    assert_eq!(cluster.n_live(), 1);
    assert_eq!(cluster.home_of(1), Some(w2), "session re-homed to the survivor");

    let rep = cluster.run_until_done(10_000).unwrap();
    assert_eq!(cluster.output(1).unwrap(), &want1[..], "recovered session diverged");
    assert_eq!(cluster.output(2).unwrap(), &want2[..], "undisturbed session diverged");
    assert_eq!(rep.completed, 2);
    assert_eq!(rep.failures, 1);
    assert_eq!(rep.recovered_sessions, 1);
    assert!(rep.replayed_tokens > 0, "mid-decode recovery must replay tokens");
    assert_eq!(
        rep.replay_divergence, 0,
        "teacher-forced replay must reproduce the lost KV exactly: {rep:?}"
    );
    assert!(rep.finite_or_empty(), "report grew a NaN: {rep:?}");
}

#[test]
fn kill_guards_reject_bad_victims() {
    retroinfer::require_live_path!();
    let dir = default_artifacts_dir();
    let mut cluster = ClusterEngine::new(&dir, &ClusterConfig::default()).unwrap();
    assert!(cluster.kill_replica(7).is_err(), "out-of-range victim");
    cluster.kill_replica(0).unwrap();
    assert!(cluster.kill_replica(0).is_err(), "already dead");
    assert!(
        cluster.kill_replica(1).is_err(),
        "the last live replica must refuse to die"
    );
}
