//! GPU block cache with pluggable replacement policies (paper §4.3).
//!
//! The cache holds *copies* of KV blocks in "GPU memory" (a flat slot
//! arena), keyed by the per-head physical block id. Policies: LRU
//! (paper default), FIFO, CLOCK, and 2Q — all O(1) via an intrusive
//! vec-based doubly-linked list.

use crate::config::CachePolicy;
use std::collections::HashMap;

const NIL: u32 = u32::MAX;

/// Intrusive doubly-linked list over slot indices.
struct DList {
    head: u32,
    tail: u32,
    prev: Vec<u32>,
    next: Vec<u32>,
}

impl DList {
    fn new(capacity: usize) -> Self {
        DList { head: NIL, tail: NIL, prev: vec![NIL; capacity], next: vec![NIL; capacity] }
    }

    fn push_front(&mut self, s: u32) {
        self.prev[s as usize] = NIL;
        self.next[s as usize] = self.head;
        if self.head != NIL {
            self.prev[self.head as usize] = s;
        }
        self.head = s;
        if self.tail == NIL {
            self.tail = s;
        }
    }

    fn remove(&mut self, s: u32) {
        let (p, n) = (self.prev[s as usize], self.next[s as usize]);
        if p != NIL {
            self.next[p as usize] = n;
        } else {
            self.head = n;
        }
        if n != NIL {
            self.prev[n as usize] = p;
        } else {
            self.tail = p;
        }
        self.prev[s as usize] = NIL;
        self.next[s as usize] = NIL;
    }

    fn pop_back(&mut self) -> Option<u32> {
        let t = self.tail;
        if t == NIL {
            None
        } else {
            self.remove(t);
            Some(t)
        }
    }
}

/// Fixed-capacity block cache.
pub struct BlockCache {
    policy: CachePolicy,
    capacity: usize,
    /// Slot data arena: slot s owns `data[s*slot_elems..(s+1)*slot_elems]`
    /// (key half then value half of one block).
    data: Vec<f32>,
    slot_elems: usize,
    /// block key -> slot
    map: HashMap<u64, u32>,
    /// slot -> block key
    keys: Vec<u64>,
    free: Vec<u32>,
    // policy state
    main: DList,        // LRU/FIFO/CLOCK order; 2Q's Am
    a1in: DList,        // 2Q probationary queue
    in_a1: Vec<bool>,   // 2Q: slot is in A1in
    refbit: Vec<bool>,  // CLOCK reference bits
}

impl BlockCache {
    /// `capacity` in blocks; `slot_elems` = f32 elements per block
    /// (2 * tokens_per_block * d).
    pub fn new(policy: CachePolicy, capacity: usize, slot_elems: usize) -> Self {
        BlockCache {
            policy,
            capacity,
            data: vec![0.0; capacity * slot_elems],
            slot_elems,
            map: HashMap::with_capacity(capacity * 2),
            keys: vec![u64::MAX; capacity],
            free: (0..capacity as u32).rev().collect(),
            main: DList::new(capacity),
            a1in: DList::new(capacity),
            in_a1: vec![false; capacity],
            refbit: vec![false; capacity],
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn policy(&self) -> CachePolicy {
        self.policy
    }

    /// Read-only lookup: does NOT touch policy state (the synchronous
    /// access path of §4.3 — policy updates happen asynchronously).
    pub fn peek(&self, key: u64) -> Option<u32> {
        self.map.get(&key).copied()
    }

    /// Policy touch for a hit (run during the asynchronous update).
    pub fn touch(&mut self, key: u64) {
        let Some(&s) = self.map.get(&key) else { return };
        match self.policy {
            CachePolicy::Lru => {
                self.main.remove(s);
                self.main.push_front(s);
            }
            CachePolicy::Fifo => {}
            CachePolicy::Clock => {
                self.refbit[s as usize] = true;
            }
            CachePolicy::TwoQ => {
                if self.in_a1[s as usize] {
                    // promote probationary block to the main queue
                    self.a1in.remove(s);
                    self.in_a1[s as usize] = false;
                    self.main.push_front(s);
                } else {
                    self.main.remove(s);
                    self.main.push_front(s);
                }
            }
        }
    }

    /// Admit `key`; returns (slot, evicted key if any). No-op if present.
    pub fn admit(&mut self, key: u64) -> (u32, Option<u64>) {
        if let Some(&s) = self.map.get(&key) {
            return (s, None);
        }
        if self.capacity == 0 {
            return (NIL, None);
        }
        let mut evicted = None;
        let slot = if let Some(s) = self.free.pop() {
            s
        } else {
            let s = self.evict_slot();
            let old = self.keys[s as usize];
            self.map.remove(&old);
            evicted = Some(old);
            s
        };
        self.keys[slot as usize] = key;
        self.map.insert(key, slot);
        match self.policy {
            CachePolicy::Lru | CachePolicy::Fifo => self.main.push_front(slot),
            CachePolicy::Clock => {
                self.main.push_front(slot);
                self.refbit[slot as usize] = false;
            }
            CachePolicy::TwoQ => {
                self.a1in.push_front(slot);
                self.in_a1[slot as usize] = true;
            }
        }
        (slot, evicted)
    }

    /// Drop a resident block outright (tier demotion: a block moving to
    /// the cold spill tier must not keep occupying a GPU slot). Returns
    /// the freed slot, or `None` if the key is not resident.
    pub fn remove(&mut self, key: u64) -> Option<u32> {
        let s = self.map.remove(&key)?;
        if self.in_a1[s as usize] {
            self.a1in.remove(s);
            self.in_a1[s as usize] = false;
        } else {
            self.main.remove(s);
        }
        self.refbit[s as usize] = false;
        self.keys[s as usize] = u64::MAX;
        self.free.push(s);
        Some(s)
    }

    fn evict_slot(&mut self) -> u32 {
        match self.policy {
            CachePolicy::Lru | CachePolicy::Fifo => {
                self.main.pop_back().expect("cache full but main empty")
            }
            CachePolicy::Clock => {
                // Second-chance sweep from the tail.
                loop {
                    let s = self.main.pop_back().expect("clock empty");
                    if self.refbit[s as usize] {
                        self.refbit[s as usize] = false;
                        self.main.push_front(s);
                    } else {
                        return s;
                    }
                }
            }
            CachePolicy::TwoQ => {
                // Evict from A1in first (scan resistance), then Am.
                if let Some(s) = self.a1in.pop_back() {
                    self.in_a1[s as usize] = false;
                    s
                } else {
                    self.main.pop_back().expect("2q empty")
                }
            }
        }
    }

    /// Block data of a resident slot.
    pub fn slot_data(&self, slot: u32) -> &[f32] {
        let s = slot as usize;
        &self.data[s * self.slot_elems..(s + 1) * self.slot_elems]
    }

    pub fn slot_data_mut(&mut self, slot: u32) -> &mut [f32] {
        let s = slot as usize;
        &mut self.data[s * self.slot_elems..(s + 1) * self.slot_elems]
    }

    pub fn slot_elems(&self) -> usize {
        self.slot_elems
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys_of(c: &BlockCache) -> Vec<u64> {
        let mut ks: Vec<u64> = c.map.keys().copied().collect();
        ks.sort();
        ks
    }

    #[test]
    fn admit_until_full_then_evict_lru() {
        let mut c = BlockCache::new(CachePolicy::Lru, 3, 4);
        for k in 0..3u64 {
            let (_, ev) = c.admit(k);
            assert!(ev.is_none());
        }
        // touch 0 so it is MRU; admitting 3 must evict 1
        c.touch(0);
        let (_, ev) = c.admit(3);
        assert_eq!(ev, Some(1));
        assert_eq!(keys_of(&c), vec![0, 2, 3]);
    }

    #[test]
    fn fifo_ignores_touches() {
        let mut c = BlockCache::new(CachePolicy::Fifo, 2, 4);
        c.admit(10);
        c.admit(11);
        c.touch(10); // FIFO: no effect
        let (_, ev) = c.admit(12);
        assert_eq!(ev, Some(10));
    }

    #[test]
    fn clock_second_chance() {
        let mut c = BlockCache::new(CachePolicy::Clock, 2, 4);
        c.admit(1);
        c.admit(2);
        c.touch(1); // ref bit set
        let (_, ev) = c.admit(3);
        // 1 gets a second chance, 2 is evicted
        assert_eq!(ev, Some(2));
        assert!(c.peek(1).is_some());
    }

    #[test]
    fn twoq_scan_resistance() {
        let mut c = BlockCache::new(CachePolicy::TwoQ, 4, 4);
        c.admit(1);
        c.touch(1); // promote 1 to Am
        c.admit(2);
        c.admit(3);
        c.admit(4);
        // a scan of one-shot blocks must evict from A1in, preserving 1
        let (_, ev) = c.admit(5);
        assert_ne!(ev, Some(1));
        assert!(c.peek(1).is_some());
    }

    #[test]
    fn peek_does_not_change_order() {
        let mut c = BlockCache::new(CachePolicy::Lru, 2, 4);
        c.admit(1);
        c.admit(2);
        c.peek(1); // read-only
        let (_, ev) = c.admit(3);
        assert_eq!(ev, Some(1), "peek must not refresh LRU position");
    }

    #[test]
    fn readmit_is_noop() {
        let mut c = BlockCache::new(CachePolicy::Lru, 2, 4);
        let (s1, _) = c.admit(7);
        let (s2, ev) = c.admit(7);
        assert_eq!(s1, s2);
        assert!(ev.is_none());
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn slot_data_roundtrip() {
        let mut c = BlockCache::new(CachePolicy::Lru, 2, 4);
        let (s, _) = c.admit(9);
        c.slot_data_mut(s).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(c.slot_data(s), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn remove_frees_slot_under_every_policy() {
        for p in [CachePolicy::Lru, CachePolicy::Fifo, CachePolicy::Clock, CachePolicy::TwoQ] {
            let mut c = BlockCache::new(p, 2, 4);
            c.admit(1);
            c.admit(2);
            if p == CachePolicy::TwoQ {
                c.touch(1); // exercise removal from Am as well as A1in
            }
            assert!(c.remove(1).is_some());
            assert!(c.remove(1).is_none(), "{p:?}: double remove");
            assert!(c.peek(1).is_none());
            assert_eq!(c.len(), 1);
            // the freed slot is reusable and eviction still works
            c.admit(3);
            let (_, ev) = c.admit(4);
            assert!(ev.is_some(), "{p:?}: eviction broken after remove");
            assert_eq!(c.len(), 2);
        }
    }

    #[test]
    fn zero_capacity_never_caches() {
        let mut c = BlockCache::new(CachePolicy::Lru, 0, 4);
        let (s, ev) = c.admit(1);
        assert_eq!(s, NIL);
        assert!(ev.is_none());
        assert!(c.peek(1).is_none());
    }

    #[test]
    fn stress_all_policies_bounded() {
        for p in [CachePolicy::Lru, CachePolicy::Fifo, CachePolicy::Clock, CachePolicy::TwoQ] {
            let mut c = BlockCache::new(p, 8, 2);
            for i in 0..1000u64 {
                c.admit(i % 37);
                if i % 3 == 0 {
                    c.touch(i % 37);
                }
                assert!(c.len() <= 8, "{p:?} exceeded capacity");
            }
            assert_eq!(c.len(), 8);
        }
    }
}
