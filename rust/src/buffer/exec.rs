//! Execution buffer: the contiguous staging area in GPU memory that the
//! attention kernel consumes (paper §4.3 "Assemble the Execution Buffer").
//! Its content is gathered from three sources: the steady zone (GPU→GPU),
//! the block cache (GPU→GPU), and CPU KV blocks on a miss (CPU→GPU).

/// Reusable execution buffer for one (head, query) attention call.
/// Token-major flat `[n, d]` keys and values.
#[derive(Default)]
pub struct ExecBuffer {
    pub keys: Vec<f32>,
    pub vals: Vec<f32>,
    d: usize,
}

impl ExecBuffer {
    pub fn new(d: usize) -> Self {
        ExecBuffer { keys: Vec::new(), vals: Vec::new(), d }
    }

    pub fn clear(&mut self) {
        self.keys.clear();
        self.vals.clear();
    }

    pub fn n_tokens(&self) -> usize {
        if self.d == 0 {
            0
        } else {
            self.keys.len() / self.d
        }
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn push(&mut self, keys: &[f32], vals: &[f32]) {
        debug_assert_eq!(keys.len(), vals.len());
        self.keys.extend_from_slice(keys);
        self.vals.extend_from_slice(vals);
    }
}

/// Data-movement accounting for one assembly (consumed by `memsim` and
/// the Figure 16 ablation).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AccessStats {
    /// Tokens copied from the steady zone (GPU→GPU).
    pub steady_tokens: usize,
    /// Blocks found in the GPU cache (GPU→GPU copy).
    pub hit_blocks: usize,
    /// Hits served from the cross-session shared prefix cache (subset
    /// of `hit_blocks`: one GPU slot deduped across sessions).
    pub shared_hit_blocks: usize,
    /// Blocks fetched from CPU memory (PCIe transfer).
    pub miss_blocks: usize,
    /// Blocks served from the cold spill tier (the block was neither
    /// GPU-cached nor hot in CPU RAM when selected).
    pub cold_blocks: usize,
    /// Of `cold_blocks`, reads served from the pipelined-decode staging
    /// area — their page I/O ran on the thread pool's I/O lane and
    /// completed under attention compute instead of stalling the
    /// gather. `cold_staged_blocks / cold_blocks` is the measured
    /// intra-step spill-overlap ratio.
    pub cold_staged_blocks: usize,
    /// Bytes copied GPU→GPU (steady + cache hits).
    pub g2g_bytes: usize,
    /// Bytes moved over PCIe (cache misses).
    pub pcie_bytes: usize,
    /// Bytes read from the spill tier (cold-hit stalls).
    pub spill_bytes: usize,
    /// Wall time of the zone-selection phase (centroid scoring + top-k),
    /// in nanoseconds — the "select" row of the decode phase report.
    pub select_ns: u64,
    /// Wall time of the gather/pack phase (execution-buffer assembly +
    /// WaveInputs copy-out), in nanoseconds.
    pub gather_ns: u64,
}

impl AccessStats {
    pub fn hit_ratio(&self) -> f64 {
        let total = self.hit_blocks + self.miss_blocks;
        if total == 0 {
            1.0
        } else {
            self.hit_blocks as f64 / total as f64
        }
    }

    pub fn add(&mut self, o: &AccessStats) {
        self.steady_tokens += o.steady_tokens;
        self.hit_blocks += o.hit_blocks;
        self.shared_hit_blocks += o.shared_hit_blocks;
        self.miss_blocks += o.miss_blocks;
        self.cold_blocks += o.cold_blocks;
        self.cold_staged_blocks += o.cold_staged_blocks;
        self.g2g_bytes += o.g2g_bytes;
        self.pcie_bytes += o.pcie_bytes;
        self.spill_bytes += o.spill_bytes;
        self.select_ns += o.select_ns;
        self.gather_ns += o.gather_ns;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_buffer_accumulates_tokens() {
        let mut eb = ExecBuffer::new(4);
        eb.push(&[1.0; 8], &[2.0; 8]);
        assert_eq!(eb.n_tokens(), 2);
        eb.clear();
        assert_eq!(eb.n_tokens(), 0);
    }

    #[test]
    fn hit_ratio_edges() {
        let mut s = AccessStats::default();
        assert_eq!(s.hit_ratio(), 1.0);
        s.hit_blocks = 3;
        s.miss_blocks = 1;
        assert!((s.hit_ratio() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn stats_add() {
        let mut a = AccessStats {
            steady_tokens: 1,
            hit_blocks: 2,
            shared_hit_blocks: 1,
            miss_blocks: 3,
            cold_blocks: 4,
            cold_staged_blocks: 2,
            g2g_bytes: 5,
            pcie_bytes: 6,
            spill_bytes: 7,
            select_ns: 8,
            gather_ns: 9,
        };
        let b = a;
        a.add(&b);
        assert_eq!(a.miss_blocks, 6);
        assert_eq!(a.cold_blocks, 8);
        assert_eq!(a.cold_staged_blocks, 4);
        assert_eq!(a.pcie_bytes, 12);
        assert_eq!(a.spill_bytes, 14);
        assert_eq!(a.select_ns, 16);
        assert_eq!(a.gather_ns, 18);
    }
}
