//! Cluster mapping table (paper §4.3): the layer of indirection between
//! the wave index's *logical* unit (clusters) and the wave buffer's
//! *physical* unit (blocks). Implemented as an array indexed by cluster id
//! for O(1) lookup, with a reverse block→cluster map so evictions can
//! invalidate descriptors. Blocks are addressed by their engine-global
//! arena id (sparse across sessions, hence a hash map rather than a
//! dense array).

use crate::kvcache::BlockRef;
use std::collections::HashMap;

/// Where one of a cluster's blocks currently lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockHome {
    /// Only in CPU memory.
    Cpu,
    /// Cached in the given GPU cache slot.
    Gpu(u32),
}

/// Descriptor of one cluster: its CPU blocks and their GPU residency.
#[derive(Clone, Debug)]
pub struct ClusterDesc {
    pub blocks: Vec<BlockRef>,
    pub home: Vec<BlockHome>,
}

impl ClusterDesc {
    pub fn n_tokens(&self) -> usize {
        self.blocks.iter().map(|b| b.len as usize).sum()
    }
}

/// Array-indexed mapping table for one head.
pub struct MappingTable {
    clusters: Vec<ClusterDesc>,
    /// arena block id -> (cluster id, index within cluster)
    owner: HashMap<u64, (u32, u16)>,
}

impl MappingTable {
    pub fn new() -> Self {
        MappingTable { clusters: Vec::new(), owner: HashMap::new() }
    }

    /// Register a cluster's blocks; cluster ids must be appended in order
    /// (mirrors the meta index).
    pub fn add_cluster(&mut self, blocks: Vec<BlockRef>) -> u32 {
        let cid = self.clusters.len() as u32;
        for (i, b) in blocks.iter().enumerate() {
            self.owner.insert(b.block, (cid, i as u16));
        }
        let home = vec![BlockHome::Cpu; blocks.len()];
        self.clusters.push(ClusterDesc { blocks, home });
        cid
    }

    pub fn n_clusters(&self) -> usize {
        self.clusters.len()
    }

    /// Read-only descriptor lookup (the synchronous access path).
    pub fn lookup(&self, cluster: u32) -> &ClusterDesc {
        &self.clusters[cluster as usize]
    }

    /// Mark a block as admitted to GPU slot `slot`.
    pub fn set_cached(&mut self, block: u64, slot: u32) {
        let (c, i) = self.owner[&block];
        self.clusters[c as usize].home[i as usize] = BlockHome::Gpu(slot);
    }

    /// Invalidate a block's GPU residency (after eviction).
    pub fn set_evicted(&mut self, block: u64) {
        if let Some(&(c, i)) = self.owner.get(&block) {
            self.clusters[c as usize].home[i as usize] = BlockHome::Cpu;
        }
    }

    /// Owning (cluster, index) of an arena block id.
    pub fn owner(&self, block: u64) -> (u32, u16) {
        self.owner.get(&block).copied().unwrap_or((u32::MAX, 0))
    }

    /// Blocks currently GPU-resident (for invariants/tests).
    pub fn gpu_resident_blocks(&self) -> usize {
        self.clusters
            .iter()
            .flat_map(|c| &c.home)
            .filter(|h| matches!(h, BlockHome::Gpu(_)))
            .count()
    }
}

impl Default for MappingTable {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bref(block: u64, idx: u32, len: u16) -> BlockRef {
        BlockRef { block, idx, len }
    }

    #[test]
    fn add_and_lookup() {
        let mut mt = MappingTable::new();
        let c0 = mt.add_cluster(vec![bref(0, 0, 8), bref(1, 1, 3)]);
        let c1 = mt.add_cluster(vec![bref(2, 2, 8)]);
        assert_eq!((c0, c1), (0, 1));
        assert_eq!(mt.lookup(0).n_tokens(), 11);
        assert_eq!(mt.lookup(1).blocks[0].block, 2);
        assert!(mt.lookup(0).home.iter().all(|h| *h == BlockHome::Cpu));
    }

    #[test]
    fn cached_evicted_cycle() {
        let mut mt = MappingTable::new();
        mt.add_cluster(vec![bref(0, 0, 8), bref(1, 1, 8)]);
        mt.set_cached(1, 42);
        assert_eq!(mt.lookup(0).home[1], BlockHome::Gpu(42));
        assert_eq!(mt.gpu_resident_blocks(), 1);
        mt.set_evicted(1);
        assert_eq!(mt.lookup(0).home[1], BlockHome::Cpu);
        assert_eq!(mt.gpu_resident_blocks(), 0);
    }

    #[test]
    fn owner_reverse_map_with_sparse_global_ids() {
        let mut mt = MappingTable::new();
        // arena ids from a later session are large and non-contiguous
        mt.add_cluster(vec![bref(1 << 40, 0, 8)]);
        mt.add_cluster(vec![bref((1 << 40) + 7, 1, 8), bref((1 << 40) + 9, 2, 2)]);
        assert_eq!(mt.owner(1 << 40), (0, 0));
        assert_eq!(mt.owner((1 << 40) + 9), (1, 1));
        assert_eq!(mt.owner(3), (u32::MAX, 0));
        // evicting an unknown block is a no-op, not a panic
        mt.set_evicted(3);
    }
}
