//! Cluster mapping table (paper §4.3): the layer of indirection between
//! the wave index's *logical* unit (clusters) and the wave buffer's
//! *physical* unit (blocks). Implemented as an array indexed by cluster id
//! for O(1) lookup, with a reverse block→cluster map so evictions can
//! invalidate descriptors. Blocks are addressed by their engine-global
//! arena id (sparse across sessions, hence a hash map rather than a
//! dense array).

use crate::kvcache::BlockRef;
use std::collections::HashMap;

/// Where one of a cluster's blocks currently lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockHome {
    /// Hot CPU memory only.
    Cpu,
    /// Cached in the given GPU cache slot.
    Gpu(u32),
    /// Demoted to the cold spill tier (neither GPU-cached nor hot in
    /// CPU RAM — a selection touching it is a cold-hit stall until the
    /// engine promotes it).
    Cold,
}

/// Descriptor of one cluster: its CPU blocks and their GPU residency.
#[derive(Clone, Debug)]
pub struct ClusterDesc {
    pub blocks: Vec<BlockRef>,
    pub home: Vec<BlockHome>,
}

impl ClusterDesc {
    pub fn n_tokens(&self) -> usize {
        self.blocks.iter().map(|b| b.len as usize).sum()
    }
}

/// Array-indexed mapping table for one head.
pub struct MappingTable {
    clusters: Vec<ClusterDesc>,
    /// arena block id -> (cluster id, index within cluster)
    owner: HashMap<u64, (u32, u16)>,
}

impl MappingTable {
    pub fn new() -> Self {
        MappingTable { clusters: Vec::new(), owner: HashMap::new() }
    }

    /// Register a cluster's blocks; cluster ids must be appended in order
    /// (mirrors the meta index).
    pub fn add_cluster(&mut self, blocks: Vec<BlockRef>) -> u32 {
        let cid = self.clusters.len() as u32;
        for (i, b) in blocks.iter().enumerate() {
            self.owner.insert(b.block, (cid, i as u16));
        }
        let home = vec![BlockHome::Cpu; blocks.len()];
        self.clusters.push(ClusterDesc { blocks, home });
        cid
    }

    pub fn n_clusters(&self) -> usize {
        self.clusters.len()
    }

    /// Read-only descriptor lookup (the synchronous access path).
    pub fn lookup(&self, cluster: u32) -> &ClusterDesc {
        &self.clusters[cluster as usize]
    }

    /// Mark a block as admitted to GPU slot `slot`.
    pub fn set_cached(&mut self, block: u64, slot: u32) {
        let (c, i) = self.owner[&block];
        self.clusters[c as usize].home[i as usize] = BlockHome::Gpu(slot);
    }

    /// Invalidate a block's GPU residency (after eviction). Only a
    /// `Gpu` home transitions back to `Cpu` — evicting a block whose
    /// base tier is cold must leave it `Cold`, not resurrect a phantom
    /// hot-CPU residency.
    pub fn set_evicted(&mut self, block: u64) {
        if let Some(&(c, i)) = self.owner.get(&block) {
            let h = &mut self.clusters[c as usize].home[i as usize];
            if matches!(h, BlockHome::Gpu(_)) {
                *h = BlockHome::Cpu;
            }
        }
    }

    /// Mark a block demoted to the cold tier. Callers must drop any
    /// GPU-cache copy first (`WaveBuffer::note_demoted` does both under
    /// one lock) so a block is never `Gpu` in the cache and `Cold` here.
    pub fn set_cold(&mut self, block: u64) {
        if let Some(&(c, i)) = self.owner.get(&block) {
            self.clusters[c as usize].home[i as usize] = BlockHome::Cold;
        }
    }

    /// Mark a cold block promoted back to hot CPU memory.
    pub fn set_hot(&mut self, block: u64) {
        if let Some(&(c, i)) = self.owner.get(&block) {
            let h = &mut self.clusters[c as usize].home[i as usize];
            if *h == BlockHome::Cold {
                *h = BlockHome::Cpu;
            }
        }
    }

    /// Invalidate a whole cluster's descriptor: every block's
    /// reverse-map entry is removed regardless of its `BlockHome` state
    /// — a mixed `Gpu` + `Cold` cluster must not leave stale `owner`
    /// entries behind (the eviction-bookkeeping regression in
    /// `tests/spill.rs`). Returns the removed blocks with their last
    /// homes so the caller can drop GPU slots / cold pages. No serving
    /// path retires clusters yet (today's pipeline only appends and
    /// tears whole heads down, which drops the table outright); this is
    /// the teardown entry point cluster rebuilds must go through.
    pub fn invalidate_cluster(&mut self, cluster: u32) -> Vec<(u64, BlockHome)> {
        let desc = &mut self.clusters[cluster as usize];
        let blocks = std::mem::take(&mut desc.blocks);
        let homes = std::mem::take(&mut desc.home);
        let mut removed = Vec::with_capacity(blocks.len());
        for (b, h) in blocks.iter().zip(homes) {
            // remove only entries this cluster actually owns: an id
            // re-registered by a later cluster must keep its new owner
            if self.owner.get(&b.block).is_some_and(|&(c, _)| c == cluster) {
                self.owner.remove(&b.block);
            }
            removed.push((b.block, h));
        }
        removed
    }

    /// Current home of a block (`None` for unknown ids).
    pub fn home(&self, block: u64) -> Option<BlockHome> {
        self.owner
            .get(&block)
            .map(|&(c, i)| self.clusters[c as usize].home[i as usize])
    }

    /// Owning (cluster, index) of an arena block id.
    pub fn owner(&self, block: u64) -> (u32, u16) {
        self.owner.get(&block).copied().unwrap_or((u32::MAX, 0))
    }

    /// Blocks currently GPU-resident (for invariants/tests).
    pub fn gpu_resident_blocks(&self) -> usize {
        self.clusters
            .iter()
            .flat_map(|c| &c.home)
            .filter(|h| matches!(h, BlockHome::Gpu(_)))
            .count()
    }

    /// Blocks currently marked cold (for invariants/tests).
    pub fn cold_blocks(&self) -> usize {
        self.clusters
            .iter()
            .flat_map(|c| &c.home)
            .filter(|h| matches!(h, BlockHome::Cold))
            .count()
    }
}

impl Default for MappingTable {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bref(block: u64, idx: u32, len: u16) -> BlockRef {
        BlockRef { block, idx, len }
    }

    #[test]
    fn add_and_lookup() {
        let mut mt = MappingTable::new();
        let c0 = mt.add_cluster(vec![bref(0, 0, 8), bref(1, 1, 3)]);
        let c1 = mt.add_cluster(vec![bref(2, 2, 8)]);
        assert_eq!((c0, c1), (0, 1));
        assert_eq!(mt.lookup(0).n_tokens(), 11);
        assert_eq!(mt.lookup(1).blocks[0].block, 2);
        assert!(mt.lookup(0).home.iter().all(|h| *h == BlockHome::Cpu));
    }

    #[test]
    fn cached_evicted_cycle() {
        let mut mt = MappingTable::new();
        mt.add_cluster(vec![bref(0, 0, 8), bref(1, 1, 8)]);
        mt.set_cached(1, 42);
        assert_eq!(mt.lookup(0).home[1], BlockHome::Gpu(42));
        assert_eq!(mt.gpu_resident_blocks(), 1);
        mt.set_evicted(1);
        assert_eq!(mt.lookup(0).home[1], BlockHome::Cpu);
        assert_eq!(mt.gpu_resident_blocks(), 0);
    }

    #[test]
    fn cold_transitions_do_not_fabricate_cpu_homes() {
        let mut mt = MappingTable::new();
        mt.add_cluster(vec![bref(0, 0, 8), bref(1, 1, 8)]);
        mt.set_cold(0);
        assert_eq!(mt.lookup(0).home[0], BlockHome::Cold);
        assert_eq!(mt.cold_blocks(), 1);
        // evicting a cold block must not resurrect a hot-CPU home
        mt.set_evicted(0);
        assert_eq!(mt.lookup(0).home[0], BlockHome::Cold);
        mt.set_hot(0);
        assert_eq!(mt.lookup(0).home[0], BlockHome::Cpu);
        assert_eq!(mt.cold_blocks(), 0);
        // set_hot on a GPU-cached block is a no-op
        mt.set_cached(1, 3);
        mt.set_hot(1);
        assert_eq!(mt.lookup(0).home[1], BlockHome::Gpu(3));
        // unknown ids are no-ops, not panics
        mt.set_cold(99);
        mt.set_hot(99);
    }

    #[test]
    fn invalidate_cluster_removes_every_owner_entry() {
        let mut mt = MappingTable::new();
        let c0 = mt.add_cluster(vec![bref(0, 0, 8), bref(1, 1, 8), bref(2, 2, 4)]);
        // mixed homes: Gpu + Cold + Cpu
        mt.set_cached(0, 7);
        mt.set_cold(1);
        let removed = mt.invalidate_cluster(c0);
        assert_eq!(removed.len(), 3);
        assert_eq!(removed[0], (0, BlockHome::Gpu(7)));
        assert_eq!(removed[1], (1, BlockHome::Cold));
        assert_eq!(removed[2], (2, BlockHome::Cpu));
        for b in 0..3u64 {
            assert_eq!(mt.owner(b), (u32::MAX, 0), "stale owner entry for block {b}");
        }
        assert_eq!(mt.gpu_resident_blocks(), 0);
        assert_eq!(mt.cold_blocks(), 0);
        // later clusters can re-register the same descriptor slot count
        let c1 = mt.add_cluster(vec![bref(9, 0, 8)]);
        assert_eq!(mt.owner(9), (c1, 0));
    }

    #[test]
    fn owner_reverse_map_with_sparse_global_ids() {
        let mut mt = MappingTable::new();
        // arena ids from a later session are large and non-contiguous
        mt.add_cluster(vec![bref(1 << 40, 0, 8)]);
        mt.add_cluster(vec![bref((1 << 40) + 7, 1, 8), bref((1 << 40) + 9, 2, 2)]);
        assert_eq!(mt.owner(1 << 40), (0, 0));
        assert_eq!(mt.owner((1 << 40) + 9), (1, 1));
        assert_eq!(mt.owner(3), (u32::MAX, 0));
        // evicting an unknown block is a no-op, not a panic
        mt.set_evicted(3);
    }
}
