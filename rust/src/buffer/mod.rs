//! Wave buffer — the accuracy-agnostic GPU-CPU buffer manager (paper §4.3).
//!
//! The control plane (mapping table + cache replacement) runs on CPU
//! threads; the data plane assembles the execution buffer from three
//! sources (steady zone, GPU block cache, CPU KV blocks). Cache *access*
//! is synchronous and read-only; cache *update* (replacement decisions,
//! admission copies, metadata) is decoupled and runs asynchronously on the
//! buffer manager's thread pool, overlapping with attention computation.

pub mod cache;
pub mod exec;
pub mod mapping;

pub use cache::BlockCache;
pub use exec::{AccessStats, ExecBuffer};
pub use mapping::{BlockHome, ClusterDesc, MappingTable};

use crate::config::{BufferConfig, CachePolicy};
use crate::index::{WaveIndex, ZoneSelection};
use crate::util::threadpool::ThreadPool;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Cumulative wave-buffer counters (lock-free; read by metrics/benches).
#[derive(Default)]
pub struct BufferStats {
    pub lookups: AtomicU64,
    pub hit_blocks: AtomicU64,
    pub miss_blocks: AtomicU64,
    /// Hits served from the cross-session shared prefix cache (also
    /// counted in `hit_blocks` — a GPU hit is a GPU hit; this splits
    /// out the dedup share).
    pub shared_hit_blocks: AtomicU64,
    /// Cold hits: selected blocks served through the spill tier.
    pub cold_blocks: AtomicU64,
    /// Of `cold_blocks`, reads served from the staging area (I/O-lane
    /// page read completed under compute — no stall).
    pub cold_staged_blocks: AtomicU64,
    pub g2g_bytes: AtomicU64,
    pub pcie_bytes: AtomicU64,
    /// Bytes read from the cold spill tier.
    pub spill_bytes: AtomicU64,
    pub evictions: AtomicU64,
    pub async_updates: AtomicU64,
}

/// Cross-session GPU block cache for shared (refcounted) prefix blocks
/// (DESIGN.md §2 "Prefix sharing & CoW", ROADMAP "cross-session
/// block-cache sharing"): one engine-owned cache per (layer, kv-head)
/// slot, consulted by every session's wave buffer, so a prefix shared
/// by N decoding sessions occupies ONE GPU slot instead of N.
///
/// Consistency is by construction: only shared blocks — read-only and
/// never demoted while any owner holds them — are admitted, so an
/// entry can never go stale; and per-session mapping tables never
/// record shared-cache residency (their homes stay `Cpu`), so eviction
/// here needs no multi-owner home walk — the next access simply misses
/// back to the hot CPU copy.
pub struct SharedBlockCache {
    inner: Mutex<BlockCache>,
    slot_elems: usize,
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub evictions: AtomicU64,
}

impl SharedBlockCache {
    /// `capacity` in blocks; `slot_elems` = 2 × tokens_per_block × d.
    pub fn new(policy: CachePolicy, capacity: usize, slot_elems: usize) -> SharedBlockCache {
        SharedBlockCache {
            inner: Mutex::new(BlockCache::new(policy, capacity, slot_elems)),
            slot_elems,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    pub fn shared(policy: CachePolicy, capacity: usize, slot_elems: usize) -> Arc<SharedBlockCache> {
        Arc::new(SharedBlockCache::new(policy, capacity, slot_elems))
    }

    /// Copy a resident block's first `n` key/value elements into the
    /// execution buffer; false on a miss. Read-only (policy touches run
    /// in the asynchronous update, like the private cache).
    pub fn copy_into(&self, id: u64, n: usize, k_out: &mut Vec<f32>, v_out: &mut Vec<f32>) -> bool {
        let g = self.inner.lock().unwrap();
        match g.peek(id) {
            Some(slot) => {
                let data = g.slot_data(slot);
                let half = self.slot_elems / 2;
                k_out.extend_from_slice(&data[..n]);
                v_out.extend_from_slice(&data[half..half + n]);
                self.hits.fetch_add(1, Ordering::Relaxed);
                true
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                false
            }
        }
    }

    /// Policy touch for a hit (asynchronous update path).
    pub fn touch(&self, id: u64) {
        self.inner.lock().unwrap().touch(id);
    }

    /// Admit a copy of a shared block (asynchronous update path).
    pub fn admit_copy(&self, id: u64, keys: &[f32], vals: &[f32]) {
        let mut g = self.inner.lock().unwrap();
        let (slot, evicted) = g.admit(id);
        if slot != u32::MAX {
            let half = self.slot_elems / 2;
            let data = g.slot_data_mut(slot);
            data[..keys.len()].copy_from_slice(keys);
            data[half..half + vals.len()].copy_from_slice(vals);
        }
        if evicted.is_some() {
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Blocks currently resident.
    pub fn resident_blocks(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn hit_count(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }
}

impl BufferStats {
    pub fn hit_ratio(&self) -> f64 {
        let h = self.hit_blocks.load(Ordering::Relaxed) as f64;
        let m = self.miss_blocks.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            1.0
        } else {
            h / (h + m)
        }
    }
}

struct Inner {
    cache: BlockCache,
    mapping: MappingTable,
    /// Reusable gather/update capture buffers: taken at assembly start
    /// under the gather lock, returned cleared once the cache update has
    /// run, so a steady-state (all-hit) assembly allocates nothing. With
    /// `async_update` on, an in-flight update job owns the scratch and a
    /// concurrent assembly falls back to a fresh one — correctness never
    /// depends on the reuse.
    scr: UpdateScratch,
}

/// Capture buffers shared between the gather pass and the (possibly
/// asynchronous) cache-update pass.
#[derive(Default)]
struct UpdateScratch {
    hit_keys: Vec<u64>,
    shared_hit_keys: Vec<u64>,
    /// (arena block id, padded slot image) for private-cache admission.
    missed: Vec<(u64, Vec<f32>)>,
    /// (arena block id, keys, vals) for shared-cache admission.
    missed_shared: Vec<(u64, Vec<f32>, Vec<f32>)>,
}

impl UpdateScratch {
    fn is_empty(&self) -> bool {
        self.hit_keys.is_empty()
            && self.shared_hit_keys.is_empty()
            && self.missed.is_empty()
            && self.missed_shared.is_empty()
    }

    fn clear(&mut self) {
        self.hit_keys.clear();
        self.shared_hit_keys.clear();
        self.missed.clear();
        self.missed_shared.clear();
    }
}

/// The decoupled cache-update pass (paper §4.3): policy touches for
/// hits, admission for misses — private cache under `inner`'s lock,
/// shared prefix blocks under the cross-session cache's own lock. Runs
/// inline or as a pool job; either way the scratch is cleared and handed
/// back to `inner` for the next assembly.
fn apply_cache_update(
    inner: &Mutex<Inner>,
    stats: &BufferStats,
    shared: Option<&SharedBlockCache>,
    mut scr: UpdateScratch,
) {
    {
        let mut g = inner.lock().unwrap();
        for &k in &scr.hit_keys {
            g.cache.touch(k);
        }
        for (block, data) in scr.missed.drain(..) {
            // a block demoted to the cold tier between the assembly
            // snapshot and this update must not re-enter the GPU cache
            // (cold blocks hold no slots)
            if g.mapping.home(block) == Some(BlockHome::Cold) {
                continue;
            }
            let (slot, evicted) = g.cache.admit(block);
            if slot != u32::MAX {
                g.cache.slot_data_mut(slot).copy_from_slice(&data);
                g.mapping.set_cached(block, slot);
            }
            if let Some(old) = evicted {
                g.mapping.set_evicted(old);
                stats.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    if let Some(sc) = shared {
        for &k in &scr.shared_hit_keys {
            sc.touch(k);
        }
        // shared blocks never demote while refs are held, so no tier
        // re-check is needed before admission
        for (block, bk, bv) in scr.missed_shared.drain(..) {
            sc.admit_copy(block, &bk, &bv);
        }
    }
    stats.async_updates.fetch_add(1, Ordering::Relaxed);
    scr.clear();
    inner.lock().unwrap().scr = scr;
}

/// Per-head wave buffer.
pub struct WaveBuffer {
    cfg: BufferConfig,
    d: usize,
    tokens_per_block: usize,
    inner: Arc<Mutex<Inner>>,
    pool: Arc<ThreadPool>,
    stats: Arc<BufferStats>,
    /// Cross-session cache for shared prefix blocks (one per engine
    /// head slot; `None` when prefix sharing is off).
    shared: Option<Arc<SharedBlockCache>>,
}

impl WaveBuffer {
    /// `capacity_blocks` is this head's share of the GPU cache.
    pub fn new(
        cfg: BufferConfig,
        d: usize,
        tokens_per_block: usize,
        capacity_blocks: usize,
        pool: Arc<ThreadPool>,
    ) -> Self {
        let capacity = if cfg.gpu_cache_enabled { capacity_blocks } else { 0 };
        let slot_elems = 2 * tokens_per_block * d;
        WaveBuffer {
            inner: Arc::new(Mutex::new(Inner {
                cache: BlockCache::new(cfg.policy, capacity, slot_elems),
                mapping: MappingTable::new(),
                scr: UpdateScratch::default(),
            })),
            cfg,
            d,
            tokens_per_block,
            pool,
            stats: Arc::new(BufferStats::default()),
            shared: None,
        }
    }

    /// Attach the engine's cross-session shared prefix cache for this
    /// buffer's head slot (set before the first assembly).
    pub fn set_shared_cache(&mut self, cache: Arc<SharedBlockCache>) {
        self.shared = Some(cache);
    }

    /// Cache capacity sized from the config: `cache_frac` of `n_tokens`.
    pub fn capacity_for(cfg: &BufferConfig, n_tokens: usize, tokens_per_block: usize) -> usize {
        ((n_tokens as f64 * cfg.cache_frac) as usize / tokens_per_block.max(1)).max(1)
    }

    /// Register all clusters of a freshly built index (prefill phase;
    /// the paper builds the mapping table asynchronously — we expose it
    /// as one call the engine may run on the pool).
    pub fn register_index(&self, index: &WaveIndex) {
        let mut inner = self.inner.lock().unwrap();
        for c in inner.mapping.n_clusters()..index.meta().m() {
            let blocks = index.cluster_blocks(c as u32).to_vec();
            inner.mapping.add_cluster(blocks);
        }
    }

    /// Assemble the execution buffer for one query's zone selection.
    ///
    /// Synchronous part: read-only mapping lookup + the three-source copy.
    /// Asynchronous part: cache replacement + admission, submitted to the
    /// CPU pool (or run inline when `async_update` is off).
    pub fn assemble(
        &self,
        index: &WaveIndex,
        sel: &ZoneSelection,
        eb: &mut ExecBuffer,
    ) -> AccessStats {
        let d = self.d;
        let mut st = AccessStats::default();
        eb.clear();

        // Source 1: steady zone (GPU->GPU), pushed straight from the
        // index's sink/pending slices (no intermediate Vec).
        let (sk, sv) = index.sink_kv();
        let (pk, pv) = index.pend_kv();
        st.steady_tokens = (sk.len() + pk.len()) / d;
        st.g2g_bytes += 2 * (sk.len() + pk.len()) * 4;
        eb.push(sk, sv);
        eb.push(pk, pv);

        // Sources 2 & 3: retrieval-zone clusters via the mapping table.
        // Hit keys and miss payloads are captured into the reusable
        // update scratch — the paper's "copy from the execution buffer"
        // (blue arrow, Fig. 9). Shared (refcounted prefix) blocks admit
        // to the cross-session cache instead of this session's private
        // one.
        let mut scr;
        {
            let mut inner = self.inner.lock().unwrap();
            scr = std::mem::take(&mut inner.scr);
            let inner = &*inner;
            for &c in &sel.retrieval {
                let desc = inner.mapping.lookup(c);
                for (i, b) in desc.blocks.iter().enumerate() {
                    let nbytes = 2 * b.len as usize * d * 4;
                    let cached = match desc.home[i] {
                        BlockHome::Gpu(slot) if self.cfg.gpu_cache_enabled => Some(slot),
                        _ => None,
                    };
                    let is_shared = self.shared.is_some() && index.store().is_shared(*b);
                    if let Some(slot) = cached {
                        // GPU cache hit: copy slot -> exec buffer.
                        let data = inner.cache.slot_data(slot);
                        let half = self.tokens_per_block * d;
                        let n = b.len as usize * d;
                        eb.push(&data[..n], &data[half..half + n]);
                        st.hit_blocks += 1;
                        st.g2g_bytes += nbytes;
                        scr.hit_keys.push(b.block);
                    } else if is_shared
                        && self.cfg.gpu_cache_enabled
                        && self
                            .shared
                            .as_ref()
                            .unwrap()
                            .copy_into(b.block, b.len as usize * d, &mut eb.keys, &mut eb.vals)
                    {
                        // Cross-session hit: the prefix block is GPU-
                        // resident ONCE for every sharing session.
                        st.hit_blocks += 1;
                        st.shared_hit_blocks += 1;
                        st.g2g_bytes += nbytes;
                        scr.shared_hit_keys.push(b.block);
                    } else if let (Some(bk), Some(bv)) =
                        (index.store().try_block_keys(*b), index.store().try_block_vals(*b))
                    {
                        // Miss: PCIe fetch from the hot CPU block store.
                        eb.push(bk, bv);
                        st.miss_blocks += 1;
                        st.pcie_bytes += nbytes;
                        if self.cfg.gpu_cache_enabled && is_shared {
                            scr.missed_shared.push((b.block, bk.to_vec(), bv.to_vec()));
                        } else if self.cfg.gpu_cache_enabled {
                            let mut data = vec![0.0f32; 2 * self.tokens_per_block * d];
                            data[..bk.len()].copy_from_slice(bk);
                            let half = self.tokens_per_block * d;
                            data[half..half + bv.len()].copy_from_slice(bv);
                            scr.missed.push((b.block, data));
                        }
                    } else {
                        // Cold hit: the block is neither GPU-cached nor
                        // hot in CPU RAM. The data path reads through the
                        // spill tier (byte-identical to the hot path) —
                        // served from the pipelined staging area when an
                        // I/O-lane read already landed the page (overlap),
                        // a synchronous stall otherwise. Promote-then-fill
                        // is the engine's async job, and cold reads are
                        // never admitted to the GPU cache — admission
                        // copies come from hot blocks only.
                        let tier =
                            index.store().copy_block_kv_tiered(*b, &mut eb.keys, &mut eb.vals);
                        st.cold_blocks += 1;
                        if tier == crate::kvcache::KvReadTier::ColdStaged {
                            st.cold_staged_blocks += 1;
                        }
                        st.spill_bytes += nbytes;
                    }
                }
            }
        }

        self.stats.lookups.fetch_add(1, Ordering::Relaxed);
        self.stats.hit_blocks.fetch_add(st.hit_blocks as u64, Ordering::Relaxed);
        self.stats
            .shared_hit_blocks
            .fetch_add(st.shared_hit_blocks as u64, Ordering::Relaxed);
        self.stats.miss_blocks.fetch_add(st.miss_blocks as u64, Ordering::Relaxed);
        self.stats.cold_blocks.fetch_add(st.cold_blocks as u64, Ordering::Relaxed);
        self.stats
            .cold_staged_blocks
            .fetch_add(st.cold_staged_blocks as u64, Ordering::Relaxed);
        self.stats.g2g_bytes.fetch_add(st.g2g_bytes as u64, Ordering::Relaxed);
        self.stats.pcie_bytes.fetch_add(st.pcie_bytes as u64, Ordering::Relaxed);
        self.stats.spill_bytes.fetch_add(st.spill_bytes as u64, Ordering::Relaxed);

        // Cache update: policy touches for hits, admission for misses.
        // Shared prefix blocks go to the cross-session cache under its
        // own lock; the rest to this session's private cache. The update
        // returns the scratch to `inner` for the next assembly.
        if self.cfg.gpu_cache_enabled && !scr.is_empty() {
            if self.cfg.async_update {
                let inner = Arc::clone(&self.inner);
                let stats = Arc::clone(&self.stats);
                let shared = self.shared.clone();
                self.pool
                    .submit(move || apply_cache_update(&inner, &stats, shared.as_deref(), scr));
            } else {
                apply_cache_update(&self.inner, &self.stats, self.shared.as_deref(), scr);
            }
        } else {
            scr.clear();
            self.inner.lock().unwrap().scr = scr;
        }
        st
    }

    /// Register clusters appended by incremental index updates.
    pub fn sync_new_clusters(&self, index: &WaveIndex) {
        self.register_index(index);
    }

    /// Tier bookkeeping for a demotion: the blocks lose their GPU-cache
    /// copies (a cold block must not keep occupying GPU slots) and
    /// their mapping homes go `Cold` — both under one lock, so the
    /// mapping never claims a GPU residency the cache no longer holds.
    pub fn note_demoted(&self, blocks: &[crate::kvcache::BlockRef]) {
        let mut g = self.inner.lock().unwrap();
        for b in blocks {
            if g.cache.remove(b.block).is_some() {
                self.stats.evictions.fetch_add(1, Ordering::Relaxed);
            }
            g.mapping.set_cold(b.block);
        }
    }

    /// Tier bookkeeping for a promotion: cold homes return to hot CPU.
    pub fn note_promoted(&self, blocks: &[crate::kvcache::BlockRef]) {
        let mut g = self.inner.lock().unwrap();
        for b in blocks {
            g.mapping.set_hot(b.block);
        }
    }

    /// Blocks the mapping table currently marks cold.
    pub fn cold_marked_blocks(&self) -> usize {
        self.inner.lock().unwrap().mapping.cold_blocks()
    }

    /// Wait for all pending asynchronous cache updates.
    pub fn flush(&self) {
        self.pool.wait_idle();
    }

    pub fn stats(&self) -> &BufferStats {
        &self.stats
    }

    pub fn cfg(&self) -> &BufferConfig {
        &self.cfg
    }

    /// Blocks currently resident in the GPU cache.
    pub fn resident_blocks(&self) -> usize {
        self.inner.lock().unwrap().cache.len()
    }

    /// Consistency check: every GPU-marked block in the mapping table is
    /// resident in the cache with matching content length.
    pub fn check_consistency(&self) -> bool {
        let inner = self.inner.lock().unwrap();
        let resident = inner.mapping.gpu_resident_blocks();
        resident == inner.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CachePolicy, ZoneConfig};
    use crate::index::SelectScratch;
    use crate::util::rng::Rng;

    fn mk_index(n: usize, d: usize, seed: u64) -> WaveIndex {
        let cfg = ZoneConfig {
            steady_sink: 4,
            steady_local: 16,
            tokens_per_cluster: 8,
            build_segment: 128,
            update_segment: 32,
            kmeans_iters: 5,
            ..ZoneConfig::default()
        };
        let mut rng = Rng::new(seed);
        let k = rng.normal_vec(n * d);
        let v = rng.normal_vec(n * d);
        WaveIndex::build(cfg, d, 2048, &k, &v, seed)
    }

    fn mk_buffer(idx: &WaveIndex, cap: usize, async_update: bool) -> WaveBuffer {
        let cfg = BufferConfig {
            policy: CachePolicy::Lru,
            async_update,
            ..BufferConfig::default()
        };
        let pool = Arc::new(ThreadPool::new(2));
        let wb = WaveBuffer::new(cfg, idx.d(), idx.store().tokens_per_block(), cap, pool);
        wb.register_index(idx);
        wb
    }

    #[test]
    fn first_access_misses_second_hits() {
        let d = 16;
        let idx = mk_index(512, d, 1);
        let wb = mk_buffer(&idx, 64, false);
        let q = vec![0.7; d];
        let mut sc = SelectScratch::default();
        let sel = idx.select_with(&q, 4, 0, &mut sc);
        let mut eb = ExecBuffer::new(d);
        let s1 = wb.assemble(&idx, &sel, &mut eb);
        assert!(s1.miss_blocks > 0);
        assert_eq!(s1.hit_blocks, 0);
        let s2 = wb.assemble(&idx, &sel, &mut eb);
        assert_eq!(s2.miss_blocks, 0, "all blocks must now be cached");
        assert_eq!(s2.hit_blocks, s1.miss_blocks);
        assert_eq!(s2.pcie_bytes, 0);
    }

    #[test]
    fn exec_buffer_content_matches_direct_gather() {
        // Assembly through the buffer (hit or miss) must produce the same
        // bytes as gathering straight from the store.
        let d = 16;
        let idx = mk_index(512, d, 2);
        let wb = mk_buffer(&idx, 32, false);
        let q = vec![-0.2; d];
        let mut sc = SelectScratch::default();
        let sel = idx.select_with(&q, 6, 0, &mut sc);
        let mut eb1 = ExecBuffer::new(d);
        wb.assemble(&idx, &sel, &mut eb1); // all misses
        let k1 = eb1.keys.clone();
        let mut eb2 = ExecBuffer::new(d);
        wb.assemble(&idx, &sel, &mut eb2); // all hits
        assert_eq!(k1, eb2.keys, "hit path must serve identical data");
        assert_eq!(eb1.vals, eb2.vals);
    }

    #[test]
    fn disabled_cache_always_misses() {
        let d = 16;
        let idx = mk_index(256, d, 3);
        let cfg = BufferConfig { gpu_cache_enabled: false, ..BufferConfig::default() };
        let pool = Arc::new(ThreadPool::new(1));
        let wb = WaveBuffer::new(cfg, d, idx.store().tokens_per_block(), 64, pool);
        wb.register_index(&idx);
        let q = vec![0.5; d];
        let mut sc = SelectScratch::default();
        let sel = idx.select_with(&q, 4, 0, &mut sc);
        let mut eb = ExecBuffer::new(d);
        for _ in 0..3 {
            let s = wb.assemble(&idx, &sel, &mut eb);
            assert_eq!(s.hit_blocks, 0);
            assert!(s.miss_blocks > 0);
        }
        assert_eq!(wb.resident_blocks(), 0);
    }

    #[test]
    fn async_update_converges_and_stays_consistent() {
        let d = 16;
        let idx = mk_index(512, d, 4);
        let wb = mk_buffer(&idx, 16, true);
        let mut rng = Rng::new(9);
        let mut sc = SelectScratch::default();
        let mut eb = ExecBuffer::new(d);
        for _ in 0..50 {
            let q = rng.normal_vec(d);
            let sel = idx.select_with(&q, 3, 0, &mut sc);
            wb.assemble(&idx, &sel, &mut eb);
        }
        wb.flush();
        assert!(wb.check_consistency());
        assert!(wb.resident_blocks() <= 16);
        assert!(wb.stats().async_updates.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn temporal_locality_yields_high_hit_ratio() {
        // Repeatedly querying nearby directions: hit ratio must be high
        // (the paper's 0.79-0.94 observation at 5% cache).
        let d = 16;
        let idx = mk_index(1024, d, 5);
        let cap = WaveBuffer::capacity_for(&BufferConfig::default(), 1024, idx.store().tokens_per_block()).max(8);
        let wb = mk_buffer(&idx, cap, false);
        let mut rng = Rng::new(11);
        let base = rng.normal_vec(d);
        let mut sc = SelectScratch::default();
        let mut eb = ExecBuffer::new(d);
        for _ in 0..40 {
            let q: Vec<f32> =
                base.iter().map(|x| x + 0.05 * rng.normal_f32()).collect();
            let sel = idx.select_with(&q, 3, 0, &mut sc);
            wb.assemble(&idx, &sel, &mut eb);
        }
        assert!(
            wb.stats().hit_ratio() > 0.7,
            "locality hit ratio = {}",
            wb.stats().hit_ratio()
        );
    }

    #[test]
    fn cold_blocks_serve_identical_bytes_through_the_spill_tier() {
        let d = 16;
        let mut idx = mk_index(512, d, 7);
        let wb = mk_buffer(&idx, 64, false);
        let q = vec![0.4; d];
        let mut sc = SelectScratch::default();
        let sel = idx.select_with(&q, 4, 0, &mut sc);
        let mut eb_hot = ExecBuffer::new(d);
        wb.assemble(&idx, &sel, &mut eb_hot); // all misses; admits copies
        // demote every retrieved cluster; GPU copies must go with them
        for &c in &sel.retrieval {
            assert!(idx.demote_cluster(c) > 0);
            wb.note_demoted(idx.cluster_blocks(c));
        }
        assert!(wb.check_consistency());
        assert!(wb.cold_marked_blocks() > 0);
        let mut eb_cold = ExecBuffer::new(d);
        let st = wb.assemble(&idx, &sel, &mut eb_cold);
        assert!(st.cold_blocks > 0, "demoted blocks must be cold-hit stalls");
        assert_eq!(st.miss_blocks, 0);
        assert_eq!(st.hit_blocks, 0);
        assert!(st.spill_bytes > 0);
        // the cold data path is byte-identical to the hot one
        assert_eq!(eb_hot.keys, eb_cold.keys);
        assert_eq!(eb_hot.vals, eb_cold.vals);
        // promotion restores the hot fetch + admission path
        for &c in &sel.retrieval {
            let (n, _, err) = idx.promote_cluster(c);
            assert!(err.is_none(), "uncapped promote must not fail");
            assert!(n > 0);
            wb.note_promoted(idx.cluster_blocks(c));
        }
        assert_eq!(wb.cold_marked_blocks(), 0);
        let mut eb_back = ExecBuffer::new(d);
        let st = wb.assemble(&idx, &sel, &mut eb_back);
        assert_eq!(st.cold_blocks, 0);
        assert!(st.miss_blocks > 0, "promoted blocks fetch hot again");
        assert_eq!(eb_back.keys, eb_hot.keys);
    }

    #[test]
    fn shared_prefix_cache_dedups_across_buffers() {
        use crate::kvcache::BlockArena;
        let d = 16;
        let zcfg = ZoneConfig {
            steady_sink: 4,
            steady_local: 16,
            tokens_per_cluster: 8,
            build_segment: 128,
            update_segment: 32,
            kmeans_iters: 5,
            ..ZoneConfig::default()
        };
        let mut rng = Rng::new(21);
        let k = rng.normal_vec(512 * d);
        let v = rng.normal_vec(512 * d);
        let arena = BlockArena::shared(d, 2048);
        let mut idx_a =
            WaveIndex::try_build_in_for(&arena, 0, zcfg.clone(), &k, &v, 9).unwrap();
        let covered = idx_a.clustered_prefix_tokens();
        let sealed = idx_a.seal_prefix(covered);
        // pin like the registry would, so the prefix outlives any session
        for c in &sealed.clusters {
            for b in &c.blocks {
                assert!(arena.pin_shared(b.id));
            }
        }
        let idx_b =
            WaveIndex::try_build_grafted_in_for(&arena, 1, zcfg.clone(), &sealed, covered, &k, &v, 9)
                .unwrap();
        assert!(idx_b.n_shared_blocks() > 0);
        let tpb = idx_a.store().tokens_per_block();
        let sc = SharedBlockCache::shared(CachePolicy::Lru, 64, 2 * tpb * d);
        let mk_buf = |idx: &WaveIndex| {
            let bcfg = BufferConfig {
                policy: CachePolicy::Lru,
                async_update: false,
                ..BufferConfig::default()
            };
            let pool = Arc::new(ThreadPool::new(1));
            let mut wb = WaveBuffer::new(bcfg, d, tpb, 64, pool);
            wb.set_shared_cache(Arc::clone(&sc));
            wb.register_index(idx);
            wb
        };
        let wb_a = mk_buf(&idx_a);
        let wb_b = mk_buf(&idx_b);
        let q = vec![0.3; d];
        let mut scr = SelectScratch::default();
        let sel_a = idx_a.select_with(&q, 4, 0, &mut scr);
        let mut eb_a = ExecBuffer::new(d);
        let s1 = wb_a.assemble(&idx_a, &sel_a, &mut eb_a);
        assert!(s1.miss_blocks > 0);
        assert_eq!(s1.hit_blocks, 0);
        // session B retrieves the same clusters (identical grafted meta):
        // served from the ONE shared GPU copy session A's miss admitted
        let sel_b = idx_b.select_with(&q, 4, 0, &mut scr);
        assert_eq!(sel_a.retrieval, sel_b.retrieval, "grafted meta must select identically");
        let mut eb_b = ExecBuffer::new(d);
        let s2 = wb_b.assemble(&idx_b, &sel_b, &mut eb_b);
        assert_eq!(s2.miss_blocks, 0, "cross-session cache must serve B's blocks");
        assert!(s2.shared_hit_blocks > 0);
        assert_eq!(s2.hit_blocks, s2.shared_hit_blocks);
        assert_eq!(eb_a.keys, eb_b.keys, "shared-cache path serves identical bytes");
        assert_eq!(eb_a.vals, eb_b.vals);
        // shared blocks never enter the per-session private caches —
        // the prefix occupies one GPU slot set, not one per session
        assert_eq!(wb_a.resident_blocks(), 0);
        assert_eq!(wb_b.resident_blocks(), 0);
        assert_eq!(sc.resident_blocks(), s1.miss_blocks);
        assert_eq!(sc.hit_count(), s2.shared_hit_blocks as u64);
        drop(idx_b);
        drop(idx_a);
        for c in &sealed.clusters {
            for b in &c.blocks {
                arena.unpin_shared(b.id);
            }
        }
        assert_eq!(arena.live_blocks(), 0, "prefix storage frees at refcount zero");
    }

    #[test]
    fn eviction_keeps_mapping_consistent() {
        let d = 16;
        let idx = mk_index(1024, d, 6);
        let wb = mk_buffer(&idx, 4, false); // tiny cache forces evictions
        let mut rng = Rng::new(13);
        let mut sc = SelectScratch::default();
        let mut eb = ExecBuffer::new(d);
        for _ in 0..30 {
            let q = rng.normal_vec(d);
            let sel = idx.select_with(&q, 5, 0, &mut sc);
            wb.assemble(&idx, &sel, &mut eb);
        }
        assert!(wb.stats().evictions.load(Ordering::Relaxed) > 0);
        assert!(wb.check_consistency());
        assert!(wb.resident_blocks() <= 4);
    }
}
