//! Cold-tier spill store: the file/mmap-simulated page store behind the
//! tiered [`BlockArena`](super::BlockArena) (DESIGN.md §2 "Tiered arena
//! & spill"). The paper's wave buffer exists because the KV cache
//! outgrows the fast tier (HBM) and must live in a slower one (DRAM)
//! behind an asynchronous transfer path (§4.3); this module reproduces
//! that hierarchy one level down — hot RAM tier ↔ cold spill tier — the
//! way InfiniGen's offload+prefetch pipeline does for HBM↔DRAM.
//!
//! Pages are keyed by the same engine-global block ids the hot tier
//! uses, so mapping tables and block caches never re-key when a block
//! changes tier. Serialization is little-endian per element and
//! round-trips every f32 bit pattern exactly (`tests/spill.rs` asserts
//! demote→promote bit-identity), which is what lets a tiered replay
//! emit tokens bit-identical to a single-tier run.
//!
//! Concurrency: all state sits behind internal locks, so spilled pages
//! can be written, staged (async prefetch) and read from `&self` — the
//! engine submits `stage` jobs to its [`ThreadPool`]
//! (`crate::util::threadpool::ThreadPool`) so promotion overlaps decode
//! the way the wave buffer overlaps PCIe with GPU compute. Lock order
//! is always file → staging; the two are never taken in the other
//! order.

use super::arena::BlockData;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// The simulated page file: a flat byte heap carved into fixed-size
/// pages (the mmap stand-in), an id → page index, and a free page list.
struct SpillFile {
    data: Vec<u8>,
    index: HashMap<u64, u32>,
    free: Vec<u32>,
}

/// Cold-tier block store keyed by engine-global block ids.
pub struct SpillStore {
    d: usize,
    tpb: usize,
    /// Serialized bytes of one page: K + V halves as f32 LE, positions
    /// as u32 LE.
    page_bytes: usize,
    file: Mutex<SpillFile>,
    /// Async-prefetch staging area: pages read ahead of promotion by
    /// pool jobs, consumed (without a second file read) when the block
    /// is promoted.
    staged: Mutex<HashMap<u64, BlockData>>,
    writes_total: AtomicU64,
    reads_total: AtomicU64,
    dropped_total: AtomicU64,
    staged_total: AtomicU64,
    staged_hits: AtomicU64,
}

impl SpillStore {
    pub fn new(d: usize, tpb: usize) -> SpillStore {
        SpillStore {
            d,
            tpb,
            page_bytes: 2 * tpb * d * 4 + tpb * 4,
            file: Mutex::new(SpillFile {
                data: Vec::new(),
                index: HashMap::new(),
                free: Vec::new(),
            }),
            staged: Mutex::new(HashMap::new()),
            writes_total: AtomicU64::new(0),
            reads_total: AtomicU64::new(0),
            dropped_total: AtomicU64::new(0),
            staged_total: AtomicU64::new(0),
            staged_hits: AtomicU64::new(0),
        }
    }

    /// Serialized size of one cold page in bytes.
    pub fn page_bytes(&self) -> usize {
        self.page_bytes
    }

    fn serialize_into(&self, data: &BlockData, out: &mut [u8]) {
        debug_assert_eq!(out.len(), self.page_bytes);
        let mut off = 0;
        for x in data.keys.iter().chain(data.vals.iter()) {
            out[off..off + 4].copy_from_slice(&x.to_le_bytes());
            off += 4;
        }
        for p in &data.pos {
            out[off..off + 4].copy_from_slice(&p.to_le_bytes());
            off += 4;
        }
    }

    fn deserialize_into(&self, page: &[u8], out: &mut BlockData) {
        debug_assert_eq!(page.len(), self.page_bytes);
        debug_assert_eq!(out.keys.len(), self.tpb * self.d);
        let half = self.tpb * self.d;
        let mut off = 0;
        for i in 0..half {
            out.keys[i] = f32::from_le_bytes(page[off..off + 4].try_into().unwrap());
            off += 4;
        }
        for i in 0..half {
            out.vals[i] = f32::from_le_bytes(page[off..off + 4].try_into().unwrap());
            off += 4;
        }
        for i in 0..self.tpb {
            out.pos[i] = u32::from_le_bytes(page[off..off + 4].try_into().unwrap());
            off += 4;
        }
    }

    /// Write (demote) one block's data into a cold page. Panics if the
    /// id is already cold — a block must never be in two tiers.
    pub fn write(&self, id: u64, data: &BlockData) {
        let mut f = self.file.lock().unwrap();
        assert!(!f.index.contains_key(&id), "block {id} already in the cold tier");
        let page = match f.free.pop() {
            Some(p) => p,
            None => {
                let p = (f.data.len() / self.page_bytes) as u32;
                f.data.resize(f.data.len() + self.page_bytes, 0);
                p
            }
        };
        let start = page as usize * self.page_bytes;
        let pb = self.page_bytes;
        // split the borrow: serialize into the page slice in place
        let slice = &mut f.data[start..start + pb];
        self.serialize_into(data, slice);
        f.index.insert(id, page);
        self.writes_total.fetch_add(1, Ordering::Relaxed);
    }

    /// Whether `id` currently lives in the cold tier.
    pub fn contains(&self, id: u64) -> bool {
        self.file.lock().unwrap().index.contains_key(&id)
    }

    /// Copy a cold page into `out` without changing residency (the
    /// synchronous cold-read path of a GPU-cache miss on a cold block).
    /// Returns false if `id` is not cold.
    pub fn peek_into(&self, id: u64, out: &mut BlockData) -> bool {
        let f = self.file.lock().unwrap();
        let Some(&page) = f.index.get(&id) else {
            return false;
        };
        let start = page as usize * self.page_bytes;
        self.deserialize_into(&f.data[start..start + self.page_bytes], out);
        self.reads_total.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Append the first `n_elems` key and value f32s of a cold page
    /// directly to `k_out` / `v_out` (no intermediate allocation — the
    /// cold-read data path of execution-buffer assembly). Residency is
    /// unchanged. Returns false if `id` is not cold.
    pub fn peek_kv_into(
        &self,
        id: u64,
        n_elems: usize,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
    ) -> bool {
        let f = self.file.lock().unwrap();
        let Some(&page) = f.index.get(&id) else {
            return false;
        };
        let half = self.tpb * self.d;
        debug_assert!(n_elems <= half);
        let start = page as usize * self.page_bytes;
        k_out.reserve(n_elems);
        v_out.reserve(n_elems);
        for i in 0..n_elems {
            let off = start + 4 * i;
            k_out.push(f32::from_le_bytes(f.data[off..off + 4].try_into().unwrap()));
        }
        let vstart = start + 4 * half;
        for i in 0..n_elems {
            let off = vstart + 4 * i;
            v_out.push(f32::from_le_bytes(f.data[off..off + 4].try_into().unwrap()));
        }
        self.reads_total.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Async-prefetch one page into the staging area (no residency
    /// change; the matching [`SpillStore::take_into`] consumes it).
    /// Returns false if `id` is not cold — a block promoted or dropped
    /// while the prefetch job was queued is simply skipped.
    pub fn stage(&self, id: u64) -> bool {
        let f = self.file.lock().unwrap();
        let Some(&page) = f.index.get(&id) else {
            return false;
        };
        let mut data = BlockData::zeroed(self.tpb, self.d);
        let start = page as usize * self.page_bytes;
        self.deserialize_into(&f.data[start..start + self.page_bytes], &mut data);
        self.reads_total.fetch_add(1, Ordering::Relaxed);
        self.staged_total.fetch_add(1, Ordering::Relaxed);
        // lock order: file → staged (held file lock keeps the page from
        // being promoted/dropped between the read and the insert)
        self.staged.lock().unwrap().insert(id, data);
        true
    }

    /// Take (promote) a cold page out of the store into `out`. Serves
    /// from the staging area when an async prefetch already read the
    /// page (returns `Some(true)` — the overlap win), from the file
    /// otherwise (`Some(false)` — a cold-hit stall). `None` if the id
    /// is not cold.
    pub fn take_into(&self, id: u64, out: &mut BlockData) -> Option<bool> {
        let mut f = self.file.lock().unwrap();
        let page = f.index.remove(&id)?;
        f.free.push(page);
        let staged = self.staged.lock().unwrap().remove(&id);
        match staged {
            Some(data) => {
                out.keys.copy_from_slice(&data.keys);
                out.vals.copy_from_slice(&data.vals);
                out.pos.copy_from_slice(&data.pos);
                self.staged_hits.fetch_add(1, Ordering::Relaxed);
                Some(true)
            }
            None => {
                let start = page as usize * self.page_bytes;
                self.deserialize_into(&f.data[start..start + self.page_bytes], out);
                self.reads_total.fetch_add(1, Ordering::Relaxed);
                Some(false)
            }
        }
    }

    /// Drop a cold block outright (finished-session reclamation: cold
    /// blocks die in place, never promoted first). Returns false if the
    /// id is not cold.
    pub fn drop_block(&self, id: u64) -> bool {
        let mut f = self.file.lock().unwrap();
        let Some(page) = f.index.remove(&id) else {
            return false;
        };
        f.free.push(page);
        self.staged.lock().unwrap().remove(&id);
        self.dropped_total.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Blocks currently resident in the cold tier.
    pub fn cold_blocks(&self) -> usize {
        self.file.lock().unwrap().index.len()
    }

    /// Bytes of cold pages currently holding blocks.
    pub fn cold_bytes(&self) -> usize {
        self.cold_blocks() * self.page_bytes
    }

    /// Total bytes of the backing "file" (live + recycled pages — the
    /// spill tier's resident footprint).
    pub fn file_bytes(&self) -> usize {
        self.file.lock().unwrap().data.len()
    }

    /// Pages currently staged by async prefetch.
    pub fn staged_blocks(&self) -> usize {
        self.staged.lock().unwrap().len()
    }

    pub fn writes_total(&self) -> u64 {
        self.writes_total.load(Ordering::Relaxed)
    }

    pub fn reads_total(&self) -> u64 {
        self.reads_total.load(Ordering::Relaxed)
    }

    pub fn dropped_total(&self) -> u64 {
        self.dropped_total.load(Ordering::Relaxed)
    }

    pub fn staged_hits(&self) -> u64 {
        self.staged_hits.load(Ordering::Relaxed)
    }
}

/// One cluster's spill-relevant metadata, fed to a [`SpillPolicy`] by
/// `WaveIndex::demote_until` (the wave index owns the access epochs the
/// policy ranks by).
#[derive(Clone, Copy, Debug)]
pub struct SpillCandidate {
    pub cluster: u32,
    /// Selection epoch the cluster was last retrieved at (0 = never).
    pub last_access: u64,
    /// Hot blocks the cluster currently holds (what demotion frees).
    pub hot_blocks: usize,
}

/// Pluggable victim ordering for demotion. Implementations sort the
/// candidate list demote-first; callers demote from the front until
/// enough hot blocks are free.
pub trait SpillPolicy: Send + Sync {
    fn order(&self, candidates: &mut [SpillCandidate]);
    fn name(&self) -> &'static str;
}

/// Default policy: demote the least-recently-selected clusters first
/// (ties broken by cluster id for determinism). Mirrors the wave
/// buffer's LRU default one tier down.
pub struct ColdestFirst;

impl SpillPolicy for ColdestFirst {
    fn order(&self, candidates: &mut [SpillCandidate]) {
        candidates.sort_by_key(|c| (c.last_access, c.cluster));
    }

    fn name(&self) -> &'static str {
        "coldest-first"
    }
}

/// Alternative policy: among cold clusters, demote the largest first so
/// the fewest clusters lose hot residency (fewer, bigger writebacks).
pub struct LargestColdFirst;

impl SpillPolicy for LargestColdFirst {
    fn order(&self, candidates: &mut [SpillCandidate]) {
        candidates.sort_by_key(|c| (c.last_access, std::cmp::Reverse(c.hot_blocks), c.cluster));
    }

    fn name(&self) -> &'static str {
        "largest-cold-first"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(tpb: usize, d: usize, seed: u32) -> BlockData {
        let mut b = BlockData::zeroed(tpb, d);
        for (i, x) in b.keys.iter_mut().enumerate() {
            *x = f32::from_bits(seed.wrapping_mul(31).wrapping_add(i as u32));
        }
        for (i, x) in b.vals.iter_mut().enumerate() {
            *x = f32::from_bits(seed.wrapping_mul(37).wrapping_add(i as u32) | 1);
        }
        for (i, p) in b.pos.iter_mut().enumerate() {
            *p = seed.wrapping_add(i as u32);
        }
        b
    }

    fn bits(b: &BlockData) -> (Vec<u32>, Vec<u32>, Vec<u32>) {
        (
            b.keys.iter().map(|x| x.to_bits()).collect(),
            b.vals.iter().map(|x| x.to_bits()).collect(),
            b.pos.clone(),
        )
    }

    #[test]
    fn write_take_roundtrip_is_bit_exact() {
        let s = SpillStore::new(8, 4);
        // includes NaN/denormal bit patterns via from_bits
        let b = filled(4, 8, 0x7fc0_0001);
        let want = bits(&b);
        s.write(9, &b);
        assert!(s.contains(9));
        assert_eq!(s.cold_blocks(), 1);
        assert_eq!(s.cold_bytes(), s.page_bytes());
        let mut out = BlockData::zeroed(4, 8);
        assert_eq!(s.take_into(9, &mut out), Some(false));
        assert_eq!(bits(&out), want);
        assert_eq!(s.cold_blocks(), 0);
        assert!(s.take_into(9, &mut out).is_none());
    }

    #[test]
    fn staged_pages_serve_promotion_without_a_second_read() {
        let s = SpillStore::new(4, 4);
        let b = filled(4, 4, 7);
        let want = bits(&b);
        s.write(1, &b);
        assert!(s.stage(1));
        assert_eq!(s.staged_blocks(), 1);
        let reads_before = s.reads_total();
        let mut out = BlockData::zeroed(4, 4);
        assert_eq!(s.take_into(1, &mut out), Some(true));
        assert_eq!(bits(&out), want);
        assert_eq!(s.reads_total(), reads_before, "staged take must not re-read the file");
        assert_eq!(s.staged_hits(), 1);
        assert_eq!(s.staged_blocks(), 0);
        // staging a block that is no longer cold is a no-op
        assert!(!s.stage(1));
    }

    #[test]
    fn pages_recycle_and_peek_does_not_change_residency() {
        let s = SpillStore::new(4, 4);
        s.write(1, &filled(4, 4, 1));
        s.write(2, &filled(4, 4, 2));
        let file_before = s.file_bytes();
        let mut out = BlockData::zeroed(4, 4);
        assert!(s.peek_into(1, &mut out));
        assert_eq!(s.cold_blocks(), 2, "peek must not evict");
        // direct kv-prefix read matches the full-page deserialization
        let b2 = filled(4, 4, 2);
        let (mut k, mut v) = (Vec::new(), Vec::new());
        assert!(s.peek_kv_into(2, 10, &mut k, &mut v));
        assert_eq!(
            k.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b2.keys[..10].iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b2.vals[..10].iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert!(!s.peek_kv_into(99, 1, &mut k, &mut v));
        assert!(s.drop_block(1));
        assert!(!s.drop_block(1));
        // a new write reuses the freed page: the file does not grow
        s.write(3, &filled(4, 4, 3));
        assert_eq!(s.file_bytes(), file_before);
        assert_eq!(s.cold_blocks(), 2);
        assert_eq!(s.dropped_total(), 1);
    }

    #[test]
    #[should_panic(expected = "already in the cold tier")]
    fn double_demote_panics() {
        let s = SpillStore::new(4, 4);
        s.write(5, &filled(4, 4, 5));
        s.write(5, &filled(4, 4, 6));
    }

    #[test]
    fn policies_order_victims() {
        let mk = |cluster, last_access, hot_blocks| SpillCandidate {
            cluster,
            last_access,
            hot_blocks,
        };
        let base = vec![mk(0, 5, 2), mk(1, 1, 1), mk(2, 1, 4), mk(3, 9, 8)];
        let mut c = base.clone();
        ColdestFirst.order(&mut c);
        assert_eq!(c.iter().map(|x| x.cluster).collect::<Vec<_>>(), vec![1, 2, 0, 3]);
        let mut c = base.clone();
        LargestColdFirst.order(&mut c);
        assert_eq!(c.iter().map(|x| x.cluster).collect::<Vec<_>>(), vec![2, 1, 0, 3]);
        assert_eq!(ColdestFirst.name(), "coldest-first");
        assert_eq!(LargestColdFirst.name(), "largest-cold-first");
    }
}
