//! Cold-tier spill store: the file/mmap-simulated page store behind the
//! tiered [`BlockArena`](super::BlockArena) (DESIGN.md §2 "Tiered arena
//! & spill"). The paper's wave buffer exists because the KV cache
//! outgrows the fast tier (HBM) and must live in a slower one (DRAM)
//! behind an asynchronous transfer path (§4.3); this module reproduces
//! that hierarchy one level down — hot RAM tier ↔ cold spill tier — the
//! way InfiniGen's offload+prefetch pipeline does for HBM↔DRAM.
//!
//! Pages are keyed by the same engine-global block ids the hot tier
//! uses, so mapping tables and block caches never re-key when a block
//! changes tier. Every page carries a little-endian header (codec tag +
//! physical payload length), and the payload is produced by a pluggable
//! [`PageCodec`] (DESIGN.md §2 "Spill codecs"):
//!
//! - [`CodecTag::Exact`] (the default) serializes f32/u32 LE per element
//!   and round-trips every f32 bit pattern exactly (`tests/spill.rs`
//!   asserts demote→promote bit-identity), which is what lets a tiered
//!   replay emit tokens bit-identical to a single-tier run.
//! - [`CodecTag::Int8Angle`] / [`CodecTag::Int4Angle`] quantize in the
//!   angle domain: each K/V vector keeps its norm as an exact f32 and
//!   quantizes only the direction, group-wise with a per-group
//!   scale/zero-point (SPHERICAL-KV-style rate allocation: magnitudes
//!   dominate attention logits, so they stay exact).
//! - [`CodecTag::LowRankK`] projects only the K half onto a fixed
//!   orthonormal rank-`d/2` basis (low-rank K-projection); V and
//!   positions stay exact.
//!
//! Lossy codecs are only ever applied when the caller passes
//! `lossy_ok = true` ([`SpillStore::write_with`]) — the wave index's
//! estimation head makes that call per cluster, and sink/steady-local
//! tokens are always stored exact. Decoding dispatches on the per-page
//! tag, so a store holding a mix of codecs round-trips every page
//! through the same `peek`/`stage`/`take` paths.
//!
//! Concurrency: all state sits behind internal locks, so spilled pages
//! can be written, staged (async prefetch) and read from `&self` — the
//! engine submits `stage` jobs to its [`ThreadPool`]
//! (`crate::util::threadpool::ThreadPool`) so promotion overlaps decode
//! the way the wave buffer overlaps PCIe with GPU compute. Lock order
//! is always file → staging; the two are never taken in the other
//! order.

use super::arena::BlockData;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Bytes of the per-page LE header: `[tag u8][reserved u8][tokens u16]
/// [payload_len u32]`.
pub const PAGE_HEADER_BYTES: usize = 8;

/// Quantization group width (elements sharing one scale/zero-point).
const ANGLE_GROUP: usize = 16;

/// Per-page codec identifier, stored in the page header so mixed-codec
/// stores round-trip (the write-time codec choice never needs to be
/// remembered anywhere else).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum CodecTag {
    /// Bit-exact LE passthrough (the default; lossless).
    Exact = 0,
    /// Group-wise int8 direction quantization, exact per-vector norms.
    Int8Angle = 1,
    /// Group-wise int4 direction quantization, exact per-vector norms.
    Int4Angle = 2,
    /// K projected onto a fixed orthonormal rank-d/2 basis; V/pos exact.
    LowRankK = 3,
}

impl CodecTag {
    pub fn from_u8(t: u8) -> Option<CodecTag> {
        match t {
            0 => Some(CodecTag::Exact),
            1 => Some(CodecTag::Int8Angle),
            2 => Some(CodecTag::Int4Angle),
            3 => Some(CodecTag::LowRankK),
            _ => None,
        }
    }

    pub fn is_lossy(self) -> bool {
        self != CodecTag::Exact
    }
}

/// A per-page spill codec. Implementations are stateless statics
/// (dispatched by [`codec_for`]); geometry comes in per call so one
/// instance serves every store.
pub trait PageCodec: Send + Sync {
    fn tag(&self) -> CodecTag;
    fn name(&self) -> &'static str;
    /// Worst-case payload bytes for a `(tpb, d)` page. A codec whose
    /// worst case exceeds the exact payload is skipped (the store falls
    /// back to `Exact`) so compressed payloads always fit their page.
    fn max_payload_bytes(&self, tpb: usize, d: usize) -> usize;
    /// Encode a full block into `out`; returns the payload length.
    fn encode(&self, data: &BlockData, tpb: usize, d: usize, out: &mut [u8]) -> usize;
    /// Decode a payload produced by `encode` back into a full block.
    fn decode(&self, payload: &[u8], tpb: usize, d: usize, out: &mut BlockData);
}

/// Uncompressed payload bytes of one `(tpb, d)` page: K + V halves as
/// f32 LE plus positions as u32 LE. This is the page's *logical* size
/// regardless of which codec wrote it.
pub fn raw_payload_bytes(tpb: usize, d: usize) -> usize {
    2 * tpb * d * 4 + tpb * 4
}

// ---------------------------------------------------------------------
// Exact passthrough
// ---------------------------------------------------------------------

/// Bit-exact LE serialization (the PR 3 page format, now as a codec).
pub struct ExactCodec;

impl PageCodec for ExactCodec {
    fn tag(&self) -> CodecTag {
        CodecTag::Exact
    }

    fn name(&self) -> &'static str {
        "exact"
    }

    fn max_payload_bytes(&self, tpb: usize, d: usize) -> usize {
        raw_payload_bytes(tpb, d)
    }

    fn encode(&self, data: &BlockData, tpb: usize, d: usize, out: &mut [u8]) -> usize {
        let len = raw_payload_bytes(tpb, d);
        debug_assert!(out.len() >= len);
        let mut off = 0;
        for x in data.keys.iter().chain(data.vals.iter()) {
            out[off..off + 4].copy_from_slice(&x.to_le_bytes());
            off += 4;
        }
        for p in &data.pos {
            out[off..off + 4].copy_from_slice(&p.to_le_bytes());
            off += 4;
        }
        len
    }

    fn decode(&self, payload: &[u8], tpb: usize, d: usize, out: &mut BlockData) {
        let half = tpb * d;
        debug_assert_eq!(payload.len(), raw_payload_bytes(tpb, d));
        let mut off = 0;
        for i in 0..half {
            out.keys[i] = f32::from_le_bytes(payload[off..off + 4].try_into().unwrap());
            off += 4;
        }
        for i in 0..half {
            out.vals[i] = f32::from_le_bytes(payload[off..off + 4].try_into().unwrap());
            off += 4;
        }
        for i in 0..tpb {
            out.pos[i] = u32::from_le_bytes(payload[off..off + 4].try_into().unwrap());
            off += 4;
        }
    }
}

// ---------------------------------------------------------------------
// Angle-domain group quantizers (int8 / int4)
// ---------------------------------------------------------------------

fn angle_groups(d: usize) -> usize {
    d.div_ceil(ANGLE_GROUP)
}

/// Encoded bytes of one angle-quantized vector: exact norm (f32) +
/// per-group (zero-point f32, scale f32) + `code_bytes` of codes.
fn angle_vec_bytes(d: usize, code_bytes: usize) -> usize {
    4 + 8 * angle_groups(d) + code_bytes
}

/// Quantize one vector's direction group-wise at `levels` quantization
/// steps, appending `[norm][lo, scale]*groups` then the raw (unpacked)
/// codes to `codes`. The norm is stored exact; only the unit direction
/// is quantized (angle-domain: attention logits scale with the norm, so
/// it gets full precision).
fn encode_angle_vec(x: &[f32], levels: u32, header: &mut Vec<u8>, codes: &mut Vec<u8>) {
    let norm = x.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt() as f32;
    header.extend_from_slice(&norm.to_le_bytes());
    let inv = if norm.is_finite() && norm > 0.0 { 1.0 / norm } else { 0.0 };
    for g in x.chunks(ANGLE_GROUP) {
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for v in g {
            let u = v * inv;
            let u = if u.is_finite() { u } else { 0.0 };
            lo = lo.min(u);
            hi = hi.max(u);
        }
        if !lo.is_finite() || !hi.is_finite() {
            lo = 0.0;
            hi = 0.0;
        }
        let scale = if hi > lo { (hi - lo) / (levels - 1) as f32 } else { 0.0 };
        header.extend_from_slice(&lo.to_le_bytes());
        header.extend_from_slice(&scale.to_le_bytes());
        for v in g {
            let u = v * inv;
            let u = if u.is_finite() { u } else { 0.0 };
            let q = if scale > 0.0 {
                (((u - lo) / scale).round() as i64).clamp(0, (levels - 1) as i64) as u8
            } else {
                0
            };
            codes.push(q);
        }
    }
}

/// Inverse of [`encode_angle_vec`] given the unpacked codes.
fn decode_angle_vec(norm: f32, groups: &[u8], codes: &[u8], out: &mut [f32]) {
    for (gi, g) in out.chunks_mut(ANGLE_GROUP).enumerate() {
        let lo = f32::from_le_bytes(groups[gi * 8..gi * 8 + 4].try_into().unwrap());
        let scale = f32::from_le_bytes(groups[gi * 8 + 4..gi * 8 + 8].try_into().unwrap());
        for (j, v) in g.iter_mut().enumerate() {
            let q = codes[gi * ANGLE_GROUP + j] as f32;
            *v = norm * (lo + q * scale);
        }
    }
}

fn angle_encode_page(
    data: &BlockData,
    tpb: usize,
    d: usize,
    levels: u32,
    pack4: bool,
    out: &mut [u8],
) -> usize {
    let mut buf: Vec<u8> = Vec::with_capacity(out.len());
    let mut codes: Vec<u8> = Vec::with_capacity(d);
    for half in [&data.keys, &data.vals] {
        for t in 0..tpb {
            codes.clear();
            encode_angle_vec(&half[t * d..(t + 1) * d], levels, &mut buf, &mut codes);
            if pack4 {
                for pair in codes.chunks(2) {
                    let hi = pair.get(1).copied().unwrap_or(0);
                    buf.push((pair[0] & 0x0f) | (hi << 4));
                }
            } else {
                buf.extend_from_slice(&codes);
            }
        }
    }
    for p in &data.pos {
        buf.extend_from_slice(&p.to_le_bytes());
    }
    out[..buf.len()].copy_from_slice(&buf);
    buf.len()
}

fn angle_decode_page(
    payload: &[u8],
    tpb: usize,
    d: usize,
    pack4: bool,
    out: &mut BlockData,
) {
    let groups = angle_groups(d);
    let code_bytes = if pack4 { d.div_ceil(2) } else { d };
    let vec_bytes = angle_vec_bytes(d, code_bytes);
    let mut codes: Vec<u8> = vec![0; groups * ANGLE_GROUP];
    let mut off = 0;
    for hi in 0..2 {
        for t in 0..tpb {
            let norm = f32::from_le_bytes(payload[off..off + 4].try_into().unwrap());
            let gstart = off + 4;
            let cstart = gstart + 8 * groups;
            if pack4 {
                for (j, c) in codes.iter_mut().enumerate().take(d) {
                    let b = payload[cstart + j / 2];
                    *c = if j % 2 == 0 { b & 0x0f } else { b >> 4 };
                }
            } else {
                codes[..d].copy_from_slice(&payload[cstart..cstart + d]);
            }
            let half = if hi == 0 { &mut out.keys } else { &mut out.vals };
            decode_angle_vec(
                norm,
                &payload[gstart..gstart + 8 * groups],
                &codes,
                &mut half[t * d..(t + 1) * d],
            );
            off += vec_bytes;
        }
    }
    for i in 0..tpb {
        out.pos[i] = u32::from_le_bytes(payload[off..off + 4].try_into().unwrap());
        off += 4;
    }
}

/// Group-wise int8 angle quantizer: exact norms, 256-level directions.
pub struct Int8AngleCodec;

impl PageCodec for Int8AngleCodec {
    fn tag(&self) -> CodecTag {
        CodecTag::Int8Angle
    }

    fn name(&self) -> &'static str {
        "int8-angle"
    }

    fn max_payload_bytes(&self, tpb: usize, d: usize) -> usize {
        2 * tpb * angle_vec_bytes(d, d) + tpb * 4
    }

    fn encode(&self, data: &BlockData, tpb: usize, d: usize, out: &mut [u8]) -> usize {
        angle_encode_page(data, tpb, d, 256, false, out)
    }

    fn decode(&self, payload: &[u8], tpb: usize, d: usize, out: &mut BlockData) {
        angle_decode_page(payload, tpb, d, false, out)
    }
}

/// Group-wise int4 angle quantizer: exact norms, 16-level directions,
/// two codes per byte.
pub struct Int4AngleCodec;

impl PageCodec for Int4AngleCodec {
    fn tag(&self) -> CodecTag {
        CodecTag::Int4Angle
    }

    fn name(&self) -> &'static str {
        "int4-angle"
    }

    fn max_payload_bytes(&self, tpb: usize, d: usize) -> usize {
        2 * tpb * angle_vec_bytes(d, d.div_ceil(2)) + tpb * 4
    }

    fn encode(&self, data: &BlockData, tpb: usize, d: usize, out: &mut [u8]) -> usize {
        angle_encode_page(data, tpb, d, 16, true, out)
    }

    fn decode(&self, payload: &[u8], tpb: usize, d: usize, out: &mut BlockData) {
        angle_decode_page(payload, tpb, d, true, out)
    }
}

// ---------------------------------------------------------------------
// Low-rank K projection
// ---------------------------------------------------------------------

fn lowrank_rank(d: usize) -> usize {
    (d / 2).max(1)
}

/// The fixed orthonormal `[r, d]` projection basis for head dim `d`,
/// derived deterministically (seeded Gram-Schmidt) and cached — every
/// store and every session projects through the same basis, so pages
/// decode identically wherever they were encoded.
fn lowrank_basis(d: usize) -> Arc<Vec<f32>> {
    static CACHE: OnceLock<Mutex<HashMap<usize, Arc<Vec<f32>>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(b) = cache.lock().unwrap().get(&d) {
        return Arc::clone(b);
    }
    let r = lowrank_rank(d);
    let mut rng = crate::util::rng::Rng::new(0x4c52_4b42 ^ d as u64);
    let mut basis: Vec<f32> = Vec::with_capacity(r * d);
    while basis.len() < r * d {
        let mut v = rng.normal_vec(d);
        for p in 0..basis.len() / d {
            let row = &basis[p * d..(p + 1) * d];
            let dot: f32 = v.iter().zip(row).map(|(a, b)| a * b).sum();
            for (vi, ri) in v.iter_mut().zip(row) {
                *vi -= dot * ri;
            }
        }
        let n = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        if n > 1e-3 {
            for vi in &mut v {
                *vi /= n;
            }
            basis.extend_from_slice(&v);
        }
    }
    let b = Arc::new(basis);
    cache.lock().unwrap().entry(d).or_insert_with(|| Arc::clone(&b));
    b
}

/// Low-rank K-projection codec: K vectors stored as rank-`d/2`
/// coefficients in a fixed orthonormal basis (Efficient-Low-Rank-
/// Attention-style); V and positions stay bit-exact.
pub struct LowRankKCodec;

impl PageCodec for LowRankKCodec {
    fn tag(&self) -> CodecTag {
        CodecTag::LowRankK
    }

    fn name(&self) -> &'static str {
        "lowrank-k"
    }

    fn max_payload_bytes(&self, tpb: usize, d: usize) -> usize {
        tpb * lowrank_rank(d) * 4 + tpb * d * 4 + tpb * 4
    }

    fn encode(&self, data: &BlockData, tpb: usize, d: usize, out: &mut [u8]) -> usize {
        let r = lowrank_rank(d);
        let basis = lowrank_basis(d);
        let mut off = 0;
        for t in 0..tpb {
            let x = &data.keys[t * d..(t + 1) * d];
            for j in 0..r {
                let row = &basis[j * d..(j + 1) * d];
                let c: f32 = x.iter().zip(row).map(|(a, b)| a * b).sum();
                out[off..off + 4].copy_from_slice(&c.to_le_bytes());
                off += 4;
            }
        }
        for v in &data.vals {
            out[off..off + 4].copy_from_slice(&v.to_le_bytes());
            off += 4;
        }
        for p in &data.pos {
            out[off..off + 4].copy_from_slice(&p.to_le_bytes());
            off += 4;
        }
        off
    }

    fn decode(&self, payload: &[u8], tpb: usize, d: usize, out: &mut BlockData) {
        let r = lowrank_rank(d);
        let basis = lowrank_basis(d);
        let mut off = 0;
        for t in 0..tpb {
            let x = &mut out.keys[t * d..(t + 1) * d];
            x.fill(0.0);
            for j in 0..r {
                let c = f32::from_le_bytes(payload[off..off + 4].try_into().unwrap());
                off += 4;
                let row = &basis[j * d..(j + 1) * d];
                for (xi, ri) in x.iter_mut().zip(row) {
                    *xi += c * ri;
                }
            }
        }
        for i in 0..tpb * d {
            out.vals[i] = f32::from_le_bytes(payload[off..off + 4].try_into().unwrap());
            off += 4;
        }
        for i in 0..tpb {
            out.pos[i] = u32::from_le_bytes(payload[off..off + 4].try_into().unwrap());
            off += 4;
        }
    }
}

// ---------------------------------------------------------------------
// Session-snapshot pages
// ---------------------------------------------------------------------

/// Append one block as a self-describing snapshot page to a byte
/// stream: the same LE `[tag][reserved][tokens u16][payload_len u32]`
/// header the cold tier writes, followed by an [`ExactCodec`] payload.
/// Snapshot pages are the unit of live session migration
/// (`LiveEngine::export_session`): always exact — whatever codec the
/// source's cold tier used, the migrated replica must rebuild the very
/// bits the source would have attended over, so re-encoding lossily
/// here would double-quantize. `tokens` records how many leading
/// positions of the (always full-stride) block are meaningful.
pub fn append_snapshot_page(
    data: &BlockData,
    tokens: usize,
    tpb: usize,
    d: usize,
    out: &mut Vec<u8>,
) {
    debug_assert!(tokens <= tpb && tokens <= u16::MAX as usize);
    let raw = raw_payload_bytes(tpb, d);
    let start = out.len();
    out.resize(start + PAGE_HEADER_BYTES + raw, 0);
    let page = &mut out[start..];
    let plen = ExactCodec.encode(data, tpb, d, &mut page[PAGE_HEADER_BYTES..]);
    debug_assert_eq!(plen, raw);
    page[0] = CodecTag::Exact as u8;
    page[1] = 0;
    page[2..4].copy_from_slice(&(tokens as u16).to_le_bytes());
    page[4..8].copy_from_slice(&(plen as u32).to_le_bytes());
}

/// Decode one snapshot page from `buf` at byte offset `off` into `out`.
/// Dispatches on the page's own tag (like every cold read), so a future
/// compressed snapshot format reads through the same path. Returns
/// `(valid_tokens, next_offset)`, or `None` on a truncated page, an
/// unknown tag, or a token count exceeding the block stride — the
/// caller treats that as a corrupt snapshot, not a panic.
pub fn read_snapshot_page(
    buf: &[u8],
    off: usize,
    tpb: usize,
    d: usize,
    out: &mut BlockData,
) -> Option<(usize, usize)> {
    let body = off.checked_add(PAGE_HEADER_BYTES)?;
    if buf.len() < body {
        return None;
    }
    let tag = CodecTag::from_u8(buf[off])?;
    let tokens = u16::from_le_bytes(buf[off + 2..off + 4].try_into().unwrap()) as usize;
    let plen = u32::from_le_bytes(buf[off + 4..off + 8].try_into().unwrap()) as usize;
    let end = body.checked_add(plen)?;
    if buf.len() < end || tokens > tpb || plen > raw_payload_bytes(tpb, d) {
        return None;
    }
    codec_for(tag).decode(&buf[body..end], tpb, d, out);
    Some((tokens, end))
}

/// The static codec instance for a tag.
pub fn codec_for(tag: CodecTag) -> &'static dyn PageCodec {
    static EXACT: ExactCodec = ExactCodec;
    static INT8: Int8AngleCodec = Int8AngleCodec;
    static INT4: Int4AngleCodec = Int4AngleCodec;
    static LOWRANK: LowRankKCodec = LowRankKCodec;
    match tag {
        CodecTag::Exact => &EXACT,
        CodecTag::Int8Angle => &INT8,
        CodecTag::Int4Angle => &INT4,
        CodecTag::LowRankK => &LOWRANK,
    }
}

// ---------------------------------------------------------------------
// The page store
// ---------------------------------------------------------------------

/// The simulated page file: a flat byte heap carved into fixed-size
/// pages (the mmap stand-in), an id → page index, and a free page list.
/// Compressed payloads occupy a prefix of their page, so the free list
/// and page recycling are codec-oblivious; the header records how many
/// payload bytes are physically meaningful.
struct SpillFile {
    data: Vec<u8>,
    index: HashMap<u64, u32>,
    free: Vec<u32>,
}

/// The async-prefetch staging area: decoded pages awaiting consumption,
/// tagged with the staging epoch they were read in. The engine bumps
/// the epoch once per decode step ([`SpillStore::begin_staging_epoch`]),
/// which drops pages staged two or more epochs ago — a selection that
/// was never consumed must not accumulate (double-buffered: the current
/// and the immediately previous epoch survive, so pages staged late in
/// step N still serve step N+1's promotions). An optional cap bounds
/// the footprint within an epoch as well.
struct StagingArea {
    map: HashMap<u64, (u64, BlockData)>,
    epoch: u64,
    cap: Option<usize>,
}

/// Cold-tier block store keyed by engine-global block ids.
pub struct SpillStore {
    d: usize,
    tpb: usize,
    /// Full page stride: header + worst-case (exact) payload.
    page_bytes: usize,
    /// Uncompressed payload bytes per page (the logical size).
    raw_bytes: usize,
    /// Configured codec tag for lossy-eligible writes (`write_with`
    /// with `lossy_ok = true`); exact-required writes always use
    /// [`CodecTag::Exact`] regardless.
    codec: AtomicU8,
    file: Mutex<SpillFile>,
    /// Async-prefetch staging area: pages read ahead of promotion by
    /// I/O-lane jobs, consumed (without a second file read) when the
    /// block is promoted or assembled.
    staged: Mutex<StagingArea>,
    writes_total: AtomicU64,
    reads_total: AtomicU64,
    dropped_total: AtomicU64,
    staged_total: AtomicU64,
    staged_hits: AtomicU64,
    /// Staged pages dropped unconsumed (epoch expiry or cap eviction).
    staged_stale_dropped: AtomicU64,
    /// Cold reads through the assembly data path (`peek_kv_into`).
    cold_reads_total: AtomicU64,
    /// Of those, reads served from the staging area — no file stall.
    /// `cold_reads_staged / cold_reads_total` is the measured intra-step
    /// spill-overlap ratio.
    cold_reads_staged: AtomicU64,
    /// Fault-injection shim: artificial delay (µs) before every file
    /// page read, plus an id-keyed jitter bound that scrambles the
    /// completion order of concurrent staging reads. Test-only knobs;
    /// zero (the default) is a no-op.
    read_delay_us: AtomicU64,
    read_jitter_us: AtomicU64,
    /// Physical bytes (header + encoded payload) of resident cold pages.
    physical_bytes: AtomicU64,
    /// Resident cold pages written with a lossy codec.
    compressed_blocks: AtomicU64,
}

impl SpillStore {
    pub fn new(d: usize, tpb: usize) -> SpillStore {
        let raw = raw_payload_bytes(tpb, d);
        SpillStore {
            d,
            tpb,
            page_bytes: PAGE_HEADER_BYTES + raw,
            raw_bytes: raw,
            codec: AtomicU8::new(CodecTag::Exact as u8),
            file: Mutex::new(SpillFile {
                data: Vec::new(),
                index: HashMap::new(),
                free: Vec::new(),
            }),
            staged: Mutex::new(StagingArea { map: HashMap::new(), epoch: 0, cap: None }),
            writes_total: AtomicU64::new(0),
            reads_total: AtomicU64::new(0),
            dropped_total: AtomicU64::new(0),
            staged_total: AtomicU64::new(0),
            staged_hits: AtomicU64::new(0),
            staged_stale_dropped: AtomicU64::new(0),
            cold_reads_total: AtomicU64::new(0),
            cold_reads_staged: AtomicU64::new(0),
            read_delay_us: AtomicU64::new(0),
            read_jitter_us: AtomicU64::new(0),
            physical_bytes: AtomicU64::new(0),
            compressed_blocks: AtomicU64::new(0),
        }
    }

    /// Serialized size of one cold page in bytes (header + exact
    /// payload: the per-page *capacity*, not what a compressed page
    /// physically uses — see [`SpillStore::physical_bytes`]).
    pub fn page_bytes(&self) -> usize {
        self.page_bytes
    }

    /// Select the codec used for lossy-eligible writes. Pages already
    /// resident keep the codec they were written with (the per-page tag
    /// dispatches decoding), so switching mid-run is safe.
    pub fn set_codec(&self, tag: CodecTag) {
        self.codec.store(tag as u8, Ordering::Relaxed);
    }

    /// The codec applied when a write is lossy-eligible.
    pub fn codec_tag(&self) -> CodecTag {
        CodecTag::from_u8(self.codec.load(Ordering::Relaxed)).unwrap_or(CodecTag::Exact)
    }

    /// Begin a new staging epoch — the engine calls this once per
    /// decode step. Pages staged two or more epochs ago were selected
    /// but never consumed; they are dropped here (counted in
    /// [`SpillStore::staged_stale_dropped`]), so a long run's staging
    /// footprint stays O(per-step depth), not O(steps). Double-buffered
    /// on purpose: the current and the immediately previous epoch both
    /// survive, so pages staged late in step N still serve step N+1.
    pub fn begin_staging_epoch(&self) {
        let mut s = self.staged.lock().unwrap();
        s.epoch += 1;
        let cutoff = s.epoch.saturating_sub(1);
        let before = s.map.len();
        s.map.retain(|_, (e, _)| *e >= cutoff);
        let dropped = (before - s.map.len()) as u64;
        if dropped > 0 {
            self.staged_stale_dropped.fetch_add(dropped, Ordering::Relaxed);
        }
    }

    /// Cap the staging area's resident pages (`None` = epoch-bounded
    /// only). When the cap binds, the oldest-epoch entries are evicted
    /// first (counted as stale drops) — staging is purely an overlap
    /// optimization, so eviction costs a re-read, never correctness.
    pub fn set_staging_cap(&self, cap: Option<usize>) {
        self.staged.lock().unwrap().cap = cap;
    }

    /// Fault-injection shim: delay every staging/stall page read by
    /// `us` microseconds plus an id-keyed pseudo-random jitter in
    /// `[0, jitter_us)` — the jitter scrambles the completion order of
    /// concurrently staged pages, which the pipelined-decode property
    /// tests use to prove merge order is completion-order independent.
    /// Zero/zero (the default) is a no-op.
    pub fn set_read_fault(&self, us: u64, jitter_us: u64) {
        self.read_delay_us.store(us, Ordering::Relaxed);
        self.read_jitter_us.store(jitter_us, Ordering::Relaxed);
    }

    fn fault_delay(&self, id: u64) {
        let base = self.read_delay_us.load(Ordering::Relaxed);
        let jitter = self.read_jitter_us.load(Ordering::Relaxed);
        if base == 0 && jitter == 0 {
            return;
        }
        let j = if jitter == 0 { 0 } else { id.wrapping_mul(0x9E37_79B9_7F4A_7C15) % jitter };
        std::thread::sleep(std::time::Duration::from_micros(base + j));
    }

    fn read_header(page: &[u8]) -> (CodecTag, usize) {
        let tag = CodecTag::from_u8(page[0]).expect("corrupt spill page header");
        let plen = u32::from_le_bytes(page[4..8].try_into().unwrap()) as usize;
        (tag, plen)
    }

    fn decode_page(&self, page: &[u8], out: &mut BlockData) {
        let (tag, plen) = Self::read_header(page);
        codec_for(tag).decode(
            &page[PAGE_HEADER_BYTES..PAGE_HEADER_BYTES + plen],
            self.tpb,
            self.d,
            out,
        );
    }

    /// Write (demote) one block's data into a cold page with the exact
    /// codec — bit-identical round-trip guaranteed. Panics if the id is
    /// already cold: a block must never be in two tiers.
    pub fn write(&self, id: u64, data: &BlockData) {
        self.write_with(id, data, false);
    }

    /// Write (demote) one block's data into a cold page. With
    /// `lossy_ok` the configured codec is applied (falling back to
    /// exact when its worst case would not fit the page); without it
    /// the page is always exact — the caller's accuracy bound, not the
    /// store, decides whether lossy storage is acceptable.
    pub fn write_with(&self, id: u64, data: &BlockData, lossy_ok: bool) {
        let mut tag = if lossy_ok { self.codec_tag() } else { CodecTag::Exact };
        if codec_for(tag).max_payload_bytes(self.tpb, self.d) > self.raw_bytes {
            tag = CodecTag::Exact;
        }
        let mut f = self.file.lock().unwrap();
        assert!(!f.index.contains_key(&id), "block {id} already in the cold tier");
        let page = match f.free.pop() {
            Some(p) => p,
            None => {
                let p = (f.data.len() / self.page_bytes) as u32;
                f.data.resize(f.data.len() + self.page_bytes, 0);
                p
            }
        };
        let start = page as usize * self.page_bytes;
        let pb = self.page_bytes;
        // split the borrow: encode into the page slice in place
        let slice = &mut f.data[start..start + pb];
        let plen =
            codec_for(tag).encode(data, self.tpb, self.d, &mut slice[PAGE_HEADER_BYTES..]);
        debug_assert!(plen <= self.raw_bytes);
        slice[0] = tag as u8;
        slice[1] = 0;
        slice[2..4].copy_from_slice(&(self.tpb as u16).to_le_bytes());
        slice[4..8].copy_from_slice(&(plen as u32).to_le_bytes());
        f.index.insert(id, page);
        self.writes_total.fetch_add(1, Ordering::Relaxed);
        self.physical_bytes.fetch_add((PAGE_HEADER_BYTES + plen) as u64, Ordering::Relaxed);
        if tag.is_lossy() {
            self.compressed_blocks.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Account a page's removal (caller holds the file lock and has
    /// already read the page's header).
    fn retire_page(&self, tag: CodecTag, plen: usize) {
        self.physical_bytes.fetch_sub((PAGE_HEADER_BYTES + plen) as u64, Ordering::Relaxed);
        if tag.is_lossy() {
            self.compressed_blocks.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Whether `id` currently lives in the cold tier.
    pub fn contains(&self, id: u64) -> bool {
        self.file.lock().unwrap().index.contains_key(&id)
    }

    /// The codec tag of a resident cold page (None if `id` is not
    /// cold). Test/report introspection for the accuracy-bound rule.
    pub fn page_tag(&self, id: u64) -> Option<CodecTag> {
        let f = self.file.lock().unwrap();
        let &page = f.index.get(&id)?;
        let start = page as usize * self.page_bytes;
        Some(Self::read_header(&f.data[start..start + PAGE_HEADER_BYTES]).0)
    }

    /// Copy a cold page into `out` without changing residency (the
    /// synchronous cold-read path of a GPU-cache miss on a cold block).
    /// Returns false if `id` is not cold.
    pub fn peek_into(&self, id: u64, out: &mut BlockData) -> bool {
        let f = self.file.lock().unwrap();
        let Some(&page) = f.index.get(&id) else {
            return false;
        };
        let start = page as usize * self.page_bytes;
        self.decode_page(&f.data[start..start + self.page_bytes], out);
        self.reads_total.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Append the first `n_elems` key and value f32s of a cold page
    /// directly to `k_out` / `v_out` (the cold-read data path of
    /// execution-buffer assembly). Exact pages stream straight from the
    /// page bytes; compressed pages decode through their codec first.
    /// Residency is unchanged. Returns `None` if `id` is not cold,
    /// `Some(staged)` otherwise — `staged` reports whether the read was
    /// served from the staging area (no file stall: the intra-step
    /// overlap win) or had to decode the page synchronously.
    pub fn peek_kv_into(
        &self,
        id: u64,
        n_elems: usize,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
    ) -> Option<bool> {
        let f = self.file.lock().unwrap();
        let &page = f.index.get(&id)?;
        let half = self.tpb * self.d;
        debug_assert!(n_elems <= half);
        self.cold_reads_total.fetch_add(1, Ordering::Relaxed);
        // Staged-first: an I/O-lane prefetch that already decoded this
        // page serves the read with no file stall — the intra-step
        // overlap win. Staged bytes are decoded from the same page, so
        // the result is bit-identical either way. Lock order: file →
        // staged.
        if let Some((_, data)) = self.staged.lock().unwrap().map.get(&id) {
            k_out.extend_from_slice(&data.keys[..n_elems]);
            v_out.extend_from_slice(&data.vals[..n_elems]);
            self.cold_reads_staged.fetch_add(1, Ordering::Relaxed);
            return Some(true);
        }
        // A genuine cold-hit stall: the fault shim charges it while the
        // file lock is held, like a real blocking page read would.
        self.fault_delay(id);
        let start = page as usize * self.page_bytes;
        let (tag, _plen) = Self::read_header(&f.data[start..start + PAGE_HEADER_BYTES]);
        if tag == CodecTag::Exact {
            let base = start + PAGE_HEADER_BYTES;
            k_out.reserve(n_elems);
            v_out.reserve(n_elems);
            for i in 0..n_elems {
                let off = base + 4 * i;
                k_out.push(f32::from_le_bytes(f.data[off..off + 4].try_into().unwrap()));
            }
            let vstart = base + 4 * half;
            for i in 0..n_elems {
                let off = vstart + 4 * i;
                v_out.push(f32::from_le_bytes(f.data[off..off + 4].try_into().unwrap()));
            }
        } else {
            let mut tmp = BlockData::zeroed(self.tpb, self.d);
            self.decode_page(&f.data[start..start + self.page_bytes], &mut tmp);
            k_out.extend_from_slice(&tmp.keys[..n_elems]);
            v_out.extend_from_slice(&tmp.vals[..n_elems]);
        }
        self.reads_total.fetch_add(1, Ordering::Relaxed);
        Some(false)
    }

    /// Async-prefetch one page into the staging area (no residency
    /// change; the matching [`SpillStore::take_into`] consumes it).
    /// Returns false if `id` is not cold — a block promoted or dropped
    /// while the prefetch job was queued is simply skipped.
    pub fn stage(&self, id: u64) -> bool {
        // Fault shim sleeps BEFORE the file lock: a slow staging read
        // occupies only its I/O-lane worker, never the store.
        self.fault_delay(id);
        let f = self.file.lock().unwrap();
        let Some(&page) = f.index.get(&id) else {
            return false;
        };
        let mut data = BlockData::zeroed(self.tpb, self.d);
        let start = page as usize * self.page_bytes;
        self.decode_page(&f.data[start..start + self.page_bytes], &mut data);
        self.reads_total.fetch_add(1, Ordering::Relaxed);
        self.staged_total.fetch_add(1, Ordering::Relaxed);
        // lock order: file → staged (held file lock keeps the page from
        // being promoted/dropped between the read and the insert)
        let mut s = self.staged.lock().unwrap();
        let epoch = s.epoch;
        s.map.insert(id, (epoch, data));
        if let Some(cap) = s.cap {
            let mut evicted = 0u64;
            while s.map.len() > cap.max(1) {
                // evict the oldest-epoch (then lowest-id) entry first
                let victim = s.map.iter().map(|(k, (e, _))| (*e, *k)).min().map(|(_, k)| k);
                match victim {
                    Some(k) => {
                        s.map.remove(&k);
                        evicted += 1;
                    }
                    None => break,
                }
            }
            if evicted > 0 {
                self.staged_stale_dropped.fetch_add(evicted, Ordering::Relaxed);
            }
        }
        true
    }

    /// Take (promote) a cold page out of the store into `out`. Serves
    /// from the staging area when an async prefetch already read the
    /// page (returns `Some(true)` — the overlap win), from the file
    /// otherwise (`Some(false)` — a cold-hit stall). `None` if the id
    /// is not cold.
    pub fn take_into(&self, id: u64, out: &mut BlockData) -> Option<bool> {
        let mut f = self.file.lock().unwrap();
        let page = f.index.remove(&id)?;
        f.free.push(page);
        let start = page as usize * self.page_bytes;
        let (tag, plen) = Self::read_header(&f.data[start..start + PAGE_HEADER_BYTES]);
        self.retire_page(tag, plen);
        let staged = self.staged.lock().unwrap().map.remove(&id);
        match staged {
            Some((_, data)) => {
                out.keys.copy_from_slice(&data.keys);
                out.vals.copy_from_slice(&data.vals);
                out.pos.copy_from_slice(&data.pos);
                self.staged_hits.fetch_add(1, Ordering::Relaxed);
                Some(true)
            }
            None => {
                self.decode_page(&f.data[start..start + self.page_bytes], out);
                self.reads_total.fetch_add(1, Ordering::Relaxed);
                Some(false)
            }
        }
    }

    /// Drop a cold block outright (finished-session reclamation: cold
    /// blocks die in place, never promoted first). Returns false if the
    /// id is not cold.
    pub fn drop_block(&self, id: u64) -> bool {
        let mut f = self.file.lock().unwrap();
        let Some(page) = f.index.remove(&id) else {
            return false;
        };
        f.free.push(page);
        let start = page as usize * self.page_bytes;
        let (tag, plen) = Self::read_header(&f.data[start..start + PAGE_HEADER_BYTES]);
        self.retire_page(tag, plen);
        self.staged.lock().unwrap().map.remove(&id);
        self.dropped_total.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Blocks currently resident in the cold tier.
    pub fn cold_blocks(&self) -> usize {
        self.file.lock().unwrap().index.len()
    }

    /// Bytes of cold pages currently holding blocks (page-stride
    /// capacity — the tier's reserved footprint).
    pub fn cold_bytes(&self) -> usize {
        self.cold_blocks() * self.page_bytes
    }

    /// Uncompressed (logical) payload bytes of resident cold blocks —
    /// what the cold tier would hold with every page exact.
    pub fn logical_bytes(&self) -> usize {
        self.cold_blocks() * self.raw_bytes
    }

    /// Physical bytes (header + encoded payload) of resident cold
    /// blocks — what actually crosses the spill channel. The achieved
    /// compression ratio is `physical_bytes / logical_bytes`.
    pub fn physical_bytes(&self) -> usize {
        self.physical_bytes.load(Ordering::Relaxed) as usize
    }

    /// Resident cold blocks stored with a lossy codec.
    pub fn compressed_blocks(&self) -> usize {
        self.compressed_blocks.load(Ordering::Relaxed) as usize
    }

    /// Total bytes of the backing "file" (live + recycled pages — the
    /// spill tier's resident footprint).
    pub fn file_bytes(&self) -> usize {
        self.file.lock().unwrap().data.len()
    }

    /// Pages currently staged by async prefetch.
    pub fn staged_blocks(&self) -> usize {
        self.staged.lock().unwrap().map.len()
    }

    pub fn writes_total(&self) -> u64 {
        self.writes_total.load(Ordering::Relaxed)
    }

    pub fn reads_total(&self) -> u64 {
        self.reads_total.load(Ordering::Relaxed)
    }

    pub fn dropped_total(&self) -> u64 {
        self.dropped_total.load(Ordering::Relaxed)
    }

    pub fn staged_hits(&self) -> u64 {
        self.staged_hits.load(Ordering::Relaxed)
    }

    /// Staged pages dropped unconsumed (epoch expiry or cap eviction).
    pub fn staged_stale_dropped(&self) -> u64 {
        self.staged_stale_dropped.load(Ordering::Relaxed)
    }

    /// Cold reads through the assembly data path (`peek_kv_into`).
    pub fn cold_reads_total(&self) -> u64 {
        self.cold_reads_total.load(Ordering::Relaxed)
    }

    /// Of [`SpillStore::cold_reads_total`], reads served from the
    /// staging area without a file stall — the numerator of the
    /// measured intra-step spill-overlap ratio.
    pub fn cold_reads_staged(&self) -> u64 {
        self.cold_reads_staged.load(Ordering::Relaxed)
    }
}

/// One cluster's spill-relevant metadata, fed to a [`SpillPolicy`] by
/// `WaveIndex::demote_until` (the wave index owns the access epochs the
/// policy ranks by and the estimation-head error bound behind
/// `lossy_ok`).
#[derive(Clone, Copy, Debug)]
pub struct SpillCandidate {
    pub cluster: u32,
    /// Selection epoch the cluster was last retrieved at (0 = never).
    pub last_access: u64,
    /// Hot blocks the cluster currently holds (what demotion frees).
    pub hot_blocks: usize,
    /// Whether the wave index's estimation head cleared this cluster
    /// for lossy storage: its tokens sit outside the sink/steady-local
    /// zones and its keys are tight enough around the centroid that the
    /// estimator's error bound absorbs quantization noise. Clusters
    /// with `lossy_ok = false` are always stored exact.
    pub lossy_ok: bool,
}

/// Pluggable victim ordering for demotion. Implementations sort the
/// candidate list demote-first; callers demote from the front until
/// enough hot blocks are free.
pub trait SpillPolicy: Send + Sync {
    fn order(&self, candidates: &mut [SpillCandidate]);
    fn name(&self) -> &'static str;
}

/// Default policy: demote the least-recently-selected clusters first
/// (ties broken by cluster id for determinism). Mirrors the wave
/// buffer's LRU default one tier down.
pub struct ColdestFirst;

impl SpillPolicy for ColdestFirst {
    fn order(&self, candidates: &mut [SpillCandidate]) {
        candidates.sort_by_key(|c| (c.last_access, c.cluster));
    }

    fn name(&self) -> &'static str {
        "coldest-first"
    }
}

/// Alternative policy: among cold clusters, demote the largest first so
/// the fewest clusters lose hot residency (fewer, bigger writebacks).
pub struct LargestColdFirst;

impl SpillPolicy for LargestColdFirst {
    fn order(&self, candidates: &mut [SpillCandidate]) {
        candidates.sort_by_key(|c| (c.last_access, std::cmp::Reverse(c.hot_blocks), c.cluster));
    }

    fn name(&self) -> &'static str {
        "largest-cold-first"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(tpb: usize, d: usize, seed: u32) -> BlockData {
        let mut b = BlockData::zeroed(tpb, d);
        for (i, x) in b.keys.iter_mut().enumerate() {
            *x = f32::from_bits(seed.wrapping_mul(31).wrapping_add(i as u32));
        }
        for (i, x) in b.vals.iter_mut().enumerate() {
            *x = f32::from_bits(seed.wrapping_mul(37).wrapping_add(i as u32) | 1);
        }
        for (i, p) in b.pos.iter_mut().enumerate() {
            *p = seed.wrapping_add(i as u32);
        }
        b
    }

    /// Finite, well-scaled data (what lossy codecs are actually fed).
    fn gaussian(tpb: usize, d: usize, seed: u64) -> BlockData {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut b = BlockData::zeroed(tpb, d);
        for x in b.keys.iter_mut().chain(b.vals.iter_mut()) {
            *x = 2.0 * rng.normal_f32();
        }
        for (i, p) in b.pos.iter_mut().enumerate() {
            *p = i as u32;
        }
        b
    }

    fn bits(b: &BlockData) -> (Vec<u32>, Vec<u32>, Vec<u32>) {
        (
            b.keys.iter().map(|x| x.to_bits()).collect(),
            b.vals.iter().map(|x| x.to_bits()).collect(),
            b.pos.clone(),
        )
    }

    fn cos(a: &[f32], b: &[f32]) -> f32 {
        let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
        let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
        dot / (na * nb).max(1e-12)
    }

    #[test]
    fn write_take_roundtrip_is_bit_exact() {
        let s = SpillStore::new(8, 4);
        // includes NaN/denormal bit patterns via from_bits
        let b = filled(4, 8, 0x7fc0_0001);
        let want = bits(&b);
        s.write(9, &b);
        assert!(s.contains(9));
        assert_eq!(s.cold_blocks(), 1);
        assert_eq!(s.cold_bytes(), s.page_bytes());
        assert_eq!(s.page_tag(9), Some(CodecTag::Exact));
        let mut out = BlockData::zeroed(4, 8);
        assert_eq!(s.take_into(9, &mut out), Some(false));
        assert_eq!(bits(&out), want);
        assert_eq!(s.cold_blocks(), 0);
        assert_eq!(s.physical_bytes(), 0);
        assert!(s.take_into(9, &mut out).is_none());
    }

    #[test]
    fn exact_stays_exact_even_with_a_lossy_codec_configured() {
        let s = SpillStore::new(8, 4);
        s.set_codec(CodecTag::Int8Angle);
        let b = filled(4, 8, 0x0000_0001); // denormals
        let want = bits(&b);
        // plain write and write_with(lossy_ok = false) both stay exact
        s.write(1, &b);
        s.write_with(2, &b, false);
        assert_eq!(s.page_tag(1), Some(CodecTag::Exact));
        assert_eq!(s.page_tag(2), Some(CodecTag::Exact));
        assert_eq!(s.compressed_blocks(), 0);
        for id in [1, 2] {
            let mut out = BlockData::zeroed(4, 8);
            assert!(s.peek_into(id, &mut out));
            assert_eq!(bits(&out), want);
        }
    }

    #[test]
    fn int8_angle_preserves_norms_and_directions() {
        let (tpb, d) = (4, 16);
        let b = gaussian(tpb, d, 7);
        let s = SpillStore::new(d, tpb);
        s.set_codec(CodecTag::Int8Angle);
        s.write_with(1, &b, true);
        assert_eq!(s.page_tag(1), Some(CodecTag::Int8Angle));
        assert_eq!(s.compressed_blocks(), 1);
        let mut out = BlockData::zeroed(tpb, d);
        assert!(s.peek_into(1, &mut out));
        assert_eq!(out.pos, b.pos, "positions must stay exact");
        for t in 0..tpb {
            for (orig, dec) in [(&b.keys, &out.keys), (&b.vals, &out.vals)] {
                let x = &orig[t * d..(t + 1) * d];
                let y = &dec[t * d..(t + 1) * d];
                let c = cos(x, y);
                assert!(c > 0.999, "int8 direction drifted: cos = {c}");
                let nx: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
                let ny: f32 = y.iter().map(|v| v * v).sum::<f32>().sqrt();
                assert!((nx - ny).abs() <= 0.02 * nx.max(1e-6), "norm drifted: {nx} vs {ny}");
            }
        }
    }

    #[test]
    fn int4_angle_decodes_within_loose_bounds() {
        let (tpb, d) = (4, 16);
        let b = gaussian(tpb, d, 11);
        let s = SpillStore::new(d, tpb);
        s.set_codec(CodecTag::Int4Angle);
        s.write_with(1, &b, true);
        assert_eq!(s.page_tag(1), Some(CodecTag::Int4Angle));
        let mut out = BlockData::zeroed(tpb, d);
        assert!(s.peek_into(1, &mut out));
        assert_eq!(out.pos, b.pos);
        for t in 0..tpb {
            let c = cos(&b.keys[t * d..(t + 1) * d], &out.keys[t * d..(t + 1) * d]);
            assert!(c > 0.95, "int4 direction drifted: cos = {c}");
        }
        // int4 pages are smaller than int8 pages
        let s8 = SpillStore::new(d, tpb);
        s8.set_codec(CodecTag::Int8Angle);
        s8.write_with(1, &b, true);
        assert!(s.physical_bytes() < s8.physical_bytes());
    }

    #[test]
    fn lowrank_k_keeps_values_and_positions_exact() {
        let (tpb, d) = (4, 16);
        let b = gaussian(tpb, d, 13);
        let s = SpillStore::new(d, tpb);
        s.set_codec(CodecTag::LowRankK);
        s.write_with(1, &b, true);
        assert_eq!(s.page_tag(1), Some(CodecTag::LowRankK));
        let mut out = BlockData::zeroed(tpb, d);
        assert!(s.peek_into(1, &mut out));
        let want = bits(&b);
        assert_eq!(out.vals.iter().map(|x| x.to_bits()).collect::<Vec<_>>(), want.1);
        assert_eq!(out.pos, b.pos);
        // decoded K is the basis projection: finite and norm-bounded
        for (orig, dec) in b.keys.iter().zip(&out.keys) {
            assert!(dec.is_finite());
            assert!(dec.abs() <= orig.abs() + 20.0);
        }
        assert!(s.physical_bytes() < s.logical_bytes());
    }

    #[test]
    fn mixed_codec_store_round_trips_every_page() {
        let (tpb, d) = (4, 16);
        let s = SpillStore::new(d, tpb);
        s.set_codec(CodecTag::Int8Angle);
        let exact = filled(tpb, d, 0x7fc0_0001);
        let lossy = gaussian(tpb, d, 3);
        s.write_with(10, &exact, false);
        s.write_with(11, &lossy, true);
        s.set_codec(CodecTag::Int4Angle);
        s.write_with(12, &lossy, true);
        assert_eq!(s.page_tag(10), Some(CodecTag::Exact));
        assert_eq!(s.page_tag(11), Some(CodecTag::Int8Angle));
        assert_eq!(s.page_tag(12), Some(CodecTag::Int4Angle));
        assert_eq!(s.compressed_blocks(), 2);
        assert!(s.physical_bytes() < 3 * (s.page_bytes() - PAGE_HEADER_BYTES));
        // every page decodes through its own tag, whatever is configured
        let mut out = BlockData::zeroed(tpb, d);
        assert_eq!(s.take_into(10, &mut out), Some(false));
        assert_eq!(bits(&out), bits(&exact), "exact page must stay bit-exact");
        for id in [11, 12] {
            assert_eq!(s.take_into(id, &mut out), Some(false));
            assert!(out.keys.iter().all(|x| x.is_finite()));
            assert_eq!(out.pos, lossy.pos);
        }
        assert_eq!(s.compressed_blocks(), 0);
        assert_eq!(s.physical_bytes(), 0);
    }

    #[test]
    fn int8_halves_physical_bytes_vs_logical() {
        let (tpb, d) = (4, 16);
        let s = SpillStore::new(d, tpb);
        s.set_codec(CodecTag::Int8Angle);
        for id in 0..8u64 {
            s.write_with(id, &gaussian(tpb, d, id), true);
        }
        assert_eq!(s.compressed_blocks(), 8);
        assert!(
            2 * s.physical_bytes() <= s.logical_bytes(),
            "int8 must at least halve the spill bytes: {} vs {}",
            s.physical_bytes(),
            s.logical_bytes()
        );
    }

    #[test]
    fn codec_falls_back_to_exact_when_it_cannot_fit() {
        // d=2: the angle header (norm + group scale/zp) exceeds the raw
        // vector bytes, so the quantizer cannot fit the page
        let (tpb, d) = (4, 2);
        let s = SpillStore::new(d, tpb);
        s.set_codec(CodecTag::Int8Angle);
        let b = filled(tpb, d, 0x7f80_0000); // includes inf bits
        let want = bits(&b);
        s.write_with(1, &b, true);
        assert_eq!(s.page_tag(1), Some(CodecTag::Exact), "oversized codec must fall back");
        let mut out = BlockData::zeroed(tpb, d);
        assert_eq!(s.take_into(1, &mut out), Some(false));
        assert_eq!(bits(&out), want);
    }

    #[test]
    fn staged_pages_serve_promotion_without_a_second_read() {
        let s = SpillStore::new(4, 4);
        let b = filled(4, 4, 7);
        let want = bits(&b);
        s.write(1, &b);
        assert!(s.stage(1));
        assert_eq!(s.staged_blocks(), 1);
        let reads_before = s.reads_total();
        let mut out = BlockData::zeroed(4, 4);
        assert_eq!(s.take_into(1, &mut out), Some(true));
        assert_eq!(bits(&out), want);
        assert_eq!(s.reads_total(), reads_before, "staged take must not re-read the file");
        assert_eq!(s.staged_hits(), 1);
        assert_eq!(s.staged_blocks(), 0);
        // staging a block that is no longer cold is a no-op
        assert!(!s.stage(1));
    }

    #[test]
    fn staged_pages_serve_kv_prefix_reads_without_a_file_stall() {
        let s = SpillStore::new(4, 4);
        let b = filled(4, 4, 9);
        s.write(1, &b);
        s.write(2, &filled(4, 4, 10));
        assert!(s.stage(1));
        let (mut k, mut v) = (Vec::new(), Vec::new());
        assert_eq!(s.peek_kv_into(1, 8, &mut k, &mut v), Some(true));
        assert_eq!(
            k.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.keys[..8].iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "staged serve must be bit-identical to the file read"
        );
        assert_eq!(s.cold_reads_total(), 1);
        assert_eq!(s.cold_reads_staged(), 1);
        // a staged read does not consume the page — promotion still hits
        assert_eq!(s.staged_blocks(), 1);
        // an unstaged block stalls on the file instead
        k.clear();
        v.clear();
        assert_eq!(s.peek_kv_into(2, 8, &mut k, &mut v), Some(false));
        assert_eq!(s.cold_reads_total(), 2);
        assert_eq!(s.cold_reads_staged(), 1);
    }

    #[test]
    fn staging_epochs_drop_stale_pages_double_buffered() {
        let s = SpillStore::new(4, 4);
        for id in 0..6u64 {
            s.write(id, &filled(4, 4, id as u32));
        }
        s.begin_staging_epoch(); // epoch 1
        assert!(s.stage(0));
        assert!(s.stage(1));
        s.begin_staging_epoch(); // epoch 2: epoch-1 pages survive (double buffer)
        assert_eq!(s.staged_blocks(), 2);
        assert_eq!(s.staged_stale_dropped(), 0);
        assert!(s.stage(2));
        s.begin_staging_epoch(); // epoch 3: epoch-1 pages are now stale
        assert_eq!(s.staged_blocks(), 1, "only the epoch-2 page survives");
        assert_eq!(s.staged_stale_dropped(), 2);
        s.begin_staging_epoch(); // epoch 4: epoch-2 page expires too
        assert_eq!(s.staged_blocks(), 0);
        assert_eq!(s.staged_stale_dropped(), 3);
        // a stale-dropped page falls back to a correct (file) promotion
        let mut out = BlockData::zeroed(4, 4);
        assert_eq!(s.take_into(0, &mut out), Some(false));
        assert_eq!(bits(&out), bits(&filled(4, 4, 0)));
    }

    #[test]
    fn staging_cap_bounds_footprint_evicting_oldest_first() {
        let s = SpillStore::new(4, 4);
        s.set_staging_cap(Some(2));
        for id in 0..5u64 {
            s.write(id, &filled(4, 4, id as u32));
        }
        s.begin_staging_epoch();
        assert!(s.stage(0));
        s.begin_staging_epoch();
        for id in 1..5u64 {
            assert!(s.stage(id));
            assert!(s.staged_blocks() <= 2, "cap must bind at every insert");
        }
        // oldest (epoch-1 id 0, then lowest current-epoch ids) evicted
        assert_eq!(s.staged_blocks(), 2);
        assert_eq!(s.staged_stale_dropped(), 3);
    }

    #[test]
    fn pages_recycle_and_peek_does_not_change_residency() {
        let s = SpillStore::new(4, 4);
        s.write(1, &filled(4, 4, 1));
        s.write(2, &filled(4, 4, 2));
        let file_before = s.file_bytes();
        let mut out = BlockData::zeroed(4, 4);
        assert!(s.peek_into(1, &mut out));
        assert_eq!(s.cold_blocks(), 2, "peek must not evict");
        // direct kv-prefix read matches the full-page deserialization
        let b2 = filled(4, 4, 2);
        let (mut k, mut v) = (Vec::new(), Vec::new());
        assert_eq!(s.peek_kv_into(2, 10, &mut k, &mut v), Some(false));
        assert_eq!(
            k.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b2.keys[..10].iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b2.vals[..10].iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert!(s.peek_kv_into(99, 1, &mut k, &mut v).is_none());
        assert!(s.drop_block(1));
        assert!(!s.drop_block(1));
        // a new write reuses the freed page: the file does not grow
        s.write(3, &filled(4, 4, 3));
        assert_eq!(s.file_bytes(), file_before);
        assert_eq!(s.cold_blocks(), 2);
        assert_eq!(s.dropped_total(), 1);
    }

    #[test]
    fn compressed_pages_read_back_through_kv_prefix_path() {
        let (tpb, d) = (4, 16);
        let s = SpillStore::new(d, tpb);
        s.set_codec(CodecTag::Int8Angle);
        let b = gaussian(tpb, d, 5);
        s.write_with(1, &b, true);
        let mut full = BlockData::zeroed(tpb, d);
        assert!(s.peek_into(1, &mut full));
        let (mut k, mut v) = (Vec::new(), Vec::new());
        assert_eq!(s.peek_kv_into(1, 2 * d, &mut k, &mut v), Some(false));
        assert_eq!(
            k.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            full.keys[..2 * d].iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "kv-prefix read must match the full decode"
        );
        assert_eq!(
            v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            full.vals[..2 * d].iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn snapshot_pages_roundtrip_every_bit_pattern() {
        let (tpb, d) = (4, 8);
        // NaN payload, inf exponent, denormals — the page must carry
        // every f32 bit pattern unchanged
        let blocks = [
            filled(tpb, d, 0x7fc0_0001),
            filled(tpb, d, 0x7f80_0000),
            filled(tpb, d, 0x0000_0001),
        ];
        let mut stream = Vec::new();
        for (i, b) in blocks.iter().enumerate() {
            append_snapshot_page(b, tpb - i.min(tpb - 1), tpb, d, &mut stream);
        }
        assert_eq!(
            stream.len(),
            blocks.len() * (PAGE_HEADER_BYTES + raw_payload_bytes(tpb, d))
        );
        let mut off = 0;
        let mut out = BlockData::zeroed(tpb, d);
        for (i, b) in blocks.iter().enumerate() {
            let (tokens, next) =
                read_snapshot_page(&stream, off, tpb, d, &mut out).expect("valid page");
            assert_eq!(tokens, tpb - i.min(tpb - 1));
            assert_eq!(bits(&out), bits(b));
            off = next;
        }
        assert_eq!(off, stream.len());
        // truncated stream and bad offsets fail soft, never panic
        assert!(read_snapshot_page(&stream, off, tpb, d, &mut out).is_none());
        assert!(read_snapshot_page(&stream[..5], 0, tpb, d, &mut out).is_none());
        assert!(read_snapshot_page(&stream, usize::MAX - 2, tpb, d, &mut out).is_none());
    }

    #[test]
    #[should_panic(expected = "already in the cold tier")]
    fn double_demote_panics() {
        let s = SpillStore::new(4, 4);
        s.write(5, &filled(4, 4, 5));
        s.write(5, &filled(4, 4, 6));
    }

    #[test]
    fn policies_order_victims() {
        let mk = |cluster, last_access, hot_blocks| SpillCandidate {
            cluster,
            last_access,
            hot_blocks,
            lossy_ok: false,
        };
        let base = vec![mk(0, 5, 2), mk(1, 1, 1), mk(2, 1, 4), mk(3, 9, 8)];
        let mut c = base.clone();
        ColdestFirst.order(&mut c);
        assert_eq!(c.iter().map(|x| x.cluster).collect::<Vec<_>>(), vec![1, 2, 0, 3]);
        let mut c = base.clone();
        LargestColdFirst.order(&mut c);
        assert_eq!(c.iter().map(|x| x.cluster).collect::<Vec<_>>(), vec![2, 1, 0, 3]);
        assert_eq!(ColdestFirst.name(), "coldest-first");
        assert_eq!(LargestColdFirst.name(), "largest-cold-first");
    }
}
