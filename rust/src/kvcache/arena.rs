//! Block arena: the engine-owned slab of fixed-size KV blocks (paper
//! §4.3 treats KV placement as a storage-engine problem — a shared pool
//! with explicit admission and reclamation, not per-session `Vec`s).
//!
//! One arena serves every session and every (layer, kv-head) of an
//! engine. [`HeadStore`](super::HeadStore) handles check blocks out via
//! [`BlockArena::alloc`] and return them through [`BlockArena::reclaim`]
//! (driven by `HeadStore`'s `Drop`), so finishing a session puts all of
//! its storage back on the free-list instead of leaking it for the
//! process lifetime. Block ids are engine-global and monotonically
//! increasing — a reclaimed slot's storage is recycled but its id is
//! never reissued, which keeps block-cache keys and mapping-table
//! entries free of ABA aliasing across sessions.
//!
//! Concurrency: allocation/reclaim take a short free-list lock; block
//! *data* is only ever written between `alloc` and publication inside
//! the owning `HeadStore`, and only read while that store is alive, so
//! reads need no lock at all (the parallel head fan-out in
//! `engine::assemble` relies on this).

use super::tokens_per_block;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Storage of one fixed-size KV block: `tpb × d` keys, `tpb × d` values
/// and `tpb` token positions. Capacity never changes after first
/// allocation, so recycling through the free-list is realloc-free.
pub struct BlockData {
    pub(crate) keys: Vec<f32>,
    pub(crate) vals: Vec<f32>,
    pub(crate) pos: Vec<u32>,
}

impl BlockData {
    fn zeroed(tpb: usize, d: usize) -> BlockData {
        BlockData {
            keys: vec![0.0; tpb * d],
            vals: vec![0.0; tpb * d],
            pos: vec![u32::MAX; tpb],
        }
    }
}

/// Engine-wide slab of KV blocks with a free-list and byte accounting.
pub struct BlockArena {
    d: usize,
    tpb: usize,
    free: Mutex<Vec<BlockData>>,
    /// Next engine-global block id (never reused).
    next_id: AtomicU64,
    live_blocks: AtomicUsize,
    free_blocks: AtomicUsize,
    allocated_total: AtomicU64,
    reclaimed_total: AtomicU64,
}

impl BlockArena {
    pub fn new(d: usize, block_bytes: usize) -> BlockArena {
        let tpb = tokens_per_block(block_bytes, d, 4);
        BlockArena {
            d,
            tpb,
            free: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(0),
            live_blocks: AtomicUsize::new(0),
            free_blocks: AtomicUsize::new(0),
            allocated_total: AtomicU64::new(0),
            reclaimed_total: AtomicU64::new(0),
        }
    }

    /// Shared-handle constructor (the form every owner actually wants).
    pub fn shared(d: usize, block_bytes: usize) -> Arc<BlockArena> {
        Arc::new(BlockArena::new(d, block_bytes))
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Tokens per block for this arena's geometry.
    pub fn tokens_per_block(&self) -> usize {
        self.tpb
    }

    /// Bytes of one full block (K + V halves), f32 elements.
    pub fn block_bytes(&self) -> usize {
        2 * self.tpb * self.d * 4
    }

    /// Check one block out of the arena: recycled storage when the
    /// free-list has any, fresh zeroed storage otherwise. Returns the
    /// block's engine-global id and its storage.
    pub(crate) fn alloc(&self) -> (u64, BlockData) {
        let recycled = self.free.lock().unwrap().pop();
        let data = match recycled {
            Some(d) => {
                self.free_blocks.fetch_sub(1, Ordering::Relaxed);
                d
            }
            None => BlockData::zeroed(self.tpb, self.d),
        };
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.live_blocks.fetch_add(1, Ordering::Relaxed);
        self.allocated_total.fetch_add(1, Ordering::Relaxed);
        (id, data)
    }

    /// Return blocks to the free-list (their ids retire permanently).
    pub(crate) fn reclaim<I: IntoIterator<Item = BlockData>>(&self, blocks: I) {
        let mut free = self.free.lock().unwrap();
        let mut n = 0usize;
        for b in blocks {
            debug_assert_eq!(b.keys.len(), self.tpb * self.d);
            free.push(b);
            n += 1;
        }
        drop(free);
        self.free_blocks.fetch_add(n, Ordering::Relaxed);
        self.live_blocks.fetch_sub(n, Ordering::Relaxed);
        self.reclaimed_total.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Blocks currently checked out to live sessions.
    pub fn live_blocks(&self) -> usize {
        self.live_blocks.load(Ordering::Relaxed)
    }

    /// Recycled blocks waiting on the free-list.
    pub fn free_blocks(&self) -> usize {
        self.free_blocks.load(Ordering::Relaxed)
    }

    /// Bytes held by live (checked-out) blocks.
    pub fn live_bytes(&self) -> usize {
        self.live_blocks() * self.block_bytes()
    }

    /// Bytes resident in the arena overall (live + free-list).
    pub fn resident_bytes(&self) -> usize {
        (self.live_blocks() + self.free_blocks()) * self.block_bytes()
    }

    /// Blocks ever allocated (fresh or recycled checkouts).
    pub fn allocated_total(&self) -> u64 {
        self.allocated_total.load(Ordering::Relaxed)
    }

    /// Blocks ever returned to the free-list.
    pub fn reclaimed_total(&self) -> u64 {
        self.reclaimed_total.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_matches_helper() {
        let a = BlockArena::new(32, 2048);
        assert_eq!(a.tokens_per_block(), 8);
        assert_eq!(a.block_bytes(), 2 * 8 * 32 * 4);
        assert_eq!(a.live_blocks(), 0);
    }

    #[test]
    fn alloc_reclaim_recycles_storage_not_ids() {
        let a = BlockArena::new(4, 256);
        let (id0, b0) = a.alloc();
        let (id1, b1) = a.alloc();
        assert_eq!((id0, id1), (0, 1));
        assert_eq!(a.live_blocks(), 2);
        assert_eq!(a.live_bytes(), 2 * a.block_bytes());
        a.reclaim([b0, b1]);
        assert_eq!(a.live_blocks(), 0);
        assert_eq!(a.free_blocks(), 2);
        // storage recycled, ids fresh
        let (id2, b2) = a.alloc();
        assert_eq!(id2, 2);
        assert_eq!(a.free_blocks(), 1);
        assert_eq!(a.allocated_total(), 3);
        assert_eq!(a.reclaimed_total(), 2);
        a.reclaim([b2]);
    }

    #[test]
    fn concurrent_alloc_reclaim_balances() {
        let a = BlockArena::shared(8, 512);
        let mut handles = Vec::new();
        for _ in 0..4 {
            let a = Arc::clone(&a);
            handles.push(std::thread::spawn(move || {
                for _ in 0..200 {
                    let (_, b) = a.alloc();
                    a.reclaim([b]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.live_blocks(), 0);
        assert_eq!(a.allocated_total(), 800);
        assert_eq!(a.reclaimed_total(), 800);
    }
}
