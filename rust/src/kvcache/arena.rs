//! Block arena: the engine-owned slab of fixed-size KV blocks (paper
//! §4.3 treats KV placement as a storage-engine problem — a shared pool
//! with explicit admission and reclamation, not per-session `Vec`s).
//!
//! One arena serves every session and every (layer, kv-head) of an
//! engine. [`HeadStore`](super::HeadStore) handles check blocks out via
//! [`BlockArena::try_alloc_for`] and return them through
//! [`BlockArena::reclaim_for`] (driven by `HeadStore`'s `Drop`), so
//! finishing a session puts all of its storage back on the free-list
//! instead of leaking it for the process lifetime. Block ids are
//! engine-global and monotonically increasing — a reclaimed slot's
//! storage is recycled but its id is never reissued, which keeps
//! block-cache keys and mapping-table entries free of ABA aliasing
//! across sessions.
//!
//! Capacity and multi-tenancy (DESIGN.md §2 "Admission & quotas"): the
//! arena optionally enforces a hard block cap and per-tenant quotas.
//! Allocation under a cap goes through the fallible
//! [`BlockArena::try_alloc_for`] path, which reports a typed
//! [`AllocError`] instead of growing forever; the scheduler's admission
//! gate consults the same counters to defer prefills before they can
//! hit the cap. Because allocation always recycles the free-list before
//! creating fresh storage, bounding *live* blocks at `capacity` bounds
//! the arena's *resident* footprint (live + free) at `capacity` too.
//!
//! Tiers (DESIGN.md §2 "Tiered arena & spill"): the slab above is the
//! **hot** tier; behind it sits a cold [`SpillStore`] keyed by the same
//! engine-global ids. [`BlockArena::demote_for`] moves a live block's
//! data into a cold page and returns its hot storage to the free-list
//! (hot occupancy drops — this is what "demote, then retry" frees when
//! the hot tier is full); [`BlockArena::try_promote_for`] checks hot
//! storage back out under the same capacity/quota gates as a fresh
//! alloc and refills it from the cold page, preserving the block's id.
//! A block is never in both tiers, and capacity/quota bound the *hot*
//! tier only — the cold tier is the overflow the wave buffer's
//! hierarchy exists for.
//!
//! Sharing (DESIGN.md §2 "Prefix sharing & CoW"): a hot block can be
//! converted into a **shared** block ([`BlockArena::note_shared_for`]),
//! after which any number of sessions hold refcounted read-only views
//! of the same storage ([`BlockArena::share_block_for`]) and the prefix
//! registry pins it resident ([`BlockArena::pin_shared`]). A shared
//! block is charged **once**: one unit of `live_blocks`, billed to one
//! tenant at a time (the first owner; the charge transfers to a
//! surviving owner when the charged tenant's last reference exits).
//! Storage returns to the free-list only when the refcount reaches
//! zero, so a refcounted block is never freed while another owner holds
//! it; shared blocks never demote (the spill path skips them). Writes
//! to a shared block go through copy-on-write at the `HeadStore` layer
//! (`unshare_for_write`): the writer checks out a fresh private block
//! (new id — caches keyed by the old id keep serving the shared bytes)
//! and releases its shared reference.
//!
//! Concurrency: allocation/reclaim take a short free-list lock (the
//! capacity check happens under it, so concurrent allocators cannot
//! both sneak past the cap); block *data* is only ever written between
//! alloc and publication inside the owning `HeadStore`, and only read
//! while that store is alive, so reads need no lock at all (the
//! parallel head fan-out in `engine::assemble` relies on this). Tier
//! moves go through the owning `HeadStore`'s `&mut` methods, so a
//! block's residency never changes under a concurrent reader. Shared
//! bookkeeping takes its own lock; it is never acquired while the
//! free-list or tenant lock is held.

use super::spill::SpillStore;
use super::tokens_per_block;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Tenant identity threaded from `Request` down to block accounting.
pub type TenantId = u32;

/// The tenant used by single-tenant paths (tests, standalone baselines).
pub const DEFAULT_TENANT: TenantId = 0;

/// Why a block checkout was refused (typed so the scheduler/engine can
/// defer instead of panicking).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocError {
    /// The arena's live-block count reached its configured capacity.
    ArenaFull { capacity_blocks: usize },
    /// The requesting tenant reached its per-tenant block quota.
    QuotaExceeded { tenant: TenantId, quota_blocks: usize },
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::ArenaFull { capacity_blocks } => {
                write!(f, "arena full ({capacity_blocks} blocks)")
            }
            AllocError::QuotaExceeded { tenant, quota_blocks } => {
                write!(f, "tenant {tenant} quota exceeded ({quota_blocks} blocks)")
            }
        }
    }
}

impl std::error::Error for AllocError {}

/// Storage of one fixed-size KV block: `tpb × d` keys, `tpb × d` values
/// and `tpb` token positions. Capacity never changes after first
/// allocation, so recycling through the free-list is realloc-free.
pub struct BlockData {
    pub(crate) keys: Vec<f32>,
    pub(crate) vals: Vec<f32>,
    pub(crate) pos: Vec<u32>,
}

impl BlockData {
    pub(crate) fn zeroed(tpb: usize, d: usize) -> BlockData {
        BlockData {
            keys: vec![0.0; tpb * d],
            vals: vec![0.0; tpb * d],
            pos: vec![u32::MAX; tpb],
        }
    }
}

/// Per-tenant quota + occupancy record.
#[derive(Default)]
struct TenantUsage {
    quota_blocks: Option<usize>,
    live_blocks: usize,
}

/// Refcount record of one shared block. `refs` counts every outstanding
/// hold (session views + registry pins); `owners` tracks the session
/// holders per tenant so the single live-block charge can transfer when
/// the charged tenant's last session exits.
struct ShareInfo {
    /// Canonical storage handle; holders carry clones.
    data: Arc<BlockData>,
    /// Outstanding holds (sessions + pins). Free at zero.
    refs: usize,
    /// Session holders as (tenant, count) — small per-block multiset.
    owners: Vec<(TenantId, usize)>,
    /// Tenant currently billed the block's single live-block charge.
    charged: TenantId,
}

/// Engine-wide slab of KV blocks with a free-list, byte accounting, an
/// optional capacity cap and per-tenant quotas.
pub struct BlockArena {
    d: usize,
    tpb: usize,
    free: Mutex<Vec<BlockData>>,
    /// Hard cap on live blocks; `usize::MAX` means unbounded.
    capacity_blocks: AtomicUsize,
    /// Per-tenant quota + live occupancy (small map; one entry per tenant).
    tenants: Mutex<HashMap<TenantId, TenantUsage>>,
    /// Next engine-global block id (never reused).
    next_id: AtomicU64,
    live_blocks: AtomicUsize,
    free_blocks: AtomicUsize,
    allocated_total: AtomicU64,
    reclaimed_total: AtomicU64,
    /// Shared (refcounted) blocks keyed by engine-global id.
    shared: Mutex<HashMap<u64, ShareInfo>>,
    shared_freed_total: AtomicU64,
    /// Cold tier: spilled pages keyed by the same engine-global ids.
    spill: SpillStore,
    demoted_total: AtomicU64,
    promoted_total: AtomicU64,
    /// Promotions served from the async-prefetch staging area (the
    /// overlap win the prefetch worker exists for).
    promoted_staged_total: AtomicU64,
}

impl BlockArena {
    pub fn new(d: usize, block_bytes: usize) -> BlockArena {
        let tpb = tokens_per_block(block_bytes, d, 4);
        BlockArena {
            d,
            tpb,
            free: Mutex::new(Vec::new()),
            capacity_blocks: AtomicUsize::new(usize::MAX),
            tenants: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(0),
            live_blocks: AtomicUsize::new(0),
            free_blocks: AtomicUsize::new(0),
            allocated_total: AtomicU64::new(0),
            reclaimed_total: AtomicU64::new(0),
            shared: Mutex::new(HashMap::new()),
            shared_freed_total: AtomicU64::new(0),
            spill: SpillStore::new(d, tpb),
            demoted_total: AtomicU64::new(0),
            promoted_total: AtomicU64::new(0),
            promoted_staged_total: AtomicU64::new(0),
        }
    }

    /// Shared-handle constructor (the form every owner actually wants).
    pub fn shared(d: usize, block_bytes: usize) -> Arc<BlockArena> {
        Arc::new(BlockArena::new(d, block_bytes))
    }

    /// Shared arena with a byte capacity (rounded down to whole blocks,
    /// minimum one block).
    pub fn shared_with_capacity(
        d: usize,
        block_bytes: usize,
        capacity_bytes: usize,
    ) -> Arc<BlockArena> {
        let a = BlockArena::new(d, block_bytes);
        let cap = (capacity_bytes / a.block_bytes()).max(1);
        a.set_capacity_blocks(Some(cap));
        Arc::new(a)
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Tokens per block for this arena's geometry.
    pub fn tokens_per_block(&self) -> usize {
        self.tpb
    }

    /// Bytes of one full block (K + V halves), f32 elements.
    pub fn block_bytes(&self) -> usize {
        2 * self.tpb * self.d * 4
    }

    /// The configured live-block cap (`None` = unbounded).
    pub fn capacity_blocks(&self) -> Option<usize> {
        match self.capacity_blocks.load(Ordering::Relaxed) {
            usize::MAX => None,
            c => Some(c),
        }
    }

    /// The configured capacity in bytes (`None` = unbounded).
    pub fn capacity_bytes(&self) -> Option<usize> {
        self.capacity_blocks().map(|c| c * self.block_bytes())
    }

    /// Set (or clear) the live-block cap. Lowering the cap below current
    /// occupancy does not evict anything — it only refuses new checkouts
    /// until reclamation brings occupancy back under the cap.
    pub fn set_capacity_blocks(&self, cap: Option<usize>) {
        self.capacity_blocks.store(cap.unwrap_or(usize::MAX), Ordering::Relaxed);
    }

    /// Set (or clear) a tenant's block quota.
    pub fn set_tenant_quota(&self, tenant: TenantId, quota_blocks: Option<usize>) {
        self.tenants.lock().unwrap().entry(tenant).or_default().quota_blocks = quota_blocks;
    }

    /// A tenant's configured quota (`None` = unbounded).
    pub fn tenant_quota_blocks(&self, tenant: TenantId) -> Option<usize> {
        self.tenants.lock().unwrap().get(&tenant).and_then(|u| u.quota_blocks)
    }

    /// Blocks currently checked out to `tenant`'s sessions.
    pub fn tenant_live_blocks(&self, tenant: TenantId) -> usize {
        self.tenants.lock().unwrap().get(&tenant).map(|u| u.live_blocks).unwrap_or(0)
    }

    /// Fallible checkout on behalf of `tenant`: recycled storage when the
    /// free-list has any, fresh zeroed storage otherwise. Refuses (with a
    /// typed error, no allocation performed) when the arena cap or the
    /// tenant's quota is reached. Returns the block's engine-global id
    /// and its storage.
    pub fn try_alloc_for(&self, tenant: TenantId) -> Result<(u64, BlockData), AllocError> {
        let mut free = self.free.lock().unwrap();
        let cap = self.capacity_blocks.load(Ordering::Relaxed);
        if self.live_blocks.load(Ordering::Relaxed) >= cap {
            return Err(AllocError::ArenaFull { capacity_blocks: cap });
        }
        {
            let mut tn = self.tenants.lock().unwrap();
            let u = tn.entry(tenant).or_default();
            if let Some(q) = u.quota_blocks {
                if u.live_blocks >= q {
                    return Err(AllocError::QuotaExceeded { tenant, quota_blocks: q });
                }
            }
            u.live_blocks += 1;
        }
        let data = match free.pop() {
            Some(d) => {
                self.free_blocks.fetch_sub(1, Ordering::Relaxed);
                d
            }
            None => BlockData::zeroed(self.tpb, self.d),
        };
        // live_blocks must advance BEFORE the free-list lock drops:
        // a concurrent allocator re-checks the cap under this lock, so
        // publishing the increment late would let two checkouts share
        // the last slot and overshoot the capacity.
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.live_blocks.fetch_add(1, Ordering::Relaxed);
        self.allocated_total.fetch_add(1, Ordering::Relaxed);
        drop(free);
        Ok((id, data))
    }

    /// Return `tenant`'s blocks to the free-list (their ids retire
    /// permanently; the tenant's occupancy drops accordingly).
    pub fn reclaim_for<I: IntoIterator<Item = BlockData>>(&self, tenant: TenantId, blocks: I) {
        let mut free = self.free.lock().unwrap();
        let mut n = 0usize;
        for b in blocks {
            debug_assert_eq!(b.keys.len(), self.tpb * self.d);
            free.push(b);
            n += 1;
        }
        if n == 0 {
            return;
        }
        // counters update under the free lock so allocators never observe
        // pushed storage without the matching live/free adjustment
        self.free_blocks.fetch_add(n, Ordering::Relaxed);
        self.live_blocks.fetch_sub(n, Ordering::Relaxed);
        self.reclaimed_total.fetch_add(n as u64, Ordering::Relaxed);
        drop(free);
        let mut tn = self.tenants.lock().unwrap();
        let u = tn.entry(tenant).or_default();
        u.live_blocks = u.live_blocks.saturating_sub(n);
    }

    /// Return default-tenant blocks to the free-list.
    pub fn reclaim<I: IntoIterator<Item = BlockData>>(&self, blocks: I) {
        self.reclaim_for(DEFAULT_TENANT, blocks)
    }

    /// Convert a live private block (already charged to `tenant`) into a
    /// shared one: its storage moves behind a refcount and the caller
    /// becomes the first holder (refs = 1). Occupancy does not change —
    /// the block stays one unit of `live_blocks`, billed to `tenant`
    /// until its last session reference exits.
    pub fn note_shared_for(&self, tenant: TenantId, id: u64, data: BlockData) -> Arc<BlockData> {
        debug_assert_eq!(data.keys.len(), self.tpb * self.d);
        let arc = Arc::new(data);
        let mut sh = self.shared.lock().unwrap();
        let prev = sh.insert(
            id,
            ShareInfo {
                data: Arc::clone(&arc),
                refs: 1,
                owners: vec![(tenant, 1)],
                charged: tenant,
            },
        );
        debug_assert!(prev.is_none(), "block {id} shared twice");
        arc
    }

    /// Take one more session hold of a shared block on behalf of
    /// `tenant` (no allocation, no capacity or quota charge — the block
    /// is already resident and billed once). `None` if `id` is not a
    /// shared block.
    pub fn share_block_for(&self, tenant: TenantId, id: u64) -> Option<Arc<BlockData>> {
        let mut sh = self.shared.lock().unwrap();
        let info = sh.get_mut(&id)?;
        info.refs += 1;
        let had_owners = !info.owners.is_empty();
        match info.owners.iter_mut().find(|(t, _)| *t == tenant) {
            Some((_, n)) => *n += 1,
            None => info.owners.push((tenant, 1)),
        }
        // A block held only by registry pins stays billed to its
        // departed last owner (there is nobody else to bill); the first
        // tenant to re-attach takes the charge over, so a departed
        // donor is never billed for a prefix another tenant is serving.
        if !had_owners && info.charged != tenant {
            let mut tn = self.tenants.lock().unwrap();
            let old = tn.entry(info.charged).or_default();
            old.live_blocks = old.live_blocks.saturating_sub(1);
            tn.entry(tenant).or_default().live_blocks += 1;
            info.charged = tenant;
        }
        Some(Arc::clone(&info.data))
    }

    /// Take a tenant-less hold of a shared block (the prefix registry's
    /// pin: keeps the block resident across session churn without
    /// appearing in any tenant's occupancy). `false` if not shared.
    pub fn pin_shared(&self, id: u64) -> bool {
        let mut sh = self.shared.lock().unwrap();
        match sh.get_mut(&id) {
            Some(info) => {
                info.refs += 1;
                true
            }
            None => false,
        }
    }

    /// Release one hold taken by `share_block_for` (or the original
    /// `note_shared_for` hold). The caller must drop its `Arc` clone
    /// first. Transfers the live-block charge to a surviving owner when
    /// the charged tenant's last session reference exits; frees the
    /// storage (back to the free-list) only at refcount zero. Returns
    /// whether the block was freed.
    pub fn release_shared_for(&self, tenant: TenantId, id: u64) -> bool {
        self.release_hold(id, Some(tenant))
    }

    /// Release a registry pin taken by `pin_shared`.
    pub fn unpin_shared(&self, id: u64) -> bool {
        self.release_hold(id, None)
    }

    fn release_hold(&self, id: u64, tenant: Option<TenantId>) -> bool {
        let mut sh = self.shared.lock().unwrap();
        let Some(info) = sh.get_mut(&id) else {
            debug_assert!(false, "release of a non-shared block {id}");
            return false;
        };
        debug_assert!(info.refs > 0);
        info.refs -= 1;
        if let Some(t) = tenant {
            if let Some(p) = info.owners.iter().position(|(ot, _)| *ot == t) {
                info.owners[p].1 -= 1;
                if info.owners[p].1 == 0 {
                    info.owners.remove(p);
                }
            } else {
                debug_assert!(false, "tenant {t} released a hold it never took on {id}");
            }
            // Charge transfer: the billed tenant's last session reference
            // left but other session owners remain — the block's single
            // live-block charge moves to a surviving owner.
            if t == info.charged
                && !info.owners.iter().any(|(ot, _)| *ot == t)
                && !info.owners.is_empty()
            {
                let new = info.owners[0].0;
                let mut tn = self.tenants.lock().unwrap();
                let old_u = tn.entry(info.charged).or_default();
                old_u.live_blocks = old_u.live_blocks.saturating_sub(1);
                tn.entry(new).or_default().live_blocks += 1;
                info.charged = new;
            }
        }
        if info.refs > 0 {
            return false;
        }
        // Last hold gone: retire the id and recycle the storage.
        let info = sh.remove(&id).unwrap();
        drop(sh);
        let charged = info.charged;
        match Arc::try_unwrap(info.data) {
            Ok(data) => {
                let mut free = self.free.lock().unwrap();
                free.push(data);
                self.free_blocks.fetch_add(1, Ordering::Relaxed);
                self.live_blocks.fetch_sub(1, Ordering::Relaxed);
                self.reclaimed_total.fetch_add(1, Ordering::Relaxed);
            }
            Err(_) => {
                // a holder released before dropping its clone: the
                // storage cannot be recycled, but the accounting must
                // still retire the block
                debug_assert!(false, "shared block {id} released while a clone is live");
                self.live_blocks.fetch_sub(1, Ordering::Relaxed);
                self.reclaimed_total.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.shared_freed_total.fetch_add(1, Ordering::Relaxed);
        let mut tn = self.tenants.lock().unwrap();
        let u = tn.entry(charged).or_default();
        u.live_blocks = u.live_blocks.saturating_sub(1);
        true
    }

    /// Whether `id` is currently a shared block.
    pub fn is_shared(&self, id: u64) -> bool {
        self.shared.lock().unwrap().contains_key(&id)
    }

    /// Outstanding holds of a shared block (0 if not shared).
    pub fn shared_refcount(&self, id: u64) -> usize {
        self.shared.lock().unwrap().get(&id).map(|i| i.refs).unwrap_or(0)
    }

    /// Shared blocks currently live (each counted once in `live_blocks`).
    pub fn shared_blocks_live(&self) -> usize {
        self.shared.lock().unwrap().len()
    }

    /// Total session references across all shared blocks (the dedup
    /// numerator: N sessions sharing one block contribute N here and 1
    /// to `shared_blocks_live`). Registry pins are excluded.
    pub fn shared_session_refs(&self) -> usize {
        self.shared
            .lock()
            .unwrap()
            .values()
            .map(|i| i.owners.iter().map(|(_, n)| *n).sum::<usize>())
            .sum()
    }

    /// Shared blocks ever fully released (refcount reached zero).
    pub fn shared_freed_total(&self) -> u64 {
        self.shared_freed_total.load(Ordering::Relaxed)
    }

    /// The cold-tier spill store behind this arena's block ids.
    pub fn spill(&self) -> &SpillStore {
        &self.spill
    }

    /// Demote a live hot block on behalf of `tenant`: its data moves to
    /// a cold page (same id), its hot storage returns to the free-list,
    /// and its hot occupancy (arena + tenant) drops — the resident hot
    /// footprint never grows. Panics if the id is already cold (a block
    /// must never be in two tiers).
    pub fn demote_for(&self, tenant: TenantId, id: u64, data: BlockData) {
        self.demote_for_with(tenant, id, data, false)
    }

    /// [`BlockArena::demote_for`] with an accuracy-bound bit: when the
    /// caller cleared this block for lossy storage, the spill store's
    /// configured codec compresses the page (exact otherwise).
    pub fn demote_for_with(&self, tenant: TenantId, id: u64, data: BlockData, lossy_ok: bool) {
        debug_assert_eq!(data.keys.len(), self.tpb * self.d);
        self.spill.write_with(id, &data, lossy_ok);
        let mut free = self.free.lock().unwrap();
        free.push(data);
        self.free_blocks.fetch_add(1, Ordering::Relaxed);
        self.live_blocks.fetch_sub(1, Ordering::Relaxed);
        self.reclaimed_total.fetch_add(1, Ordering::Relaxed);
        drop(free);
        self.demoted_total.fetch_add(1, Ordering::Relaxed);
        let mut tn = self.tenants.lock().unwrap();
        let u = tn.entry(tenant).or_default();
        u.live_blocks = u.live_blocks.saturating_sub(1);
    }

    /// Promote a cold block back into the hot tier on behalf of
    /// `tenant`: hot storage is checked out under the same capacity and
    /// quota gates as a fresh alloc (so promotion can never violate the
    /// hot cap) and refilled bit-exactly from the cold page. Returns the
    /// storage plus whether the page was served from the async-prefetch
    /// staging area. Panics if the id is not cold.
    pub fn try_promote_for(
        &self,
        tenant: TenantId,
        id: u64,
    ) -> Result<(BlockData, bool), AllocError> {
        let mut free = self.free.lock().unwrap();
        let cap = self.capacity_blocks.load(Ordering::Relaxed);
        if self.live_blocks.load(Ordering::Relaxed) >= cap {
            return Err(AllocError::ArenaFull { capacity_blocks: cap });
        }
        {
            let mut tn = self.tenants.lock().unwrap();
            let u = tn.entry(tenant).or_default();
            if let Some(q) = u.quota_blocks {
                if u.live_blocks >= q {
                    return Err(AllocError::QuotaExceeded { tenant, quota_blocks: q });
                }
            }
            u.live_blocks += 1;
        }
        let mut data = match free.pop() {
            Some(d) => {
                self.free_blocks.fetch_sub(1, Ordering::Relaxed);
                d
            }
            None => BlockData::zeroed(self.tpb, self.d),
        };
        self.live_blocks.fetch_add(1, Ordering::Relaxed);
        self.allocated_total.fetch_add(1, Ordering::Relaxed);
        drop(free);
        let staged = self
            .spill
            .take_into(id, &mut data)
            .expect("promote of a block that is not in the cold tier");
        self.promoted_total.fetch_add(1, Ordering::Relaxed);
        if staged {
            self.promoted_staged_total.fetch_add(1, Ordering::Relaxed);
        }
        Ok((data, staged))
    }

    /// Drop a cold block outright (finished sessions reclaim cold
    /// blocks without promoting them). Returns false if the id is not
    /// cold.
    pub fn drop_cold(&self, id: u64) -> bool {
        self.spill.drop_block(id)
    }

    /// Stage a cold page for a later promotion (async prefetch; safe to
    /// call from thread-pool jobs — this is the worker-side read that
    /// overlaps decode). Returns false if the block is not cold.
    pub fn prefetch(&self, id: u64) -> bool {
        self.spill.stage(id)
    }

    /// Open a new intra-step staging epoch (pipelined decode calls this
    /// once per decode step). Staged pages survive exactly one epoch
    /// turnover (double buffering); anything older was selected by a
    /// prior step and never consumed — it is dropped and counted.
    pub fn begin_staging_epoch(&self) {
        self.spill.begin_staging_epoch();
    }

    /// Bound the staging area to `cap` pages (oldest evicted first).
    pub fn set_staging_cap(&self, cap: Option<usize>) {
        self.spill.set_staging_cap(cap);
    }

    /// Fault-injection shim: delay every cold-page read by `us`
    /// microseconds (+ a deterministic per-id jitter in `0..jitter_us`).
    pub fn set_read_fault(&self, us: u64, jitter_us: u64) {
        self.spill.set_read_fault(us, jitter_us);
    }

    /// Staged pages dropped as stale (never consumed) or evicted by the
    /// staging cap.
    pub fn staged_stale_dropped(&self) -> u64 {
        self.spill.staged_stale_dropped()
    }

    /// Cold-page KV reads ever served (staged + synchronous file).
    pub fn cold_reads_total(&self) -> u64 {
        self.spill.cold_reads_total()
    }

    /// Cold-page KV reads served from the staging area — i.e. reads
    /// whose file I/O completed under compute instead of stalling it.
    pub fn cold_reads_staged(&self) -> u64 {
        self.spill.cold_reads_staged()
    }

    /// Pages currently staged for promotion or pipelined gather.
    pub fn staged_blocks(&self) -> usize {
        self.spill.staged_blocks()
    }

    /// Blocks currently resident in the cold tier.
    pub fn cold_blocks(&self) -> usize {
        self.spill.cold_blocks()
    }

    /// Bytes currently resident in the cold tier.
    pub fn cold_bytes(&self) -> usize {
        self.spill.cold_bytes()
    }

    /// Live blocks across both tiers (hot checked-out + cold spilled).
    pub fn total_live_blocks(&self) -> usize {
        self.live_blocks() + self.cold_blocks()
    }

    /// Blocks ever demoted into the cold tier.
    pub fn demoted_total(&self) -> u64 {
        self.demoted_total.load(Ordering::Relaxed)
    }

    /// Blocks ever promoted back into the hot tier.
    pub fn promoted_total(&self) -> u64 {
        self.promoted_total.load(Ordering::Relaxed)
    }

    /// Promotions served from the async-prefetch staging area.
    pub fn promoted_staged_total(&self) -> u64 {
        self.promoted_staged_total.load(Ordering::Relaxed)
    }

    /// Blocks currently checked out to live sessions.
    pub fn live_blocks(&self) -> usize {
        self.live_blocks.load(Ordering::Relaxed)
    }

    /// Recycled blocks waiting on the free-list.
    pub fn free_blocks(&self) -> usize {
        self.free_blocks.load(Ordering::Relaxed)
    }

    /// Bytes held by live (checked-out) blocks.
    pub fn live_bytes(&self) -> usize {
        self.live_blocks() * self.block_bytes()
    }

    /// Bytes resident in the arena overall (live + free-list).
    pub fn resident_bytes(&self) -> usize {
        (self.live_blocks() + self.free_blocks()) * self.block_bytes()
    }

    /// Hot-storage checkouts ever performed (fresh or recycled; fresh
    /// allocs and promotions both count, so `live_blocks =
    /// allocated_total - reclaimed_total` holds in tiered runs too).
    pub fn allocated_total(&self) -> u64 {
        self.allocated_total.load(Ordering::Relaxed)
    }

    /// Hot storage ever returned to the free-list (reclaims and
    /// demotions both count).
    pub fn reclaimed_total(&self) -> u64 {
        self.reclaimed_total.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Uncapped checkout for the default tenant (test shorthand).
    fn alloc(a: &BlockArena) -> (u64, BlockData) {
        a.try_alloc_for(DEFAULT_TENANT).unwrap()
    }

    #[test]
    fn geometry_matches_helper() {
        let a = BlockArena::new(32, 2048);
        assert_eq!(a.tokens_per_block(), 8);
        assert_eq!(a.block_bytes(), 2 * 8 * 32 * 4);
        assert_eq!(a.live_blocks(), 0);
        assert_eq!(a.capacity_blocks(), None);
    }

    #[test]
    fn alloc_reclaim_recycles_storage_not_ids() {
        let a = BlockArena::new(4, 256);
        let (id0, b0) = alloc(&a);
        let (id1, b1) = alloc(&a);
        assert_eq!((id0, id1), (0, 1));
        assert_eq!(a.live_blocks(), 2);
        assert_eq!(a.live_bytes(), 2 * a.block_bytes());
        a.reclaim([b0, b1]);
        assert_eq!(a.live_blocks(), 0);
        assert_eq!(a.free_blocks(), 2);
        // storage recycled, ids fresh
        let (id2, b2) = alloc(&a);
        assert_eq!(id2, 2);
        assert_eq!(a.free_blocks(), 1);
        assert_eq!(a.allocated_total(), 3);
        assert_eq!(a.reclaimed_total(), 2);
        a.reclaim([b2]);
    }

    #[test]
    fn concurrent_alloc_reclaim_balances() {
        let a = BlockArena::shared(8, 512);
        let mut handles = Vec::new();
        for _ in 0..4 {
            let a = Arc::clone(&a);
            handles.push(std::thread::spawn(move || {
                for _ in 0..200 {
                    let (_, b) = a.try_alloc_for(DEFAULT_TENANT).unwrap();
                    a.reclaim([b]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.live_blocks(), 0);
        assert_eq!(a.allocated_total(), 800);
        assert_eq!(a.reclaimed_total(), 800);
    }

    #[test]
    fn capacity_refuses_at_cap_and_readmits_after_reclaim() {
        let a = BlockArena::new(4, 256);
        a.set_capacity_blocks(Some(2));
        let (_, b0) = a.try_alloc_for(DEFAULT_TENANT).unwrap();
        let (_, b1) = a.try_alloc_for(DEFAULT_TENANT).unwrap();
        assert_eq!(
            a.try_alloc_for(DEFAULT_TENANT).unwrap_err(),
            AllocError::ArenaFull { capacity_blocks: 2 }
        );
        a.reclaim([b0]);
        // reclamation frees capacity; the freed storage is recycled so the
        // resident footprint stays at the cap
        let (_, b2) = a.try_alloc_for(DEFAULT_TENANT).unwrap();
        assert_eq!(a.live_blocks(), 2);
        assert_eq!(a.resident_bytes(), 2 * a.block_bytes());
        a.reclaim([b1, b2]);
        assert_eq!(a.live_blocks(), 0);
    }

    #[test]
    fn quota_is_per_tenant() {
        let a = BlockArena::new(4, 256);
        a.set_tenant_quota(1, Some(1));
        let (_, b1) = a.try_alloc_for(1).unwrap();
        assert_eq!(
            a.try_alloc_for(1).unwrap_err(),
            AllocError::QuotaExceeded { tenant: 1, quota_blocks: 1 }
        );
        // another tenant is unaffected by tenant 1's quota
        let (_, b2) = a.try_alloc_for(2).unwrap();
        assert_eq!(a.tenant_live_blocks(1), 1);
        assert_eq!(a.tenant_live_blocks(2), 1);
        a.reclaim_for(1, [b1]);
        assert_eq!(a.tenant_live_blocks(1), 0);
        let (_, b3) = a.try_alloc_for(1).unwrap();
        a.reclaim_for(1, [b3]);
        a.reclaim_for(2, [b2]);
        assert_eq!(a.live_blocks(), 0);
    }

    #[test]
    fn byte_capacity_rounds_to_blocks() {
        let a = BlockArena::shared_with_capacity(4, 256, 1000);
        // block_bytes = 2 * 8 * 4 * 4 = 256 -> 3 whole blocks fit in 1000 B
        assert_eq!(a.block_bytes(), 256);
        assert_eq!(a.capacity_blocks(), Some(3));
        assert_eq!(a.capacity_bytes(), Some(768));
    }

    #[test]
    fn demote_frees_hot_occupancy_and_promote_restores_it() {
        let a = BlockArena::new(4, 256);
        a.set_capacity_blocks(Some(2));
        let (id0, b0) = a.try_alloc_for(7).unwrap();
        let (_, b1) = a.try_alloc_for(7).unwrap();
        assert!(a.try_alloc_for(7).is_err(), "hot tier full");
        // demote-then-retry: spilling id0 opens a hot slot
        a.demote_for(7, id0, b0);
        assert_eq!(a.live_blocks(), 1);
        assert_eq!(a.cold_blocks(), 1);
        assert_eq!(a.total_live_blocks(), 2);
        assert_eq!(a.tenant_live_blocks(7), 1);
        let (_, b2) = a.try_alloc_for(7).unwrap();
        // hot tier full again: promotion respects the cap
        assert!(matches!(
            a.try_promote_for(7, id0),
            Err(AllocError::ArenaFull { capacity_blocks: 2 })
        ));
        a.reclaim_for(7, [b2]);
        let (b0_back, staged) = a.try_promote_for(7, id0).unwrap();
        assert!(!staged);
        assert_eq!(a.cold_blocks(), 0);
        assert_eq!(a.live_blocks(), 2);
        assert_eq!(a.demoted_total(), 1);
        assert_eq!(a.promoted_total(), 1);
        // resident hot footprint never exceeded the cap through the
        // whole demote/promote cycle
        assert!(a.resident_bytes() <= 2 * a.block_bytes());
        a.reclaim_for(7, [b0_back, b1]);
        assert_eq!(a.live_blocks(), 0);
        assert_eq!(a.tenant_live_blocks(7), 0);
    }

    #[test]
    fn prefetch_stages_cold_pages_for_promotion() {
        let a = BlockArena::new(4, 256);
        let (id, b) = a.try_alloc_for(DEFAULT_TENANT).unwrap();
        a.demote_for(DEFAULT_TENANT, id, b);
        assert!(a.prefetch(id), "cold block must stage");
        let (data, staged) = a.try_promote_for(DEFAULT_TENANT, id).unwrap();
        assert!(staged, "promotion must consume the staged page");
        assert_eq!(a.promoted_staged_total(), 1);
        // prefetching a hot block is a no-op
        assert!(!a.prefetch(id));
        a.reclaim([data]);
    }

    #[test]
    fn dropped_cold_blocks_never_promote() {
        let a = BlockArena::new(4, 256);
        let (id, b) = a.try_alloc_for(DEFAULT_TENANT).unwrap();
        a.demote_for(DEFAULT_TENANT, id, b);
        assert_eq!(a.cold_blocks(), 1);
        assert!(a.drop_cold(id));
        assert_eq!(a.cold_blocks(), 0);
        assert!(!a.drop_cold(id));
        assert_eq!(a.live_blocks(), 0);
    }

    #[test]
    fn shared_block_charges_once_and_frees_at_refcount_zero() {
        let a = BlockArena::new(4, 256);
        let (id, data) = a.try_alloc_for(1).unwrap();
        assert_eq!(a.tenant_live_blocks(1), 1);
        // seal: tenant 1 stays charged, refcount 1
        let h1 = a.note_shared_for(1, id, data);
        assert!(a.is_shared(id));
        assert_eq!(a.shared_refcount(id), 1);
        assert_eq!((a.live_blocks(), a.tenant_live_blocks(1)), (1, 1));
        // two more sessions + a registry pin: no new charge anywhere
        let h2 = a.share_block_for(2, id).unwrap();
        let h3 = a.share_block_for(2, id).unwrap();
        assert!(a.pin_shared(id));
        assert_eq!(a.shared_refcount(id), 4);
        assert_eq!(a.shared_session_refs(), 3);
        assert_eq!(a.shared_blocks_live(), 1);
        assert_eq!(a.live_blocks(), 1, "a shared block is counted once");
        assert_eq!(a.tenant_live_blocks(2), 0, "sharers are not charged");
        // charged owner exits: the charge transfers to tenant 2
        drop(h1);
        assert!(!a.release_shared_for(1, id));
        assert_eq!(a.tenant_live_blocks(1), 0);
        assert_eq!(a.tenant_live_blocks(2), 1);
        // remaining holds drain; storage recycles only at zero
        drop(h2);
        assert!(!a.release_shared_for(2, id));
        drop(h3);
        assert!(!a.release_shared_for(2, id));
        assert_eq!(a.live_blocks(), 1, "registry pin keeps the block live");
        assert!(a.unpin_shared(id));
        assert_eq!(a.live_blocks(), 0);
        assert_eq!(a.free_blocks(), 1);
        assert_eq!(a.tenant_live_blocks(2), 0);
        assert!(!a.is_shared(id));
        assert_eq!(a.shared_freed_total(), 1);
    }

    #[test]
    fn reattach_after_pin_only_takes_the_charge_from_the_departed_owner() {
        let a = BlockArena::new(4, 256);
        let (id, data) = a.try_alloc_for(1).unwrap();
        let h1 = a.note_shared_for(1, id, data);
        assert!(a.pin_shared(id), "registry pin");
        // donor tenant 1 fully exits; only the pin keeps the block — the
        // departed tenant stays billed (nobody else to bill)
        drop(h1);
        a.release_shared_for(1, id);
        assert_eq!((a.tenant_live_blocks(1), a.live_blocks()), (1, 1));
        // tenant 2 attaches later: the charge must follow the live owner
        let h2 = a.share_block_for(2, id).unwrap();
        assert_eq!(a.tenant_live_blocks(1), 0, "departed donor must stop paying");
        assert_eq!(a.tenant_live_blocks(2), 1);
        drop(h2);
        a.release_shared_for(2, id);
        a.unpin_shared(id);
        assert_eq!(a.live_blocks(), 0);
        assert_eq!(a.tenant_live_blocks(2), 0);
    }

    #[test]
    fn sharing_does_not_consume_capacity_or_quota() {
        let a = BlockArena::new(4, 256);
        a.set_capacity_blocks(Some(1));
        a.set_tenant_quota(2, Some(0));
        let (id, data) = a.try_alloc_for(1).unwrap();
        let h1 = a.note_shared_for(1, id, data);
        // arena at cap, tenant 2 at quota 0 — sharing still succeeds
        let h2 = a.share_block_for(2, id).unwrap();
        assert_eq!(a.live_blocks(), 1);
        assert_eq!(a.tenant_live_blocks(2), 0);
        drop((h1, h2));
        a.release_shared_for(1, id);
        a.release_shared_for(2, id);
        assert_eq!(a.live_blocks(), 0);
    }

    #[test]
    fn failed_alloc_changes_nothing() {
        let a = BlockArena::new(4, 256);
        a.set_capacity_blocks(Some(1));
        let (_, b0) = a.try_alloc_for(7).unwrap();
        let before = (a.live_blocks(), a.free_blocks(), a.allocated_total(), a.tenant_live_blocks(7));
        assert!(a.try_alloc_for(7).is_err());
        let after = (a.live_blocks(), a.free_blocks(), a.allocated_total(), a.tenant_live_blocks(7));
        assert_eq!(before, after, "a refused checkout must not mutate accounting");
        a.reclaim_for(7, [b0]);
    }
}
