//! Prefix registry: cross-session dedup of shared prompt prefixes
//! (DESIGN.md §2 "Prefix sharing & CoW").
//!
//! Identical prompt prefixes (system prompts, few-shot templates,
//! shared documents) are the dominant KV redundancy in multi-user
//! serving: every session re-materializes the same blocks and re-runs
//! the same segmented clustering. The registry maps **token-hash
//! chains** — one chained hash per block-aligned prefix segment (the
//! sink tokens plus `k` full build segments) — to sealed block runs
//! plus the wave-index cluster metadata (centroids, value sums, token
//! positions) needed to graft them under a new session's index. A
//! prefill that matches the longest registered chain checks the blocks
//! out as shared, refcounted views ([`BlockArena::share_block_for`])
//! instead of recomputing and re-clustering them; its private tail
//! appends normally.
//!
//! Determinism contract: chain hashes cover *token ids*, so two prompts
//! match only if the covered tokens are identical; K/V vectors of a
//! causal model at those positions are then identical, and with
//! content-derived clustering seeds ([`ChainGeometry::content_seed`])
//! the donor's sealed clusters are bit-identical to what the matching
//! session would have built itself — grafting changes placement, never
//! results.
//!
//! Lifetime: registering an entry pins every sealed block
//! ([`BlockArena::pin_shared`]) so the prefix survives session churn;
//! evicting or clearing an entry unpins them, and the storage returns
//! to the arena free-list once the last attached session exits
//! (refcount zero). The registry never holds block bytes itself — the
//! arena's canonical handle does.

use super::arena::BlockArena;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// FNV-1a over a byte stream, seeded (chainable).
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    if h == 0 {
        h = 0xcbf29ce484222325;
    }
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn hash_tokens(seed: u64, tokens: &[i32]) -> u64 {
    let mut h = fnv1a(seed, b"tok");
    for &t in tokens {
        h = fnv1a(h, &t.to_le_bytes());
    }
    h
}

/// The block-aligned chain geometry: how a prompt is cut into hashable
/// prefix segments. Must mirror the wave index's build segmentation
/// (`ZoneConfig`: sink tokens stay out of clustering, the middle is
/// clustered in `segment`-token chunks, the last `local` tokens pend)
/// so a registered chain link always corresponds to whole sealed
/// clusters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChainGeometry {
    /// Steady-sink tokens (excluded from clustering, included in every
    /// link's hash).
    pub sink: usize,
    /// Build-segment length in tokens (one chain link per full segment).
    pub segment: usize,
    /// Steady-local tokens at the end of the context (never sealed: a
    /// link is graftable only when it leaves at least `local` tokens of
    /// private tail).
    pub local: usize,
}

impl ChainGeometry {
    /// Geometry fingerprint folded into every hash so entries from a
    /// different segmentation can never collide into a match.
    fn base(&self) -> u64 {
        let mut h = fnv1a(0, b"prefix-chain-v1");
        h = fnv1a(h, &(self.sink as u64).to_le_bytes());
        h = fnv1a(h, &(self.segment as u64).to_le_bytes());
        h
    }

    /// Chain links of a prompt: `(covered_tokens, chain_hash)` pairs,
    /// shortest first. Link `k` covers the sink plus the first `k` full
    /// build segments.
    pub fn links(&self, tokens: &[i32]) -> Vec<(usize, u64)> {
        let mut out = Vec::new();
        if tokens.len() < self.sink {
            return out;
        }
        let mut h = hash_tokens(self.base(), &tokens[..self.sink]);
        let mut covered = self.sink;
        while covered + self.segment <= tokens.len() {
            h = hash_tokens(h, &tokens[covered..covered + self.segment]);
            covered += self.segment;
            out.push((covered, h));
        }
        out
    }

    /// Content-derived clustering seed: a hash of the sink plus first
    /// build segment (or the whole prompt when shorter). Prompts that
    /// share their first segment — the precondition for sharing
    /// anything — get the same seed, so the per-segment k-means of the
    /// common region is bit-identical across sessions regardless of
    /// session id.
    pub fn content_seed(&self, tokens: &[i32]) -> u64 {
        let n = tokens.len().min(self.sink + self.segment);
        hash_tokens(self.base(), &tokens[..n])
    }
}

/// One sealed block of a prefix run (data lives in the arena behind the
/// refcount; the registry records only the id and valid length).
#[derive(Clone, Copy, Debug)]
pub struct SealedBlockMeta {
    pub id: u64,
    pub len: u16,
}

/// One sealed cluster: the wave-index metadata a grafting session needs
/// (centroid, value sum, token positions) plus its block run.
#[derive(Clone, Debug)]
pub struct SealedCluster {
    pub centroid: Vec<f32>,
    pub vsum: Vec<f32>,
    pub pos: Vec<u32>,
    pub blocks: Vec<SealedBlockMeta>,
}

/// All sealed clusters of one (layer, kv-head) slot, in segment order.
#[derive(Clone, Debug, Default)]
pub struct SealedSlot {
    pub clusters: Vec<SealedCluster>,
}

impl SealedSlot {
    pub fn n_blocks(&self) -> usize {
        self.clusters.iter().map(|c| c.blocks.len()).sum()
    }
}

/// A successful registry match, ready to graft.
#[derive(Clone)]
pub struct PrefixMatch {
    /// Chain hash the match resolved to.
    pub key: u64,
    /// Prompt tokens covered by the sealed prefix.
    pub covered: usize,
    /// Per-slot sealed clusters (`layers × kv_heads` entries).
    pub slots: Arc<Vec<SealedSlot>>,
}

struct PrefixEntry {
    covered: usize,
    slots: Arc<Vec<SealedSlot>>,
    /// Prefills this entry served (eviction weight: hot templates
    /// survive cold churn).
    hits: u64,
    /// Registry tick of the last hit (or registration), the LRU
    /// tiebreak among equally-hit entries.
    last_use: u64,
}

struct RegState {
    entries: HashMap<u64, PrefixEntry>,
    /// Monotone use counter stamping `last_use` (hit-weighted LRU
    /// eviction at `max_entries`: victim = least hits, then least
    /// recently used).
    tick: u64,
}

/// Cross-session prefix registry over one [`BlockArena`].
pub struct PrefixRegistry {
    arena: Arc<BlockArena>,
    geom: ChainGeometry,
    /// Registered entries capped at this count (0 disables storage:
    /// probes always miss — the seeds-only configuration).
    max_entries: usize,
    state: Mutex<RegState>,
    hits: AtomicU64,
    misses: AtomicU64,
    matched_tokens: AtomicU64,
}

impl PrefixRegistry {
    pub fn new(arena: Arc<BlockArena>, geom: ChainGeometry, max_entries: usize) -> PrefixRegistry {
        PrefixRegistry {
            arena,
            geom,
            max_entries,
            state: Mutex::new(RegState { entries: HashMap::new(), tick: 0 }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            matched_tokens: AtomicU64::new(0),
        }
    }

    pub fn shared(
        arena: Arc<BlockArena>,
        geom: ChainGeometry,
        max_entries: usize,
    ) -> Arc<PrefixRegistry> {
        Arc::new(PrefixRegistry::new(arena, geom, max_entries))
    }

    pub fn geometry(&self) -> ChainGeometry {
        self.geom
    }

    pub fn arena(&self) -> &Arc<BlockArena> {
        &self.arena
    }

    /// Graftable chain links of a prompt: links whose coverage leaves at
    /// least the steady-local tail private (a fresh build of this very
    /// prompt would have clustered exactly those segments).
    pub fn links(&self, tokens: &[i32]) -> Vec<(usize, u64)> {
        let limit = tokens.len().saturating_sub(self.geom.local);
        let mut links = self.geom.links(tokens);
        links.retain(|&(covered, _)| covered <= limit);
        links
    }

    /// The longest registered match for a prompt, with hit/miss
    /// accounting (the serving path — the engine checks out the result).
    pub fn match_longest(&self, tokens: &[i32]) -> Option<PrefixMatch> {
        let links = self.links(tokens);
        let mut st = self.state.lock().unwrap();
        for &(covered, key) in links.iter().rev() {
            if st.entries.contains_key(&key) {
                st.tick += 1;
                let tick = st.tick;
                let e = st.entries.get_mut(&key).expect("checked above");
                debug_assert_eq!(e.covered, covered);
                e.hits += 1;
                e.last_use = tick;
                let slots = Arc::clone(&e.slots);
                self.hits.fetch_add(1, Ordering::Relaxed);
                self.matched_tokens.fetch_add(covered as u64, Ordering::Relaxed);
                return Some(PrefixMatch { key, covered, slots });
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Tokens the longest registered match would cover, with NO
    /// counter side effects (the scheduler's admission gate polls this
    /// on every pass to discount a queued request's footprint).
    pub fn matched_tokens_for(&self, tokens: &[i32]) -> usize {
        self.matched_tokens_for_links(&self.links(tokens))
    }

    /// Probe pre-computed chain links (see [`PrefixRegistry::links`])
    /// without re-hashing the prompt — the gate caches a queued
    /// request's links once and re-probes only the registry map on
    /// every pass (entries registered later still discount it). No
    /// counter side effects.
    pub fn matched_tokens_for_links(&self, links: &[(usize, u64)]) -> usize {
        let st = self.state.lock().unwrap();
        links
            .iter()
            .rev()
            .find(|(_, key)| st.entries.contains_key(key))
            .map(|&(covered, _)| covered)
            .unwrap_or(0)
    }

    /// Whether a chain key is registered.
    pub fn contains(&self, key: u64) -> bool {
        self.state.lock().unwrap().entries.contains_key(&key)
    }

    /// Register a sealed prefix under its chain key, pinning every block
    /// resident. Blocks must already be shared in the arena
    /// (`HeadStore::seal_block`). Returns false (and pins nothing) if
    /// the key is already registered or the registry is disabled; the
    /// caller's sealed blocks then simply free when its last holder
    /// exits. Over capacity, evicts the least-hit entry (ties broken by
    /// least-recent use, then key): hot templates survive a churn of
    /// one-shot prefixes that plain FIFO would let push them out.
    pub fn register(&self, key: u64, covered: usize, slots: Vec<SealedSlot>) -> bool {
        if self.max_entries == 0 {
            return false;
        }
        let mut st = self.state.lock().unwrap();
        if st.entries.contains_key(&key) {
            return false;
        }
        for slot in &slots {
            for c in &slot.clusters {
                for b in &c.blocks {
                    let pinned = self.arena.pin_shared(b.id);
                    debug_assert!(pinned, "registering an unsealed block {}", b.id);
                }
            }
        }
        st.tick += 1;
        let tick = st.tick;
        st.entries
            .insert(key, PrefixEntry { covered, slots: Arc::new(slots), hits: 0, last_use: tick });
        while st.entries.len() > self.max_entries {
            let victim = st
                .entries
                .iter()
                .min_by_key(|(k, e)| (e.hits, e.last_use, **k))
                .map(|(k, _)| *k)
                .expect("non-empty while over capacity");
            let e = st.entries.remove(&victim).expect("victim came from this map");
            Self::unpin_entry(&self.arena, &e);
        }
        true
    }

    fn unpin_entry(arena: &BlockArena, e: &PrefixEntry) {
        for slot in e.slots.iter() {
            for c in &slot.clusters {
                for b in &c.blocks {
                    arena.unpin_shared(b.id);
                }
            }
        }
    }

    /// Drop every entry, unpinning all sealed blocks (storage frees as
    /// attached sessions exit; immediately if none are attached).
    pub fn clear(&self) {
        let mut st = self.state.lock().unwrap();
        for (_, e) in st.entries.drain() {
            Self::unpin_entry(&self.arena, &e);
        }
    }

    /// Registered prefixes.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocks pinned across all entries.
    pub fn pinned_blocks(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.entries
            .values()
            .map(|e| e.slots.iter().map(|s| s.n_blocks()).sum::<usize>())
            .sum()
    }

    /// Prefills that matched a registered prefix.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Prefills that found no registered prefix.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Prompt tokens served from sealed prefixes (cumulative).
    pub fn matched_tokens(&self) -> u64 {
        self.matched_tokens.load(Ordering::Relaxed)
    }
}

impl Drop for PrefixRegistry {
    fn drop(&mut self) {
        self.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::TenantId;

    fn geom() -> ChainGeometry {
        ChainGeometry { sink: 4, segment: 16, local: 8 }
    }

    #[test]
    fn links_are_segment_aligned_and_content_keyed() {
        let g = geom();
        let a: Vec<i32> = (0..60).collect();
        let links = g.links(&a);
        // sink 4 + segments at 20, 36, 52 (next would need 68 > 60)
        assert_eq!(links.iter().map(|l| l.0).collect::<Vec<_>>(), vec![20, 36, 52]);
        // same prefix, different tail: shared links match, later differ
        let mut b = a.clone();
        b[40] += 1;
        let lb = g.links(&b);
        assert_eq!(links[0], lb[0]);
        assert_eq!(links[1], lb[1]);
        assert_ne!(links[2].1, lb[2].1);
        // different first token: nothing matches
        let mut c = a.clone();
        c[0] += 1;
        assert_ne!(g.links(&c)[0].1, links[0].1);
        // content seed agrees across prompts sharing the first segment
        assert_eq!(g.content_seed(&a), g.content_seed(&b));
        assert_ne!(g.content_seed(&a), g.content_seed(&c));
    }

    #[test]
    fn registry_matches_longest_and_respects_local_tail() {
        let arena = BlockArena::shared(4, 256);
        let reg = PrefixRegistry::new(Arc::clone(&arena), geom(), 8);
        let toks: Vec<i32> = (0..60).collect();
        let links = reg.links(&toks);
        // the 52-token link would leave only 8 tokens of tail — exactly
        // `local`, still allowed; all three links are graftable
        assert_eq!(links.len(), 3);
        // register the first two links (no sealed blocks needed to match)
        assert!(reg.register(links[0].1, links[0].0, vec![SealedSlot::default()]));
        assert!(reg.register(links[1].1, links[1].0, vec![SealedSlot::default()]));
        assert!(!reg.register(links[1].1, links[1].0, vec![]), "no double registration");
        let m = reg.match_longest(&toks).expect("must match");
        assert_eq!(m.covered, 36, "longest registered link wins");
        assert_eq!(reg.hits(), 1);
        assert_eq!(reg.matched_tokens(), 36);
        assert_eq!(reg.matched_tokens_for(&toks), 36, "probe is side-effect free");
        assert_eq!(reg.hits(), 1);
        // a shorter prompt can only use links that keep its own local
        // tail private: at 40 tokens the 36-token link is out of reach
        let short = &toks[..40];
        assert_eq!(reg.matched_tokens_for(short), 20);
        assert!(reg.match_longest(&toks[..20]).is_none());
        assert_eq!(reg.misses(), 1);
    }

    #[test]
    fn eviction_and_clear_unpin_blocks() {
        let arena = BlockArena::shared(4, 256);
        let reg = PrefixRegistry::new(Arc::clone(&arena), geom(), 1);
        // two sealed single-block prefixes
        let mk_sealed = |tenant: TenantId| {
            let (id, data) = arena.try_alloc_for(tenant).unwrap();
            let arc = arena.note_shared_for(tenant, id, data);
            // the "session" immediately exits: only the pin keeps it
            drop(arc);
            let slot = SealedSlot {
                clusters: vec![SealedCluster {
                    centroid: vec![0.0; 4],
                    vsum: vec![0.0; 4],
                    pos: vec![0],
                    blocks: vec![SealedBlockMeta { id, len: 1 }],
                }],
            };
            (id, slot)
        };
        let (id0, s0) = mk_sealed(1);
        assert!(reg.register(10, 20, vec![s0]));
        arena.release_shared_for(1, id0); // session hold gone; pin remains
        assert_eq!(arena.live_blocks(), 1);
        let (id1, s1) = mk_sealed(1);
        assert!(reg.register(11, 20, vec![s1]));
        arena.release_shared_for(1, id1);
        // capacity 1: the older entry evicted, its block freed
        assert_eq!(reg.len(), 1);
        assert_eq!(arena.live_blocks(), 1);
        assert!(!arena.is_shared(id0));
        reg.clear();
        assert_eq!(arena.live_blocks(), 0);
        assert_eq!(reg.pinned_blocks(), 0);
    }

    #[test]
    fn hot_templates_survive_cold_churn() {
        let arena = BlockArena::shared(4, 256);
        let g = geom();
        let reg = PrefixRegistry::new(arena, g, 2);
        // a "hot template" prompt, registered then hit repeatedly
        let hot: Vec<i32> = (0..32).collect();
        let hot_link = reg.links(&hot)[0];
        assert!(reg.register(hot_link.1, hot_link.0, vec![SealedSlot::default()]));
        for _ in 0..3 {
            assert!(reg.match_longest(&hot).is_some());
        }
        // churn: a stream of one-shot prefixes, each registered once and
        // never matched again — under FIFO the hot template would be the
        // oldest entry and die on the second registration
        for i in 0..8 {
            let cold: Vec<i32> = (100 + 32 * i..100 + 32 * i + 32).collect();
            let link = reg.links(&cold)[0];
            assert!(reg.register(link.1, link.0, vec![SealedSlot::default()]));
            assert!(reg.len() <= 2);
            assert!(
                reg.contains(hot_link.1),
                "hit-weighted eviction must keep the hot template (round {i})"
            );
        }
        // the template is still servable after all the churn
        assert!(reg.match_longest(&hot).is_some());
    }

    #[test]
    fn disabled_registry_never_stores() {
        let arena = BlockArena::shared(4, 256);
        let reg = PrefixRegistry::new(arena, geom(), 0);
        assert!(!reg.register(1, 20, vec![]));
        assert!(reg.match_longest(&(0..60).collect::<Vec<i32>>()).is_none());
    }
}
