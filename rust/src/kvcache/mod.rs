//! KV substrate: CPU-resident block storage for key/value vectors.
//!
//! The wave index operates on *clusters*; the wave buffer moves *blocks*
//! (fixed-size physical units, paper §4.3). This module owns the physical
//! layer as a storage engine: one engine-wide [`BlockArena`] (slab +
//! free-list + byte accounting) from which per-(layer, kv-head)
//! [`HeadStore`] handles check blocks out and into which finished
//! sessions return them. A cluster spans one or more blocks; blocks are
//! not shared across clusters (the tail block of a cluster may be
//! partially filled — the fragmentation the paper's copy kernels skip
//! over). Block ids are engine-global, so the wave buffer's cache and
//! mapping table address arena blocks directly by id.
//!
//! The arena optionally enforces a hard capacity and per-tenant quotas
//! ([`AllocError`], [`BlockArena::try_alloc_for`]); the scheduler's
//! admission gate (DESIGN.md §2 "Admission & quotas") defers prefills
//! against the same counters so serving never outgrows the budget.
//!
//! The arena is **tiered** (DESIGN.md §2 "Tiered arena & spill"): the
//! capacity-bounded slab is the hot tier, and a [`spill::SpillStore`]
//! keyed by the same engine-global block ids holds demoted blocks so
//! total live KV can exceed the hot cap. `demote`/`promote` move blocks
//! between tiers; a full hot tier now means "demote, then retry" before
//! the scheduler's "defer".
//!
//! Blocks are also **shareable** (DESIGN.md §2 "Prefix sharing & CoW"):
//! per-block refcounts with copy-on-write let N sessions serve one
//! physical copy of an identical prompt prefix, and the
//! [`prefix::PrefixRegistry`] maps token-hash chains to sealed block
//! runs (plus their wave-index cluster metadata) so prefills check
//! shared prefixes out instead of recomputing them.

pub mod arena;
pub mod prefix;
pub mod spill;
pub mod store;

pub use arena::{AllocError, BlockArena, BlockData, TenantId, DEFAULT_TENANT};
pub use prefix::{ChainGeometry, PrefixMatch, PrefixRegistry, SealedSlot};
pub use spill::{
    append_snapshot_page, read_snapshot_page, CodecTag, ColdestFirst, ExactCodec,
    Int4AngleCodec, Int8AngleCodec, LargestColdFirst, LowRankKCodec, PageCodec,
    SpillCandidate, SpillPolicy, SpillStore,
};
pub use store::{BlockRef, HeadStore, KvReadTier, KvStore};

/// Tokens that fit in one physical block of `block_bytes`, given the head
/// dimension and element width (a block holds both K and V halves).
pub fn tokens_per_block(block_bytes: usize, d_head: usize, elem_bytes: usize) -> usize {
    (block_bytes / (2 * d_head * elem_bytes)).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_per_block_paper_config() {
        // Paper: 2 KB blocks, d_head 128, fp16 -> 4 tokens/block.
        assert_eq!(tokens_per_block(2048, 128, 2), 4);
        // Live path: d_head 32, f32 -> 8 tokens/block.
        assert_eq!(tokens_per_block(2048, 32, 4), 8);
    }

    #[test]
    fn tokens_per_block_never_zero() {
        assert_eq!(tokens_per_block(16, 128, 4), 1);
    }
}
