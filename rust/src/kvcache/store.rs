//! Block store handles: the per-(layer, kv-head) view over the shared
//! [`BlockArena`]. A `HeadStore` owns no KV storage of its own — it is
//! an arena reference plus the list of blocks checked out to this head,
//! and dropping it returns every hot block to the arena free-list and
//! every cold block to the spill store's free pages (cold blocks die in
//! place, never promoted first).
//!
//! Tier-awareness (DESIGN.md §2 "Tiered arena & spill"): each owned
//! block is either **hot** (its [`BlockData`] lives in this handle) or
//! **cold** (its data lives in the arena's spill store under the same
//! engine-global id). The `len`-guarded slice accessors only serve hot
//! blocks; possibly-cold callers use the fallible
//! [`HeadStore::try_block_keys`] family or [`HeadStore::copy_block_kv`]
//! (which reads through the spill tier without changing residency).
//! [`HeadStore::demote_block`] / [`HeadStore::promote_block`] move one
//! block between tiers.
//!
//! Sharing (DESIGN.md §2 "Prefix sharing & CoW"): a hot block can be
//! **sealed** into a shared, refcounted view ([`HeadStore::seal_block`])
//! and other handles can attach the same storage under the same
//! engine-global id ([`HeadStore::attach_shared`]) without a fresh
//! checkout — the prefix-dedup path. Shared blocks are read-only and
//! never demote; a writer diverges through copy-on-write
//! ([`HeadStore::unshare_for_write`]): a fresh private block (new id)
//! takes a bit-identical copy and the shared reference is released, so
//! a sharer's view can never observe the write.
//!
//! Every handle carries the [`TenantId`] it allocates on behalf of, so
//! quota accounting follows the blocks from checkout to reclamation.

use super::arena::{AllocError, BlockArena, BlockData, TenantId, DEFAULT_TENANT};
use std::sync::Arc;

/// Where [`HeadStore::copy_block_kv_tiered`] found a block's bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvReadTier {
    /// Hot-tier storage (private or shared) — no spill involved.
    Hot,
    /// Cold block served from the staging area: its page read ran on
    /// the I/O lane and completed under compute (overlapped).
    ColdStaged,
    /// Cold block decoded synchronously from the page file (a stall).
    ColdFile,
}

/// A reference to a span of tokens inside one physical arena block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockRef {
    /// Engine-global arena block id (never reused; this is the key the
    /// wave buffer's block cache and mapping table address blocks by).
    pub block: u64,
    /// Index of the block within the owning [`HeadStore`]'s block list
    /// (O(1) data access without an id lookup).
    pub idx: u32,
    /// Number of valid tokens in this block (≤ tokens_per_block).
    pub len: u16,
}

/// Hot storage of one owned block: private (exclusively owned by this
/// handle, writable between alloc and publication) or shared (a
/// refcounted read-only view of storage other handles may also hold).
enum BlockPayload {
    Hot(BlockData),
    Shared(Arc<BlockData>),
}

impl BlockPayload {
    fn data(&self) -> &BlockData {
        match self {
            BlockPayload::Hot(d) => d,
            BlockPayload::Shared(a) => a,
        }
    }
}

/// One checked-out arena block plus its valid length. `data` is `None`
/// while the block lives in the cold tier (its bytes sit in the arena's
/// spill store under `id`).
struct OwnedBlock {
    id: u64,
    len: u16,
    data: Option<BlockPayload>,
}

/// Per-(layer, kv-head) handle over the shared arena.
///
/// Keys and values are block-granular: block `b` holds `[len, d]` keys
/// and values plus the original context position of each token slot.
pub struct HeadStore {
    arena: Arc<BlockArena>,
    tenant: TenantId,
    blocks: Vec<OwnedBlock>,
}

impl HeadStore {
    /// Handle over a private single-head arena (tests, standalone
    /// baselines). Engine code uses [`HeadStore::new_in`] with the
    /// engine-owned arena instead.
    pub fn new(d: usize, block_bytes: usize) -> Self {
        Self::new_in(BlockArena::shared(d, block_bytes))
    }

    /// Handle over a shared arena, default tenant.
    pub fn new_in(arena: Arc<BlockArena>) -> Self {
        Self::new_in_for(arena, DEFAULT_TENANT)
    }

    /// Handle over a shared arena on behalf of `tenant` (multi-tenant
    /// serving: quota accounting follows the handle's checkouts).
    pub fn new_in_for(arena: Arc<BlockArena>, tenant: TenantId) -> Self {
        HeadStore { arena, tenant, blocks: Vec::new() }
    }

    pub fn d(&self) -> usize {
        self.arena.d()
    }

    /// Tokens per block for this store.
    pub fn tokens_per_block(&self) -> usize {
        self.arena.tokens_per_block()
    }

    /// The shared arena this handle allocates from.
    pub fn arena(&self) -> &Arc<BlockArena> {
        &self.arena
    }

    /// The tenant this handle allocates on behalf of.
    pub fn tenant(&self) -> TenantId {
        self.tenant
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn n_tokens(&self) -> usize {
        self.blocks.iter().map(|b| b.len as usize).sum()
    }

    /// Bytes of one full block (K + V halves), f32 elements.
    pub fn block_bytes(&self) -> usize {
        self.arena.block_bytes()
    }

    /// Fallible form of [`HeadStore::alloc_cluster`]: append a cluster's
    /// tokens, packing them into freshly checked-out arena blocks.
    /// `keys`/`vals` are `[n, d]` flat; `pos[i]` is token i's context
    /// position. Returns the block refs the cluster occupies, in order.
    ///
    /// Failure atomicity: if the arena refuses a block mid-cluster, every
    /// block this call already checked out is returned and the store is
    /// left exactly as it was.
    pub fn try_alloc_cluster(
        &mut self,
        keys: &[f32],
        vals: &[f32],
        pos: &[u32],
    ) -> Result<Vec<BlockRef>, AllocError> {
        let d = self.arena.d();
        let tpb = self.arena.tokens_per_block();
        let n = pos.len();
        debug_assert_eq!(keys.len(), n * d);
        debug_assert_eq!(vals.len(), n * d);
        let start_blocks = self.blocks.len();
        let mut refs = Vec::with_capacity(n.div_ceil(tpb));
        let mut off = 0;
        while off < n {
            let take = (n - off).min(tpb);
            // Blocks are always checked out full-size; recycled tails
            // stay stale but are never read (`len`-guarded accessors).
            let (id, mut data) = match self.arena.try_alloc_for(self.tenant) {
                Ok(x) => x,
                Err(e) => {
                    // roll back this call's checkouts (all private hot:
                    // they were pushed by this very call)
                    self.arena.reclaim_for(
                        self.tenant,
                        self.blocks.drain(start_blocks..).map(|b| match b.data {
                            Some(BlockPayload::Hot(d)) => d,
                            _ => unreachable!("freshly allocated blocks are private hot"),
                        }),
                    );
                    return Err(e);
                }
            };
            data.keys[..take * d].copy_from_slice(&keys[off * d..(off + take) * d]);
            data.vals[..take * d].copy_from_slice(&vals[off * d..(off + take) * d]);
            data.pos[..take].copy_from_slice(&pos[off..off + take]);
            let idx = self.blocks.len() as u32;
            self.blocks
                .push(OwnedBlock { id, len: take as u16, data: Some(BlockPayload::Hot(data)) });
            refs.push(BlockRef { block: id, idx, len: take as u16 });
            off += take;
        }
        Ok(refs)
    }

    /// Append a cluster's tokens (infallible form — only valid against
    /// uncapped arenas; capped paths use [`HeadStore::try_alloc_cluster`]).
    pub fn alloc_cluster(&mut self, keys: &[f32], vals: &[f32], pos: &[u32]) -> Vec<BlockRef> {
        self.try_alloc_cluster(keys, vals, pos)
            .expect("KV block allocation refused — capped arenas must use try_alloc_cluster")
    }

    fn owned(&self, r: BlockRef) -> &OwnedBlock {
        let b = &self.blocks[r.idx as usize];
        debug_assert_eq!(b.id, r.block, "BlockRef from a different store");
        debug_assert_eq!(b.len, r.len);
        b
    }

    fn hot_data(&self, r: BlockRef) -> &BlockData {
        self.owned(r)
            .data
            .as_ref()
            .expect("block is in the cold tier — promote it or use the copy accessors")
            .data()
    }

    /// Whether a block's data is resident in the hot tier (private or
    /// shared — shared blocks are always hot).
    pub fn is_hot(&self, r: BlockRef) -> bool {
        self.owned(r).data.is_some()
    }

    /// Whether a block is a shared (refcounted, read-only) view.
    pub fn is_shared(&self, r: BlockRef) -> bool {
        matches!(self.owned(r).data, Some(BlockPayload::Shared(_)))
    }

    /// Key vectors of a hot block: `[len, d]` flat. Panics on a cold
    /// block (use [`HeadStore::try_block_keys`] / `copy_block_kv`).
    pub fn block_keys(&self, r: BlockRef) -> &[f32] {
        &self.hot_data(r).keys[..r.len as usize * self.arena.d()]
    }

    /// Value vectors of a hot block: `[len, d]` flat.
    pub fn block_vals(&self, r: BlockRef) -> &[f32] {
        &self.hot_data(r).vals[..r.len as usize * self.arena.d()]
    }

    /// Context positions of a hot block's tokens.
    pub fn block_pos(&self, r: BlockRef) -> &[u32] {
        &self.hot_data(r).pos[..r.len as usize]
    }

    /// Fallible key access: `None` when the block is cold.
    pub fn try_block_keys(&self, r: BlockRef) -> Option<&[f32]> {
        let b = self.owned(r);
        b.data.as_ref().map(|p| &p.data().keys[..r.len as usize * self.arena.d()])
    }

    /// Fallible value access: `None` when the block is cold.
    pub fn try_block_vals(&self, r: BlockRef) -> Option<&[f32]> {
        let b = self.owned(r);
        b.data.as_ref().map(|p| &p.data().vals[..r.len as usize * self.arena.d()])
    }

    /// Append a block's valid keys and values to `k_out` / `v_out`,
    /// reading through the spill tier when the block is cold (residency
    /// unchanged — this is the cold-read data path the wave buffer's
    /// assembly falls back to). Returns whether the block was hot.
    pub fn copy_block_kv(&self, r: BlockRef, k_out: &mut Vec<f32>, v_out: &mut Vec<f32>) -> bool {
        self.copy_block_kv_tiered(r, k_out, v_out) == KvReadTier::Hot
    }

    /// [`HeadStore::copy_block_kv`] with tier attribution: reports
    /// whether the bytes came from hot storage, the cold staging area
    /// (an I/O-lane read that completed under compute — no stall), or a
    /// synchronous cold-page decode (a genuine spill stall). The bytes
    /// are bit-identical in all three cases for an exact page, and
    /// identical between the two cold paths for every codec (staged
    /// pages are decoded from the same page bytes).
    pub fn copy_block_kv_tiered(
        &self,
        r: BlockRef,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
    ) -> KvReadTier {
        let n = r.len as usize * self.arena.d();
        match &self.owned(r).data {
            Some(p) => {
                let d = p.data();
                k_out.extend_from_slice(&d.keys[..n]);
                v_out.extend_from_slice(&d.vals[..n]);
                KvReadTier::Hot
            }
            None => {
                match self.arena.spill().peek_kv_into(r.block, n, k_out, v_out) {
                    Some(true) => KvReadTier::ColdStaged,
                    Some(false) => KvReadTier::ColdFile,
                    None => panic!("cold block {} missing from the spill store", r.block),
                }
            }
        }
    }

    /// Demote one block into the cold tier with the exact codec
    /// (bit-identical round-trip). Returns false if it was already cold
    /// — or shared: a refcounted block is pinned hot while any owner
    /// holds it (demoting one owner's view would stall every sharer on
    /// the spill tier and break the charge-once accounting).
    pub fn demote_block(&mut self, r: BlockRef) -> bool {
        self.demote_block_with(r, false)
    }

    /// Demote one block, marking its cold page lossy-eligible when the
    /// caller's accuracy bound allows (`lossy_ok` — the spill store
    /// applies its configured codec only to eligible pages).
    pub fn demote_block_with(&mut self, r: BlockRef, lossy_ok: bool) -> bool {
        let b = &mut self.blocks[r.idx as usize];
        debug_assert_eq!(b.id, r.block, "BlockRef from a different store");
        match b.data.take() {
            Some(BlockPayload::Hot(data)) => {
                self.arena.demote_for_with(self.tenant, b.id, data, lossy_ok);
                true
            }
            Some(shared @ BlockPayload::Shared(_)) => {
                b.data = Some(shared);
                false
            }
            None => false,
        }
    }

    /// Seal one private hot block into a shared, refcounted view (this
    /// handle keeps reading it; other handles may now
    /// [`HeadStore::attach_shared`] it). Returns false if the block is
    /// cold; a block that is already shared stays shared.
    pub fn seal_block(&mut self, r: BlockRef) -> bool {
        let b = &mut self.blocks[r.idx as usize];
        debug_assert_eq!(b.id, r.block, "BlockRef from a different store");
        match b.data.take() {
            Some(BlockPayload::Hot(data)) => {
                let arc = self.arena.note_shared_for(self.tenant, b.id, data);
                b.data = Some(BlockPayload::Shared(arc));
                true
            }
            Some(shared @ BlockPayload::Shared(_)) => {
                b.data = Some(shared);
                true
            }
            None => false,
        }
    }

    /// Attach a shared block (sealed by another handle) to this store:
    /// the refcount rises, no storage is allocated, and no capacity or
    /// quota charge is taken. Returns `None` if `id` is not currently a
    /// shared block in the arena.
    pub fn attach_shared(&mut self, id: u64, len: u16) -> Option<BlockRef> {
        let arc = self.arena.share_block_for(self.tenant, id)?;
        let idx = self.blocks.len() as u32;
        self.blocks.push(OwnedBlock { id, len, data: Some(BlockPayload::Shared(arc)) });
        Some(BlockRef { block: id, idx, len })
    }

    /// Copy-on-write divergence: replace this handle's view of a shared
    /// block with a freshly checked-out private copy (bit-identical
    /// bytes, NEW engine-global id — caches keyed by the old id keep
    /// serving the shared content) and release the shared reference.
    /// The returned ref is writable via the `_mut` accessors; other
    /// owners' views are untouched. A private block returns its own ref
    /// unchanged. Errors if the arena refuses the private checkout.
    pub fn unshare_for_write(&mut self, r: BlockRef) -> Result<BlockRef, AllocError> {
        let b = &self.blocks[r.idx as usize];
        debug_assert_eq!(b.id, r.block, "BlockRef from a different store");
        match &b.data {
            Some(BlockPayload::Hot(_)) => return Ok(r),
            Some(BlockPayload::Shared(_)) => {}
            None => panic!("unshare_for_write on a cold block"),
        }
        let (new_id, mut data) = self.arena.try_alloc_for(self.tenant)?;
        let b = &mut self.blocks[r.idx as usize];
        let Some(BlockPayload::Shared(arc)) = b.data.take() else { unreachable!() };
        data.keys.copy_from_slice(&arc.keys);
        data.vals.copy_from_slice(&arc.vals);
        data.pos.copy_from_slice(&arc.pos);
        let old_id = b.id;
        b.id = new_id;
        b.data = Some(BlockPayload::Hot(data));
        drop(arc);
        self.arena.release_shared_for(self.tenant, old_id);
        Ok(BlockRef { block: new_id, idx: r.idx, len: r.len })
    }

    /// Mutable key access to a private hot block (the CoW write path).
    /// Panics on shared or cold blocks — call
    /// [`HeadStore::unshare_for_write`] first.
    pub fn block_keys_mut(&mut self, r: BlockRef) -> &mut [f32] {
        let d = self.arena.d();
        let b = &mut self.blocks[r.idx as usize];
        debug_assert_eq!(b.id, r.block, "BlockRef from a different store");
        match &mut b.data {
            Some(BlockPayload::Hot(data)) => &mut data.keys[..r.len as usize * d],
            _ => panic!("mutable access to a shared or cold block — unshare_for_write first"),
        }
    }

    /// Mutable value access to a private hot block (see
    /// [`HeadStore::block_keys_mut`]).
    pub fn block_vals_mut(&mut self, r: BlockRef) -> &mut [f32] {
        let d = self.arena.d();
        let b = &mut self.blocks[r.idx as usize];
        debug_assert_eq!(b.id, r.block, "BlockRef from a different store");
        match &mut b.data {
            Some(BlockPayload::Hot(data)) => &mut data.vals[..r.len as usize * d],
            _ => panic!("mutable access to a shared or cold block — unshare_for_write first"),
        }
    }

    /// Promote one block back into the hot tier (hot capacity and the
    /// tenant quota gate the checkout, exactly like a fresh alloc).
    /// `Ok(None)` if the block was already hot; `Ok(Some(staged))`
    /// reports whether the async prefetcher had staged the page.
    pub fn promote_block(&mut self, r: BlockRef) -> Result<Option<bool>, AllocError> {
        let b = &self.blocks[r.idx as usize];
        debug_assert_eq!(b.id, r.block, "BlockRef from a different store");
        if b.data.is_some() {
            return Ok(None);
        }
        let (data, staged) = self.arena.try_promote_for(self.tenant, r.block)?;
        self.blocks[r.idx as usize].data = Some(BlockPayload::Hot(data));
        Ok(Some(staged))
    }

    /// Demote up to `n` hot blocks, oldest first; returns how many were
    /// demoted (the driver-level spill path for modelled workloads).
    pub fn demote_oldest(&mut self, n: usize) -> usize {
        self.demote_oldest_with(n, false)
    }

    /// [`HeadStore::demote_oldest`] with an explicit lossy-eligibility
    /// bit for every demoted page (pressure-harness drivers that model
    /// the accuracy bound at the trace level rather than per cluster).
    pub fn demote_oldest_with(&mut self, n: usize, lossy_ok: bool) -> usize {
        let mut done = 0;
        for i in 0..self.blocks.len() {
            if done >= n {
                break;
            }
            let (id, len, hot) = {
                let b = &self.blocks[i];
                (b.id, b.len, b.data.is_some())
            };
            if !hot {
                continue;
            }
            if self.demote_block_with(BlockRef { block: id, idx: i as u32, len }, lossy_ok) {
                done += 1;
            }
        }
        done
    }

    /// Promote up to `n` cold blocks, oldest first, stopping at the
    /// first refused checkout; returns how many were promoted.
    pub fn promote_oldest(&mut self, n: usize) -> usize {
        let mut done = 0;
        for i in 0..self.blocks.len() {
            if done >= n {
                break;
            }
            let (id, len, hot) = {
                let b = &self.blocks[i];
                (b.id, b.len, b.data.is_some())
            };
            if hot {
                continue;
            }
            match self.promote_block(BlockRef { block: id, idx: i as u32, len }) {
                Ok(_) => done += 1,
                Err(_) => break,
            }
        }
        done
    }

    /// Blocks of this handle currently hot.
    pub fn n_hot_blocks(&self) -> usize {
        self.blocks.iter().filter(|b| b.data.is_some()).count()
    }

    /// Blocks of this handle currently cold.
    pub fn n_cold_blocks(&self) -> usize {
        self.blocks.iter().filter(|b| b.data.is_none()).count()
    }

    /// Refs of this handle's cold blocks, in checkout order. The
    /// pressure harness enumerates these to model the pipelined
    /// stage-then-gather read path ([`HeadStore::copy_block_kv_tiered`]).
    pub fn cold_block_refs(&self) -> Vec<BlockRef> {
        self.blocks
            .iter()
            .enumerate()
            .filter(|(_, b)| b.data.is_none())
            .map(|(i, b)| BlockRef { block: b.id, idx: i as u32, len: b.len })
            .collect()
    }

    /// Blocks of this handle that are shared (refcounted) views.
    pub fn n_shared_blocks(&self) -> usize {
        self.blocks
            .iter()
            .filter(|b| matches!(b.data, Some(BlockPayload::Shared(_))))
            .count()
    }
}

impl Drop for HeadStore {
    fn drop(&mut self) {
        // A finished session returns every private hot block to the
        // arena, releases its shared references (storage frees only at
        // refcount zero) and drops its cold blocks in place — never
        // promoting them first (the scheduler's reclamation path must
        // not touch the hot cap).
        let mut hot = Vec::new();
        for b in self.blocks.drain(..) {
            match b.data {
                Some(BlockPayload::Hot(data)) => hot.push(data),
                Some(BlockPayload::Shared(arc)) => {
                    drop(arc);
                    self.arena.release_shared_for(self.tenant, b.id);
                }
                None => {
                    self.arena.drop_cold(b.id);
                }
            }
        }
        self.arena.reclaim_for(self.tenant, hot);
    }
}

/// All KV data of one sequence: `layers x kv_heads` head stores sharing
/// one arena (and one tenant).
pub struct KvStore {
    n_layers: usize,
    kv_heads: usize,
    arena: Arc<BlockArena>,
    stores: Vec<HeadStore>,
}

impl KvStore {
    pub fn new(n_layers: usize, kv_heads: usize, d: usize, block_bytes: usize) -> Self {
        Self::new_in(BlockArena::shared(d, block_bytes), n_layers, kv_heads)
    }

    pub fn new_in(arena: Arc<BlockArena>, n_layers: usize, kv_heads: usize) -> Self {
        Self::new_in_for(arena, DEFAULT_TENANT, n_layers, kv_heads)
    }

    /// Per-tenant form: every head handle allocates on `tenant`'s quota.
    pub fn new_in_for(
        arena: Arc<BlockArena>,
        tenant: TenantId,
        n_layers: usize,
        kv_heads: usize,
    ) -> Self {
        let stores = (0..n_layers * kv_heads)
            .map(|_| HeadStore::new_in_for(Arc::clone(&arena), tenant))
            .collect();
        KvStore { n_layers, kv_heads, arena, stores }
    }

    pub fn head(&self, layer: usize, kv_head: usize) -> &HeadStore {
        &self.stores[layer * self.kv_heads + kv_head]
    }

    pub fn head_mut(&mut self, layer: usize, kv_head: usize) -> &mut HeadStore {
        &mut self.stores[layer * self.kv_heads + kv_head]
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn kv_heads(&self) -> usize {
        self.kv_heads
    }

    pub fn arena(&self) -> &Arc<BlockArena> {
        &self.arena
    }

    /// Total CPU-resident bytes across all heads.
    pub fn total_bytes(&self) -> usize {
        self.stores.iter().map(|s| s.n_blocks() * s.block_bytes()).sum()
    }

    /// Demote up to `n` hot blocks across heads (head order, oldest
    /// blocks first); returns how many were demoted.
    pub fn demote_blocks(&mut self, n: usize) -> usize {
        self.demote_blocks_with(n, false)
    }

    /// [`KvStore::demote_blocks`] with an explicit lossy-eligibility bit
    /// applied to every demoted page.
    pub fn demote_blocks_with(&mut self, n: usize, lossy_ok: bool) -> usize {
        let mut done = 0;
        for s in self.stores.iter_mut() {
            if done >= n {
                break;
            }
            done += s.demote_oldest_with(n - done, lossy_ok);
        }
        done
    }

    /// Promote up to `n` cold blocks across heads, stopping early if
    /// the hot tier refuses a checkout; returns how many were promoted.
    pub fn promote_blocks(&mut self, n: usize) -> usize {
        let mut done = 0;
        for s in self.stores.iter_mut() {
            if done >= n {
                break;
            }
            done += s.promote_oldest(n - done);
        }
        done
    }

    /// Cold blocks held across all heads.
    pub fn n_cold_blocks(&self) -> usize {
        self.stores.iter().map(|s| s.n_cold_blocks()).sum()
    }

    /// Up to `max` cold refs across heads, paired with the flat head
    /// index (`layer * kv_heads + kv_head`) owning each — deterministic
    /// head order, checkout order within a head.
    pub fn cold_refs(&self, max: usize) -> Vec<(usize, BlockRef)> {
        let mut out = Vec::new();
        'heads: for (hi, s) in self.stores.iter().enumerate() {
            for r in s.cold_block_refs() {
                if out.len() >= max {
                    break 'heads;
                }
                out.push((hi, r));
            }
        }
        out
    }

    /// Head store by flat index (`layer * kv_heads + kv_head`).
    pub fn head_flat(&self, i: usize) -> &HeadStore {
        &self.stores[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mk(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<u32>) {
        let mut rng = Rng::new(seed);
        (rng.normal_vec(n * d), rng.normal_vec(n * d), (0..n as u32).collect())
    }

    #[test]
    fn alloc_roundtrip_single_block() {
        let d = 32;
        let mut hs = HeadStore::new(d, 2048); // 8 tokens/block
        let (k, v, p) = mk(5, d, 1);
        let refs = hs.alloc_cluster(&k, &v, &p);
        assert_eq!(refs.len(), 1);
        assert_eq!(refs[0].len, 5);
        assert_eq!(hs.block_keys(refs[0]), &k[..]);
        assert_eq!(hs.block_vals(refs[0]), &v[..]);
        assert_eq!(hs.block_pos(refs[0]), &p[..]);
    }

    #[test]
    fn alloc_spans_multiple_blocks() {
        let d = 32;
        let mut hs = HeadStore::new(d, 2048);
        let (k, v, p) = mk(20, d, 2); // 8 + 8 + 4
        let refs = hs.alloc_cluster(&k, &v, &p);
        assert_eq!(refs.len(), 3);
        assert_eq!(refs.iter().map(|r| r.len as usize).sum::<usize>(), 20);
        assert_eq!(refs[2].len, 4);
        // tokens preserved in order across blocks
        let mut got = Vec::new();
        for r in &refs {
            got.extend_from_slice(hs.block_pos(*r));
        }
        assert_eq!(got, p);
        assert_eq!(hs.n_tokens(), 20);
        assert_eq!(hs.n_blocks(), 3);
    }

    #[test]
    fn clusters_do_not_share_blocks() {
        let d = 32;
        let mut hs = HeadStore::new(d, 2048);
        let (k, v, p) = mk(3, d, 3);
        let r1 = hs.alloc_cluster(&k, &v, &p);
        let r2 = hs.alloc_cluster(&k, &v, &p);
        assert_ne!(r1[0].block, r2[0].block);
        // partial tail block still advances the block counter
        assert_eq!(hs.n_blocks(), 2);
    }

    #[test]
    fn drop_returns_blocks_to_arena() {
        let d = 16;
        let arena = BlockArena::shared(d, 512);
        let baseline = arena.live_blocks();
        {
            let mut hs = HeadStore::new_in(Arc::clone(&arena));
            let (k, v, p) = mk(30, d, 4);
            hs.alloc_cluster(&k, &v, &p);
            assert!(arena.live_blocks() > baseline);
        }
        assert_eq!(arena.live_blocks(), baseline);
        assert!(arena.free_blocks() > 0);
        // recycled storage serves the next store (tpb=4: 30 tokens -> 8
        // blocks reclaimed; 8 tokens -> 2 blocks checked back out)
        assert_eq!(arena.free_blocks(), 8);
        let mut hs2 = HeadStore::new_in(Arc::clone(&arena));
        let (k, v, p) = mk(8, d, 5);
        let r = hs2.alloc_cluster(&k, &v, &p);
        assert_eq!(hs2.block_keys(r[0]), &k[..4 * d]);
        assert_eq!(arena.free_blocks(), 6);
        assert_eq!(arena.allocated_total(), 10);
    }

    #[test]
    fn failed_cluster_rolls_back_this_call_only() {
        let d = 16; // tpb = 4 at 512-byte blocks
        let arena = BlockArena::shared(d, 512);
        arena.set_capacity_blocks(Some(3));
        let mut hs = HeadStore::new_in(Arc::clone(&arena));
        let (k, v, p) = mk(8, d, 6);
        let refs = hs.try_alloc_cluster(&k, &v, &p).unwrap(); // 2 blocks
        assert_eq!(refs.len(), 2);
        // second cluster needs 2 blocks but only 1 slot remains: the call
        // fails and returns its own partial checkout, leaving the first
        // cluster intact and readable
        let (k2, v2, p2) = mk(8, d, 7);
        let err = hs.try_alloc_cluster(&k2, &v2, &p2).unwrap_err();
        assert_eq!(err, AllocError::ArenaFull { capacity_blocks: 3 });
        assert_eq!(hs.n_blocks(), 2);
        assert_eq!(hs.n_tokens(), 8);
        assert_eq!(arena.live_blocks(), 2);
        assert_eq!(hs.block_keys(refs[0]), &k[..4 * d]);
        // a smaller cluster still fits
        let (k3, v3, p3) = mk(3, d, 8);
        assert!(hs.try_alloc_cluster(&k3, &v3, &p3).is_ok());
        assert_eq!(arena.live_blocks(), 3);
    }

    #[test]
    fn tenant_follows_store_through_drop() {
        let d = 16;
        let arena = BlockArena::shared(d, 512);
        {
            let mut hs = HeadStore::new_in_for(Arc::clone(&arena), 9);
            assert_eq!(hs.tenant(), 9);
            let (k, v, p) = mk(10, d, 9);
            hs.alloc_cluster(&k, &v, &p);
            assert_eq!(arena.tenant_live_blocks(9), 3);
        }
        assert_eq!(arena.tenant_live_blocks(9), 0);
        assert_eq!(arena.live_blocks(), 0);
    }

    #[test]
    fn demote_promote_roundtrip_preserves_block_bytes() {
        let d = 16; // tpb = 4 at 512-byte blocks
        let arena = BlockArena::shared(d, 512);
        let mut hs = HeadStore::new_in(Arc::clone(&arena));
        let (k, v, p) = mk(10, d, 21);
        let refs = hs.alloc_cluster(&k, &v, &p);
        assert_eq!(refs.len(), 3);
        let want_k = hs.block_keys(refs[2]).to_vec();
        let want_v = hs.block_vals(refs[2]).to_vec();
        assert!(hs.demote_block(refs[2]));
        assert!(!hs.is_hot(refs[2]));
        assert!(!hs.demote_block(refs[2]), "second demote is a no-op");
        assert_eq!(hs.n_cold_blocks(), 1);
        assert_eq!(hs.n_hot_blocks(), 2);
        assert_eq!(arena.cold_blocks(), 1);
        assert_eq!(arena.live_blocks(), 2);
        assert!(hs.try_block_keys(refs[2]).is_none());
        // cold read path serves identical bytes without promoting
        let (mut ck, mut cv) = (Vec::new(), Vec::new());
        assert!(!hs.copy_block_kv(refs[2], &mut ck, &mut cv));
        assert_eq!(ck, want_k);
        assert_eq!(cv, want_v);
        assert!(!hs.is_hot(refs[2]));
        // promotion restores the exact bytes and the hot accessors
        assert_eq!(hs.promote_block(refs[2]).unwrap(), Some(false));
        assert_eq!(hs.promote_block(refs[2]).unwrap(), None, "already hot");
        assert_eq!(hs.block_keys(refs[2]), &want_k[..]);
        assert_eq!(hs.block_vals(refs[2]), &want_v[..]);
        assert_eq!(arena.cold_blocks(), 0);
        // token accounting is tier-independent
        assert_eq!(hs.n_tokens(), 10);
    }

    #[test]
    fn seal_attach_serves_identical_bytes_without_new_blocks() {
        let d = 16; // tpb = 4 at 512-byte blocks
        let arena = BlockArena::shared(d, 512);
        let mut a = HeadStore::new_in_for(Arc::clone(&arena), 1);
        let (k, v, p) = mk(7, d, 40);
        let refs = a.alloc_cluster(&k, &v, &p);
        assert_eq!(refs.len(), 2);
        let live_before = arena.live_blocks();
        for r in &refs {
            assert!(a.seal_block(*r));
            assert!(a.is_shared(*r));
            assert!(a.is_hot(*r), "shared blocks stay hot");
        }
        // sealing twice is a no-op
        assert!(a.seal_block(refs[0]));
        // another tenant attaches the same storage: same ids, no alloc
        let mut b = HeadStore::new_in_for(Arc::clone(&arena), 2);
        let brefs: Vec<BlockRef> =
            refs.iter().map(|r| b.attach_shared(r.block, r.len).unwrap()).collect();
        assert_eq!(arena.live_blocks(), live_before, "attach allocates nothing");
        assert_eq!(arena.tenant_live_blocks(2), 0, "sharers are not charged");
        for (ra, rb) in refs.iter().zip(&brefs) {
            assert_eq!(rb.block, ra.block);
            assert_eq!(a.block_keys(*ra), b.block_keys(*rb));
            assert_eq!(a.block_vals(*ra), b.block_vals(*rb));
            assert_eq!(a.block_pos(*ra), b.block_pos(*rb));
            assert!(!b.demote_block(*rb), "shared blocks never demote");
        }
        // sharer exits first: storage stays; sealer exits: refcount zero
        drop(b);
        assert_eq!(arena.live_blocks(), live_before);
        drop(a);
        assert_eq!(arena.live_blocks(), 0);
        assert_eq!(arena.shared_blocks_live(), 0);
    }

    #[test]
    fn cow_divergence_leaves_the_sharer_bit_identical() {
        let d = 16;
        let arena = BlockArena::shared(d, 512);
        let mut a = HeadStore::new_in_for(Arc::clone(&arena), 1);
        let (k, v, p) = mk(4, d, 41);
        let r = a.alloc_cluster(&k, &v, &p)[0];
        assert!(a.seal_block(r));
        let mut b = HeadStore::new_in_for(Arc::clone(&arena), 2);
        let rb = b.attach_shared(r.block, r.len).unwrap();
        // B diverges: new id, tenant 2 now pays for its private copy
        let rb2 = b.unshare_for_write(rb).unwrap();
        assert_ne!(rb2.block, rb.block, "CoW must mint a fresh id");
        assert_eq!(rb2.idx, rb.idx);
        assert!(!b.is_shared(rb2));
        assert_eq!(arena.tenant_live_blocks(2), 1);
        assert_eq!(b.block_keys(rb2), a.block_keys(r), "copy starts bit-identical");
        // writes through B cannot reach A's view
        b.block_keys_mut(rb2).fill(9.5);
        b.block_vals_mut(rb2)[0] = -3.25;
        assert_eq!(a.block_keys(r), &k[..], "sharer's bytes must be untouched");
        assert_eq!(a.block_vals(r), &v[..]);
        // unsharing a private block is the identity
        assert_eq!(b.unshare_for_write(rb2).unwrap(), rb2);
        drop(b);
        drop(a);
        assert_eq!(arena.live_blocks(), 0);
    }

    #[test]
    fn dropping_a_store_with_cold_blocks_reclaims_both_tiers() {
        let d = 16;
        let arena = BlockArena::shared(d, 512);
        {
            let mut hs = HeadStore::new_in_for(Arc::clone(&arena), 4);
            let (k, v, p) = mk(12, d, 22);
            hs.alloc_cluster(&k, &v, &p); // 3 blocks
            assert_eq!(hs.demote_oldest(2), 2);
            assert_eq!(arena.cold_blocks(), 2);
            assert_eq!(arena.live_blocks(), 1);
            assert_eq!(arena.tenant_live_blocks(4), 1);
        }
        // drop reclaims the hot block and drops the cold ones in place
        assert_eq!(arena.live_blocks(), 0);
        assert_eq!(arena.cold_blocks(), 0);
        assert_eq!(arena.tenant_live_blocks(4), 0);
        assert_eq!(arena.spill().dropped_total(), 2);
    }

    #[test]
    fn kvstore_tier_moves_span_heads() {
        let mut st = KvStore::new(2, 2, 8, 512); // tpb = 8
        let (k, v, p) = mk(8, 8, 23);
        for l in 0..2 {
            for h in 0..2 {
                st.head_mut(l, h).alloc_cluster(&k, &v, &p);
            }
        }
        assert_eq!(st.arena().live_blocks(), 4);
        assert_eq!(st.demote_blocks(3), 3);
        assert_eq!(st.n_cold_blocks(), 3);
        assert_eq!(st.arena().live_blocks(), 1);
        assert_eq!(st.promote_blocks(2), 2);
        assert_eq!(st.n_cold_blocks(), 1);
        assert_eq!(st.arena().total_live_blocks(), 4);
    }

    #[test]
    fn kvstore_shapes() {
        let st = KvStore::new(4, 2, 32, 2048);
        assert_eq!(st.n_layers(), 4);
        assert_eq!(st.kv_heads(), 2);
        assert_eq!(st.total_bytes(), 0);
        assert_eq!(st.arena().live_blocks(), 0);
    }

    #[test]
    fn kvstore_head_indexing_independent() {
        let mut st = KvStore::new(2, 2, 8, 512);
        let (k, v, p) = mk(4, 8, 5);
        st.head_mut(1, 0).alloc_cluster(&k, &v, &p);
        assert_eq!(st.head(1, 0).n_tokens(), 4);
        assert_eq!(st.head(0, 0).n_tokens(), 0);
        assert_eq!(st.head(1, 1).n_tokens(), 0);
        // all heads draw from the one shared arena
        assert_eq!(st.arena().live_blocks(), 1);
        assert_eq!(st.total_bytes(), st.arena().live_bytes());
    }
}
