//! Block store: the CPU-memory home of all KV vectors.

use super::tokens_per_block;

/// A reference to a span of tokens inside one physical block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockRef {
    /// Physical block id within the owning [`HeadStore`].
    pub block: u32,
    /// Number of valid tokens in this block (≤ tokens_per_block).
    pub len: u16,
}

/// Per-(layer, kv-head) pool of KV blocks.
///
/// Keys and values are stored block-granular: block `b` owns
/// `keys[b*tpb*d .. (b+1)*tpb*d]` (same for `vals`). Token positions are
/// tracked alongside for recall metrics and needle evaluation.
pub struct HeadStore {
    d: usize,
    tpb: usize,
    keys: Vec<f32>,
    vals: Vec<f32>,
    /// Original context position of each token slot.
    pos: Vec<u32>,
    /// Valid token count per block.
    lens: Vec<u16>,
}

impl HeadStore {
    pub fn new(d: usize, block_bytes: usize) -> Self {
        let tpb = tokens_per_block(block_bytes, d, 4);
        HeadStore { d, tpb, keys: Vec::new(), vals: Vec::new(), pos: Vec::new(), lens: Vec::new() }
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Tokens per block for this store.
    pub fn tokens_per_block(&self) -> usize {
        self.tpb
    }

    pub fn n_blocks(&self) -> usize {
        self.lens.len()
    }

    pub fn n_tokens(&self) -> usize {
        self.lens.iter().map(|&l| l as usize).sum()
    }

    /// Bytes of one full block (K + V halves), f32 elements.
    pub fn block_bytes(&self) -> usize {
        2 * self.tpb * self.d * 4
    }

    /// Append a cluster's tokens, packing them into fresh blocks.
    /// `keys`/`vals` are `[n, d]` flat; `pos[i]` is token i's context
    /// position. Returns the block refs the cluster occupies, in order.
    pub fn alloc_cluster(&mut self, keys: &[f32], vals: &[f32], pos: &[u32]) -> Vec<BlockRef> {
        let n = pos.len();
        debug_assert_eq!(keys.len(), n * self.d);
        debug_assert_eq!(vals.len(), n * self.d);
        let mut refs = Vec::with_capacity(n.div_ceil(self.tpb));
        let mut off = 0;
        while off < n {
            let take = (n - off).min(self.tpb);
            let block = self.lens.len() as u32;
            // Blocks are always allocated full-size; the tail stays zeroed
            // (fragmentation skipped by the copy path via `len`).
            self.keys.resize(self.keys.len() + self.tpb * self.d, 0.0);
            self.vals.resize(self.vals.len() + self.tpb * self.d, 0.0);
            self.pos.resize(self.pos.len() + self.tpb, u32::MAX);
            let base = block as usize * self.tpb * self.d;
            self.keys[base..base + take * self.d]
                .copy_from_slice(&keys[off * self.d..(off + take) * self.d]);
            self.vals[base..base + take * self.d]
                .copy_from_slice(&vals[off * self.d..(off + take) * self.d]);
            let pbase = block as usize * self.tpb;
            self.pos[pbase..pbase + take].copy_from_slice(&pos[off..off + take]);
            self.lens.push(take as u16);
            refs.push(BlockRef { block, len: take as u16 });
            off += take;
        }
        refs
    }

    /// Key vectors of a block: `[len, d]` flat.
    pub fn block_keys(&self, r: BlockRef) -> &[f32] {
        let base = r.block as usize * self.tpb * self.d;
        &self.keys[base..base + r.len as usize * self.d]
    }

    /// Value vectors of a block: `[len, d]` flat.
    pub fn block_vals(&self, r: BlockRef) -> &[f32] {
        let base = r.block as usize * self.tpb * self.d;
        &self.vals[base..base + r.len as usize * self.d]
    }

    /// Context positions of a block's tokens.
    pub fn block_pos(&self, r: BlockRef) -> &[u32] {
        let base = r.block as usize * self.tpb;
        &self.pos[base..base + r.len as usize]
    }

    /// Valid length of block `b`.
    pub fn block_len(&self, b: u32) -> u16 {
        self.lens[b as usize]
    }
}

/// All KV data of one sequence: `layers x kv_heads` head stores.
pub struct KvStore {
    n_layers: usize,
    kv_heads: usize,
    stores: Vec<HeadStore>,
}

impl KvStore {
    pub fn new(n_layers: usize, kv_heads: usize, d: usize, block_bytes: usize) -> Self {
        let stores = (0..n_layers * kv_heads).map(|_| HeadStore::new(d, block_bytes)).collect();
        KvStore { n_layers, kv_heads, stores }
    }

    pub fn head(&self, layer: usize, kv_head: usize) -> &HeadStore {
        &self.stores[layer * self.kv_heads + kv_head]
    }

    pub fn head_mut(&mut self, layer: usize, kv_head: usize) -> &mut HeadStore {
        &mut self.stores[layer * self.kv_heads + kv_head]
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn kv_heads(&self) -> usize {
        self.kv_heads
    }

    /// Total CPU-resident bytes across all heads.
    pub fn total_bytes(&self) -> usize {
        self.stores.iter().map(|s| s.n_blocks() * s.block_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mk(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<u32>) {
        let mut rng = Rng::new(seed);
        (rng.normal_vec(n * d), rng.normal_vec(n * d), (0..n as u32).collect())
    }

    #[test]
    fn alloc_roundtrip_single_block() {
        let d = 32;
        let mut hs = HeadStore::new(d, 2048); // 8 tokens/block
        let (k, v, p) = mk(5, d, 1);
        let refs = hs.alloc_cluster(&k, &v, &p);
        assert_eq!(refs.len(), 1);
        assert_eq!(refs[0].len, 5);
        assert_eq!(hs.block_keys(refs[0]), &k[..]);
        assert_eq!(hs.block_vals(refs[0]), &v[..]);
        assert_eq!(hs.block_pos(refs[0]), &p[..]);
    }

    #[test]
    fn alloc_spans_multiple_blocks() {
        let d = 32;
        let mut hs = HeadStore::new(d, 2048);
        let (k, v, p) = mk(20, d, 2); // 8 + 8 + 4
        let refs = hs.alloc_cluster(&k, &v, &p);
        assert_eq!(refs.len(), 3);
        assert_eq!(refs.iter().map(|r| r.len as usize).sum::<usize>(), 20);
        assert_eq!(refs[2].len, 4);
        // tokens preserved in order across blocks
        let mut got = Vec::new();
        for r in &refs {
            got.extend_from_slice(hs.block_pos(*r));
        }
        assert_eq!(got, p);
        assert_eq!(hs.n_tokens(), 20);
        assert_eq!(hs.n_blocks(), 3);
    }

    #[test]
    fn clusters_do_not_share_blocks() {
        let d = 32;
        let mut hs = HeadStore::new(d, 2048);
        let (k, v, p) = mk(3, d, 3);
        let r1 = hs.alloc_cluster(&k, &v, &p);
        let r2 = hs.alloc_cluster(&k, &v, &p);
        assert_ne!(r1[0].block, r2[0].block);
        // partial tail block still advances the block counter
        assert_eq!(hs.n_blocks(), 2);
    }

    #[test]
    fn kvstore_shapes() {
        let st = KvStore::new(4, 2, 32, 2048);
        assert_eq!(st.n_layers(), 4);
        assert_eq!(st.kv_heads(), 2);
        assert_eq!(st.total_bytes(), 0);
    }

    #[test]
    fn kvstore_head_indexing_independent() {
        let mut st = KvStore::new(2, 2, 8, 512);
        let (k, v, p) = mk(4, 8, 5);
        st.head_mut(1, 0).alloc_cluster(&k, &v, &p);
        assert_eq!(st.head(1, 0).n_tokens(), 4);
        assert_eq!(st.head(0, 0).n_tokens(), 0);
        assert_eq!(st.head(1, 1).n_tokens(), 0);
    }
}
