//! Per-system cost profiles consumed by the simulator. Behavioural
//! parameters (exact fraction, hit ratio) default to the paper's settings
//! and are overridden with *measured* values from real wave-buffer runs
//! by the benches (`SystemProfile::with_hit_ratio` etc.).

/// How a sparse-attention system uses the hardware, per decode step.
#[derive(Clone, Debug)]
pub struct SystemProfile {
    pub name: &'static str,
    /// Whole KV cache resident in GPU memory.
    pub kv_on_gpu: bool,
    /// Fraction of KV bytes kept on GPU as a (partial) key cache
    /// for speculation (InfiniGen).
    pub gpu_key_frac: f64,
    /// Fraction of KV bytes reserved as the GPU block cache (RetroInfer).
    pub gpu_cache_frac: f64,
    /// Meta-index bytes as a fraction of KV bytes (centroids + VS).
    pub meta_frac: f64,
    /// Representative-structure bytes as a fraction of KV (Quest min/max).
    pub scan_struct_frac: f64,
    /// Fraction of context tokens attended exactly per step.
    pub exact_frac: f64,
    /// Fixed exactly-attended tokens (steady zone).
    pub exact_fixed: usize,
    /// Fraction of exact-attention bytes that must cross PCIe (before
    /// cache hits): 0 for GPU-resident systems, 1 for offload systems.
    pub pcie_fetch_frac: f64,
    /// Fraction of the PCIe-fetched (uncached) bytes that additionally
    /// come from the cold spill tier (tiered arena: hot RAM tier capped
    /// below the working set). 0 = single-tier.
    pub spill_frac: f64,
    /// Physical/logical byte ratio of cold pages under the configured
    /// spill codec (DESIGN.md §2 "Spill codecs"): compressed pages move
    /// proportionally fewer bytes over the spill channel, scaling its
    /// effective bandwidth by 1/ratio. 1.0 = exact (incompressible).
    pub spill_codec_ratio: f64,
    /// Fraction of spill-channel time hidden under compute within the
    /// step (the pipelined decode executor's *measured* intra-step
    /// `spill_overlap_pct`, from the pressure harness): the overlapped
    /// share joins the overlap max, the remainder serializes after it.
    /// 1.0 = fully hidden (the pre-pipeline optimistic assumption).
    pub spill_overlap_frac: f64,
    /// Fraction of per-sequence KV bytes deduplicated across the batch
    /// by cross-session prefix sharing (refcounted blocks + the shared
    /// GPU prefix cache): those bytes are resident once per batch, and
    /// their exact-attention fetches never cross PCIe again after the
    /// first session faults them in. 0 = no sharing.
    pub shared_prefix_frac: f64,
    /// GPU cache hit ratio on fetched bytes (measured; RetroInfer only).
    pub hit_ratio: f64,
    /// Fraction of context covered by the estimation zone (RetroInfer).
    pub est_frac: f64,
    /// Attention computed on the CPU (MagicPIG).
    pub cpu_attention: bool,
    /// Bytes scanned per step over representatives/signatures/codes,
    /// as a fraction of full KV bytes.
    pub scan_frac: f64,
    /// Software overhead per layer per step, seconds.
    pub per_layer_overhead_s: f64,
    /// CPU buffer-management seconds per sequence per step.
    pub cpu_mgmt_s_per_seq: f64,
    /// Transfers/CPU work overlap GPU compute.
    pub overlap_transfers: bool,
    /// Cache updates off the critical path.
    pub async_update: bool,
    /// Supports decode-time index updates.
    pub supports_update: bool,
}

impl SystemProfile {
    pub fn with_hit_ratio(mut self, h: f64) -> Self {
        self.hit_ratio = h;
        self
    }

    pub fn with_exact_frac(mut self, f: f64) -> Self {
        self.exact_frac = f;
        self
    }

    pub fn with_est_frac(mut self, f: f64) -> Self {
        self.est_frac = f;
        self
    }

    /// Feed a *measured* intra-step spill-overlap ratio (e.g. the
    /// pressure harness's `spill_overlap_pct / 100`) into the overlap
    /// composition. Clamped to [0, 1].
    pub fn with_spill_overlap(mut self, f: f64) -> Self {
        self.spill_overlap_frac = f.clamp(0.0, 1.0);
        self
    }
}

fn base(name: &'static str) -> SystemProfile {
    SystemProfile {
        name,
        kv_on_gpu: false,
        gpu_key_frac: 0.0,
        gpu_cache_frac: 0.0,
        meta_frac: 0.0,
        scan_struct_frac: 0.0,
        exact_frac: 0.018,
        exact_fixed: 68,
        pcie_fetch_frac: 0.0,
        spill_frac: 0.0,
        spill_codec_ratio: 1.0,
        spill_overlap_frac: 1.0,
        shared_prefix_frac: 0.0,
        hit_ratio: 0.0,
        est_frac: 0.0,
        cpu_attention: false,
        scan_frac: 0.0,
        per_layer_overhead_s: 0.0,
        cpu_mgmt_s_per_seq: 0.0,
        overlap_transfers: false,
        async_update: false,
        supports_update: true,
    }
}

/// FlashInfer-style full attention, KV on GPU.
pub fn full() -> SystemProfile {
    SystemProfile { kv_on_gpu: true, exact_frac: 1.0, exact_fixed: 0, ..base("full") }
}

/// vLLM: full attention + paged-KV bookkeeping overhead.
pub fn vllm() -> SystemProfile {
    SystemProfile { per_layer_overhead_s: 2e-6, ..full() }
}

/// Quest: GPU-resident KV + chunk representatives; scans 2/chunk_size of
/// the key bytes (min+max per 16-token chunk = 1/16 of KV bytes).
pub fn quest() -> SystemProfile {
    SystemProfile {
        kv_on_gpu: true,
        scan_struct_frac: 1.0 / 16.0,
        scan_frac: 1.0 / 16.0,
        exact_frac: 0.018,
        ..base("quest")
    }
}

/// MagicPIG: KV offloaded, CPU attention over LSH samples. The effective
/// sampled fraction is higher than the nominal budget (collision noise),
/// and signature scans touch L*4 bytes/token.
pub fn magicpig() -> SystemProfile {
    SystemProfile {
        cpu_attention: true,
        exact_frac: 0.03,
        scan_frac: 0.02,
        overlap_transfers: true,
        supports_update: false,
        ..base("magicpig")
    }
}

/// InfiniGen: the key cache (plus speculation workspace) stays on GPU —
/// ~55% of KV bytes — with per-layer speculative selection and uncached
/// PCIe fetches. The GPU-resident key cache is why it OOMs at 1M (§5.3).
pub fn infinigen() -> SystemProfile {
    SystemProfile {
        gpu_key_frac: 0.55,
        pcie_fetch_frac: 1.0,
        exact_frac: 0.05,
        scan_frac: 0.25,
        per_layer_overhead_s: 25e-6,
        ..base("infinigen")
    }
}

/// PQCache: codes + codebooks scanned each step, selected tokens fetched
/// over PCIe, serial GPU-CPU pipeline.
pub fn pqcache() -> SystemProfile {
    SystemProfile {
        pcie_fetch_frac: 1.0,
        exact_frac: 0.018,
        scan_frac: 0.04,
        per_layer_overhead_s: 40e-6,
        cpu_mgmt_s_per_seq: 30e-6,
        ..base("pqcache")
    }
}

/// StreamingLLM: sink + window only; tiny GPU footprint.
pub fn streaming() -> SystemProfile {
    SystemProfile { exact_frac: 0.0, exact_fixed: 1024 + 68, ..base("streaming") }
}

/// RetroInfer with GPU cache + async updates (paper configuration).
/// `hit_ratio` is the measured block-cache hit ratio (0.79-0.94).
pub fn retroinfer(hit_ratio: f64) -> SystemProfile {
    SystemProfile {
        gpu_cache_frac: 0.05,
        meta_frac: 1.0 / 16.0,
        exact_frac: 0.018,
        pcie_fetch_frac: 1.0,
        hit_ratio,
        est_frac: 0.232,
        scan_frac: 1.0 / 32.0, // centroid scoring reads K-side meta
        overlap_transfers: true,
        async_update: true,
        cpu_mgmt_s_per_seq: 0.3e-6,
        ..base("retroinfer")
    }
}

/// RetroInfer over a tiered KV arena: the hot RAM tier is capped below
/// the working set, so `spill_frac` of the uncached fetches read
/// through the cold spill tier first (DESIGN.md §2 "Tiered arena &
/// spill"; prefetch overlap is modeled by `overlap_transfers`).
pub fn retroinfer_spilled(hit_ratio: f64, spill_frac: f64) -> SystemProfile {
    SystemProfile { name: "retroinfer-spill", spill_frac, ..retroinfer(hit_ratio) }
}

/// RetroInfer over a tiered arena with a lossy spill codec on the cold
/// pages: the same spilled fraction crosses the spill channel at
/// `codec_ratio` (physical/logical) of its logical size, so effective
/// spill bandwidth scales by `1/codec_ratio` (≈0.47 for int8 angle
/// quantization at d=16 — the fig13 measured cell).
pub fn retroinfer_spilled_compressed(
    hit_ratio: f64,
    spill_frac: f64,
    codec_ratio: f64,
) -> SystemProfile {
    SystemProfile {
        name: "retroinfer-spill-comp",
        spill_codec_ratio: codec_ratio,
        ..retroinfer_spilled(hit_ratio, spill_frac)
    }
}

/// RetroInfer with cross-session prefix sharing: `shared_frac` of each
/// sequence's KV is a template prefix deduplicated across the batch
/// (DESIGN.md §2 "Prefix sharing & CoW") — resident once in host
/// memory, served once from the shared GPU prefix cache instead of
/// refetched per session.
pub fn retroinfer_prefix(hit_ratio: f64, shared_frac: f64) -> SystemProfile {
    SystemProfile {
        name: "retroinfer-prefix",
        shared_prefix_frac: shared_frac,
        ..retroinfer(hit_ratio)
    }
}

/// Figure 16 "Base": KV offloaded, no GPU cache, synchronous management.
pub fn retroinfer_base() -> SystemProfile {
    SystemProfile {
        gpu_cache_frac: 0.0,
        hit_ratio: 0.0,
        overlap_transfers: false,
        async_update: false,
        cpu_mgmt_s_per_seq: 5e-6,
        ..retroinfer(0.0)
    }
}

/// Figure 16 "+GPU cache": cache on, updates still synchronous.
pub fn retroinfer_sync(hit_ratio: f64) -> SystemProfile {
    SystemProfile {
        async_update: false,
        cpu_mgmt_s_per_seq: 5e-6,
        ..retroinfer(hit_ratio)
    }
}

/// RetroInfer-GPU: keeps KV on GPU for light loads (Fig. 17 variant).
pub fn retroinfer_gpu() -> SystemProfile {
    SystemProfile {
        kv_on_gpu: true,
        gpu_cache_frac: 0.0,
        pcie_fetch_frac: 0.0,
        hit_ratio: 0.0,
        ..retroinfer(0.0)
    }
}

/// All headline systems for the throughput figures.
pub fn headline() -> Vec<SystemProfile> {
    vec![full(), quest(), magicpig(), infinigen(), pqcache(), retroinfer(0.85)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_have_distinct_names() {
        let names: Vec<&str> = headline().iter().map(|p| p.name).collect();
        let mut dedup = names.clone();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len());
    }

    #[test]
    fn retro_memory_footprint_is_small() {
        let p = retroinfer(0.85);
        assert!(!p.kv_on_gpu);
        assert!(p.gpu_cache_frac + p.meta_frac < 0.15);
    }

    #[test]
    fn compressed_spill_profile_inherits_and_scales() {
        let p = retroinfer_spilled_compressed(0.85, 0.3, 0.47);
        assert_eq!(p.name, "retroinfer-spill-comp");
        assert_eq!(p.spill_frac, 0.3);
        assert_eq!(p.spill_codec_ratio, 0.47);
        // everything else matches the uncompressed spill profile
        let u = retroinfer_spilled(0.85, 0.3);
        assert_eq!(u.spill_codec_ratio, 1.0);
        assert_eq!(p.hit_ratio, u.hit_ratio);
        assert_eq!(p.pcie_fetch_frac, u.pcie_fetch_frac);
    }

    #[test]
    fn builders_override() {
        let p = retroinfer(0.5).with_hit_ratio(0.9).with_exact_frac(0.05).with_est_frac(0.3);
        assert_eq!(p.hit_ratio, 0.9);
        assert_eq!(p.exact_frac, 0.05);
        assert_eq!(p.est_frac, 0.3);
    }
}
