//! Analytic hardware simulator (DESIGN.md §1 substitution for the A100
//! testbed). Throughput figures (13, 14, 16, 17) depend on byte/flop
//! accounting and on what overlaps with what — this module models exactly
//! that, calibrated to the paper's §2.2 numbers. The *behavioural* inputs
//! (hit ratios, PCIe bytes per step, retrieval fractions) come from
//! running the real wave-index/wave-buffer code on workload traces; only
//! the per-byte and per-flop costs are modeled.

pub mod profiles;

pub use profiles::SystemProfile;

use crate::config::{HardwareSpec, ModelSpec};

/// Why a configuration cannot run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimError {
    GpuOom,
    CpuOom,
}

/// Breakdown of one decode step (seconds), before overlap composition.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepBreakdown {
    pub dense_s: f64,
    pub attn_gpu_s: f64,
    pub scan_s: f64,
    pub estimation_s: f64,
    pub pcie_s: f64,
    /// Cold-spill-tier read time (tiered KV arena: the fraction of
    /// uncached fetches whose blocks live below the hot RAM tier).
    pub spill_s: f64,
    pub cpu_s: f64,
    pub overhead_s: f64,
    /// Final composed step latency.
    pub total_s: f64,
}

/// GPU memory required by `profile` at (ctx, batch), bytes per GPU.
pub fn gpu_mem_bytes(
    model: &ModelSpec,
    profile: &SystemProfile,
    ctx: usize,
    batch: usize,
) -> usize {
    let g = model.n_gpus;
    let weights = model.weight_bytes() / g;
    let kv = model.kv_cache_bytes(ctx, batch) / g;
    let mut mem = weights;
    if profile.kv_on_gpu {
        mem += kv;
    }
    // partial/full key cache kept on GPU for speculation (InfiniGen).
    mem += (kv as f64 * profile.gpu_key_frac) as usize;
    // GPU block cache (RetroInfer).
    mem += (kv as f64 * profile.gpu_cache_frac) as usize;
    // meta index: centroids + vsum approx = (K+V)/tokens_per_cluster.
    mem += (kv as f64 * profile.meta_frac) as usize;
    // representatives scan structures (Quest min/max = K/chunk * 2).
    mem += (kv as f64 * profile.scan_struct_frac) as usize;
    mem
}

/// Host memory required, bytes. Cross-session prefix sharing stores
/// the shared fraction once per batch instead of once per sequence
/// (refcounted blocks, DESIGN.md §2 "Prefix sharing & CoW").
pub fn cpu_mem_bytes(model: &ModelSpec, profile: &SystemProfile, ctx: usize, batch: usize) -> usize {
    if profile.kv_on_gpu {
        0
    } else {
        let kv = model.kv_cache_bytes(ctx, batch);
        if profile.shared_prefix_frac > 0.0 && batch > 1 {
            let dedup = profile.shared_prefix_frac * (batch - 1) as f64 / batch as f64;
            (kv as f64 * (1.0 - dedup)) as usize
        } else {
            kv
        }
    }
}

/// Check capacity; Ok(()) if (ctx, batch) fits.
pub fn check_fit(
    model: &ModelSpec,
    hw: &HardwareSpec,
    profile: &SystemProfile,
    ctx: usize,
    batch: usize,
) -> Result<(), SimError> {
    // reserve 1% of GPU memory for activations/workspace (the paper's
    // "max batch 4 / max context 512K" calibration points sit right at
    // the capacity edge, so the reserve must be small)
    if gpu_mem_bytes(model, profile, ctx, batch) as f64 > 0.99 * hw.gpu_mem_bytes as f64 {
        return Err(SimError::GpuOom);
    }
    if cpu_mem_bytes(model, profile, ctx, batch) > hw.cpu_mem_bytes {
        return Err(SimError::CpuOom);
    }
    Ok(())
}

/// Largest batch that fits at context `ctx` (0 if even batch 1 OOMs).
pub fn max_batch(model: &ModelSpec, hw: &HardwareSpec, profile: &SystemProfile, ctx: usize) -> usize {
    let mut b = 0;
    while b < 4096 && check_fit(model, hw, profile, ctx, b + 1).is_ok() {
        b += 1;
    }
    b
}

/// MFU for dense GEMMs in decode (memory-bound at small batch; the max
/// with the weight-read term handles that regime).
const DENSE_EFF: f64 = 0.5;
/// Efficiency of the irregular estimation kernel.
const EST_EFF: f64 = 0.1;
/// Number of kernel launches per layer on the decode path.
const KERNELS_PER_LAYER: f64 = 6.0;
/// Effective fraction of host STREAM bandwidth reachable by CPU attention
/// over LSH-sampled (randomly scattered) KV vectors — gathers, not streams.
const CPU_GATHER_EFF: f64 = 0.35;

/// One decode step (all layers) for `batch` sequences at context `ctx`.
pub fn decode_step(
    model: &ModelSpec,
    hw: &HardwareSpec,
    profile: &SystemProfile,
    ctx: usize,
    batch: usize,
) -> StepBreakdown {
    let b = batch as f64;
    let g = model.n_gpus as f64;
    let mut br = StepBreakdown::default();

    // Dense projections + MLP: weight-read bound at small batch,
    // flop bound at large batch. Weights are read once per step.
    let w_read = (model.weight_bytes() as f64 / g) / hw.gpu_bw;
    let dense_flops = b * model.decode_dense_flops() / g;
    br.dense_s = w_read.max(hw.gpu_compute_s(dense_flops, DENSE_EFF));

    // Exact attention over the selected tokens.
    let n_exact = (profile.exact_frac * ctx as f64) as usize + profile.exact_fixed;
    let attn_bytes = b * model.attention_read_bytes(n_exact) as f64 / g;
    let attn_flops = b * model.attention_flops(n_exact) / g;
    if profile.cpu_attention {
        // MagicPIG: attention on the host.
        br.cpu_s = (attn_bytes / (hw.cpu_bw * CPU_GATHER_EFF)).max(attn_flops / hw.cpu_flops);
        // only q down / output up cross PCIe (negligible bytes, latency only)
        br.pcie_s = model.n_layers as f64 * hw.pcie_latency_s;
    } else {
        br.attn_gpu_s = (attn_bytes / hw.gpu_bw).max(hw.gpu_compute_s(attn_flops, DENSE_EFF));
        if profile.pcie_fetch_frac > 0.0 {
            // Execution-buffer assembly: selected KV is COPIED into the
            // contiguous execution buffer (read + write = 2x bytes) before
            // attention can run — the gather cost the paper's dedicated
            // CUDA copy kernels minimize but cannot remove (§4.6).
            br.attn_gpu_s += 2.0 * attn_bytes / hw.gpu_bw;
        }
        // PCIe fetch for the non-cached fraction of selected KV. Shared
        // prefix blocks are GPU-resident once per batch (cross-session
        // cache), so their fetches are paid by one session, not all.
        let fetch = attn_bytes
            * profile.pcie_fetch_frac
            * (1.0 - profile.hit_ratio)
            * (1.0 - profile.shared_prefix_frac * (b - 1.0) / b.max(1.0));
        if fetch > 0.0 {
            br.pcie_s = fetch / hw.pcie_bw + model.n_layers as f64 * hw.pcie_latency_s;
        }
        // Tiered arena: part of the uncached fetches first climb from
        // the cold spill tier into hot RAM (fig13/fig14 account for the
        // new tier through this term). A lossy spill codec moves only
        // `spill_codec_ratio` of the logical bytes over the channel —
        // compression as an effective-bandwidth multiplier.
        if fetch > 0.0 && profile.spill_frac > 0.0 {
            br.spill_s = fetch * profile.spill_frac * profile.spill_codec_ratio / hw.spill_bw;
        }
    }

    // Representative / meta / signature scan per step.
    let scan_bytes = b * profile.scan_frac * model.attention_read_bytes(ctx) as f64 / g;
    br.scan_s = scan_bytes / hw.gpu_bw;

    // Estimation zone: O(m) weighted merge over centroids.
    if profile.est_frac > 0.0 {
        let est_clusters = profile.est_frac * ctx as f64 / 16.0;
        let est_flops = b * model.attention_flops(est_clusters as usize) / g;
        br.estimation_s = hw.gpu_compute_s(est_flops, EST_EFF);
    }

    // Software overhead per layer (speculation, PQ management, ...).
    br.overhead_s = model.n_layers as f64
        * (profile.per_layer_overhead_s + KERNELS_PER_LAYER * hw.kernel_launch_s);

    // Cache-management CPU cost (mapping lookups + replacement) — paid
    // per layer per sequence when synchronous (the paper's 1.5ms/layer
    // LRU overhead observation motivates decoupling, Fig. 16).
    let mgmt_s = b * model.n_layers as f64 * profile.cpu_mgmt_s_per_seq;

    // Compose with overlap:
    let gpu_s = br.dense_s + br.attn_gpu_s + br.scan_s + br.estimation_s;
    br.total_s = if profile.overlap_transfers {
        // PCIe + spill prefetch + async CPU work overlap GPU compute
        // (wave buffer one level up, prefetch worker one level down).
        // Only the *measured* overlapped fraction of spill time hides
        // under the max (the pipelined executor's intra-step
        // spill_overlap_pct); the un-overlapped remainder is a gather
        // stall and serializes after it.
        let spill_hidden = br.spill_s * profile.spill_overlap_frac;
        let spill_stall = br.spill_s - spill_hidden;
        gpu_s
            .max(br.pcie_s)
            .max(spill_hidden)
            .max(br.cpu_s + if profile.async_update { 0.0 } else { mgmt_s })
            + spill_stall
            + if profile.async_update { 0.0 } else { mgmt_s }
            + br.overhead_s
    } else {
        // Serial composition (InfiniGen/PQCache-style pipelines).
        gpu_s + br.pcie_s + br.spill_s + br.cpu_s + mgmt_s + br.overhead_s
    };
    br
}

/// Decoding throughput in tokens/s (whole batch) or the OOM error.
pub fn decode_throughput(
    model: &ModelSpec,
    hw: &HardwareSpec,
    profile: &SystemProfile,
    ctx: usize,
    batch: usize,
) -> Result<f64, SimError> {
    check_fit(model, hw, profile, ctx, batch)?;
    let st = decode_step(model, hw, profile, ctx, batch);
    Ok(batch as f64 / st.total_s)
}

/// Prefill latency (seconds) for one sequence of `ctx` tokens.
/// `cluster_frac_measured` is the measured segmented-clustering share of
/// prefill flops (from the real index build), ~0 for baselines.
pub fn prefill_latency(
    model: &ModelSpec,
    hw: &HardwareSpec,
    ctx: usize,
    cluster_flops: f64,
    offload: bool,
) -> f64 {
    let g = model.n_gpus as f64;
    let t = ctx as f64;
    let dense = t * model.decode_dense_flops() / g;
    // causal attention: sum_i flops(i) = flops(ctx) * ctx / 2
    let attn = model.attention_flops(ctx) * t / 2.0 / g;
    let compute_s = hw.gpu_compute_s(dense + attn + cluster_flops, 0.45);
    let offload_s = if offload {
        // KV offload to CPU memory overlaps compute; only the tail shows.
        let bytes = model.kv_cache_bytes(ctx, 1) as f64 / g;
        (bytes / hw.pcie_bw - compute_s).max(0.0) + 0.004 * compute_s
    } else {
        0.0
    };
    compute_s + offload_s
}

/// Segmented-clustering flops for a prefill of `ctx` tokens
/// (k-means assign+update per segment, all layers and kv heads).
pub fn clustering_flops(model: &ModelSpec, ctx: usize, segment: usize, iters: usize) -> f64 {
    let seg = segment.min(ctx) as f64;
    let k = seg / 16.0;
    let n_seg = (ctx as f64 / seg).ceil();
    let per_seg = seg * k * model.d_head as f64 * 2.0 * iters as f64;
    per_seg * n_seg * (model.n_layers * model.kv_heads) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use profiles::*;

    fn setup() -> (ModelSpec, HardwareSpec) {
        (ModelSpec::llama3_8b(), HardwareSpec::a100())
    }

    #[test]
    fn full_attention_oom_matches_paper() {
        let (m, hw) = setup();
        let p = full();
        // §2.2: max batch 4 at 128K, max context 512K at batch 1.
        let mb = max_batch(&m, &hw, &p, 128 * 1024);
        assert!((3..=5).contains(&mb), "max batch at 128K = {mb}");
        assert!(check_fit(&m, &hw, &p, 512 * 1024, 1).is_ok());
        assert_eq!(check_fit(&m, &hw, &p, 1 << 20, 1), Err(SimError::GpuOom));
    }

    #[test]
    fn full_attention_bandwidth_saturates() {
        // §2.2: beyond batch ~3 at 128K, throughput gains are marginal
        // because attention reads saturate HBM.
        let (m, hw) = setup();
        let p = full();
        let t1 = decode_throughput(&m, &hw, &p, 128 * 1024, 1).unwrap();
        let t3 = decode_throughput(&m, &hw, &p, 128 * 1024, 3).unwrap();
        let t4 = decode_throughput(&m, &hw, &p, 128 * 1024, 4).unwrap();
        assert!(t3 > 1.4 * t1, "some scaling up to 3: {t3} vs {t1}");
        assert!(t4 < 1.2 * t3, "saturation beyond 3: {t4} vs {t3}");
    }

    #[test]
    fn retroinfer_scales_past_full_attention() {
        let (m, hw) = setup();
        let pf = full();
        let pr = retroinfer(0.85);
        let ctx = 120 * 1024;
        let bf = max_batch(&m, &hw, &pf, ctx);
        let br = max_batch(&m, &hw, &pr, ctx);
        assert!(br >= 4 * bf, "retro batch {br} vs full {bf}");
        let tf = decode_throughput(&m, &hw, &pf, ctx, bf).unwrap();
        let tr = decode_throughput(&m, &hw, &pr, ctx, br.min(64)).unwrap();
        let speedup = tr / tf;
        assert!(
            (2.5..8.0).contains(&speedup),
            "paper reports ~4.4x at 120K; got {speedup:.1}x"
        );
    }

    #[test]
    fn million_token_survivors() {
        // Fig 13d: full/Quest/InfiniGen OOM at 1M; RetroInfer, MagicPIG,
        // PQCache survive; RetroInfer wins by ~an order of magnitude.
        let (m, hw) = setup();
        let ctx = 1 << 20;
        assert_eq!(max_batch(&m, &hw, &full(), ctx), 0);
        assert_eq!(max_batch(&m, &hw, &quest(), ctx), 0);
        assert_eq!(max_batch(&m, &hw, &infinigen(), ctx), 0);
        let br = max_batch(&m, &hw, &retroinfer(0.85), ctx);
        assert!(br >= 2, "retro batch at 1M = {br}");
        let tr = decode_throughput(&m, &hw, &retroinfer(0.85), ctx, br).unwrap();
        let tm = decode_throughput(&m, &hw, &magicpig(), ctx, br.min(max_batch(&m, &hw, &magicpig(), ctx))).unwrap();
        let tp = decode_throughput(&m, &hw, &pqcache(), ctx, br.min(max_batch(&m, &hw, &pqcache(), ctx))).unwrap();
        assert!(tr / tm > 4.0, "vs magicpig: {:.1}x", tr / tm);
        assert!(tr / tp > 4.0, "vs pqcache: {:.1}x", tr / tp);
    }

    #[test]
    fn gpu_cache_and_async_update_help() {
        // Fig 16 ablation ordering: base < +cache < +async.
        let (m, hw) = setup();
        let ctx = 120 * 1024;
        let b = 16;
        let t_base = decode_throughput(&m, &hw, &retroinfer_base(), ctx, b).unwrap();
        let t_cache = decode_throughput(&m, &hw, &retroinfer_sync(0.85), ctx, b).unwrap();
        let t_async = decode_throughput(&m, &hw, &retroinfer(0.85), ctx, b).unwrap();
        assert!(t_cache > 1.2 * t_base, "cache helps: {t_cache} vs {t_base}");
        assert!(t_async > 1.02 * t_cache, "async helps: {t_async} vs {t_cache}");
    }

    #[test]
    fn spill_tier_costs_bandwidth_but_survives_1m() {
        let (m, hw) = setup();
        let ctx = 1 << 20;
        let b = 4;
        let t_hot = decode_throughput(&m, &hw, &retroinfer(0.85), ctx, b).unwrap();
        let t_some = decode_throughput(&m, &hw, &retroinfer_spilled(0.85, 0.3), ctx, b).unwrap();
        let t_most = decode_throughput(&m, &hw, &retroinfer_spilled(0.85, 0.9), ctx, b).unwrap();
        assert!(t_some <= t_hot, "spill cannot be free: {t_some} vs {t_hot}");
        assert!(t_most <= t_some, "more spill is monotonically slower");
        assert!(t_most > 0.0, "spilled serving still survives at 1M");
        // the spill term shows up in the breakdown
        let br = decode_step(&m, &hw, &retroinfer_spilled(0.85, 0.9), ctx, b);
        assert!(br.spill_s > 0.0);
        assert_eq!(decode_step(&m, &hw, &retroinfer(0.85), ctx, b).spill_s, 0.0);
    }

    #[test]
    fn spill_codec_scales_effective_bandwidth() {
        let (m, hw) = setup();
        let ctx = 1 << 20;
        let b = 4;
        // the spill term scales linearly with the physical/logical ratio
        let s_exact = decode_step(&m, &hw, &retroinfer_spilled(0.85, 0.9), ctx, b).spill_s;
        let s_int8 =
            decode_step(&m, &hw, &retroinfer_spilled_compressed(0.85, 0.9, 0.47), ctx, b).spill_s;
        let s_int4 =
            decode_step(&m, &hw, &retroinfer_spilled_compressed(0.85, 0.9, 0.35), ctx, b).spill_s;
        assert!((s_int8 / s_exact - 0.47).abs() < 1e-9, "{s_int8} vs {s_exact}");
        assert!(s_int4 < s_int8, "a smaller ratio moves fewer bytes");
        // throughput is monotone in the ratio: compression never hurts
        let t_exact = decode_throughput(&m, &hw, &retroinfer_spilled(0.85, 0.9), ctx, b).unwrap();
        let t_int8 =
            decode_throughput(&m, &hw, &retroinfer_spilled_compressed(0.85, 0.9, 0.47), ctx, b)
                .unwrap();
        let t_int4 =
            decode_throughput(&m, &hw, &retroinfer_spilled_compressed(0.85, 0.9, 0.35), ctx, b)
                .unwrap();
        assert!(t_int8 >= t_exact, "compression cannot slow the channel: {t_int8} vs {t_exact}");
        assert!(t_int4 >= t_int8, "monotone in ratio: {t_int4} vs {t_int8}");
        // an incompressible codec (ratio 1.0) is exactly the uncompressed row
        let t_unit =
            decode_throughput(&m, &hw, &retroinfer_spilled_compressed(0.85, 0.9, 1.0), ctx, b)
                .unwrap();
        assert_eq!(t_unit, t_exact);
    }

    #[test]
    fn partial_spill_overlap_serializes_the_remainder() {
        let (m, hw) = setup();
        let ctx = 1 << 20;
        let b = 4;
        let p = retroinfer_spilled(0.85, 0.9);
        let t_full = decode_throughput(&m, &hw, &p, ctx, b).unwrap();
        let t_half = decode_throughput(&m, &hw, &p.clone().with_spill_overlap(0.5), ctx, b).unwrap();
        let t_none = decode_throughput(&m, &hw, &p.clone().with_spill_overlap(0.0), ctx, b).unwrap();
        assert!(t_half <= t_full, "less overlap cannot be faster: {t_half} vs {t_full}");
        assert!(t_none <= t_half, "monotone in the overlap fraction: {t_none} vs {t_half}");
        // the un-overlapped stall must visibly serialize: zero overlap
        // adds min(spill_s, rest-of-max) on top of the composed step
        let st_full = decode_step(&m, &hw, &p, ctx, b);
        let st_none = decode_step(&m, &hw, &p.clone().with_spill_overlap(0.0), ctx, b);
        assert!(st_full.spill_s > 0.0);
        assert!(
            st_none.total_s > st_full.total_s,
            "a fully-serialized spill term must lengthen the step: {} vs {}",
            st_none.total_s,
            st_full.total_s
        );
        // overlap_frac 1.0 composes exactly as before (default unchanged)
        assert_eq!(
            decode_throughput(&m, &hw, &p.clone().with_spill_overlap(1.0), ctx, b).unwrap(),
            t_full
        );
    }

    #[test]
    fn prefix_sharing_saves_memory_and_transfers() {
        let (m, hw) = setup();
        let ctx = 120 * 1024;
        let b = 16;
        // host footprint: 75% shared across 16 sequences ≈ 0.297 of dense
        let dense = cpu_mem_bytes(&m, &retroinfer(0.85), ctx, b);
        let deduped = cpu_mem_bytes(&m, &retroinfer_prefix(0.85, 0.75), ctx, b);
        assert!(deduped < dense / 2, "dedup must shrink host KV: {deduped} vs {dense}");
        assert_eq!(
            cpu_mem_bytes(&m, &retroinfer_prefix(0.85, 0.75), ctx, 1),
            cpu_mem_bytes(&m, &retroinfer(0.85), ctx, 1),
            "a lone session has nothing to share"
        );
        // throughput: fewer PCIe fetches can only help, monotonically
        let t0 = decode_throughput(&m, &hw, &retroinfer(0.85), ctx, b).unwrap();
        let t1 = decode_throughput(&m, &hw, &retroinfer_prefix(0.85, 0.5), ctx, b).unwrap();
        let t2 = decode_throughput(&m, &hw, &retroinfer_prefix(0.85, 0.9), ctx, b).unwrap();
        assert!(t1 >= t0, "sharing cannot slow decode: {t1} vs {t0}");
        assert!(t2 >= t1, "more sharing is monotonically no slower");
        // the PCIe term visibly shrinks
        let pf = decode_step(&m, &hw, &retroinfer(0.85), ctx, b).pcie_s;
        let ps = decode_step(&m, &hw, &retroinfer_prefix(0.85, 0.9), ctx, b).pcie_s;
        assert!(ps < pf, "shared-prefix fetch bytes must drop: {ps} vs {pf}");
    }

    #[test]
    fn prefill_clustering_fraction_small() {
        // §4.4 / Fig 15: segmented clustering <5% of prefill.
        let (m, hw) = setup();
        for ctx in [120 * 1024, 1 << 20] {
            let cf = clustering_flops(&m, ctx, 8192, 10);
            let t0 = prefill_latency(&m, &hw, ctx, 0.0, false);
            let t1 = prefill_latency(&m, &hw, ctx, cf, ctx == 1 << 20);
            assert!(t1 < 1.07 * t0, "ctx {ctx}: {t1} vs {t0}");
        }
    }

    #[test]
    fn qwen72b_needs_8_gpus() {
        let m = ModelSpec::qwen25_72b();
        let hw = HardwareSpec::a100();
        // per-GPU weights ~18GB; retro at 128K batch 8 fits
        assert!(check_fit(&m, &hw, &retroinfer(0.85), 128 * 1024, 8).is_ok());
        // single-GPU hypothetical would not (weights alone ~145GB)
        let m1 = ModelSpec { n_gpus: 1, ..m };
        assert_eq!(check_fit(&m1, &hw, &full(), 1024, 1), Err(SimError::GpuOom));
    }
}
