//! RetroInfer CLI — leader entrypoint.
//!
//! Subcommands:
//!   info                     show artifacts / model / zone configuration
//!   serve                    live TinyLM serving through PJRT (wave or full)
//!   sim                      paper-scale decode-throughput simulation
//!   accuracy                 attention-fidelity comparison across systems
//!
//! Examples:
//!   retroinfer serve --prompt-len 2048 --requests 4 --max-new 16
//!   retroinfer sim --system retroinfer --ctx 131072 --batch 16
//!   retroinfer accuracy --task s_niah --ctx 8192 --budget 0.018

use retroinfer::baselines::{all_systems, SparseSystem};
use retroinfer::config::{HardwareSpec, ModelSpec};
use retroinfer::coordinator::{Action, Batcher, Request, Scheduler};
use retroinfer::engine::{AttnMode, LiveEngine};
use retroinfer::memsim::{self, profiles};
use retroinfer::runtime::default_artifacts_dir;
use retroinfer::util::bench::Table;
use retroinfer::util::cli::Args;
use retroinfer::util::rng::Rng;
use retroinfer::util::stats::cosine;
use retroinfer::workload::tasks::{self, TaskKind};
use std::time::Instant;

fn main() {
    let args = Args::from_env(true);
    let code = match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("serve") => cmd_serve(&args),
        Some("sim") => cmd_sim(&args),
        Some("accuracy") => cmd_accuracy(&args),
        _ => {
            eprintln!("usage: retroinfer <info|serve|sim|accuracy> [--flags]");
            eprintln!("see `cargo run -- info` or the module docs for details");
            2
        }
    };
    std::process::exit(code);
}

fn cmd_info(_args: &Args) -> i32 {
    let dir = default_artifacts_dir();
    match retroinfer::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {dir}");
            println!(
                "model: {} (layers={} d_model={} q_heads={} kv_heads={} d_head={})",
                m.model.name, m.model.n_layers, m.model.d_model, m.model.q_heads,
                m.model.kv_heads, m.model.d_head
            );
            println!(
                "buckets: batch={:?} prefill_t={:?} wave_ne={} wave_m={}",
                m.buckets.batch, m.buckets.prefill_t, m.buckets.wave_ne, m.buckets.wave_m
            );
            println!("executables: {}", m.executables.len());
            0
        }
        Err(e) => {
            eprintln!("error: {e:#} (run `make artifacts` first)");
            1
        }
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let dir = default_artifacts_dir();
    let prompt_len = args.usize_or("prompt-len", 2048);
    let n_requests = args.usize_or("requests", 2);
    let max_new = args.usize_or("max-new", 16);
    let mode = if args.str_or("mode", "wave") == "full" { AttnMode::Full } else { AttnMode::Wave };
    let seed = args.u64_or("seed", 7);

    println!("# live serve: mode={mode:?} prompt_len={prompt_len} requests={n_requests} max_new={max_new}");
    let mut eng = match LiveEngine::new(&dir, mode) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("engine init failed: {e:#}");
            return 1;
        }
    };
    let mut sched = Scheduler::new(Batcher::new(&[1, 2, 4, 8], 8));
    let mut rng = Rng::new(seed);
    for id in 0..n_requests as u64 {
        let prompt: Vec<i32> = (0..prompt_len).map(|_| rng.below(256) as i32).collect();
        sched.submit(Request::new(id, prompt, max_new), 0.0);
    }

    let t0 = Instant::now();
    while !sched.all_done() {
        match sched.next_action() {
            Action::Prefill(id) => {
                let prompt = sched.session(id).unwrap().req.prompt.clone();
                match eng.prefill(id, &prompt) {
                    Ok(tok) => sched.prefill_done(id, tok, t0.elapsed().as_secs_f64()),
                    Err(e) => {
                        eprintln!("prefill {id} failed: {e:#}");
                        return 1;
                    }
                }
            }
            Action::DecodeBatch(ids, bucket) => match eng.decode_step(&ids, bucket) {
                Ok(toks) => {
                    let now = t0.elapsed().as_secs_f64();
                    for (id, t) in ids.iter().zip(toks) {
                        sched.token_decoded(*id, t, now);
                    }
                }
                Err(e) => {
                    eprintln!("decode failed: {e:#}");
                    return 1;
                }
            },
            // admission-gated prefills re-enter after reclamation below
            Action::Defer => {}
            Action::Idle => break,
        }
        // Session-finished events flow into engine reclamation: the
        // session's KV blocks go back to the arena free-list.
        for fid in sched.take_finished() {
            eng.finish_session(fid);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let toks = eng.metrics.counter("decoded_tokens");
    println!("completed {n_requests} requests in {wall:.2}s");
    println!("decode throughput: {:.1} tok/s", toks as f64 / wall.max(1e-9));
    println!("{}", eng.metrics.summary("decode_step_s"));
    println!("{}", eng.metrics.summary("prefill_s"));
    if mode == AttnMode::Wave {
        println!("wave-buffer hit ratio: {:.3}", eng.buffer_hit_ratio());
        println!("pcie bytes: {}", eng.metrics.counter("pcie_bytes"));
        println!("{}", eng.metrics.summary("assemble_s"));
        println!(
            "assembly steps: parallel={} serial={}",
            eng.metrics.counter("assembly_parallel_steps"),
            eng.metrics.counter("assembly_serial_steps"),
        );
    }
    println!(
        "arena: live={} blocks ({} B), free-list={} blocks, reclaimed={} blocks over {} sessions",
        eng.arena().live_blocks(),
        eng.arena().live_bytes(),
        eng.arena().free_blocks(),
        eng.metrics.counter("arena_reclaimed_blocks"),
        eng.metrics.counter("sessions_finished"),
    );
    for s in sched.sessions() {
        println!(
            "  req {}: {} tokens, first {:?}...",
            s.req.id,
            s.generated.len(),
            &s.generated[..s.generated.len().min(8)]
        );
    }
    0
}

fn cmd_sim(args: &Args) -> i32 {
    let model = ModelSpec::by_name(args.str_or("model", "llama3-8b")).expect("unknown model");
    let hw = HardwareSpec::by_name(args.str_or("hw", "a100")).expect("unknown hw");
    let ctx = args.usize_or("ctx", 128 * 1024);
    let batch = args.usize_or("batch", 0);
    let hit = args.f64_or("hit-ratio", 0.85);
    let system = args.str_or("system", "all").to_string();

    let profs: Vec<_> = match system.as_str() {
        "all" => profiles::headline(),
        "retroinfer" => vec![profiles::retroinfer(hit)],
        "full" => vec![profiles::full()],
        "quest" => vec![profiles::quest()],
        "magicpig" => vec![profiles::magicpig()],
        "infinigen" => vec![profiles::infinigen()],
        "pqcache" => vec![profiles::pqcache()],
        other => {
            eprintln!("unknown system {other}");
            return 2;
        }
    };

    println!("# sim: model={} hw={} ctx={ctx}", model.name, hw.name);
    let mut table = Table::new(&["system", "max_batch", "batch", "tok/s", "note"]);
    for p in profs {
        let mb = memsim::max_batch(&model, &hw, &p, ctx);
        let b = if batch == 0 { mb.min(64) } else { batch.min(mb) };
        let (tput, note) = if mb == 0 {
            (0.0, "OOM".to_string())
        } else {
            match memsim::decode_throughput(&model, &hw, &p, ctx, b) {
                Ok(t) => (t, String::new()),
                Err(e) => (0.0, format!("{e:?}")),
            }
        };
        table.row(vec![
            p.name.to_string(),
            mb.to_string(),
            b.to_string(),
            format!("{tput:.1}"),
            note,
        ]);
    }
    table.print();
    0
}

fn cmd_accuracy(args: &Args) -> i32 {
    let ctx = args.usize_or("ctx", 8192);
    let d = args.usize_or("d", 32);
    let budget_frac = args.f64_or("budget", 0.018);
    let n_queries = args.usize_or("queries", 8);
    let seed = args.u64_or("seed", 3);
    let kind = match args.str_or("task", "s_niah") {
        "s_niah" => TaskKind::SingleNeedle,
        "mv_niah" => TaskKind::MultiNeedle,
        "qa_1" => TaskKind::Qa,
        "fwe" => TaskKind::Aggregate,
        other => {
            eprintln!("unknown task {other}");
            return 2;
        }
    };

    let task = tasks::generate(kind, ctx, d, n_queries, seed);
    let wl = &task.workload;
    let budget = ((ctx as f64) * budget_frac) as usize + 68;
    println!("# accuracy: task={} ctx={ctx} budget={budget} tokens", kind.name());

    let mut full_outs: Vec<Vec<f32>> = Vec::new();
    {
        let mut full = retroinfer::baselines::FullAttention::new(&wl.keys, &wl.vals, d);
        for q in &wl.queries {
            let mut out = vec![0.0; d];
            full.decode(q, ctx, &mut out);
            full_outs.push(out);
        }
    }

    let mut table = Table::new(&["system", "needle_acc", "output_cos"]);
    for sys in all_systems(&wl.keys, &wl.vals, d, seed).iter_mut() {
        let mut exact = Vec::new();
        let mut cos_sum = 0.0;
        for (qi, q) in wl.queries.iter().enumerate() {
            let mut out = vec![0.0; d];
            let st = sys.decode(q, budget, &mut out);
            exact.push(st.exact_positions);
            cos_sum += cosine(&out, &full_outs[qi]);
        }
        let acc = tasks::needle_accuracy(&exact, &wl.needles);
        table.row(vec![
            sys.name().to_string(),
            format!("{:.3}", acc),
            format!("{:.4}", cos_sum / wl.queries.len() as f64),
        ]);
    }
    table.print();
    0
}
