//! Request arrival processes for the end-to-end load experiments
//! (Figure 17): Poisson open-loop arrivals and closed-loop clients.

use crate::util::rng::Rng;

/// One request in a load trace.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestSpec {
    /// Arrival time in seconds from trace start.
    pub arrive_s: f64,
    /// Prompt length in tokens.
    pub input_tokens: usize,
    /// Tokens to generate.
    pub output_tokens: usize,
}

/// Open-loop Poisson arrivals at `rate` req/s for `n` requests.
pub fn poisson_arrivals(
    rate: f64,
    n: usize,
    input_tokens: usize,
    output_tokens: usize,
    seed: u64,
) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exponential(rate);
            RequestSpec { arrive_s: t, input_tokens, output_tokens }
        })
        .collect()
}

/// Closed-loop trace: `clients` concurrent clients, each issuing its next
/// request immediately (arrival time 0 with think time folded into the
/// serving loop); total `n` requests.
pub fn closed_loop(
    clients: usize,
    n: usize,
    input_tokens: usize,
    output_tokens: usize,
) -> Vec<RequestSpec> {
    (0..n)
        .map(|i| RequestSpec {
            // first `clients` arrive at t=0, the rest are released by the
            // engine when a slot frees (arrive_s = f64::INFINITY marker).
            arrive_s: if i < clients { 0.0 } else { f64::INFINITY },
            input_tokens,
            output_tokens,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_interarrival() {
        let reqs = poisson_arrivals(10.0, 2000, 100, 10, 1);
        assert_eq!(reqs.len(), 2000);
        let total = reqs.last().unwrap().arrive_s;
        let mean = total / 2000.0;
        assert!((mean - 0.1).abs() < 0.02, "mean interarrival = {mean}");
        // strictly increasing
        for w in reqs.windows(2) {
            assert!(w[1].arrive_s >= w[0].arrive_s);
        }
    }

    #[test]
    fn closed_loop_marks_deferred() {
        let reqs = closed_loop(4, 10, 100, 10);
        assert_eq!(reqs.iter().filter(|r| r.arrive_s == 0.0).count(), 4);
        assert_eq!(reqs.iter().filter(|r| r.arrive_s.is_infinite()).count(), 6);
    }
}
