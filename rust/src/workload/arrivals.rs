//! Request arrival processes for the end-to-end load experiments
//! (Figure 17): Poisson open-loop arrivals, closed-loop clients, and
//! multi-tenant mixes for the admission-control scenarios.

use crate::kvcache::TenantId;
use crate::util::rng::Rng;

/// One request in a load trace.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestSpec {
    /// Arrival time in seconds from trace start.
    pub arrive_s: f64,
    /// Prompt length in tokens.
    pub input_tokens: usize,
    /// Tokens to generate.
    pub output_tokens: usize,
    /// Issuing tenant (0 for single-tenant traces).
    pub tenant: TenantId,
    /// Shared-prefix identity: requests with the same hash open with
    /// the same prompt prefix (system prompt / few-shot template). The
    /// router uses it for prefix affinity; the pressure harness for
    /// modelled block sharing. `None` = no shared prefix.
    pub prefix_hash: Option<u64>,
}

/// Open-loop Poisson arrivals at `rate` req/s for `n` requests.
pub fn poisson_arrivals(
    rate: f64,
    n: usize,
    input_tokens: usize,
    output_tokens: usize,
    seed: u64,
) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exponential(rate);
            RequestSpec { arrive_s: t, input_tokens, output_tokens, tenant: 0, prefix_hash: None }
        })
        .collect()
}

/// Closed-loop trace: `clients` concurrent clients, each issuing its next
/// request immediately (arrival time 0 with think time folded into the
/// serving loop); total `n` requests.
pub fn closed_loop(
    clients: usize,
    n: usize,
    input_tokens: usize,
    output_tokens: usize,
) -> Vec<RequestSpec> {
    (0..n)
        .map(|i| RequestSpec {
            // first `clients` arrive at t=0, the rest are released by the
            // engine when a slot frees (arrive_s = f64::INFINITY marker).
            arrive_s: if i < clients { 0.0 } else { f64::INFINITY },
            input_tokens,
            output_tokens,
            tenant: 0,
            prefix_hash: None,
        })
        .collect()
}

/// Multi-tenant open-loop mix: tenant `t` issues `n_per_tenant` Poisson
/// arrivals at `rates[t]` req/s from an independent seeded stream; the
/// streams are merged into one trace sorted by arrival time. Unequal
/// rates give the skewed per-tenant backlogs the admission gate's
/// fairness rule is tested against.
pub fn multi_tenant_poisson(
    rates: &[f64],
    n_per_tenant: usize,
    input_tokens: usize,
    output_tokens: usize,
    seed: u64,
) -> Vec<RequestSpec> {
    let mut all = Vec::with_capacity(rates.len() * n_per_tenant);
    for (t, &rate) in rates.iter().enumerate() {
        let mut rng = Rng::new(seed ^ (t as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut now = 0.0;
        for _ in 0..n_per_tenant {
            now += rng.exponential(rate);
            all.push(RequestSpec {
                arrive_s: now,
                input_tokens,
                output_tokens,
                tenant: t as TenantId,
                prefix_hash: None,
            });
        }
    }
    all.sort_by(|a, b| a.arrive_s.partial_cmp(&b.arrive_s).unwrap());
    all
}

/// Diurnal rate modulation: tenant `t`'s instantaneous rate swings
/// sinusoidally between `base` and `base × burst_mult` with period
/// `period_s`, each tenant's burst phase offset by `t / n_tenants` of a
/// period — tenants peak at different times, which is exactly the load
/// shape the SLO-aware scheduler's chunk budget and preemption are
/// exercised against (one tenant bursting while another decodes under a
/// TPOT target).
///
/// Arrivals are drawn by thinning: candidate events at the peak rate
/// `base × burst_mult`, each accepted with probability
/// `rate(t) / peak`. The trace is deterministic in `seed`, covers
/// `[0, horizon_s)`, and is sorted by arrival time.
pub fn diurnal_poisson(
    base_rates: &[f64],
    burst_mult: f64,
    period_s: f64,
    horizon_s: f64,
    input_tokens: usize,
    output_tokens: usize,
    seed: u64,
) -> Vec<RequestSpec> {
    assert!(burst_mult >= 1.0 && period_s > 0.0);
    let nt = base_rates.len().max(1);
    let mut all = Vec::new();
    for (t, &base) in base_rates.iter().enumerate() {
        if base <= 0.0 {
            continue;
        }
        let mut rng = Rng::new(seed ^ (t as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let peak = base * burst_mult;
        let phase = t as f64 / nt as f64;
        let mut now = 0.0;
        loop {
            now += rng.exponential(peak);
            if now >= horizon_s {
                break;
            }
            let s = (std::f64::consts::TAU * (now / period_s + phase)).sin();
            let rate = base * (1.0 + (burst_mult - 1.0) * 0.5 * (1.0 + s));
            if rng.f64() < rate / peak {
                all.push(RequestSpec {
                    arrive_s: now,
                    input_tokens,
                    output_tokens,
                    tenant: t as TenantId,
                    prefix_hash: None,
                });
            }
        }
    }
    all.sort_by(|a, b| a.arrive_s.partial_cmp(&b.arrive_s).unwrap());
    all
}

/// Stamp every request in `reqs` with the same shared-prefix hash
/// (one system prompt / template across the trace).
pub fn stamp_shared_prefix(reqs: &mut [RequestSpec], prefix_hash: u64) {
    for r in reqs.iter_mut() {
        r.prefix_hash = Some(prefix_hash);
    }
}

/// Open-loop Poisson arrivals over `n_prefixes` shared templates:
/// request `i` draws its template (prefix hash) from a seeded stream,
/// so the router's prefix-affinity and the pressure harness's
/// block-sharing paths see a realistic template mix.
pub fn shared_prefix_poisson(
    rate: f64,
    n: usize,
    n_prefixes: usize,
    input_tokens: usize,
    output_tokens: usize,
    seed: u64,
) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed ^ 0xA5A5_5A5A);
    let mut reqs = poisson_arrivals(rate, n, input_tokens, output_tokens, seed);
    for r in reqs.iter_mut() {
        let g = rng.below(n_prefixes.max(1)) as u64;
        r.prefix_hash = Some(0x70FF_1E00 ^ g.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    }
    reqs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_prefix_traces_carry_hashes() {
        let mut reqs = poisson_arrivals(5.0, 10, 64, 4, 2);
        assert!(reqs.iter().all(|r| r.prefix_hash.is_none()));
        stamp_shared_prefix(&mut reqs, 42);
        assert!(reqs.iter().all(|r| r.prefix_hash == Some(42)));
        let mix = shared_prefix_poisson(5.0, 40, 3, 64, 4, 7);
        let distinct: std::collections::HashSet<u64> =
            mix.iter().filter_map(|r| r.prefix_hash).collect();
        assert!(!distinct.is_empty() && distinct.len() <= 3);
        // deterministic across calls
        assert_eq!(mix, shared_prefix_poisson(5.0, 40, 3, 64, 4, 7));
    }

    #[test]
    fn poisson_mean_interarrival() {
        let reqs = poisson_arrivals(10.0, 2000, 100, 10, 1);
        assert_eq!(reqs.len(), 2000);
        let total = reqs.last().unwrap().arrive_s;
        let mean = total / 2000.0;
        assert!((mean - 0.1).abs() < 0.02, "mean interarrival = {mean}");
        // strictly increasing
        for w in reqs.windows(2) {
            assert!(w[1].arrive_s >= w[0].arrive_s);
        }
    }

    #[test]
    fn closed_loop_marks_deferred() {
        let reqs = closed_loop(4, 10, 100, 10);
        assert_eq!(reqs.iter().filter(|r| r.arrive_s == 0.0).count(), 4);
        assert_eq!(reqs.iter().filter(|r| r.arrive_s.is_infinite()).count(), 6);
    }

    #[test]
    fn diurnal_arrivals_burst_at_staggered_phases() {
        // two tenants, phases 0 and 0.5: peaks at t=20 and t=60 of an
        // 80 s period (4× burst over a base of 5 req/s)
        let reqs = diurnal_poisson(&[5.0, 5.0], 4.0, 80.0, 80.0, 64, 8, 11);
        assert!(!reqs.is_empty());
        for w in reqs.windows(2) {
            assert!(w[1].arrive_s >= w[0].arrive_s, "trace not sorted");
        }
        assert!(reqs.iter().all(|r| r.arrive_s < 80.0), "horizon bound");
        let count = |t: u32, lo: f64, hi: f64| {
            reqs.iter()
                .filter(|r| r.tenant == t && r.arrive_s >= lo && r.arrive_s < hi)
                .count() as f64
        };
        // each tenant's peak window is much denser than its trough
        assert!(count(0, 10.0, 30.0) > 2.0 * count(0, 50.0, 70.0), "tenant 0 bursts at 20");
        assert!(count(1, 50.0, 70.0) > 2.0 * count(1, 10.0, 30.0), "tenant 1 bursts at 60");
        // in tenant 0's burst window, tenant 1 idles (staggered phases)
        assert!(count(0, 10.0, 30.0) > 2.0 * count(1, 10.0, 30.0));
        // deterministic across calls
        assert_eq!(reqs, diurnal_poisson(&[5.0, 5.0], 4.0, 80.0, 80.0, 64, 8, 11));
    }

    #[test]
    fn diurnal_with_unit_burst_is_plain_poisson_rate() {
        // burst_mult = 1: constant rate; mean arrivals ≈ rate × horizon
        let reqs = diurnal_poisson(&[10.0], 1.0, 50.0, 200.0, 64, 8, 3);
        let n = reqs.len() as f64;
        assert!((n - 2000.0).abs() < 200.0, "expected ~2000 arrivals, got {n}");
        assert!(reqs.iter().all(|r| r.tenant == 0));
    }

    #[test]
    fn multi_tenant_mix_merges_sorted_streams() {
        let reqs = multi_tenant_poisson(&[8.0, 2.0, 1.0], 50, 100, 10, 3);
        assert_eq!(reqs.len(), 150);
        for t in 0..3u32 {
            assert_eq!(reqs.iter().filter(|r| r.tenant == t).count(), 50);
        }
        for w in reqs.windows(2) {
            assert!(w[1].arrive_s >= w[0].arrive_s, "trace not sorted");
        }
        // the fast tenant's 50 arrivals finish earlier than the slow one's
        let last = |t: u32| {
            reqs.iter().filter(|r| r.tenant == t).map(|r| r.arrive_s).fold(0.0, f64::max)
        };
        assert!(last(0) < last(2), "rate skew must show in arrival spans");
        // deterministic across calls
        assert_eq!(reqs, multi_tenant_poisson(&[8.0, 2.0, 1.0], 50, 100, 10, 3));
    }
}
