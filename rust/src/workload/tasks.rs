//! Task generators mirroring the benchmark families the paper evaluates:
//! single-needle (NIAH / RULER s_niah), multi-value needle (mv_niah),
//! QA-style variable sparsity (qa_1), and aggregation (fwe) where many
//! tokens matter — the low-sparsity end of Figure 4(b).

use super::{base_context, plant_needle, GeometryCfg, Workload};
use crate::util::rng::Rng;

/// Benchmark task families.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// One needle, one query aligned to it (s_niah / NIAH).
    SingleNeedle,
    /// Several needles sharing a direction, all must be retrieved (mv_niah).
    MultiNeedle,
    /// Query weakly aligned with several topics: variable sparsity (qa_1).
    Qa,
    /// Aggregation: a frequent direction spread over many tokens (fwe).
    Aggregate,
}

impl TaskKind {
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::SingleNeedle => "s_niah",
            TaskKind::MultiNeedle => "mv_niah",
            TaskKind::Qa => "qa_1",
            TaskKind::Aggregate => "fwe",
        }
    }

    pub fn all() -> [TaskKind; 4] {
        [TaskKind::SingleNeedle, TaskKind::MultiNeedle, TaskKind::Qa, TaskKind::Aggregate]
    }
}

/// A generated task instance.
pub struct Task {
    pub kind: TaskKind,
    pub workload: Workload,
}

/// Generate a task at context length `n` with `n_queries` probes.
pub fn generate(kind: TaskKind, n: usize, d: usize, n_queries: usize, seed: u64) -> Task {
    let mut rng = Rng::new(seed ^ (kind as u64).wrapping_mul(0x9e37));
    let cfg = GeometryCfg { n, d, region: (n / 16).clamp(64, 4096), ..GeometryCfg::default() };
    let (mut keys, mut vals) = base_context(&cfg, &mut rng);
    let mut queries = Vec::with_capacity(n_queries);
    let mut needles = Vec::with_capacity(n_queries);

    match kind {
        TaskKind::SingleNeedle => {
            // One needle per query at a random depth.
            for _ in 0..n_queries {
                let pos = vec![rng.below(n) as u32];
                let dir = plant_needle(&mut keys, &mut vals, d, &pos, cfg.needle_gain, &mut rng);
                queries.push(dir.iter().map(|x| x * cfg.needle_gain).collect());
                needles.push(pos);
            }
        }
        TaskKind::MultiNeedle => {
            // 4 scattered needles per query sharing one direction.
            for _ in 0..n_queries {
                let pos: Vec<u32> = (0..4).map(|_| rng.below(n) as u32).collect();
                let dir = plant_needle(&mut keys, &mut vals, d, &pos, cfg.needle_gain, &mut rng);
                queries.push(dir.iter().map(|x| x * cfg.needle_gain).collect());
                needles.push(pos);
            }
        }
        TaskKind::Qa => {
            // Query = mix of 2-3 topic directions + a weak needle SPAN
            // (a fact is a sentence, not a token — spans also cluster as
            // their own unit): heavy hitters spread across regions.
            for _ in 0..n_queries {
                let start = rng.below(n.saturating_sub(4)) as u32;
                let pos: Vec<u32> = (start..start + 4).collect();
                let dir = plant_needle(&mut keys, &mut vals, d, &pos, 1.5, &mut rng);
                let mut q: Vec<f32> = dir.iter().map(|x| x * 1.5).collect();
                for _ in 0..2 {
                    let t = rng.below(n);
                    for j in 0..d {
                        q[j] += 0.4 * keys[t * d + j];
                    }
                }
                queries.push(q);
                needles.push(pos);
            }
        }
        TaskKind::Aggregate => {
            // A "frequent word": 2% of tokens share a direction; the query
            // aligns with it. No single needle — success is capturing the
            // aggregate mass (low sparsity, Fig. 4b's fwe).
            let n_freq = (n / 50).max(8);
            let pos: Vec<u32> = (0..n_freq).map(|_| rng.below(n) as u32).collect();
            let dir = plant_needle(&mut keys, &mut vals, d, &pos, 1.2, &mut rng);
            for _ in 0..n_queries {
                let q: Vec<f32> =
                    dir.iter().map(|x| x * 1.2 + 0.1 * rng.normal_f32()).collect();
                queries.push(q);
                needles.push(pos.clone());
            }
        }
    }

    Task {
        kind,
        workload: Workload {
            name: kind.name().to_string(),
            d,
            keys,
            vals,
            queries,
            needles,
        },
    }
}

/// Task-level accuracy of an attention system, matching how the paper's
/// benchmarks score: a query counts as correct when the system's exact
/// zone covers the ground-truth needle tokens (retrieval success) — the
/// proxy for "the model can copy the needle into its answer".
pub fn needle_accuracy(exact_positions: &[Vec<u32>], needles: &[Vec<u32>]) -> f64 {
    assert_eq!(exact_positions.len(), needles.len());
    if needles.is_empty() {
        return 1.0;
    }
    let mut correct = 0;
    for (ex, nd) in exact_positions.iter().zip(needles) {
        let set: std::collections::HashSet<u32> = ex.iter().copied().collect();
        if nd.iter().all(|p| set.contains(p)) {
            correct += 1;
        }
    }
    correct as f64 / needles.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::attention_weights;
    use crate::attention::sparsity::{top_k_indices, tokens_for_mass};

    #[test]
    fn single_needle_is_retrievable() {
        let t = generate(TaskKind::SingleNeedle, 1024, 16, 3, 1);
        for (q, nd) in t.workload.queries.iter().zip(&t.workload.needles) {
            let w = attention_weights(q, &t.workload.keys, 16);
            let top = top_k_indices(&w, 8);
            assert!(top.contains(&(nd[0] as usize)), "needle in top-8");
        }
    }

    #[test]
    fn multi_needle_all_in_top_k() {
        let t = generate(TaskKind::MultiNeedle, 1024, 16, 2, 2);
        for (q, nd) in t.workload.queries.iter().zip(&t.workload.needles) {
            let w = attention_weights(q, &t.workload.keys, 16);
            let top = top_k_indices(&w, 16);
            for &p in nd {
                assert!(top.contains(&(p as usize)), "needle {p} in top-16");
            }
        }
    }

    #[test]
    fn aggregate_less_sparse_than_needle() {
        let d = 16;
        let sn = generate(TaskKind::SingleNeedle, 2048, d, 1, 3);
        let ag = generate(TaskKind::Aggregate, 2048, d, 1, 3);
        let w_sn = attention_weights(&sn.workload.queries[0], &sn.workload.keys, d);
        let w_ag = attention_weights(&ag.workload.queries[0], &ag.workload.keys, d);
        let t_sn = tokens_for_mass(&w_sn, 0.9);
        let t_ag = tokens_for_mass(&w_ag, 0.9);
        assert!(
            t_ag > t_sn,
            "aggregation needs more tokens for 90% mass: {t_ag} vs {t_sn}"
        );
    }

    #[test]
    fn needle_accuracy_scoring() {
        let exact = vec![vec![1, 2, 3], vec![4, 5]];
        let needles = vec![vec![2], vec![6]];
        assert!((needle_accuracy(&exact, &needles) - 0.5).abs() < 1e-12);
        assert_eq!(needle_accuracy(&[], &[]), 1.0);
    }

    #[test]
    fn all_kinds_generate() {
        for kind in TaskKind::all() {
            let t = generate(kind, 512, 8, 2, 9);
            assert_eq!(t.workload.n_tokens(), 512);
            assert_eq!(t.workload.queries.len(), 2);
        }
    }
}
