//! Synthetic attention-geometry workloads (DESIGN.md §1 substitution for
//! RULER / NIAH / AIME / GPQA).
//!
//! What the paper's benchmarks measure *through task accuracy* is whether
//! a sparse-attention system retrieves the tokens full attention attends
//! to. These generators produce KV geometries with the properties the
//! paper documents — coarse positional locality of keys (RoPE, §4.2),
//! scattered important tokens (Fig. 3), task-dependent sparsity ratios
//! (Fig. 4) — plus planted "needles" with known ground truth, so recall
//! and output fidelity can be measured directly.

pub mod arrivals;
pub mod online;
pub mod pressure;
pub mod tasks;

pub use arrivals::{
    closed_loop, diurnal_poisson, multi_tenant_poisson, poisson_arrivals,
    shared_prefix_poisson, stamp_shared_prefix, RequestSpec,
};
pub use online::{run_online_serving, OnlineConfig, OnlineReport};
pub use pressure::{
    run_cluster_pressure, run_memory_pressure, ClusterPressureConfig, ClusterPressureReport,
    PressureConfig, PressureReport,
};
pub use tasks::{Task, TaskKind};

use crate::util::rng::Rng;

/// One synthetic context + its evaluation queries.
pub struct Workload {
    pub name: String,
    pub d: usize,
    /// `[n, d]` keys with topic-drift positional locality.
    pub keys: Vec<f32>,
    /// `[n, d]` values.
    pub vals: Vec<f32>,
    /// Evaluation queries, one per decode probe.
    pub queries: Vec<Vec<f32>>,
    /// Ground-truth needle positions per query (empty when the task has
    /// no planted needle, e.g. aggregation).
    pub needles: Vec<Vec<u32>>,
}

impl Workload {
    pub fn n_tokens(&self) -> usize {
        self.keys.len() / self.d
    }
}

/// Parameters of the geometry generator.
#[derive(Clone, Debug)]
pub struct GeometryCfg {
    pub n: usize,
    pub d: usize,
    /// Tokens per topic region (positional locality scale; ~RoPE window).
    pub region: usize,
    /// Key = topic*signal + noise; higher signal -> stronger clustering.
    pub signal: f32,
    pub noise: f32,
    /// Query-needle alignment strength (how sharply attention peaks).
    pub needle_gain: f32,
}

impl Default for GeometryCfg {
    fn default() -> Self {
        GeometryCfg { n: 8192, d: 32, region: 512, signal: 2.0, noise: 0.5, needle_gain: 3.0 }
    }
}

/// Topic-drift base context: keys within a region share a topic direction
/// (coarse spatial locality), values are independent noise.
pub fn base_context(cfg: &GeometryCfg, rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
    let (n, d) = (cfg.n, cfg.d);
    let n_regions = n.div_ceil(cfg.region);
    // Topics drift: each topic is the previous plus a step (adjacent
    // regions are more similar than distant ones, like RoPE phase drift).
    let mut topics = Vec::with_capacity(n_regions);
    let mut cur = rng.normal_vec(d);
    for _ in 0..n_regions {
        let step = rng.normal_vec(d);
        for j in 0..d {
            cur[j] = 0.8 * cur[j] + 0.6 * step[j];
        }
        topics.push(cur.clone());
    }
    let mut keys = Vec::with_capacity(n * d);
    for i in 0..n {
        let t = &topics[i / cfg.region];
        for j in 0..d {
            keys.push(cfg.signal * t[j] + cfg.noise * rng.normal_f32());
        }
    }
    let vals = rng.normal_vec(n * d);
    (keys, vals)
}

/// Plant `needles` tokens aligned with a fresh direction; returns
/// (direction, positions). The needle key REPLACES the base key at each
/// position, and its value is set to the payload so retrieval shows up in
/// the attention output.
pub fn plant_needle(
    keys: &mut [f32],
    vals: &mut [f32],
    d: usize,
    positions: &[u32],
    gain: f32,
    rng: &mut Rng,
) -> Vec<f32> {
    let dir = rng.normal_vec(d);
    let payload = rng.normal_vec(d);
    for &p in positions {
        let p = p as usize;
        for j in 0..d {
            keys[p * d + j] = gain * dir[j] + 0.1 * rng.normal_f32();
            vals[p * d + j] = payload[j];
        }
    }
    dir
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::attention_weights;
    use crate::attention::sparsity::top_k_indices;

    #[test]
    fn base_context_has_positional_locality() {
        let cfg = GeometryCfg { n: 1024, d: 16, region: 128, ..GeometryCfg::default() };
        let mut rng = Rng::new(1);
        let (keys, _) = base_context(&cfg, &mut rng);
        let d = cfg.d;
        let cos = |a: usize, b: usize| {
            let (ka, kb) = (&keys[a * d..(a + 1) * d], &keys[b * d..(b + 1) * d]);
            crate::util::stats::cosine(ka, kb)
        };
        // near pairs (same region) more similar than far pairs on average
        let mut near = 0.0;
        let mut far = 0.0;
        for i in 0..50 {
            near += cos(i * 2, i * 2 + 1);
            far += cos(i * 2, 512 + i * 2);
        }
        assert!(near > far + 5.0, "near={near} far={far}");
    }

    #[test]
    fn planted_needle_dominates_attention() {
        let cfg = GeometryCfg { n: 2048, d: 16, region: 256, ..GeometryCfg::default() };
        let mut rng = Rng::new(2);
        let (mut keys, mut vals) = base_context(&cfg, &mut rng);
        let pos = vec![777u32];
        let dir = plant_needle(&mut keys, &mut vals, cfg.d, &pos, cfg.needle_gain, &mut rng);
        let q: Vec<f32> = dir.iter().map(|x| x * cfg.needle_gain).collect();
        let w = attention_weights(&q, &keys, cfg.d);
        let top = top_k_indices(&w, 1);
        assert_eq!(top[0], 777, "needle must be the attention argmax");
    }
}
