//! Deterministic multi-tenant memory-pressure harness.
//!
//! Drives the real [`Scheduler`] admission gate against the real
//! [`BlockArena`] capacity/quota accounting with a *modelled* KV
//! footprint (block checkouts shaped like `WaveIndex::build_in`:
//! clusters that never share blocks, decode-time growth every
//! `tokens_per_block` generated tokens) — no model artifacts needed, so
//! the oversubscribed-serving invariants run in tier-1 CI. Used by
//! `rust/tests/admission.rs` (property harness), `benches/fig13_*`
//! (capped-replay report) and anything else that wants a seeded
//! overcommit scenario.
//!
//! The driver samples the arena's counters after every scheduler step
//! and *counts* violations instead of panicking, so callers (property
//! tests, benches) can assert the report:
//!
//! - `capacity_violations == 0` — live/resident blocks never exceeded
//!   the cap at any step;
//! - `quota_violations == 0` — no tenant ever exceeded its quota;
//! - `completed + rejected == n` with `prefill_failures == 0` — every
//!   deferred prefill was eventually admitted once reclamation freed
//!   space (no lost requests, no deadlock).
//!
//! With [`PressureConfig::spill`] the same driver exercises the tiered
//! arena instead: the cap bounds the hot tier only, refused checkouts
//! demote the oldest live blocks and retry, decode steps promote
//! spilled blocks back, and the report additionally asserts that total
//! live blocks exceeded the hot cap while hot-resident blocks never did
//! (`tests/spill.rs`).

use crate::coordinator::{Action, AdmissionConfig, Batcher, Request, Scheduler};
use crate::kvcache::{BlockArena, KvStore, TenantId};
use crate::workload::RequestSpec;
use std::collections::{BTreeSet, HashMap};
use std::sync::Arc;

/// Geometry + budget of a pressure scenario.
#[derive(Clone, Debug)]
pub struct PressureConfig {
    pub layers: usize,
    pub kv_heads: usize,
    pub d: usize,
    pub block_bytes: usize,
    /// Hard arena cap in blocks.
    pub capacity_blocks: usize,
    /// Optional per-tenant quota in blocks (applied to every tenant in
    /// the trace).
    pub tenant_quota_blocks: Option<usize>,
    /// Admission headroom for decode-time growth.
    pub headroom_frac: f64,
    /// Decode-pool admission cap (continuous-batching slot count).
    pub max_batch: usize,
    /// Enable the cold spill tier: `capacity_blocks` bounds the HOT
    /// tier only, admission never defers on occupancy (tiered gate),
    /// and a refused checkout demotes the oldest live blocks to the
    /// cold tier and retries — total live bytes may exceed the hot cap
    /// while hot-resident bytes never do.
    pub spill: bool,
}

impl Default for PressureConfig {
    fn default() -> Self {
        PressureConfig {
            layers: 2,
            kv_heads: 2,
            d: 16,
            block_bytes: 512, // tpb = 4 at d=16 f32
            capacity_blocks: 512,
            tenant_quota_blocks: None,
            headroom_frac: 0.25,
            max_batch: 4,
            spill: false,
        }
    }
}

/// What a pressure run observed (callers assert on this).
#[derive(Clone, Debug, Default)]
pub struct PressureReport {
    /// Requests that finished with their full token budget.
    pub completed: usize,
    /// Requests the gate rejected outright (can never fit).
    pub rejected: usize,
    /// Gate-blocked head-of-queue observations (see
    /// `Scheduler::n_deferrals`).
    pub deferrals: u64,
    /// Prefill-time block checkouts the arena refused (admission should
    /// keep this at zero).
    pub prefill_failures: usize,
    /// Decode-time block checkouts the arena refused (headroom should
    /// keep this at zero).
    pub append_failures: usize,
    /// Steps where live blocks or resident bytes exceeded the cap
    /// (must be zero — the harness's core invariant).
    pub capacity_violations: usize,
    /// Steps where some tenant exceeded its quota (must be zero).
    pub quota_violations: usize,
    pub peak_live_blocks: usize,
    pub peak_resident_bytes: usize,
    /// Peak live blocks observed per tenant.
    pub per_tenant_peak: HashMap<TenantId, usize>,
    /// Scheduler iterations the run took.
    pub steps: usize,
    /// False only if the guard tripped before the trace drained
    /// (deadlock — must be true).
    pub drained: bool,
    /// Blocks demoted to the cold tier (spill runs).
    pub demotions: usize,
    /// Blocks promoted back to the hot tier (spill runs).
    pub promotions: usize,
    /// Peak of hot + cold live blocks (exceeds `capacity_blocks` when
    /// the workload genuinely overcommits the hot tier).
    pub peak_total_live_blocks: usize,
    /// Peak cold-tier residency in blocks.
    pub peak_cold_blocks: usize,
    /// Cold blocks left after the trace drained (must be 0: finished
    /// sessions drop their cold blocks).
    pub final_cold_blocks: usize,
}

/// Blocks one head checks out for `tokens` of context, allocated as
/// clusters of `2 * tpb - 1` tokens so partial tail blocks (clusters
/// never share blocks) are part of the model.
fn checkout_prompt(store: &mut KvStore, layers: usize, heads: usize, tokens: usize) -> bool {
    let d = store.arena().d();
    let tpb = store.arena().tokens_per_block();
    let cluster = (2 * tpb).saturating_sub(1).max(1);
    for l in 0..layers {
        for h in 0..heads {
            let mut off = 0usize;
            while off < tokens {
                let take = (tokens - off).min(cluster);
                let keys = vec![0.0f32; take * d];
                let vals = vec![0.0f32; take * d];
                let pos: Vec<u32> = (off as u32..(off + take) as u32).collect();
                if store.head_mut(l, h).try_alloc_cluster(&keys, &vals, &pos).is_err() {
                    return false;
                }
                off += take;
            }
        }
    }
    true
}

/// Demote hot blocks from live stores (session id order, oldest blocks
/// first) until `need` were freed or nothing remains; the driver-level
/// "demote, then retry" path of a spill-enabled run.
fn demote_from_stores(stores: &mut HashMap<u64, KvStore>, need: usize) -> usize {
    let mut ids: Vec<u64> = stores.keys().copied().collect();
    ids.sort_unstable();
    let mut freed = 0;
    for id in ids {
        if freed >= need {
            break;
        }
        freed += stores.get_mut(&id).unwrap().demote_blocks(need - freed);
    }
    freed
}

/// Run one seeded pressure scenario to completion (or guard) and report.
pub fn run_memory_pressure(cfg: &PressureConfig, trace: &[RequestSpec]) -> PressureReport {
    let arena = BlockArena::shared(cfg.d, cfg.block_bytes);
    arena.set_capacity_blocks(Some(cfg.capacity_blocks));
    let tenants: BTreeSet<TenantId> = trace.iter().map(|r| r.tenant).collect();
    if let Some(q) = cfg.tenant_quota_blocks {
        for &t in &tenants {
            arena.set_tenant_quota(t, Some(q));
        }
    }
    let tpb = arena.tokens_per_block();
    let adm = AdmissionConfig {
        heads: cfg.layers * cfg.kv_heads,
        tokens_per_block: tpb,
        headroom_frac: cfg.headroom_frac,
        est_fudge: 1.5,
        tiered: cfg.spill,
    };
    let mut sched = Scheduler::with_admission(
        Batcher::new(&[1, 2, 4, 8], cfg.max_batch),
        Arc::clone(&arena),
        adm,
    );
    // The whole trace queues up-front: pressure comes from aggregate
    // footprint, not wall-clock pacing (admit_s keeps arrival order).
    for (i, r) in trace.iter().enumerate() {
        sched.submit(
            Request::new(i as u64, vec![1; r.input_tokens], r.output_tokens.max(1))
                .with_tenant(r.tenant),
            r.arrive_s,
        );
    }

    let cap_bytes = cfg.capacity_blocks * arena.block_bytes();
    let mut rep = PressureReport::default();
    let mut stores: HashMap<u64, KvStore> = HashMap::new();
    let mut decoded: HashMap<u64, usize> = HashMap::new();
    let mut guard = 0usize;
    while !sched.all_done() {
        guard += 1;
        if guard > 200_000 {
            rep.drained = false;
            rep.deferrals = sched.n_deferrals();
            return rep;
        }
        rep.steps += 1;
        let now = rep.steps as f64 * 1e-3;
        match sched.next_action() {
            Action::Prefill(id) => {
                let (tenant, prompt_len) = {
                    let s = sched.session(id).unwrap();
                    (s.req.tenant, s.req.prompt.len())
                };
                // generous footprint estimate: dense packing plus one
                // tail block per (2·tpb − 1)-token cluster
                let est = cfg.layers * cfg.kv_heads * prompt_len.div_ceil(tpb) * 2;
                let mut served = false;
                for _attempt in 0..64 {
                    let mut st = KvStore::new_in_for(
                        Arc::clone(&arena),
                        tenant,
                        cfg.layers,
                        cfg.kv_heads,
                    );
                    if checkout_prompt(&mut st, cfg.layers, cfg.kv_heads, prompt_len) {
                        stores.insert(id, st);
                        decoded.insert(id, 0);
                        served = true;
                        break;
                    }
                    // the partial store drops here (rollback)
                    drop(st);
                    if !cfg.spill {
                        break;
                    }
                    // full hot tier means demote-then-retry, not defer:
                    // spill the oldest live blocks and rebuild
                    let freed = demote_from_stores(&mut stores, est);
                    rep.demotions += freed;
                    if freed == 0 {
                        break;
                    }
                }
                if !served {
                    // single-tier: admission let an unservable prefill
                    // through; spill: nothing left to demote
                    rep.prefill_failures += 1;
                }
                sched.prefill_done(id, 0, now);
            }
            Action::DecodeBatch(ids, _bucket) => {
                for id in ids {
                    sched.token_decoded(id, 1, now);
                    let n = decoded.entry(id).or_insert(0);
                    *n += 1;
                    // one fresh block per head every tpb generated tokens
                    if *n % tpb != 0 || !stores.contains_key(&id) {
                        continue;
                    }
                    if cfg.spill {
                        // model the decode read path: each growth step
                        // promotes a couple of this session's spilled
                        // blocks back into the hot tier, demoting other
                        // sessions' cold blocks first when the hot tier
                        // is full (demote-then-retry)
                        let has_cold =
                            stores.get(&id).map(|s| s.n_cold_blocks() > 0).unwrap_or(false);
                        if has_cold {
                            let got = stores.get_mut(&id).unwrap().promote_blocks(2);
                            rep.promotions += got;
                            if got < 2 {
                                let freed = demote_from_stores(&mut stores, 4);
                                rep.demotions += freed;
                                if freed > 0 {
                                    let more =
                                        stores.get_mut(&id).unwrap().promote_blocks(2 - got);
                                    rep.promotions += more;
                                }
                            }
                        }
                    }
                    let d = cfg.d;
                    let keys = vec![0.0f32; tpb * d];
                    let vals = vec![0.0f32; tpb * d];
                    let pos: Vec<u32> = (0..tpb as u32).collect();
                    let mut pending: Vec<(usize, usize)> = Vec::new();
                    for l in 0..cfg.layers {
                        for h in 0..cfg.kv_heads {
                            pending.push((l, h));
                        }
                    }
                    let mut attempts = 0;
                    loop {
                        let mut still = Vec::new();
                        {
                            let st = stores.get_mut(&id).unwrap();
                            for &(l, h) in &pending {
                                if st
                                    .head_mut(l, h)
                                    .try_alloc_cluster(&keys, &vals, &pos)
                                    .is_err()
                                {
                                    still.push((l, h));
                                }
                            }
                        }
                        if still.is_empty() {
                            break;
                        }
                        attempts += 1;
                        if !cfg.spill || attempts > 8 {
                            rep.append_failures += still.len();
                            break;
                        }
                        let freed = demote_from_stores(&mut stores, 2 * still.len());
                        rep.demotions += freed;
                        if freed == 0 {
                            rep.append_failures += still.len();
                            break;
                        }
                        pending = still;
                    }
                }
            }
            Action::Defer | Action::Idle => {}
        }
        // sample the invariants after every step
        let live = arena.live_blocks();
        let resident = arena.resident_bytes();
        let cold = arena.cold_blocks();
        rep.peak_live_blocks = rep.peak_live_blocks.max(live);
        rep.peak_resident_bytes = rep.peak_resident_bytes.max(resident);
        rep.peak_cold_blocks = rep.peak_cold_blocks.max(cold);
        rep.peak_total_live_blocks = rep.peak_total_live_blocks.max(live + cold);
        if live > cfg.capacity_blocks || resident > cap_bytes {
            rep.capacity_violations += 1;
        }
        for &t in &tenants {
            let tl = arena.tenant_live_blocks(t);
            let e = rep.per_tenant_peak.entry(t).or_insert(0);
            if tl > *e {
                *e = tl;
            }
            if let Some(q) = cfg.tenant_quota_blocks {
                if tl > q {
                    rep.quota_violations += 1;
                }
            }
        }
        // reclamation: finished sessions drop their stores, returning
        // blocks to the arena (this is what re-admits deferred prefills)
        for fid in sched.take_finished() {
            stores.remove(&fid);
            decoded.remove(&fid);
        }
    }
    rep.drained = true;
    rep.final_cold_blocks = arena.cold_blocks();
    rep.deferrals = sched.n_deferrals();
    rep.rejected = sched.n_rejections() as usize;
    rep.completed = sched
        .sessions()
        .filter(|s| !s.rejected && s.generated.len() >= s.req.max_new)
        .count();
    rep
}
