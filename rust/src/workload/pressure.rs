//! Deterministic multi-tenant memory-pressure harness.
//!
//! Drives the real [`Scheduler`] admission gate against the real
//! [`BlockArena`] capacity/quota accounting with a *modelled* KV
//! footprint (block checkouts shaped like `WaveIndex::build_in`:
//! clusters that never share blocks, decode-time growth every
//! `tokens_per_block` generated tokens) — no model artifacts needed, so
//! the oversubscribed-serving invariants run in tier-1 CI. Used by
//! `rust/tests/admission.rs` (property harness), `benches/fig13_*`
//! (capped-replay report) and anything else that wants a seeded
//! overcommit scenario.
//!
//! The driver samples the arena's counters after every scheduler step
//! and *counts* violations instead of panicking, so callers (property
//! tests, benches) can assert the report:
//!
//! - `capacity_violations == 0` — live/resident blocks never exceeded
//!   the cap at any step;
//! - `quota_violations == 0` — no tenant ever exceeded its quota;
//! - `completed + rejected == n` with `prefill_failures == 0` — every
//!   deferred prefill was eventually admitted once reclamation freed
//!   space (no lost requests, no deadlock).
//!
//! With [`PressureConfig::spill`] the same driver exercises the tiered
//! arena instead: the cap bounds the hot tier only, refused checkouts
//! demote the oldest live blocks and retry, decode steps promote
//! spilled blocks back, and the report additionally asserts that total
//! live blocks exceeded the hot cap while hot-resident blocks never did
//! (`tests/spill.rs`).

//! With [`PressureConfig::shared_prefix_tokens`] the driver additionally
//! models cross-session prefix dedup: the first request of each
//! `prefix_hash` allocates the prefix blocks, seals them into shared
//! refcounted views and pins them (the modelled prefix registry); later
//! requests of the same hash attach the same blocks instead of
//! allocating, and their admission estimate is discounted by the shared
//! tokens (`Request::prefix_tokens`). The report's shared-peak fields
//! quantify the dedup; resident blocks stay ≤ cap even when the
//! nominal (unshared) footprint would exceed it.

use crate::config::SpillCodec;
use crate::coordinator::{Action, AdmissionConfig, Batcher, Phase, Request, Router, Scheduler};
use crate::kvcache::{
    AllocError, BlockArena, BlockRef, CodecTag, HeadStore, KvReadTier, KvStore, TenantId,
};
use crate::util::threadpool::ThreadPool;
use std::collections::{BTreeSet, HashMap, HashSet};
use std::sync::Arc;

use crate::workload::RequestSpec;

/// Geometry + budget of a pressure scenario.
#[derive(Clone, Debug)]
pub struct PressureConfig {
    pub layers: usize,
    pub kv_heads: usize,
    pub d: usize,
    pub block_bytes: usize,
    /// Hard arena cap in blocks.
    pub capacity_blocks: usize,
    /// Optional per-tenant quota in blocks (applied to every tenant in
    /// the trace).
    pub tenant_quota_blocks: Option<usize>,
    /// Admission headroom for decode-time growth.
    pub headroom_frac: f64,
    /// Decode-pool admission cap (continuous-batching slot count).
    pub max_batch: usize,
    /// Enable the cold spill tier: `capacity_blocks` bounds the HOT
    /// tier only, admission never defers on occupancy (tiered gate),
    /// and a refused checkout demotes the oldest live blocks to the
    /// cold tier and retries — total live bytes may exceed the hot cap
    /// while hot-resident bytes never do.
    pub spill: bool,
    /// Spill codec applied to demoted pages (spill runs). The harness
    /// drives zero-filled KV, so lossy eligibility is decided at the
    /// trace level: every demoted page is lossy-eligible when the codec
    /// is lossy (DESIGN.md §2 "Spill codecs"); the report's
    /// logical/physical cold-byte peaks quantify the achieved ratio.
    pub spill_codec: SpillCodec,
    /// Shared-prefix tokens per request (0 = off). Requests carrying a
    /// `prefix_hash` share this many leading prompt tokens: the first
    /// such request allocates + seals + pins them; later ones attach
    /// the same blocks (refcounted, charged once) with a discounted
    /// admission estimate. The donor of each hash should be serviceable
    /// first (single-tenant traces, or one hash per tenant) — a
    /// non-donor arriving before its donor simply becomes the donor.
    pub shared_prefix_tokens: usize,
}

impl Default for PressureConfig {
    fn default() -> Self {
        PressureConfig {
            layers: 2,
            kv_heads: 2,
            d: 16,
            block_bytes: 512, // tpb = 4 at d=16 f32
            capacity_blocks: 512,
            tenant_quota_blocks: None,
            headroom_frac: 0.25,
            max_batch: 4,
            spill: false,
            spill_codec: SpillCodec::Exact,
            shared_prefix_tokens: 0,
        }
    }
}

/// What a pressure run observed (callers assert on this).
#[derive(Clone, Debug, Default)]
pub struct PressureReport {
    /// Requests that finished with their full token budget.
    pub completed: usize,
    /// Requests the gate rejected outright (can never fit).
    pub rejected: usize,
    /// Gate-blocked head-of-queue observations (see
    /// `Scheduler::n_deferrals`).
    pub deferrals: u64,
    /// Prefill-time block checkouts the arena refused (admission should
    /// keep this at zero).
    pub prefill_failures: usize,
    /// Decode-time block checkouts the arena refused (headroom should
    /// keep this at zero).
    pub append_failures: usize,
    /// Steps where live blocks or resident bytes exceeded the cap
    /// (must be zero — the harness's core invariant).
    pub capacity_violations: usize,
    /// Steps where some tenant exceeded its quota (must be zero).
    pub quota_violations: usize,
    pub peak_live_blocks: usize,
    pub peak_resident_bytes: usize,
    /// Peak live blocks observed per tenant.
    pub per_tenant_peak: HashMap<TenantId, usize>,
    /// Scheduler iterations the run took.
    pub steps: usize,
    /// False only if the guard tripped before the trace drained
    /// (deadlock — must be true).
    pub drained: bool,
    /// Blocks demoted to the cold tier (spill runs).
    pub demotions: usize,
    /// Blocks promoted back to the hot tier (spill runs).
    pub promotions: usize,
    /// Peak of hot + cold live blocks (exceeds `capacity_blocks` when
    /// the workload genuinely overcommits the hot tier).
    pub peak_total_live_blocks: usize,
    /// Peak cold-tier residency in blocks.
    pub peak_cold_blocks: usize,
    /// Peak uncompressed (logical) bytes of resident cold pages.
    pub peak_cold_logical_bytes: usize,
    /// Peak encoded (physical) bytes of resident cold pages — with a
    /// lossy codec this is what actually crosses the spill channel
    /// (`peak_cold_physical_bytes / peak_cold_logical_bytes` ≈ the
    /// achieved compression ratio).
    pub peak_cold_physical_bytes: usize,
    /// Peak resident cold pages stored with a lossy codec.
    pub peak_compressed_blocks: usize,
    /// Cold blocks left after the trace drained (must be 0: finished
    /// sessions drop their cold blocks).
    pub final_cold_blocks: usize,
    /// Peak shared (refcounted) blocks live at once (prefix runs).
    pub peak_shared_blocks: usize,
    /// Peak session references across shared blocks at once (the dedup
    /// numerator: N sessions × prefix blocks).
    pub peak_shared_refs: usize,
    /// Requests that became a prefix donor (allocated + sealed a run).
    pub prefix_donors: usize,
    /// Requests that attached an already-sealed prefix run.
    pub prefix_attaches: usize,
    /// Live blocks left after the trace drained and the modelled
    /// registry unpinned its runs (must be 0: refcounts drained).
    pub final_live_blocks: usize,
    /// Cold-tier page reads the modelled pipelined gather performed
    /// (spill runs; `HeadStore::copy_block_kv_tiered`).
    pub cold_reads: u64,
    /// Of those, reads served from the staging area because the page
    /// was prefetched on the pool's I/O lane before the gather needed
    /// it — the intra-step overlap win.
    pub cold_reads_staged: u64,
    /// Decode steps that performed at least one cold read.
    pub cold_read_steps: usize,
    /// Decode steps where at least one cold read was served staged
    /// (equals `cold_read_steps` when every reading step overlapped).
    pub staged_read_steps: usize,
}

impl PressureReport {
    /// Measured intra-step spill overlap: the percentage of cold-tier
    /// gather reads served from the staging area instead of stalling
    /// on the page file — the share of spill traffic hidden under
    /// compute (feeds `SystemProfile::with_spill_overlap`, exported as
    /// the `spill_overlap_pct` gauge by the live engine).
    pub fn spill_overlap_pct(&self) -> f64 {
        if self.cold_reads == 0 {
            0.0
        } else {
            100.0 * self.cold_reads_staged as f64 / self.cold_reads as f64
        }
    }
}

/// Check `tokens` of context starting at position `start` into one
/// head, allocated as clusters of `2 * tpb - 1` tokens so partial tail
/// blocks (clusters never share blocks) are part of the model. Returns
/// the checked-out refs in order.
fn checkout_span(
    head: &mut HeadStore,
    start: usize,
    tokens: usize,
) -> Result<Vec<BlockRef>, AllocError> {
    let d = head.d();
    let tpb = head.tokens_per_block();
    let cluster = (2 * tpb).saturating_sub(1).max(1);
    let mut refs = Vec::new();
    let mut off = 0usize;
    while off < tokens {
        let take = (tokens - off).min(cluster);
        let keys = vec![0.0f32; take * d];
        let vals = vec![0.0f32; take * d];
        let pos: Vec<u32> = ((start + off) as u32..(start + off + take) as u32).collect();
        refs.extend(head.try_alloc_cluster(&keys, &vals, &pos)?);
        off += take;
    }
    Ok(refs)
}

/// Blocks every head checks out for `tokens` of context from `start`.
fn checkout_prompt(
    store: &mut KvStore,
    layers: usize,
    heads: usize,
    start: usize,
    tokens: usize,
) -> bool {
    for l in 0..layers {
        for h in 0..heads {
            if checkout_span(store.head_mut(l, h), start, tokens).is_err() {
                return false;
            }
        }
    }
    true
}

/// The modelled prefix registry of a pressure run: sealed block runs
/// per prefix hash, kept resident by arena pins.
#[derive(Default)]
struct ModelRegistry {
    /// prefix hash → per-(layer, head) slot list of (block id, len).
    sealed: HashMap<u64, Vec<Vec<(u64, u16)>>>,
    pinned: Vec<u64>,
}

impl ModelRegistry {
    /// Serve a session's shared prefix: attach the sealed run when one
    /// exists, otherwise allocate it here and become the donor (seal +
    /// pin). Returns `Some(donated)` on success, `None` on a refused
    /// checkout (the caller rolls the whole store back).
    fn checkout_shared(
        &mut self,
        store: &mut KvStore,
        arena: &BlockArena,
        layers: usize,
        heads: usize,
        hash: u64,
        tokens: usize,
    ) -> Option<bool> {
        if let Some(run) = self.sealed.get(&hash) {
            for l in 0..layers {
                for h in 0..heads {
                    for &(id, len) in &run[l * heads + h] {
                        store.head_mut(l, h).attach_shared(id, len)?;
                    }
                }
            }
            return Some(false);
        }
        // donor: allocate the prefix privately, then seal + pin it
        let mut refs: Vec<Vec<BlockRef>> = Vec::with_capacity(layers * heads);
        for l in 0..layers {
            for h in 0..heads {
                match checkout_span(store.head_mut(l, h), 0, tokens) {
                    Ok(r) => refs.push(r),
                    Err(_) => return None,
                }
            }
        }
        let mut run = Vec::with_capacity(layers * heads);
        for l in 0..layers {
            for h in 0..heads {
                let head = store.head_mut(l, h);
                let slot_refs = &refs[l * heads + h];
                let mut v = Vec::with_capacity(slot_refs.len());
                for r in slot_refs {
                    let ok = head.seal_block(*r);
                    debug_assert!(ok);
                    let pinned = arena.pin_shared(r.block);
                    debug_assert!(pinned);
                    self.pinned.push(r.block);
                    v.push((r.block, r.len));
                }
                run.push(v);
            }
        }
        self.sealed.insert(hash, run);
        Some(true)
    }

    fn unpin_all(&mut self, arena: &BlockArena) {
        for id in self.pinned.drain(..) {
            arena.unpin_shared(id);
        }
        self.sealed.clear();
    }
}

/// Demote hot blocks from live stores (session id order, oldest blocks
/// first) until `need` were freed or nothing remains; the driver-level
/// "demote, then retry" path of a spill-enabled run.
fn demote_from_stores(
    stores: &mut HashMap<u64, KvStore>,
    need: usize,
    lossy_ok: bool,
) -> usize {
    let mut ids: Vec<u64> = stores.keys().copied().collect();
    ids.sort_unstable();
    let mut freed = 0;
    for id in ids {
        if freed >= need {
            break;
        }
        freed += stores.get_mut(&id).unwrap().demote_blocks_with(need - freed, lossy_ok);
    }
    freed
}

/// Run one seeded pressure scenario to completion (or guard) and report.
pub fn run_memory_pressure(cfg: &PressureConfig, trace: &[RequestSpec]) -> PressureReport {
    let arena = BlockArena::shared(cfg.d, cfg.block_bytes);
    arena.set_capacity_blocks(Some(cfg.capacity_blocks));
    arena.spill().set_codec(match cfg.spill_codec {
        SpillCodec::Exact => CodecTag::Exact,
        SpillCodec::Int8 => CodecTag::Int8Angle,
        SpillCodec::Int4 => CodecTag::Int4Angle,
        SpillCodec::LowRankK => CodecTag::LowRankK,
    });
    // zero-filled KV: the accuracy bound degenerates to the codec choice
    let lossy_ok = cfg.spill && cfg.spill_codec.is_lossy();
    let tenants: BTreeSet<TenantId> = trace.iter().map(|r| r.tenant).collect();
    if let Some(q) = cfg.tenant_quota_blocks {
        for &t in &tenants {
            arena.set_tenant_quota(t, Some(q));
        }
    }
    let tpb = arena.tokens_per_block();
    let adm = AdmissionConfig {
        heads: cfg.layers * cfg.kv_heads,
        tokens_per_block: tpb,
        headroom_frac: cfg.headroom_frac,
        est_fudge: 1.5,
        tiered: cfg.spill,
    };
    let mut sched = Scheduler::with_admission(
        Batcher::new(&[1, 2, 4, 8], cfg.max_batch),
        Arc::clone(&arena),
        adm,
    );
    // The whole trace queues up-front: pressure comes from aggregate
    // footprint, not wall-clock pacing (admit_s keeps arrival order).
    // With prefix sharing, every request after the first of its hash
    // carries the admission discount (its shared tokens are already —
    // or will be, by its donor — resident and charged elsewhere).
    let mut req_hash: HashMap<u64, u64> = HashMap::new();
    let mut donors_seen: HashSet<u64> = HashSet::new();
    for (i, r) in trace.iter().enumerate() {
        let mut req = Request::new(i as u64, vec![1; r.input_tokens], r.output_tokens.max(1))
            .with_tenant(r.tenant);
        if cfg.shared_prefix_tokens > 0 {
            if let Some(h) = r.prefix_hash {
                req_hash.insert(i as u64, h);
                if !donors_seen.insert(h) {
                    req = req
                        .with_prefix_tokens(cfg.shared_prefix_tokens.min(r.input_tokens));
                }
            }
        }
        sched.submit(req, r.arrive_s);
    }

    let cap_bytes = cfg.capacity_blocks * arena.block_bytes();
    let mut rep = PressureReport::default();
    let mut stores: HashMap<u64, KvStore> = HashMap::new();
    let mut decoded: HashMap<u64, usize> = HashMap::new();
    let mut registry = ModelRegistry::default();
    // Pipelined-read model (spill runs): a small pool whose I/O lane
    // stages the pages each decode step is about to gather, exactly as
    // `BatchAssembler`'s pipelined executor does in the live engine.
    let pool = if cfg.spill { Some(ThreadPool::with_io_threads(1, 1)) } else { None };
    let mut guard = 0usize;
    while !sched.all_done() {
        guard += 1;
        if guard > 200_000 {
            rep.drained = false;
            rep.deferrals = sched.n_deferrals();
            return rep;
        }
        rep.steps += 1;
        let now = rep.steps as f64 * 1e-3;
        match sched.next_action() {
            Action::Prefill(id) => {
                let (tenant, prompt_len) = {
                    let s = sched.session(id).unwrap();
                    (s.req.tenant, s.req.prompt.len())
                };
                let hash = req_hash.get(&id).copied();
                let share_tok = match hash {
                    Some(_) => cfg.shared_prefix_tokens.min(prompt_len),
                    None => 0,
                };
                // generous footprint estimate: dense packing plus one
                // tail block per (2·tpb − 1)-token cluster
                let est = cfg.layers * cfg.kv_heads * prompt_len.div_ceil(tpb) * 2;
                let mut served = false;
                for _attempt in 0..64 {
                    let mut st = KvStore::new_in_for(
                        Arc::clone(&arena),
                        tenant,
                        cfg.layers,
                        cfg.kv_heads,
                    );
                    // shared prefix first (attach or donate), then the
                    // private tail
                    let shared_ok = match (share_tok, hash) {
                        (0, _) | (_, None) => Some(false),
                        (tok, Some(h)) => registry.checkout_shared(
                            &mut st,
                            &arena,
                            cfg.layers,
                            cfg.kv_heads,
                            h,
                            tok,
                        ),
                    };
                    if let Some(donated) = shared_ok {
                        if checkout_prompt(
                            &mut st,
                            cfg.layers,
                            cfg.kv_heads,
                            share_tok,
                            prompt_len - share_tok,
                        ) {
                            if share_tok > 0 {
                                if donated {
                                    rep.prefix_donors += 1;
                                } else {
                                    rep.prefix_attaches += 1;
                                }
                            }
                            stores.insert(id, st);
                            decoded.insert(id, 0);
                            served = true;
                            break;
                        }
                    }
                    // the partial store drops here (rollback; shared
                    // attaches release their refcounts)
                    drop(st);
                    if !cfg.spill {
                        break;
                    }
                    // full hot tier means demote-then-retry, not defer:
                    // spill the oldest live blocks and rebuild
                    let freed = demote_from_stores(&mut stores, est, lossy_ok);
                    rep.demotions += freed;
                    if freed == 0 {
                        break;
                    }
                }
                if !served {
                    // single-tier: admission let an unservable prefill
                    // through; spill: nothing left to demote
                    rep.prefill_failures += 1;
                }
                sched.prefill_done(id, 0, now);
            }
            Action::DecodeBatch(ids, _bucket) => {
                // Pipelined-read model: open this step's staging epoch,
                // then issue every growing session's upcoming cold-page
                // reads on the I/O lane the moment "selection" is known
                // (here: the deterministic first cold refs). The gather
                // below reads the same refs through the tiered path;
                // reads served from the staging area are the measured
                // intra-step overlap.
                let mut step_reads: HashMap<u64, Vec<(usize, BlockRef)>> = HashMap::new();
                if let Some(pool) = pool.as_ref() {
                    arena.begin_staging_epoch();
                    for &id in &ids {
                        let grows = (decoded.get(&id).copied().unwrap_or(0) + 1) % tpb == 0;
                        if !grows {
                            continue;
                        }
                        let Some(st) = stores.get(&id) else { continue };
                        // each growing session gathers up to 6 cold
                        // pages this step; the first 4 are issued async
                        // (the prefetch depth), the tail models
                        // selection past the staging window
                        let reads = st.cold_refs(6);
                        if reads.is_empty() {
                            continue;
                        }
                        let stage_ids: Vec<u64> =
                            reads.iter().take(4).map(|(_, r)| r.block).collect();
                        let a = Arc::clone(&arena);
                        pool.submit_io(move || {
                            for b in stage_ids {
                                a.prefetch(b);
                            }
                        });
                        step_reads.insert(id, reads);
                    }
                    // the step's modelled compute runs after I/O lands
                    // — in the live engine this is the overlap window
                    pool.wait_idle();
                }
                for id in ids {
                    sched.token_decoded(id, 1, now);
                    let n = decoded.entry(id).or_insert(0);
                    *n += 1;
                    // one fresh block per head every tpb generated tokens
                    if *n % tpb != 0 || !stores.contains_key(&id) {
                        continue;
                    }
                    if cfg.spill {
                        // the modelled gather: read this step's selected
                        // cold pages through the tiered path (residency
                        // unchanged); staged hits are overlapped I/O,
                        // file hits are cold stalls
                        if let Some(reads) = step_reads.remove(&id) {
                            let st = stores.get(&id).unwrap();
                            let mut kbuf = Vec::new();
                            let mut vbuf = Vec::new();
                            let mut total_here = 0u64;
                            let mut staged_here = 0u64;
                            for (hi, r) in reads {
                                kbuf.clear();
                                vbuf.clear();
                                match st
                                    .head_flat(hi)
                                    .copy_block_kv_tiered(r, &mut kbuf, &mut vbuf)
                                {
                                    KvReadTier::ColdStaged => {
                                        total_here += 1;
                                        staged_here += 1;
                                    }
                                    KvReadTier::ColdFile => total_here += 1,
                                    KvReadTier::Hot => {}
                                }
                            }
                            rep.cold_reads += total_here;
                            rep.cold_reads_staged += staged_here;
                            if total_here > 0 {
                                rep.cold_read_steps += 1;
                            }
                            if staged_here > 0 {
                                rep.staged_read_steps += 1;
                            }
                        }
                        // model the decode read path: each growth step
                        // promotes a couple of this session's spilled
                        // blocks back into the hot tier, demoting other
                        // sessions' cold blocks first when the hot tier
                        // is full (demote-then-retry)
                        let has_cold =
                            stores.get(&id).map(|s| s.n_cold_blocks() > 0).unwrap_or(false);
                        if has_cold {
                            let got = stores.get_mut(&id).unwrap().promote_blocks(2);
                            rep.promotions += got;
                            if got < 2 {
                                let freed = demote_from_stores(&mut stores, 4, lossy_ok);
                                rep.demotions += freed;
                                if freed > 0 {
                                    let more =
                                        stores.get_mut(&id).unwrap().promote_blocks(2 - got);
                                    rep.promotions += more;
                                }
                            }
                        }
                    }
                    let d = cfg.d;
                    let keys = vec![0.0f32; tpb * d];
                    let vals = vec![0.0f32; tpb * d];
                    let pos: Vec<u32> = (0..tpb as u32).collect();
                    let mut pending: Vec<(usize, usize)> = Vec::new();
                    for l in 0..cfg.layers {
                        for h in 0..cfg.kv_heads {
                            pending.push((l, h));
                        }
                    }
                    let mut attempts = 0;
                    loop {
                        let mut still = Vec::new();
                        {
                            let st = stores.get_mut(&id).unwrap();
                            for &(l, h) in &pending {
                                if st
                                    .head_mut(l, h)
                                    .try_alloc_cluster(&keys, &vals, &pos)
                                    .is_err()
                                {
                                    still.push((l, h));
                                }
                            }
                        }
                        if still.is_empty() {
                            break;
                        }
                        attempts += 1;
                        if !cfg.spill || attempts > 8 {
                            rep.append_failures += still.len();
                            break;
                        }
                        let freed = demote_from_stores(&mut stores, 2 * still.len(), lossy_ok);
                        rep.demotions += freed;
                        if freed == 0 {
                            rep.append_failures += still.len();
                            break;
                        }
                        pending = still;
                    }
                }
            }
            Action::Defer | Action::Idle => {}
        }
        // sample the invariants after every step
        let live = arena.live_blocks();
        let resident = arena.resident_bytes();
        let cold = arena.cold_blocks();
        rep.peak_live_blocks = rep.peak_live_blocks.max(live);
        rep.peak_resident_bytes = rep.peak_resident_bytes.max(resident);
        rep.peak_cold_blocks = rep.peak_cold_blocks.max(cold);
        rep.peak_cold_logical_bytes =
            rep.peak_cold_logical_bytes.max(arena.spill().logical_bytes());
        rep.peak_cold_physical_bytes =
            rep.peak_cold_physical_bytes.max(arena.spill().physical_bytes());
        rep.peak_compressed_blocks =
            rep.peak_compressed_blocks.max(arena.spill().compressed_blocks());
        rep.peak_total_live_blocks = rep.peak_total_live_blocks.max(live + cold);
        rep.peak_shared_blocks = rep.peak_shared_blocks.max(arena.shared_blocks_live());
        rep.peak_shared_refs = rep.peak_shared_refs.max(arena.shared_session_refs());
        if live > cfg.capacity_blocks || resident > cap_bytes {
            rep.capacity_violations += 1;
        }
        for &t in &tenants {
            let tl = arena.tenant_live_blocks(t);
            let e = rep.per_tenant_peak.entry(t).or_insert(0);
            if tl > *e {
                *e = tl;
            }
            if let Some(q) = cfg.tenant_quota_blocks {
                if tl > q {
                    rep.quota_violations += 1;
                }
            }
        }
        // reclamation: finished sessions drop their stores, returning
        // blocks to the arena (this is what re-admits deferred prefills)
        for fid in sched.take_finished() {
            stores.remove(&fid);
            decoded.remove(&fid);
        }
    }
    rep.drained = true;
    rep.final_cold_blocks = arena.cold_blocks();
    // the modelled registry releases its pins: with every session gone,
    // shared refcounts drain to zero and the arena empties
    registry.unpin_all(&arena);
    rep.final_live_blocks = arena.live_blocks();
    rep.deferrals = sched.n_deferrals();
    rep.rejected = sched.n_rejections() as usize;
    rep.completed = sched
        .sessions()
        .filter(|s| !s.rejected && s.generated.len() >= s.req.max_new)
        .count();
    rep
}

/// Geometry + fault plan of a modelled cluster-pressure scenario: N
/// workers, each a full [`PressureConfig`]-style node (own arena, own
/// admission gate), behind the real [`Router`]. Exercises the three
/// cluster verbs without model artifacts, so the failure-injection
/// invariants run in tier-1 CI: **steal** (a gate-deferred head moves to
/// the least-loaded live peer), **recover** (a killed worker's sessions
/// restart on survivors from their queue — in the modelled world KV is
/// zero-filled, so recovery degenerates to requeue-and-re-prefill), and
/// per-worker capacity isolation (one worker's overload never breaches
/// another's cap).
#[derive(Clone, Debug)]
pub struct ClusterPressureConfig {
    pub workers: usize,
    /// Per-worker node geometry/budget. `spill` and
    /// `shared_prefix_tokens` are single-node features and must be off
    /// here (the cluster model needs the single-tier gate so deferral —
    /// and therefore stealing — can happen).
    pub node: PressureConfig,
    /// Offer gate-deferred heads to the least-loaded live peer.
    pub steal: bool,
    /// Kill this worker after `kill_at_step` scheduler rounds.
    pub kill_worker: Option<usize>,
    pub kill_at_step: usize,
}

impl Default for ClusterPressureConfig {
    fn default() -> Self {
        ClusterPressureConfig {
            workers: 2,
            node: PressureConfig::default(),
            steal: true,
            kill_worker: None,
            kill_at_step: 0,
        }
    }
}

/// What a cluster-pressure run observed.
#[derive(Clone, Debug, Default)]
pub struct ClusterPressureReport {
    /// Requests that finished with their full token budget (survivors +
    /// the killed worker's already-finished sessions).
    pub completed: usize,
    /// Requests some gate rejected outright.
    pub rejected: usize,
    /// Requests moved off their routed worker (steals + failure
    /// re-homes), from the router's own counter.
    pub steals: u64,
    /// Gate-blocked head-of-queue observations summed over workers.
    pub deferrals: u64,
    /// Sessions re-homed off the killed worker.
    pub recovered: usize,
    /// Of those, sessions that were mid-decode (lost KV, restarted).
    pub restarted_mid_decode: usize,
    /// Steps where any worker's live blocks exceeded its own cap (must
    /// be zero — per-worker isolation).
    pub capacity_violations: usize,
    /// Prefill checkouts an arena refused after admission let them
    /// through (must be zero).
    pub prefill_failures: usize,
    /// Blocks still live on the killed worker's arena after its stores
    /// dropped (must be zero — failure leaks nothing).
    pub leaked_blocks: usize,
    pub peak_live_blocks_per_worker: Vec<usize>,
    pub completed_per_worker: Vec<usize>,
    /// Coordinator rounds the run took.
    pub steps: usize,
    /// False only if the guard tripped before the trace drained.
    pub drained: bool,
}

/// One modelled worker: private arena + gate + stores.
struct ModelWorker {
    arena: Arc<BlockArena>,
    sched: Scheduler,
    stores: HashMap<u64, KvStore>,
    decoded: HashMap<u64, usize>,
}

fn model_worker(node: &PressureConfig) -> ModelWorker {
    let arena = BlockArena::shared(node.d, node.block_bytes);
    arena.set_capacity_blocks(Some(node.capacity_blocks));
    let adm = AdmissionConfig {
        heads: node.layers * node.kv_heads,
        tokens_per_block: arena.tokens_per_block(),
        headroom_frac: node.headroom_frac,
        est_fudge: 1.5,
        tiered: false,
    };
    let sched = Scheduler::with_admission(
        Batcher::new(&[1, 2, 4, 8], node.max_batch),
        Arc::clone(&arena),
        adm,
    );
    ModelWorker {
        arena,
        sched,
        stores: HashMap::new(),
        decoded: HashMap::new(),
    }
}

/// Run one seeded cluster-pressure scenario to completion (or guard).
/// The trace is routed up-front (least-loaded), then the coordinator
/// rounds every live worker through the same prefill/decode footprint
/// model as [`run_memory_pressure`], stealing deferred heads and — when
/// the fault plan says so — killing a worker mid-run and re-homing its
/// unfinished sessions to survivors.
pub fn run_cluster_pressure(
    cfg: &ClusterPressureConfig,
    trace: &[RequestSpec],
) -> ClusterPressureReport {
    assert!(cfg.workers > 0);
    assert!(
        !cfg.node.spill && cfg.node.shared_prefix_tokens == 0,
        "cluster pressure models the single-tier gate only"
    );
    let node = &cfg.node;
    let tpb_ref = crate::kvcache::tokens_per_block(node.block_bytes, node.d, 4);
    let mut workers: Vec<Option<ModelWorker>> =
        (0..cfg.workers).map(|_| Some(model_worker(node))).collect();
    let mut router = Router::new(cfg.workers);
    let mut rep = ClusterPressureReport {
        peak_live_blocks_per_worker: vec![0; cfg.workers],
        completed_per_worker: vec![0; cfg.workers],
        ..Default::default()
    };
    for (i, r) in trace.iter().enumerate() {
        let w = router.route_with_prefix(None);
        let req = Request::new(i as u64, vec![1; r.input_tokens], r.output_tokens.max(1))
            .with_tenant(r.tenant);
        workers[w].as_mut().unwrap().sched.submit(req, r.arrive_s);
    }

    let mut killed_deferrals = 0u64;
    let mut killed_rejected = 0usize;
    let mut guard = 0usize;
    loop {
        let all_done = workers.iter().flatten().all(|w| w.sched.all_done());
        if all_done {
            break;
        }
        guard += 1;
        if guard > 200_000 {
            rep.drained = false;
            rep.deferrals = killed_deferrals
                + workers.iter().flatten().map(|w| w.sched.n_deferrals()).sum::<u64>();
            return rep;
        }
        rep.steps += 1;
        let now = rep.steps as f64 * 1e-3;

        // fault plan: the worker dies, its arena must drain, and its
        // unfinished sessions re-home to survivors
        if Some(rep.steps) == cfg.kill_worker.map(|_| cfg.kill_at_step) {
            let victim = cfg.kill_worker.unwrap();
            if let Some(mut dead) = workers[victim].take() {
                for fid in dead.sched.take_finished() {
                    if let Some(s) = dead.sched.session(fid) {
                        if !s.rejected && s.generated.len() >= s.req.max_new {
                            rep.completed += 1;
                            rep.completed_per_worker[victim] += 1;
                        }
                    }
                }
                router.mark_down(victim);
                killed_deferrals += dead.sched.n_deferrals();
                killed_rejected += dead.sched.n_rejections() as usize;
                // the KV dies with the worker: dropping the stores must
                // return every block to its (now unreachable) arena
                dead.stores.clear();
                dead.decoded.clear();
                rep.leaked_blocks = dead.arena.live_blocks();
                for mut s in dead.sched.drain_unfinished() {
                    let target = router
                        .steal_target(victim)
                        .expect("survivors exist (mark_down enforces it)");
                    if s.phase == Phase::Decode {
                        rep.restarted_mid_decode += 1;
                    }
                    // restart from the queue: the modelled KV carries no
                    // token state, so requeue-and-re-prefill is the whole
                    // recovery story here (the live path additionally
                    // replays generated tokens — tests/cluster.rs)
                    s.generated.clear();
                    s.phase = Phase::Queued;
                    s.first_token_s = f64::NAN;
                    workers[target].as_mut().unwrap().sched.adopt_session(s, now);
                    router.note_stolen(victim, target);
                    rep.recovered += 1;
                }
            }
        }

        for w in 0..cfg.workers {
            if workers[w].is_none() {
                continue;
            }
            let action = workers[w].as_mut().unwrap().sched.next_action();
            match action {
                Action::Prefill(id) => {
                    let mw = workers[w].as_mut().unwrap();
                    let (tenant, prompt_len) = {
                        let s = mw.sched.session(id).unwrap();
                        (s.req.tenant, s.req.prompt.len())
                    };
                    let mut st =
                        KvStore::new_in_for(Arc::clone(&mw.arena), tenant, node.layers, node.kv_heads);
                    if checkout_prompt(&mut st, node.layers, node.kv_heads, 0, prompt_len) {
                        mw.stores.insert(id, st);
                        mw.decoded.insert(id, 0);
                    } else {
                        rep.prefill_failures += 1;
                    }
                    mw.sched.prefill_done(id, 0, now);
                }
                Action::DecodeBatch(ids, _bucket) => {
                    let mw = workers[w].as_mut().unwrap();
                    for id in ids {
                        mw.sched.token_decoded(id, 1, now);
                        let n = mw.decoded.entry(id).or_insert(0);
                        *n += 1;
                        if *n % tpb_ref != 0 || !mw.stores.contains_key(&id) {
                            continue;
                        }
                        let keys = vec![0.0f32; tpb_ref * node.d];
                        let vals = vec![0.0f32; tpb_ref * node.d];
                        let pos: Vec<u32> = (0..tpb_ref as u32).collect();
                        let st = mw.stores.get_mut(&id).unwrap();
                        for l in 0..node.layers {
                            for h in 0..node.kv_heads {
                                // headroom should make growth infallible;
                                // a refusal is a prefill-style failure
                                if st
                                    .head_mut(l, h)
                                    .try_alloc_cluster(&keys, &vals, &pos)
                                    .is_err()
                                {
                                    rep.prefill_failures += 1;
                                }
                            }
                        }
                    }
                }
                Action::Defer | Action::Idle => {}
            }
            // donor check every round: a busy worker decodes instead of
            // returning `Defer`, so the gate-blocked head is probed
            // directly. Load-gated: a request only moves where it
            // reduces imbalance (also stops ping-pong between two full
            // workers).
            if cfg.steal {
                if let Some(t) = router.steal_target(w) {
                    if router.load(t) + 1 < router.load(w) {
                        if let Some(req) = workers[w].as_mut().unwrap().sched.steal_deferred()
                        {
                            workers[t].as_mut().unwrap().sched.submit(req, now);
                            router.note_stolen(w, t);
                        }
                    }
                }
            }
            // sample the per-worker isolation invariant, then reclaim
            let mw = workers[w].as_mut().unwrap();
            let live = mw.arena.live_blocks();
            rep.peak_live_blocks_per_worker[w] = rep.peak_live_blocks_per_worker[w].max(live);
            if live > node.capacity_blocks {
                rep.capacity_violations += 1;
            }
            for fid in mw.sched.take_finished() {
                if let Some(s) = mw.sched.session(fid) {
                    if !s.rejected && s.generated.len() >= s.req.max_new {
                        rep.completed += 1;
                        rep.completed_per_worker[w] += 1;
                    }
                }
                mw.stores.remove(&fid);
                mw.decoded.remove(&fid);
                router.complete(w);
            }
        }
    }
    rep.drained = true;
    rep.steals = router.steals();
    rep.deferrals = killed_deferrals
        + workers.iter().flatten().map(|w| w.sched.n_deferrals()).sum::<u64>();
    rep.rejected = killed_rejected
        + workers
            .iter()
            .flatten()
            .map(|w| w.sched.n_rejections() as usize)
            .sum::<usize>();
    rep
}
