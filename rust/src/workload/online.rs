//! Modelled online-serving driver: chunked prefill + continuous
//! batching under SLOs, in deterministic virtual time.
//!
//! Drives the real [`Scheduler::next_plan`] planning loop (EDF
//! admission, TPOT-slack chunk budgeting, deadline-slack decode
//! selection) with a *modelled* engine: prefill costs
//! `prefill_token_s` per token, a decode step costs `decode_step_s`,
//! and tokens are a deterministic function of (session, position). No
//! model artifacts and no real clock, so the online-serving invariants
//! — bounded inter-token gaps under chunked prefill, SLO attainment,
//! bit-identical token streams across runs — hold exactly and run in
//! tier-1 CI (`rust/tests/slo.rs`, `examples/serve_e2e.rs
//! --online-modelled`, `benches/fig13_throughput.rs`).
//!
//! The monolithic baseline (`chunked: false`) models prefill-eager
//! serving: the scheduler believes prefill is free (it always rides),
//! but the driver charges the full prompt cost in one step — exactly
//! the head-of-line blocking that blows a decode session's inter-token
//! gap when a long prompt arrives mid-stream. Chunked mode tells the
//! scheduler the true per-chunk cost, so the slack budget keeps every
//! step's duration under
//! `decode_step_s + max_chunks_per_step × chunk_cost`.

use crate::coordinator::{Batcher, Phase, Request, Scheduler, SloPolicy};
use crate::util::stats::LogHistogram;
use crate::workload::RequestSpec;
use std::collections::HashMap;

/// One online-serving scenario.
#[derive(Clone, Debug)]
pub struct OnlineConfig {
    /// Arrival trace (finite `arrive_s` only; closed-loop INFINITY
    /// markers are not supported here).
    pub trace: Vec<RequestSpec>,
    /// Chunked prefill (true) vs monolithic prefill-eager baseline.
    pub chunked: bool,
    /// Prefill chunk size in tokens (chunked mode).
    pub chunk_tokens: usize,
    /// Modelled prefill cost per token.
    pub prefill_token_s: f64,
    /// Modelled decode-step cost.
    pub decode_step_s: f64,
    /// Cap on prefill chunks riding along with one decode step.
    pub max_chunks_per_step: usize,
    /// Decode-pool admission cap + batch buckets.
    pub max_batch: usize,
    pub buckets: Vec<usize>,
    /// SLO targets applied to the interactive class: every request with
    /// `input_tokens <= slo_max_input`. Longer prompts run best-effort
    /// at priority 0 (the batch class). `INFINITY` disables a target.
    pub slo_ttft_s: f64,
    pub slo_tpot_s: f64,
    pub slo_max_input: usize,
    /// Step-count guard against a non-converging scenario.
    pub max_steps: usize,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        OnlineConfig {
            trace: Vec::new(),
            chunked: true,
            chunk_tokens: 512,
            prefill_token_s: 1e-5,
            decode_step_s: 5e-3,
            max_chunks_per_step: 2,
            max_batch: 8,
            buckets: vec![1, 2, 4, 8],
            slo_ttft_s: f64::INFINITY,
            slo_tpot_s: f64::INFINITY,
            slo_max_input: 1024,
            max_steps: 1_000_000,
        }
    }
}

impl OnlineConfig {
    /// Upper bound on one chunked step's duration — the per-step budget
    /// the max inter-token gap of an always-batched decode session is
    /// asserted against.
    pub fn step_budget_s(&self) -> f64 {
        self.decode_step_s
            + self.max_chunks_per_step as f64 * self.chunk_tokens as f64 * self.prefill_token_s
    }
}

/// What an online run observed.
#[derive(Clone, Debug, PartialEq)]
pub struct OnlineReport {
    pub completed: usize,
    pub rejected: usize,
    pub steps: usize,
    pub makespan_s: f64,
    pub decoded_tokens: usize,
    pub throughput_tok_s: f64,
    /// TTFT percentiles across all completed sessions (streaming
    /// histogram estimates, NaN when empty).
    pub ttft_p50_s: f64,
    pub ttft_p95_s: f64,
    pub ttft_p99_s: f64,
    /// Inter-token-gap percentiles across all decoded tokens.
    pub tpot_p50_s: f64,
    pub tpot_p95_s: f64,
    pub tpot_p99_s: f64,
    /// Max inter-token gap of SLO-class sessions / of all sessions.
    pub max_gap_s: f64,
    pub max_gap_all_s: f64,
    /// Fraction of SLO-class sessions whose first token met the TTFT
    /// target (rejected SLO sessions count as misses; 1.0 when the
    /// class is empty).
    pub ttft_attainment: f64,
    /// Fraction of SLO-class inter-token gaps within the TPOT target.
    pub tpot_attainment: f64,
    /// Per-session generated token streams — byte-for-byte comparable
    /// across runs and across chunked/monolithic modes.
    pub tokens: HashMap<u64, Vec<i32>>,
}

/// Deterministic modelled token `k` of session `id`.
fn model_token(id: u64, k: usize) -> i32 {
    ((id as i32) << 16) | (k as i32 & 0xFFFF)
}

/// Run one scenario to completion; see module docs for the model.
pub fn run_online_serving(cfg: &OnlineConfig) -> OnlineReport {
    assert!(cfg.trace.iter().all(|r| r.arrive_s.is_finite()), "open-loop traces only");
    let mut arrivals: Vec<(u64, RequestSpec)> =
        cfg.trace.iter().cloned().enumerate().map(|(i, r)| (i as u64, r)).collect();
    arrivals.sort_by(|a, b| a.1.arrive_s.partial_cmp(&b.1.arrive_s).unwrap().then(a.0.cmp(&b.0)));

    // The scheduler's belief about chunk cost: truthful in chunked
    // mode; "free" in the monolithic baseline so prefill always rides
    // (prefill-eager), with the driver charging the real cost below.
    let plan_chunk_tokens = if cfg.chunked { cfg.chunk_tokens.max(1) } else { usize::MAX / 4 };
    let pol = SloPolicy {
        chunk_tokens: plan_chunk_tokens,
        chunk_s: if cfg.chunked {
            cfg.chunk_tokens.max(1) as f64 * cfg.prefill_token_s
        } else {
            0.0
        },
        decode_step_s: cfg.decode_step_s,
        max_chunks_per_step: cfg.max_chunks_per_step,
    };
    let mut sched = Scheduler::new(Batcher::new(&cfg.buckets, cfg.max_batch));

    let mut now = 0.0f64;
    let mut next_arrival = 0usize;
    let mut fed: HashMap<u64, usize> = HashMap::new();
    let mut last_emit: HashMap<u64, f64> = HashMap::new();
    let mut is_slo: HashMap<u64, bool> = HashMap::new();
    let mut tokens: HashMap<u64, Vec<i32>> = HashMap::new();
    let mut ttft_hist = LogHistogram::latency_s();
    let mut tpot_hist = LogHistogram::latency_s();
    let (mut max_gap_slo, mut max_gap_all) = (0.0f64, 0.0f64);
    let (mut ttft_met, mut slo_sessions) = (0usize, 0usize);
    let (mut gaps_met, mut gaps_slo) = (0usize, 0usize);
    let mut decoded_tokens = 0usize;
    let mut steps = 0usize;

    loop {
        // release arrivals due by `now`
        while next_arrival < arrivals.len() && arrivals[next_arrival].1.arrive_s <= now {
            let (id, spec) = &arrivals[next_arrival];
            let interactive = spec.input_tokens <= cfg.slo_max_input;
            let mut req = Request::new(
                *id,
                vec![0i32; spec.input_tokens.max(1)],
                spec.output_tokens.max(1),
            )
            .with_tenant(spec.tenant);
            req.arrive_s = spec.arrive_s;
            if interactive {
                req = req.with_slo(cfg.slo_ttft_s, cfg.slo_tpot_s).with_priority(1);
                if cfg.slo_ttft_s.is_finite() || cfg.slo_tpot_s.is_finite() {
                    slo_sessions += 1;
                }
            }
            is_slo.insert(
                *id,
                interactive && (cfg.slo_ttft_s.is_finite() || cfg.slo_tpot_s.is_finite()),
            );
            sched.submit(req, spec.arrive_s);
            next_arrival += 1;
        }
        if sched.all_done() {
            if next_arrival >= arrivals.len() {
                break;
            }
            now = now.max(arrivals[next_arrival].1.arrive_s);
            continue;
        }
        steps += 1;
        if steps > cfg.max_steps {
            break;
        }
        let plan = sched.next_plan(now, &pol);
        debug_assert!(plan.preempt.is_empty() && plan.resume.is_empty(), "no gate armed");
        if plan.is_idle() {
            // rejections mutate in-plan; otherwise only a future arrival
            // can unblock an idle scheduler
            if sched.take_finished().is_empty() && next_arrival < arrivals.len() {
                now = now.max(arrivals[next_arrival].1.arrive_s);
            }
            continue;
        }

        // apply + charge the step in virtual time
        for &id in &plan.start_prefill {
            sched.prefill_started(id);
            fed.insert(id, 0);
        }
        let mut dur = if plan.decode.is_empty() { 0.0 } else { cfg.decode_step_s };
        let mut finished_prefills: Vec<u64> = Vec::new();
        for &id in &plan.chunks {
            let total = sched.session(id).unwrap().req.prompt.len();
            let f = fed.get_mut(&id).unwrap();
            let advance = plan_chunk_tokens.min(total - *f);
            *f += advance;
            dur += advance as f64 * cfg.prefill_token_s;
            sched.chunk_done(id, *f);
            if *f == total {
                finished_prefills.push(id);
            }
        }
        if dur == 0.0 {
            dur = cfg.decode_step_s.max(1e-9);
        }
        let t_end = now + dur;
        for id in finished_prefills {
            sched.prefill_done(id, model_token(id, 0), t_end);
            tokens.entry(id).or_default().push(model_token(id, 0));
            decoded_tokens += 1;
            let ttft = t_end - sched.session(id).unwrap().req.arrive_s;
            ttft_hist.observe(ttft);
            if is_slo[&id] && ttft <= cfg.slo_ttft_s {
                ttft_met += 1;
            }
            last_emit.insert(id, t_end);
        }
        for &id in &plan.decode {
            let k = sched.session(id).unwrap().n_generated();
            let tok = model_token(id, k);
            sched.token_decoded(id, tok, t_end);
            tokens.entry(id).or_default().push(tok);
            decoded_tokens += 1;
            let gap = t_end - last_emit.insert(id, t_end).unwrap_or(t_end);
            tpot_hist.observe(gap);
            max_gap_all = max_gap_all.max(gap);
            if is_slo[&id] {
                max_gap_slo = max_gap_slo.max(gap);
                gaps_slo += 1;
                if gap <= cfg.slo_tpot_s {
                    gaps_met += 1;
                }
            }
        }
        now = t_end;
        sched.take_finished();
    }

    let completed =
        sched.sessions().filter(|s| s.phase == Phase::Done && !s.rejected).count();
    let rejected = sched.sessions().filter(|s| s.rejected).count();
    OnlineReport {
        completed,
        rejected,
        steps,
        makespan_s: now,
        decoded_tokens,
        throughput_tok_s: if now > 0.0 { decoded_tokens as f64 / now } else { 0.0 },
        ttft_p50_s: ttft_hist.percentile(50.0),
        ttft_p95_s: ttft_hist.percentile(95.0),
        ttft_p99_s: ttft_hist.percentile(99.0),
        tpot_p50_s: tpot_hist.percentile(50.0),
        tpot_p95_s: tpot_hist.percentile(95.0),
        tpot_p99_s: tpot_hist.percentile(99.0),
        max_gap_s: max_gap_slo,
        max_gap_all_s: max_gap_all,
        ttft_attainment: if slo_sessions == 0 {
            1.0
        } else {
            ttft_met as f64 / slo_sessions as f64
        },
        tpot_attainment: if gaps_slo == 0 { 1.0 } else { gaps_met as f64 / gaps_slo as f64 },
        tokens,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two interactive decode streams with a long best-effort prompt
    /// landing mid-stream — the scenario the chunked-prefill gap bound
    /// is defined by.
    fn midstream_cfg(chunked: bool) -> OnlineConfig {
        OnlineConfig {
            trace: vec![
                RequestSpec {
                    arrive_s: 0.0,
                    input_tokens: 64,
                    output_tokens: 200,
                    tenant: 0,
                    prefix_hash: None,
                },
                RequestSpec {
                    arrive_s: 0.0,
                    input_tokens: 64,
                    output_tokens: 200,
                    tenant: 0,
                    prefix_hash: None,
                },
                RequestSpec {
                    arrive_s: 0.05,
                    input_tokens: 20_000,
                    output_tokens: 4,
                    tenant: 1,
                    prefix_hash: None,
                },
            ],
            chunked,
            chunk_tokens: 512,
            prefill_token_s: 1e-5,
            decode_step_s: 5e-3,
            max_chunks_per_step: 2,
            max_batch: 4,
            buckets: vec![1, 2, 4, 8],
            slo_ttft_s: 0.05,
            slo_tpot_s: 0.05,
            slo_max_input: 1024,
            ..OnlineConfig::default()
        }
    }

    #[test]
    fn chunked_bounds_gaps_where_monolithic_blows_them() {
        let chunked = run_online_serving(&midstream_cfg(true));
        let mono = run_online_serving(&midstream_cfg(false));
        let budget = midstream_cfg(true).step_budget_s();
        assert_eq!(chunked.completed, 3);
        assert_eq!(mono.completed, 3);
        assert_eq!(chunked.rejected + mono.rejected, 0);
        // chunked: every step a decode session waits is bounded
        assert!(
            chunked.max_gap_s <= budget + 1e-9,
            "chunked max gap {} exceeds step budget {}",
            chunked.max_gap_s,
            budget
        );
        // monolithic: the 20k-token prefill lands whole in one step
        assert!(
            mono.max_gap_s > 5.0 * budget,
            "monolithic gap {} should dwarf the budget {}",
            mono.max_gap_s,
            budget
        );
        assert!(mono.max_gap_s > 0.2, "20k tokens × 1e-5 s/token stalls one full step");
        // chunked meets every TPOT gap; monolithic misses at least one
        assert_eq!(chunked.tpot_attainment, 1.0);
        assert!(mono.tpot_attainment < 1.0);
        assert_eq!(chunked.ttft_attainment, 1.0);
        // token streams are identical across scheduling modes and
        // complete to each session's full output budget
        assert_eq!(chunked.tokens, mono.tokens);
        for (id, want) in [(0u64, 200usize), (1, 200), (2, 4)] {
            assert_eq!(chunked.tokens[&id].len(), want, "session {id} token count");
        }
    }

    #[test]
    fn online_runs_are_deterministic() {
        let a = run_online_serving(&midstream_cfg(true));
        let b = run_online_serving(&midstream_cfg(true));
        assert_eq!(a, b, "virtual-time runs must be bit-identical");
    }

    #[test]
    fn diurnal_trace_completes_with_sane_slo_accounting() {
        let trace = crate::workload::diurnal_poisson(&[20.0, 20.0], 3.0, 4.0, 4.0, 64, 8, 9);
        let n = trace.len();
        assert!(n > 20);
        let cfg = OnlineConfig {
            trace,
            slo_ttft_s: 0.5,
            slo_tpot_s: 0.1,
            ..OnlineConfig::default()
        };
        let r = run_online_serving(&cfg);
        assert_eq!(r.completed + r.rejected, n, "no request lost");
        assert!(r.ttft_attainment >= 0.0 && r.ttft_attainment <= 1.0);
        assert!(r.tpot_attainment >= 0.0 && r.tpot_attainment <= 1.0);
        assert!(r.throughput_tok_s > 0.0);
        assert!(r.ttft_p50_s > 0.0 && r.tpot_p50_s > 0.0);
        assert!(r.ttft_p99_s >= r.ttft_p50_s * 0.999);
    }
}
