//! PJRT runtime: loads HLO-text artifacts and executes them on the CPU
//! PJRT client (the `xla` crate). Executables compile lazily on first use
//! and are cached for the life of the runtime — Python is never involved.

use super::manifest::Manifest;
use crate::tensor::Tensor;
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// The PJRT execution context for one artifacts directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: String,
    pub manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    pub fn load(dir: &str) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client, dir: dir.to_string(), manifest, exes: HashMap::new() })
    }

    /// Compile (or fetch cached) an executable by manifest name.
    pub fn ensure(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let sig = self.manifest.exe(name)?.clone();
        let path = format!("{}/{}", self.dir, sig.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute `name` with ordered input literals; returns the output
    /// tuple's elements (aot.py lowers with return_tuple=True). Accepts
    /// owned or borrowed literals — the hot path passes cached weight
    /// literals by reference (zero copies per step).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &mut self,
        name: &str,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        self.ensure(name)?;
        let sig = self.manifest.exe(name)?;
        if sig.params.len() != inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} params, got {}",
                sig.params.len(),
                inputs.len()
            ));
        }
        let exe = self.exes.get(name).unwrap();
        let bufs = exe
            .execute::<L>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{name}: fetching result: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("{name}: untupling: {e:?}"))
    }

    /// Execute `name` with device-resident buffers.
    ///
    /// CAUTION: `BufferFromHostLiteral` is asynchronous and this crate
    /// does not expose the transfer's ready-future — the source literal
    /// of every input buffer must outlive the execution. Prefer `run`
    /// unless you manage literal lifetimes explicitly.
    pub fn run_b(&mut self, name: &str, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        self.ensure(name)?;
        let sig = self.manifest.exe(name)?;
        if sig.params.len() != inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} params, got {}",
                sig.params.len(),
                inputs.len()
            ));
        }
        let exe = self.exes.get(name).unwrap();
        let bufs = exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{name}: fetching result: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("{name}: untupling: {e:?}"))
    }

    /// Upload a host literal to a device-resident buffer. See the caveat
    /// on [`Runtime::run_b`]: `lit` must stay alive until the transfer
    /// completes (in practice: until an execution consuming the buffer
    /// has synchronized).
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow!("host->device: {e:?}"))
    }

    /// Number of compiled executables currently cached.
    pub fn compiled(&self) -> usize {
        self.exes.len()
    }
}

/// Host tensor -> f32 literal with the given logical shape.
pub fn lit_f32(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(t.data())
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape {:?}: {e:?}", t.shape()))
}

/// Flat f32 slice -> literal with explicit shape.
pub fn lit_f32_shaped(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).map_err(|e| anyhow!("reshape {shape:?}: {e:?}"))
}

/// i32 slice -> 1-D literal.
pub fn lit_i32(data: &[i32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// i32 scalar literal.
pub fn lit_i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Literal -> (shape, f32 data).
pub fn lit_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape().map_err(|e| anyhow!("shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
    Ok(Tensor::from_vec(&dims, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    #[test]
    fn smoke_executable_runs() {
        crate::require_live_path!();
        let mut rt = Runtime::load(&artifacts_dir()).unwrap();
        let x = lit_f32_shaped(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        let y = lit_f32_shaped(&[1.0; 4], &[2, 2]).unwrap();
        let out = rt.run("smoke", &[x, y]).unwrap();
        assert_eq!(out.len(), 1);
        let t = lit_to_tensor(&out[0]).unwrap();
        assert_eq!(t.data(), &[5.0, 5.0, 9.0, 9.0]);
        assert_eq!(rt.compiled(), 1);
        // cached second run
        let x = lit_f32_shaped(&[0.0; 4], &[2, 2]).unwrap();
        let y = lit_f32_shaped(&[0.0; 4], &[2, 2]).unwrap();
        rt.run("smoke", &[x, y]).unwrap();
        assert_eq!(rt.compiled(), 1);
    }

    #[test]
    fn param_count_checked() {
        crate::require_live_path!();
        let mut rt = Runtime::load(&artifacts_dir()).unwrap();
        let x = lit_f32_shaped(&[0.0; 4], &[2, 2]).unwrap();
        assert!(rt.run("smoke", &[x]).is_err());
    }
}
