//! Manifest parsing: `artifacts/manifest.json` describes the model config,
//! shape buckets, zone defaults, weight layout and executable signatures
//! produced by `python/compile/aot.py`.

use crate::util::json::parse;
use anyhow::{anyhow, Context, Result};

#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub q_heads: usize,
    pub kv_heads: usize,
    pub d_head: usize,
    pub ffn: usize,
    pub weights_file: String,
}

impl ModelCfg {
    pub fn group(&self) -> usize {
        self.q_heads / self.kv_heads
    }
}

#[derive(Clone, Debug)]
pub struct Buckets {
    pub batch: Vec<usize>,
    pub prefill_t: Vec<usize>,
    pub attn_full_t: usize,
    pub wave_ne: usize,
    pub wave_m: usize,
    pub prefill_chunk: usize,
}

impl Buckets {
    /// Smallest batch bucket >= `b`.
    pub fn batch_bucket(&self, b: usize) -> Option<usize> {
        self.batch.iter().copied().find(|&x| x >= b)
    }
}

#[derive(Clone, Debug)]
pub struct ParamSig {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct ExeSig {
    pub name: String,
    pub file: String,
    pub params: Vec<ParamSig>,
    pub outputs: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct WeightSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub elements: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: ModelCfg,
    pub buckets: Buckets,
    pub weights: Vec<WeightSpec>,
    pub executables: Vec<ExeSig>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
        let j = parse(&text).map_err(|e| anyhow!("parsing {path}: {e:?}"))?;

        let m = j.field("model");
        let model = ModelCfg {
            name: m.str_field("name").to_string(),
            vocab: m.usize_field("vocab"),
            d_model: m.usize_field("d_model"),
            n_layers: m.usize_field("n_layers"),
            q_heads: m.usize_field("q_heads"),
            kv_heads: m.usize_field("kv_heads"),
            d_head: m.usize_field("d_head"),
            ffn: m.usize_field("ffn"),
            weights_file: m.str_field("weights_file").to_string(),
        };

        let b = j.field("buckets");
        let buckets = Buckets {
            batch: b.field("batch").usize_vec(),
            prefill_t: b.field("prefill_t").usize_vec(),
            attn_full_t: b.usize_field("attn_full_t"),
            wave_ne: b.usize_field("wave_ne"),
            wave_m: b.usize_field("wave_m"),
            prefill_chunk: b.usize_field("prefill_chunk"),
        };

        let weights = j
            .arr_field("weights")
            .iter()
            .map(|w| WeightSpec {
                name: w.str_field("name").to_string(),
                shape: w.field("shape").usize_vec(),
                offset: w.usize_field("offset"),
                elements: w.usize_field("elements"),
            })
            .collect();

        let executables = j
            .arr_field("executables")
            .iter()
            .map(|e| ExeSig {
                name: e.str_field("name").to_string(),
                file: e.str_field("file").to_string(),
                params: e
                    .arr_field("params")
                    .iter()
                    .map(|p| ParamSig {
                        name: p.str_field("name").to_string(),
                        dtype: p.str_field("dtype").to_string(),
                        shape: p.field("shape").usize_vec(),
                    })
                    .collect(),
                outputs: e
                    .arr_field("outputs")
                    .iter()
                    .map(|o| o.as_str().unwrap_or_default().to_string())
                    .collect(),
            })
            .collect();

        Ok(Manifest { model, buckets, weights, executables })
    }

    pub fn exe(&self, name: &str) -> Result<&ExeSig> {
        self.executables
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("executable {name} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    #[test]
    fn loads_real_manifest() {
        crate::require_artifacts!();
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert_eq!(m.model.name, "tinylm");
        assert_eq!(m.model.n_layers, 4);
        assert_eq!(m.model.group(), 4);
        assert!(m.buckets.batch.contains(&8));
        assert!(!m.weights.is_empty());
        assert!(m.exe("smoke").is_ok());
        assert!(m.exe("nope").is_err());
    }

    #[test]
    fn weight_layout_is_contiguous() {
        crate::require_artifacts!();
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let mut off = 0;
        for w in &m.weights {
            assert_eq!(w.offset, off, "{} offset", w.name);
            assert_eq!(w.elements, w.shape.iter().product::<usize>());
            off += w.elements * 4;
        }
    }

    #[test]
    fn batch_bucketing() {
        crate::require_artifacts!();
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert_eq!(m.buckets.batch_bucket(1), Some(1));
        assert_eq!(m.buckets.batch_bucket(3), Some(4));
        assert_eq!(m.buckets.batch_bucket(99), None);
    }
}
