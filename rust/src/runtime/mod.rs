//! Runtime layer: PJRT client wrapper, manifest/weights loading, and the
//! TinyLM live engine that executes the AOT-compiled HLO artifacts from
//! the L3 hot path. Python never runs here — `make artifacts` is the only
//! Python step, at build time.

pub mod client;
pub mod manifest;
pub mod tinylm;
pub mod weights;

pub use client::{lit_f32, lit_f32_shaped, lit_i32, lit_i32_scalar, lit_to_tensor, Runtime};
pub use manifest::{Buckets, ExeSig, Manifest, ModelCfg};
pub use tinylm::TinyLm;
pub use weights::Weights;

/// Default artifacts directory (relative to the workspace root).
pub fn default_artifacts_dir() -> String {
    std::env::var("RI_ARTIFACTS").unwrap_or_else(|_| {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    })
}

/// True when `make artifacts` has produced the manifest (needed by the
/// manifest/weights loaders and everything downstream of them).
pub fn artifacts_present() -> bool {
    std::path::Path::new(&format!("{}/manifest.json", default_artifacts_dir())).exists()
}

/// True when the full live path can run: artifacts on disk AND a real
/// PJRT backend (the vendored offline `xla` stub reports unavailable —
/// see DESIGN.md §1). Tests of the live path skip cleanly when false
/// instead of failing on an environment they cannot control.
pub fn live_path_available() -> bool {
    artifacts_present() && xla::PjRtClient::cpu().is_ok()
}

/// Test guard: skip (early-return) unless AOT artifacts are on disk.
#[macro_export]
macro_rules! require_artifacts {
    () => {
        if !$crate::runtime::artifacts_present() {
            eprintln!("skipped: artifacts missing (run `make artifacts`)");
            return;
        }
    };
}

/// Test guard: skip (early-return) unless artifacts AND a real PJRT
/// backend are available (offline builds ship the `xla` stub).
#[macro_export]
macro_rules! require_live_path {
    () => {
        if !$crate::runtime::live_path_available() {
            eprintln!("skipped: live PJRT path unavailable (offline build, DESIGN.md \u{a7}1)");
            return;
        }
    };
}
