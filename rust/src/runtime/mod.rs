//! Runtime layer: PJRT client wrapper, manifest/weights loading, and the
//! TinyLM live engine that executes the AOT-compiled HLO artifacts from
//! the L3 hot path. Python never runs here — `make artifacts` is the only
//! Python step, at build time.

pub mod client;
pub mod manifest;
pub mod tinylm;
pub mod weights;

pub use client::{lit_f32, lit_f32_shaped, lit_i32, lit_i32_scalar, lit_to_tensor, Runtime};
pub use manifest::{Buckets, ExeSig, Manifest, ModelCfg};
pub use tinylm::TinyLm;
pub use weights::Weights;

/// Default artifacts directory (relative to the workspace root).
pub fn default_artifacts_dir() -> String {
    std::env::var("RI_ARTIFACTS").unwrap_or_else(|_| {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    })
}
