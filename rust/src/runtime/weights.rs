//! TinyLM weight loading: `weights.bin` is flat f32 in manifest order.

use super::manifest::Manifest;
use crate::tensor::Tensor;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;

/// Named weight tensors.
pub struct Weights {
    tensors: HashMap<String, Tensor>,
}

impl Weights {
    pub fn load(dir: &str, manifest: &Manifest) -> Result<Weights> {
        let path = format!("{dir}/{}", manifest.model.weights_file);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path}"))?;
        let mut tensors = HashMap::new();
        for w in &manifest.weights {
            let start = w.offset;
            let end = start + w.elements * 4;
            if end > bytes.len() {
                return Err(anyhow!("{}: weight {} out of range", path, w.name));
            }
            let data: Vec<f32> = bytes[start..end]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.insert(w.name.clone(), Tensor::from_vec(&w.shape, data));
        }
        Ok(Weights { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).ok_or_else(|| anyhow!("weight {name} missing"))
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.keys().map(|s| s.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    #[test]
    fn loads_all_weights_with_shapes() {
        crate::require_artifacts!();
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let w = Weights::load(&artifacts_dir(), &m).unwrap();
        for spec in &m.weights {
            let t = w.get(&spec.name).unwrap();
            assert_eq!(t.shape(), &spec.shape[..], "{}", spec.name);
            assert!(t.data().iter().all(|x| x.is_finite()), "{} finite", spec.name);
        }
        // norms initialize to 1
        assert!(w.get("lnf").unwrap().data().iter().all(|&x| x == 1.0));
        assert!(w.get("zzz").is_err());
    }
}
