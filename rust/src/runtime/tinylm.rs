//! TinyLM live engine: executes the factored per-layer entry points
//! (embed -> qkv -> attention -> mlp -> logits) plus bucketed prefill,
//! all through PJRT. The wave index runs in Rust *between* qkv and
//! attention — exactly the paper's Figure 5 interplay.

use super::client::{lit_f32, lit_f32_shaped, lit_i32, lit_to_tensor, Runtime};
use super::manifest::{Buckets, ModelCfg};
use super::weights::Weights;
use crate::tensor::Tensor;
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Inputs to one wave-attention call (already assembled, padded to the
/// manifest's Ne/M capacities).
pub struct WaveInputs {
    /// [B, KVH, Ne, d] exact keys / values (steady + execution buffer).
    pub kx: Vec<f32>,
    pub vx: Vec<f32>,
    /// [B, KVH, Ne] validity mask.
    pub kmask: Vec<f32>,
    /// [B, KVH, M, d] centroids / value sums.
    pub cent: Vec<f32>,
    pub vsum: Vec<f32>,
    /// [B, KVH, M] cluster sizes / estimation-zone mask.
    pub csize: Vec<f32>,
    pub emask: Vec<f32>,
}

impl WaveInputs {
    pub fn zeros(b: usize, kvh: usize, ne: usize, m: usize, d: usize) -> Self {
        WaveInputs {
            kx: vec![0.0; b * kvh * ne * d],
            vx: vec![0.0; b * kvh * ne * d],
            kmask: vec![0.0; b * kvh * ne],
            cent: vec![0.0; b * kvh * m * d],
            vsum: vec![0.0; b * kvh * m * d],
            csize: vec![0.0; b * kvh * m],
            emask: vec![0.0; b * kvh * m],
        }
    }
}

/// The live TinyLM model: cached weight literals (whole-stack for prefill
/// + PER-LAYER slices for the decode hot path — the executables take
/// single-layer weights so the per-call host->device parameter copy is 4x
/// smaller, see EXPERIMENTS.md §Perf) + PJRT executables.
pub struct TinyLm {
    rt: Runtime,
    wlit: HashMap<String, xla::Literal>,
    pub cfg: ModelCfg,
    pub buckets: Buckets,
}

impl TinyLm {
    pub fn load(dir: &str) -> Result<TinyLm> {
        let rt = Runtime::load(dir)?;
        let cfg = rt.manifest.model.clone();
        let buckets = rt.manifest.buckets.clone();
        let weights = Weights::load(dir, &rt.manifest)?;
        let mut wlit = HashMap::new();
        for spec in &rt.manifest.weights {
            let t = weights.get(&spec.name)?;
            wlit.insert(spec.name.clone(), lit_f32(t)?);
            // per-layer slices of the stacked layer weights
            if spec.shape.len() >= 2 && spec.shape[0] == cfg.n_layers {
                let trailing = &spec.shape[1..];
                for layer in 0..cfg.n_layers {
                    let row = t.row(&[layer]);
                    wlit.insert(
                        format!("{}.{layer}", spec.name),
                        crate::runtime::client::lit_f32_shaped(row, trailing)?,
                    );
                }
            }
        }
        Ok(TinyLm { rt, wlit, cfg, buckets })
    }

    /// Cached weight literal by name. Free function over the map so
    /// `self.rt` can be borrowed mutably in the same expression.
    fn wl<'a>(
        wlit: &'a HashMap<String, xla::Literal>,
        name: &str,
    ) -> Result<&'a xla::Literal> {
        wlit.get(name).ok_or_else(|| anyhow!("weight literal {name}"))
    }

    /// Whole-prompt prefill (batch 1). `tokens.len()` must be one of the
    /// prefill buckets. Returns (k_cache, v_cache) as `[L, 1, KVH, T, d]`
    /// tensors plus last-token logits `[1, V]`.
    pub fn prefill(&mut self, tokens: &[i32]) -> Result<(Tensor, Tensor, Tensor)> {
        let t = tokens.len();
        if !self.buckets.prefill_t.contains(&t) {
            return Err(anyhow!("prefill length {t} not in buckets {:?}", self.buckets.prefill_t));
        }
        let name = format!("prefill_b1_t{t}");
        let sig = self.rt.manifest.exe(&name)?.clone();
        let toks = lit_i32(tokens).reshape(&[1, t as i64]).map_err(|e| anyhow!("{e:?}"))?;
        let (rt, wlit) = (&mut self.rt, &self.wlit);
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(sig.params.len());
        for p in &sig.params[..sig.params.len() - 1] {
            inputs.push(Self::wl(wlit, &p.name)?);
        }
        inputs.push(&toks);
        let out = rt.run(&name, &inputs)?;
        Ok((lit_to_tensor(&out[0])?, lit_to_tensor(&out[1])?, lit_to_tensor(&out[2])?))
    }

    /// tokens [b] -> hidden [b, D]. `b` must be a batch bucket.
    pub fn embed(&mut self, tokens: &[i32]) -> Result<Tensor> {
        let b = tokens.len();
        let toks = lit_i32(tokens);
        let (rt, wlit) = (&mut self.rt, &self.wlit);
        let out = rt.run(&format!("embed_b{b}"), &[Self::wl(wlit, "tok_emb")?, &toks])?;
        lit_to_tensor(&out[0])
    }

    /// hidden [b,D], pos [b] -> (q [b,KVH,G,d], k [b,KVH,d], v [b,KVH,d]).
    pub fn qkv(&mut self, layer: usize, hidden: &Tensor, pos: &[i32]) -> Result<(Tensor, Tensor, Tensor)> {
        let b = hidden.shape()[0];
        let h = lit_f32(hidden)?;
        let p = lit_i32(pos);
        let (rt, wlit) = (&mut self.rt, &self.wlit);
        let out = rt.run(
            &format!("qkv_b{b}"),
            &[
                Self::wl(wlit, &format!("ln1.{layer}"))?,
                Self::wl(wlit, &format!("wq.{layer}"))?,
                Self::wl(wlit, &format!("wk.{layer}"))?,
                Self::wl(wlit, &format!("wv.{layer}"))?,
                &h,
                &p,
            ],
        )?;
        Ok((lit_to_tensor(&out[0])?, lit_to_tensor(&out[1])?, lit_to_tensor(&out[2])?))
    }

    /// Full attention over a padded cache `[b, KVH, T, d]` with per-seq
    /// valid `lengths`. Returns ctx `[b, q_dim]`.
    pub fn attn_full(
        &mut self,
        q: &Tensor,
        k_cache: &[f32],
        v_cache: &[f32],
        lengths: &[i32],
    ) -> Result<Tensor> {
        let b = q.shape()[0];
        let (kvh, t, d) = (self.cfg.kv_heads, self.buckets.attn_full_t, self.cfg.d_head);
        let out = self.rt.run(
            &format!("attn_full_b{b}_t{t}"),
            &[
                lit_f32(q)?,
                lit_f32_shaped(k_cache, &[b, kvh, t, d])?,
                lit_f32_shaped(v_cache, &[b, kvh, t, d])?,
                lit_i32(lengths),
            ],
        )?;
        lit_to_tensor(&out[0])
    }

    /// Tripartite wave attention through the L1 Pallas kernel's HLO.
    pub fn attn_wave(&mut self, q: &Tensor, wi: &WaveInputs) -> Result<Tensor> {
        let b = q.shape()[0];
        let (kvh, d) = (self.cfg.kv_heads, self.cfg.d_head);
        let (ne, m) = (self.buckets.wave_ne, self.buckets.wave_m);
        let out = self.rt.run(
            &format!("attn_wave_b{b}"),
            &[
                lit_f32(q)?,
                lit_f32_shaped(&wi.kx, &[b, kvh, ne, d])?,
                lit_f32_shaped(&wi.vx, &[b, kvh, ne, d])?,
                lit_f32_shaped(&wi.kmask, &[b, kvh, ne])?,
                lit_f32_shaped(&wi.cent, &[b, kvh, m, d])?,
                lit_f32_shaped(&wi.vsum, &[b, kvh, m, d])?,
                lit_f32_shaped(&wi.csize, &[b, kvh, m])?,
                lit_f32_shaped(&wi.emask, &[b, kvh, m])?,
            ],
        )?;
        lit_to_tensor(&out[0])
    }

    /// Residual + output projection + FFN.
    pub fn mlp(&mut self, layer: usize, hidden: &Tensor, ctx: &Tensor) -> Result<Tensor> {
        let b = hidden.shape()[0];
        let h = lit_f32(hidden)?;
        let c = lit_f32(ctx)?;
        let (rt, wlit) = (&mut self.rt, &self.wlit);
        let out = rt.run(
            &format!("mlp_b{b}"),
            &[
                Self::wl(wlit, &format!("wo.{layer}"))?,
                Self::wl(wlit, &format!("ln2.{layer}"))?,
                Self::wl(wlit, &format!("w1.{layer}"))?,
                Self::wl(wlit, &format!("w2.{layer}"))?,
                &h,
                &c,
            ],
        )?;
        lit_to_tensor(&out[0])
    }

    /// hidden [b,D] -> logits [b,V].
    pub fn logits(&mut self, hidden: &Tensor) -> Result<Tensor> {
        let b = hidden.shape()[0];
        let h = lit_f32(hidden)?;
        let (rt, wlit) = (&mut self.rt, &self.wlit);
        let out = rt.run(
            &format!("logits_b{b}"),
            &[Self::wl(wlit, "lnf")?, Self::wl(wlit, "unemb")?, &h],
        )?;
        lit_to_tensor(&out[0])
    }

    /// Greedy argmax per row of a logits tensor.
    pub fn greedy(logits: &Tensor) -> Vec<i32> {
        let (b, v) = (logits.shape()[0], logits.shape()[1]);
        (0..b)
            .map(|i| {
                let row = &logits.data()[i * v..(i + 1) * v];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j as i32)
                    .unwrap_or(0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifacts_dir;

    #[test]
    fn embed_qkv_mlp_logits_roundtrip() {
        crate::require_live_path!();
        let mut lm = TinyLm::load(&default_artifacts_dir()).unwrap();
        let hidden = lm.embed(&[5]).unwrap();
        assert_eq!(hidden.shape(), &[1, 256]);
        let (q, k, v) = lm.qkv(0, &hidden, &[0]).unwrap();
        assert_eq!(q.shape(), &[1, 2, 4, 32]);
        assert_eq!(k.shape(), &[1, 2, 32]);
        assert_eq!(v.shape(), &[1, 2, 32]);
        let ctx = Tensor::zeros(&[1, 256]);
        let h2 = lm.mlp(0, &hidden, &ctx).unwrap();
        assert_eq!(h2.shape(), &[1, 256]);
        let lg = lm.logits(&h2).unwrap();
        assert_eq!(lg.shape(), &[1, 256]);
        assert!(lg.data().iter().all(|x| x.is_finite()));
        let g = TinyLm::greedy(&lg);
        assert_eq!(g.len(), 1);
    }

    #[test]
    fn wave_attention_with_single_exact_token_returns_its_value() {
        crate::require_live_path!();
        let mut lm = TinyLm::load(&default_artifacts_dir()).unwrap();
        let (kvh, d) = (lm.cfg.kv_heads, lm.cfg.d_head);
        let (ne, m) = (lm.buckets.wave_ne, lm.buckets.wave_m);
        let mut wi = WaveInputs::zeros(1, kvh, ne, m, d);
        // one valid exact token per head with value = 7.0
        for h in 0..kvh {
            wi.kmask[h * ne] = 1.0;
            for j in 0..d {
                wi.vx[(h * ne) * d + j] = 7.0;
            }
        }
        let q = Tensor::zeros(&[1, kvh, lm.cfg.group(), d]);
        let ctx = lm.attn_wave(&q, &wi).unwrap();
        assert_eq!(ctx.shape(), &[1, 256]);
        for x in ctx.data() {
            assert!((x - 7.0).abs() < 1e-5, "softmax over 1 token = its value, got {x}");
        }
    }

    #[test]
    fn greedy_argmax() {
        let t = Tensor::from_vec(&[2, 3], vec![0.1, 0.9, 0.2, 5.0, 1.0, 2.0]);
        assert_eq!(TinyLm::greedy(&t), vec![1, 0]);
    }
}
