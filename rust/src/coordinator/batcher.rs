//! Continuous batcher: each engine step assembles a decode batch from all
//! sessions in the Decode phase, padded up to the nearest executable
//! batch bucket (vLLM-style iteration-level scheduling). Under
//! multi-tenant load, batch slots are dealt round-robin across tenants so
//! one tenant's decode backlog cannot monopolize every step.

use crate::kvcache::TenantId;
use std::collections::VecDeque;

/// Decode-batch assembly policy.
pub struct Batcher {
    /// Executable batch buckets, ascending (e.g. [1, 2, 4, 8]).
    buckets: Vec<usize>,
    /// Hard cap on concurrent decodes (GPU memory admission).
    max_batch: usize,
}

impl Batcher {
    pub fn new(buckets: &[usize], max_batch: usize) -> Self {
        let mut b = buckets.to_vec();
        b.sort_unstable();
        Batcher { buckets: b, max_batch }
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Pick the sessions to decode this step (oldest first) and the
    /// bucket size to pad to. Returns (chosen ids, bucket).
    pub fn select(&self, decodable: &[u64]) -> Option<(Vec<u64>, usize)> {
        if decodable.is_empty() {
            return None;
        }
        let n = decodable.len().min(self.max_batch).min(*self.buckets.last().unwrap());
        let take: Vec<u64> = decodable[..n].to_vec();
        let bucket = self.buckets.iter().copied().find(|&b| b >= n)?;
        Some((take, bucket))
    }

    /// Tenant-fair selection: when the decodable set spans more than one
    /// tenant, deal batch slots round-robin across tenants (tenants
    /// ordered by first appearance, per-tenant order preserved). With a
    /// single tenant this is exactly [`Batcher::select`].
    pub fn select_by_tenant(
        &self,
        decodable: &[u64],
        tenant_of: impl Fn(u64) -> TenantId,
    ) -> Option<(Vec<u64>, usize)> {
        if decodable.is_empty() {
            return None;
        }
        let mut tenants: Vec<TenantId> = Vec::new();
        let mut per: Vec<VecDeque<u64>> = Vec::new();
        for &id in decodable {
            let t = tenant_of(id);
            match tenants.iter().position(|&x| x == t) {
                Some(i) => per[i].push_back(id),
                None => {
                    tenants.push(t);
                    per.push(VecDeque::new());
                    per.last_mut().unwrap().push_back(id);
                }
            }
        }
        if tenants.len() <= 1 {
            return self.select(decodable);
        }
        let n = decodable.len().min(self.max_batch).min(*self.buckets.last().unwrap());
        let mut take = Vec::with_capacity(n);
        let mut ring = 0usize;
        while take.len() < n {
            if let Some(id) = per[ring].pop_front() {
                take.push(id);
            }
            ring = (ring + 1) % per.len();
        }
        let bucket = self.buckets.iter().copied().find(|&b| b >= n)?;
        Some((take, bucket))
    }

    /// Deadline-slack selection: when more sessions are decodable than
    /// fit one batch, keep the ones closest to violating their TPOT
    /// target instead of a first-come prefix (`slack_of` returns
    /// seconds of slack; `INFINITY` = best-effort, ties broken by queue
    /// order so best-effort traffic still round-robins). The chosen ids
    /// keep their original relative order, so the engine assembles the
    /// batch in admission order exactly as with [`Batcher::select`].
    pub fn select_by_slack(
        &self,
        decodable: &[u64],
        slack_of: impl Fn(u64) -> f64,
    ) -> Option<(Vec<u64>, usize)> {
        if decodable.is_empty() {
            return None;
        }
        let n = decodable.len().min(self.max_batch).min(*self.buckets.last().unwrap());
        if n == decodable.len() {
            return self.select(decodable);
        }
        let mut order: Vec<usize> = (0..decodable.len()).collect();
        order.sort_by(|&a, &b| {
            slack_of(decodable[a]).total_cmp(&slack_of(decodable[b])).then(a.cmp(&b))
        });
        let mut keep = order[..n].to_vec();
        keep.sort_unstable(); // restore admission order
        let take: Vec<u64> = keep.into_iter().map(|i| decodable[i]).collect();
        let bucket = self.buckets.iter().copied().find(|&b| b >= n)?;
        Some((take, bucket))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pads_to_bucket() {
        let b = Batcher::new(&[1, 2, 4, 8], 8);
        let (ids, bucket) = b.select(&[10, 11, 12]).unwrap();
        assert_eq!(ids, vec![10, 11, 12]);
        assert_eq!(bucket, 4);
    }

    #[test]
    fn caps_at_largest_bucket() {
        let b = Batcher::new(&[1, 2, 4, 8], 64);
        let ids: Vec<u64> = (0..20).collect();
        let (take, bucket) = b.select(&ids).unwrap();
        assert_eq!(take.len(), 8);
        assert_eq!(bucket, 8);
    }

    #[test]
    fn respects_admission_cap() {
        let b = Batcher::new(&[1, 2, 4, 8], 2);
        let (take, bucket) = b.select(&[1, 2, 3]).unwrap();
        assert_eq!(take.len(), 2);
        assert_eq!(bucket, 2);
    }

    #[test]
    fn empty_queue_is_none() {
        let b = Batcher::new(&[1, 2], 2);
        assert!(b.select(&[]).is_none());
    }

    #[test]
    fn single_tenant_fair_select_matches_plain() {
        let b = Batcher::new(&[1, 2, 4, 8], 8);
        let ids = [10u64, 11, 12];
        assert_eq!(b.select_by_tenant(&ids, |_| 0), b.select(&ids));
    }

    #[test]
    fn fair_select_interleaves_tenants() {
        let b = Batcher::new(&[1, 2, 4, 8], 4);
        // tenant 0 owns ids 0..5 (older), tenant 1 owns 10..12
        let ids = [0u64, 1, 2, 3, 4, 10, 11, 12];
        let tenant_of = |id: u64| if id < 10 { 0u32 } else { 1 };
        let (take, bucket) = b.select_by_tenant(&ids, tenant_of).unwrap();
        assert_eq!(bucket, 4);
        // slots dealt alternately: tenant 1 gets half the batch despite
        // tenant 0's longer (older) backlog
        assert_eq!(take, vec![0, 10, 1, 11]);
    }

    #[test]
    fn slack_select_prefers_tight_deadlines_in_admission_order() {
        let b = Batcher::new(&[1, 2, 4, 8], 4);
        let ids = [10u64, 11, 12, 13, 14, 15];
        // 13 and 15 are closest to violating; 10 and 12 next
        let slack = |id: u64| match id {
            13 => 0.01,
            15 => 0.02,
            10 => 0.5,
            12 => 0.7,
            _ => f64::INFINITY,
        };
        let (take, bucket) = b.select_by_slack(&ids, slack).unwrap();
        assert_eq!(bucket, 4);
        // least-slack four, in original (admission) order
        assert_eq!(take, vec![10, 12, 13, 15]);
    }

    #[test]
    fn slack_select_without_pressure_matches_plain() {
        let b = Batcher::new(&[1, 2, 4, 8], 8);
        let ids = [10u64, 11, 12];
        assert_eq!(b.select_by_slack(&ids, |_| f64::INFINITY), b.select(&ids));
    }

    #[test]
    fn slack_select_ties_keep_queue_order() {
        let b = Batcher::new(&[1, 2], 2);
        let ids = [5u64, 6, 7];
        // all best-effort: the oldest two ride, exactly like select()
        let (take, _) = b.select_by_slack(&ids, |_| f64::INFINITY).unwrap();
        assert_eq!(take, vec![5, 6]);
    }

    #[test]
    fn fair_select_drains_exhausted_tenant() {
        let b = Batcher::new(&[1, 2, 4, 8], 8);
        let ids = [0u64, 10, 1, 2, 3];
        let tenant_of = |id: u64| if id < 10 { 0u32 } else { 1 };
        let (take, bucket) = b.select_by_tenant(&ids, tenant_of).unwrap();
        assert_eq!(bucket, 8);
        // tenant 1 has one session; after it drains, tenant 0 fills the rest
        assert_eq!(take, vec![0, 10, 1, 2, 3]);
    }
}
