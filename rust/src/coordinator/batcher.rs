//! Continuous batcher: each engine step assembles a decode batch from all
//! sessions in the Decode phase, padded up to the nearest executable
//! batch bucket (vLLM-style iteration-level scheduling).

/// Decode-batch assembly policy.
pub struct Batcher {
    /// Executable batch buckets, ascending (e.g. [1, 2, 4, 8]).
    buckets: Vec<usize>,
    /// Hard cap on concurrent decodes (GPU memory admission).
    max_batch: usize,
}

impl Batcher {
    pub fn new(buckets: &[usize], max_batch: usize) -> Self {
        let mut b = buckets.to_vec();
        b.sort_unstable();
        Batcher { buckets: b, max_batch }
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Pick the sessions to decode this step (oldest first) and the
    /// bucket size to pad to. Returns (chosen ids, bucket).
    pub fn select(&self, decodable: &[u64]) -> Option<(Vec<u64>, usize)> {
        if decodable.is_empty() {
            return None;
        }
        let n = decodable.len().min(self.max_batch).min(*self.buckets.last().unwrap());
        let take: Vec<u64> = decodable[..n].to_vec();
        let bucket = self.buckets.iter().copied().find(|&b| b >= n)?;
        Some((take, bucket))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pads_to_bucket() {
        let b = Batcher::new(&[1, 2, 4, 8], 8);
        let (ids, bucket) = b.select(&[10, 11, 12]).unwrap();
        assert_eq!(ids, vec![10, 11, 12]);
        assert_eq!(bucket, 4);
    }

    #[test]
    fn caps_at_largest_bucket() {
        let b = Batcher::new(&[1, 2, 4, 8], 64);
        let ids: Vec<u64> = (0..20).collect();
        let (take, bucket) = b.select(&ids).unwrap();
        assert_eq!(take.len(), 8);
        assert_eq!(bucket, 8);
    }

    #[test]
    fn respects_admission_cap() {
        let b = Batcher::new(&[1, 2, 4, 8], 2);
        let (take, bucket) = b.select(&[1, 2, 3]).unwrap();
        assert_eq!(take.len(), 2);
        assert_eq!(bucket, 2);
    }

    #[test]
    fn empty_queue_is_none() {
        let b = Batcher::new(&[1, 2], 2);
        assert!(b.select(&[]).is_none());
    }
}
