//! Request router: assigns incoming requests to workers (GPUs or
//! model-parallel groups). Least-loaded with round-robin tie-break —
//! the multi-GPU story of §4.5 (wave index/buffer are per-head modular,
//! so routing is the only cross-GPU coordination needed).
//!
//! Prefix affinity (DESIGN.md §2 "Prefix sharing & CoW"): requests
//! carrying a prefix hash ([`crate::workload::RequestSpec::prefix_hash`])
//! route to the worker already holding that prefix hot, so its sealed
//! blocks and shared GPU cache are reused instead of re-materialized on
//! a second worker. Affinity yields to load balance when the home
//! worker is badly overloaded (the prefix re-homes to the least-loaded
//! worker); requests without a hash fall back to least-loaded.

use std::collections::HashMap;

pub struct Router {
    loads: Vec<usize>,
    rr: usize,
    /// prefix hash → worker currently holding that prefix hot.
    prefix_home: HashMap<u64, usize>,
    /// Workers marked failed ([`Router::mark_down`]): never routed to,
    /// never a steal target, and prefixes homed there re-home on their
    /// next sighting.
    down: Vec<bool>,
    affinity_hits: u64,
    affinity_misses: u64,
    steals: u64,
    rehomed_on_failure: u64,
}

impl Router {
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        Router {
            loads: vec![0; workers],
            rr: 0,
            prefix_home: HashMap::new(),
            down: vec![false; workers],
            affinity_hits: 0,
            affinity_misses: 0,
            steals: 0,
            rehomed_on_failure: 0,
        }
    }

    pub fn workers(&self) -> usize {
        self.loads.len()
    }

    /// Live (not-failed) workers.
    pub fn live_workers(&self) -> usize {
        self.down.iter().filter(|&&d| !d).count()
    }

    pub fn is_down(&self, worker: usize) -> bool {
        self.down[worker]
    }

    /// Mark a worker failed: it stops receiving routes and steal
    /// offers, its load is zeroed (its in-flight requests are lost and
    /// must be re-homed by the caller), and every prefix homed on it
    /// re-homes to a live worker at its next sighting. Panics if this
    /// would down the last live worker.
    pub fn mark_down(&mut self, worker: usize) {
        assert!(
            self.down.iter().enumerate().any(|(w, &d)| w != worker && !d),
            "cannot mark the last live worker down"
        );
        self.down[worker] = true;
        self.loads[worker] = 0;
        // Evict the failed worker's homes eagerly so `prefix_home`
        // never reports a dead worker.
        let dead: Vec<u64> = self
            .prefix_home
            .iter()
            .filter(|&(_, &w)| w == worker)
            .map(|(&p, _)| p)
            .collect();
        self.rehomed_on_failure += dead.len() as u64;
        for p in dead {
            self.prefix_home.remove(&p);
        }
    }

    /// Return a previously-failed worker to service (fresh, empty).
    pub fn mark_up(&mut self, worker: usize) {
        self.down[worker] = false;
        self.loads[worker] = 0;
    }

    fn least_loaded(&mut self) -> usize {
        let min = *self
            .loads
            .iter()
            .zip(&self.down)
            .filter(|(_, &d)| !d)
            .map(|(l, _)| l)
            .min()
            .expect("at least one live worker");
        // round-robin among the least-loaded
        let n = self.loads.len();
        for off in 0..n {
            let w = (self.rr + off) % n;
            if !self.down[w] && self.loads[w] == min {
                self.rr = (w + 1) % n;
                return w;
            }
        }
        unreachable!()
    }

    /// The least-loaded live worker other than `from` — where a
    /// deferred request on `from` should be offered (work stealing), or
    /// where a failed replica's session should recover. `None` when no
    /// other live worker exists. Does not bump loads; call
    /// [`Router::note_stolen`] once the target accepts.
    pub fn steal_target(&self, from: usize) -> Option<usize> {
        (0..self.loads.len())
            .filter(|&w| w != from && !self.down[w])
            .min_by_key(|&w| (self.loads[w], w))
    }

    /// Account a request moved from `from` to `to` (steal or failure
    /// re-home): the load follows the request.
    pub fn note_stolen(&mut self, from: usize, to: usize) {
        if !self.down[from] {
            self.loads[from] = self.loads[from].saturating_sub(1);
        }
        self.loads[to] += 1;
        self.steals += 1;
    }

    /// Requests moved off their routed worker (steals + failure
    /// re-homes accounted through [`Router::note_stolen`]).
    pub fn steals(&self) -> u64 {
        self.steals
    }

    /// Prefix homes evicted because their worker failed.
    pub fn rehomed_on_failure(&self) -> u64 {
        self.rehomed_on_failure
    }

    /// Route one request; returns the worker index.
    pub fn route(&mut self) -> usize {
        self.route_with_prefix(None)
    }

    /// Route one request with an optional prefix-affinity hint. A known
    /// prefix routes to its home worker (affinity hit) unless that
    /// worker's load exceeds the cluster minimum by more than one slot
    /// per worker, in which case the prefix re-homes to the
    /// least-loaded worker (counted as a miss). An unknown prefix homes
    /// on the least-loaded worker (affinity miss).
    pub fn route_with_prefix(&mut self, prefix: Option<u64>) -> usize {
        let Some(p) = prefix else {
            let w = self.least_loaded();
            self.loads[w] += 1;
            return w;
        };
        if let Some(&home) = self.prefix_home.get(&p) {
            let min = *self
                .loads
                .iter()
                .zip(&self.down)
                .filter(|(_, &d)| !d)
                .map(|(l, _)| l)
                .min()
                .expect("at least one live worker");
            if !self.down[home] && self.loads[home] <= min + self.loads.len() {
                self.affinity_hits += 1;
                self.loads[home] += 1;
                return home;
            }
        }
        let w = self.least_loaded();
        self.affinity_misses += 1;
        self.prefix_home.insert(p, w);
        self.loads[w] += 1;
        w
    }

    /// Mark a request on `worker` complete.
    pub fn complete(&mut self, worker: usize) {
        self.loads[worker] = self.loads[worker].saturating_sub(1);
    }

    pub fn load(&self, worker: usize) -> usize {
        self.loads[worker]
    }

    /// Requests routed to a prefix's home worker.
    pub fn affinity_hits(&self) -> u64 {
        self.affinity_hits
    }

    /// Prefix-carrying requests that had no (usable) home yet.
    pub fn affinity_misses(&self) -> u64 {
        self.affinity_misses
    }

    /// The worker currently homing a prefix, if any.
    pub fn prefix_home(&self, prefix: u64) -> Option<usize> {
        self.prefix_home.get(&prefix).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balances_evenly() {
        let mut r = Router::new(4);
        for _ in 0..8 {
            r.route();
        }
        for w in 0..4 {
            assert_eq!(r.load(w), 2);
        }
    }

    #[test]
    fn prefers_least_loaded_after_completion() {
        let mut r = Router::new(2);
        let a = r.route();
        let _b = r.route();
        r.complete(a);
        assert_eq!(r.route(), a, "freed worker gets the next request");
    }

    #[test]
    fn single_worker() {
        let mut r = Router::new(1);
        assert_eq!(r.route(), 0);
        assert_eq!(r.route(), 0);
        assert_eq!(r.load(0), 2);
    }

    #[test]
    fn prefix_affinity_sticks_to_the_home_worker() {
        let mut r = Router::new(3);
        let w0 = r.route_with_prefix(Some(7));
        assert_eq!(r.affinity_misses(), 1, "first sight homes the prefix");
        // later requests with the same prefix follow, despite other
        // workers being idle
        for _ in 0..2 {
            assert_eq!(r.route_with_prefix(Some(7)), w0);
        }
        assert_eq!(r.affinity_hits(), 2);
        assert_eq!(r.load(w0), 3);
        // a different prefix homes elsewhere (least-loaded)
        let w1 = r.route_with_prefix(Some(9));
        assert_ne!(w1, w0);
        assert_eq!(r.prefix_home(9), Some(w1));
        // hash-less requests keep balancing
        let w2 = r.route_with_prefix(None);
        assert_ne!(w2, w0);
        assert_ne!(w2, w1);
    }

    #[test]
    fn downed_worker_never_routed_and_prefixes_rehome() {
        let mut r = Router::new(3);
        let w0 = r.route_with_prefix(Some(7));
        r.mark_down(w0);
        assert!(r.is_down(w0));
        assert_eq!(r.live_workers(), 2);
        assert_eq!(r.prefix_home(7), None, "failed home must be evicted");
        assert_eq!(r.rehomed_on_failure(), 1);
        for _ in 0..6 {
            let w = r.route_with_prefix(Some(7));
            assert_ne!(w, w0, "routed to a failed worker");
        }
        // the prefix has a new (live) home
        let home = r.prefix_home(7).expect("rehomed");
        assert_ne!(home, w0);
        // recovery: the worker returns empty and is routable again
        r.mark_up(w0);
        assert_eq!(r.load(w0), 0);
        assert!((0..12).any(|_| r.route() == w0));
    }

    #[test]
    fn steal_target_is_least_loaded_live_peer() {
        let mut r = Router::new(3);
        // load worker 0 heavily, worker 2 lightly
        for _ in 0..4 {
            let w = r.route();
            let _ = w;
        }
        // loads now ~[2,1,1]; steal from 0 goes to 1 (tie → lowest id)
        let t = r.steal_target(0).unwrap();
        assert_ne!(t, 0);
        let before_from = r.load(0);
        let before_to = r.load(t);
        r.note_stolen(0, t);
        assert_eq!(r.load(0), before_from - 1);
        assert_eq!(r.load(t), before_to + 1);
        assert_eq!(r.steals(), 1);
        // a downed peer is never a steal target
        r.mark_down(t);
        let t2 = r.steal_target(0).unwrap();
        assert_ne!(t2, t);
        // no live peer → no target
        r.mark_down(t2);
        assert_eq!(r.steal_target(0), None);
    }

    #[test]
    #[should_panic(expected = "last live worker")]
    fn cannot_down_the_last_live_worker() {
        let mut r = Router::new(2);
        r.mark_down(0);
        r.mark_down(1);
    }

    #[test]
    fn overloaded_home_rehomes_the_prefix() {
        let mut r = Router::new(2);
        let w0 = r.route_with_prefix(Some(1));
        // a pure burst of one prefix must eventually spill off its home
        // (load exceeds the idle worker's by more than one slot/worker)
        let mut rehomed = None;
        for _ in 0..8 {
            let w = r.route_with_prefix(Some(1));
            if w != w0 {
                rehomed = Some(w);
                break;
            }
        }
        let w1 = rehomed.expect("a hot home must yield to load balance");
        assert_eq!(r.prefix_home(1), Some(w1), "the prefix re-homes");
        assert!(r.affinity_hits() >= 1);
        assert_eq!(r.affinity_misses(), 2, "the re-home counts as a miss");
    }
}
