//! Request router: assigns incoming requests to workers (GPUs or
//! model-parallel groups). Least-loaded with round-robin tie-break —
//! the multi-GPU story of §4.5 (wave index/buffer are per-head modular,
//! so routing is the only cross-GPU coordination needed).

pub struct Router {
    loads: Vec<usize>,
    rr: usize,
}

impl Router {
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        Router { loads: vec![0; workers], rr: 0 }
    }

    pub fn workers(&self) -> usize {
        self.loads.len()
    }

    /// Route one request; returns the worker index.
    pub fn route(&mut self) -> usize {
        let min = *self.loads.iter().min().unwrap();
        // round-robin among the least-loaded
        let n = self.loads.len();
        for off in 0..n {
            let w = (self.rr + off) % n;
            if self.loads[w] == min {
                self.rr = (w + 1) % n;
                self.loads[w] += 1;
                return w;
            }
        }
        unreachable!()
    }

    /// Mark a request on `worker` complete.
    pub fn complete(&mut self, worker: usize) {
        self.loads[worker] = self.loads[worker].saturating_sub(1);
    }

    pub fn load(&self, worker: usize) -> usize {
        self.loads[worker]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balances_evenly() {
        let mut r = Router::new(4);
        for _ in 0..8 {
            r.route();
        }
        for w in 0..4 {
            assert_eq!(r.load(w), 2);
        }
    }

    #[test]
    fn prefers_least_loaded_after_completion() {
        let mut r = Router::new(2);
        let a = r.route();
        let _b = r.route();
        r.complete(a);
        assert_eq!(r.route(), a, "freed worker gets the next request");
    }

    #[test]
    fn single_worker() {
        let mut r = Router::new(1);
        assert_eq!(r.route(), 0);
        assert_eq!(r.route(), 0);
        assert_eq!(r.load(0), 2);
    }
}
