//! Request router: assigns incoming requests to workers (GPUs or
//! model-parallel groups). Least-loaded with round-robin tie-break —
//! the multi-GPU story of §4.5 (wave index/buffer are per-head modular,
//! so routing is the only cross-GPU coordination needed).
//!
//! Prefix affinity (DESIGN.md §2 "Prefix sharing & CoW"): requests
//! carrying a prefix hash ([`crate::workload::RequestSpec::prefix_hash`])
//! route to the worker already holding that prefix hot, so its sealed
//! blocks and shared GPU cache are reused instead of re-materialized on
//! a second worker. Affinity yields to load balance when the home
//! worker is badly overloaded (the prefix re-homes to the least-loaded
//! worker); requests without a hash fall back to least-loaded.

use std::collections::HashMap;

pub struct Router {
    loads: Vec<usize>,
    rr: usize,
    /// prefix hash → worker currently holding that prefix hot.
    prefix_home: HashMap<u64, usize>,
    affinity_hits: u64,
    affinity_misses: u64,
}

impl Router {
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        Router {
            loads: vec![0; workers],
            rr: 0,
            prefix_home: HashMap::new(),
            affinity_hits: 0,
            affinity_misses: 0,
        }
    }

    pub fn workers(&self) -> usize {
        self.loads.len()
    }

    fn least_loaded(&mut self) -> usize {
        let min = *self.loads.iter().min().unwrap();
        // round-robin among the least-loaded
        let n = self.loads.len();
        for off in 0..n {
            let w = (self.rr + off) % n;
            if self.loads[w] == min {
                self.rr = (w + 1) % n;
                return w;
            }
        }
        unreachable!()
    }

    /// Route one request; returns the worker index.
    pub fn route(&mut self) -> usize {
        self.route_with_prefix(None)
    }

    /// Route one request with an optional prefix-affinity hint. A known
    /// prefix routes to its home worker (affinity hit) unless that
    /// worker's load exceeds the cluster minimum by more than one slot
    /// per worker, in which case the prefix re-homes to the
    /// least-loaded worker (counted as a miss). An unknown prefix homes
    /// on the least-loaded worker (affinity miss).
    pub fn route_with_prefix(&mut self, prefix: Option<u64>) -> usize {
        let Some(p) = prefix else {
            let w = self.least_loaded();
            self.loads[w] += 1;
            return w;
        };
        if let Some(&home) = self.prefix_home.get(&p) {
            let min = *self.loads.iter().min().unwrap();
            if self.loads[home] <= min + self.loads.len() {
                self.affinity_hits += 1;
                self.loads[home] += 1;
                return home;
            }
        }
        let w = self.least_loaded();
        self.affinity_misses += 1;
        self.prefix_home.insert(p, w);
        self.loads[w] += 1;
        w
    }

    /// Mark a request on `worker` complete.
    pub fn complete(&mut self, worker: usize) {
        self.loads[worker] = self.loads[worker].saturating_sub(1);
    }

    pub fn load(&self, worker: usize) -> usize {
        self.loads[worker]
    }

    /// Requests routed to a prefix's home worker.
    pub fn affinity_hits(&self) -> u64 {
        self.affinity_hits
    }

    /// Prefix-carrying requests that had no (usable) home yet.
    pub fn affinity_misses(&self) -> u64 {
        self.affinity_misses
    }

    /// The worker currently homing a prefix, if any.
    pub fn prefix_home(&self, prefix: u64) -> Option<usize> {
        self.prefix_home.get(&prefix).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balances_evenly() {
        let mut r = Router::new(4);
        for _ in 0..8 {
            r.route();
        }
        for w in 0..4 {
            assert_eq!(r.load(w), 2);
        }
    }

    #[test]
    fn prefers_least_loaded_after_completion() {
        let mut r = Router::new(2);
        let a = r.route();
        let _b = r.route();
        r.complete(a);
        assert_eq!(r.route(), a, "freed worker gets the next request");
    }

    #[test]
    fn single_worker() {
        let mut r = Router::new(1);
        assert_eq!(r.route(), 0);
        assert_eq!(r.route(), 0);
        assert_eq!(r.load(0), 2);
    }

    #[test]
    fn prefix_affinity_sticks_to_the_home_worker() {
        let mut r = Router::new(3);
        let w0 = r.route_with_prefix(Some(7));
        assert_eq!(r.affinity_misses(), 1, "first sight homes the prefix");
        // later requests with the same prefix follow, despite other
        // workers being idle
        for _ in 0..2 {
            assert_eq!(r.route_with_prefix(Some(7)), w0);
        }
        assert_eq!(r.affinity_hits(), 2);
        assert_eq!(r.load(w0), 3);
        // a different prefix homes elsewhere (least-loaded)
        let w1 = r.route_with_prefix(Some(9));
        assert_ne!(w1, w0);
        assert_eq!(r.prefix_home(9), Some(w1));
        // hash-less requests keep balancing
        let w2 = r.route_with_prefix(None);
        assert_ne!(w2, w0);
        assert_ne!(w2, w1);
    }

    #[test]
    fn overloaded_home_rehomes_the_prefix() {
        let mut r = Router::new(2);
        let w0 = r.route_with_prefix(Some(1));
        // a pure burst of one prefix must eventually spill off its home
        // (load exceeds the idle worker's by more than one slot/worker)
        let mut rehomed = None;
        for _ in 0..8 {
            let w = r.route_with_prefix(Some(1));
            if w != w0 {
                rehomed = Some(w);
                break;
            }
        }
        let w1 = rehomed.expect("a hot home must yield to load balance");
        assert_eq!(r.prefix_home(1), Some(w1), "the prefix re-homes");
        assert!(r.affinity_hits() >= 1);
        assert_eq!(r.affinity_misses(), 2, "the re-home counts as a miss");
    }
}
