//! L3 coordinator: request lifecycle, routing, continuous batching and
//! prefill/decode scheduling (the serving-side contribution that wraps
//! the wave index / wave buffer, per the paper's system integration) —
//! plus admission control that gates prefills on the KV arena's
//! capacity and per-tenant quotas (DESIGN.md §2 "Admission & quotas").

pub mod batcher;
pub mod request;
pub mod router;
pub mod scheduler;

pub use batcher::Batcher;
pub use request::{Phase, Request, Session};
pub use router::Router;
pub use scheduler::{Action, AdmissionConfig, Scheduler, SloPolicy, StepPlan};
