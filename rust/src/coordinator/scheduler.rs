//! Prefill/decode scheduler: owns the session table and decides, each
//! engine iteration, whether to run a prefill (one prompt at a time —
//! prefill saturates the device) or a decode batch (continuous batching).
//! Decode-first keeps time-to-next-token low once requests are admitted;
//! queued prefills run when the decode pool is below the admission cap.

use super::batcher::Batcher;
use super::request::{Phase, Request, Session};
use std::collections::HashMap;

/// What the engine should run next.
#[derive(Clone, Debug, PartialEq)]
pub enum Action {
    Prefill(u64),
    DecodeBatch(Vec<u64>, usize),
    Idle,
}

pub struct Scheduler {
    sessions: HashMap<u64, Session>,
    queue: Vec<u64>,
    batcher: Batcher,
    /// Decode-phase sessions kept sorted by (admit_s, id) — maintained
    /// incrementally on phase transitions instead of re-collected and
    /// re-sorted on every engine iteration.
    decode_order: Vec<u64>,
    /// Sessions that reached Done since the last `take_finished` —
    /// drained by the serving loop into engine reclamation
    /// (`LiveEngine::finish_session`).
    finished: Vec<u64>,
}

impl Scheduler {
    pub fn new(batcher: Batcher) -> Self {
        Scheduler {
            sessions: HashMap::new(),
            queue: Vec::new(),
            batcher,
            decode_order: Vec::new(),
            finished: Vec::new(),
        }
    }

    pub fn submit(&mut self, req: Request, now_s: f64) {
        let id = req.id;
        let mut s = Session::new(req);
        s.admit_s = now_s;
        self.sessions.insert(id, s);
        self.queue.push(id);
    }

    pub fn session(&self, id: u64) -> Option<&Session> {
        self.sessions.get(&id)
    }

    pub fn session_mut(&mut self, id: u64) -> Option<&mut Session> {
        self.sessions.get_mut(&id)
    }

    /// Sessions currently decoding, oldest admission first (the
    /// incrementally-maintained sorted buffer).
    pub fn decodable(&self) -> &[u64] {
        &self.decode_order
    }

    /// Insert `id` into the sorted decode buffer.
    fn enter_decode(&mut self, id: u64) {
        let key = (self.sessions[&id].admit_s, id);
        let pos = self.decode_order.partition_point(|&o| (self.sessions[&o].admit_s, o) < key);
        self.decode_order.insert(pos, id);
    }

    /// Remove `id` from the sorted decode buffer (no-op if absent).
    fn leave_decode(&mut self, id: u64) {
        if let Some(p) = self.decode_order.iter().position(|&x| x == id) {
            self.decode_order.remove(p);
        }
    }

    /// Next action. Decode runs whenever a full-enough batch exists or no
    /// prefill is queued; prefill admits new work when the decode pool
    /// has headroom.
    pub fn next_action(&mut self) -> Action {
        let queued = self.queue.first().copied();
        match queued {
            Some(id) if self.decode_order.len() < self.batcher.max_batch() => {
                self.queue.remove(0);
                self.sessions.get_mut(&id).unwrap().phase = Phase::Prefill;
                Action::Prefill(id)
            }
            _ => match self.batcher.select(&self.decode_order) {
                Some((ids, bucket)) => Action::DecodeBatch(ids, bucket),
                None => Action::Idle,
            },
        }
    }

    /// Mark prefill complete (first token produced).
    pub fn prefill_done(&mut self, id: u64, first_token: i32, now_s: f64) {
        let s = self.sessions.get_mut(&id).unwrap();
        s.phase = Phase::Decode;
        s.generated.push(first_token);
        s.first_token_s = now_s;
        if s.finished() {
            s.phase = Phase::Done;
            s.done_s = now_s;
            self.finished.push(id);
        } else {
            self.enter_decode(id);
        }
    }

    /// Record one decoded token; completes the session at max_new.
    pub fn token_decoded(&mut self, id: u64, token: i32, now_s: f64) {
        let s = self.sessions.get_mut(&id).unwrap();
        s.generated.push(token);
        if s.finished() {
            s.phase = Phase::Done;
            s.done_s = now_s;
            self.leave_decode(id);
            self.finished.push(id);
        }
    }

    /// Drain the session-finished events accumulated since the last
    /// call. The serving loop feeds these into engine reclamation so
    /// finished sessions return their KV blocks to the arena.
    pub fn take_finished(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.finished)
    }

    pub fn all_done(&self) -> bool {
        self.queue.is_empty() && self.sessions.values().all(|s| s.phase == Phase::Done)
    }

    pub fn sessions(&self) -> impl Iterator<Item = &Session> {
        self.sessions.values()
    }

    pub fn n_decoding(&self) -> usize {
        self.decode_order.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(max_batch: usize) -> Scheduler {
        Scheduler::new(Batcher::new(&[1, 2, 4, 8], max_batch))
    }

    #[test]
    fn prefill_then_decode_then_done() {
        let mut s = sched(4);
        s.submit(Request::new(1, vec![1, 2], 2), 0.0);
        assert_eq!(s.next_action(), Action::Prefill(1));
        s.prefill_done(1, 42, 0.1);
        assert_eq!(s.next_action(), Action::DecodeBatch(vec![1], 1));
        s.token_decoded(1, 43, 0.2);
        assert!(s.all_done());
        assert_eq!(s.session(1).unwrap().generated, vec![42, 43]);
        assert_eq!(s.next_action(), Action::Idle);
    }

    #[test]
    fn admission_cap_defers_prefill() {
        let mut s = sched(2);
        for id in 1..=3 {
            s.submit(Request::new(id, vec![1], 10), 0.0);
        }
        // two prefills admitted
        assert_eq!(s.next_action(), Action::Prefill(1));
        s.prefill_done(1, 0, 0.0);
        assert_eq!(s.next_action(), Action::Prefill(2));
        s.prefill_done(2, 0, 0.0);
        // pool full: third prefill deferred, decode batch runs
        match s.next_action() {
            Action::DecodeBatch(ids, bucket) => {
                assert_eq!(ids, vec![1, 2]);
                assert_eq!(bucket, 2);
            }
            a => panic!("expected decode, got {a:?}"),
        }
    }

    #[test]
    fn continuous_batching_admits_mid_flight() {
        let mut s = sched(8);
        s.submit(Request::new(1, vec![1], 5), 0.0);
        assert_eq!(s.next_action(), Action::Prefill(1));
        s.prefill_done(1, 0, 0.0);
        // a new request arrives while 1 decodes
        s.submit(Request::new(2, vec![1], 5), 0.1);
        assert_eq!(s.next_action(), Action::Prefill(2));
        s.prefill_done(2, 0, 0.2);
        match s.next_action() {
            Action::DecodeBatch(ids, _) => assert_eq!(ids, vec![1, 2]),
            a => panic!("{a:?}"),
        }
    }

    #[test]
    fn finishing_at_prefill_token() {
        let mut s = sched(2);
        s.submit(Request::new(7, vec![1], 1), 0.0);
        assert_eq!(s.next_action(), Action::Prefill(7));
        s.prefill_done(7, 9, 0.5);
        assert!(s.all_done());
        assert_eq!(s.session(7).unwrap().phase, Phase::Done);
        // a session that finishes at its prefill token still emits a
        // finished event and never enters the decode buffer
        assert_eq!(s.take_finished(), vec![7]);
        assert!(s.decodable().is_empty());
    }

    #[test]
    fn decode_buffer_stays_sorted_by_admission() {
        let mut s = sched(8);
        // admit out of id order: id 5 first, then 2, then 9
        for (id, at) in [(5u64, 0.0), (2, 1.0), (9, 2.0)] {
            s.submit(Request::new(id, vec![1], 10), at);
            assert_eq!(s.next_action(), Action::Prefill(id));
            s.prefill_done(id, 0, at);
        }
        assert_eq!(s.decodable(), &[5, 2, 9]);
        assert_eq!(s.n_decoding(), 3);
        // finishing the middle session removes it in place
        for _ in 0..10 {
            s.token_decoded(2, 1, 3.0);
        }
        assert_eq!(s.decodable(), &[5, 9]);
        assert_eq!(s.take_finished(), vec![2]);
        assert!(s.take_finished().is_empty(), "events drain exactly once");
    }

    #[test]
    fn finished_events_cover_every_session() {
        let mut s = sched(4);
        for id in 0..3u64 {
            s.submit(Request::new(id, vec![1], 2), 0.0);
        }
        let mut finished = Vec::new();
        let mut guard = 0;
        while !s.all_done() {
            guard += 1;
            assert!(guard < 1000);
            match s.next_action() {
                Action::Prefill(id) => s.prefill_done(id, 0, 0.1),
                Action::DecodeBatch(ids, _) => {
                    for id in ids {
                        s.token_decoded(id, 1, 0.2);
                    }
                }
                Action::Idle => break,
            }
            finished.extend(s.take_finished());
        }
        finished.sort_unstable();
        assert_eq!(finished, vec![0, 1, 2]);
    }
}
