//! Prefill/decode scheduler: owns the session table and decides, each
//! engine iteration, whether to run a prefill (one prompt at a time —
//! prefill saturates the device) or a decode batch (continuous batching).
//! Decode-first keeps time-to-next-token low once requests are admitted;
//! queued prefills run when the decode pool is below the admission cap.

use super::batcher::Batcher;
use super::request::{Phase, Request, Session};
use std::collections::HashMap;

/// What the engine should run next.
#[derive(Clone, Debug, PartialEq)]
pub enum Action {
    Prefill(u64),
    DecodeBatch(Vec<u64>, usize),
    Idle,
}

pub struct Scheduler {
    sessions: HashMap<u64, Session>,
    queue: Vec<u64>,
    batcher: Batcher,
}

impl Scheduler {
    pub fn new(batcher: Batcher) -> Self {
        Scheduler { sessions: HashMap::new(), queue: Vec::new(), batcher }
    }

    pub fn submit(&mut self, req: Request, now_s: f64) {
        let id = req.id;
        let mut s = Session::new(req);
        s.admit_s = now_s;
        self.sessions.insert(id, s);
        self.queue.push(id);
    }

    pub fn session(&self, id: u64) -> Option<&Session> {
        self.sessions.get(&id)
    }

    pub fn session_mut(&mut self, id: u64) -> Option<&mut Session> {
        self.sessions.get_mut(&id)
    }

    /// Sessions currently decoding, oldest admission first.
    fn decodable(&self) -> Vec<u64> {
        let mut v: Vec<u64> = self
            .sessions
            .values()
            .filter(|s| s.phase == Phase::Decode)
            .map(|s| s.req.id)
            .collect();
        v.sort_by(|a, b| {
            let (sa, sb) = (&self.sessions[a], &self.sessions[b]);
            sa.admit_s.partial_cmp(&sb.admit_s).unwrap().then(a.cmp(b))
        });
        v
    }

    /// Next action. Decode runs whenever a full-enough batch exists or no
    /// prefill is queued; prefill admits new work when the decode pool
    /// has headroom.
    pub fn next_action(&mut self) -> Action {
        let decoding = self.decodable();
        let queued = self.queue.first().copied();
        match queued {
            Some(id) if decoding.len() < self.batcher.max_batch() => {
                self.queue.remove(0);
                self.sessions.get_mut(&id).unwrap().phase = Phase::Prefill;
                Action::Prefill(id)
            }
            _ => match self.batcher.select(&decoding) {
                Some((ids, bucket)) => Action::DecodeBatch(ids, bucket),
                None => Action::Idle,
            },
        }
    }

    /// Mark prefill complete (first token produced).
    pub fn prefill_done(&mut self, id: u64, first_token: i32, now_s: f64) {
        let s = self.sessions.get_mut(&id).unwrap();
        s.phase = Phase::Decode;
        s.generated.push(first_token);
        s.first_token_s = now_s;
        if s.finished() {
            s.phase = Phase::Done;
            s.done_s = now_s;
        }
    }

    /// Record one decoded token; completes the session at max_new.
    pub fn token_decoded(&mut self, id: u64, token: i32, now_s: f64) {
        let s = self.sessions.get_mut(&id).unwrap();
        s.generated.push(token);
        if s.finished() {
            s.phase = Phase::Done;
            s.done_s = now_s;
        }
    }

    pub fn all_done(&self) -> bool {
        self.queue.is_empty() && self.sessions.values().all(|s| s.phase == Phase::Done)
    }

    pub fn sessions(&self) -> impl Iterator<Item = &Session> {
        self.sessions.values()
    }

    pub fn n_decoding(&self) -> usize {
        self.sessions.values().filter(|s| s.phase == Phase::Decode).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(max_batch: usize) -> Scheduler {
        Scheduler::new(Batcher::new(&[1, 2, 4, 8], max_batch))
    }

    #[test]
    fn prefill_then_decode_then_done() {
        let mut s = sched(4);
        s.submit(Request::new(1, vec![1, 2], 2), 0.0);
        assert_eq!(s.next_action(), Action::Prefill(1));
        s.prefill_done(1, 42, 0.1);
        assert_eq!(s.next_action(), Action::DecodeBatch(vec![1], 1));
        s.token_decoded(1, 43, 0.2);
        assert!(s.all_done());
        assert_eq!(s.session(1).unwrap().generated, vec![42, 43]);
        assert_eq!(s.next_action(), Action::Idle);
    }

    #[test]
    fn admission_cap_defers_prefill() {
        let mut s = sched(2);
        for id in 1..=3 {
            s.submit(Request::new(id, vec![1], 10), 0.0);
        }
        // two prefills admitted
        assert_eq!(s.next_action(), Action::Prefill(1));
        s.prefill_done(1, 0, 0.0);
        assert_eq!(s.next_action(), Action::Prefill(2));
        s.prefill_done(2, 0, 0.0);
        // pool full: third prefill deferred, decode batch runs
        match s.next_action() {
            Action::DecodeBatch(ids, bucket) => {
                assert_eq!(ids, vec![1, 2]);
                assert_eq!(bucket, 2);
            }
            a => panic!("expected decode, got {a:?}"),
        }
    }

    #[test]
    fn continuous_batching_admits_mid_flight() {
        let mut s = sched(8);
        s.submit(Request::new(1, vec![1], 5), 0.0);
        assert_eq!(s.next_action(), Action::Prefill(1));
        s.prefill_done(1, 0, 0.0);
        // a new request arrives while 1 decodes
        s.submit(Request::new(2, vec![1], 5), 0.1);
        assert_eq!(s.next_action(), Action::Prefill(2));
        s.prefill_done(2, 0, 0.2);
        match s.next_action() {
            Action::DecodeBatch(ids, _) => assert_eq!(ids, vec![1, 2]),
            a => panic!("{a:?}"),
        }
    }

    #[test]
    fn finishing_at_prefill_token() {
        let mut s = sched(2);
        s.submit(Request::new(7, vec![1], 1), 0.0);
        assert_eq!(s.next_action(), Action::Prefill(7));
        s.prefill_done(7, 9, 0.5);
        assert!(s.all_done());
        assert_eq!(s.session(7).unwrap().phase, Phase::Done);
    }
}
