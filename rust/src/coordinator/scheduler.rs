//! Prefill/decode scheduler: owns the session table and decides, each
//! engine iteration, whether to run a prefill (one prompt at a time —
//! prefill saturates the device) or a decode batch (continuous batching).
//! Decode-first keeps time-to-next-token low once requests are admitted;
//! queued prefills run when the decode pool is below the admission cap.
//!
//! Admission control (DESIGN.md §2 "Admission & quotas"): when the
//! scheduler is built with an arena + [`AdmissionConfig`], every queued
//! prefill passes a gate before it is released. The gate estimates the
//! prompt's KV block footprint and defers the prefill
//! ([`Action::Defer`]) while the arena is too full to hold it — the
//! request stays at the head of its tenant's queue and is re-examined on
//! every call, so reclamation (`take_finished` → engine
//! `finish_session`) automatically re-admits it. Requests whose
//! footprint can never fit (estimate exceeds usable capacity or the
//! tenant quota) are rejected up-front instead of deadlocking the queue.
//! Queues are per-tenant and served round-robin, so one tenant's backlog
//! cannot starve the rest.

use super::batcher::Batcher;
use super::request::{Phase, Request, Session};
use crate::kvcache::{BlockArena, PrefixRegistry, TenantId};
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

/// What the engine should run next.
#[derive(Clone, Debug, PartialEq)]
pub enum Action {
    Prefill(u64),
    DecodeBatch(Vec<u64>, usize),
    /// Queued prefills exist but none fits the arena right now; the
    /// serving loop should keep draining finished sessions (reclamation
    /// frees capacity) and call again.
    Defer,
    Idle,
}

/// Parameters of the admission gate's block-footprint estimate.
#[derive(Clone, Debug, PartialEq)]
pub struct AdmissionConfig {
    /// KV stores per session: `n_layers × kv_heads`.
    pub heads: usize,
    /// The arena's block geometry.
    pub tokens_per_block: usize,
    /// Fraction of the arena capacity held back from admission so
    /// decode-time appends of already-admitted sessions cannot hit the
    /// cap.
    pub headroom_frac: f64,
    /// Multiplier on the analytic `heads × ceil(T / tpb)` estimate,
    /// covering cluster tail-block fragmentation (clusters never share
    /// blocks) and decode-time update segments.
    pub est_fudge: f64,
    /// The arena behind the gate is tiered (cold spill enabled): a full
    /// hot tier means the engine demotes and retries, so occupancy
    /// never defers admission — the cold tier absorbs the overflow and
    /// only the batcher's slot count paces new work. This is the
    /// "demote, then retry, before defer" change of meaning for
    /// `ArenaFull` (DESIGN.md §2 "Tiered arena & spill").
    pub tiered: bool,
}

impl AdmissionConfig {
    /// Estimated arena blocks a session with `context_tokens` of
    /// lifetime context will occupy. Callers pass `prompt + max_new` so
    /// the estimate covers decode-time growth too — a session admitted
    /// flush against its tenant quota must still be able to finish.
    pub fn estimate_blocks(&self, context_tokens: usize) -> usize {
        let per_head = context_tokens.div_ceil(self.tokens_per_block.max(1)).max(1);
        ((self.heads.max(1) * per_head) as f64 * self.est_fudge).ceil() as usize
    }
}

/// Gate verdict for one queued prefill.
enum Gate {
    Admit,
    Defer,
    Reject,
}

/// Timing model behind SLO-aware step planning ([`Scheduler::next_plan`]):
/// how long one prefill chunk and one decode step cost, used to convert
/// TTFT/TPOT slack into a per-step chunk budget.
#[derive(Clone, Debug, PartialEq)]
pub struct SloPolicy {
    /// Tokens fed per prefill chunk (`LiveEngine::prefill_advance` chunk
    /// size).
    pub chunk_tokens: usize,
    /// Estimated wall time of one prefill chunk.
    pub chunk_s: f64,
    /// Estimated wall time of one decode step.
    pub decode_step_s: f64,
    /// Hard cap on prefill chunks per engine step regardless of slack.
    pub max_chunks_per_step: usize,
}

impl Default for SloPolicy {
    fn default() -> Self {
        SloPolicy {
            chunk_tokens: 512,
            chunk_s: 0.01,
            decode_step_s: 0.005,
            max_chunks_per_step: 8,
        }
    }
}

/// One engine step as planned by [`Scheduler::next_plan`]: which parked
/// session to revive, which decoding session to demote, which queued
/// prompts start prefilling, how many prefill chunks ride along with the
/// decode batch, and the decode batch itself. The caller applies the
/// plan through the transition methods (`prefill_started`, `chunk_done`,
/// `preempted`, `resumed`, `prefill_done`, `token_decoded`); planning
/// itself only mutates on outright rejection.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StepPlan {
    /// Parked (preempted) sessions to promote back into decode — at most
    /// one per plan, and only when the step saw no capacity pressure.
    pub resume: Vec<u64>,
    /// Decoding sessions to demote to the cold tier — at most one per
    /// plan, chosen when an SLO-carrying queued request is capacity-
    /// deferred and a strictly-lower-priority decode exists.
    pub preempt: Vec<u64>,
    /// Queued requests whose prefill should begin this step.
    pub start_prefill: Vec<u64>,
    /// Prefill chunks to feed this step, earliest TTFT deadline first;
    /// an id appears once per chunk, so repeats mean "advance this job
    /// several chunks".
    pub chunks: Vec<u64>,
    /// Decode batch for this step (empty = no decode).
    pub decode: Vec<u64>,
    /// Bucket the decode batch pads to (0 when `decode` is empty).
    pub bucket: usize,
}

impl StepPlan {
    pub fn is_idle(&self) -> bool {
        self.resume.is_empty()
            && self.preempt.is_empty()
            && self.start_prefill.is_empty()
            && self.chunks.is_empty()
            && self.decode.is_empty()
    }
}

pub struct Scheduler {
    sessions: HashMap<u64, Session>,
    /// Per-tenant FIFO queues (tenants in first-submit order), served
    /// round-robin by `next_action`.
    queues: Vec<(TenantId, VecDeque<u64>)>,
    /// Round-robin cursor: index into `queues` of the next tenant to
    /// consider for prefill admission.
    rr: usize,
    batcher: Batcher,
    /// Admission gate state (None = admit everything, the single-tenant
    /// dev default).
    arena: Option<Arc<BlockArena>>,
    admission: Option<AdmissionConfig>,
    /// Prefix registry for footprint discounts: tokens served from a
    /// registered shared prefix are resident once (charged to the
    /// prefix's first owner), so a queued request's estimate subtracts
    /// them — shared-prefix sessions admit under caps that would
    /// otherwise defer them.
    prefix: Option<Arc<PrefixRegistry>>,
    /// Decode-phase sessions kept sorted by (admit_s, id) — maintained
    /// incrementally on phase transitions instead of re-collected and
    /// re-sorted on every engine iteration.
    decode_order: Vec<u64>,
    /// Sessions that reached Done since the last `take_finished` —
    /// drained by the serving loop into engine reclamation
    /// (`LiveEngine::finish_session`).
    finished: Vec<u64>,
    deferrals: u64,
    rejections: u64,
}

impl Scheduler {
    pub fn new(batcher: Batcher) -> Self {
        Scheduler {
            sessions: HashMap::new(),
            queues: Vec::new(),
            rr: 0,
            batcher,
            arena: None,
            admission: None,
            prefix: None,
            decode_order: Vec::new(),
            finished: Vec::new(),
            deferrals: 0,
            rejections: 0,
        }
    }

    /// Scheduler with an admission gate over `arena`'s capacity/quota
    /// counters.
    pub fn with_admission(
        batcher: Batcher,
        arena: Arc<BlockArena>,
        admission: AdmissionConfig,
    ) -> Self {
        let mut s = Scheduler::new(batcher);
        s.arena = Some(arena);
        s.admission = Some(admission);
        s
    }

    /// Arm prefix-aware admission: the gate's footprint estimate
    /// subtracts the tokens a queued prompt would serve from the
    /// longest registered prefix (the registry map is re-probed on
    /// every pass — a prefix registered after the request queued still
    /// discounts it). Chain links of already-queued requests are
    /// computed here, of later ones at `submit`; gate passes only probe.
    pub fn set_prefix_registry(&mut self, registry: Arc<PrefixRegistry>) {
        for s in self.sessions.values_mut() {
            if s.prefix_links.is_none() {
                s.prefix_links = Some(registry.links(&s.req.prompt));
            }
        }
        self.prefix = Some(registry);
    }

    pub fn submit(&mut self, req: Request, now_s: f64) {
        let id = req.id;
        let tenant = req.tenant;
        let mut s = Session::new(req);
        s.admit_s = now_s;
        // links are immutable per request: hash the prompt once here,
        // not on every gate pass
        if let Some(reg) = &self.prefix {
            s.prefix_links = Some(reg.links(&s.req.prompt));
        }
        self.sessions.insert(id, s);
        match self.queues.iter_mut().find(|(t, _)| *t == tenant) {
            Some((_, q)) => q.push_back(id),
            None => {
                let mut q = VecDeque::new();
                q.push_back(id);
                self.queues.push((tenant, q));
            }
        }
    }

    pub fn session(&self, id: u64) -> Option<&Session> {
        self.sessions.get(&id)
    }

    pub fn session_mut(&mut self, id: u64) -> Option<&mut Session> {
        self.sessions.get_mut(&id)
    }

    /// Sessions currently decoding, oldest admission first (the
    /// incrementally-maintained sorted buffer).
    pub fn decodable(&self) -> &[u64] {
        &self.decode_order
    }

    /// Insert `id` into the sorted decode buffer.
    fn enter_decode(&mut self, id: u64) {
        let key = (self.sessions[&id].admit_s, id);
        let pos = self.decode_order.partition_point(|&o| (self.sessions[&o].admit_s, o) < key);
        self.decode_order.insert(pos, id);
    }

    /// Remove `id` from the sorted decode buffer (no-op if absent).
    fn leave_decode(&mut self, id: u64) {
        if let Some(p) = self.decode_order.iter().position(|&x| x == id) {
            self.decode_order.remove(p);
        }
    }

    /// Admission verdict for a queued request: can its estimated block
    /// footprint be checked out right now without hitting the arena cap
    /// (minus headroom) or the tenant's quota?
    fn gate(&self, id: u64) -> Gate {
        let (Some(arena), Some(adm)) = (&self.arena, &self.admission) else {
            return Gate::Admit;
        };
        if adm.tiered {
            // Tiered arena: hot-tier occupancy is the engine's problem
            // (demote-then-retry), not an admission signal.
            return Gate::Admit;
        }
        let s = &self.sessions[&id];
        // lifetime footprint: the prompt plus every token the session
        // may decode (so quota admission can never strand a session
        // mid-decode on QuotaExceeded), minus the tokens a registered
        // shared prefix already keeps resident (charged once, to the
        // prefix's first owner — not to this session)
        // Clamp on both arms: a registry whose chain geometry rounds up
        // (or a stale link set) can report more matched tokens than this
        // prompt holds, and `prompt.len() - shared` would underflow into
        // a huge estimate → spurious Reject.
        let shared = match (&self.prefix, &s.prefix_links) {
            (Some(reg), Some(links)) => {
                reg.matched_tokens_for_links(links).min(s.req.prompt.len())
            }
            _ => s.req.prefix_tokens.min(s.req.prompt.len()),
        };
        let est = adm.estimate_blocks(s.req.prompt.len() - shared + s.req.max_new);
        if let Some(cap) = arena.capacity_blocks() {
            let usable =
                (((cap as f64) * (1.0 - adm.headroom_frac)).floor() as usize).max(1);
            if est > usable {
                return Gate::Reject;
            }
            if arena.live_blocks() + est > usable {
                return Gate::Defer;
            }
        }
        if let Some(quota) = arena.tenant_quota_blocks(s.req.tenant) {
            if est > quota {
                return Gate::Reject;
            }
            if arena.tenant_live_blocks(s.req.tenant) + est > quota {
                return Gate::Defer;
            }
        }
        Gate::Admit
    }

    /// Next action. Decode runs whenever a full-enough batch exists or no
    /// prefill is admittable; prefill admits new work when the decode
    /// pool has headroom AND the admission gate passes. Queued-but-
    /// deferred work makes an otherwise idle scheduler return
    /// [`Action::Defer`] so the serving loop keeps reclaiming.
    pub fn next_action(&mut self) -> Action {
        let mut blocked = false;
        if self.decode_order.len() < self.batcher.max_batch() && !self.queues.is_empty() {
            let nt = self.queues.len();
            for k in 0..nt {
                let qi = (self.rr + k) % nt;
                // Rejection exposes a new head, which must be re-gated in
                // the same pass — otherwise an admittable request behind a
                // rejected one could strand behind an Idle return.
                while let Some(&id) = self.queues[qi].1.front() {
                    match self.gate(id) {
                        Gate::Admit => {
                            self.queues[qi].1.pop_front();
                            self.rr = (qi + 1) % nt;
                            self.sessions.get_mut(&id).unwrap().phase = Phase::Prefill;
                            return Action::Prefill(id);
                        }
                        Gate::Defer => {
                            blocked = true;
                            self.deferrals += 1;
                            break;
                        }
                        Gate::Reject => {
                            // can never fit: fail fast instead of deadlocking
                            self.queues[qi].1.pop_front();
                            self.rejections += 1;
                            let s = self.sessions.get_mut(&id).unwrap();
                            s.rejected = true;
                            s.phase = Phase::Done;
                            self.finished.push(id);
                        }
                    }
                }
            }
        }
        let sessions = &self.sessions;
        match self
            .batcher
            .select_by_tenant(&self.decode_order, |id| sessions[&id].req.tenant)
        {
            Some((ids, bucket)) => Action::DecodeBatch(ids, bucket),
            None if blocked => Action::Defer,
            None => Action::Idle,
        }
    }

    /// A queued request whose TTFT target cannot be met even if admitted
    /// right now and given every step's full chunk budget: the best
    /// case is `chunks` consecutive steps of `chunk_s` each.
    fn unmeetable(&self, id: u64, now_s: f64, pol: &SloPolicy) -> bool {
        let s = &self.sessions[&id];
        if !s.req.ttft_target_s.is_finite() {
            return false;
        }
        let chunks = s.req.prompt.len().div_ceil(pol.chunk_tokens.max(1)).max(1);
        now_s + chunks as f64 * pol.chunk_s > s.req.ttft_deadline_s()
    }

    /// Preemption victim: the decoding session with the lowest priority
    /// strictly below `below_priority`; ties demote the youngest
    /// admission (oldest work keeps its progress).
    fn pick_victim(&self, below_priority: i32) -> Option<u64> {
        self.decode_order
            .iter()
            .copied()
            .filter(|id| self.sessions[id].req.priority < below_priority)
            .min_by(|&a, &b| {
                let (sa, sb) = (&self.sessions[&a], &self.sessions[&b]);
                sa.req
                    .priority
                    .cmp(&sb.req.priority)
                    .then(sb.admit_s.total_cmp(&sa.admit_s))
                    .then(b.cmp(&a))
            })
    }

    /// SLO-aware step plan (DESIGN.md §2 "Online serving & preemption").
    /// Replaces the one-action-at-a-time [`Scheduler::next_action`] for
    /// serving loops that run chunked prefill: each step carries a
    /// decode batch AND a slack-bounded number of prefill chunks.
    ///
    /// The plan is computed in four passes:
    /// 1. **Admission (EDF)** — queue heads are examined earliest TTFT
    ///    deadline first (best-effort heads keep round-robin order).
    ///    Heads whose deadline is provably unmeetable under `pol`'s
    ///    timing model — or whose footprint can never fit — are rejected
    ///    immediately (the only mutation planning performs). Capacity-
    ///    deferred heads carrying an SLO may nominate one preemption
    ///    victim. Admitted heads start prefill, bounded by free batch
    ///    slots.
    /// 2. **Resume** — when the step saw no capacity pressure and a
    ///    batch slot is free, the highest-priority parked session is
    ///    promoted back (one per step, so resume can never thrash
    ///    against preemption).
    /// 3. **Chunk budget** — the tightest TPOT slack across decoding
    ///    sessions caps how many prefill chunks ride along:
    ///    `floor((slack - decode_step_s) / chunk_s)`, clamped to
    ///    `max_chunks_per_step`. A starvation guard forces one chunk
    ///    when an open prefill's own TTFT deadline is about to become
    ///    unmeetable. Chunks go to the earliest-deadline job first, each
    ///    job drained fully before the next (EDF with full allocation).
    /// 4. **Decode selection** — deadline-slack selection when any
    ///    decoding session carries a TPOT target, tenant-fair round-
    ///    robin otherwise.
    ///
    /// Planning is idempotent modulo rejections: calling twice without
    /// applying transitions returns the same plan.
    pub fn next_plan(&mut self, now_s: f64, pol: &SloPolicy) -> StepPlan {
        let mut plan = StepPlan::default();
        let mut blocked = false;

        // -- 1. admission pass, earliest deadline first ----------------
        let n_prefilling = self.n_prefilling();
        let mut slots = self
            .batcher
            .max_batch()
            .saturating_sub(self.decode_order.len() + n_prefilling);
        let nt = self.queues.len();
        // collect one candidate head per tenant queue, draining heads
        // that are rejected outright (capacity or provably-unmeetable
        // deadline) so admittable work behind them is seen this pass
        let mut heads: Vec<(usize, u64, f64)> = Vec::new();
        for k in 0..nt {
            let qi = (self.rr + k) % nt;
            while let Some(&id) = self.queues[qi].1.front() {
                if matches!(self.gate(id), Gate::Reject) || self.unmeetable(id, now_s, pol) {
                    self.queues[qi].1.pop_front();
                    self.rejections += 1;
                    let s = self.sessions.get_mut(&id).unwrap();
                    s.rejected = true;
                    s.phase = Phase::Done;
                    self.finished.push(id);
                    continue;
                }
                heads.push((k, id, self.sessions[&id].req.ttft_deadline_s()));
                break;
            }
        }
        heads.sort_by(|a, b| a.2.total_cmp(&b.2).then(a.0.cmp(&b.0)));
        for &(_, id, _) in &heads {
            if slots == 0 {
                break;
            }
            match self.gate(id) {
                Gate::Admit => {
                    plan.start_prefill.push(id);
                    slots -= 1;
                }
                Gate::Defer => {
                    blocked = true;
                    self.deferrals += 1;
                    if plan.preempt.is_empty() && self.sessions[&id].req.has_slo() {
                        if let Some(v) = self.pick_victim(self.sessions[&id].req.priority) {
                            plan.preempt.push(v);
                        }
                    }
                }
                // the gate is deterministic within a pass, but keep the
                // arm total: a Reject here just waits for the next plan
                Gate::Reject => {}
            }
        }

        // -- 2. opportunistic resume (only under zero pressure) --------
        if !blocked && plan.preempt.is_empty() && slots > 0 {
            let mut parked: Vec<u64> = self
                .sessions
                .iter()
                .filter(|(_, s)| s.phase == Phase::Preempted)
                .map(|(&id, _)| id)
                .collect();
            parked.sort_by(|&a, &b| {
                let (sa, sb) = (&self.sessions[&a], &self.sessions[&b]);
                sb.req
                    .priority
                    .cmp(&sa.req.priority)
                    .then(sa.admit_s.total_cmp(&sb.admit_s))
                    .then(a.cmp(&b))
            });
            if let Some(&id) = parked.first() {
                plan.resume.push(id);
            }
        }

        // -- 3. chunk budget from the tightest TPOT slack --------------
        let tightest = self
            .decode_order
            .iter()
            .map(|&id| self.sessions[&id].tpot_slack_s(now_s))
            .fold(f64::INFINITY, f64::min);
        let mut budget = if tightest.is_finite() {
            let fit = ((tightest - pol.decode_step_s) / pol.chunk_s.max(1e-12)).floor();
            (fit.max(0.0) as usize).min(pol.max_chunks_per_step)
        } else {
            pol.max_chunks_per_step
        };
        // open jobs: in-flight prefills plus the ones starting this step
        let mut open: Vec<(u64, f64, usize)> = self
            .sessions
            .iter()
            .filter(|(_, s)| s.phase == Phase::Prefill)
            .chain(plan.start_prefill.iter().map(|id| (id, &self.sessions[id])))
            .map(|(&id, s)| {
                let left = (s.req.prompt.len().saturating_sub(s.prefill_fed))
                    .div_ceil(pol.chunk_tokens.max(1))
                    .max(1);
                (id, s.req.ttft_deadline_s(), left)
            })
            .collect();
        open.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
        if budget == 0
            && open.iter().any(|&(_, dl, left)| {
                dl.is_finite() && dl - now_s < (left as f64 + 1.0) * pol.chunk_s
            })
        {
            // starvation guard: decode pressure may slow a prefill but
            // must never stall it into missing a still-meetable deadline
            budget = 1;
        }
        for &(id, _, left) in &open {
            if budget == 0 {
                break;
            }
            let take = left.min(budget);
            plan.chunks.extend(std::iter::repeat(id).take(take));
            budget -= take;
        }

        // -- 4. decode batch ------------------------------------------
        let sessions = &self.sessions;
        let any_tpot = self
            .decode_order
            .iter()
            .any(|id| sessions[id].req.tpot_target_s.is_finite());
        let sel = if any_tpot {
            self.batcher
                .select_by_slack(&self.decode_order, |id| sessions[&id].tpot_slack_s(now_s))
        } else {
            self.batcher
                .select_by_tenant(&self.decode_order, |id| sessions[&id].req.tenant)
        };
        if let Some((ids, bucket)) = sel {
            plan.decode = ids;
            plan.bucket = bucket;
        }
        plan
    }

    /// Apply a planned prefill start: the request leaves its tenant
    /// queue and enters `Phase::Prefill` (chunks advance it from here).
    pub fn prefill_started(&mut self, id: u64) {
        for (_, q) in self.queues.iter_mut() {
            if let Some(p) = q.iter().position(|&x| x == id) {
                q.remove(p);
                break;
            }
        }
        self.sessions.get_mut(&id).unwrap().phase = Phase::Prefill;
    }

    /// Record chunked-prefill progress (`fed_tokens` of the prompt are
    /// now built) — feeds the planner's remaining-chunk estimates.
    pub fn chunk_done(&mut self, id: u64, fed_tokens: usize) {
        let s = self.sessions.get_mut(&id).unwrap();
        s.prefill_fed = fed_tokens.min(s.req.prompt.len());
    }

    /// Apply a planned preemption: the session leaves the decode buffer
    /// and parks until [`Scheduler::resumed`]. The KV half is the
    /// engine's `preempt_session` (snapshot + hot-block reclaim).
    pub fn preempted(&mut self, id: u64) {
        let s = self.sessions.get_mut(&id).unwrap();
        debug_assert_eq!(s.phase, Phase::Decode, "only decoding sessions preempt");
        s.phase = Phase::Preempted;
        s.preemptions += 1;
        self.leave_decode(id);
    }

    /// Apply a planned resume: the parked session re-enters the decode
    /// buffer (the engine side is `resume_session`, which restores the
    /// exact snapshot — generation continues bit-identically).
    pub fn resumed(&mut self, id: u64) {
        let s = self.sessions.get_mut(&id).unwrap();
        debug_assert_eq!(s.phase, Phase::Preempted, "only parked sessions resume");
        s.phase = Phase::Decode;
        self.enter_decode(id);
    }

    /// Sessions currently mid-prefill (chunked jobs in flight).
    pub fn n_prefilling(&self) -> usize {
        self.sessions.values().filter(|s| s.phase == Phase::Prefill).count()
    }

    /// Sessions parked in the cold tier awaiting resume.
    pub fn n_preempted(&self) -> usize {
        self.sessions.values().filter(|s| s.phase == Phase::Preempted).count()
    }

    /// Pop one queued request whose admission gate currently defers and
    /// hand it (with its session state) to the caller — the work-steal
    /// donor side: instead of spinning on [`Action::Defer`], the cluster
    /// coordinator offers the blocked head-of-queue to the least-loaded
    /// replica. Only `Phase::Queued` requests are stealable (an admitted
    /// prefill's KV already lives on this replica — moving it is
    /// migration, not stealing). Returns `None` when no queue head is
    /// gate-blocked.
    pub fn steal_deferred(&mut self) -> Option<Request> {
        let nt = self.queues.len();
        for k in 0..nt {
            let qi = (self.rr + k) % nt;
            let Some(&id) = self.queues[qi].1.front() else {
                continue;
            };
            if matches!(self.gate(id), Gate::Defer) {
                self.queues[qi].1.pop_front();
                let s = self.sessions.remove(&id).expect("queued session exists");
                debug_assert_eq!(s.phase, Phase::Queued);
                return Some(s.req);
            }
        }
        None
    }

    /// Remove a session from this scheduler entirely (any phase),
    /// returning its state — the bookkeeping half of live migration
    /// (the KV half moves through `LiveEngine::export_session`) and of
    /// failure recovery (the coordinator re-homes a dead replica's
    /// sessions from exactly this state). The id leaves the tenant
    /// queue, the decode buffer, and the pending-finished events.
    pub fn take_session(&mut self, id: u64) -> Option<Session> {
        let s = self.sessions.remove(&id)?;
        for (_, q) in self.queues.iter_mut() {
            if let Some(p) = q.iter().position(|&x| x == id) {
                q.remove(p);
                break;
            }
        }
        self.leave_decode(id);
        self.finished.retain(|&x| x != id);
        Some(s)
    }

    /// Re-adopt a session taken from another scheduler (migration
    /// target side): it enters the decode buffer if mid-decode, the
    /// tenant queue if still queued. `Done` sessions are recorded and
    /// immediately reported finished.
    pub fn adopt_session(&mut self, mut s: Session, now_s: f64) {
        let id = s.req.id;
        debug_assert!(!self.sessions.contains_key(&id), "adopting a duplicate session");
        if s.admit_s.is_nan() {
            s.admit_s = now_s;
        }
        let phase = s.phase;
        let tenant = s.req.tenant;
        self.sessions.insert(id, s);
        match phase {
            Phase::Queued => match self.queues.iter_mut().find(|(t, _)| *t == tenant) {
                Some((_, q)) => q.push_back(id),
                None => {
                    let mut q = VecDeque::new();
                    q.push_back(id);
                    self.queues.push((tenant, q));
                }
            },
            Phase::Decode => self.enter_decode(id),
            Phase::Prefill => {
                // an in-flight prefill cannot migrate; the caller
                // re-queues it (its KV will rebuild on this replica)
                self.sessions.get_mut(&id).unwrap().phase = Phase::Queued;
                match self.queues.iter_mut().find(|(t, _)| *t == tenant) {
                    Some((_, q)) => q.push_back(id),
                    None => {
                        let mut q = VecDeque::new();
                        q.push_back(id);
                        self.queues.push((tenant, q));
                    }
                }
            }
            Phase::Preempted => {
                // the parked snapshot lives on the source engine and
                // does not travel: restart from the prompt — decode is
                // deterministic, so the regenerated tokens are identical
                let s = self.sessions.get_mut(&id).unwrap();
                s.phase = Phase::Queued;
                s.generated.clear();
                s.first_token_s = f64::NAN;
                s.last_token_s = f64::NAN;
                s.prefill_fed = 0;
                match self.queues.iter_mut().find(|(t, _)| *t == tenant) {
                    Some((_, q)) => q.push_back(id),
                    None => {
                        let mut q = VecDeque::new();
                        q.push_back(id);
                        self.queues.push((tenant, q));
                    }
                }
            }
            Phase::Done => self.finished.push(id),
        }
    }

    /// Remove and return every not-yet-finished session — the failure
    /// path: a dead replica's scheduler is drained and its sessions
    /// re-homed on survivors. Queues and the decode buffer empty;
    /// finished sessions stay behind for their final accounting.
    pub fn drain_unfinished(&mut self) -> Vec<Session> {
        let ids: Vec<u64> = self
            .sessions
            .iter()
            .filter(|(_, s)| s.phase != Phase::Done)
            .map(|(&id, _)| id)
            .collect();
        let mut out = Vec::with_capacity(ids.len());
        for id in ids {
            if let Some(s) = self.take_session(id) {
                out.push(s);
            }
        }
        out.sort_by_key(|s| s.req.id);
        out
    }

    /// Mark prefill complete (first token produced).
    pub fn prefill_done(&mut self, id: u64, first_token: i32, now_s: f64) {
        let s = self.sessions.get_mut(&id).unwrap();
        s.phase = Phase::Decode;
        s.generated.push(first_token);
        s.first_token_s = now_s;
        s.last_token_s = now_s;
        s.prefill_fed = s.req.prompt.len();
        if s.finished() {
            s.phase = Phase::Done;
            s.done_s = now_s;
            self.finished.push(id);
        } else {
            self.enter_decode(id);
        }
    }

    /// Record one decoded token; completes the session at max_new.
    pub fn token_decoded(&mut self, id: u64, token: i32, now_s: f64) {
        let s = self.sessions.get_mut(&id).unwrap();
        s.generated.push(token);
        s.last_token_s = now_s;
        if s.finished() {
            s.phase = Phase::Done;
            s.done_s = now_s;
            self.leave_decode(id);
            self.finished.push(id);
        }
    }

    /// Drain the session-finished events accumulated since the last
    /// call. The serving loop feeds these into engine reclamation so
    /// finished sessions return their KV blocks to the arena.
    pub fn take_finished(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.finished)
    }

    pub fn all_done(&self) -> bool {
        self.queues.iter().all(|(_, q)| q.is_empty())
            && self.sessions.values().all(|s| s.phase == Phase::Done)
    }

    pub fn sessions(&self) -> impl Iterator<Item = &Session> {
        self.sessions.values()
    }

    pub fn n_decoding(&self) -> usize {
        self.decode_order.len()
    }

    /// Requests still waiting in tenant queues.
    pub fn n_waiting(&self) -> usize {
        self.queues.iter().map(|(_, q)| q.len()).sum()
    }

    /// Gate-blocked head-of-queue observations (a queued prefill was
    /// deferred because the arena was too full for it).
    pub fn n_deferrals(&self) -> u64 {
        self.deferrals
    }

    /// Requests rejected outright (estimated footprint can never fit).
    pub fn n_rejections(&self) -> u64 {
        self.rejections
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::{prop_assert, prop_assert_eq};

    fn sched(max_batch: usize) -> Scheduler {
        Scheduler::new(Batcher::new(&[1, 2, 4, 8], max_batch))
    }

    #[test]
    fn prefill_then_decode_then_done() {
        let mut s = sched(4);
        s.submit(Request::new(1, vec![1, 2], 2), 0.0);
        assert_eq!(s.next_action(), Action::Prefill(1));
        s.prefill_done(1, 42, 0.1);
        assert_eq!(s.next_action(), Action::DecodeBatch(vec![1], 1));
        s.token_decoded(1, 43, 0.2);
        assert!(s.all_done());
        assert_eq!(s.session(1).unwrap().generated, vec![42, 43]);
        assert_eq!(s.next_action(), Action::Idle);
    }

    #[test]
    fn admission_cap_defers_prefill() {
        let mut s = sched(2);
        for id in 1..=3 {
            s.submit(Request::new(id, vec![1], 10), 0.0);
        }
        // two prefills admitted
        assert_eq!(s.next_action(), Action::Prefill(1));
        s.prefill_done(1, 0, 0.0);
        assert_eq!(s.next_action(), Action::Prefill(2));
        s.prefill_done(2, 0, 0.0);
        // pool full: third prefill deferred, decode batch runs
        match s.next_action() {
            Action::DecodeBatch(ids, bucket) => {
                assert_eq!(ids, vec![1, 2]);
                assert_eq!(bucket, 2);
            }
            a => panic!("expected decode, got {a:?}"),
        }
    }

    #[test]
    fn continuous_batching_admits_mid_flight() {
        let mut s = sched(8);
        s.submit(Request::new(1, vec![1], 5), 0.0);
        assert_eq!(s.next_action(), Action::Prefill(1));
        s.prefill_done(1, 0, 0.0);
        // a new request arrives while 1 decodes
        s.submit(Request::new(2, vec![1], 5), 0.1);
        assert_eq!(s.next_action(), Action::Prefill(2));
        s.prefill_done(2, 0, 0.2);
        match s.next_action() {
            Action::DecodeBatch(ids, _) => assert_eq!(ids, vec![1, 2]),
            a => panic!("{a:?}"),
        }
    }

    #[test]
    fn finishing_at_prefill_token() {
        let mut s = sched(2);
        s.submit(Request::new(7, vec![1], 1), 0.0);
        assert_eq!(s.next_action(), Action::Prefill(7));
        s.prefill_done(7, 9, 0.5);
        assert!(s.all_done());
        assert_eq!(s.session(7).unwrap().phase, Phase::Done);
        // a session that finishes at its prefill token still emits a
        // finished event and never enters the decode buffer
        assert_eq!(s.take_finished(), vec![7]);
        assert!(s.decodable().is_empty());
    }

    #[test]
    fn decode_buffer_stays_sorted_by_admission() {
        let mut s = sched(8);
        // admit out of id order: id 5 first, then 2, then 9
        for (id, at) in [(5u64, 0.0), (2, 1.0), (9, 2.0)] {
            s.submit(Request::new(id, vec![1], 10), at);
            assert_eq!(s.next_action(), Action::Prefill(id));
            s.prefill_done(id, 0, at);
        }
        assert_eq!(s.decodable(), &[5, 2, 9]);
        assert_eq!(s.n_decoding(), 3);
        // finishing the middle session removes it in place
        for _ in 0..10 {
            s.token_decoded(2, 1, 3.0);
        }
        assert_eq!(s.decodable(), &[5, 9]);
        assert_eq!(s.take_finished(), vec![2]);
        assert!(s.take_finished().is_empty(), "events drain exactly once");
    }

    #[test]
    fn finished_events_cover_every_session() {
        let mut s = sched(4);
        for id in 0..3u64 {
            s.submit(Request::new(id, vec![1], 2), 0.0);
        }
        let mut finished = Vec::new();
        let mut guard = 0;
        while !s.all_done() {
            guard += 1;
            assert!(guard < 1000);
            match s.next_action() {
                Action::Prefill(id) => s.prefill_done(id, 0, 0.1),
                Action::DecodeBatch(ids, _) => {
                    for id in ids {
                        s.token_decoded(id, 1, 0.2);
                    }
                }
                Action::Defer => panic!("defer without admission control"),
                Action::Idle => break,
            }
            finished.extend(s.take_finished());
        }
        finished.sort_unstable();
        assert_eq!(finished, vec![0, 1, 2]);
    }

    #[test]
    fn tiered_gate_admits_past_a_full_hot_tier() {
        // hot tier far too small for even one request's estimate: the
        // single-tier gate would reject, the tiered gate must admit
        // (the engine demotes-then-retries; cold absorbs the overflow)
        let arena = BlockArena::shared(16, 512);
        arena.set_capacity_blocks(Some(2));
        let adm = AdmissionConfig {
            heads: 4,
            tokens_per_block: 4,
            headroom_frac: 0.2,
            est_fudge: 1.5,
            tiered: true,
        };
        let mut s = Scheduler::with_admission(
            Batcher::new(&[1, 2, 4, 8], 4),
            Arc::clone(&arena),
            adm.clone(),
        );
        s.submit(Request::new(1, vec![1; 400], 4), 0.0);
        assert_eq!(s.next_action(), Action::Prefill(1));
        assert_eq!(s.n_deferrals(), 0);
        assert_eq!(s.n_rejections(), 0);
        // the same request under the single-tier gate is rejected
        let mut s1 = Scheduler::with_admission(
            Batcher::new(&[1, 2, 4, 8], 4),
            Arc::clone(&arena),
            AdmissionConfig { tiered: false, ..adm },
        );
        s1.submit(Request::new(2, vec![1; 400], 4), 0.0);
        assert_ne!(s1.next_action(), Action::Prefill(2));
        assert_eq!(s1.n_rejections(), 1);
    }

    #[test]
    fn prefix_hint_discounts_admission_footprint() {
        // cap sized so the FULL estimate never fits (reject) but the
        // prefix-discounted remainder does
        let arena = BlockArena::shared(16, 512); // tpb = 4
        arena.set_capacity_blocks(Some(100));
        let adm = AdmissionConfig {
            heads: 4,
            tokens_per_block: 4,
            headroom_frac: 0.2,
            est_fudge: 1.5,
            tiered: false,
        };
        let mk = |hint: usize| {
            let mut s = Scheduler::with_admission(
                Batcher::new(&[1, 2, 4, 8], 4),
                Arc::clone(&arena),
                adm.clone(),
            );
            // full estimate: 4 heads × ceil(404/4) × 1.5 = 606 blocks ≫ 80
            s.submit(Request::new(1, vec![1; 400], 4).with_prefix_tokens(hint), 0.0);
            s
        };
        let mut unshared = mk(0);
        assert_ne!(unshared.next_action(), Action::Prefill(1));
        assert_eq!(unshared.n_rejections(), 1, "full footprint can never fit");
        // with 384 prefix tokens resident elsewhere: 4 × ceil(20/4) × 1.5
        // = 30 blocks < 80 usable
        let mut shared = mk(384);
        assert_eq!(shared.next_action(), Action::Prefill(1));
        assert_eq!(shared.n_rejections(), 0);
        assert_eq!(shared.n_deferrals(), 0);
    }

    #[test]
    fn prefix_registry_discount_applies_to_queued_requests() {
        use crate::kvcache::prefix::{ChainGeometry, SealedSlot};
        let arena = BlockArena::shared(16, 512);
        arena.set_capacity_blocks(Some(100));
        let geom = ChainGeometry { sink: 4, segment: 64, local: 8 };
        let reg = PrefixRegistry::shared(Arc::clone(&arena), geom, 4);
        let adm = AdmissionConfig {
            heads: 4,
            tokens_per_block: 4,
            headroom_frac: 0.2,
            est_fudge: 1.5,
            tiered: false,
        };
        let mut s = Scheduler::with_admission(
            Batcher::new(&[1, 2, 4, 8], 4),
            Arc::clone(&arena),
            adm,
        );
        s.set_prefix_registry(Arc::clone(&reg));
        let prompt: Vec<i32> = (0..400).collect();
        s.submit(Request::new(1, prompt.clone(), 4), 0.0);
        // nothing registered yet: the full estimate rejects... but the
        // registry may gain the prefix while the request is queued, so
        // defer/reject semantics must re-probe. Register first, then gate.
        let links = reg.links(&prompt);
        let &(covered, key) = links.last().unwrap();
        assert_eq!(covered, 388);
        assert!(reg.register(key, covered, vec![SealedSlot::default()]));
        assert_eq!(s.next_action(), Action::Prefill(1), "registered prefix must discount");
        assert_eq!(s.n_rejections(), 0);
        // probing from the gate must not inflate serving hit counters
        assert_eq!(reg.hits(), 0);
    }

    #[test]
    fn registry_discount_clamped_to_prompt_len() {
        // Regression: the registry arm of the gate subtracted the
        // matched-token count without clamping it to the prompt length.
        // A link set carrying more coverage than this prompt holds (a
        // stale or over-covering chain) made
        // `prompt.len() - shared` underflow to a huge estimate and the
        // gate returned a spurious Reject. Clamped, the request admits.
        use crate::kvcache::prefix::{ChainGeometry, SealedSlot};
        let arena = BlockArena::shared(16, 512);
        arena.set_capacity_blocks(Some(100));
        let geom = ChainGeometry { sink: 4, segment: 64, local: 8 };
        let reg = PrefixRegistry::shared(Arc::clone(&arena), geom, 4);
        let adm = AdmissionConfig {
            heads: 4,
            tokens_per_block: 4,
            headroom_frac: 0.2,
            est_fudge: 1.5,
            tiered: false,
        };
        let mut s = Scheduler::with_admission(
            Batcher::new(&[1, 2, 4, 8], 4),
            Arc::clone(&arena),
            adm,
        );
        s.set_prefix_registry(Arc::clone(&reg));
        // Register the chain of a LONGER prompt sharing this content.
        let long: Vec<i32> = (0..600).collect();
        let links = reg.links(&long);
        let &(covered, key) = links.last().unwrap();
        assert!(reg.register(key, covered, vec![SealedSlot::default()]));
        // Boundary: shared == prompt.len() exactly must also admit
        // (estimate reduces to max_new alone, no underflow at 0).
        let prompt: Vec<i32> = (0..400).collect();
        s.submit(Request::new(1, prompt, 4), 0.0);
        // Force the over-covering link set onto the queued session, as a
        // stale cache would: its matched tokens exceed the prompt length.
        s.session_mut(1).unwrap().prefix_links = Some(links);
        assert!(reg.matched_tokens_for_links(s.session(1).unwrap().prefix_links.as_ref().unwrap()) > 400);
        assert_eq!(s.next_action(), Action::Prefill(1), "clamped discount must admit");
        assert_eq!(s.n_rejections(), 0, "underflowed estimate caused a spurious reject");
    }

    #[test]
    fn tenant_round_robin_prevents_starvation() {
        let mut s = sched(8);
        // tenant 0 floods five requests before tenant 1's single request
        for id in 0..5u64 {
            s.submit(Request::new(id, vec![1], 3), 0.0);
        }
        s.submit(Request::new(10, vec![1], 3).with_tenant(1), 0.1);
        // round-robin: tenant 0's head, then tenant 1's — NOT all five of
        // tenant 0 first
        assert_eq!(s.next_action(), Action::Prefill(0));
        s.prefill_done(0, 0, 0.2);
        assert_eq!(s.next_action(), Action::Prefill(10));
        s.prefill_done(10, 0, 0.3);
        assert_eq!(s.next_action(), Action::Prefill(1));
        s.prefill_done(1, 0, 0.4);
        assert_eq!(s.n_waiting(), 3);
    }

    /// Regression for the PR 1 incremental decode-order rewrite: the
    /// incrementally-sorted buffer must equal a from-scratch sort of the
    /// session table after ANY interleaving of submit / prefill_done /
    /// token_decoded / finish transitions.
    #[test]
    fn prop_decode_buffer_matches_from_scratch_sort() {
        check("decode-order-incremental", 10, |rng| {
            let mut s = sched(1 + rng.below(8));
            let mut next_id = 0u64;
            let mut now = 0.0;
            for _ in 0..300 {
                now += 0.125;
                if rng.below(3) == 0 && next_id < 40 {
                    let max_new = 1 + rng.below(6);
                    let tenant = rng.below(3) as u32;
                    s.submit(
                        Request::new(next_id, vec![1], max_new).with_tenant(tenant),
                        now,
                    );
                    next_id += 1;
                } else {
                    match s.next_action() {
                        Action::Prefill(id) => s.prefill_done(id, 0, now),
                        Action::DecodeBatch(ids, _) => {
                            for id in ids {
                                s.token_decoded(id, 1, now);
                            }
                        }
                        Action::Defer | Action::Idle => {}
                    }
                }
                // oracle: re-derive the decode buffer from the session
                // table and sort from scratch by (admit_s, id)
                let mut expect: Vec<u64> = s
                    .sessions()
                    .filter(|x| x.phase == Phase::Decode)
                    .map(|x| x.req.id)
                    .collect();
                expect.sort_by(|&a, &b| {
                    let (sa, sb) = (s.session(a).unwrap(), s.session(b).unwrap());
                    sa.admit_s
                        .partial_cmp(&sb.admit_s)
                        .unwrap()
                        .then(a.cmp(&b))
                });
                prop_assert_eq!(s.decodable().to_vec(), expect);
            }
            Ok(())
        });
    }

    /// `take_finished` drains each finished session exactly once, no
    /// matter how the drains interleave with service.
    #[test]
    fn prop_take_finished_drains_exactly_once() {
        check("take-finished-once", 8, |rng| {
            let n_req = 3 + rng.below(10);
            let mut s = sched(4);
            for id in 0..n_req as u64 {
                s.submit(Request::new(id, vec![1], 1 + rng.below(4)), 0.0);
            }
            let mut seen = std::collections::HashSet::new();
            let mut guard = 0;
            while !s.all_done() {
                guard += 1;
                prop_assert!(guard < 10_000, "no termination");
                match s.next_action() {
                    Action::Prefill(id) => s.prefill_done(id, 0, 0.1),
                    Action::DecodeBatch(ids, _) => {
                        for id in ids {
                            s.token_decoded(id, 1, 0.2);
                        }
                    }
                    Action::Defer | Action::Idle => {}
                }
                // drain at random times (sometimes skipping rounds)
                if rng.below(2) == 0 {
                    for id in s.take_finished() {
                        prop_assert!(seen.insert(id), "session {} drained twice", id);
                    }
                }
            }
            for id in s.take_finished() {
                prop_assert!(seen.insert(id), "session {} drained twice", id);
            }
            prop_assert_eq!(seen.len(), n_req);
            prop_assert!(s.take_finished().is_empty(), "drain not empty after drain");
            Ok(())
        });
    }

    #[test]
    fn plan_chunks_ride_along_and_follow_progress() {
        let mut s = sched(4);
        let pol = SloPolicy {
            chunk_tokens: 4,
            chunk_s: 0.01,
            decode_step_s: 0.005,
            max_chunks_per_step: 2,
        };
        s.submit(Request::new(1, vec![0; 10], 3), 0.0);
        let p = s.next_plan(0.0, &pol);
        assert_eq!(p.start_prefill, vec![1]);
        // no decode pressure: the full per-step cap rides (job needs 3)
        assert_eq!(p.chunks, vec![1, 1]);
        assert!(p.decode.is_empty());
        // planning is idempotent until transitions are applied
        assert_eq!(s.next_plan(0.0, &pol), p);
        s.prefill_started(1);
        s.chunk_done(1, 8);
        assert_eq!(s.n_prefilling(), 1);
        assert_eq!(s.n_waiting(), 0);
        let p2 = s.next_plan(0.02, &pol);
        assert!(p2.start_prefill.is_empty());
        assert_eq!(p2.chunks, vec![1], "one chunk left after 8/10 tokens fed");
        s.chunk_done(1, 10);
        s.prefill_done(1, 7, 0.03);
        let p3 = s.next_plan(0.04, &pol);
        assert!(p3.chunks.is_empty());
        assert_eq!(p3.decode, vec![1]);
        assert_eq!(p3.bucket, 1);
    }

    #[test]
    fn plan_throttles_chunks_under_tpot_pressure() {
        let mut s = sched(4);
        let pol = SloPolicy {
            chunk_tokens: 4,
            chunk_s: 0.01,
            decode_step_s: 0.005,
            max_chunks_per_step: 8,
        };
        // session 1 decodes under a tight TPOT target
        s.submit(Request::new(1, vec![0; 4], 5).with_slo(f64::INFINITY, 0.012), 0.0);
        s.prefill_started(1);
        s.prefill_done(1, 0, 0.0);
        // big best-effort prompt queued behind it
        s.submit(Request::new(2, vec![0; 64], 3), 0.0);
        // slack 0.012: floor((0.012 - 0.005) / 0.01) = 0 chunks fit
        let p = s.next_plan(0.0, &pol);
        assert_eq!(p.start_prefill, vec![2]);
        assert!(p.chunks.is_empty(), "tight TPOT slack starves best-effort chunks");
        assert_eq!(p.decode, vec![1]);
        assert_eq!(p.bucket, 1);
        // a cheaper chunk model fits 3 into the same slack
        let fast = SloPolicy { chunk_s: 0.002, ..pol };
        let p2 = s.next_plan(0.0, &fast);
        assert_eq!(p2.chunks, vec![2, 2, 2]);
    }

    #[test]
    fn plan_starvation_guard_keeps_deadline_prefill_alive() {
        let mut s = sched(4);
        let pol = SloPolicy {
            chunk_tokens: 4,
            chunk_s: 0.01,
            decode_step_s: 0.005,
            max_chunks_per_step: 8,
        };
        s.submit(Request::new(1, vec![0; 4], 5).with_slo(f64::INFINITY, 0.012), 0.0);
        s.prefill_started(1);
        s.prefill_done(1, 0, 0.0);
        // SLO prompt: 2 chunks needed, TTFT deadline at 0.055 — still
        // meetable from 0.03 (2 × 0.01 fits), but not if stalled a step
        s.submit(Request::new(2, vec![0; 8], 3).with_slo(0.055, f64::INFINITY), 0.0);
        // at 0.03 the decode slack is blown (budget 0), but stalling the
        // prefill one more step would make its still-meetable deadline
        // unmeetable — the guard forces one chunk through
        let p = s.next_plan(0.03, &pol);
        assert_eq!(p.start_prefill, vec![2]);
        assert_eq!(p.chunks, vec![2], "starvation guard forces one chunk");
        assert_eq!(p.decode, vec![1]);
    }

    #[test]
    fn plan_rejects_provably_unmeetable_ttft() {
        let mut s = sched(4);
        let pol = SloPolicy::default(); // 512-token chunks, 0.01 s each
        // 10 chunks minimum = 0.1 s of prefill against a 0.05 s target
        s.submit(Request::new(1, vec![0; 5120], 3).with_slo(0.05, f64::INFINITY), 0.0);
        // an admittable best-effort request behind it in the same queue
        s.submit(Request::new(2, vec![0; 512], 3), 0.0);
        let p = s.next_plan(0.0, &pol);
        assert_eq!(p.start_prefill, vec![2], "rejection exposes the next head in-pass");
        let sess = s.session(1).unwrap();
        assert!(sess.rejected);
        assert_eq!(sess.phase, Phase::Done);
        assert_eq!(s.n_rejections(), 1);
        assert_eq!(s.take_finished(), vec![1]);
    }

    #[test]
    fn plan_preempts_lowest_priority_then_resumes_when_pressure_clears() {
        use crate::kvcache::DEFAULT_TENANT;
        let arena = BlockArena::shared(16, 512);
        arena.set_capacity_blocks(Some(100));
        let adm = AdmissionConfig {
            heads: 4,
            tokens_per_block: 4,
            headroom_frac: 0.2, // usable = 80 blocks
            est_fudge: 1.5,
            tiered: false,
        };
        let mut s = Scheduler::with_admission(
            Batcher::new(&[1, 2, 4, 8], 4),
            Arc::clone(&arena),
            adm,
        );
        let pol = SloPolicy::default();
        // three decoding sessions: one priority-1, two priority-0 (12 younger)
        for (id, prio, at) in [(10u64, 1, 0.0), (11, 0, 0.0), (12, 0, 0.5)] {
            s.submit(Request::new(id, vec![0; 4], 50).with_priority(prio), at);
            s.prefill_started(id);
            s.prefill_done(id, 0, at);
        }
        // occupy the arena so the gate defers the newcomer:
        // est = 4 heads × ceil(44/4) × 1.5 = 66 ≤ 80, but 60 + 66 > 80
        let held: Vec<_> =
            (0..60).map(|_| arena.try_alloc_for(DEFAULT_TENANT).unwrap().1).collect();
        s.submit(
            Request::new(1, vec![0; 40], 4).with_slo(1.0, f64::INFINITY).with_priority(2),
            1.0,
        );
        let p = s.next_plan(1.0, &pol);
        assert!(p.start_prefill.is_empty(), "gate defers under pressure");
        assert_eq!(p.preempt, vec![12], "lowest priority, youngest admission");
        assert!(p.resume.is_empty(), "no resume while preempting");
        assert!(s.n_deferrals() > 0);
        s.preempted(12);
        assert_eq!(s.n_preempted(), 1);
        assert!(!s.decodable().contains(&12));
        assert_eq!(s.session(12).unwrap().preemptions, 1);
        // pressure clears: the head admits and the parked session resumes
        arena.reclaim_for(DEFAULT_TENANT, held);
        let p2 = s.next_plan(1.1, &pol);
        assert_eq!(p2.start_prefill, vec![1]);
        assert!(p2.preempt.is_empty());
        assert_eq!(p2.resume, vec![12]);
        s.resumed(12);
        assert_eq!(s.n_preempted(), 0);
        assert!(s.decodable().contains(&12));
    }

    #[test]
    fn adopted_preempted_session_restarts_from_prompt() {
        let mut a = sched(4);
        a.submit(Request::new(5, vec![1, 2], 4), 0.0);
        a.prefill_started(5);
        a.prefill_done(5, 9, 0.1);
        a.token_decoded(5, 8, 0.2);
        a.preempted(5);
        let sess = a.take_session(5).unwrap();
        assert_eq!(sess.phase, Phase::Preempted);
        let mut b = sched(4);
        b.adopt_session(sess, 1.0);
        let s5 = b.session(5).unwrap();
        assert_eq!(s5.phase, Phase::Queued);
        assert!(s5.generated.is_empty(), "parked snapshot is engine-local: restart");
        assert_eq!(s5.preemptions, 1);
        assert_eq!(b.next_action(), Action::Prefill(5));
    }

    /// Plan-driven serving must terminate with every session Done for
    /// any mix of prompt lengths, TPOT targets, chunk budgets and batch
    /// caps — and the decode buffer must stay consistent throughout.
    #[test]
    fn prop_plan_driven_loop_finishes_every_session() {
        check("plan-loop-total", 8, |rng| {
            let pol = SloPolicy {
                chunk_tokens: 4,
                chunk_s: 0.01,
                decode_step_s: 0.005,
                max_chunks_per_step: 1 + rng.below(4),
            };
            let mut s = sched(1 + rng.below(6));
            let n_req = 3 + rng.below(8);
            for id in 0..n_req as u64 {
                let mut r = Request::new(id, vec![0; 1 + rng.below(20)], 1 + rng.below(5))
                    .with_tenant(rng.below(2) as u32);
                if rng.below(2) == 0 {
                    r = r.with_slo(f64::INFINITY, 0.05 + 0.01 * rng.below(5) as f64);
                }
                s.submit(r, 0.0);
            }
            let mut now = 0.0;
            let mut fed: std::collections::HashMap<u64, usize> = Default::default();
            let mut guard = 0;
            loop {
                guard += 1;
                prop_assert!(guard < 10_000, "plan loop does not converge");
                let plan = s.next_plan(now, &pol);
                prop_assert!(
                    plan.preempt.is_empty() && plan.resume.is_empty(),
                    "no admission gate: nothing preempts"
                );
                if plan.is_idle() {
                    prop_assert!(s.all_done(), "idle plan implies all work finished");
                    break;
                }
                for &id in &plan.start_prefill {
                    s.prefill_started(id);
                    fed.insert(id, 0);
                }
                for &id in &plan.chunks {
                    let total = s.session(id).unwrap().req.prompt.len();
                    let f = fed.get_mut(&id).unwrap();
                    *f = (*f + pol.chunk_tokens).min(total);
                    s.chunk_done(id, *f);
                    if *f == total {
                        s.prefill_done(id, 0, now);
                    }
                }
                for &id in &plan.decode {
                    s.token_decoded(id, 1, now + pol.decode_step_s);
                }
                now += pol.decode_step_s + plan.chunks.len() as f64 * pol.chunk_s;
                s.take_finished();
                // invariant: decode buffer mirrors the session table
                let n_decode =
                    s.sessions().filter(|x| x.phase == Phase::Decode).count();
                prop_assert_eq!(s.decodable().len(), n_decode);
            }
            prop_assert_eq!(s.sessions().count(), n_req);
            Ok(())
        });
    }
}
