//! Request and session state tracked by the coordinator.

use crate::kvcache::{TenantId, DEFAULT_TENANT};

/// An inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Tenant the request bills its KV blocks to (quota accounting and
    /// admission fairness key).
    pub tenant: TenantId,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Arrival time (seconds from trace start).
    pub arrive_s: f64,
    /// Prompt tokens expected to be served from an already-resident
    /// shared prefix (admission-footprint hint for callers without a
    /// live `PrefixRegistry` — the registry, when armed on the
    /// scheduler, supersedes this).
    pub prefix_tokens: usize,
    /// Time-to-first-token SLO: the first token must land within this
    /// many seconds of arrival. `INFINITY` = best-effort (no target).
    pub ttft_target_s: f64,
    /// Time-per-output-token SLO: the max acceptable gap between
    /// consecutive decoded tokens. `INFINITY` = best-effort.
    pub tpot_target_s: f64,
    /// Preemption priority: under hot-tier pressure the scheduler
    /// demotes lower-priority decoding sessions first. Higher is more
    /// important; best-effort traffic defaults to 0.
    pub priority: i32,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new: usize) -> Self {
        Request {
            id,
            tenant: DEFAULT_TENANT,
            prompt,
            max_new,
            arrive_s: 0.0,
            prefix_tokens: 0,
            ttft_target_s: f64::INFINITY,
            tpot_target_s: f64::INFINITY,
            priority: 0,
        }
    }

    /// Attribute the request to a tenant (builder form).
    pub fn with_tenant(mut self, tenant: TenantId) -> Self {
        self.tenant = tenant;
        self
    }

    /// Declare that `tokens` of the prompt are served from a shared
    /// prefix (builder form; see `prefix_tokens`).
    pub fn with_prefix_tokens(mut self, tokens: usize) -> Self {
        self.prefix_tokens = tokens;
        self
    }

    /// Attach TTFT/TPOT targets (builder form). `INFINITY` leaves a
    /// dimension best-effort.
    pub fn with_slo(mut self, ttft_s: f64, tpot_s: f64) -> Self {
        self.ttft_target_s = ttft_s;
        self.tpot_target_s = tpot_s;
        self
    }

    /// Set the preemption priority (builder form; higher survives
    /// longer under pressure).
    pub fn with_priority(mut self, priority: i32) -> Self {
        self.priority = priority;
        self
    }

    /// Whether any latency target is attached.
    pub fn has_slo(&self) -> bool {
        self.ttft_target_s.is_finite() || self.tpot_target_s.is_finite()
    }

    /// Absolute TTFT deadline (arrival + target; `INFINITY` when
    /// best-effort).
    pub fn ttft_deadline_s(&self) -> f64 {
        self.arrive_s + self.ttft_target_s
    }
}

/// Lifecycle phase of a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Queued,
    Prefill,
    Decode,
    /// Demoted to the cold tier mid-generation (snapshot parked, hot
    /// blocks reclaimed); resumes bit-identically into `Decode`.
    Preempted,
    Done,
}

/// Per-request serving state.
#[derive(Debug)]
pub struct Session {
    pub req: Request,
    pub phase: Phase,
    pub generated: Vec<i32>,
    /// Set when admission control refused the request outright (its
    /// estimated footprint can never fit the capacity/quota). Rejected
    /// sessions are `Done` with no generated tokens.
    pub rejected: bool,
    /// Prefix chain links of the prompt, computed once by the first
    /// admission-gate pass (the links are immutable per request; only
    /// the registry's entry map changes between passes).
    pub prefix_links: Option<Vec<(usize, u64)>>,
    /// Time the request was admitted / finished prefill / completed.
    pub admit_s: f64,
    pub first_token_s: f64,
    pub done_s: f64,
    /// Time the most recent token was emitted (TPOT slack accounting;
    /// `NaN` until the first token).
    pub last_token_s: f64,
    /// Prompt tokens already fed through chunked prefill.
    pub prefill_fed: usize,
    /// How many times this session was preempted to the cold tier.
    pub preemptions: u32,
}

impl Session {
    pub fn new(req: Request) -> Self {
        Session {
            req,
            phase: Phase::Queued,
            generated: Vec::new(),
            rejected: false,
            prefix_links: None,
            admit_s: f64::NAN,
            first_token_s: f64::NAN,
            done_s: f64::NAN,
            last_token_s: f64::NAN,
            prefill_fed: 0,
            preemptions: 0,
        }
    }

    /// Tokens decoded so far.
    pub fn n_generated(&self) -> usize {
        self.generated.len()
    }

    /// Whether generation is complete.
    pub fn finished(&self) -> bool {
        self.generated.len() >= self.req.max_new
    }

    /// Request latency (arrival -> completion).
    pub fn latency_s(&self) -> f64 {
        self.done_s - self.req.arrive_s
    }

    /// Seconds of TTFT slack left at `now` (negative = deadline blown;
    /// `INFINITY` = best-effort). Meaningful until the first token.
    pub fn ttft_slack_s(&self, now_s: f64) -> f64 {
        self.req.ttft_deadline_s() - now_s
    }

    /// Seconds until this decoding session's next token violates its
    /// TPOT target (measured from the last emitted token, or from
    /// `first_token_s` before any decode). `INFINITY` = best-effort.
    pub fn tpot_slack_s(&self, now_s: f64) -> f64 {
        if !self.req.tpot_target_s.is_finite() {
            return f64::INFINITY;
        }
        let last = if self.last_token_s.is_nan() { self.first_token_s } else { self.last_token_s };
        if last.is_nan() {
            return f64::INFINITY;
        }
        last + self.req.tpot_target_s - now_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_fields() {
        let mut s = Session::new(Request::new(1, vec![1, 2, 3], 2));
        assert_eq!(s.phase, Phase::Queued);
        assert_eq!(s.req.tenant, DEFAULT_TENANT);
        assert!(!s.rejected);
        assert!(!s.finished());
        s.generated.push(7);
        s.generated.push(8);
        assert!(s.finished());
        s.req.arrive_s = 1.0;
        s.done_s = 3.5;
        assert!((s.latency_s() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn with_tenant_attributes() {
        let r = Request::new(2, vec![1], 1).with_tenant(5);
        assert_eq!(r.tenant, 5);
        assert_eq!(r.id, 2);
    }

    #[test]
    fn slo_defaults_are_best_effort() {
        let r = Request::new(3, vec![1], 1);
        assert!(!r.has_slo());
        assert_eq!(r.ttft_deadline_s(), f64::INFINITY);
        let s = Session::new(r);
        assert_eq!(s.ttft_slack_s(1e9), f64::INFINITY);
        assert_eq!(s.tpot_slack_s(1e9), f64::INFINITY);
    }

    #[test]
    fn slo_slack_accounting() {
        let mut r = Request::new(4, vec![1], 4).with_slo(2.0, 0.5).with_priority(3);
        r.arrive_s = 10.0;
        assert!(r.has_slo());
        assert_eq!(r.priority, 3);
        let mut s = Session::new(r);
        // TTFT slack counts down from arrival
        assert!((s.ttft_slack_s(11.0) - 1.0).abs() < 1e-12);
        assert!(s.ttft_slack_s(12.5) < 0.0, "blown deadline goes negative");
        // no token yet: TPOT unconstrained
        assert_eq!(s.tpot_slack_s(11.0), f64::INFINITY);
        s.first_token_s = 11.0;
        assert!((s.tpot_slack_s(11.2) - 0.3).abs() < 1e-12);
        // later tokens measure from the most recent one
        s.last_token_s = 12.0;
        assert!((s.tpot_slack_s(12.1) - 0.4).abs() < 1e-12);
    }
}
