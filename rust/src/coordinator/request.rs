//! Request and session state tracked by the coordinator.

use crate::kvcache::{TenantId, DEFAULT_TENANT};

/// An inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Tenant the request bills its KV blocks to (quota accounting and
    /// admission fairness key).
    pub tenant: TenantId,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Arrival time (seconds from trace start).
    pub arrive_s: f64,
    /// Prompt tokens expected to be served from an already-resident
    /// shared prefix (admission-footprint hint for callers without a
    /// live `PrefixRegistry` — the registry, when armed on the
    /// scheduler, supersedes this).
    pub prefix_tokens: usize,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new: usize) -> Self {
        Request { id, tenant: DEFAULT_TENANT, prompt, max_new, arrive_s: 0.0, prefix_tokens: 0 }
    }

    /// Attribute the request to a tenant (builder form).
    pub fn with_tenant(mut self, tenant: TenantId) -> Self {
        self.tenant = tenant;
        self
    }

    /// Declare that `tokens` of the prompt are served from a shared
    /// prefix (builder form; see `prefix_tokens`).
    pub fn with_prefix_tokens(mut self, tokens: usize) -> Self {
        self.prefix_tokens = tokens;
        self
    }
}

/// Lifecycle phase of a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Queued,
    Prefill,
    Decode,
    Done,
}

/// Per-request serving state.
#[derive(Debug)]
pub struct Session {
    pub req: Request,
    pub phase: Phase,
    pub generated: Vec<i32>,
    /// Set when admission control refused the request outright (its
    /// estimated footprint can never fit the capacity/quota). Rejected
    /// sessions are `Done` with no generated tokens.
    pub rejected: bool,
    /// Prefix chain links of the prompt, computed once by the first
    /// admission-gate pass (the links are immutable per request; only
    /// the registry's entry map changes between passes).
    pub prefix_links: Option<Vec<(usize, u64)>>,
    /// Time the request was admitted / finished prefill / completed.
    pub admit_s: f64,
    pub first_token_s: f64,
    pub done_s: f64,
}

impl Session {
    pub fn new(req: Request) -> Self {
        Session {
            req,
            phase: Phase::Queued,
            generated: Vec::new(),
            rejected: false,
            prefix_links: None,
            admit_s: f64::NAN,
            first_token_s: f64::NAN,
            done_s: f64::NAN,
        }
    }

    /// Tokens decoded so far.
    pub fn n_generated(&self) -> usize {
        self.generated.len()
    }

    /// Whether generation is complete.
    pub fn finished(&self) -> bool {
        self.generated.len() >= self.req.max_new
    }

    /// Request latency (arrival -> completion).
    pub fn latency_s(&self) -> f64 {
        self.done_s - self.req.arrive_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_fields() {
        let mut s = Session::new(Request::new(1, vec![1, 2, 3], 2));
        assert_eq!(s.phase, Phase::Queued);
        assert_eq!(s.req.tenant, DEFAULT_TENANT);
        assert!(!s.rejected);
        assert!(!s.finished());
        s.generated.push(7);
        s.generated.push(8);
        assert!(s.finished());
        s.req.arrive_s = 1.0;
        s.done_s = 3.5;
        assert!((s.latency_s() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn with_tenant_attributes() {
        let r = Request::new(2, vec![1], 1).with_tenant(5);
        assert_eq!(r.tenant, 5);
        assert_eq!(r.id, 2);
    }
}
