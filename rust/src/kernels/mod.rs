//! Runtime-dispatched kernel layer for the decode hot path.
//!
//! Two backends (DESIGN.md "Kernel layer & dispatch"):
//!
//! * [`Backend::Scalar`] — the portable reference, bit-identical to the
//!   pre-kernel-layer code (`tensor::dot`'s historical 4-accumulator
//!   order is preserved exactly).
//! * [`Backend::Avx2Fma`] — AVX2+FMA paths selected at runtime with
//!   `is_x86_feature_detected!`. Bit-identical to itself (fixed lane
//!   layout and horizontal-sum shuffle tree per kernel), but not to the
//!   scalar backend: FMA fuses roundings and lanes regroup the sum.
//!   Scalar-vs-SIMD agreement is property-tested to tight tolerance in
//!   `tests/kernels.rs`.
//!
//! The process-wide backend is pinned on first use ([`active`]) and
//! logged once, so a run never mixes reduction orders: every
//! parallel==sequential bit-identity test in the repo holds under either
//! pinned kernel. `RETRO_KERNELS=scalar|simd|auto` overrides selection
//! (benchmarks construct [`Backend`] values directly instead, to compare
//! both in one process).
//!
//! Transcendentals stay scalar: `exp` in the fused softmax merge is
//! libm's on both backends, so the only scalar-vs-SIMD divergence is the
//! dot/axpy reduction order. A vectorized exp approximation would change
//! results by far more than FMA regrouping does.

use std::sync::OnceLock;

#[cfg(target_arch = "x86_64")]
mod avx2;
mod scalar;

/// A pinned kernel implementation. `Copy` so hot loops can pass it by
/// value; construct via [`active`] (process-pinned) or [`Backend::simd`]
/// (explicit, for benches/tests comparing both in one process).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Portable reference kernels with the historical reduction order.
    Scalar,
    /// AVX2+FMA kernels; falls back to scalar per-call if constructed on
    /// a machine without the features (so a stray value is safe, just
    /// slow — the dispatch shims re-check detection).
    Avx2Fma,
}

/// Inputs to the fused exp+axpy accumulation (pass 2 of the tripartite
/// merge): `scores` softmax-shifted by `max`, rows of width `d` drawn
/// from `rows`.
pub struct ExpAxpy<'a> {
    pub scores: &'a [f32],
    pub max: f32,
    pub rows: &'a [f32],
    pub d: usize,
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn simd_ok() -> bool {
    // std caches feature detection in an atomic; this is a load+test.
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn simd_dot(a: &[f32], b: &[f32]) -> f32 {
    if simd_ok() {
        // SAFETY: avx2+fma presence just checked.
        unsafe { avx2::dot(a, b) }
    } else {
        scalar::dot(a, b)
    }
}
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn simd_dot(a: &[f32], b: &[f32]) -> f32 {
    scalar::dot(a, b)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn simd_axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    if simd_ok() {
        // SAFETY: avx2+fma presence just checked.
        unsafe { avx2::axpy(alpha, x, y) }
    } else {
        scalar::axpy(alpha, x, y)
    }
}
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn simd_axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    scalar::axpy(alpha, x, y)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn simd_matvec_nt(q: &[f32], rows: &[f32], d: usize, out: &mut [f32]) {
    if simd_ok() {
        // SAFETY: avx2+fma presence just checked.
        unsafe { avx2::matvec_nt(q, rows, d, out) }
    } else {
        scalar::matvec_nt(q, rows, d, out)
    }
}
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn simd_matvec_nt(q: &[f32], rows: &[f32], d: usize, out: &mut [f32]) {
    scalar::matvec_nt(q, rows, d, out)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn simd_group_max(qs: &[f32], g: usize, rows: &[f32], d: usize, out: &mut [f32]) {
    if simd_ok() {
        // SAFETY: avx2+fma presence just checked.
        unsafe { avx2::group_max_scores(qs, g, rows, d, out) }
    } else {
        scalar::group_max_scores(qs, g, rows, d, out)
    }
}
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn simd_group_max(qs: &[f32], g: usize, rows: &[f32], d: usize, out: &mut [f32]) {
    scalar::group_max_scores(qs, g, rows, d, out)
}

impl Backend {
    /// The SIMD backend if this machine supports it.
    pub fn simd() -> Option<Backend> {
        #[cfg(target_arch = "x86_64")]
        {
            if simd_ok() {
                return Some(Backend::Avx2Fma);
            }
        }
        None
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2Fma => "avx2+fma",
        }
    }

    /// Dot product with this backend's fixed reduction order.
    #[inline]
    pub fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        match self {
            Backend::Scalar => scalar::dot(a, b),
            Backend::Avx2Fma => simd_dot(a, b),
        }
    }

    /// y += alpha * x.
    #[inline]
    pub fn axpy(&self, alpha: f32, x: &[f32], y: &mut [f32]) {
        match self {
            Backend::Scalar => scalar::axpy(alpha, x, y),
            Backend::Avx2Fma => simd_axpy(alpha, x, y),
        }
    }

    /// out[c] = q · rows[c] for `out.len()` contiguous rows of width `d`.
    #[inline]
    pub fn matvec_nt(&self, q: &[f32], rows: &[f32], d: usize, out: &mut [f32]) {
        match self {
            Backend::Scalar => scalar::matvec_nt(q, rows, d, out),
            Backend::Avx2Fma => simd_matvec_nt(q, rows, d, out),
        }
    }

    /// out[c] = max over the g queries in `qs` ([g, d] flat) of
    /// q_i · rows[c] (GQA group max used by cluster selection).
    #[inline]
    pub fn group_max_scores(&self, qs: &[f32], g: usize, rows: &[f32], d: usize, out: &mut [f32]) {
        match self {
            Backend::Scalar => scalar::group_max_scores(qs, g, rows, d, out),
            Backend::Avx2Fma => simd_group_max(qs, g, rows, d, out),
        }
    }

    /// Reduce a `[g, m]` GEMM score block to the per-column GQA group
    /// max: `out[c] = max_i gm[i*m + c]`. Comparison-only (no arithmetic),
    /// so it is backend-invariant; paired with [`Backend::gemm_nt`] it
    /// produces bit-identical results to [`Backend::group_max_scores`] —
    /// same per-(query, row) dots (gemm's 64-row tiles preserve both the
    /// 4-row block positions and the remainder-row set of the direct
    /// path), same strict-`>` first-max in query order (NaN scores never
    /// replace the running max, mirroring the direct path).
    #[inline]
    pub fn group_max_reduce(&self, gm: &[f32], g: usize, m: usize, out: &mut [f32]) {
        debug_assert_eq!(gm.len(), g * m);
        debug_assert_eq!(out.len(), m);
        out.fill(f32::NEG_INFINITY);
        for gi in 0..g {
            for (o, &s) in out.iter_mut().zip(&gm[gi * m..(gi + 1) * m]) {
                if s > *o {
                    *o = s;
                }
            }
        }
    }

    /// Blocked `[n,d] x [m,d]^T` GEMM: `out[i*m + j] = a_i · b_j`.
    /// B is tiled in blocks of rows so a tile stays cache-hot across all
    /// A rows; each output element is one `matvec_nt` row dot, so the
    /// result is bit-identical for ANY caller-side partition of the A
    /// rows (this is what makes pooled k-means assignment match serial).
    pub fn gemm_nt(&self, a: &[f32], b: &[f32], d: usize, out: &mut [f32]) {
        let n = if d == 0 { 0 } else { a.len() / d };
        let m = if d == 0 { 0 } else { b.len() / d };
        debug_assert_eq!(out.len(), n * m);
        // 64 rows of d<=128 f32 = <=32 KiB per tile: fits L1d alongside a.
        const TILE_B_ROWS: usize = 64;
        let mut j0 = 0;
        while j0 < m {
            let jt = (m - j0).min(TILE_B_ROWS);
            let bt = &b[j0 * d..(j0 + jt) * d];
            for i in 0..n {
                let orow = &mut out[i * m + j0..i * m + j0 + jt];
                self.matvec_nt(&a[i * d..(i + 1) * d], bt, d, orow);
            }
            j0 += jt;
        }
    }

    /// Score `out.len() <- rows.len()/d` contiguous rows: fills `out`
    /// with `scale * (q · row_c)` and returns the running max (NaN
    /// scores are skipped by the max, mirroring `f32::max`).
    pub fn score_rows(
        &self,
        q: &[f32],
        rows: &[f32],
        d: usize,
        scale: f32,
        out: &mut Vec<f32>,
    ) -> f32 {
        let m = if d == 0 { 0 } else { rows.len() / d };
        out.clear();
        out.resize(m, 0.0);
        self.matvec_nt(q, rows, d, out);
        let mut mx = f32::NEG_INFINITY;
        for s in out.iter_mut() {
            *s *= scale;
            mx = mx.max(*s);
        }
        mx
    }

    /// Score an indexed row subset: `out[i] = scale * (q · rows[idx[i]])`,
    /// returning the running max.
    pub fn score_indexed(
        &self,
        q: &[f32],
        rows: &[f32],
        d: usize,
        scale: f32,
        idx: &[usize],
        out: &mut Vec<f32>,
    ) -> f32 {
        out.clear();
        out.reserve(idx.len());
        let mut mx = f32::NEG_INFINITY;
        for &i in idx {
            let s = self.dot(q, &rows[i * d..(i + 1) * d]) * scale;
            out.push(s);
            mx = mx.max(s);
        }
        mx
    }

    /// Fused softmax-accumulate over an indexed row subset (pass 2 of the
    /// tripartite merge): for each score, `w = exp(s - max)` (scalar libm
    /// on both backends), `out += w * rows[idx[i]]`, and the returned f64
    /// denominator accumulates `w` — or `w * weights[idx[i]]` when
    /// cluster sizes are supplied — in index order.
    pub fn exp_axpy(
        &self,
        p: &ExpAxpy<'_>,
        idx: &[usize],
        weights: Option<&[f32]>,
        out: &mut [f32],
    ) -> f64 {
        let d = p.d;
        let mut denom = 0.0f64;
        for (s, &i) in p.scores.iter().zip(idx) {
            let w = (s - p.max).exp();
            denom += match weights {
                Some(ws) => (w * ws[i]) as f64,
                None => w as f64,
            };
            self.axpy(w, &p.rows[i * d..(i + 1) * d], out);
        }
        denom
    }

    /// `exp_axpy` over contiguous rows 0..scores.len() (full attention).
    pub fn exp_axpy_rows(&self, p: &ExpAxpy<'_>, out: &mut [f32]) -> f64 {
        let d = p.d;
        let mut denom = 0.0f64;
        for (i, s) in p.scores.iter().enumerate() {
            let w = (s - p.max).exp();
            denom += w as f64;
            self.axpy(w, &p.rows[i * d..(i + 1) * d], out);
        }
        denom
    }
}

static ACTIVE: OnceLock<Backend> = OnceLock::new();

/// The process-pinned backend. Selection happens exactly once:
/// `RETRO_KERNELS=scalar` forces the reference kernels,
/// `RETRO_KERNELS=simd` (or `avx2`) requests SIMD (falling back to
/// scalar if undetected), anything else auto-detects. The choice is
/// logged to stderr so a run's kernel is recorded next to its output.
pub fn active() -> Backend {
    *ACTIVE.get_or_init(|| {
        let want = std::env::var("RETRO_KERNELS").unwrap_or_default();
        let bk = match want.as_str() {
            "scalar" => Backend::Scalar,
            "simd" | "avx2" => Backend::simd().unwrap_or(Backend::Scalar),
            _ => Backend::simd().unwrap_or(Backend::Scalar),
        };
        eprintln!(
            "[kernels] backend pinned: {} (RETRO_KERNELS={})",
            bk.name(),
            if want.is_empty() { "auto" } else { want.as_str() }
        );
        bk
    })
}

/// Dot product with the process-pinned backend.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    active().dot(a, b)
}

/// y += alpha * x with the process-pinned backend.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    active().axpy(alpha, x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_dot_matches_naive_tolerance() {
        let a: Vec<f32> = (0..13).map(|x| x as f32 * 0.5).collect();
        let b: Vec<f32> = (0..13).map(|x| (13 - x) as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((Backend::Scalar.dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn gemm_matches_per_row_dots() {
        let d = 7;
        let (n, m) = (5, 130); // m spans two B tiles
        let a: Vec<f32> = (0..n * d).map(|x| (x as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..m * d).map(|x| (x as f32 * 0.11).cos()).collect();
        let mut out = vec![0.0f32; n * m];
        Backend::Scalar.gemm_nt(&a, &b, d, &mut out);
        for i in 0..n {
            for j in 0..m {
                let r = Backend::Scalar.dot(&a[i * d..(i + 1) * d], &b[j * d..(j + 1) * d]);
                assert_eq!(out[i * m + j], r, "gemm tile boundary changed the reduction order");
            }
        }
    }

    #[test]
    fn gemm_is_partition_invariant() {
        // bit-identity across caller-side row partitions: the property
        // pooled k-means assignment relies on.
        let d = 16;
        let (n, m) = (9, 70);
        let a: Vec<f32> = (0..n * d).map(|x| (x as f32 * 0.19).sin()).collect();
        let b: Vec<f32> = (0..m * d).map(|x| (x as f32 * 0.07).cos()).collect();
        let bk = active();
        let mut whole = vec![0.0f32; n * m];
        bk.gemm_nt(&a, &b, d, &mut whole);
        let mut parts = vec![0.0f32; n * m];
        let split = 4;
        bk.gemm_nt(&a[..split * d], &b, d, &mut parts[..split * m]);
        bk.gemm_nt(&a[split * d..], &b, d, &mut parts[split * m..]);
        assert_eq!(whole, parts);
    }

    #[test]
    fn active_is_pinned() {
        assert_eq!(active(), active());
    }

    #[test]
    fn group_max_picks_best_query() {
        let d = 4;
        let qs = vec![1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0]; // g=2
        let rows = vec![0.5, 3.0, 0.0, 0.0, 2.0, -1.0, 0.0, 0.0];
        let mut out = vec![0.0f32; 2];
        Backend::Scalar.group_max_scores(&qs, 2, &rows, d, &mut out);
        assert_eq!(out, vec![3.0, 2.0]);
    }

    #[test]
    fn gemm_plus_reduce_matches_group_max_bitwise() {
        // The GQA-batched selection path (one gemm_nt over the group's
        // queries + a comparison-only column reduce) must equal the
        // fused group_max_scores kernel bit-for-bit on the active
        // backend — this is what keeps batched centroid scoring
        // bit-identical to the per-head path.
        let bk = active();
        let d = 24;
        for &(g, m) in &[(1usize, 5usize), (2, 67), (4, 130), (3, 64)] {
            let qs: Vec<f32> = (0..g * d).map(|x| (x as f32 * 0.23).sin()).collect();
            let rows: Vec<f32> = (0..m * d).map(|x| (x as f32 * 0.13).cos()).collect();
            let mut direct = vec![0.0f32; m];
            bk.group_max_scores(&qs, g, &rows, d, &mut direct);
            let mut gm = vec![0.0f32; g * m];
            bk.gemm_nt(&qs, &rows, d, &mut gm);
            let mut reduced = vec![0.0f32; m];
            bk.group_max_reduce(&gm, g, m, &mut reduced);
            let db: Vec<u32> = direct.iter().map(|x| x.to_bits()).collect();
            let rb: Vec<u32> = reduced.iter().map(|x| x.to_bits()).collect();
            assert_eq!(db, rb, "g={g} m={m}: batched scoring diverged from fused kernel");
        }
    }

    #[test]
    fn empty_inputs_are_safe() {
        let bk = active();
        assert_eq!(bk.dot(&[], &[]), 0.0);
        bk.axpy(2.0, &[], &mut []);
        bk.matvec_nt(&[], &[], 0, &mut []);
        let mut out = Vec::new();
        assert_eq!(bk.score_rows(&[], &[], 0, 1.0, &mut out), f32::NEG_INFINITY);
        assert!(out.is_empty());
    }
}
