//! AVX2+FMA kernels (x86_64 only). Callers must verify `avx2` and `fma`
//! with `is_x86_feature_detected!` before entering (the dispatch shims in
//! `kernels::mod` do); every function is `#[target_feature]`-gated and
//! therefore `unsafe` to call.
//!
//! Reduction-order contract: each kernel commits to ONE lane/accumulator
//! layout and ONE horizontal-sum shuffle sequence, so the SIMD backend is
//! bit-identical to itself across runs and call sites. It is NOT
//! bit-identical to the scalar backend (FMA fuses the multiply-add
//! rounding and lanes regroup the sum); agreement is tolerance-tested in
//! `tests/kernels.rs`.

use std::arch::x86_64::*;

/// Fixed horizontal sum of 8 lanes: (lo128 + hi128), movehl fold, then a
/// lane-1 shuffle fold. Same shuffle tree everywhere.
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn hsum8(v: __m256) -> f32 {
    unsafe {
        let hi = _mm256_extractf128_ps::<1>(v);
        let lo = _mm256_castps256_ps128(v);
        let s4 = _mm_add_ps(lo, hi);
        let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
        let s1 = _mm_add_ss(s2, _mm_shuffle_ps::<0b01>(s2, s2));
        _mm_cvtss_f32(s1)
    }
}

/// Dot product: two 8-lane FMA accumulators over 16-element chunks, an
/// optional single 8-lane chunk, `hsum8(acc0 + acc1)`, then an FMA scalar
/// tail in index order.
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    unsafe {
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let n16 = n / 16 * 16;
        let mut j = 0;
        while j < n16 {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(j)), _mm256_loadu_ps(bp.add(j)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(j + 8)),
                _mm256_loadu_ps(bp.add(j + 8)),
                acc1,
            );
            j += 16;
        }
        if j + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(j)), _mm256_loadu_ps(bp.add(j)), acc0);
            j += 8;
        }
        let mut s = hsum8(_mm256_add_ps(acc0, acc1));
        while j < n {
            s = (*ap.add(j)).mul_add(*bp.add(j), s);
            j += 1;
        }
        s
    }
}

/// y += alpha * x: 8-lane FMA body, FMA scalar tail in index order.
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    unsafe {
        let n = x.len();
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let va = _mm256_set1_ps(alpha);
        let n8 = n / 8 * 8;
        let mut j = 0;
        while j < n8 {
            let acc = _mm256_fmadd_ps(va, _mm256_loadu_ps(xp.add(j)), _mm256_loadu_ps(yp.add(j)));
            _mm256_storeu_ps(yp.add(j), acc);
            j += 8;
        }
        while j < n {
            *yp.add(j) = (*xp.add(j)).mul_add(alpha, *yp.add(j));
            j += 1;
        }
    }
}

/// Scores 4 rows against one query with 4 independent 8-lane FMA
/// accumulators (register-blocked so `q` is loaded once per 8 columns).
/// Returns the 4 dots; tails use scalar FMA in index order.
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot4(
    q: &[f32],
    r0: *const f32,
    r1: *const f32,
    r2: *const f32,
    r3: *const f32,
) -> [f32; 4] {
    unsafe {
        let d = q.len();
        let qp = q.as_ptr();
        let mut a0 = _mm256_setzero_ps();
        let mut a1 = _mm256_setzero_ps();
        let mut a2 = _mm256_setzero_ps();
        let mut a3 = _mm256_setzero_ps();
        let n8 = d / 8 * 8;
        let mut j = 0;
        while j < n8 {
            let qv = _mm256_loadu_ps(qp.add(j));
            a0 = _mm256_fmadd_ps(qv, _mm256_loadu_ps(r0.add(j)), a0);
            a1 = _mm256_fmadd_ps(qv, _mm256_loadu_ps(r1.add(j)), a1);
            a2 = _mm256_fmadd_ps(qv, _mm256_loadu_ps(r2.add(j)), a2);
            a3 = _mm256_fmadd_ps(qv, _mm256_loadu_ps(r3.add(j)), a3);
            j += 8;
        }
        let mut s = [hsum8(a0), hsum8(a1), hsum8(a2), hsum8(a3)];
        while j < d {
            let qj = *qp.add(j);
            s[0] = (*r0.add(j)).mul_add(qj, s[0]);
            s[1] = (*r1.add(j)).mul_add(qj, s[1]);
            s[2] = (*r2.add(j)).mul_add(qj, s[2]);
            s[3] = (*r3.add(j)).mul_add(qj, s[3]);
            j += 1;
        }
        s
    }
}

/// out[c] = q · rows[c]: rows processed in blocks of 4 via `dot4`, then a
/// per-row `dot` remainder. Note the remainder rows use `dot`'s two-
/// accumulator order while blocked rows use `dot4`'s single accumulator —
/// the order depends only on (d, row position), so outputs are still
/// deterministic for a given shape.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn matvec_nt(q: &[f32], rows: &[f32], d: usize, out: &mut [f32]) {
    debug_assert!(rows.len() >= out.len() * d);
    unsafe {
        let m = out.len();
        let rp = rows.as_ptr();
        let m4 = m / 4 * 4;
        let mut c = 0;
        while c < m4 {
            let s = dot4(
                q,
                rp.add(c * d),
                rp.add((c + 1) * d),
                rp.add((c + 2) * d),
                rp.add((c + 3) * d),
            );
            out[c..c + 4].copy_from_slice(&s);
            c += 4;
        }
        while c < m {
            out[c] = dot(q, &rows[c * d..(c + 1) * d]);
            c += 1;
        }
    }
}

/// out[c] = max_i qs[i] · rows[c] over the g queries in `qs` ([g, d]).
/// Same 4-row blocking as `matvec_nt`; the max uses strict `>` (first
/// maximal query wins), matching the scalar backend.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn group_max_scores(qs: &[f32], g: usize, rows: &[f32], d: usize, out: &mut [f32]) {
    debug_assert!(qs.len() >= g * d);
    debug_assert!(rows.len() >= out.len() * d);
    unsafe {
        let m = out.len();
        let rp = rows.as_ptr();
        let m4 = m / 4 * 4;
        let mut c = 0;
        while c < m4 {
            let mut best = [f32::NEG_INFINITY; 4];
            for gi in 0..g {
                let s = dot4(
                    &qs[gi * d..(gi + 1) * d],
                    rp.add(c * d),
                    rp.add((c + 1) * d),
                    rp.add((c + 2) * d),
                    rp.add((c + 3) * d),
                );
                for (b, v) in best.iter_mut().zip(s) {
                    if v > *b {
                        *b = v;
                    }
                }
            }
            out[c..c + 4].copy_from_slice(&best);
            c += 4;
        }
        while c < m {
            let row = &rows[c * d..(c + 1) * d];
            let mut best = f32::NEG_INFINITY;
            for gi in 0..g {
                let s = dot(&qs[gi * d..(gi + 1) * d], row);
                if s > best {
                    best = s;
                }
            }
            out[c] = best;
            c += 1;
        }
    }
}
