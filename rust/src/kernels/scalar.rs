//! Scalar reference kernels: the portable fallback and the numeric
//! ground truth the SIMD paths are property-tested against.
//!
//! Reduction-order contract (DESIGN.md "Kernel layer & dispatch"): every
//! function here has ONE fixed accumulation order, so the scalar backend
//! is bit-identical to itself across runs, threads, and call sites.
//! `dot` keeps the exact 4-accumulator order the repo shipped with (the
//! pre-kernel `tensor::dot`), so pinning `RETRO_KERNELS=scalar`
//! reproduces historical outputs bit-for-bit.

/// Dot product, unrolled by 4 with the `(s0+s1)+(s2+s3)`-free layout the
/// original `tensor::dot` used: `s0 + s1 + s2 + s3` left-to-right, then
/// the scalar remainder.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// y += alpha * x, in index order (two roundings per element — no FMA —
/// matching the original `tensor::axpy`).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// out[c] = q · rows[c] for `out.len()` rows of width `d`.
#[inline]
pub fn matvec_nt(q: &[f32], rows: &[f32], d: usize, out: &mut [f32]) {
    debug_assert!(rows.len() >= out.len() * d);
    for (c, o) in out.iter_mut().enumerate() {
        *o = dot(q, &rows[c * d..(c + 1) * d]);
    }
}

/// out[c] = max over the g queries in `qs` ([g, d] flat) of q_i · rows[c].
/// Strict `>` keeps the first (lowest query index) maximum, which matches
/// a left fold with `f32::max` on NaN-free and all-NaN inputs alike.
#[inline]
pub fn group_max_scores(qs: &[f32], g: usize, rows: &[f32], d: usize, out: &mut [f32]) {
    debug_assert!(qs.len() >= g * d);
    debug_assert!(rows.len() >= out.len() * d);
    for (c, o) in out.iter_mut().enumerate() {
        let row = &rows[c * d..(c + 1) * d];
        let mut best = f32::NEG_INFINITY;
        for gi in 0..g {
            let s = dot(&qs[gi * d..(gi + 1) * d], row);
            if s > best {
                best = s;
            }
        }
        *o = best;
    }
}
