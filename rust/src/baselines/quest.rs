//! Quest (Tang et al., ICML'24): query-aware page selection. The context
//! is split into fixed chunks; each chunk keeps elementwise min/max key
//! vectors as representatives. A chunk's upper-bound score for query q is
//! sum_j max(q_j*min_j, q_j*max_j); the top-scoring chunks within budget
//! are attended exactly. GPU-only: the whole KV cache (plus
//! representatives) stays in GPU memory.

use super::{DecodeStats, SparseSystem};
use crate::attention::subset_attention;

pub struct Quest {
    d: usize,
    chunk: usize,
    keys: Vec<f32>,
    vals: Vec<f32>,
    /// Per-chunk elementwise min/max of keys: `[n_chunks, d]` each.
    cmin: Vec<f32>,
    cmax: Vec<f32>,
}

impl Quest {
    pub fn new(keys: &[f32], vals: &[f32], d: usize, chunk: usize) -> Self {
        let mut q = Quest {
            d,
            chunk,
            keys: Vec::new(),
            vals: Vec::new(),
            cmin: Vec::new(),
            cmax: Vec::new(),
        };
        q.keys = keys.to_vec();
        q.vals = vals.to_vec();
        q.rebuild_representatives();
        q
    }

    fn n(&self) -> usize {
        self.keys.len() / self.d
    }

    fn n_chunks(&self) -> usize {
        self.n().div_ceil(self.chunk)
    }

    fn rebuild_representatives(&mut self) {
        let (n, d) = (self.n(), self.d);
        let nc = n.div_ceil(self.chunk);
        self.cmin = vec![f32::INFINITY; nc * d];
        self.cmax = vec![f32::NEG_INFINITY; nc * d];
        for i in 0..n {
            let c = i / self.chunk;
            for j in 0..d {
                let k = self.keys[i * d + j];
                let mn = &mut self.cmin[c * d + j];
                if k < *mn {
                    *mn = k;
                }
                let mx = &mut self.cmax[c * d + j];
                if k > *mx {
                    *mx = k;
                }
            }
        }
    }

    /// Upper-bound score of chunk `c` (Quest Eq. 1).
    fn chunk_score(&self, q: &[f32], c: usize) -> f32 {
        let d = self.d;
        let mut s = 0.0;
        for j in 0..d {
            s += (q[j] * self.cmin[c * d + j]).max(q[j] * self.cmax[c * d + j]);
        }
        s
    }
}

impl SparseSystem for Quest {
    fn name(&self) -> &'static str {
        "quest"
    }

    fn decode(&mut self, q: &[f32], budget: usize, out: &mut [f32]) -> DecodeStats {
        let nc = self.n_chunks();
        let n = self.n();
        let want_chunks = budget.div_ceil(self.chunk).min(nc).max(1);
        let mut order: Vec<usize> = (0..nc).collect();
        let scores: Vec<f32> = (0..nc).map(|c| self.chunk_score(q, c)).collect();
        if want_chunks < nc {
            order.select_nth_unstable_by(want_chunks - 1, |&a, &b| {
                scores[b].partial_cmp(&scores[a]).unwrap()
            });
        }
        let mut sel = Vec::with_capacity(want_chunks * self.chunk);
        for &c in &order[..want_chunks] {
            let start = c * self.chunk;
            let end = ((c + 1) * self.chunk).min(n);
            sel.extend(start..end);
        }
        subset_attention(q, &self.keys, &self.vals, self.d, &sel, out);
        DecodeStats {
            exact_positions: sel.iter().map(|&i| i as u32).collect(),
            hbm_bytes: 2 * sel.len() * self.d * 4,
            scan_bytes: 2 * nc * self.d * 4, // min+max representative scan
            ..DecodeStats::default()
        }
    }

    fn append(&mut self, key: &[f32], val: &[f32]) {
        let d = self.d;
        let i = self.n();
        self.keys.extend_from_slice(key);
        self.vals.extend_from_slice(val);
        let c = i / self.chunk;
        if c * d >= self.cmin.len() {
            self.cmin.extend(std::iter::repeat(f32::INFINITY).take(d));
            self.cmax.extend(std::iter::repeat(f32::NEG_INFINITY).take(d));
        }
        for j in 0..d {
            let k = key[j];
            if k < self.cmin[c * d + j] {
                self.cmin[c * d + j] = k;
            }
            if k > self.cmax[c * d + j] {
                self.cmax[c * d + j] = k;
            }
        }
    }

    fn kv_on_gpu(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::attention_weights;
    use crate::util::rng::Rng;

    #[test]
    fn upper_bound_property() {
        // chunk score must upper-bound every member's exact score
        let d = 8;
        let mut rng = Rng::new(4);
        let keys = rng.normal_vec(64 * d);
        let vals = rng.normal_vec(64 * d);
        let sys = Quest::new(&keys, &vals, d, 16);
        let q = rng.normal_vec(d);
        for c in 0..4 {
            let ub = sys.chunk_score(&q, c);
            for i in c * 16..(c + 1) * 16 {
                let s: f32 = (0..d).map(|j| q[j] * keys[i * d + j]).sum();
                assert!(s <= ub + 1e-4, "chunk {c} token {i}: {s} > {ub}");
            }
        }
    }

    #[test]
    fn finds_needle_chunk() {
        let d = 8;
        let mut rng = Rng::new(5);
        let mut keys = rng.normal_vec(256 * d);
        let vals = rng.normal_vec(256 * d);
        // plant needle at 100
        let dir = rng.normal_vec(d);
        for j in 0..d {
            keys[100 * d + j] = 5.0 * dir[j];
        }
        let q: Vec<f32> = dir.iter().map(|x| 5.0 * x).collect();
        let mut sys = Quest::new(&keys, &vals, d, 16);
        let mut out = vec![0.0; d];
        let st = sys.decode(&q, 32, &mut out);
        assert!(st.exact_positions.contains(&100));
        let w = attention_weights(&q, &keys, d);
        assert!(w[100] > 0.5);
    }

    #[test]
    fn append_updates_representatives() {
        let d = 4;
        let mut rng = Rng::new(6);
        let keys = rng.normal_vec(16 * d);
        let vals = rng.normal_vec(16 * d);
        let mut sys = Quest::new(&keys, &vals, d, 16);
        // appending starts a new chunk
        sys.append(&[9.0; 4], &[1.0; 4]);
        assert_eq!(sys.n(), 17);
        assert_eq!(sys.n_chunks(), 2);
        assert_eq!(sys.cmax[1 * d], 9.0);
    }

    #[test]
    fn budget_controls_selection_size() {
        let d = 8;
        let mut rng = Rng::new(7);
        let keys = rng.normal_vec(128 * d);
        let vals = rng.normal_vec(128 * d);
        let mut sys = Quest::new(&keys, &vals, d, 16);
        let q = rng.normal_vec(d);
        let mut out = vec![0.0; d];
        let st = sys.decode(&q, 32, &mut out);
        assert_eq!(st.exact_positions.len(), 32); // 2 chunks
    }
}
