//! MagicPIG (Chen et al., ICLR'25): LSH importance sampling. SimHash
//! signatures (K hyperplanes per table, L tables) are built over centered
//! keys; a token is *sampled* when its signature collides with the query
//! in at least one table. Sampled tokens get unbiased softmax weights via
//! 1/p_i correction, where p_i = 1-(1-p^K)^L and p is the per-plane
//! collision probability (1 - theta/pi). Attention runs on the CPU —
//! MagicPIG's defining system trait (and its throughput ceiling).

use super::{DecodeStats, SparseSystem};
use crate::tensor::{dot, norm};
use crate::util::rng::Rng;

pub struct MagicPig {
    d: usize,
    k_bits: usize,
    l_tables: usize,
    keys: Vec<f32>,
    vals: Vec<f32>,
    /// Centering vector (all-but-the-top, as in the paper).
    mu: Vec<f32>,
    /// `[l_tables * k_bits, d]` random hyperplanes.
    planes: Vec<f32>,
    /// Per-token signatures: `[n, l_tables]` packed bit patterns.
    sigs: Vec<u32>,
}

impl MagicPig {
    pub fn new(keys: &[f32], vals: &[f32], d: usize, k_bits: usize, l_tables: usize, seed: u64) -> Self {
        assert!(k_bits <= 32);
        let n = keys.len() / d;
        let mut rng = Rng::new(seed ^ xp1g_u64());
        let planes = rng.normal_vec(l_tables * k_bits * d);
        let mut mu = vec![0.0f32; d];
        for i in 0..n {
            for j in 0..d {
                mu[j] += keys[i * d + j];
            }
        }
        mu.iter_mut().for_each(|x| *x /= n.max(1) as f32);
        let mut mp = MagicPig {
            d,
            k_bits,
            l_tables,
            keys: keys.to_vec(),
            vals: vals.to_vec(),
            mu,
            planes,
            sigs: Vec::new(),
        };
        mp.sigs = (0..n).flat_map(|i| mp.signatures_of(&mp.centered(i))).collect();
        mp
    }

    fn n(&self) -> usize {
        self.keys.len() / self.d
    }

    fn centered(&self, i: usize) -> Vec<f32> {
        let d = self.d;
        (0..d).map(|j| self.keys[i * d + j] - self.mu[j]).collect()
    }

    /// One packed K-bit signature per table.
    fn signatures_of(&self, x: &[f32]) -> Vec<u32> {
        let d = self.d;
        (0..self.l_tables)
            .map(|t| {
                let mut sig = 0u32;
                for b in 0..self.k_bits {
                    let p = &self.planes[(t * self.k_bits + b) * d..(t * self.k_bits + b + 1) * d];
                    if dot(x, p) >= 0.0 {
                        sig |= 1 << b;
                    }
                }
                sig
            })
            .collect()
    }

    /// Sampling probability for angle `theta` between q and k.
    fn sample_prob(&self, cos_sim: f32) -> f64 {
        let theta = cos_sim.clamp(-1.0, 1.0).acos() as f64;
        let p = 1.0 - theta / std::f64::consts::PI;
        1.0 - (1.0 - p.powi(self.k_bits as i32)).powi(self.l_tables as i32)
    }
}

fn xp1g_u64() -> u64 {
    0x7069675f6c736800 // deterministic salt
}

impl SparseSystem for MagicPig {
    fn name(&self) -> &'static str {
        "magicpig"
    }

    fn decode(&mut self, q: &[f32], _budget: usize, out: &mut [f32]) -> DecodeStats {
        let d = self.d;
        let n = self.n();
        let qc: Vec<f32> = (0..d).map(|j| q[j] - self.mu[j]).collect();
        let qsigs = self.signatures_of(&qc);
        // Collision in >= 1 table => sampled.
        let mut sampled = Vec::new();
        for i in 0..n {
            let s = &self.sigs[i * self.l_tables..(i + 1) * self.l_tables];
            if s.iter().zip(&qsigs).any(|(a, b)| a == b) {
                sampled.push(i);
            }
        }
        // Unbiased softmax with 1/p_i corrections (importance sampling).
        let scale = 1.0 / (d as f32).sqrt();
        let qn = norm(&qc).max(1e-12);
        let mut m = f32::NEG_INFINITY;
        let mut scores = Vec::with_capacity(sampled.len());
        for &i in &sampled {
            let s = dot(q, &self.keys[i * d..(i + 1) * d]) * scale;
            scores.push(s);
            m = m.max(s);
        }
        out.iter_mut().for_each(|o| *o = 0.0);
        if !m.is_finite() {
            return DecodeStats::default();
        }
        let mut denom = 0.0f64;
        let mut acc = vec![0.0f64; d];
        for (idx, &i) in sampled.iter().enumerate() {
            let kc = self.centered(i);
            let cos = dot(&qc, &kc) / (qn * norm(&kc).max(1e-12));
            let p = self.sample_prob(cos).max(1e-6);
            let w = ((scores[idx] - m).exp() as f64) / p;
            denom += w;
            for j in 0..d {
                acc[j] += w * self.vals[i * d + j] as f64;
            }
        }
        let inv = 1.0 / denom.max(1e-30);
        for j in 0..d {
            out[j] = (acc[j] * inv) as f32;
        }
        DecodeStats {
            exact_positions: sampled.iter().map(|&i| i as u32).collect(),
            // CPU reads the sampled KV vectors; signatures scanned too.
            cpu_bytes: 2 * sampled.len() * d * 4,
            scan_bytes: n * self.l_tables * 4,
            ..DecodeStats::default()
        }
    }

    fn append(&mut self, _key: &[f32], _val: &[f32]) {
        // MagicPIG's published implementation has no index update path;
        // appended tokens are simply not indexed (paper excludes it from
        // long-generation experiments).
    }

    fn supports_updates(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::cosine;

    #[test]
    fn needle_is_sampled_with_high_probability() {
        let d = 16;
        let mut rng = Rng::new(8);
        let mut keys = rng.normal_vec(512 * d);
        let vals = rng.normal_vec(512 * d);
        let dir = rng.normal_vec(d);
        for j in 0..d {
            keys[300 * d + j] = 4.0 * dir[j];
        }
        let q: Vec<f32> = dir.iter().map(|x| 4.0 * x).collect();
        let mut sys = MagicPig::new(&keys, &vals, d, 8, 48, 1);
        let mut out = vec![0.0; d];
        let st = sys.decode(&q, 0, &mut out);
        assert!(
            st.exact_positions.contains(&300),
            "aligned needle must collide in some table (sampled {} tokens)",
            st.exact_positions.len()
        );
    }

    #[test]
    fn sampling_is_sparse() {
        let d = 16;
        let mut rng = Rng::new(9);
        let keys = rng.normal_vec(1024 * d);
        let vals = rng.normal_vec(1024 * d);
        let q = rng.normal_vec(d);
        let mut sys = MagicPig::new(&keys, &vals, d, 10, 20, 2);
        let mut out = vec![0.0; d];
        let st = sys.decode(&q, 0, &mut out);
        assert!(
            st.exact_positions.len() < 512,
            "random queries should sample a minority: {}",
            st.exact_positions.len()
        );
        assert!(st.cpu_bytes > 0);
    }

    #[test]
    fn estimate_tracks_full_attention_on_peaked_dist() {
        let d = 16;
        let mut rng = Rng::new(10);
        let mut keys = rng.normal_vec(512 * d);
        let vals = rng.normal_vec(512 * d);
        let dir = rng.normal_vec(d);
        for j in 0..d {
            keys[100 * d + j] = 4.0 * dir[j];
        }
        let q: Vec<f32> = dir.iter().map(|x| 4.0 * x).collect();
        let mut full = vec![0.0; d];
        crate::attention::full_attention(&q, &keys, &vals, d, &mut full);
        let mut sys = MagicPig::new(&keys, &vals, d, 8, 64, 3);
        let mut out = vec![0.0; d];
        sys.decode(&q, 0, &mut out);
        assert!(cosine(&out, &full) > 0.9, "cos = {}", cosine(&out, &full));
    }

    #[test]
    fn no_update_support() {
        let d = 4;
        let keys = vec![0.1; 16];
        let vals = vec![0.1; 16];
        let mut sys = MagicPig::new(&keys, &vals, d, 4, 4, 4);
        assert!(!sys.supports_updates());
        sys.append(&[1.0; 4], &[1.0; 4]); // must not panic, not indexed
        assert_eq!(sys.n(), 4);
    }
}
