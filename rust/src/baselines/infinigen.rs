//! InfiniGen (Lee et al., OSDI'24): speculative prefetch via partial
//! channels. A subset of key channels (the high-magnitude ones after the
//! paper's SVD skew) approximates attention scores; the top-budget tokens
//! by partial score are fetched from CPU memory for exact attention.
//! The partial key cache must stay GPU-resident for speculation — the
//! reason InfiniGen OOMs at 1M context (paper §5.3).

use super::{DecodeStats, SparseSystem};
use crate::attention::subset_attention;

pub struct InfiniGen {
    d: usize,
    n_channels: usize,
    /// Channels used for speculation, chosen by key-magnitude skew.
    channels: Vec<usize>,
    keys: Vec<f32>,
    vals: Vec<f32>,
    /// GPU-resident partial keys `[n, n_channels]`.
    partial: Vec<f32>,
}

impl InfiniGen {
    pub fn new(keys: &[f32], vals: &[f32], d: usize, n_channels: usize) -> Self {
        let n = keys.len() / d;
        let n_channels = n_channels.min(d);
        // Channel energy: sum of squares per dim (stand-in for the SVD
        // skew the paper computes offline on layer inputs).
        let mut energy = vec![0.0f64; d];
        for i in 0..n {
            for j in 0..d {
                let k = keys[i * d + j] as f64;
                energy[j] += k * k;
            }
        }
        let mut order: Vec<usize> = (0..d).collect();
        order.sort_by(|&a, &b| energy[b].partial_cmp(&energy[a]).unwrap());
        let mut channels = order[..n_channels].to_vec();
        channels.sort_unstable();
        let mut ig = InfiniGen {
            d,
            n_channels,
            channels,
            keys: keys.to_vec(),
            vals: vals.to_vec(),
            partial: Vec::new(),
        };
        ig.partial = (0..n).flat_map(|i| ig.partial_of(i)).collect();
        ig
    }

    fn n(&self) -> usize {
        self.keys.len() / self.d
    }

    fn partial_of(&self, i: usize) -> Vec<f32> {
        self.channels.iter().map(|&j| self.keys[i * self.d + j]).collect()
    }
}

impl SparseSystem for InfiniGen {
    fn name(&self) -> &'static str {
        "infinigen"
    }

    fn decode(&mut self, q: &[f32], budget: usize, out: &mut [f32]) -> DecodeStats {
        let n = self.n();
        let nc = self.n_channels;
        let budget = budget.min(n).max(1);
        // Speculative partial scores on the GPU-resident skinny cache.
        let qp: Vec<f32> = self.channels.iter().map(|&j| q[j]).collect();
        let scores: Vec<f32> = (0..n)
            .map(|i| {
                let p = &self.partial[i * nc..(i + 1) * nc];
                qp.iter().zip(p).map(|(a, b)| a * b).sum()
            })
            .collect();
        let mut order: Vec<usize> = (0..n).collect();
        if budget < n {
            order.select_nth_unstable_by(budget - 1, |&a, &b| {
                scores[b].partial_cmp(&scores[a]).unwrap()
            });
        }
        let sel: Vec<usize> = order[..budget].to_vec();
        subset_attention(q, &self.keys, &self.vals, self.d, &sel, out);
        DecodeStats {
            exact_positions: sel.iter().map(|&i| i as u32).collect(),
            // selected tokens fetched over PCIe every step (no cache)
            pcie_bytes: 2 * sel.len() * self.d * 4,
            hbm_bytes: 2 * sel.len() * self.d * 4,
            // speculation scans the partial key cache on GPU
            scan_bytes: n * nc * 4,
            ..DecodeStats::default()
        }
    }

    fn append(&mut self, key: &[f32], val: &[f32]) {
        self.keys.extend_from_slice(key);
        self.vals.extend_from_slice(val);
        let row: Vec<f32> = self.channels.iter().map(|&j| key[j]).collect();
        self.partial.extend_from_slice(&row);
    }

    fn kv_on_gpu(&self) -> bool {
        true // the partial key cache scales with context and lives on GPU
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn channels_are_high_energy_dims() {
        let d = 8;
        let mut rng = Rng::new(11);
        let mut keys = rng.normal_vec(64 * d);
        // blow up dim 5
        for i in 0..64 {
            keys[i * d + 5] *= 10.0;
        }
        let vals = rng.normal_vec(64 * d);
        let ig = InfiniGen::new(&keys, &vals, d, 2);
        assert!(ig.channels.contains(&5));
    }

    #[test]
    fn speculation_finds_strong_needle() {
        let d = 16;
        let mut rng = Rng::new(12);
        let mut keys = rng.normal_vec(256 * d);
        let vals = rng.normal_vec(256 * d);
        let dir = rng.normal_vec(d);
        for j in 0..d {
            keys[50 * d + j] = 5.0 * dir[j];
        }
        let q: Vec<f32> = dir.iter().map(|x| 5.0 * x).collect();
        let mut sys = InfiniGen::new(&keys, &vals, d, 8);
        let mut out = vec![0.0; d];
        let st = sys.decode(&q, 32, &mut out);
        assert!(st.exact_positions.contains(&50));
        assert!(st.pcie_bytes > 0, "fetches over PCIe");
    }

    #[test]
    fn partial_scores_are_lossy() {
        // With very few channels, selection quality degrades vs full dot —
        // the speculation error mode the paper describes.
        let d = 32;
        let mut rng = Rng::new(13);
        let keys = rng.normal_vec(512 * d);
        let vals = rng.normal_vec(512 * d);
        let q = rng.normal_vec(d);
        let mut few = InfiniGen::new(&keys, &vals, d, 2);
        let mut many = InfiniGen::new(&keys, &vals, d, 32);
        let mut o1 = vec![0.0; d];
        let mut o2 = vec![0.0; d];
        let s_few = few.decode(&q, 32, &mut o1);
        let s_many = many.decode(&q, 32, &mut o2);
        // with all channels, selection == true top-32; fewer channels
        // must not produce an identical set on random geometry
        assert_ne!(s_few.exact_positions, s_many.exact_positions);
    }

    #[test]
    fn append_extends_partial_cache() {
        let d = 8;
        let mut rng = Rng::new(14);
        let keys = rng.normal_vec(16 * d);
        let vals = rng.normal_vec(16 * d);
        let mut sys = InfiniGen::new(&keys, &vals, d, 4);
        sys.append(&rng.normal_vec(d), &rng.normal_vec(d));
        assert_eq!(sys.n(), 17);
        assert_eq!(sys.partial.len(), 17 * 4);
    }
}
