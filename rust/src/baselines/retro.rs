//! RetroInfer behind the common [`SparseSystem`] interface: wave index
//! selection + tripartite attention, with an optional wave buffer for
//! cache-aware data-movement accounting.

use super::{DecodeStats, SparseSystem};
use crate::buffer::{ExecBuffer, WaveBuffer};
use crate::config::{BufferConfig, ZoneConfig};
use crate::index::{DecodeScratch, SelectScratch, WaveIndex};
use crate::util::threadpool::ThreadPool;
use std::sync::Arc;

pub struct Retro {
    index: WaveIndex,
    buffer: Option<WaveBuffer>,
    exec: ExecBuffer,
    scratch: SelectScratch,
    attend: DecodeScratch,
}

impl Retro {
    /// Build with paper-default zones scaled to the context length, plus
    /// a wave buffer at 5% GPU cache.
    pub fn build_default(keys: &[f32], vals: &[f32], d: usize, seed: u64) -> Self {
        let n = keys.len() / d;
        let cfg = ZoneConfig {
            // scale segment sizes down for short synthetic contexts
            build_segment: ZoneConfig::default().build_segment.min((n / 2).max(64)),
            update_segment: ZoneConfig::default().update_segment.min((n / 8).max(32)),
            ..ZoneConfig::default()
        };
        Self::build(cfg, BufferConfig::default(), keys, vals, d, seed)
    }

    pub fn build(
        zcfg: ZoneConfig,
        bcfg: BufferConfig,
        keys: &[f32],
        vals: &[f32],
        d: usize,
        seed: u64,
    ) -> Self {
        let n = keys.len() / d;
        let index = WaveIndex::build(zcfg, d, bcfg.block_bytes, keys, vals, seed);
        let cap = WaveBuffer::capacity_for(&bcfg, n, index.store().tokens_per_block());
        let pool = Arc::new(ThreadPool::new(bcfg.cpu_threads.max(1)));
        let buffer = WaveBuffer::new(bcfg, d, index.store().tokens_per_block(), cap, pool);
        buffer.register_index(&index);
        Retro {
            index,
            buffer: Some(buffer),
            exec: ExecBuffer::new(d),
            scratch: SelectScratch::default(),
            attend: DecodeScratch::default(),
        }
    }

    /// Index-only variant (no buffer accounting), for accuracy sweeps.
    pub fn index_only(zcfg: ZoneConfig, keys: &[f32], vals: &[f32], d: usize, seed: u64) -> Self {
        let index = WaveIndex::build(zcfg, d, 2048, keys, vals, seed);
        Retro {
            index,
            buffer: None,
            exec: ExecBuffer::new(d),
            scratch: SelectScratch::default(),
            attend: DecodeScratch::default(),
        }
    }

    pub fn index(&self) -> &WaveIndex {
        &self.index
    }

    pub fn buffer(&self) -> Option<&WaveBuffer> {
        self.buffer.as_ref()
    }

    /// The block arena this system's KV storage is checked out of.
    pub fn arena(&self) -> &std::sync::Arc<crate::kvcache::BlockArena> {
        self.index.arena()
    }
}

impl SparseSystem for Retro {
    fn name(&self) -> &'static str {
        "retroinfer"
    }

    fn decode(&mut self, q: &[f32], budget: usize, out: &mut [f32]) -> DecodeStats {
        let m = self.index.meta().m();
        let tpc = self.index.cfg().tokens_per_cluster;
        let r = (budget / tpc.max(1)).min(m).max(if m > 0 { 1 } else { 0 });
        let e = self.index.cfg().estimation_clusters(m).min(m.saturating_sub(r));
        // Selection and attention run through the reusable scratches:
        // steady-state decode allocates nothing here.
        let sel = self.index.select_into(q, r, e, &mut self.scratch);
        let d = self.index.d();

        let (pcie, hbm) = if let Some(buf) = &self.buffer {
            let st = buf.assemble(&self.index, sel, &mut self.exec);
            (st.pcie_bytes, st.g2g_bytes)
        } else {
            // no cache: every retrieved block crosses PCIe
            let bytes: usize = sel
                .retrieval
                .iter()
                .map(|&c| 2 * self.index.meta().cluster_tokens(c as usize).len() * d * 4)
                .sum();
            (bytes, 2 * self.index.steady_tokens() * d * 4)
        };
        self.index.attend_with(q, sel, &mut self.attend, out);
        DecodeStats {
            exact_positions: self.index.exact_positions(sel),
            pcie_bytes: pcie,
            hbm_bytes: hbm,
            // centroid scoring scans the meta index
            scan_bytes: self.index.meta().gpu_bytes(),
            ..DecodeStats::default()
        }
    }

    fn append(&mut self, key: &[f32], val: &[f32]) {
        self.index.append(key, val);
        if let Some(buf) = &self.buffer {
            buf.sync_new_clusters(&self.index);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full_attention;
    use crate::util::rng::Rng;
    use crate::util::stats::cosine;

    #[test]
    fn sparse_decode_tracks_full_attention() {
        let d = 16;
        let n = 1024;
        let mut rng = Rng::new(20);
        // clustered keys so the index has structure to exploit
        let dirs: Vec<Vec<f32>> = (0..16).map(|_| rng.normal_vec(d)).collect();
        let mut keys = Vec::new();
        for i in 0..n {
            let t = &dirs[(i / 64) % 16];
            for j in 0..d {
                keys.push(2.0 * t[j] + 0.4 * rng.normal_f32());
            }
        }
        let vals = rng.normal_vec(n * d);
        let mut sys = Retro::build_default(&keys, &vals, d, 1);
        let q: Vec<f32> = dirs[5].iter().map(|x| 1.5 * x).collect();
        let mut out = vec![0.0; d];
        let st = sys.decode(&q, 128, &mut out);
        let mut full = vec![0.0; d];
        full_attention(&q, &keys, &vals, d, &mut full);
        assert!(cosine(&out, &full) > 0.95, "cos = {}", cosine(&out, &full));
        assert!(st.exact_positions.len() < n / 2, "must be sparse");
    }

    #[test]
    fn buffer_reduces_pcie_on_repeat() {
        let d = 16;
        let mut rng = Rng::new(21);
        let keys = rng.normal_vec(1024 * d);
        let vals = rng.normal_vec(1024 * d);
        let mut sys = Retro::build_default(&keys, &vals, d, 2);
        let q = rng.normal_vec(d);
        let mut out = vec![0.0; d];
        let s1 = sys.decode(&q, 64, &mut out);
        if let Some(b) = sys.buffer() {
            b.flush();
        }
        let s2 = sys.decode(&q, 64, &mut out);
        assert!(s2.pcie_bytes < s1.pcie_bytes, "{} !< {}", s2.pcie_bytes, s1.pcie_bytes);
    }

    #[test]
    fn append_then_decode_includes_new_tokens() {
        let d = 8;
        let mut rng = Rng::new(22);
        let keys = rng.normal_vec(256 * d);
        let vals = rng.normal_vec(256 * d);
        let mut sys = Retro::build_default(&keys, &vals, d, 3);
        for _ in 0..100 {
            sys.append(&rng.normal_vec(d), &rng.normal_vec(d));
        }
        let q = rng.normal_vec(d);
        let mut out = vec![0.0; d];
        let st = sys.decode(&q, 64, &mut out);
        assert!(st.exact_positions.iter().any(|&p| p >= 256), "recent tokens covered");
        assert!(out.iter().all(|x| x.is_finite()));
    }
}
