//! Sparse-attention baselines, re-implemented from scratch over the same
//! KV substrate (DESIGN.md §1): full attention, StreamingLLM (sink+window),
//! Quest (chunk min/max representatives), MagicPIG (SimHash LSH sampling),
//! InfiniGen (partial-channel speculation), PQCache (product quantization),
//! and RetroInfer itself behind the same interface.
//!
//! Each system owns its selection policy AND reports its data-movement
//! pattern, so both accuracy figures (10-12, 18-19) and throughput
//! figures (13-17, via `memsim`) can compare them on equal footing.

pub mod full;
pub mod infinigen;
pub mod magicpig;
pub mod pqcache;
pub mod quest;
pub mod retro;
pub mod streaming;

pub use full::FullAttention;
pub use infinigen::InfiniGen;
pub use magicpig::MagicPig;
pub use pqcache::PqCache;
pub use quest::Quest;
pub use retro::Retro;
pub use streaming::StreamingLlm;

/// Data-movement accounting for one decode step of one head.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DecodeStats {
    /// Context positions attended exactly (for recall / needle scoring).
    pub exact_positions: Vec<u32>,
    /// KV bytes transferred over PCIe (CPU -> GPU).
    pub pcie_bytes: usize,
    /// KV bytes read from GPU HBM (exact attention + cache hits).
    pub hbm_bytes: usize,
    /// KV bytes read by the CPU (CPU-side attention, e.g. MagicPIG).
    pub cpu_bytes: usize,
    /// Bytes scanned over representatives/meta structures per step.
    pub scan_bytes: usize,
}

/// A sparse-attention system serving a single (layer, kv-head) context.
pub trait SparseSystem {
    fn name(&self) -> &'static str;

    /// Compute attention output for query `q` with a budget of roughly
    /// `budget` exactly-attended tokens. Writes `out` (`d` floats).
    fn decode(&mut self, q: &[f32], budget: usize, out: &mut [f32]) -> DecodeStats;

    /// Append a newly generated token's KV.
    fn append(&mut self, key: &[f32], val: &[f32]);

    /// Whether the system supports decode-time index updates
    /// (MagicPIG does not — Table 1 / Fig. 17b exclusions).
    fn supports_updates(&self) -> bool {
        true
    }

    /// Whether the KV cache must reside in GPU memory (OOM behaviour).
    fn kv_on_gpu(&self) -> bool {
        false
    }
}

/// Construct every system over the same context, at the paper's settings.
pub fn all_systems(
    keys: &[f32],
    vals: &[f32],
    d: usize,
    seed: u64,
) -> Vec<Box<dyn SparseSystem>> {
    vec![
        Box::new(FullAttention::new(keys, vals, d)),
        Box::new(StreamingLlm::new(keys, vals, d, 4)),
        Box::new(Quest::new(keys, vals, d, 16)),
        Box::new(MagicPig::new(keys, vals, d, 8, 48, seed)),
        Box::new(InfiniGen::new(keys, vals, d, (d / 2).max(4))),
        Box::new(PqCache::new(keys, vals, d, 2, 16, seed)),
        Box::new(Retro::build_default(keys, vals, d, seed)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full_attention;
    use crate::util::rng::Rng;
    use crate::util::stats::cosine;

    /// Every system must degrade gracefully toward full attention as the
    /// budget grows to the whole context.
    #[test]
    fn all_systems_converge_at_full_budget() {
        let d = 16;
        let n = 512;
        let mut rng = Rng::new(5);
        let keys = rng.normal_vec(n * d);
        let vals = rng.normal_vec(n * d);
        let q = rng.normal_vec(d);
        let mut full = vec![0.0; d];
        full_attention(&q, &keys, &vals, d, &mut full);
        for sys in all_systems(&keys, &vals, d, 7).iter_mut() {
            let mut out = vec![0.0; d];
            sys.decode(&q, n, &mut out);
            let c = cosine(&out, &full);
            // LSH sampling (MagicPIG) is stochastic; others must be >0.99.
            let floor = if sys.name() == "magicpig" { 0.8 } else { 0.99 };
            assert!(c > floor, "{} at full budget: cos={c}", sys.name());
        }
    }

    #[test]
    fn stats_have_positions_within_context() {
        let d = 8;
        let n = 256;
        let mut rng = Rng::new(6);
        let keys = rng.normal_vec(n * d);
        let vals = rng.normal_vec(n * d);
        let q = rng.normal_vec(d);
        for sys in all_systems(&keys, &vals, d, 8).iter_mut() {
            let mut out = vec![0.0; d];
            let st = sys.decode(&q, 32, &mut out);
            for &p in &st.exact_positions {
                assert!((p as usize) < n, "{}: position {p} out of range", sys.name());
            }
            assert!(out.iter().all(|x| x.is_finite()), "{}: non-finite output", sys.name());
        }
    }
}
