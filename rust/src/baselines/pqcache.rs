//! PQCache (Zhang et al., SIGMOD'25): product-quantization retrieval.
//! Keys are split into `m` subspaces; each subspace gets a k-means
//! codebook; tokens are stored as code tuples. At decode time an ADC
//! (asymmetric distance computation) table scores all tokens cheaply;
//! the top-budget tokens are fetched from CPU memory for exact attention.

use super::{DecodeStats, SparseSystem};
use crate::attention::subset_attention;
use crate::index::spherical_kmeans;

pub struct PqCache {
    d: usize,
    m: usize,
    ncodes: usize,
    sub: usize,
    keys: Vec<f32>,
    vals: Vec<f32>,
    /// `[m, ncodes, sub]` codebooks.
    codebooks: Vec<f32>,
    /// `[n, m]` code assignments.
    codes: Vec<u8>,
}

impl PqCache {
    /// `m` partitions, `ncodes` centroids per partition (paper: 2
    /// partitions, 6-bit codes for <=16K contexts).
    pub fn new(keys: &[f32], vals: &[f32], d: usize, m: usize, ncodes: usize, seed: u64) -> Self {
        assert!(d % m == 0 && ncodes <= 256);
        let sub = d / m;
        let n = keys.len() / d;
        let mut codebooks = vec![0.0f32; m * ncodes * sub];
        let mut codes = vec![0u8; n * m];
        for s in 0..m {
            // gather subvectors
            let mut subvecs = vec![0.0f32; n * sub];
            for i in 0..n {
                subvecs[i * sub..(i + 1) * sub]
                    .copy_from_slice(&keys[i * d + s * sub..i * d + (s + 1) * sub]);
            }
            let cl = spherical_kmeans(&subvecs, sub, ncodes, 8, false, seed ^ s as u64);
            for c in 0..cl.k {
                codebooks[(s * ncodes + c) * sub..(s * ncodes + c + 1) * sub]
                    .copy_from_slice(&cl.centroids[c * sub..(c + 1) * sub]);
            }
            for i in 0..n {
                codes[i * m + s] = cl.assign[i] as u8;
            }
        }
        PqCache { d, m, ncodes, sub, keys: keys.to_vec(), vals: vals.to_vec(), codebooks, codes }
    }

    fn n(&self) -> usize {
        self.keys.len() / self.d
    }

    /// ADC score of token `i` given per-subspace lookup tables.
    fn adc_score(&self, tables: &[f32], i: usize) -> f32 {
        let mut s = 0.0;
        for sp in 0..self.m {
            let c = self.codes[i * self.m + sp] as usize;
            s += tables[sp * self.ncodes + c];
        }
        s
    }
}

impl SparseSystem for PqCache {
    fn name(&self) -> &'static str {
        "pqcache"
    }

    fn decode(&mut self, q: &[f32], budget: usize, out: &mut [f32]) -> DecodeStats {
        let n = self.n();
        let budget = budget.min(n).max(1);
        // Build ADC tables: q_sub . codeword for every (subspace, code).
        let mut tables = vec![0.0f32; self.m * self.ncodes];
        for sp in 0..self.m {
            let qs = &q[sp * self.sub..(sp + 1) * self.sub];
            for c in 0..self.ncodes {
                let cw = &self.codebooks[(sp * self.ncodes + c) * self.sub
                    ..(sp * self.ncodes + c + 1) * self.sub];
                tables[sp * self.ncodes + c] = qs.iter().zip(cw).map(|(a, b)| a * b).sum();
            }
        }
        let scores: Vec<f32> = (0..n).map(|i| self.adc_score(&tables, i)).collect();
        let mut order: Vec<usize> = (0..n).collect();
        if budget < n {
            order.select_nth_unstable_by(budget - 1, |&a, &b| {
                scores[b].partial_cmp(&scores[a]).unwrap()
            });
        }
        let sel: Vec<usize> = order[..budget].to_vec();
        subset_attention(q, &self.keys, &self.vals, self.d, &sel, out);
        DecodeStats {
            exact_positions: sel.iter().map(|&i| i as u32).collect(),
            pcie_bytes: 2 * sel.len() * self.d * 4,
            hbm_bytes: 2 * sel.len() * self.d * 4,
            // code scan (1 byte per code) + codebook fetch per step — the
            // overhead that grows with context (paper §5.3).
            scan_bytes: n * self.m + self.m * self.ncodes * self.sub * 4,
            ..DecodeStats::default()
        }
    }

    fn append(&mut self, key: &[f32], val: &[f32]) {
        // assign to the nearest existing codeword per subspace
        let d = self.d;
        self.keys.extend_from_slice(key);
        self.vals.extend_from_slice(val);
        for sp in 0..self.m {
            let ks = &key[sp * self.sub..(sp + 1) * self.sub];
            let mut best = 0u8;
            let mut best_s = f32::NEG_INFINITY;
            for c in 0..self.ncodes {
                let cw = &self.codebooks
                    [(sp * self.ncodes + c) * self.sub..(sp * self.ncodes + c + 1) * self.sub];
                let s: f32 = ks.iter().zip(cw).map(|(a, b)| a * b).sum();
                if s > best_s {
                    best_s = s;
                    best = c as u8;
                }
            }
            self.codes.push(best);
        }
        let _ = d;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn adc_finds_strong_needle() {
        let d = 16;
        let mut rng = Rng::new(15);
        let mut keys = rng.normal_vec(512 * d);
        let vals = rng.normal_vec(512 * d);
        let dir = rng.normal_vec(d);
        for j in 0..d {
            keys[200 * d + j] = 5.0 * dir[j];
        }
        let q: Vec<f32> = dir.iter().map(|x| 5.0 * x).collect();
        let mut sys = PqCache::new(&keys, &vals, d, 2, 16, 1);
        let mut out = vec![0.0; d];
        let st = sys.decode(&q, 48, &mut out);
        assert!(st.exact_positions.contains(&200));
    }

    #[test]
    fn codes_compress_context() {
        let d = 16;
        let mut rng = Rng::new(16);
        let keys = rng.normal_vec(256 * d);
        let vals = rng.normal_vec(256 * d);
        let sys = PqCache::new(&keys, &vals, d, 4, 16, 2);
        assert_eq!(sys.codes.len(), 256 * 4);
        // 4 bytes/token vs 64 bytes of raw keys: 16x compression
        assert!(sys.codes.len() * 16 <= keys.len() * 4);
    }

    #[test]
    fn append_assigns_codes() {
        let d = 8;
        let mut rng = Rng::new(17);
        let keys = rng.normal_vec(64 * d);
        let vals = rng.normal_vec(64 * d);
        let mut sys = PqCache::new(&keys, &vals, d, 2, 8, 3);
        sys.append(&rng.normal_vec(d), &rng.normal_vec(d));
        assert_eq!(sys.n(), 65);
        assert_eq!(sys.codes.len(), 65 * 2);
    }

    #[test]
    fn coarse_quantization_is_lossy() {
        // ADC ranking != exact ranking in general: with tiny codebooks the
        // selected set differs from the true top-k on random geometry.
        let d = 16;
        let mut rng = Rng::new(18);
        let keys = rng.normal_vec(512 * d);
        let vals = rng.normal_vec(512 * d);
        let q = rng.normal_vec(d);
        let mut sys = PqCache::new(&keys, &vals, d, 2, 4, 4);
        let mut out = vec![0.0; d];
        let st = sys.decode(&q, 32, &mut out);
        let w = crate::attention::attention_weights(&q, &keys, d);
        let truth: Vec<usize> =
            crate::attention::sparsity::top_k_indices(&w, 32);
        let sel: std::collections::HashSet<u32> = st.exact_positions.iter().copied().collect();
        let hits = truth.iter().filter(|&&t| sel.contains(&(t as u32))).count();
        assert!(hits < 32, "4-code PQ cannot be exact: {hits}/32");
    }
}
