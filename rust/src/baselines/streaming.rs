//! StreamingLLM-style fixed-position heuristic: attention sinks + a
//! sliding local window. The static-sparsity baseline whose accuracy
//! collapses on retrieval tasks (paper §2.3 "fixed-position heuristics").

use super::{DecodeStats, SparseSystem};
use crate::attention::subset_attention;

pub struct StreamingLlm {
    d: usize,
    keys: Vec<f32>,
    vals: Vec<f32>,
    sink: usize,
}

impl StreamingLlm {
    pub fn new(keys: &[f32], vals: &[f32], d: usize, sink: usize) -> Self {
        StreamingLlm { d, keys: keys.to_vec(), vals: vals.to_vec(), sink }
    }

    fn n(&self) -> usize {
        self.keys.len() / self.d
    }
}

impl SparseSystem for StreamingLlm {
    fn name(&self) -> &'static str {
        "streaming"
    }

    fn decode(&mut self, q: &[f32], budget: usize, out: &mut [f32]) -> DecodeStats {
        let n = self.n();
        let sink = self.sink.min(n);
        let window = budget.saturating_sub(sink).min(n - sink);
        let mut sel: Vec<usize> = (0..sink).collect();
        sel.extend(n - window..n);
        subset_attention(q, &self.keys, &self.vals, self.d, &sel, out);
        DecodeStats {
            exact_positions: sel.iter().map(|&i| i as u32).collect(),
            hbm_bytes: 2 * sel.len() * self.d * 4,
            ..DecodeStats::default()
        }
    }

    fn append(&mut self, key: &[f32], val: &[f32]) {
        self.keys.extend_from_slice(key);
        self.vals.extend_from_slice(val);
    }

    fn kv_on_gpu(&self) -> bool {
        true // only sink+window ever used; effectively tiny GPU footprint
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn selects_sink_and_tail() {
        let d = 4;
        let mut rng = Rng::new(2);
        let keys = rng.normal_vec(64 * d);
        let vals = rng.normal_vec(64 * d);
        let mut sys = StreamingLlm::new(&keys, &vals, d, 4);
        let q = rng.normal_vec(d);
        let mut out = vec![0.0; d];
        let st = sys.decode(&q, 12, &mut out);
        assert_eq!(st.exact_positions.len(), 12);
        assert_eq!(&st.exact_positions[..4], &[0, 1, 2, 3]);
        assert_eq!(st.exact_positions[4], 56); // window start
        assert_eq!(*st.exact_positions.last().unwrap(), 63);
    }

    #[test]
    fn misses_mid_context_needle() {
        // The defining failure mode: a needle in the middle is never
        // selected regardless of its attention weight.
        let d = 4;
        let mut rng = Rng::new(3);
        let keys = rng.normal_vec(128 * d);
        let vals = rng.normal_vec(128 * d);
        let mut sys = StreamingLlm::new(&keys, &vals, d, 4);
        let q = rng.normal_vec(d);
        let mut out = vec![0.0; d];
        let st = sys.decode(&q, 16, &mut out);
        assert!(!st.exact_positions.contains(&64));
    }
}
