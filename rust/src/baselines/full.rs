//! Full attention: the accuracy gold standard and the FlashInfer-style
//! efficiency baseline. All KV resides in GPU memory; every step reads
//! the entire cache.

use super::{DecodeStats, SparseSystem};
use crate::attention::full_attention;

pub struct FullAttention {
    d: usize,
    keys: Vec<f32>,
    vals: Vec<f32>,
}

impl FullAttention {
    pub fn new(keys: &[f32], vals: &[f32], d: usize) -> Self {
        FullAttention { d, keys: keys.to_vec(), vals: vals.to_vec() }
    }

    pub fn n(&self) -> usize {
        self.keys.len() / self.d
    }
}

impl SparseSystem for FullAttention {
    fn name(&self) -> &'static str {
        "full"
    }

    fn decode(&mut self, q: &[f32], _budget: usize, out: &mut [f32]) -> DecodeStats {
        full_attention(q, &self.keys, &self.vals, self.d, out);
        let n = self.n();
        DecodeStats {
            exact_positions: (0..n as u32).collect(),
            hbm_bytes: 2 * n * self.d * 4,
            ..DecodeStats::default()
        }
    }

    fn append(&mut self, key: &[f32], val: &[f32]) {
        self.keys.extend_from_slice(key);
        self.vals.extend_from_slice(val);
    }

    fn kv_on_gpu(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn reads_whole_cache_every_step() {
        let d = 8;
        let mut rng = Rng::new(1);
        let keys = rng.normal_vec(100 * d);
        let vals = rng.normal_vec(100 * d);
        let mut sys = FullAttention::new(&keys, &vals, d);
        let q = rng.normal_vec(d);
        let mut out = vec![0.0; d];
        let st = sys.decode(&q, 1, &mut out); // budget ignored
        assert_eq!(st.exact_positions.len(), 100);
        assert_eq!(st.hbm_bytes, 2 * 100 * d * 4);
        assert_eq!(st.pcie_bytes, 0);
    }

    #[test]
    fn append_grows_cache() {
        let d = 4;
        let mut sys = FullAttention::new(&[0.0; 8], &[0.0; 8], d);
        sys.append(&[1.0; 4], &[1.0; 4]);
        assert_eq!(sys.n(), 3);
    }
}
