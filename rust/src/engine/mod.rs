//! Serving engine: drives TinyLM through PJRT with the wave index/buffer
//! on the decode path (live engine), and an analytic load simulator for
//! paper-scale end-to-end experiments (Figure 17).

pub mod live;
pub mod sim;

pub use live::{AttnMode, LiveEngine};
pub use sim::{simulate_cluster, simulate_load, LoadReport};
