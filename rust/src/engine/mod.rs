//! Serving engine: drives TinyLM through PJRT with the wave index/buffer
//! on the decode path (live engine), fans per-head execution-buffer
//! assembly across the CPU pool (assemble), and provides an analytic
//! load simulator for paper-scale end-to-end experiments (Figure 17).

pub mod assemble;
pub mod cluster;
pub mod live;
pub mod sim;

pub use assemble::{
    assemble_head, cold_blocks_of, gather_head, select_head, AssembleShape, BatchAssembler,
    HeadSlices, HeadTask,
};
pub use cluster::{ClusterConfig, ClusterEngine, ClusterRunReport};
pub use live::{AttnMode, LiveEngine, SessionSnapshot};
pub use sim::{simulate_cluster, simulate_cluster_detailed, simulate_load, ClusterReport, LoadReport};
