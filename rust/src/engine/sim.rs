//! Paper-scale load simulation (Figure 17): continuous batching over an
//! arrival trace, with per-step costs from `memsim`. Prefills run
//! exclusively (they saturate the device); decode steps batch all active
//! sessions. The behavioural inputs (hit ratio, retrieval fraction) come
//! from measured wave-buffer runs.

use crate::config::{HardwareSpec, ModelSpec};
use crate::memsim::{self, SystemProfile};
use crate::util::stats::Sample;
use crate::workload::RequestSpec;

/// Result of one simulated load run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub name: String,
    pub n_requests: usize,
    pub completed: usize,
    pub makespan_s: f64,
    /// Request throughput (completed / makespan).
    pub req_per_s: f64,
    pub mean_latency_s: f64,
    pub p99_latency_s: f64,
    /// Whether the run OOMed before admitting even one request.
    pub oom: bool,
}

struct Active {
    arrive_s: f64,
    ctx: usize,
    remaining: usize,
}

/// Simulate serving `reqs` with continuous batching and admission cap
/// `max_batch`. Closed-loop entries (`arrive_s == inf`) are released as
/// slots free up.
pub fn simulate_load(
    model: &ModelSpec,
    hw: &HardwareSpec,
    profile: &SystemProfile,
    reqs: &[RequestSpec],
    max_batch: usize,
) -> LoadReport {
    let mut now = 0.0f64;
    let mut queue: Vec<(usize, f64)> = Vec::new(); // (req idx, arrival)
    let mut next = 0usize;
    let mut active: Vec<(usize, Active)> = Vec::new();
    let mut lat = Sample::new();
    let mut completed = 0usize;

    // Feasibility: one request at its full context must fit.
    let ctx_max = reqs.iter().map(|r| r.input_tokens + r.output_tokens).max().unwrap_or(0);
    if memsim::check_fit(model, hw, profile, ctx_max, 1).is_err() {
        return LoadReport {
            name: profile.name.to_string(),
            n_requests: reqs.len(),
            completed: 0,
            makespan_s: 0.0,
            req_per_s: 0.0,
            mean_latency_s: f64::INFINITY,
            p99_latency_s: f64::INFINITY,
            oom: true,
        };
    }

    let cluster_flops = |ctx: usize| memsim::clustering_flops(model, ctx, 8192, 10);
    let is_retro = profile.name.starts_with("retroinfer");
    // Admission cap: never admit more concurrency than fits at the
    // largest per-request context (prevents admit/shed livelock).
    let max_batch = max_batch.min(memsim::max_batch(model, hw, profile, ctx_max)).max(1);

    loop {
        // Admit open-loop arrivals that have happened.
        while next < reqs.len() && reqs[next].arrive_s <= now {
            if reqs[next].arrive_s.is_finite() {
                queue.push((next, reqs[next].arrive_s));
                next += 1;
            } else {
                break;
            }
        }
        // Release closed-loop requests when there is capacity.
        while next < reqs.len()
            && reqs[next].arrive_s.is_infinite()
            && active.len() + queue.len() < max_batch
        {
            queue.push((next, now));
            next += 1;
        }

        if queue.is_empty() && active.is_empty() {
            if next >= reqs.len() {
                break;
            }
            // jump to the next arrival
            now = reqs[next].arrive_s.max(now);
            continue;
        }

        // Prefill one queued request if the pool has room.
        if let Some(pos) = (!queue.is_empty() && active.len() < max_batch).then_some(0) {
            let (ri, arr) = queue.remove(pos);
            let r = &reqs[ri];
            let cf = if is_retro { cluster_flops(r.input_tokens) } else { 0.0 };
            let offload = is_retro || profile.cpu_attention;
            now += memsim::prefill_latency(model, hw, r.input_tokens, cf, offload);
            active.push((
                ri,
                Active { arrive_s: arr, ctx: r.input_tokens, remaining: r.output_tokens },
            ));
            continue;
        }

        // One decode step over all active sessions.
        let b = active.len();
        let ctx_avg = active.iter().map(|(_, a)| a.ctx).sum::<usize>() / b;
        let st = memsim::decode_step(model, hw, profile, ctx_avg, b);
        now += st.total_s;
        for (_, a) in active.iter_mut() {
            a.ctx += 1;
            a.remaining -= 1;
        }
        let mut i = 0;
        while i < active.len() {
            if active[i].1.remaining == 0 {
                let (_, a) = active.swap_remove(i);
                lat.add(now - a.arrive_s);
                completed += 1;
            } else {
                i += 1;
            }
        }
    }

    let mean = lat.mean();
    let p99 = lat.percentile(99.0);
    LoadReport {
        name: profile.name.to_string(),
        n_requests: reqs.len(),
        completed,
        makespan_s: now,
        req_per_s: completed as f64 / now.max(1e-9),
        mean_latency_s: mean,
        p99_latency_s: p99,
        oom: false,
    }
}

/// Multi-GPU serving (paper §4.5): requests are routed across `workers`
/// independent replicas by the least-loaded [`Router`]; each worker runs
/// its own wave index/buffer (no cross-worker coordination — the paper's
/// modularity argument). Returns the aggregate report.
pub fn simulate_cluster(
    model: &ModelSpec,
    hw: &HardwareSpec,
    profile: &SystemProfile,
    reqs: &[RequestSpec],
    max_batch_per_worker: usize,
    workers: usize,
) -> LoadReport {
    use crate::coordinator::Router;
    let mut router = Router::new(workers);
    let mut shards: Vec<Vec<RequestSpec>> = vec![Vec::new(); workers];
    for r in reqs {
        // prefix affinity: requests sharing a template land on the
        // worker already holding that prefix hot (hash-less requests
        // fall back to least-loaded)
        shards[router.route_with_prefix(r.prefix_hash)].push(r.clone());
    }
    let mut completed = 0;
    let mut makespan = 0.0f64;
    let mut lat_sum = 0.0;
    let mut p99 = 0.0f64;
    let mut oom = false;
    for shard in &shards {
        if shard.is_empty() {
            continue;
        }
        let rep = simulate_load(model, hw, profile, shard, max_batch_per_worker);
        oom |= rep.oom;
        completed += rep.completed;
        makespan = makespan.max(rep.makespan_s);
        lat_sum += rep.mean_latency_s * rep.completed as f64;
        p99 = p99.max(rep.p99_latency_s);
    }
    LoadReport {
        name: format!("{}x{}", profile.name, workers),
        n_requests: reqs.len(),
        completed,
        makespan_s: makespan,
        req_per_s: completed as f64 / makespan.max(1e-9),
        mean_latency_s: if completed > 0 { lat_sum / completed as f64 } else { f64::INFINITY },
        p99_latency_s: p99,
        oom,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::profiles;
    use crate::workload::{closed_loop, poisson_arrivals};

    fn setup() -> (ModelSpec, HardwareSpec) {
        (ModelSpec::llama3_8b(), HardwareSpec::a100())
    }

    #[test]
    fn all_requests_complete() {
        let (m, hw) = setup();
        let reqs = poisson_arrivals(0.05, 8, 120 * 1024, 64, 1);
        let rep = simulate_load(&m, &hw, &profiles::retroinfer(0.85), &reqs, 16);
        assert!(!rep.oom);
        assert_eq!(rep.completed, 8);
        assert!(rep.mean_latency_s.is_finite());
        assert!(rep.p99_latency_s >= rep.mean_latency_s * 0.5);
    }

    #[test]
    fn retro_beats_full_under_long_input_load() {
        // Fig 17a: under load, RetroInfer sustains higher request
        // throughput than full attention (which is capped at batch ~4).
        let (m, hw) = setup();
        // the paper's long-input workload: 120K in / 4K out
        let reqs = closed_loop(16, 24, 120 * 1024, 4096);
        let rf = simulate_load(&m, &hw, &profiles::full(), &reqs, 16);
        let rr = simulate_load(&m, &hw, &profiles::retroinfer(0.85), &reqs, 16);
        assert!(!rf.oom && !rr.oom);
        assert!(
            rr.req_per_s > 1.5 * rf.req_per_s,
            "retro {:.4} vs full {:.4} req/s",
            rr.req_per_s,
            rf.req_per_s
        );
    }

    #[test]
    fn closed_loop_releases_all() {
        let (m, hw) = setup();
        let reqs = closed_loop(4, 12, 32 * 1024, 128);
        let rep = simulate_load(&m, &hw, &profiles::retroinfer_gpu(), &reqs, 4);
        assert_eq!(rep.completed, 12);
    }

    #[test]
    fn cluster_scales_request_throughput() {
        // §4.5: wave index/buffer are per-head modular; adding replicas
        // scales request throughput near-linearly under saturating load.
        let (m, hw) = setup();
        let reqs = closed_loop(32, 32, 120 * 1024, 2048);
        let one = simulate_cluster(&m, &hw, &profiles::retroinfer(0.85), &reqs, 16, 1);
        let four = simulate_cluster(&m, &hw, &profiles::retroinfer(0.85), &reqs, 16, 4);
        assert!(!one.oom && !four.oom);
        assert_eq!(four.completed, 32);
        assert!(
            four.req_per_s > 2.5 * one.req_per_s,
            "4 workers: {:.4} vs 1 worker: {:.4}",
            four.req_per_s,
            one.req_per_s
        );
    }

    #[test]
    fn oom_reported_for_infeasible_context() {
        let (m, hw) = setup();
        let reqs = poisson_arrivals(0.1, 2, 1 << 20, 64, 2);
        let rep = simulate_load(&m, &hw, &profiles::full(), &reqs, 4);
        assert!(rep.oom);
        assert_eq!(rep.completed, 0);
    }
}
