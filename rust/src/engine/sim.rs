//! Paper-scale load simulation (Figure 17): continuous batching over an
//! arrival trace, with per-step costs from `memsim`. Prefills run
//! exclusively (they saturate the device); decode steps batch all active
//! sessions. The behavioural inputs (hit ratio, retrieval fraction) come
//! from measured wave-buffer runs.

use crate::config::{HardwareSpec, ModelSpec};
use crate::memsim::{self, SystemProfile};
use crate::util::stats::Sample;
use crate::workload::RequestSpec;

/// Result of one simulated load run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub name: String,
    pub n_requests: usize,
    pub completed: usize,
    pub makespan_s: f64,
    /// Request throughput (completed / makespan).
    pub req_per_s: f64,
    pub mean_latency_s: f64,
    pub p99_latency_s: f64,
    /// Whether the run OOMed before admitting even one request.
    pub oom: bool,
}

struct Active {
    arrive_s: f64,
    ctx: usize,
    remaining: usize,
}

/// Simulate serving `reqs` with continuous batching and admission cap
/// `max_batch`. Closed-loop entries (`arrive_s == inf`) are released as
/// slots free up.
pub fn simulate_load(
    model: &ModelSpec,
    hw: &HardwareSpec,
    profile: &SystemProfile,
    reqs: &[RequestSpec],
    max_batch: usize,
) -> LoadReport {
    let mut now = 0.0f64;
    let mut queue: Vec<(usize, f64)> = Vec::new(); // (req idx, arrival)
    let mut next = 0usize;
    let mut active: Vec<(usize, Active)> = Vec::new();
    let mut lat = Sample::new();
    let mut completed = 0usize;

    // Feasibility: one request at its full context must fit.
    let ctx_max = reqs.iter().map(|r| r.input_tokens + r.output_tokens).max().unwrap_or(0);
    if memsim::check_fit(model, hw, profile, ctx_max, 1).is_err() {
        return LoadReport {
            name: profile.name.to_string(),
            n_requests: reqs.len(),
            completed: 0,
            makespan_s: 0.0,
            req_per_s: 0.0,
            mean_latency_s: f64::INFINITY,
            p99_latency_s: f64::INFINITY,
            oom: true,
        };
    }

    let cluster_flops = |ctx: usize| memsim::clustering_flops(model, ctx, 8192, 10);
    let is_retro = profile.name.starts_with("retroinfer");
    // Admission cap: never admit more concurrency than fits at the
    // largest per-request context (prevents admit/shed livelock).
    let max_batch = max_batch.min(memsim::max_batch(model, hw, profile, ctx_max)).max(1);

    // Closed-loop entries (`arrive_s == inf`) release in trace order as
    // capacity frees up; finite arrivals admit whenever their time has
    // come, even when queued *behind* a closed-loop entry in the trace
    // (a mixed trace must not strand its open-loop tail).
    let mut admitted = vec![false; reqs.len()];
    let mut released = 0usize; // closed-loop entries released so far

    loop {
        // Admit open-loop arrivals that have happened, scanning past
        // closed-loop entries instead of stopping at the first one.
        for (ri, r) in reqs.iter().enumerate().skip(next) {
            if !r.arrive_s.is_finite() {
                continue;
            }
            if r.arrive_s > now {
                break;
            }
            if !admitted[ri] {
                admitted[ri] = true;
                queue.push((ri, r.arrive_s));
            }
        }
        // Release closed-loop requests (in trace order) when there is
        // capacity.
        while active.len() + queue.len() < max_batch {
            let Some(ri) = reqs
                .iter()
                .enumerate()
                .skip(released)
                .find(|(ri, r)| r.arrive_s.is_infinite() && !admitted[*ri])
                .map(|(ri, _)| ri)
            else {
                break;
            };
            admitted[ri] = true;
            released = ri + 1;
            queue.push((ri, now));
        }
        while next < reqs.len() && admitted[next] {
            next += 1;
        }

        if queue.is_empty() && active.is_empty() {
            if next >= reqs.len() {
                break;
            }
            // jump to the next arrival
            now = reqs[next].arrive_s.max(now);
            continue;
        }

        // Prefill one queued request if the pool has room.
        if let Some(pos) = (!queue.is_empty() && active.len() < max_batch).then_some(0) {
            let (ri, arr) = queue.remove(pos);
            let r = &reqs[ri];
            let cf = if is_retro { cluster_flops(r.input_tokens) } else { 0.0 };
            let offload = is_retro || profile.cpu_attention;
            now += memsim::prefill_latency(model, hw, r.input_tokens, cf, offload);
            if r.output_tokens == 0 {
                // Prefill-only request (embedding/scoring-style): it is
                // done the moment prefill lands. Entering the decode
                // pool would underflow `remaining -= 1`.
                lat.add(now - arr);
                completed += 1;
            } else {
                active.push((
                    ri,
                    Active { arrive_s: arr, ctx: r.input_tokens, remaining: r.output_tokens },
                ));
            }
            continue;
        }

        // One decode step over all active sessions.
        let b = active.len();
        let ctx_avg = active.iter().map(|(_, a)| a.ctx).sum::<usize>() / b;
        let st = memsim::decode_step(model, hw, profile, ctx_avg, b);
        now += st.total_s;
        for (_, a) in active.iter_mut() {
            a.ctx += 1;
            a.remaining -= 1;
        }
        let mut i = 0;
        while i < active.len() {
            if active[i].1.remaining == 0 {
                let (_, a) = active.swap_remove(i);
                lat.add(now - a.arrive_s);
                completed += 1;
            } else {
                i += 1;
            }
        }
    }

    let mean = lat.mean();
    let p99 = lat.percentile(99.0);
    LoadReport {
        name: profile.name.to_string(),
        n_requests: reqs.len(),
        completed,
        makespan_s: now,
        req_per_s: completed as f64 / now.max(1e-9),
        mean_latency_s: mean,
        p99_latency_s: p99,
        oom: false,
    }
}

/// A cluster run broken out per shard: the aggregate plus each worker's
/// own [`LoadReport`] (so an OOM shard is attributable instead of
/// silently poisoning the aggregate).
#[derive(Clone, Debug)]
pub struct ClusterReport {
    pub aggregate: LoadReport,
    pub shards: Vec<LoadReport>,
}

/// Multi-GPU serving (paper §4.5): requests are routed across `workers`
/// independent replicas by the least-loaded [`Router`]; each worker runs
/// its own wave index/buffer (no cross-worker coordination — the paper's
/// modularity argument). Returns the aggregate report.
pub fn simulate_cluster(
    model: &ModelSpec,
    hw: &HardwareSpec,
    profile: &SystemProfile,
    reqs: &[RequestSpec],
    max_batch_per_worker: usize,
    workers: usize,
) -> LoadReport {
    simulate_cluster_detailed(model, hw, profile, reqs, max_batch_per_worker, workers).aggregate
}

/// Like [`simulate_cluster`], but also returns every shard's own report.
pub fn simulate_cluster_detailed(
    model: &ModelSpec,
    hw: &HardwareSpec,
    profile: &SystemProfile,
    reqs: &[RequestSpec],
    max_batch_per_worker: usize,
    workers: usize,
) -> ClusterReport {
    use crate::coordinator::Router;
    let mut router = Router::new(workers);
    let mut shards: Vec<Vec<RequestSpec>> = vec![Vec::new(); workers];
    for r in reqs {
        // prefix affinity: requests sharing a template land on the
        // worker already holding that prefix hot (hash-less requests
        // fall back to least-loaded)
        shards[router.route_with_prefix(r.prefix_hash)].push(r.clone());
    }
    let mut completed = 0;
    let mut makespan = 0.0f64;
    let mut lat_sum = 0.0;
    let mut lat_weight = 0usize;
    let mut p99 = 0.0f64;
    let mut oom = false;
    let mut shard_reports = Vec::new();
    for shard in &shards {
        if shard.is_empty() {
            continue;
        }
        let rep = simulate_load(model, hw, profile, shard, max_batch_per_worker);
        oom |= rep.oom;
        completed += rep.completed;
        makespan = makespan.max(rep.makespan_s);
        // Weight each shard's mean by its completions, skipping shards
        // that completed nothing: an OOM shard reports
        // `mean_latency_s == inf` with `completed == 0`, and
        // `inf × 0 = NaN` would poison the aggregate. Such shards are
        // still visible through `oom` and their own entry in `shards`.
        if rep.completed > 0 && rep.mean_latency_s.is_finite() {
            lat_sum += rep.mean_latency_s * rep.completed as f64;
            lat_weight += rep.completed;
        }
        if rep.p99_latency_s.is_finite() {
            p99 = p99.max(rep.p99_latency_s);
        }
        shard_reports.push(rep);
    }
    let aggregate = LoadReport {
        name: format!("{}x{}", profile.name, workers),
        n_requests: reqs.len(),
        completed,
        makespan_s: makespan,
        req_per_s: completed as f64 / makespan.max(1e-9),
        mean_latency_s: if lat_weight > 0 { lat_sum / lat_weight as f64 } else { f64::INFINITY },
        p99_latency_s: if lat_weight > 0 { p99 } else { f64::INFINITY },
        oom,
    };
    ClusterReport { aggregate, shards: shard_reports }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::profiles;
    use crate::workload::{closed_loop, poisson_arrivals};

    fn setup() -> (ModelSpec, HardwareSpec) {
        (ModelSpec::llama3_8b(), HardwareSpec::a100())
    }

    #[test]
    fn all_requests_complete() {
        let (m, hw) = setup();
        let reqs = poisson_arrivals(0.05, 8, 120 * 1024, 64, 1);
        let rep = simulate_load(&m, &hw, &profiles::retroinfer(0.85), &reqs, 16);
        assert!(!rep.oom);
        assert_eq!(rep.completed, 8);
        assert!(rep.mean_latency_s.is_finite());
        assert!(rep.p99_latency_s >= rep.mean_latency_s * 0.5);
    }

    #[test]
    fn retro_beats_full_under_long_input_load() {
        // Fig 17a: under load, RetroInfer sustains higher request
        // throughput than full attention (which is capped at batch ~4).
        let (m, hw) = setup();
        // the paper's long-input workload: 120K in / 4K out
        let reqs = closed_loop(16, 24, 120 * 1024, 4096);
        let rf = simulate_load(&m, &hw, &profiles::full(), &reqs, 16);
        let rr = simulate_load(&m, &hw, &profiles::retroinfer(0.85), &reqs, 16);
        assert!(!rf.oom && !rr.oom);
        assert!(
            rr.req_per_s > 1.5 * rf.req_per_s,
            "retro {:.4} vs full {:.4} req/s",
            rr.req_per_s,
            rf.req_per_s
        );
    }

    #[test]
    fn closed_loop_releases_all() {
        let (m, hw) = setup();
        let reqs = closed_loop(4, 12, 32 * 1024, 128);
        let rep = simulate_load(&m, &hw, &profiles::retroinfer_gpu(), &reqs, 4);
        assert_eq!(rep.completed, 12);
    }

    #[test]
    fn cluster_scales_request_throughput() {
        // §4.5: wave index/buffer are per-head modular; adding replicas
        // scales request throughput near-linearly under saturating load.
        let (m, hw) = setup();
        let reqs = closed_loop(32, 32, 120 * 1024, 2048);
        let one = simulate_cluster(&m, &hw, &profiles::retroinfer(0.85), &reqs, 16, 1);
        let four = simulate_cluster(&m, &hw, &profiles::retroinfer(0.85), &reqs, 16, 4);
        assert!(!one.oom && !four.oom);
        assert_eq!(four.completed, 32);
        assert!(
            four.req_per_s > 2.5 * one.req_per_s,
            "4 workers: {:.4} vs 1 worker: {:.4}",
            four.req_per_s,
            one.req_per_s
        );
    }

    #[test]
    fn oom_reported_for_infeasible_context() {
        let (m, hw) = setup();
        let reqs = poisson_arrivals(0.1, 2, 1 << 20, 64, 2);
        let rep = simulate_load(&m, &hw, &profiles::full(), &reqs, 4);
        assert!(rep.oom);
        assert_eq!(rep.completed, 0);
    }

    #[test]
    fn cluster_mean_survives_oom_shard() {
        // Regression: a shard whose every request is infeasible reports
        // `mean_latency_s == inf` with `completed == 0`; the aggregate
        // used to compute `inf × 0 = NaN`. Build a trace where one
        // prefix-affinity group is infeasibly long so exactly one shard
        // OOMs while the others complete.
        let (m, hw) = setup();
        let mut reqs = closed_loop(8, 8, 32 * 1024, 128);
        // Pin the infeasible requests to one worker via prefix affinity.
        for (i, r) in reqs.iter_mut().enumerate() {
            r.prefix_hash = Some(if i < 2 { 0xBAD } else { 0x60 + (i as u64 % 3) });
            if i < 2 {
                r.input_tokens = 1 << 20; // cannot fit on any worker
            }
        }
        let det = simulate_cluster_detailed(&m, &hw, &profiles::full(), &reqs, 4, 4);
        let rep = &det.aggregate;
        assert!(rep.oom, "the infeasible shard must surface as oom");
        assert!(det.shards.iter().any(|s| s.oom && s.completed == 0));
        assert!(rep.completed > 0 && rep.completed < reqs.len());
        assert!(
            rep.mean_latency_s.is_finite() && !rep.mean_latency_s.is_nan(),
            "aggregate mean poisoned: {}",
            rep.mean_latency_s
        );
        assert!(rep.p99_latency_s.is_finite());
    }

    #[test]
    fn prefill_only_requests_complete_without_underflow() {
        // Regression: `output_tokens == 0` entered the decode pool and
        // underflowed `remaining -= 1` (panic in debug, wrap + hang in
        // release). Such requests must complete at prefill time.
        let (m, hw) = setup();
        let reqs = poisson_arrivals(0.5, 6, 16 * 1024, 0, 3);
        let rep = simulate_load(&m, &hw, &profiles::retroinfer(0.85), &reqs, 4);
        assert!(!rep.oom);
        assert_eq!(rep.completed, 6);
        assert!(rep.mean_latency_s.is_finite() && rep.mean_latency_s > 0.0);
        // Mixed trace: prefill-only alongside normal decode requests.
        let mut mixed = poisson_arrivals(0.5, 6, 16 * 1024, 32, 4);
        for r in mixed.iter_mut().skip(3) {
            r.output_tokens = 0;
        }
        let rep = simulate_load(&m, &hw, &profiles::retroinfer(0.85), &mixed, 4);
        assert_eq!(rep.completed, 6);
    }

    #[test]
    fn open_loop_arrival_behind_closed_loop_entry_is_admitted() {
        // Regression: the arrival scan `break`ed at the first
        // `arrive_s == inf` entry, so a finite arrival sequenced after a
        // closed-loop entry in the trace was never admitted and the
        // simulation either dropped it or spun. Mixed traces must
        // complete every request.
        let (m, hw) = setup();
        let mut reqs = closed_loop(2, 6, 32 * 1024, 64); // 2 at t=0, 4 at inf
        reqs.push(RequestSpec {
            arrive_s: 1.0,
            input_tokens: 32 * 1024,
            output_tokens: 64,
            tenant: 0,
            prefix_hash: None,
        });
        let rep = simulate_load(&m, &hw, &profiles::retroinfer(0.85), &reqs, 4);
        assert!(!rep.oom);
        assert_eq!(rep.completed, 7, "open-loop tail request stranded");
        assert!(rep.mean_latency_s.is_finite());
    }
}
